#!/usr/bin/env python
"""Live-mutation parity gate (``make mutation-parity``, part of ``make
check``) — DESIGN.md §10.

Asserts, for every registered engine × codec, over a monolithic AND a
sharded (n_shards=4) base:

1. **pre-merge parity** — after a scripted insert / delete / update
   sequence (tombstones in base and segments, a reused stable id), the
   ``MutableRetriever`` top-k is BYTE-identical (ids and scores) to an
   oracle ``Retriever.build`` over the post-mutation corpus, under
   exhaustive engine budgets;
2. **post-merge parity** — merge/compaction folds segments + tombstones
   into a fresh generation and the same oracle match holds;
3. **crash-injection open** — a crash between the new generation's
   write and the ``CURRENT`` flip leaves the PREVIOUS generation
   loadable via ``open_retriever`` and serving byte-identically; the
   retried merge then flips cleanly and reopens at the new generation.

Exit status = number of failures (0 = pass).
"""

from __future__ import annotations

import os
import shutil
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))

import numpy as np  # noqa: E402

from repro.core.layout import available_layouts  # noqa: E402
from repro.data.synthetic import SyntheticConfig, generate_collection  # noqa: E402
from repro.serve.api import (  # noqa: E402
    Retriever,
    RetrieverConfig,
    available_engines,
    open_retriever,
)
from repro.serve.segments import InjectedCrash, MutableRetriever  # noqa: E402

#: budgets exhaustive for the 50-doc parity corpus (candidate sets
#: identical mutable vs oracle, so top-k must match byte-for-byte)
ENGINE_PARAMS = {
    "seismic": dict(cut=16, block_budget=512, n_probe=512, n_postings=10000,
                    block_size=8),
    "hnsw": dict(beam=64, iters=64, n_seeds=4, m=8, ef_construction=48),
    "flat": {},
}

N_BASE = 40
SHARD_COUNTS = (1, 4)


def _fail(errors: list, msg: str) -> None:
    errors.append(msg)
    print(f"FAIL {msg}")


def _mutate(m: MutableRetriever, fwd) -> None:
    """The scripted stream: 2 inserts (4 + 3 docs), deletes in base AND
    segment, one update-in-place (stable-id reuse)."""
    m.insert([fwd.doc(i) for i in range(N_BASE, N_BASE + 4)])
    m.delete([3, 17, N_BASE + 1])
    m.update([fwd.doc(N_BASE + 4)], ids=[10])
    m.insert([fwd.doc(i) for i in range(N_BASE + 5, N_BASE + 8)])


def _parity(m, oracle_ids, oracle_sc, live, Q) -> str | None:
    mi, ms = map(np.asarray, m.search(Q))
    if not np.array_equal(mi, live[oracle_ids]):
        return "ids"
    if not np.array_equal(ms, oracle_sc):
        return "scores"
    return None


def main() -> int:
    errors: list[str] = []
    col = generate_collection(
        SyntheticConfig(name="mutation-parity", dim=256, n_docs=50,
                        n_queries=4, doc_nnz_mean=24.0, query_nnz_mean=8.0,
                        seed=7),
        value_format="f16",
    )
    fwd = col.fwd
    Q = np.stack([col.query_dense(i) for i in range(col.n_queries)])
    tmp = tempfile.mkdtemp(prefix="mutation-parity-")
    try:
        for engine in available_engines():
            for codec in available_layouts():
                for n_shards in SHARD_COUNTS:
                    cfg = RetrieverConfig(engine=engine, codec=codec, k=10,
                                          n_shards=n_shards,
                                          params=ENGINE_PARAMS[engine])
                    m = MutableRetriever.create(fwd.slice(0, N_BASE), cfg)
                    _mutate(m, fwd)
                    live_fwd, live = m.live_corpus()
                    oracle = Retriever.build(live_fwd, cfg.replace(n_shards=1))
                    oi, osc = map(np.asarray, oracle.search(Q))
                    tag = f"{engine}×{codec} S={n_shards}"
                    bad = _parity(m, oi, osc, live, Q)
                    if bad:
                        _fail(errors, f"pre-merge {bad} parity: {tag}")
                        continue
                    m.merge()
                    bad = _parity(m, oi, osc, live, Q)
                    if bad:
                        _fail(errors, f"post-merge {bad} parity: {tag}")
                    else:
                        print(f"ok mutation    {tag} "
                              f"(pre- and post-merge, {m.n_live} live)")

        # crash injection over the persisted artifact root (one
        # engine×codec is enough: the commit protocol is engine-blind)
        cfg = RetrieverConfig(engine="flat", codec="streamvbyte", k=10,
                              params={})
        root = os.path.join(tmp, "idx")
        m = MutableRetriever.create(fwd.slice(0, N_BASE), cfg, root=root)
        _mutate(m, fwd)
        want = np.asarray(m.search(Q)[0])
        try:
            m.merge(crash_before_flip=True)
            _fail(errors, "crash injection: InjectedCrash not raised")
        except InjectedCrash:
            pass
        r = open_retriever(root)
        if r.generation != 0 or len(r.segments) != len(m.segments):
            _fail(errors, f"crash injection: reopened generation "
                          f"{r.generation} with {len(r.segments)} segments "
                          f"(wanted gen 0 intact)")
        elif not np.array_equal(np.asarray(r.search(Q)[0]), want):
            _fail(errors, "crash injection: pre-crash generation serves "
                          "different top-k after reopen")
        else:
            m.merge()  # the retry reclaims the orphan dir and flips
            r2 = open_retriever(root)
            if r2.generation != 1 or r2.segments:
                _fail(errors, "crash injection: retried merge did not flip")
            elif not np.array_equal(np.asarray(r2.search(Q)[0]),
                                    np.asarray(m.search(Q)[0])):
                _fail(errors, "crash injection: post-retry reopen diverges")
            else:
                print("ok crash-open  flat×streamvbyte (gen 0 intact after "
                      "injected crash; retried flip reopens at gen 1)")
    finally:
        shutil.rmtree(tmp, ignore_errors=True)

    if errors:
        print(f"mutation-parity: {len(errors)} failure(s)")
    else:
        print("mutation-parity OK")
    return len(errors)


if __name__ == "__main__":
    sys.exit(main())
