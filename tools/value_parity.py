#!/usr/bin/env python
"""Value-codec parity gate (``make value-parity``, part of ``make
check``).

Asserts, on a tiny synthetic collection with an empty-document edge
case, for every value codec on the vq axis (DESIGN.md §12):

1. **losslessness of the default** — ``vq="f16"`` is a pure tag:
   packing with it yields byte-identical arrays to a legacy pack that
   never heard of the vq axis, rows AND blocks, for every id codec;
2. **rows-kernel 3-mode parity** — for every id codec × quantized vq,
   the fused rows kernel (``pallas_interpret`` and ``pallas_compiled``)
   matches the jnp gather→dequant→dot reference to the repo's parity
   contract (scores allclose rtol=1e-5/atol=1e-6 — quantized decode is
   exact per slot; only reduction order may differ);
3. **end-to-end 3-mode parity** — ``Retriever`` top-k ids are
   byte-identical across ``jnp`` / ``pallas_interpret`` /
   ``pallas_compiled`` for every engine × id codec × quantized vq,
   with allclose scores;
4. **quality floor** — exhaustive (flat) top-k overlap of each
   quantized vq against the full-precision oracle stays above the
   per-codec floor: ≥0.95 for u8_sq, ≥0.85 for u4_sq and pq.

Exit status = number of failures (0 = pass).
"""

from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import layout  # noqa: E402
from repro.core.forward_index import ForwardIndex, pack_forward_index  # noqa: E402
from repro.core.scoring import score_candidate_rows  # noqa: E402
from repro.data.synthetic import SyntheticConfig, generate_collection  # noqa: E402
from repro.kernels.registry import get_kernels  # noqa: E402
from repro.serve.api import Retriever, RetrieverConfig, available_engines, get_engine  # noqa: E402

from tools.kernel_parity import ENGINE_PARAMS, FUSED_MODES  # noqa: E402

#: quantized value codecs on the vq axis (``f16`` is the lossless tag)
QUANT_VQS = ("u8_sq", "u4_sq", "pq")

#: minimum mean top-k overlap vs the full-precision oracle
OVERLAP_FLOOR = {"u8_sq": 0.95, "u4_sq": 0.85, "pq": 0.85}


def _fail(errors: list, msg: str) -> None:
    errors.append(msg)
    print(f"FAIL {msg}")


def main() -> int:
    errors: list[str] = []
    cfg = SyntheticConfig(name="value-parity", dim=1024, n_docs=150,
                          n_queries=8, doc_nnz_mean=40.0,
                          query_nnz_mean=12.0, seed=0)
    col = generate_collection(cfg, value_format="f16")
    docs = [col.fwd.doc(d) for d in range(col.fwd.n_docs)]
    docs.append((np.zeros(0, np.uint32), np.zeros(0, np.float32)))
    fwd = ForwardIndex.from_docs(docs, col.fwd.dim, value_format="f16")
    n = fwd.n_docs
    q = col.query_dense(0)
    Q = np.stack([col.query_dense(i) for i in range(col.n_queries)])
    scale = float(fwd.value_format.scale)
    rng = np.random.default_rng(0)
    cand = np.concatenate(
        [rng.choice(n, 48, replace=False), [n, n - 1, 7, 7]]
    ).astype(np.int32)  # sentinel + duplicate ids included

    for codec in layout.available_layouts():
        # 1. vq="f16" is byte-identical to a pack that predates the axis
        legacy = layout.pack_rows(fwd, codec=codec).arrays()
        tagged = layout.pack_rows(fwd, codec=codec, vq="f16")
        if tagged.vq != "f16":
            _fail(errors, f"f16 tag: pack_rows({codec}).vq == {tagged.vq!r}")
        for k, v in tagged.arrays().items():
            if k not in legacy or not np.array_equal(legacy[k], np.asarray(v)):
                _fail(errors, f"f16 losslessness: {codec} rows array {k!r} "
                              f"differs from legacy pack")
                break
        else:
            print(f"ok f16-rows    {codec}: byte-identical to legacy pack")
        pb_legacy = pack_forward_index(fwd, codec=codec, block_size=128)
        pb_tagged = pack_forward_index(fwd, codec=codec, block_size=128,
                                       vq="f16")
        for k, v in pb_legacy.as_dict().items():
            w = pb_tagged.as_dict().get(k)
            same = (v is None and w is None) or (
                v is not None and w is not None
                and np.array_equal(np.asarray(v), np.asarray(w))
            )
            if not same:
                _fail(errors, f"f16 losslessness: {codec} block field {k!r} "
                              f"differs from legacy pack")
                break
        else:
            print(f"ok f16-blocks  {codec}: byte-identical to legacy pack")

        # 2. rows-kernel 3-mode parity at every quantized vq
        for vq in QUANT_VQS:
            arrays = {
                k: jnp.asarray(v)
                for k, v in layout.pack_rows(fwd, codec=codec, vq=vq).arrays().items()
            }
            want = np.asarray(
                score_candidate_rows(codec, arrays, jnp.asarray(cand),
                                     jnp.asarray(q), scale, backend="jnp")
            )
            ks = get_kernels(codec)
            for mode in FUSED_MODES:
                got = np.asarray(
                    ks.rows_scores(arrays, jnp.asarray(cand), jnp.asarray(q),
                                   scale, mode)
                )
                if not np.allclose(got, want, rtol=1e-5, atol=1e-6):
                    _fail(errors, f"rows parity: {codec}+{vq} [{mode}]")
                else:
                    print(f"ok rows-kernel {codec}+{vq} [{mode}]")

    # 3. end-to-end parity across all three modes, engine × codec × vq
    hosts = {}
    for e in available_engines():
        impl = get_engine(e)
        if hasattr(impl, "host_index"):
            hosts[e] = impl.host_index(
                fwd, RetrieverConfig(engine=e, params=ENGINE_PARAMS[e]))
    for engine in available_engines():
        for codec in layout.available_layouts():
            for vq in QUANT_VQS:
                def build(backend):
                    c = RetrieverConfig(engine=engine, codec=codec, vq=vq,
                                        backend=backend, k=10,
                                        params=ENGINE_PARAMS[engine])
                    if engine in hosts:
                        return Retriever.from_host_index(hosts[engine], c)
                    return Retriever.build(fwd, c)
                ij, sj = build("jnp").search(Q)
                ij, sj = np.asarray(ij), np.asarray(sj)
                for backend in FUSED_MODES:
                    ib, sb = build(backend).search(Q)
                    if not np.array_equal(ij, np.asarray(ib)):
                        _fail(errors, f"top-k id parity: {engine}×{codec}+{vq} "
                                      f"[{backend}]")
                    elif not np.allclose(sj, np.asarray(sb), rtol=1e-5,
                                         atol=1e-6):
                        _fail(errors, f"top-k score parity: "
                                      f"{engine}×{codec}+{vq} [{backend}]")
                    else:
                        print(f"ok backend     {engine}×{codec}+{vq} "
                              f"[{backend}]")

    # 4. quality floor: exhaustive top-k overlap vs the f16 oracle
    def flat(vq):
        return Retriever.build(fwd, RetrieverConfig(engine="flat", vq=vq, k=10))
    oracle_ids, _ = flat("f16").search(Q)
    oracle_ids = np.asarray(oracle_ids)
    for vq in QUANT_VQS:
        ids, _ = flat(vq).search(Q)
        ids = np.asarray(ids)
        overlap = float(np.mean([
            len(set(oracle_ids[i].tolist()) & set(ids[i].tolist())) / oracle_ids.shape[1]
            for i in range(oracle_ids.shape[0])
        ]))
        floor = OVERLAP_FLOOR[vq]
        if overlap < floor:
            _fail(errors, f"quality floor: {vq} top-k overlap "
                          f"{overlap:.3f} < {floor}")
        else:
            print(f"ok quality     {vq}: top-k overlap {overlap:.3f} "
                  f"≥ {floor}")

    if errors:
        print(f"value-parity: {len(errors)} failure(s)")
    else:
        print("value-parity OK")
    return len(errors)


if __name__ == "__main__":
    sys.exit(main())
