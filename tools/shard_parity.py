#!/usr/bin/env python
"""Sharded-serving parity gate (``make shard-parity``, part of ``make
check``).

Asserts, for every registered engine × codec (mirroring
``tools/kernel_parity.py``):

1. **top-k parity** — the sharded retriever (n_shards ∈ {4, 7}; 7 over
   a 50-doc corpus exercises the ragged last shard) returns
   BYTE-identical ids and scores to the unsharded oracle under
   exhaustive engine budgets — sharding must be invisible to callers;
2. **mmap round-trip** — a saved shard tree reopened via
   ``open_retriever`` serves from ``np.memmap`` views and still
   answers byte-identically;
3. **on-disk bytes** — the FORWARD-INDEX row payload (the quantity the
   paper compresses, and the term that dominates index size at scale)
   summed over shards stays within 1.02× of the monolithic build for
   every engine × codec; for the disjoint-range engines (flat, hnsw)
   the bound also holds for the whole ``arrays.npz`` sum. Seismic's
   *navigational* structures (block summaries, block→doc lists) are
   structurally larger when split into self-contained shards — every
   shard re-blocks its own posting lists, so block-padding waste
   multiplies with the shard count — which a coarse ≤ 2.5× backstop
   keeps from regressing further.

Exit status = number of failures (0 = pass).
"""

from __future__ import annotations

import os
import shutil
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))

import numpy as np  # noqa: E402

from repro.core.layout import available_layouts  # noqa: E402
from repro.data.synthetic import SyntheticConfig, generate_collection  # noqa: E402
from repro.serve.api import (  # noqa: E402
    Retriever,
    RetrieverConfig,
    available_engines,
    open_retriever,
)
from repro.serve.sharded import SHARD_DIR_FMT, ShardedRetriever  # noqa: E402

#: budgets exhaustive for the 50-doc parity corpus (candidate sets
#: identical sharded vs not, so top-k must match byte-for-byte)
ENGINE_PARAMS = {
    "seismic": dict(cut=16, block_budget=512, n_probe=512, n_postings=10000,
                    block_size=8),
    "hnsw": dict(beam=56, iters=56, n_seeds=4, m=8, ef_construction=48),
    "flat": {},
}

#: bytes-gate corpus is larger so fixed per-shard overheads amortize
BYTES_N_DOCS = 600
BYTES_TOLERANCE = 1.02
#: backstop for seismic's whole-archive ratio (see module docstring)
NAV_BACKSTOP = 2.5
SHARD_COUNTS = (4, 7)


def _fail(errors: list, msg: str) -> None:
    errors.append(msg)
    print(f"FAIL {msg}")


def _collection(n_docs: int, dim: int, seed: int):
    return generate_collection(
        SyntheticConfig(name="shard-parity", dim=dim, n_docs=n_docs,
                        n_queries=4, doc_nnz_mean=24.0, query_nnz_mean=8.0,
                        seed=seed),
        value_format="f16",
    )


def _npz_bytes(tree, n_shards: int) -> int:
    return sum(
        os.path.getsize(os.path.join(tree, SHARD_DIR_FMT.format(s), "arrays.npz"))
        for s in range(n_shards)
    )


def main() -> int:
    errors: list[str] = []
    col = _collection(50, 256, seed=7)
    Q = np.stack([col.query_dense(i) for i in range(col.n_queries)])
    tmp = tempfile.mkdtemp(prefix="shard-parity-")
    try:
        for engine in available_engines():
            for codec in available_layouts():
                cfg = RetrieverConfig(engine=engine, codec=codec, k=10,
                                      params=ENGINE_PARAMS[engine])
                oracle = Retriever.build(col.fwd, cfg)
                ids_o, sc_o = map(np.asarray, oracle.search(Q))
                for n_shards in SHARD_COUNTS:
                    r = Retriever.build(col.fwd, cfg.replace(n_shards=n_shards))
                    ids, sc = map(np.asarray, r.search(Q))
                    if not np.array_equal(ids, ids_o):
                        _fail(errors, f"top-k id parity: {engine}×{codec} S={n_shards}")
                    elif not np.array_equal(sc, sc_o):
                        _fail(errors, f"top-k score parity: {engine}×{codec} S={n_shards}")
                    else:
                        print(f"ok sharded     {engine}×{codec} S={n_shards}")
                # mmap round-trip through the artifact tree (S=4)
                tree = os.path.join(tmp, f"{engine}-{codec}")
                Retriever.build(col.fwd, cfg.replace(n_shards=4)).save(tree)
                r2 = open_retriever(tree)
                mapped = isinstance(r2, ShardedRetriever) and all(
                    isinstance(a, np.memmap)
                    for sh in r2.shards for a in sh.arrays.values() if a.size
                )
                ids2, sc2 = map(np.asarray, r2.search(Q))
                if not mapped:
                    _fail(errors, f"mmap open: {engine}×{codec} not memory-mapped")
                elif not (np.array_equal(ids2, ids_o) and np.array_equal(sc2, sc_o)):
                    _fail(errors, f"mmap round-trip parity: {engine}×{codec}")
                else:
                    print(f"ok mmap        {engine}×{codec}")
                shutil.rmtree(tree)

        # on-disk bytes: sum of shard payloads vs monolithic (both
        # uncompressed npz — the format mmap_npz requires)
        def row_bytes(arrays) -> int:
            return sum(np.asarray(v).nbytes for k, v in arrays.items()
                       if k.endswith("_rows"))

        bcol = _collection(BYTES_N_DOCS, 512, seed=0)
        for engine in available_engines():
            for codec in available_layouts():
                # build-time knobs only (no search here): engine
                # defaults, except hnsw graph params kept small
                params = ENGINE_PARAMS[engine] if engine == "hnsw" else {}
                cfg = RetrieverConfig(engine=engine, codec=codec, k=10,
                                      params=params)
                mono_dir = os.path.join(tmp, "mono")
                mono_r = Retriever.build(bcol.fwd, cfg)
                mono_r.save(mono_dir, compress=False)
                mono = os.path.getsize(os.path.join(mono_dir, "arrays.npz"))
                mono_rows = row_bytes(mono_r.arrays)
                tree = os.path.join(tmp, "tree")
                sh_r = Retriever.build(bcol.fwd, cfg.replace(n_shards=4))
                sh_r.save(tree)
                sharded = _npz_bytes(tree, 4)
                sh_rows = sum(row_bytes(sh.arrays) for sh in sh_r.shards)
                rratio, nratio = sh_rows / mono_rows, sharded / mono
                npz_bound = (BYTES_TOLERANCE if engine != "seismic"
                             else NAV_BACKSTOP)
                if rratio > BYTES_TOLERANCE:
                    _fail(errors,
                          f"disk bytes: {engine}×{codec} sharded row payload "
                          f"{sh_rows} > {BYTES_TOLERANCE}× monolithic "
                          f"{mono_rows} (ratio {rratio:.3f})")
                elif nratio > npz_bound:
                    _fail(errors,
                          f"disk bytes: {engine}×{codec} sharded npz "
                          f"{sharded} > {npz_bound}× monolithic {mono} "
                          f"(ratio {nratio:.3f})")
                else:
                    print(f"ok disk-bytes  {engine}×{codec}: rows "
                          f"{rratio:.3f} ≤ {BYTES_TOLERANCE}, npz "
                          f"{nratio:.3f} ≤ {npz_bound}")
                shutil.rmtree(mono_dir)
                shutil.rmtree(tree)
    finally:
        shutil.rmtree(tmp, ignore_errors=True)

    if errors:
        print(f"shard-parity: {len(errors)} failure(s)")
    else:
        print("shard-parity OK")
    return len(errors)


if __name__ == "__main__":
    sys.exit(main())
