#!/usr/bin/env python
"""Overlapped-serving parity gate (``make overlap-parity``, part of
``make check``).

Overlap must be invisible in the bytes (DESIGN.md §11): prefetch,
background compaction and the tombstone-aware mesh are latency
mechanisms, never answer mechanisms. For every registered engine ×
codec this asserts:

1. **prefetch parity** — the out-of-core sequential path over an
   mmap'd shard tree at ``max_resident=1`` answers BYTE-identically
   with the host prefetcher on and off, and the prefetcher actually
   ran (staged buffers consumed, ``prefetch_hits`` > 0);
2. **mesh + live tombstones** — with the host forced to 8 devices,
   ``use_mesh=True`` (which raises rather than falling back) over a
   tombstoned sharded index answers byte-identically to the
   sequential rotation over the same index, and no tombstoned doc
   surfaces in the top-k;
3. **background-merge parity** — queries racing a
   ``merge(background=True)`` from submission THROUGH the commit flip
   return byte-identical answers to the pre-merge result (compaction
   does not change the live corpus), and the post-flip generation
   answers byte-identically too.

Exit status = number of failures (0 = pass).
"""

from __future__ import annotations

import os
import shutil
import sys
import tempfile

# the mesh leg needs ≥ n_shards devices: force host platform devices
# BEFORE jax initializes (same trick as the sharded test suite)
os.environ["XLA_FLAGS"] = (
    os.environ.get("XLA_FLAGS", "")
    + " --xla_force_host_platform_device_count=8"
).strip()

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))

import numpy as np  # noqa: E402

from repro.core.layout import available_layouts  # noqa: E402
from repro.data.synthetic import SyntheticConfig, generate_collection  # noqa: E402
from repro.serve.api import (  # noqa: E402
    Retriever,
    RetrieverConfig,
    available_engines,
    open_retriever,
)
from repro.serve.segments import MutableRetriever  # noqa: E402

#: budgets exhaustive for the 50-doc parity corpus (candidate sets
#: identical across serving paths, so top-k must match byte-for-byte)
ENGINE_PARAMS = {
    "seismic": dict(cut=16, block_budget=512, n_probe=512, n_postings=10000,
                    block_size=8),
    "hnsw": dict(beam=56, iters=56, n_seeds=4, m=8, ef_construction=48),
    "flat": {},
}

N_SHARDS = 4
#: dead docs spanning shards (50-doc corpus → shard ranges of ~13/12)
TOMBSTONES = (0, 12, 13, 26, 49)


def _fail(errors: list, msg: str) -> None:
    errors.append(msg)
    print(f"FAIL {msg}")


def _collection():
    return generate_collection(
        SyntheticConfig(name="overlap-parity", dim=256, n_docs=50,
                        n_queries=4, doc_nnz_mean=24.0, query_nnz_mean=8.0,
                        seed=7),
        value_format="f16",
    )


def _prefetch_leg(errors, col, Q, cfg, engine, codec, tmp) -> None:
    tree = os.path.join(tmp, f"{engine}-{codec}")
    Retriever.build(col.fwd, cfg.replace(n_shards=N_SHARDS)).save(tree)
    res = {}
    for label, prefetch in (("off", False), ("on", True)):
        r = open_retriever(tree)
        r.use_mesh = False  # this leg prices the out-of-core rotation
        r.max_resident = 1
        r.prefetch = prefetch
        for _ in range(2):  # two passes: the wrap-around stage lands
            ids, sc = map(np.asarray, r.search(Q))
        res[label] = (ids, sc, r.prefetch_hits)
    (ids0, sc0, _), (ids1, sc1, hits) = res["off"], res["on"]
    if not (np.array_equal(ids0, ids1) and np.array_equal(sc0, sc1)):
        _fail(errors, f"prefetch parity: {engine}×{codec} on≠off")
    elif hits == 0:
        _fail(errors, f"prefetch inert: {engine}×{codec} consumed no "
                      f"staged shard (hits=0)")
    else:
        print(f"ok prefetch    {engine}×{codec} (hits={hits})")
    shutil.rmtree(tree)


def _mesh_leg(errors, col, Q, cfg, engine, codec) -> None:
    r = Retriever.build(col.fwd, cfg.replace(n_shards=N_SHARDS))
    r.set_tombstones(np.asarray(TOMBSTONES, np.int64))
    r.use_mesh = False
    ids_seq, sc_seq = map(np.asarray, r.search(Q))
    r.use_mesh = True  # raises instead of falling back sequential
    ids_m, sc_m = map(np.asarray, r.search(Q))
    dead_served = np.intersect1d(ids_m.ravel(), np.asarray(TOMBSTONES))
    if not (np.array_equal(ids_m, ids_seq) and np.array_equal(sc_m, sc_seq)):
        _fail(errors, f"mesh tombstone parity: {engine}×{codec} "
                      f"mesh ≠ sequential")
    elif dead_served.size:
        _fail(errors, f"mesh tombstones: {engine}×{codec} served dead "
                      f"docs {dead_served.tolist()}")
    else:
        print(f"ok mesh-tombs  {engine}×{codec}")


def _merge_leg(errors, col, Q, cfg, engine, codec) -> None:
    m = MutableRetriever.create(col.fwd.slice(0, 40), cfg)
    m.insert([col.fwd.doc(i) for i in range(40, 50)])
    m.delete([1, 3, 41])
    ids0, sc0 = map(np.asarray, m.search(Q))
    handle = m.merge(background=True)
    during = 0
    while not handle.done() and during < 25:
        ids, sc = map(np.asarray, m.search(Q))
        if not (np.array_equal(ids, ids0) and np.array_equal(sc, sc0)):
            _fail(errors, f"merge parity: {engine}×{codec} diverged "
                          f"DURING background merge (iteration {during})")
            handle.result()
            return
        during += 1
    handle.result()
    ids2, sc2 = map(np.asarray, m.search(Q))
    if not (np.array_equal(ids2, ids0) and np.array_equal(sc2, sc0)):
        _fail(errors, f"merge parity: {engine}×{codec} post-flip "
                      f"generation diverged")
    else:
        print(f"ok bg-merge    {engine}×{codec} "
              f"(gen={m.generation}, {during} during-merge checks)")


def main() -> int:
    errors: list[str] = []
    col = _collection()
    Q = np.stack([col.query_dense(i) for i in range(col.n_queries)])
    tmp = tempfile.mkdtemp(prefix="overlap-parity-")
    try:
        for engine in available_engines():
            for codec in available_layouts():
                cfg = RetrieverConfig(engine=engine, codec=codec, k=10,
                                      params=ENGINE_PARAMS[engine])
                _prefetch_leg(errors, col, Q, cfg, engine, codec, tmp)
                _mesh_leg(errors, col, Q, cfg, engine, codec)
                _merge_leg(errors, col, Q, cfg, engine, codec)
    finally:
        shutil.rmtree(tmp, ignore_errors=True)

    if errors:
        print(f"overlap-parity: {len(errors)} failure(s)")
    else:
        print("overlap-parity OK")
    return len(errors)


if __name__ == "__main__":
    sys.exit(main())
