#!/usr/bin/env python
"""Docs cross-reference gate (``make docs-check``).

Verifies, with zero third-party deps:

1. every ``DESIGN.md §N`` / ``EXPERIMENTS.md §X`` citation in source
   docstrings and the markdown docs resolves to a real heading. A §
   token is checked when ``DESIGN.md`` or ``EXPERIMENTS.md`` appears
   within a few lines of it (citations wrap across docstring lines);
   it must then exist in the mentioned doc's headings — or, for a bare
   token merely sharing the line window with a doc name (e.g.
   "DESIGN.md §4 / §Perf"), in the union of both docs' headings.
2. every ``make <target>`` named inside README.md code fences exists in
   the Makefile.
3. the documentation spine exists (README.md, DESIGN.md,
   EXPERIMENTS.md).
4. the deprecated per-engine class names (superseded by the
   ``repro.serve.api`` Retriever, DESIGN.md §7; their shim modules are
   deleted) appear nowhere — code and docs must not grow new
   dependencies on a removed surface.

Exit status is the number of dangling references (0 = pass).
"""

from __future__ import annotations

import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
DOC_NAMES = ("DESIGN.md", "EXPERIMENTS.md")
#: meta-placeholders used when *talking about* the citation convention
PLACEHOLDER_TOKENS = {"N", "X"}
#: chars of context on either side of a § token searched for a doc name
WINDOW = 90

SECTION_RE = re.compile(r"§([A-Za-z0-9][A-Za-z0-9_-]*)")
HEADING_RE = re.compile(r"^#+\s*§([A-Za-z0-9][A-Za-z0-9_-]*)", re.M)
FENCE_RE = re.compile(r"```.*?```", re.S)
MAKE_RE = re.compile(r"\bmake\s+([a-z][\w-]*)")
TARGET_RE = re.compile(r"^([a-z][\w-]*):", re.M)

#: per-engine classes superseded by repro.serve.api (DESIGN.md §7);
#: their shim modules were deleted after one deprecation release, so
#: any reference at all now fails the gate
DEPRECATED_RE = re.compile(r"\b(BatchedSeismic|BatchedHNSW)\b")
DEPRECATED_ALLOW = {
    "tools/docs_check.py",  # this file names them to ban them
}


def headings(doc: pathlib.Path) -> set[str]:
    return set(HEADING_RE.findall(doc.read_text(encoding="utf-8")))


def scan_files() -> list[pathlib.Path]:
    files: list[pathlib.Path] = []
    for sub in ("src", "benchmarks", "examples", "tests", "tools"):
        files += sorted((ROOT / sub).rglob("*.py"))
    files += [ROOT / n for n in ("README.md", "DESIGN.md", "EXPERIMENTS.md")]
    return [f for f in files if f.is_file()]


def check_sections(ids: dict[str, set[str]]) -> list[str]:
    errors = []
    union = set().union(*ids.values())
    for path in scan_files():
        text = path.read_text(encoding="utf-8")
        for m in SECTION_RE.finditer(text):
            tok = m.group(1)
            if tok in PLACEHOLDER_TOKENS:
                continue
            window = text[max(0, m.start() - WINDOW): m.end() + WINDOW]
            mentioned = [d for d in DOC_NAMES if d in window]
            if not mentioned:
                continue  # bare §token with no doc attribution — skip
            # adjacent form "<DOC> §tok" is strict; a bare token that
            # merely shares the window with a doc name may resolve in
            # either doc ("DESIGN.md §4 / §Perf" cites both)
            before = text[max(0, m.start() - 20): m.start()]
            strict = [d for d in DOC_NAMES if re.search(re.escape(d) + r"[\s:]*$", before)]
            ok_in = ids[strict[0]] if strict else union
            if tok not in ok_in:
                line = text.count("\n", 0, m.start()) + 1
                owner = strict[0] if strict else "/".join(mentioned)
                errors.append(
                    f"{path.relative_to(ROOT)}:{line}: §{tok} not a heading of {owner}"
                )
    return errors


def check_deprecated_names() -> list[str]:
    errors = []
    for path in scan_files():
        rel = str(path.relative_to(ROOT))
        if rel in DEPRECATED_ALLOW:
            continue
        text = path.read_text(encoding="utf-8")
        for m in DEPRECATED_RE.finditer(text):
            line = text.count("\n", 0, m.start()) + 1
            errors.append(
                f"{rel}:{line}: deprecated name {m.group(1)} referenced outside "
                f"its shim module (use repro.serve.api)"
            )
    return errors


def check_make_targets() -> list[str]:
    readme = (ROOT / "README.md").read_text(encoding="utf-8")
    makefile = (ROOT / "Makefile").read_text(encoding="utf-8")
    targets = set(TARGET_RE.findall(makefile))
    errors = []
    for fence in FENCE_RE.findall(readme):
        for t in MAKE_RE.findall(fence):
            if t not in targets:
                errors.append(f"README.md: `make {t}` is not a Makefile target")
    return errors


def main() -> int:
    errors = []
    for name in ("README.md", *DOC_NAMES):
        if not (ROOT / name).is_file():
            errors.append(f"{name} is missing")
    if errors:
        print("\n".join(errors))
        return len(errors)
    ids = {d: headings(ROOT / d) for d in DOC_NAMES}
    errors += check_sections(ids)
    errors += check_make_targets()
    errors += check_deprecated_names()
    if errors:
        print("\n".join(errors))
        print(f"docs-check: {len(errors)} dangling cross-reference(s)")
    else:
        n = sum(len(v) for v in ids.values())
        print(f"docs-check OK ({n} headings, {len(scan_files())} files scanned)")
    return len(errors)


if __name__ == "__main__":
    sys.exit(main())
