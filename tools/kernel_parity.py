#!/usr/bin/env python
"""Fused-kernel parity gate (``make kernel-parity``, part of ``make
check``).

Asserts, for every codec registered in the kernel registry
(``repro.kernels.registry``), across every execution mode of the tile
program (``pallas_interpret`` and ``pallas_compiled`` — the latter
lowers through Mosaic on TPU hosts and through the tiled XLA fallback
everywhere else, so this gate runs the same sweep on CPU CI), on a
tiny synthetic collection:

1. **block-scan parity** — the fused block kernel matches the jnp
   ``score_packed`` reference (allclose) in both modes;
2. **rows-rescoring parity** — the fused rows kernel matches the jnp
   take→decode→dot chain on a candidate set that includes the sentinel
   id, duplicates and an empty document, in both modes;
3. **end-to-end backend parity** — ``Retriever`` top-k ids are
   byte-identical across all three modes (``jnp`` vs
   ``pallas_interpret`` vs ``pallas_compiled``) for every registered
   engine × codec, with allclose scores;
4. **HBM accounting** — the fused rescoring path streams strictly
   fewer derived HBM bytes per query than the jnp chain, single-query
   AND batched (``benchmarks.kernel_bench.rows_hbm_bytes{,_batch}``).

Exit status = number of failures (0 = pass).
"""

from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import layout  # noqa: E402
from repro.core.forward_index import ForwardIndex, pack_forward_index  # noqa: E402
from repro.core.scoring import score_candidate_rows, score_packed  # noqa: E402
from repro.data.synthetic import SyntheticConfig, generate_collection  # noqa: E402
from repro.kernels.registry import available_kernels, get_kernels  # noqa: E402
from repro.serve.api import Retriever, RetrieverConfig, available_engines, get_engine  # noqa: E402

from benchmarks.kernel_bench import rows_hbm_bytes, rows_hbm_bytes_batch  # noqa: E402

#: fused-kernel execution modes swept by every parity check
FUSED_MODES = ("pallas_interpret", "pallas_compiled")

#: per-engine knobs sized for the tiny parity collection
ENGINE_PARAMS = {
    "seismic": dict(cut=8, block_budget=256, n_probe=32, n_postings=300,
                    block_size=16),
    "hnsw": dict(beam=32, iters=24, n_seeds=4, m=8, ef_construction=32),
    "flat": {},
}


def _fail(errors: list, msg: str) -> None:
    errors.append(msg)
    print(f"FAIL {msg}")


def main() -> int:
    errors: list[str] = []
    cfg = SyntheticConfig(name="parity", dim=1024, n_docs=150, n_queries=4,
                          doc_nnz_mean=40.0, query_nnz_mean=12.0, seed=0)
    col = generate_collection(cfg, value_format="f16")
    # an empty document exercises the nnz=0 row edge case everywhere
    docs = [col.fwd.doc(d) for d in range(col.fwd.n_docs)]
    docs.append((np.zeros(0, np.uint32), np.zeros(0, np.float32)))
    fwd = ForwardIndex.from_docs(docs, col.fwd.dim, value_format="f16")
    n = fwd.n_docs
    q = col.query_dense(0)
    Q = np.stack([col.query_dense(i) for i in range(col.n_queries)])
    scale = float(fwd.value_format.scale)
    rng = np.random.default_rng(0)
    cand = np.concatenate(
        [rng.choice(n, 48, replace=False), [n, n - 1, 7, 7]]
    ).astype(np.int32)  # sentinel + duplicate ids included

    for codec in available_kernels():
        ks = get_kernels(codec)
        # 1. block-scan parity, both fused modes
        if ks.block_scores is not None:
            packed = pack_forward_index(fwd, codec=codec, block_size=128)
            want = np.asarray(score_packed(q, packed))
            for mode in FUSED_MODES:
                got = np.asarray(ks.block_scores(q, packed, mode))
                if not np.allclose(got, want, rtol=1e-4, atol=1e-4):
                    _fail(errors, f"block-scan parity: {codec} [{mode}]")
                else:
                    print(f"ok block-scan  {codec} [{mode}]")
        # 2. rows parity + 4. HBM accounting
        arrays = {k: jnp.asarray(v) for k, v in layout.pack_rows(fwd, codec=codec).arrays().items()}
        want = np.asarray(
            score_candidate_rows(codec, arrays, jnp.asarray(cand), jnp.asarray(q),
                                 scale, backend="jnp")
        )
        for mode in FUSED_MODES:
            got = np.asarray(
                ks.rows_scores(arrays, jnp.asarray(cand), jnp.asarray(q), scale, mode)
            )
            if not np.allclose(got, want, rtol=1e-5, atol=1e-6):
                _fail(errors, f"rows-rescoring parity: {codec} [{mode}]")
            else:
                print(f"ok rows-kernel {codec} [{mode}]")
        fused = rows_hbm_bytes(arrays, codec, len(cand), fused=True)
        chain = rows_hbm_bytes(arrays, codec, len(cand), fused=False)
        if not fused < chain:
            _fail(errors, f"HBM accounting: fused {fused} !< jnp {chain} ({codec})")
        else:
            print(f"ok hbm-bytes   {codec}: fused {fused} < jnp {chain}")
        bfused = rows_hbm_bytes_batch(arrays, codec, len(cand), 8, fused=True)
        bchain = rows_hbm_bytes_batch(arrays, codec, len(cand), 8, fused=False)
        if not bfused < bchain:
            _fail(errors, f"HBM accounting (batched): fused {bfused:.0f} !< "
                          f"jnp {bchain:.0f} ({codec})")
        else:
            print(f"ok hbm-batch   {codec}: fused {bfused:.0f} < jnp {bchain:.0f}")

    # 3. end-to-end parity across all three modes, every engine × codec
    hosts = {}
    for e in available_engines():
        impl = get_engine(e)
        if hasattr(impl, "host_index"):
            hosts[e] = impl.host_index(fwd, RetrieverConfig(engine=e, params=ENGINE_PARAMS[e]))
    for engine in available_engines():
        for codec in layout.available_layouts():
            def build(backend):
                c = RetrieverConfig(engine=engine, codec=codec, backend=backend,
                                    k=10, params=ENGINE_PARAMS[engine])
                if engine in hosts:
                    return Retriever.from_host_index(hosts[engine], c)
                return Retriever.build(fwd, c)
            ij, sj = build("jnp").search(Q)
            ij, sj = np.asarray(ij), np.asarray(sj)
            for backend in FUSED_MODES:
                ib, sb = build(backend).search(Q)
                if not np.array_equal(ij, np.asarray(ib)):
                    _fail(errors, f"top-k id parity: {engine}×{codec} [{backend}]")
                elif not np.allclose(sj, np.asarray(sb), rtol=1e-5, atol=1e-6):
                    _fail(errors, f"top-k score parity: {engine}×{codec} [{backend}]")
                else:
                    print(f"ok backend     {engine}×{codec} [{backend}]")

    if errors:
        print(f"kernel-parity: {len(errors)} failure(s)")
    else:
        print("kernel-parity OK")
    return len(errors)


if __name__ == "__main__":
    sys.exit(main())
