#!/usr/bin/env python
"""Compiled-kernel perf-regression gate (``make perf-gate``, part of
``make check``).

Reads the committed ``BENCH_kernels.json``, re-measures the
``pallas_compiled`` scan and rescoring rows at the committed collection
size, and **NaN-fails** — the regressed row is reported with ``us=nan``
and the exit status is non-zero — whenever a freshly measured compiled
row is slower than the *committed* jnp row for the same codec.

Only (family, codec) pairs whose committed snapshot records a compiled
win (compiled µs ≤ jnp µs) are gated: the gate locks in the wins the
tiled kernels bought, it does not demand wins the snapshot never
claimed (e.g. the decode-free ``uncompressed`` rescoring row, where
fusion buys HBM bytes rather than CPU wall-clock). Rows are selected
via the structured ``mode``/``codec`` fields, never by name parsing.

The overlap leg guards the host prefetcher the same way: when the
committed ``BENCH_overlap.json`` records a prefetch win (a non-NaN
``overlap/prefetch-gate`` row), the prefetch-on/off paced stream is
re-measured fresh and the gate row NaN-fails if prefetch-on p95
regresses past prefetch-off (EXPERIMENTS.md §Overlap).
"""

from __future__ import annotations

import json
import math
import os
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_ROOT, "src"))
sys.path.insert(0, _ROOT)

#: families the gate guards (batch_sweep wall-clock is too noisy at the
#: quick-mode collection size to lock in)
GATED_FAMILIES = ("scan", "rescoring")


def _family(name: str) -> str:
    parts = name.split("/")
    return parts[1] if len(parts) > 1 else name


def _overlap_gate() -> int:
    """NaN-fail when a freshly measured prefetch-on p95 regresses past
    prefetch-off — only once the committed ``BENCH_overlap.json``
    records that win (same locked-in-wins philosophy as the kernel
    leg)."""
    path = os.path.join(_ROOT, "BENCH_overlap.json")
    if not os.path.isfile(path):
        print("perf-gate: no committed BENCH_overlap.json — overlap leg "
              "skipped")
        return 0
    with open(path) as f:
        snap = json.load(f)
    committed_win = any(
        row["name"].startswith("overlap/prefetch-gate/")
        and row.get("us") is not None
        for row in snap.get("rows", [])
    )
    if not committed_win:
        print("perf-gate: committed overlap snapshot records no prefetch "
              "win — overlap leg skipped")
        return 0

    import numpy as np

    from benchmarks.table7_overlap import _prefetch_rows
    from repro.data.synthetic import generate_collection, splade_config

    print("# perf-gate: re-measuring prefetch-on/off paced stream…",
          file=sys.stderr, flush=True)
    col = generate_collection(splade_config(800, 16, seed=0),
                              value_format="f16")
    Q = np.stack([col.query_dense(i) for i in range(16)])
    failures = 0
    for r in _prefetch_rows(col, Q, 8, "flat", "streamvbyte"):
        if "/prefetch-gate/" not in r.name:
            continue
        if math.isnan(r.us):
            failures += 1
            print(f"FAIL {r.name}: fresh us=nan — prefetch-on p95 "
                  f"regressed past prefetch-off ({r.derived})")
        else:
            print(f"ok   {r.name}: fresh prefetch-on p95 holds "
                  f"({r.derived})")
    return failures


def main() -> int:
    bench_path = os.path.join(_ROOT, "BENCH_kernels.json")
    if not os.path.isfile(bench_path):
        print("perf-gate: no committed BENCH_kernels.json — nothing to guard")
        return _overlap_gate()
    with open(bench_path) as f:
        snap = json.load(f)
    n_docs = int(snap.get("n_docs", 300))

    committed: dict[tuple[str, str, str], float] = {}
    for row in snap.get("rows", []):
        mode, codec = row.get("mode"), row.get("codec")
        if not mode or not codec or row.get("us") is None:
            continue
        committed[(_family(row["name"]), codec, mode)] = float(row["us"])

    gated = sorted(
        (fam, codec)
        for (fam, codec, mode) in committed
        if mode == "pallas_compiled"
        and fam in GATED_FAMILIES
        and (fam, codec, "jnp") in committed
        and committed[(fam, codec, "pallas_compiled")]
        <= committed[(fam, codec, "jnp")]
    )
    if not gated:
        print("perf-gate: committed snapshot records no compiled wins — "
              "nothing to guard (is BENCH_kernels.json stale?)")
        return _overlap_gate()

    from benchmarks.kernel_bench import run as bench_run

    print(f"# perf-gate: re-measuring pallas_compiled rows at n_docs={n_docs}…",
          file=sys.stderr, flush=True)
    fresh_rows = bench_run(n_docs=n_docs, modes=("pallas_compiled",), sweep=False)
    fresh = {(_family(r.name), r.codec): r for r in fresh_rows if r.codec}

    failures = 0
    for fam, codec in gated:
        jnp_us = committed[(fam, codec, "jnp")]
        r = fresh.get((fam, codec))
        if r is None:
            failures += 1
            print(f"FAIL {fam}/{codec}: compiled row missing from fresh run "
                  f"(committed jnp {jnp_us:.1f}µs)")
            continue
        if r.us > jnp_us:
            failures += 1
            measured = r.us
            r.us = math.nan  # NaN-fail: the regression row carries no number
            print(f"FAIL {fam}/{codec}: fresh compiled us=nan "
                  f"(measured {measured:.1f}µs) — slower than committed "
                  f"jnp {jnp_us:.1f}µs")
        else:
            print(f"ok   {fam}/{codec}: fresh compiled {r.us:.1f}µs "
                  f"≤ committed jnp {jnp_us:.1f}µs")
    failures += _overlap_gate()
    if failures:
        print(f"perf-gate: {failures} regression(s)")
    else:
        print(f"perf-gate OK ({len(gated)} locked-in kernel win(s) "
              f"re-verified)")
    return failures


if __name__ == "__main__":
    sys.exit(main())
