#!/usr/bin/env python
"""Compiled-kernel perf-regression gate (``make perf-gate``, part of
``make check``).

Reads the committed ``BENCH_kernels.json``, re-measures the
``pallas_compiled`` scan and rescoring rows at the committed collection
size, and **NaN-fails** — the regressed row is reported with ``us=nan``
and the exit status is non-zero — whenever a freshly measured compiled
row is slower than the *committed* jnp row for the same codec.

Only (family, codec) pairs whose committed snapshot records a compiled
win (compiled µs ≤ jnp µs) are gated: the gate locks in the wins the
tiled kernels bought, it does not demand wins the snapshot never
claimed (e.g. the decode-free ``uncompressed`` rescoring row, where
fusion buys HBM bytes rather than CPU wall-clock). Rows are selected
via the structured ``mode``/``codec`` fields, never by name parsing.
"""

from __future__ import annotations

import json
import math
import os
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_ROOT, "src"))
sys.path.insert(0, _ROOT)

#: families the gate guards (batch_sweep wall-clock is too noisy at the
#: quick-mode collection size to lock in)
GATED_FAMILIES = ("scan", "rescoring")


def _family(name: str) -> str:
    parts = name.split("/")
    return parts[1] if len(parts) > 1 else name


def main() -> int:
    bench_path = os.path.join(_ROOT, "BENCH_kernels.json")
    if not os.path.isfile(bench_path):
        print("perf-gate: no committed BENCH_kernels.json — nothing to guard")
        return 0
    with open(bench_path) as f:
        snap = json.load(f)
    n_docs = int(snap.get("n_docs", 300))

    committed: dict[tuple[str, str, str], float] = {}
    for row in snap.get("rows", []):
        mode, codec = row.get("mode"), row.get("codec")
        if not mode or not codec or row.get("us") is None:
            continue
        committed[(_family(row["name"]), codec, mode)] = float(row["us"])

    gated = sorted(
        (fam, codec)
        for (fam, codec, mode) in committed
        if mode == "pallas_compiled"
        and fam in GATED_FAMILIES
        and (fam, codec, "jnp") in committed
        and committed[(fam, codec, "pallas_compiled")]
        <= committed[(fam, codec, "jnp")]
    )
    if not gated:
        print("perf-gate: committed snapshot records no compiled wins — "
              "nothing to guard (is BENCH_kernels.json stale?)")
        return 0

    from benchmarks.kernel_bench import run as bench_run

    print(f"# perf-gate: re-measuring pallas_compiled rows at n_docs={n_docs}…",
          file=sys.stderr, flush=True)
    fresh_rows = bench_run(n_docs=n_docs, modes=("pallas_compiled",), sweep=False)
    fresh = {(_family(r.name), r.codec): r for r in fresh_rows if r.codec}

    failures = 0
    for fam, codec in gated:
        jnp_us = committed[(fam, codec, "jnp")]
        r = fresh.get((fam, codec))
        if r is None:
            failures += 1
            print(f"FAIL {fam}/{codec}: compiled row missing from fresh run "
                  f"(committed jnp {jnp_us:.1f}µs)")
            continue
        if r.us > jnp_us:
            failures += 1
            measured = r.us
            r.us = math.nan  # NaN-fail: the regression row carries no number
            print(f"FAIL {fam}/{codec}: fresh compiled us=nan "
                  f"(measured {measured:.1f}µs) — slower than committed "
                  f"jnp {jnp_us:.1f}µs")
        else:
            print(f"ok   {fam}/{codec}: fresh compiled {r.us:.1f}µs "
                  f"≤ committed jnp {jnp_us:.1f}µs")
    if failures:
        print(f"perf-gate: {failures} compiled regression(s)")
    else:
        print(f"perf-gate OK ({len(gated)} locked-in win(s) re-verified)")
    return failures


if __name__ == "__main__":
    sys.exit(main())
