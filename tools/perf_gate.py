#!/usr/bin/env python
"""Compiled-kernel perf-regression gate (``make perf-gate``, part of
``make check``).

Reads the committed ``BENCH_kernels.json``, re-measures the
``pallas_compiled`` scan and rescoring rows at the committed collection
size, and **NaN-fails** — the regressed row is reported with ``us=nan``
and the exit status is non-zero — whenever a freshly measured compiled
row is slower than the *committed* jnp row for the same codec.

Only (family, codec) pairs whose committed snapshot records a compiled
win (compiled µs ≤ jnp µs) are gated: the gate locks in the wins the
tiled kernels bought, it does not demand wins the snapshot never
claimed (e.g. the decode-free ``uncompressed`` rescoring row, where
fusion buys HBM bytes rather than CPU wall-clock). Rows are selected
via the structured ``mode``/``codec`` fields, never by name parsing.

The overlap leg guards the host prefetcher the same way: when the
committed ``BENCH_overlap.json`` records a prefetch win (a non-NaN
``overlap/prefetch-gate`` row), the prefetch-on/off paced stream is
re-measured fresh and the gate row NaN-fails if prefetch-on p95
regresses past prefetch-off (EXPERIMENTS.md §Overlap).

The values leg guards the value-codec win (DESIGN.md §12): for every
codec whose committed snapshot carries a ``vq="u8_sq"`` compiled
rescoring row, the fresh u8_sq row must stream strictly fewer
``hbm_bytes_per_q`` than the committed f16 compiled row, and its
``bits_per_posting`` must not regress past the committed u8_sq value —
NaN-fail otherwise. Value-codec rows are identified by the structured
``vq`` field and EXCLUDED from the wall-clock dictionaries, so the
f16 rows keep their historical (family, codec, mode) identities.
"""

from __future__ import annotations

import json
import math
import os
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_ROOT, "src"))
sys.path.insert(0, _ROOT)

#: families the gate guards (batch_sweep wall-clock is too noisy at the
#: quick-mode collection size to lock in)
GATED_FAMILIES = ("scan", "rescoring")


def _family(name: str) -> str:
    parts = name.split("/")
    return parts[1] if len(parts) > 1 else name


def _overlap_gate() -> int:
    """NaN-fail when a freshly measured prefetch-on p95 regresses past
    prefetch-off — only once the committed ``BENCH_overlap.json``
    records that win (same locked-in-wins philosophy as the kernel
    leg)."""
    path = os.path.join(_ROOT, "BENCH_overlap.json")
    if not os.path.isfile(path):
        print("perf-gate: no committed BENCH_overlap.json — overlap leg "
              "skipped")
        return 0
    with open(path) as f:
        snap = json.load(f)
    committed_win = any(
        row["name"].startswith("overlap/prefetch-gate/")
        and row.get("us") is not None
        for row in snap.get("rows", [])
    )
    if not committed_win:
        print("perf-gate: committed overlap snapshot records no prefetch "
              "win — overlap leg skipped")
        return 0

    import numpy as np

    from benchmarks.table7_overlap import _prefetch_rows
    from repro.data.synthetic import generate_collection, splade_config

    print("# perf-gate: re-measuring prefetch-on/off paced stream…",
          file=sys.stderr, flush=True)
    col = generate_collection(splade_config(800, 16, seed=0),
                              value_format="f16")
    Q = np.stack([col.query_dense(i) for i in range(16)])
    failures = 0
    for r in _prefetch_rows(col, Q, 8, "flat", "streamvbyte"):
        if "/prefetch-gate/" not in r.name:
            continue
        if math.isnan(r.us):
            failures += 1
            print(f"FAIL {r.name}: fresh us=nan — prefetch-on p95 "
                  f"regressed past prefetch-off ({r.derived})")
        else:
            print(f"ok   {r.name}: fresh prefetch-on p95 holds "
                  f"({r.derived})")
    return failures


def _values_gate(snap_rows: list[dict], fresh_rows) -> int:
    """NaN-fail when the freshly measured ``u8_sq`` compiled rescoring
    row stops beating the *committed* f16 compiled row on
    ``hbm_bytes_per_q``, or its ``bits_per_posting`` regresses past the
    committed u8_sq value — only for codecs whose committed snapshot
    records the u8_sq win (same locked-in-wins philosophy as the
    wall-clock leg). Rows are selected by the structured ``vq`` field."""
    from benchmarks.common import _parse_derived

    committed_f16_hbm: dict[str, float] = {}
    committed_u8_bpp: dict[str, float] = {}
    for row in snap_rows:
        if (row.get("mode") != "pallas_compiled" or not row.get("codec")
                or _family(row["name"]) != "rescoring"):
            continue
        d = row.get("derived") or {}
        vq = row.get("vq")
        if vq is None and d.get("hbm_bytes_per_q"):
            committed_f16_hbm[row["codec"]] = float(d["hbm_bytes_per_q"])
        elif vq == "u8_sq" and d.get("bits_per_posting") is not None:
            committed_u8_bpp[row["codec"]] = float(d["bits_per_posting"])
    gated = sorted(set(committed_u8_bpp) & set(committed_f16_hbm))
    if not gated:
        print("perf-gate: committed snapshot records no u8_sq rescoring "
              "rows — values leg skipped")
        return 0

    fresh_u8 = {
        r.codec: r
        for r in fresh_rows
        if r.vq == "u8_sq" and r.mode == "pallas_compiled"
        and _family(r.name) == "rescoring"
    }
    failures = 0
    for codec in gated:
        f16_hbm = committed_f16_hbm[codec]
        snap_bpp = committed_u8_bpp[codec]
        r = fresh_u8.get(codec)
        if r is None:
            failures += 1
            print(f"FAIL values/{codec}: fresh u8_sq rescoring row missing")
            continue
        d = _parse_derived(r.derived)
        hbm, bpp = d.get("hbm_bytes_per_q"), d.get("bits_per_posting")
        if hbm is None or not hbm < f16_hbm:
            failures += 1
            r.us = math.nan  # NaN-fail: the regression row carries no number
            print(f"FAIL values/{codec}: fresh u8_sq us=nan — "
                  f"hbm_bytes_per_q={hbm} no longer beats committed f16 "
                  f"{f16_hbm:.0f}")
        elif bpp is None or bpp > snap_bpp + 1e-6:
            failures += 1
            r.us = math.nan
            print(f"FAIL values/{codec}: fresh u8_sq us=nan — "
                  f"bits_per_posting={bpp} regressed past committed "
                  f"{snap_bpp:.1f}")
        else:
            print(f"ok   values/{codec}: u8_sq streams {hbm:.0f} B/q "
                  f"< f16 {f16_hbm:.0f} B/q at {bpp:.1f} bits/posting")
    return failures


def main() -> int:
    bench_path = os.path.join(_ROOT, "BENCH_kernels.json")
    if not os.path.isfile(bench_path):
        print("perf-gate: no committed BENCH_kernels.json — nothing to guard")
        return _overlap_gate()
    with open(bench_path) as f:
        snap = json.load(f)
    n_docs = int(snap.get("n_docs", 300))

    committed: dict[tuple[str, str, str], float] = {}
    for row in snap.get("rows", []):
        mode, codec = row.get("mode"), row.get("codec")
        if not mode or not codec or row.get("us") is None:
            continue
        if row.get("vq"):  # value-codec rows gate via _values_gate
            continue
        committed[(_family(row["name"]), codec, mode)] = float(row["us"])

    gated = sorted(
        (fam, codec)
        for (fam, codec, mode) in committed
        if mode == "pallas_compiled"
        and fam in GATED_FAMILIES
        and (fam, codec, "jnp") in committed
        and committed[(fam, codec, "pallas_compiled")]
        <= committed[(fam, codec, "jnp")]
    )
    if not gated:
        print("perf-gate: committed snapshot records no compiled wins — "
              "nothing to guard (is BENCH_kernels.json stale?)")
        return _overlap_gate()

    from benchmarks.kernel_bench import run as bench_run

    print(f"# perf-gate: re-measuring pallas_compiled rows at n_docs={n_docs}…",
          file=sys.stderr, flush=True)
    fresh_rows = bench_run(n_docs=n_docs, modes=("pallas_compiled",), sweep=False)
    fresh = {
        (_family(r.name), r.codec): r
        for r in fresh_rows
        if r.codec and not r.vq
    }

    failures = 0
    for fam, codec in gated:
        jnp_us = committed[(fam, codec, "jnp")]
        r = fresh.get((fam, codec))
        if r is None:
            failures += 1
            print(f"FAIL {fam}/{codec}: compiled row missing from fresh run "
                  f"(committed jnp {jnp_us:.1f}µs)")
            continue
        if r.us > jnp_us:
            failures += 1
            measured = r.us
            r.us = math.nan  # NaN-fail: the regression row carries no number
            print(f"FAIL {fam}/{codec}: fresh compiled us=nan "
                  f"(measured {measured:.1f}µs) — slower than committed "
                  f"jnp {jnp_us:.1f}µs")
        else:
            print(f"ok   {fam}/{codec}: fresh compiled {r.us:.1f}µs "
                  f"≤ committed jnp {jnp_us:.1f}µs")
    failures += _values_gate(snap.get("rows", []), fresh_rows)
    failures += _overlap_gate()
    if failures:
        print(f"perf-gate: {failures} regression(s)")
    else:
        print(f"perf-gate OK ({len(gated)} locked-in kernel win(s) "
              f"re-verified)")
    return failures


if __name__ == "__main__":
    sys.exit(main())
