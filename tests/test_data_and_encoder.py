"""Synthetic data statistics + sparse-encoder training signal."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.synthetic import (
    SyntheticConfig,
    generate_collection,
    lilsr_config,
    splade_config,
)
from repro.models.sparse_encoder import (
    SparseEncoderConfig,
    contrastive_loss,
    encode,
    encoder_init,
)


def test_splade_statistics_match_paper():
    col = generate_collection(splade_config(n_docs=400, n_queries=40, seed=0))
    nnz_doc = col.fwd.total_nnz / col.fwd.n_docs
    nnz_q = np.mean([len(c) for c in col.query_comps])
    assert abs(nnz_doc - 119) < 12, nnz_doc  # paper: 119 nnz/doc
    assert abs(nnz_q - 43) < 8, nnz_q  # paper: 43 nnz/query


def test_lilsr_statistics_match_paper():
    col = generate_collection(lilsr_config(n_docs=200, n_queries=40, seed=1))
    nnz_doc = col.fwd.total_nnz / col.fwd.n_docs
    nnz_q = np.mean([len(c) for c in col.query_comps])
    assert abs(nnz_doc - 387) < 30, nnz_doc
    assert abs(nnz_q - 6) < 3, nnz_q


def test_queries_retrieve_related_docs():
    """Topic structure: a query's exact top-10 must beat random recall."""
    col = generate_collection(
        SyntheticConfig(name="t", dim=2048, n_docs=500, n_queries=10,
                        doc_nnz_mean=60, query_nnz_mean=20, seed=2)
    )
    scores = np.stack([col.fwd.exact_scores(col.query_dense(i)) for i in range(10)])
    top = scores.max(axis=1)
    med = np.median(scores, axis=1)
    assert (top > 4 * np.maximum(med, 1e-3)).mean() >= 0.8


def _tok_batch(key, cfg, B=8, S=16):
    ks = jax.random.split(key, 4)
    return {
        "q_tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab),
        "q_mask": jnp.ones((B, S), bool),
        "d_tokens": jax.random.randint(ks[1], (B, S), 0, cfg.vocab),
        "d_mask": jnp.ones((B, S), bool),
    }


def test_sparse_encoder_shapes_and_sparsity():
    cfg = SparseEncoderConfig(vocab=512, n_layers=2, d_model=32, n_heads=4, d_ff=64,
                              max_len=16)
    key = jax.random.PRNGKey(0)
    p = encoder_init(key, cfg)
    batch = _tok_batch(key, cfg)
    emb = encode(p, cfg, batch["d_tokens"], batch["d_mask"])
    assert emb.shape == (8, 512)
    assert bool((emb >= 0).all())  # log1p(relu) ≥ 0


def test_sparse_encoder_trains():
    cfg = SparseEncoderConfig(vocab=512, n_layers=2, d_model=32, n_heads=4, d_ff=64,
                              max_len=16, flops_lambda=1e-4)
    key = jax.random.PRNGKey(1)
    p = encoder_init(key, cfg)
    from repro.train.optimizer import OptimizerConfig, make_optimizer
    from repro.train.train_step import init_train_state, make_train_step

    oinit, oupd = make_optimizer(OptimizerConfig(lr=2e-3, warmup_steps=5, total_steps=60))
    step = jax.jit(make_train_step(lambda pp, b: contrastive_loss(pp, cfg, b), oupd))
    state = init_train_state(p, oinit)
    losses = []
    batch = _tok_batch(key, cfg)  # overfit one batch
    for i in range(30):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0], (losses[0], losses[-1])
