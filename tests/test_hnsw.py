"""HNSW host reference + batched graph engine tests (DESIGN.md §5).

Covers the ISSUE-2 acceptance criteria: recall parity vs ``exact_top_k``
(recall@10 ≥ 0.9), codec invariance (identical top-k ids through every
registered row codec), and build determinism under a fixed seed.
"""

import numpy as np
import pytest

from repro.core.hnsw import HNSWIndex, HNSWParams
from repro.core.layout import available_layouts
from repro.core.seismic import exact_top_k, recall_at_k
from repro.data.synthetic import SyntheticConfig, generate_collection
from repro.serve.api import Retriever, RetrieverConfig

PARAMS = HNSWParams(m=16, ef_construction=48, seed=0)


@pytest.fixture(scope="module")
def collection():
    cfg = SyntheticConfig(
        name="test", dim=2048, n_docs=600, n_queries=10,
        doc_nnz_mean=60.0, query_nnz_mean=16.0, seed=0,
    )
    return generate_collection(cfg, value_format="f32")


@pytest.fixture(scope="module")
def index(collection):
    return HNSWIndex.build(collection.fwd, PARAMS)


def test_reference_recall(collection, index):
    recs = []
    for i in range(collection.n_queries):
        q = collection.query_dense(i)
        true_ids, _ = exact_top_k(collection.fwd, q, 10)
        got_ids, got_scores = index.search(q, k=10, ef=64)
        recs.append(recall_at_k(true_ids, got_ids))
        # returned scores are the exact inner products
        want = collection.fwd.exact_scores(q)
        np.testing.assert_allclose(got_scores, want[got_ids], rtol=1e-5, atol=1e-5)
    assert np.mean(recs) >= 0.9, np.mean(recs)


def test_reference_codec_timed_parity(collection, index):
    """Decoding candidates through a host codec changes timing, never
    results (components compression is lossless)."""
    index.prepare_codec("streamvbyte")
    q = collection.query_dense(0)
    i0, s0 = index.search(q, 10, ef=64, codec="uncompressed")
    i1, s1 = index.search(q, 10, ef=64, codec="streamvbyte")
    assert np.array_equal(i0, i1)
    np.testing.assert_allclose(s0, s1, rtol=1e-6)


def test_graph_degree_bounds(collection, index):
    for layer, adj in enumerate(index.graph):
        deg = index.params.degree(layer)
        for node, nbrs in adj.items():
            assert len(nbrs) <= deg
            assert node not in nbrs
            assert int(index.levels[node]) >= layer


@pytest.mark.parametrize("codec", available_layouts())
def test_batched_engine_recall(collection, index, codec):
    eng = Retriever.from_host_index(
        index,
        RetrieverConfig(engine="hnsw", codec=codec, k=10,
                        params=dict(beam=64, iters=64, n_seeds=8)),
    )
    Q = np.stack([collection.query_dense(i) for i in range(collection.n_queries)])
    ids, scores = eng.search(Q)
    recs = []
    for i in range(collection.n_queries):
        true_ids, _ = exact_top_k(collection.fwd, Q[i], 10)
        recs.append(recall_at_k(true_ids, np.asarray(ids[i])))
    assert np.mean(recs) >= 0.9, np.mean(recs)
    # scores of returned docs are the exact inner products
    for i in range(3):
        want = collection.fwd.exact_scores(Q[i])
        got = np.asarray(scores[i])
        ok = np.asarray(ids[i]) < collection.fwd.n_docs
        np.testing.assert_allclose(
            got[ok], want[np.asarray(ids[i])[ok]], rtol=1e-3, atol=1e-3
        )


def test_batched_engine_codec_invariance(collection, index):
    """The graph path returns the exact same top-k ids whichever row
    codec decodes the candidates — the paper's claim on algorithm #2."""
    Q = np.stack([collection.query_dense(i) for i in range(collection.n_queries)])
    res = [
        Retriever.from_host_index(
            index,
            RetrieverConfig(engine="hnsw", codec=c,
                            params=dict(beam=64, iters=64, n_seeds=8)),
        ).search(Q)
        for c in available_layouts()
    ]
    for i in range(1, len(res)):
        assert np.array_equal(np.asarray(res[0][0]), np.asarray(res[i][0]))
        np.testing.assert_allclose(
            np.asarray(res[0][1]), np.asarray(res[i][1]), rtol=1e-5
        )


def test_build_determinism(collection, index):
    again = HNSWIndex.build(collection.fwd, PARAMS)
    assert again.entry == index.entry
    assert again.max_level == index.max_level
    assert np.array_equal(again.levels, index.levels)
    assert len(again.graph) == len(index.graph)
    for layer in range(len(index.graph)):
        assert again.graph[layer] == index.graph[layer]
    for layer in range(len(index.graph)):
        assert np.array_equal(again.adjacency(layer), index.adjacency(layer))


def test_index_bytes_accounting(collection, index):
    sizes = index.index_bytes("streamvbyte")
    unc = index.index_bytes("uncompressed")
    assert sizes["forward_components"] < unc["forward_components"]
    assert sizes["graph"] == unc["graph"] == 4 * index.n_edges + index.levels.nbytes
    assert sizes["total"] < unc["total"]


def test_empty_and_tiny_index():
    from repro.core.forward_index import ForwardIndex

    fwd = ForwardIndex.from_docs(
        [(np.array([3, 7], np.uint32), np.array([1.0, 2.0], np.float32))], dim=16
    )
    idx = HNSWIndex.build(fwd, HNSWParams(m=4, ef_construction=8))
    q = np.zeros(16, np.float32)
    q[7] = 1.0
    ids, scores = idx.search(q, k=1)
    assert ids.tolist() == [0] and scores[0] == pytest.approx(2.0)
    eng = Retriever.from_host_index(
        idx, RetrieverConfig(engine="hnsw", k=1,
                             params=dict(beam=8, iters=4, n_seeds=2)))
    ids, scores = eng.search(q[None, :])
    assert np.asarray(ids)[0, 0] == 0
