"""ForwardIndex + packed-block layout tests."""

import numpy as np
import pytest

from proptest import run_property, sorted_unique_ints
from repro.core.forward_index import VALUE_FORMATS, ForwardIndex, pack_forward_index
from repro.core.scoring import score_packed


def _rand_docs(rng, n_docs, dim, max_nnz=300):
    docs = []
    for _ in range(n_docs):
        n = int(rng.integers(1, max_nnz))
        c = np.sort(rng.choice(dim, size=min(n, dim // 2), replace=False))
        v = rng.gamma(2.0, 0.5, size=len(c)).astype(np.float32) + 0.05
        docs.append((c, v))
    return docs


def test_exact_scores_matches_naive():
    rng = np.random.default_rng(0)
    dim = 4096
    docs = _rand_docs(rng, 50, dim)
    fwd = ForwardIndex.from_docs(docs, dim)
    q = rng.random(dim).astype(np.float32)
    want = np.array([q[c] @ v for c, v in docs])
    got = fwd.exact_scores(q)
    np.testing.assert_allclose(got, want, rtol=1e-5)


@pytest.mark.parametrize("vf", ["f32", "f16", "fixedu8"])
def test_value_formats_quantisation_error(vf):
    rng = np.random.default_rng(1)
    dim = 2048
    docs = _rand_docs(rng, 30, dim)
    fwd = ForwardIndex.from_docs(docs, dim, value_format=vf)
    fmt = VALUE_FORMATS[vf]
    c0, v0 = docs[0]
    order = np.argsort(c0, kind="stable")
    got_c, got_v = fwd.doc(0)
    assert np.array_equal(got_c, c0[order])
    tol = {"f32": 1e-7, "f16": 2e-3, "fixedu8": fmt.scale / 2 + 1e-6}[vf]
    np.testing.assert_allclose(got_v, v0[order], atol=tol, rtol=1e-2)


def test_component_permutation_preserves_scores():
    rng = np.random.default_rng(2)
    dim = 1024
    docs = _rand_docs(rng, 40, dim, max_nnz=60)
    fwd = ForwardIndex.from_docs(docs, dim)
    pi = rng.permutation(dim).astype(np.uint32)
    fwd_p = fwd.apply_component_permutation(pi)
    q = rng.random(dim).astype(np.float32)
    q_p = np.zeros_like(q)
    q_p[pi] = q
    np.testing.assert_allclose(fwd.exact_scores(q), fwd_p.exact_scores(q_p), rtol=1e-5)
    # components stay sorted per doc
    for d in range(fwd_p.n_docs):
        c, _ = fwd_p.doc(d)
        assert np.all(np.diff(c) > 0)


@pytest.mark.parametrize("codec", ["uncompressed", "dotvbyte", "bitpack"])
@pytest.mark.parametrize("block_size", [128, 512])
def test_packed_scoring_matches_exact(codec, block_size):
    rng = np.random.default_rng(3)
    dim = 8192
    docs = _rand_docs(rng, 80, dim)
    fwd = ForwardIndex.from_docs(docs, dim, value_format="f16")
    packed = pack_forward_index(fwd, codec=codec, block_size=block_size)
    q = np.zeros(dim, dtype=np.float32)
    qc = rng.choice(dim, 40, replace=False)
    q[qc] = rng.gamma(2.0, 0.5, size=40)
    got = np.asarray(score_packed(q, packed))
    want = fwd.exact_scores(q)
    np.testing.assert_allclose(got, want, atol=2e-3, rtol=1e-3)


def test_packed_handles_docs_larger_than_block():
    """A document with nnz > block_size must split across blocks."""
    dim = 4096
    rng = np.random.default_rng(4)
    big = np.sort(rng.choice(dim, size=500, replace=False)).astype(np.uint32)
    docs = [(big, np.ones(500, dtype=np.float32))]
    fwd = ForwardIndex.from_docs(docs, dim)
    packed = pack_forward_index(fwd, codec="dotvbyte", block_size=128)
    assert packed.n_blocks >= 4
    q = rng.random(dim).astype(np.float32)
    np.testing.assert_allclose(
        np.asarray(score_packed(q, packed)), fwd.exact_scores(q), rtol=1e-4, atol=1e-3
    )


def test_storage_bytes_accounting():
    rng = np.random.default_rng(5)
    docs = _rand_docs(rng, 20, 2048, max_nnz=50)
    fwd = ForwardIndex.from_docs(docs, 2048, value_format="f16")
    unc = fwd.storage_bytes("uncompressed")
    dvb = fwd.storage_bytes("dotvbyte")
    assert unc["components"] == 2 * fwd.total_nnz
    assert dvb["components"] < unc["components"]
    assert dvb["values"] == unc["values"] == 2 * fwd.total_nnz
