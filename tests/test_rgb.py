"""Recursive Graph Bisection tests: permutation validity, cost
reduction on label-scrambled clustered data, score preservation."""

import numpy as np

from repro.core.codecs import get_codec
from repro.core.rgb import apply_permutation_dense, log_gap_cost, recursive_graph_bisection


def _clustered_docs(rng, dim=2048, n_docs=800, scrambled=True):
    centers = rng.integers(0, dim, size=24)
    docs = []
    for _ in range(n_docs):
        c = rng.choice(centers, size=2)
        comps = np.unique(
            np.clip(
                np.concatenate([rng.normal(x, 40, 30).astype(int) for x in c]),
                0, dim - 1,
            )
        ).astype(np.uint32)
        docs.append(comps)
    if scrambled:
        relabel = rng.permutation(dim).astype(np.uint32)
        docs = [np.sort(relabel[c]) for c in docs]
    return docs


def test_permutation_is_bijection():
    rng = np.random.default_rng(0)
    docs = _clustered_docs(rng, dim=512, n_docs=200)
    pi = recursive_graph_bisection(docs, 512, max_iters=4)
    assert len(pi) == 512
    assert np.array_equal(np.sort(pi), np.arange(512, dtype=np.uint32))


def test_rgb_reduces_log_gap_cost():
    rng = np.random.default_rng(1)
    docs = _clustered_docs(rng)
    pi = recursive_graph_bisection(docs, 2048, max_iters=8)
    docs_p = [np.sort(pi[c]) for c in docs]
    c0, c1 = log_gap_cost(docs), log_gap_cost(docs_p)
    assert c1 < 0.85 * c0, (c0, c1)  # ≥15% reduction on clustered data


def test_rgb_improves_bit_codecs():
    """The paper's Table-1 effect: RGB shrinks Elias/Zeta noticeably."""
    rng = np.random.default_rng(2)
    docs = _clustered_docs(rng)
    pi = recursive_graph_bisection(docs, 2048, max_iters=8)
    docs_p = [np.sort(pi[c]) for c in docs]
    for name in ("elias_gamma", "zeta"):
        codec = get_codec(name)
        b0 = codec.bits_per_component(docs)
        b1 = codec.bits_per_component(docs_p)
        assert b1 < b0, (name, b0, b1)


def test_query_permutation_consistency():
    rng = np.random.default_rng(3)
    dim = 512
    docs = _clustered_docs(rng, dim=dim, n_docs=100)
    pi = recursive_graph_bisection(docs, dim, max_iters=4)
    q = rng.random(dim).astype(np.float32)
    qp = apply_permutation_dense(q, pi)
    for c in docs[:10]:
        cp = np.sort(pi[c])
        np.testing.assert_allclose(q[c].sum(), qp[cp].sum(), rtol=1e-5)
