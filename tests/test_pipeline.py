"""Online serving pipeline tests (DESIGN.md §8): plan cache, bucketed
micro-batching scheduler, result cache, serving metrics.

The ISSUE-5 acceptance criterion lives here: bucketed/padded batch
search through the pipeline returns byte-identical top-k (ids AND
scores) to a direct ``Retriever.search`` for every engine × codec ×
backend combination — including ragged final batches and cache-hit
replays. Scheduler semantics (deadline firing, full-bucket dispatch,
LRU eviction, recompile counting) are tested with an injected fake
clock, so nothing here sleeps."""

import numpy as np
import pytest

from repro.core.layout import available_layouts
from repro.data.synthetic import SyntheticConfig, generate_collection
from repro.serve.api import Retriever, RetrieverConfig, get_engine, open_retriever
from repro.serve.pipeline import (
    DEFAULT_BUCKETS,
    Pipeline,
    PlanCache,
    ResultCache,
    plan_buckets,
    quantized_query_key,
)

#: per-engine knobs sized for the tiny test collection
ENGINE_PARAMS = {
    "seismic": dict(cut=8, block_budget=128, n_probe=24, n_postings=200,
                    block_size=16),
    "hnsw": dict(beam=16, iters=16, n_seeds=4, m=8, ef_construction=24),
    "flat": {},
}


class FakeClock:
    """Deterministic injectable clock (seconds)."""

    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        return self.t

    def advance_us(self, us: float) -> None:
        self.t += us * 1e-6


@pytest.fixture(scope="module")
def collection():
    cfg = SyntheticConfig(
        name="pipe", dim=1024, n_docs=240, n_queries=7,
        doc_nnz_mean=35.0, query_nnz_mean=10.0, seed=3,
    )
    return generate_collection(cfg, value_format="f16")


@pytest.fixture(scope="module")
def queries(collection):
    return np.stack([collection.query_dense(i) for i in range(collection.n_queries)])


@pytest.fixture(scope="module")
def host_indexes(collection):
    out = {}
    for name in ("seismic", "hnsw"):
        impl = get_engine(name)
        cfg = RetrieverConfig(engine=name, params=ENGINE_PARAMS[name])
        out[name] = impl.host_index(collection.fwd, cfg)
    return out


def _retriever(collection, host_indexes, engine, codec, backend="jnp", **kw):
    cfg = RetrieverConfig(engine=engine, codec=codec, k=5, backend=backend,
                          params=ENGINE_PARAMS[engine], **kw)
    if engine in host_indexes:
        return Retriever.from_host_index(host_indexes[engine], cfg)
    return Retriever.build(collection.fwd, cfg)


# ---------------------------------------------------------------------------
# the acceptance criterion: pipeline ≡ direct search, all combinations
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
@pytest.mark.parametrize("codec", available_layouts())
@pytest.mark.parametrize("engine", ["seismic", "hnsw", "flat"])
def test_pipeline_matches_direct_search(collection, queries, host_indexes,
                                        engine, codec, backend):
    """Bucketed/padded scheduler dispatch — AND a cache-hit replay —
    return byte-identical top-k ids and scores to direct search, for
    every engine × codec × backend."""
    r = _retriever(collection, host_indexes, engine, codec, backend)
    ids_d, sc_d = r.search(queries)  # direct: pads 7 → bucket 8
    ids_p, sc_p = r.search_batch(queries)  # pipeline: same plan, queued
    assert np.array_equal(np.asarray(ids_d), ids_p)
    assert np.array_equal(np.asarray(sc_d), sc_p)
    # replay: every query now hits the result cache; results identical
    ids_c, sc_c = r.search_batch(queries)
    assert np.array_equal(ids_p, ids_c)
    assert np.array_equal(sc_p, sc_c)
    snap = r.pipeline().snapshot()
    assert snap["cache_hit_rate"] == pytest.approx(0.5)
    assert snap["n_queries"] == 2 * collection.n_queries


def test_ragged_batches_and_custom_buckets(collection, queries, host_indexes):
    """A 7-query stream over buckets (2, 4) coalesces into a full
    4-bucket plus a ragged 3-in-4 final batch — same bytes as direct
    search either way."""
    r = _retriever(collection, host_indexes, "flat", "streamvbyte")
    ids_d, sc_d = r.search(queries)
    pipe = Pipeline(r, buckets=(2, 4), cache_size=0)
    ids_p, sc_p = pipe.search_batch(queries)
    assert np.array_equal(np.asarray(ids_d), ids_p)
    assert np.array_equal(np.asarray(sc_d), sc_p)
    snap = pipe.snapshot()
    assert snap["dispatches"] == {4: 2}  # 4 full + 3 padded to 4
    assert snap["bucket_occupancy"][4] == pytest.approx(7 / 8)


def test_batch_beyond_largest_bucket(collection, queries, host_indexes):
    """Streams longer than the largest bucket split across dispatches
    (scheduler) / round up to a power-of-two plan (direct search) —
    results identical to per-query truth in both paths."""
    r = _retriever(collection, host_indexes, "flat", "dotvbyte")
    Q = np.concatenate([queries, queries[:3]])  # 10 queries
    ids_d, sc_d = r.search(Q)
    pipe = Pipeline(r, buckets=(4,), cache_size=0)
    ids_p, sc_p = pipe.search_batch(Q)
    assert np.array_equal(np.asarray(ids_d), ids_p)
    assert np.array_equal(np.asarray(sc_d), sc_p)
    assert pipe.snapshot()["dispatches"] == {4: 3}


# ---------------------------------------------------------------------------
# scheduler semantics (fake clock — no sleeping)
# ---------------------------------------------------------------------------


def test_deadline_fires_undersized_batch(collection, queries, host_indexes):
    clock = FakeClock()
    r = _retriever(collection, host_indexes, "flat", "uncompressed")
    pipe = Pipeline(r, buckets=(8,), deadline_us=1000.0, cache_size=0,
                    clock=clock)
    t0 = pipe.submit(queries[0])
    t1 = pipe.submit(queries[1])
    assert not t0.done and pipe.poll() == 0  # deadline not reached
    clock.advance_us(999.0)
    assert pipe.poll() == 0
    clock.advance_us(2.0)  # oldest query now past its deadline
    assert pipe.poll() == 2
    assert t0.done and t1.done
    ids_d, _ = r.search(queries[:2])
    assert np.array_equal(np.asarray(ids_d)[0], t0.ids)
    assert np.array_equal(np.asarray(ids_d)[1], t1.ids)
    assert pipe.snapshot()["dispatches"] == {8: 1}
    # end-to-end latency saw the deadline wait
    assert pipe.stats.percentile(50) >= 1000.0


def test_full_bucket_dispatches_immediately(collection, queries, host_indexes):
    clock = FakeClock()
    r = _retriever(collection, host_indexes, "flat", "uncompressed")
    pipe = Pipeline(r, buckets=(1, 2, 4), deadline_us=1e9, cache_size=0,
                    clock=clock)
    tickets = [pipe.submit(q) for q in queries[:4]]
    assert all(t.done for t in tickets)  # queue hit the largest bucket
    assert pipe.snapshot()["dispatches"] == {4: 1}


def test_ticket_result_flushes(collection, queries, host_indexes):
    r = _retriever(collection, host_indexes, "flat", "uncompressed")
    pipe = Pipeline(r, buckets=(8,), deadline_us=1e9, cache_size=0)
    t = pipe.submit(queries[0])
    assert not t.done
    ids, scores = t.result()  # blocks on a flush, never deadlocks
    assert t.done and ids.shape == (5,) and scores.shape == (5,)


# ---------------------------------------------------------------------------
# result cache
# ---------------------------------------------------------------------------


def test_result_cache_lru_eviction_and_keys():
    c = ResultCache(capacity=2)
    ids = np.arange(3)
    k1, k2, k3 = b"a", b"b", b"c"
    c.put(k1, ids, ids)
    c.put(k2, ids, ids)
    assert c.get(k1) is not None  # k1 now most-recent
    c.put(k3, ids, ids)  # evicts k2 (LRU)
    assert c.get(k2) is None
    assert c.get(k1) is not None and c.get(k3) is not None
    assert len(c) == 2
    # quantized key: f16-identical queries share one entry, distinct
    # queries do not
    q = np.zeros(64, np.float32)
    q[7], q[20] = 1.25, 3.5
    q_jitter = q.copy()
    q_jitter[q > 0] += 1e-5  # below f16 resolution at these magnitudes
    q_other = q.copy()
    q_other[20] = 3.75
    assert quantized_query_key(q) == quantized_query_key(q_jitter)
    assert quantized_query_key(q) != quantized_query_key(q_other)


def test_cache_replays_survive_caller_mutation(collection, queries,
                                               host_indexes):
    """Cached entries are read-only copies of what was served: a
    caller scribbling on the arrays it was handed cannot corrupt later
    replays (dispatch results are read-only jax-buffer views already;
    the cache owns its own immutable copies either way)."""
    r = _retriever(collection, host_indexes, "flat", "uncompressed")
    pipe = Pipeline(r, buckets=(2,))
    t1 = pipe.submit(queries[0])
    t2 = pipe.submit(queries[1])  # fills bucket 2 → dispatched
    assert t2.done
    ref = t1.ids.copy()
    with pytest.raises(ValueError):  # dispatch view: immutable
        t1.ids[:] = -1
    t3 = pipe.submit(queries[0])  # cache hit
    assert t3.from_cache
    assert np.array_equal(t3.ids, ref)
    assert t3.ids is not t1.ids  # the cache owns a copy, not a view
    with pytest.raises(ValueError):  # replayed arrays: immutable too
        t3.ids[:] = -1


def test_cache_key_dtype_matches_index_quantization(collection, host_indexes):
    """The default cache tolerance follows the index: f16 keys for an
    f16-valued index (collapse error ≤ the index's own quantization
    noise), with an explicit exact override available."""
    r = _retriever(collection, host_indexes, "flat", "uncompressed")
    assert Pipeline(r).key_dtype == np.float16  # f16 value_format
    assert Pipeline(r, key_dtype=np.float32).key_dtype == np.float32


def test_cache_disabled(collection, queries, host_indexes):
    r = _retriever(collection, host_indexes, "flat", "uncompressed")
    pipe = Pipeline(r, cache_size=0)
    pipe.search_batch(queries[:2])
    pipe.search_batch(queries[:2])
    snap = pipe.snapshot()
    assert snap["cache_hit_rate"] == 0.0
    assert len(pipe.cache) == 0


# ---------------------------------------------------------------------------
# plan cache + batch_size wiring
# ---------------------------------------------------------------------------


def test_plan_buckets_and_bucket_for(collection, host_indexes):
    assert plan_buckets() == DEFAULT_BUCKETS
    assert 24 in plan_buckets(24)
    # an explicit bucket sequence is used verbatim — the batch_size
    # hint must not leak into it (the caller asked for exactly these)
    assert plan_buckets(128, buckets=(2, 4)) == (2, 4)
    with pytest.raises(ValueError, match="positive"):
        plan_buckets(buckets=(0, 4))
    with pytest.raises(ValueError, match="positive ints"):
        plan_buckets(buckets=(2.5, 8))
    with pytest.raises(ValueError, match="non-empty"):
        plan_buckets(buckets=())
    r = _retriever(collection, host_indexes, "flat", "uncompressed")
    assert r.plans.bucket_for(1) == 1
    assert r.plans.bucket_for(7) == 8
    assert r.plans.bucket_for(128) == 128
    assert r.plans.bucket_for(129) == 256  # beyond max → next pow2
    with pytest.raises(ValueError, match="≥ 1"):
        r.plans.bucket_for(0)


def test_oversized_search_keeps_bucket_set_stable(collection, queries,
                                                  host_indexes):
    """A one-off beyond-the-largest batch gets an ad hoc plan but must
    NOT grow the configured bucket set — otherwise one oversized
    direct search would permanently raise the scheduler's full-queue
    dispatch threshold."""
    r = _retriever(collection, host_indexes, "flat", "uncompressed",
                   batch_size=3)
    pipe = Pipeline(r, buckets=(2,), cache_size=0)
    r.search(np.repeat(queries, 1 + 2 // len(queries), axis=0)[:3])
    buckets_before = r.plans.buckets
    Qbig = np.repeat(queries, 20, axis=0)  # 140 > max bucket 128
    ids_d, _ = r.search(Qbig)
    assert ids_d.shape[0] == 140
    assert r.plans.buckets == buckets_before  # 256 plan cached, set unchanged
    assert pipe.plans.buckets == (2,)


def test_empty_batch(collection, host_indexes):
    """Zero queries is a valid (if degenerate) batch: empty (0, k)
    results from both the direct and the scheduler path."""
    r = _retriever(collection, host_indexes, "flat", "uncompressed")
    ids, scores = r.search(np.zeros((0, collection.fwd.dim), np.float32))
    assert ids.shape == scores.shape == (0, 5)
    ids_p, scores_p = r.search_batch(np.zeros((0, collection.fwd.dim)))
    assert ids_p.shape == scores_p.shape == (0, 5)


def test_batch_size_hint_gets_exact_plan(collection, queries, host_indexes):
    """The once-dead RetrieverConfig.batch_size: the hinted shape joins
    the bucket set, so the steady-state batch is served un-padded."""
    r = _retriever(collection, host_indexes, "flat", "streamvbyte",
                   batch_size=7)
    assert 7 in r.plans.buckets
    assert r.plans.bucket_for(7) == 7
    ids_h, sc_h = r.search(queries)  # exact-fit plan
    r8 = _retriever(collection, host_indexes, "flat", "streamvbyte")
    ids_8, sc_8 = r8.search(queries)  # padded to bucket 8
    assert np.array_equal(np.asarray(ids_h), np.asarray(ids_8))
    assert np.array_equal(np.asarray(sc_h), np.asarray(sc_8))


@pytest.mark.parametrize("bad", [0, -3, 2.5, True, "8"])
def test_invalid_batch_size_rejected(collection, bad):
    with pytest.raises(ValueError, match="batch_size"):
        Retriever.build(collection.fwd,
                        RetrieverConfig(engine="flat", batch_size=bad))


def test_recompile_counting(collection, queries, host_indexes):
    """Warm traffic never recompiles: every batch size within one
    bucket reuses the same plan; a new bucket is one compile."""
    r = _retriever(collection, host_indexes, "flat", "uncompressed")
    assert r.plans.compiles == 0
    r.search(queries[:5])  # bucket 8
    assert r.plans.compiles == 1
    r.search(queries[:7])  # same bucket — warm
    r.search(queries[:6])
    assert r.plans.compiles == 1
    r.search(queries[:2])  # bucket 2 — one more plan
    assert r.plans.compiles == 2
    assert r.pipeline().snapshot()["recompiles"] == 2


def test_plan_cache_shared_between_search_and_pipeline(collection, queries,
                                                      host_indexes):
    r = _retriever(collection, host_indexes, "flat", "uncompressed")
    r.search(queries)  # warms bucket 8
    n = r.plans.compiles
    r.search_batch(queries)  # scheduler dispatch reuses the warm plan
    assert r.plans.compiles == n
    # an explicit bucket override compiles its own cache
    pipe = Pipeline(r, buckets=(2,))
    assert pipe.plans is not r.plans


def test_oversized_batch_rejected_by_plan(collection, host_indexes, queries):
    r = _retriever(collection, host_indexes, "flat", "uncompressed")
    plan = r.plans.get(4)
    with pytest.raises(ValueError, match="exceeds plan bucket"):
        plan(queries)  # 7 queries into a 4-bucket plan


# ---------------------------------------------------------------------------
# artifacts + metrics
# ---------------------------------------------------------------------------


def test_artifact_round_trips_batch_size(collection, host_indexes, tmp_path):
    r = _retriever(collection, host_indexes, "flat", "streamvbyte",
                   batch_size=24)
    art = r.save(tmp_path / "bs")
    r2 = open_retriever(art)
    assert r2.cfg.batch_size == 24
    assert 24 in r2.plans.buckets


def test_stats_snapshot_contract(collection, queries, host_indexes):
    clock = FakeClock()
    r = _retriever(collection, host_indexes, "flat", "uncompressed")
    pipe = Pipeline(r, buckets=(4,), deadline_us=1e9, clock=clock)
    clock.advance_us(1e6)  # 1 s window
    pipe.search_batch(queries)  # 4 + 3-padded-to-4, then replay 2 hits
    pipe.search_batch(queries[:2])
    snap = pipe.snapshot()
    assert snap["n_queries"] == 9
    assert snap["qps"] == pytest.approx(9.0)  # clock frozen after 1 s
    assert snap["dispatches"] == {4: 2}
    assert snap["bucket_occupancy"][4] == pytest.approx(7 / 8)
    assert snap["cache_hit_rate"] == pytest.approx(2 / 9)
    assert snap["recompiles"] == 1
    for key in ("p50_us", "p95_us", "p99_us"):
        assert np.isfinite(snap[key])
