"""Distribution-layer tests on 8 forced host devices.

The main pytest process must keep seeing ONE device (smoke tests), so
every multi-device case runs in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8``."""

import os
import subprocess
import sys
import textwrap

import pytest

_ENV = {
    **os.environ,
    "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
    "PYTHONPATH": os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src")]
        + os.environ.get("PYTHONPATH", "").split(os.pathsep)
    ),
}


def _run(body: str) -> None:
    script = textwrap.dedent(body)
    proc = subprocess.run(
        [sys.executable, "-c", script], env=_ENV, capture_output=True, text=True,
        timeout=900,
    )
    assert proc.returncode == 0, f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"


def test_flash_decode_matches_reference():
    _run(
        """
        import jax, jax.numpy as jnp
        from repro.launch.mesh import make_debug_mesh
        from repro.dist.collectives import flash_decode_shardmap
        from repro.models.transformer import _decode_attention_ref
        mesh = make_debug_mesh((2, 4), ("data", "model"))
        key = jax.random.PRNGKey(0)
        B,S,H,Hk,dh = 4, 64, 8, 4, 16
        q = jax.random.normal(key, (B,1,H,dh))
        k = jax.random.normal(jax.random.fold_in(key,1), (B,S,Hk,dh))
        v = jax.random.normal(jax.random.fold_in(key,2), (B,S,Hk,dh))
        vl = jnp.array([5, 33, 64, 17], jnp.int32)
        want = _decode_attention_ref(q, k, v, vl)
        with jax.set_mesh(mesh):
            got = jax.jit(flash_decode_shardmap(mesh, batch_axes=("data",), seq_axes=("model",)))(q,k,v,vl)
            got2 = jax.jit(flash_decode_shardmap(mesh, batch_axes=(), seq_axes=("data","model")))(q,k,v,vl)
        import numpy as np
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)
        np.testing.assert_allclose(np.asarray(got2), np.asarray(want), atol=1e-5)
        print("flash decode OK")
        """
    )


def test_compressed_dp_training_converges():
    """int8+EF compressed DP trainer reaches the same loss basin as the
    uncompressed jit trainer on the quadratic problem."""
    _run(
        """
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.launch.mesh import make_debug_mesh
        from repro.train.optimizer import OptimizerConfig, make_optimizer
        from repro.train.train_step import (
            make_train_step, make_dp_compressed_train_step, init_train_state)
        mesh = make_debug_mesh((8,), ("data",))
        true_w = np.arange(8).reshape(8,1).astype(np.float32)
        def loss_fn(params, batch):
            pred = batch["x"] @ params["w"] + params["b"]
            return jnp.mean((pred - batch["y"])**2), {}
        params = {"w": jnp.zeros((8,1)), "b": jnp.zeros((1,))}
        cfg = OptimizerConfig(lr=0.05, warmup_steps=5, total_steps=300)
        oinit, oupd = make_optimizer(cfg)
        with jax.set_mesh(mesh):
            step_c = make_dp_compressed_train_step(
                loss_fn, oupd, mesh, {"x": P("data"), "y": P("data")}, dp_axes=("data",))
            state = init_train_state(params, oinit, mesh=mesh, dp_axes=("data",))
            key = jax.random.PRNGKey(0)
            for i in range(300):
                kk = jax.random.fold_in(key, i)
                x = jax.random.normal(kk, (64, 8))
                state, m = step_c(state, {"x": x, "y": x @ true_w})
        final = float(m["loss"])
        assert final < 0.01, final
        err = float(jnp.abs(state["params"]["w"] - true_w).max())
        assert err < 0.2, err
        print("compressed DP OK", final)
        """
    )


def test_sharded_engine_matches_local():
    _run(
        """
        import numpy as np, jax, jax.numpy as jnp
        from repro.launch.mesh import make_debug_mesh
        from repro.data.synthetic import SyntheticConfig, generate_collection
        from repro.serve.api import (Retriever, RetrieverConfig,
                                     build_shard_arrays, make_sharded_search)
        mesh = make_debug_mesh((2, 4), ("data", "model"))
        col = generate_collection(SyntheticConfig(
            name="t", dim=2048, n_docs=600, n_queries=8,
            doc_nnz_mean=60.0, query_nnz_mean=16.0, seed=0))
        from repro.serve.api import get_engine
        ecfg = RetrieverConfig(engine="seismic", codec="dotvbyte", k=10,
                               params=dict(cut=8, block_budget=256, n_probe=48,
                                           n_postings=300, block_size=16))
        idx = get_engine("seismic").host_index(col.fwd, ecfg)
        local = Retriever.from_host_index(idx, ecfg)
        Q = np.stack([col.query_dense(i) for i in range(8)])
        ids_l, sc_l = local.search(jnp.asarray(Q))
        arrays, idmap, n_local = build_shard_arrays(col.fwd, ecfg, n_shards=4,
                                                    host_index=idx)
        with jax.set_mesh(mesh):
            fn = make_sharded_search(mesh, ecfg, n_local, col.fwd.n_docs, 1.0,
                                     index_axis="model", query_axes=("data",))
            ids_s, sc_s = jax.jit(fn)(arrays, idmap, jnp.asarray(Q))
        # same top-k score multiset per query (ids may tie-swap)
        np.testing.assert_allclose(np.sort(np.asarray(sc_s), axis=1),
                                   np.sort(np.asarray(sc_l), axis=1), rtol=1e-4, atol=1e-4)
        overlap = np.mean([len(set(np.asarray(ids_s)[i]) & set(np.asarray(ids_l)[i])) / 10
                           for i in range(8)])
        assert overlap >= 0.9, overlap
        print("sharded engine OK", overlap)
        """
    )


def test_sharded_graph_engine_matches_local():
    """Row-sharded HNSW (one sub-graph per contiguous doc range,
    DESIGN.md §5) must find the same neighbourhood as a single local
    graph — ids can differ (different graphs), recall must not."""
    _run(
        """
        import numpy as np, jax, jax.numpy as jnp
        from repro.launch.mesh import make_debug_mesh
        from repro.data.synthetic import SyntheticConfig, generate_collection
        from repro.core.seismic import exact_top_k, recall_at_k
        from repro.serve.api import (RetrieverConfig, build_shard_arrays,
                                     make_sharded_search)
        mesh = make_debug_mesh((2, 4), ("data", "model"))
        col = generate_collection(SyntheticConfig(
            name="t", dim=2048, n_docs=400, n_queries=8,
            doc_nnz_mean=60.0, query_nnz_mean=16.0, seed=0))
        gcfg = RetrieverConfig(engine="hnsw", codec="streamvbyte", k=10,
                               params=dict(beam=48, iters=48, n_seeds=4,
                                           m=8, ef_construction=32))
        Q = np.stack([col.query_dense(i) for i in range(8)])
        arrays, idmap, n_local = build_shard_arrays(col.fwd, gcfg, n_shards=4)
        with jax.set_mesh(mesh):
            fn = make_sharded_search(mesh, gcfg, n_local, col.fwd.n_docs, 1.0,
                                     index_axis="model", query_axes=("data",))
            ids_s, sc_s = jax.jit(fn)(arrays, idmap, jnp.asarray(Q))
        recs = []
        for i in range(8):
            true_ids, _ = exact_top_k(col.fwd, Q[i], 10)
            recs.append(recall_at_k(true_ids, np.asarray(ids_s)[i]))
        assert np.mean(recs) >= 0.9, np.mean(recs)
        # scores are exact inner products of the returned global ids
        for i in range(3):
            want = col.fwd.exact_scores(Q[i])
            ok = np.asarray(ids_s)[i] < col.fwd.n_docs
            np.testing.assert_allclose(np.asarray(sc_s)[i][ok],
                                       want[np.asarray(ids_s)[i][ok]],
                                       rtol=1e-4, atol=1e-4)
        print("sharded graph engine OK", np.mean(recs))
        """
    )


def test_mini_dryrun_cell_on_debug_mesh():
    """Exercise the Cell machinery end-to-end on a reduced LM arch: the
    same lower+compile+roofline path the production dry-run uses."""
    _run(
        """
        import dataclasses, jax, jax.numpy as jnp
        from repro.launch.mesh import make_debug_mesh
        from repro.launch.hlo_stats import parse_collectives, roofline_terms
        from repro.configs.base import LMArch
        from repro.models.transformer import TransformerConfig
        from repro.models.moe import MoEConfig
        from repro.train.optimizer import OptimizerConfig
        import repro.configs.base as B
        mesh = make_debug_mesh((2, 4), ("data", "model"))
        B.LM_SHAPES = {
            "train_4k": dict(kind="train", seq_len=64, global_batch=8),
            "prefill_32k": dict(kind="prefill", seq_len=64, global_batch=8),
            "decode_32k": dict(kind="decode", seq_len=64, global_batch=8),
            "long_500k": dict(kind="decode", seq_len=64, global_batch=2),
        }
        arch = LMArch(
            name="mini",
            cfg=TransformerConfig(name="mini", n_layers=2, d_model=32, n_heads=8,
                                  n_kv_heads=4, d_ff=64, vocab=128,
                                  moe=MoEConfig(n_experts=8, top_k=2, d_model=32, d_ff=16),
                                  dtype=jnp.float32),
            optimizer=OptimizerConfig(),
        )
        for shape in ("train_4k", "prefill_32k", "decode_32k", "long_500k"):
            cell = arch.build_cell(shape, mesh)
            with jax.set_mesh(mesh):
                c = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                            out_shardings=cell.out_shardings).lower(*cell.input_structs).compile()
            stats = parse_collectives(c.as_text())
            cost = c.cost_analysis()
            r = roofline_terms(global_flops=cost.get("flops",0)*8,
                               device_flops=cost.get("flops",0),
                               device_bytes=cost.get("bytes accessed",0),
                               collective_bytes=stats.total_bytes, n_chips=8,
                               model_flops=arch.model_flops(shape))
            assert r["dominant"] in ("compute","memory","collective")
            print(shape, "OK", r["dominant"])
        """
    )


def test_gnn_and_recsys_cells_on_debug_mesh():
    _run(
        """
        import jax, jax.numpy as jnp
        from repro.launch.mesh import make_debug_mesh
        import repro.configs.base as B
        from repro.configs.base import GNNArch, RecsysArch
        from repro.models.recsys import DeepFMConfig
        from repro.train.optimizer import OptimizerConfig
        mesh = make_debug_mesh((2, 4), ("data", "model"))
        B.GNN_SHAPES = {"full_graph_sm": dict(kind="train", n_nodes=127, n_edges=512,
                                              d_feat=32, n_classes=4),
                        "molecule": dict(kind="train", n_nodes=127, n_edges=256,
                                         d_feat=8, n_classes=2, graphs=16)}
        g = GNNArch(name="gat-mini")
        g.shape_names = tuple(B.GNN_SHAPES)
        for shape in B.GNN_SHAPES:
            cell = g.build_cell(shape, mesh)
            with jax.set_mesh(mesh):
                jax.jit(cell.fn, in_shardings=cell.in_shardings,
                        out_shardings=cell.out_shardings).lower(*cell.input_structs).compile()
            print("gnn", shape, "OK")
        B.REC_SHAPES = {"train_batch": dict(kind="train", batch=64),
                        "serve_p99": dict(kind="serve", batch=32),
                        "retrieval_cand": dict(kind="serve", batch=1, n_candidates=1024)}
        r = RecsysArch(name="deepfm", cfg=DeepFMConfig(vocab_sizes=(64,)*39, embed_dim=4, mlp=(16,16)),
                       optimizer=OptimizerConfig())
        r.shape_names = tuple(B.REC_SHAPES)
        for shape in B.REC_SHAPES:
            cell = r.build_cell(shape, mesh)
            with jax.set_mesh(mesh):
                jax.jit(cell.fn, in_shardings=cell.in_shardings,
                        out_shardings=cell.out_shardings).lower(*cell.input_structs).compile()
            print("recsys", shape, "OK")
        """
    )


def test_edge_sharded_gat_matches_dense():
    """§Perf dst-aligned edge-sharded GAT (both gather modes) must equal
    the dense reference exactly."""
    _run(
        """
        import numpy as np, jax, jax.numpy as jnp
        from repro.launch.mesh import make_debug_mesh
        from repro.models import gnn as G
        rng = np.random.default_rng(0)
        N, E, F, C = 64, 400, 12, 5
        cfg = G.GATConfig(name="t", d_in=F, n_classes=C, d_hidden=8, n_heads=4)
        x = rng.normal(size=(N, F)).astype(np.float32)
        ei = rng.integers(0, N, size=(2, E))
        labels = rng.integers(0, C, size=N); mask = rng.random(N) < 0.6
        params = G.gat_init(jax.random.PRNGKey(0), cfg)
        g = G.pad_graph(x, ei, labels, mask, edge_budget=512)
        want, _ = G.gat_loss(params, cfg, g)
        mesh = make_debug_mesh((2,4), ("data","model"))
        esrc, edst, ep = G.partition_edges_by_dst(ei, N, 8)
        batch = {"x": jnp.asarray(x), "edge_src": jnp.asarray(esrc),
                 "edge_dst": jnp.asarray(edst),
                 "labels": jnp.asarray(labels.astype(np.int32)),
                 "train_mask": jnp.asarray(mask.astype(np.float32))}
        with jax.set_mesh(mesh):
            a, _ = G.gat_loss_edge_sharded(params, cfg, batch, mesh)
            b, _ = G.gat_loss_edge_sharded(params, cfg, batch, mesh, min_side_gather=True)
        assert abs(float(want)-float(a)) < 2e-4, (float(want), float(a))
        assert abs(float(want)-float(b)) < 2e-4, (float(want), float(b))
        # gradients flow
        gr = jax.grad(lambda p: G.gat_loss_edge_sharded(p, cfg, batch, mesh,
                      min_side_gather=True)[0])(params)
        assert all(bool(jnp.isfinite(t).all()) for t in jax.tree.leaves(gr))
        print("edge-sharded GAT parity OK")
        """
    )


def test_doc_aligned_scan_matches_exact():
    """§Perf opt1 on REAL data: sharded doc-aligned scan == CSR exact."""
    _run(
        """
        import numpy as np, jax, jax.numpy as jnp
        from repro.launch.mesh import make_debug_mesh
        from repro.core.forward_index import ForwardIndex, pack_forward_index_sharded
        from repro.core.scoring import make_doc_aligned_scan
        rng = np.random.default_rng(0)
        dim = 4096
        docs = []
        for _ in range(200):
            n = int(rng.integers(1, 150))
            c = np.sort(rng.choice(dim, size=n, replace=False))
            docs.append((c, rng.gamma(2., .5, size=n).astype(np.float32)))
        fwd = ForwardIndex.from_docs(docs, dim, value_format="f16")
        arrays, docs_local = pack_forward_index_sharded(fwd, 8, block_size=128,
                                                        seg_dtype=np.int8)
        arrays = {k: jnp.asarray(v) for k, v in arrays.items()}
        Q = np.zeros((3, dim), np.float32)
        for i in range(3):
            qc = rng.choice(dim, 30, replace=False)
            Q[i, qc] = rng.gamma(2., .5, size=30)
        mesh = make_debug_mesh((2, 4), ("data", "model"))
        with jax.set_mesh(mesh):
            fn = make_doc_aligned_scan(mesh, ("data", "model"), docs_local, 1.0)
            got = np.asarray(jax.jit(fn)(arrays, jnp.asarray(Q)))
        want = np.stack([fwd.exact_scores(Q[i]) for i in range(3)])
        err = np.abs(got[:, :fwd.n_docs] - want).max()
        assert err < 2e-3, err
        print("doc-aligned scan OK", err)
        """
    )
