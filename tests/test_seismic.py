"""Seismic reference engine + batched TPU engine tests (served through
the unified ``repro.serve.api`` Retriever, DESIGN.md §7)."""

import numpy as np
import pytest

from repro.core.layout import available_layouts
from repro.core.seismic import SeismicIndex, SeismicParams, exact_top_k, recall_at_k
from repro.data.synthetic import SyntheticConfig, generate_collection
from repro.serve.api import Retriever, RetrieverConfig


@pytest.fixture(scope="module")
def collection():
    cfg = SyntheticConfig(
        name="test", dim=4096, n_docs=1500, n_queries=12,
        doc_nnz_mean=80.0, query_nnz_mean=24.0, seed=0,
    )
    return generate_collection(cfg, value_format="f32")


@pytest.fixture(scope="module")
def index(collection):
    return SeismicIndex.build(
        collection.fwd, SeismicParams(n_postings=400, block_size=32, summary_mass=0.6)
    )


def test_recall_reference_engine(collection, index):
    recs = []
    for i in range(collection.n_queries):
        q = collection.query_dense(i)
        true_ids, _ = exact_top_k(collection.fwd, q, 10)
        got_ids, _ = index.search(q, k=10, heap_factor=0.9, cut=12)
        recs.append(recall_at_k(true_ids, got_ids))
    assert np.mean(recs) >= 0.85, np.mean(recs)


def test_recall_monotone_in_cut(collection, index):
    """Looser pruning must not reduce recall (statistically)."""
    r_small, r_big = [], []
    for i in range(collection.n_queries):
        q = collection.query_dense(i)
        true_ids, _ = exact_top_k(collection.fwd, q, 10)
        a, _ = index.search(q, k=10, heap_factor=1.0, cut=2)
        b, _ = index.search(q, k=10, heap_factor=0.8, cut=16)
        r_small.append(recall_at_k(true_ids, a))
        r_big.append(recall_at_k(true_ids, b))
    assert np.mean(r_big) >= np.mean(r_small)


def test_codec_rescore_parity(collection, index):
    """Compression is lossless on components: identical results."""
    index.prepare_codec("dotvbyte")
    q = collection.query_dense(0)
    i0, s0 = index.search(q, 10, 0.9, 8, codec="uncompressed")
    i1, s1 = index.search(q, 10, 0.9, 8, codec="dotvbyte")
    assert np.array_equal(i0, i1)
    np.testing.assert_allclose(s0, s1, rtol=1e-6)


def test_index_bytes_accounting(collection, index):
    sizes = index.index_bytes("dotvbyte")
    unc = index.index_bytes("uncompressed")
    assert sizes["forward_components"] < unc["forward_components"]
    assert sizes["total"] < unc["total"]
    assert unc["forward_components"] == 2 * collection.fwd.total_nnz


@pytest.mark.parametrize("codec", available_layouts())
def test_batched_engine_recall(collection, index, codec):
    eng = Retriever.from_host_index(
        index,
        RetrieverConfig(engine="seismic", codec=codec, k=10,
                        params=dict(cut=12, block_budget=768, n_probe=96)),
    )
    Q = np.stack([collection.query_dense(i) for i in range(collection.n_queries)])
    ids, scores = eng.search(Q)
    recs = []
    for i in range(collection.n_queries):
        true_ids, _ = exact_top_k(collection.fwd, Q[i], 10)
        recs.append(recall_at_k(true_ids, np.asarray(ids[i])))
    assert np.mean(recs) >= 0.85, np.mean(recs)
    # scores of returned docs are the exact inner products
    for i in range(3):
        want = collection.fwd.exact_scores(Q[i])
        got = np.asarray(scores[i])
        ok = np.asarray(ids[i]) < collection.fwd.n_docs
        np.testing.assert_allclose(got[ok], want[np.asarray(ids[i])[ok]], rtol=1e-3, atol=1e-3)


def test_batched_engine_codec_parity(collection, index):
    """Components compression is lossless: every registered layout codec
    (bitpack included) returns the exact same top-k as the uncompressed
    rows."""
    codecs = ["uncompressed"] + [c for c in available_layouts() if c != "uncompressed"]
    Q = np.stack([collection.query_dense(i) for i in range(4)])
    res = [
        Retriever.from_host_index(
            index, RetrieverConfig(engine="seismic", codec=c)
        ).search(Q)
        for c in codecs
    ]
    for i in range(1, len(res)):
        assert np.array_equal(np.asarray(res[0][0]), np.asarray(res[i][0]))
        np.testing.assert_allclose(
            np.asarray(res[0][1]), np.asarray(res[i][1]), rtol=1e-5
        )
