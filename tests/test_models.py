"""Per-architecture smoke tests (reduced configs, 1 CPU device) plus
model-level invariants (decode/forward parity, chunked-attention
equivalence, MoE dispatch conservation)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, RETRIEVAL_IDS, get_arch
from repro.models.moe import MoEConfig, moe_apply, moe_init
from repro.models.transformer import (
    TransformerConfig,
    decode_step,
    forward,
    init_kv_cache,
    init_params,
)


@pytest.mark.parametrize("arch_id", ARCH_IDS + RETRIEVAL_IDS)
def test_arch_smoke(arch_id):
    """Every assigned architecture instantiates a reduced config and
    runs a forward/train step with finite outputs (deliverable f)."""
    arch = get_arch(arch_id)
    result = arch.smoke(seed=0)
    assert isinstance(result, dict) and result


def _tiny(attn="full", moe=None, qk=False):
    return TransformerConfig(
        name="tiny", n_layers=2, d_model=32, n_heads=4, n_kv_heads=2, d_ff=64,
        vocab=64, qk_norm=qk, moe=moe, attention_impl=attn, attention_chunk=8,
        dtype=jnp.float32,
    )


def test_chunked_attention_equals_full():
    key = jax.random.PRNGKey(0)
    p = init_params(key, _tiny())
    toks = jax.random.randint(key, (2, 20), 0, 64)
    lf, _ = forward(p, _tiny("full"), toks)
    lc, _ = forward(p, _tiny("chunked"), toks)
    np.testing.assert_allclose(np.asarray(lf), np.asarray(lc), atol=2e-5)


def test_decode_matches_forward():
    key = jax.random.PRNGKey(1)
    cfg = _tiny(qk=True)
    p = init_params(key, cfg)
    toks = jax.random.randint(key, (2, 10), 0, 64)
    full, _ = forward(p, cfg, toks)
    cache = init_kv_cache(cfg, 2, 10, jnp.float32)
    lens = jnp.zeros(2, jnp.int32)
    outs = []
    for t in range(10):
        lg, cache = decode_step(p, cfg, cache, toks[:, t : t + 1], lens)
        lens = lens + 1
        outs.append(lg)
    np.testing.assert_allclose(
        np.asarray(jnp.stack(outs, 1)), np.asarray(full), atol=3e-5
    )


def test_moe_capacity_conservation():
    """Dispatch weights of surviving tokens are ≤1 and ≥0; output is a
    convex-ish combination (no token counted twice per expert slot)."""
    cfg = MoEConfig(n_experts=8, top_k=2, d_model=16, d_ff=32, capacity_factor=8.0)
    key = jax.random.PRNGKey(0)
    p = moe_init(key, cfg)
    x = jax.random.normal(key, (64, 16))
    y, aux = moe_apply(p, cfg, x)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y)).all()
    assert float(aux["load_balance_loss"]) > 0
    # with huge capacity nothing drops: output must differ from zero for
    # every token (each token reaches at least one expert)
    assert (np.abs(np.asarray(y)).sum(-1) > 0).all()


def test_moe_dropping_under_tight_capacity():
    cfg = MoEConfig(n_experts=4, top_k=1, d_model=8, d_ff=16, capacity_factor=0.25)
    key = jax.random.PRNGKey(2)
    p = moe_init(key, cfg)
    x = jax.random.normal(key, (64, 8))
    y, _ = moe_apply(p, cfg, x)
    dropped = (np.abs(np.asarray(y)).sum(-1) == 0).sum()
    assert dropped > 0  # tight capacity must actually drop tokens


def test_grad_flows_through_every_param():
    cfg = _tiny(moe=MoEConfig(n_experts=4, top_k=2, d_model=32, d_ff=16))
    key = jax.random.PRNGKey(3)
    p = init_params(key, cfg)
    toks = jax.random.randint(key, (2, 12), 0, 64)

    from repro.models.transformer import lm_loss

    g = jax.grad(lambda pp: lm_loss(pp, cfg, toks[:, :-1], toks[:, 1:])[0])(p)
    flat = jax.tree_util.tree_flatten_with_path(g)[0]
    zero_paths = [jax.tree_util.keystr(k) for k, v in flat if float(jnp.abs(v).sum()) == 0]
    # only the final-layer norms may legitimately be ~0 in 2 steps; params
    # like router/experts must receive gradient
    assert not any("moe" in z and "router" in z for z in zero_paths), zero_paths
