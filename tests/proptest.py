"""Minimal property-based testing helper.

``hypothesis`` is not installed in this container, so this module
provides the same workflow in ~80 lines: seeded random strategies, many
cases per property, and on failure a greedy shrink pass plus a printed
reproduction seed. Used by the codec/kernel/scoring property tests.
"""

from __future__ import annotations

import os
from typing import Callable

import numpy as np

N_CASES = int(os.environ.get("PROPTEST_CASES", "50"))


class Strategy:
    def __init__(self, draw: Callable[[np.random.Generator], object], label: str = "?"):
        self.draw = draw
        self.label = label


def integers(lo: int, hi: int) -> Strategy:
    return Strategy(lambda rng: int(rng.integers(lo, hi + 1)), f"int[{lo},{hi}]")


def sorted_unique_ints(max_n: int, lo: int, hi: int, min_n: int = 0) -> Strategy:
    """Sorted strictly-increasing arrays — the components invariant."""

    def draw(rng):
        n = int(rng.integers(min_n, max_n + 1))
        n = min(n, hi - lo)
        if n == 0:
            return np.zeros(0, dtype=np.uint32)
        vals = rng.choice(np.arange(lo, hi, dtype=np.int64), size=n, replace=False)
        return np.sort(vals).astype(np.uint32)

    return Strategy(draw, f"sorted_unique(n≤{max_n},[{lo},{hi}))")


def float_arrays(shape_fn, lo=0.0, hi=4.0) -> Strategy:
    def draw(rng):
        shape = shape_fn(rng) if callable(shape_fn) else shape_fn
        return (rng.random(shape) * (hi - lo) + lo).astype(np.float32)

    return Strategy(draw, "float_array")


def run_property(prop: Callable, *strategies: Strategy, n_cases: int = None, seed: int = 0):
    """Run ``prop(*drawn)`` for n_cases random draws; raise with repro info."""
    n = n_cases or N_CASES
    for case in range(n):
        rng = np.random.default_rng(seed * 100_003 + case)
        args = [s.draw(rng) for s in strategies]
        try:
            prop(*args)
        except AssertionError as e:
            shrunk = _shrink(prop, args)
            raise AssertionError(
                f"property failed (seed={seed}, case={case}, "
                f"strategies={[s.label for s in strategies]}):\n"
                f"  original args: {_fmt(args)}\n"
                f"  shrunk args:   {_fmt(shrunk)}\n  {e}"
            ) from e


def _shrink(prop, args, rounds: int = 40):
    """Greedy halving shrink on array args (keeps failure failing)."""
    cur = list(args)
    for _ in range(rounds):
        progressed = False
        for i, a in enumerate(cur):
            if isinstance(a, np.ndarray) and len(a) > 1:
                cand = list(cur)
                cand[i] = a[: len(a) // 2]
                try:
                    prop(*cand)
                except AssertionError:
                    cur = cand
                    progressed = True
        if not progressed:
            break
    return cur


def _fmt(args):
    out = []
    for a in args:
        if isinstance(a, np.ndarray):
            out.append(f"ndarray{a.shape}{a.dtype}:{a[:8]!r}…")
        else:
            out.append(repr(a))
    return "[" + ", ".join(out) + "]"
