"""Value-codec tests (DESIGN.md §12) — the ISSUE-10 acceptance suite:

* **clip-fit determinism** — scalar-quant clip ranges are fit per row
  on that row's OWN live values, so a document's code bytes are
  identical whether it is packed alone, inside a slice, or inside the
  full collection (the invariant that makes shard/segment/monolithic
  builds byte-compatible).
* **nibble round-trip** — u4 packing is an exact inverse through
  ragged rows (odd nnz) and empty docs, and decode error is bounded by
  half a quantization step.
* **PQ artifact round-trip** — the codebook survives save →
  ``open_retriever`` (monolithic and sharded) with byte-identical
  top-k.
* **mutation parity at every vq** — a ``MutableRetriever`` with
  tombstones + delta segments matches the oracle rebuild byte-for-byte
  at f16/u8_sq/u4_sq pre- and post-merge; pq (whose codebook is
  per-build, not per-doc) matches exactly post-merge and by top-k
  overlap pre-merge.
* **sub-byte shard stacking** — ragged shards of nibble-packed values
  stack and serve byte-identically to the monolithic build.
* **QAT hook** — the PACT fake-quant trains (loss decreases, the clip
  is learnable) and exports the pack-time clip override.
* **spec agreement** — ``row_array_specs`` matches a real pack at
  every codec × vq.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import layout, values
from repro.core.forward_index import ForwardIndex, pack_forward_index
from repro.core.scoring import score_packed
from repro.data.synthetic import SyntheticConfig, generate_collection
from repro.serve.api import (
    ArtifactError,
    Retriever,
    RetrieverConfig,
    open_retriever,
    row_array_specs,
)
from repro.serve.segments import MutableRetriever

QUANT_VQS = ("u8_sq", "u4_sq", "pq")


@pytest.fixture(scope="module")
def collection():
    cfg = SyntheticConfig(name="values-test", dim=256, n_docs=50, n_queries=4,
                          doc_nnz_mean=24.0, query_nnz_mean=8.0, seed=3)
    return generate_collection(cfg, value_format="f16")


@pytest.fixture(scope="module")
def queries(collection):
    return np.stack(
        [collection.query_dense(i) for i in range(collection.n_queries)]
    )


# ---------------------------------------------------------------------------
# encoder invariants
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("vq", ("u8_sq", "u4_sq"))
def test_clip_fit_is_per_row_and_deterministic(collection, vq):
    """Same doc → same code bytes, packed alone or with the whole
    collection; repeated packs are byte-identical."""
    fwd = collection.fwd
    full = layout.pack_rows(fwd, codec="uncompressed", vq=vq)
    again = layout.pack_rows(fwd, codec="uncompressed", vq=vq)
    for k, v in full.arrays().items():
        np.testing.assert_array_equal(np.asarray(v), np.asarray(again.arrays()[k]))

    part = layout.pack_rows(fwd.slice(10, 20), codec="uncompressed", vq=vq)
    fa, pa = full.arrays(), part.arrays()
    w = min(fa["vals_rows"].shape[1], pa["vals_rows"].shape[1])
    np.testing.assert_array_equal(
        np.asarray(fa["vals_rows"])[10:20, :w], np.asarray(pa["vals_rows"])[:10, :w]
    )
    lo_key, sc_key = values.sq_keys(vq)
    np.testing.assert_array_equal(np.asarray(fa[lo_key])[10:20],
                                  np.asarray(pa[lo_key])[:10])
    np.testing.assert_array_equal(np.asarray(fa[sc_key])[10:20],
                                  np.asarray(pa[sc_key])[:10])


def test_u4_nibble_roundtrip_ragged_and_empty():
    """pack→unpack is exact for 4-bit codes through odd-nnz rows and an
    all-dead row; odd trailing dims are rejected."""
    rng = np.random.default_rng(0)
    codes = rng.integers(0, 16, size=(5, 8)).astype(np.uint8)
    codes[1, 3:] = 0  # odd live length (3) inside an even capacity
    codes[4, :] = 0   # empty doc
    packed = values.pack_nibbles(codes)
    assert packed.shape == (5, 4) and packed.dtype == np.uint8
    np.testing.assert_array_equal(np.asarray(values.unpack_nibbles(packed)), codes)
    with pytest.raises(ValueError):
        values.pack_nibbles(codes[:, :7])


@pytest.mark.parametrize("vq", ("u8_sq", "u4_sq"))
def test_sq_decode_error_bounded_by_half_step(collection, vq):
    """Dequantized live values differ from the originals by ≤ step/2."""
    fwd = collection.fwd
    legacy = layout.pack_rows(fwd, codec="uncompressed")
    quant = layout.pack_rows(fwd, codec="uncompressed", vq=vq)
    la, qa = legacy.arrays(), quant.arrays()
    lo_key, sc_key = values.sq_keys(vq)
    dec = np.asarray(values.decode_codes(
        vq, jnp.asarray(qa["vals_rows"]),
        lo=jnp.asarray(qa[lo_key]), step=jnp.asarray(qa[sc_key]),
    ))
    ref = np.asarray(la["vals_rows"], np.float32)
    nnz = np.asarray(la["nnz_rows"])
    live = np.arange(ref.shape[1])[None, :] < nnz[:, None]
    err = np.abs(dec[:, : ref.shape[1]] - ref)
    tol = np.asarray(qa[sc_key]) * 0.5 + 1e-5
    assert (err[live] <= np.broadcast_to(tol, err.shape)[live]).all()


# ---------------------------------------------------------------------------
# artifacts, shards, segments
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n_shards", (1, 2))
def test_pq_codebook_artifact_roundtrip(collection, queries, tmp_path, n_shards):
    """The PQ codebook rides the artifact: save → open_retriever is
    byte-identical and the manifest round-trips cfg.vq."""
    cfg = RetrieverConfig(engine="flat", codec="streamvbyte", vq="pq", k=10,
                          n_shards=n_shards)
    r = Retriever.build(collection.fwd, cfg)
    ids, scores = map(np.asarray, r.search(queries))
    art = r.save(tmp_path / f"pq-{n_shards}")
    r2 = open_retriever(art)
    assert r2.cfg.vq == "pq"
    i2, s2 = map(np.asarray, r2.search(queries))
    np.testing.assert_array_equal(ids, i2)
    np.testing.assert_array_equal(scores, s2)


def test_unknown_vq_rejected(collection, tmp_path):
    with pytest.raises(ValueError, match="value codec"):
        Retriever.build(collection.fwd,
                        RetrieverConfig(engine="flat", vq="int3"))
    r = Retriever.build(collection.fwd,
                        RetrieverConfig(engine="flat", vq="u8_sq", k=5))
    art = r.save(tmp_path / "tamper-vq")
    import json
    man = art / "manifest.json"
    meta = json.loads(man.read_text())
    meta["vq"] = "int3"
    man.write_text(json.dumps(meta))
    with pytest.raises(ArtifactError, match="value codec"):
        open_retriever(art)


@pytest.mark.parametrize("vq", ("u8_sq", "u4_sq"))
def test_sharded_matches_monolithic(collection, queries, vq):
    """Ragged shards (50 docs over 3 shards) of quantized — u4:
    nibble-packed, sub-byte — values serve byte-identically to the
    monolithic build (pq is per-build, so excluded by design)."""
    mono = Retriever.build(
        collection.fwd, RetrieverConfig(engine="flat", codec="streamvbyte",
                                        vq=vq, k=10))
    shard = Retriever.build(
        collection.fwd, RetrieverConfig(engine="flat", codec="streamvbyte",
                                        vq=vq, k=10, n_shards=3))
    mi, ms = map(np.asarray, mono.search(queries))
    si, ss = map(np.asarray, shard.search(queries))
    np.testing.assert_array_equal(mi, si)
    np.testing.assert_array_equal(ms, ss)


@pytest.mark.parametrize("vq", ("f16", "u8_sq", "u4_sq"))
def test_mutation_parity_at_vq(collection, queries, vq):
    """Tombstones + a delta segment at every per-doc-stable vq: the
    mutable view matches the oracle rebuild byte-for-byte, before and
    after merge."""
    fwd = collection.fwd
    cfg = RetrieverConfig(engine="flat", codec="streamvbyte", vq=vq, k=5)
    m = MutableRetriever.create(fwd.slice(0, 40), cfg)
    m.delete([3, 17])
    m.insert([fwd.doc(i) for i in range(40, 44)])

    def oracle_parity(label):
        live_fwd, live = m.live_corpus()
        oracle = Retriever.build(live_fwd, cfg)
        oi, osc = map(np.asarray, oracle.search(queries))
        mi, ms = map(np.asarray, m.search(queries))
        np.testing.assert_array_equal(mi, live[oi], err_msg=f"{label}: ids")
        np.testing.assert_array_equal(ms, osc, err_msg=f"{label}: scores")

    oracle_parity(f"{vq} 1 segment")
    m.merge()
    oracle_parity(f"{vq} post-merge")


def test_mutation_pq_overlap_and_merge_parity(collection, queries):
    """PQ codebooks are per-build (DESIGN.md §12): segments quantize
    against their own codebook, so pre-merge parity is top-k overlap,
    not bytes; post-merge (one build) parity is exact again."""
    fwd = collection.fwd
    cfg = RetrieverConfig(engine="flat", codec="streamvbyte", vq="pq", k=5)
    m = MutableRetriever.create(fwd.slice(0, 40), cfg)
    m.delete([3, 17])
    m.insert([fwd.doc(i) for i in range(40, 44)])
    live_fwd, live = m.live_corpus()
    oracle = Retriever.build(live_fwd, cfg)
    oi, _ = map(np.asarray, oracle.search(queries))
    mi, _ = map(np.asarray, m.search(queries))
    overlap = np.mean([
        len(set(mi[i].tolist()) & set(live[oi[i]].tolist())) / mi.shape[1]
        for i in range(mi.shape[0])
    ])
    assert overlap >= 0.8, overlap

    m.merge()
    live_fwd, live = m.live_corpus()
    oracle = Retriever.build(live_fwd, cfg)
    oi, osc = map(np.asarray, oracle.search(queries))
    mi, ms = map(np.asarray, m.search(queries))
    np.testing.assert_array_equal(mi, live[oi])
    np.testing.assert_array_equal(ms, osc)


# ---------------------------------------------------------------------------
# block path, specs, QAT
# ---------------------------------------------------------------------------


def test_block_path_vq_scores_and_fused_fallback(collection):
    """Quantized blocks score approximately like f16 blocks through the
    jnp reference, and the fused entry point serves them identically to
    the reference (block kernels fall back to jnp under vq, warning
    once)."""
    from repro.kernels.registry import get_kernels

    fwd = collection.fwd
    q = collection.query_dense(0)
    ref = np.asarray(score_packed(q, pack_forward_index(fwd, codec="dotvbyte",
                                                        block_size=128)))
    pq8 = pack_forward_index(fwd, codec="dotvbyte", block_size=128, vq="u8_sq")
    got = np.asarray(score_packed(q, pq8))
    live = ref != 0
    assert np.allclose(got[live], ref[live], rtol=0.05, atol=0.1)
    with pytest.warns(RuntimeWarning, match="no fused vq"):
        fused = np.asarray(
            get_kernels("dotvbyte").block_scores(q, pq8, "pallas_compiled"))
    np.testing.assert_array_equal(fused, got)


@pytest.mark.parametrize("codec", layout.available_layouts())
@pytest.mark.parametrize("vq", QUANT_VQS)
def test_row_array_specs_match_real_pack(collection, codec, vq):
    packed = layout.pack_rows(collection.fwd, codec=codec, vq=vq)
    arrays = packed.arrays()
    factor = values.code_factor(vq)
    l_max = int(arrays["vals_rows"].shape[1]) * factor
    d_max = int(arrays["data_rows"].shape[1]) if "data_rows" in arrays else 0
    specs = row_array_specs(codec, n_docs=collection.fwd.n_docs, l_max=l_max,
                            d_max=d_max, vq=vq)
    assert set(specs) == set(arrays)
    vq_exact = ("vals_rows", "nnz_rows") + tuple(values.VQ_ROW_KEYS)
    for k, sds in specs.items():
        a = np.asarray(arrays[k])
        assert a.dtype == np.dtype(sds.dtype), (k, a.dtype, sds.dtype)
        if k in vq_exact:
            # the value streams size exactly — the quantized byte
            # accounting (DESIGN.md §12) hangs off these widths
            assert a.shape == sds.shape, (k, a.shape, sds.shape)
        else:
            # id-codec streams are nominal sizing: pack-time encoders
            # lane-pad trailing dims, so real widths may exceed specs
            assert len(a.shape) == len(sds.shape)
            assert all(r >= s for r, s in zip(a.shape, sds.shape)), (
                k, a.shape, sds.shape)


def test_qat_trains_and_exports_clip():
    """The PACT fake-quant hook: a training step runs under quantize=True,
    the clip is learnable, and the trained range exports as the
    pack-time clip override (in storage units)."""
    from repro.models.sparse_encoder import (
        SparseEncoderConfig, contrastive_loss, encoder_init,
        export_quant_clip, fake_quantize,
    )

    cfg = SparseEncoderConfig(vocab=512, n_layers=2, d_model=32, n_heads=4,
                              d_ff=64, max_len=16, quantize=True, quant_bits=8)
    key = jax.random.PRNGKey(0)
    p = encoder_init(key, cfg)
    assert float(p["quant_hi"]) == cfg.quant_clip_init
    ks = jax.random.split(key, 2)
    batch = {
        "q_tokens": jax.random.randint(ks[0], (4, 16), 0, cfg.vocab),
        "q_mask": jnp.ones((4, 16), bool),
        "d_tokens": jax.random.randint(ks[1], (4, 16), 0, cfg.vocab),
        "d_mask": jnp.ones((4, 16), bool),
    }
    (loss, _), grads = jax.value_and_grad(
        lambda pp: contrastive_loss(pp, cfg, batch), has_aux=True)(p)
    assert np.isfinite(float(loss))
    assert np.isfinite(float(grads["quant_hi"]))  # PACT: hi is learnable

    # forward semantics: outputs snap to the 255-level grid inside [0, hi]
    acts = jnp.asarray([[0.0, 0.1, 2.0, 9.0]])
    out = np.asarray(fake_quantize(acts, jnp.float32(4.0), 8))
    assert out.max() <= 4.0 and out.min() >= 0.0
    steps = out / (4.0 / 255.0)
    np.testing.assert_allclose(steps, np.round(steps), atol=1e-4)

    lo, hi = export_quant_clip(p, cfg, storage_scale=2.0)
    assert lo == 0.0 and hi == pytest.approx(cfg.quant_clip_init / 2.0)
    with pytest.raises(ValueError, match="quantizer"):
        export_quant_clip(
            encoder_init(key, SparseEncoderConfig(vocab=512, n_layers=2,
                                                  d_model=32, n_heads=4,
                                                  d_ff=64, max_len=16)),
            cfg)
