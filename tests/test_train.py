"""Training substrate: optimizers, microbatching, checkpoint/restore,
fault-injected elastic runner."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import checkpoint
from repro.train.elastic import FaultInjector, Runner, RunnerConfig
from repro.train.optimizer import OptimizerConfig, make_optimizer
from repro.train.train_step import init_train_state, make_train_step


def _quadratic():
    true_w = np.arange(8).reshape(8, 1).astype(np.float32)

    def loss_fn(params, batch):
        pred = batch["x"] @ params["w"] + params["b"]
        return jnp.mean((pred - batch["y"]) ** 2), {}

    def batch_fn(step, key=jax.random.PRNGKey(0), bs=32):
        kk = jax.random.fold_in(key, step)
        x = jax.random.normal(kk, (bs, 8))
        return {"x": x, "y": x @ true_w}

    params = {"w": jnp.zeros((8, 1)), "b": jnp.zeros((1,))}
    return loss_fn, batch_fn, params


@pytest.mark.parametrize("opt_name", ["adamw", "adafactor"])
def test_optimizer_converges(opt_name):
    loss_fn, batch_fn, params = _quadratic()
    cfg = OptimizerConfig(name=opt_name, lr=0.05, warmup_steps=10, total_steps=400,
                          factored_min_dim=1)
    oinit, oupd = make_optimizer(cfg)
    step = jax.jit(make_train_step(loss_fn, oupd))
    state = init_train_state(params, oinit)
    first = last = None
    for i in range(400):
        state, m = step(state, batch_fn(i))
        if i == 0:
            first = float(m["loss"])
        last = float(m["loss"])
    assert last < first * 1e-2, (first, last)


def test_microbatch_equivalence():
    """mb=4 must produce the same update as mb=1 (mean of grads)."""
    loss_fn, batch_fn, params = _quadratic()
    cfg = OptimizerConfig(name="adamw", lr=0.01, warmup_steps=1, total_steps=100)
    oinit, oupd = make_optimizer(cfg)
    s1 = jax.jit(make_train_step(loss_fn, oupd, microbatches=1))
    s4 = jax.jit(make_train_step(loss_fn, oupd, microbatches=4))
    batch = batch_fn(0)
    st1, _ = s1(init_train_state(params, oinit), batch)
    st4, _ = s4(init_train_state(params, oinit), batch)
    np.testing.assert_allclose(
        np.asarray(st1["params"]["w"]), np.asarray(st4["params"]["w"]), rtol=1e-5
    )


def test_checkpoint_roundtrip_and_atomicity():
    loss_fn, batch_fn, params = _quadratic()
    cfg = OptimizerConfig(lr=0.01)
    oinit, _ = make_optimizer(cfg)
    state = init_train_state(params, oinit)
    with tempfile.TemporaryDirectory() as d:
        checkpoint.save(d, 5, state, metadata={"note": "a"})
        checkpoint.save(d, 9, state)
        assert checkpoint.latest_step(d) == 9
        restored, meta = checkpoint.restore(d, state)
        assert meta["step"] == 9
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # a stale .tmp directory must never be picked up
        os.makedirs(os.path.join(d, "step_00000011.tmp"), exist_ok=True)
        assert checkpoint.latest_step(d) == 9
        assert 11 not in checkpoint.available_steps(d)


def test_checkpoint_prunes_old():
    loss_fn, _, params = _quadratic()
    oinit, _ = make_optimizer(OptimizerConfig())
    state = init_train_state(params, oinit)
    with tempfile.TemporaryDirectory() as d:
        for s in range(6):
            checkpoint.save(d, s, state, keep_last=2)
        assert checkpoint.available_steps(d) == [4, 5]


def test_runner_recovers_from_faults():
    loss_fn, batch_fn, params = _quadratic()
    cfg = OptimizerConfig(lr=0.05, warmup_steps=5, total_steps=100)
    oinit, oupd = make_optimizer(cfg)
    step = jax.jit(make_train_step(loss_fn, oupd))
    with tempfile.TemporaryDirectory() as d:
        runner = Runner(
            RunnerConfig(total_steps=40, checkpoint_dir=d, checkpoint_every=10),
            step, batch_fn, init_train_state(params, oinit),
            fault_injector=FaultInjector(fail_at=(7, 23, 23)),
        )
        state, hist = runner.run()
        assert runner.restarts == 2
        steps_done = [h["step"] for h in hist]
        assert max(steps_done) == 39
        # deterministic replay: the final state equals a fault-free run
        runner2 = Runner(
            RunnerConfig(total_steps=40, checkpoint_dir=tempfile.mkdtemp(), checkpoint_every=10),
            step, batch_fn, init_train_state(params, oinit),
        )
        state2, _ = runner2.run()
        np.testing.assert_allclose(
            np.asarray(state["params"]["w"]), np.asarray(state2["params"]["w"]),
            rtol=1e-6,
        )


def test_runner_max_restarts():
    loss_fn, batch_fn, params = _quadratic()
    oinit, oupd = make_optimizer(OptimizerConfig(lr=0.01))
    step = jax.jit(make_train_step(loss_fn, oupd))
    with tempfile.TemporaryDirectory() as d:
        runner = Runner(
            RunnerConfig(total_steps=10, checkpoint_dir=d, checkpoint_every=5, max_restarts=2),
            step, batch_fn, init_train_state(params, oinit),
            fault_injector=FaultInjector(fail_at=(3,)),
        )
        runner.fault.fired = set()  # keep refiring

        class AlwaysFail(FaultInjector):
            def maybe_fail(self, step):
                if step == 3:
                    raise RuntimeError("permafault")

        runner.fault = AlwaysFail()
        with pytest.raises(RuntimeError):
            runner.run()
