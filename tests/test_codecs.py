"""Codec unit + property tests: every codec must round-trip every legal
component sequence, and the paper's size orderings must hold on
realistic (Zipf-gap) data."""

import numpy as np
import pytest

from proptest import run_property, sorted_unique_ints
from repro.core.codecs import available_codecs, get_codec
from repro.core.codecs.base import components_from_gaps, gaps_from_components
from repro.core.codecs.bitpack import pack_block, unpack_block
from repro.core.codecs.dotvbyte import decode_doc_arrays, encode_doc_arrays

ALL_CODECS = available_codecs()


@pytest.mark.parametrize("name", ALL_CODECS)
def test_roundtrip_property(name):
    codec = get_codec(name)

    def prop(comps):
        if len(comps) == 0:
            return
        buf = codec.encode_doc(comps)
        out = codec.decode_doc(buf, len(comps))
        assert np.array_equal(out, comps), f"{name} roundtrip mismatch"

    run_property(prop, sorted_unique_ints(400, 0, 65536, min_n=1), seed=7)


@pytest.mark.parametrize("name", ALL_CODECS)
@pytest.mark.parametrize(
    "comps",
    [
        np.array([0], dtype=np.uint32),  # component 0 (gap 0 at start)
        np.array([65535], dtype=np.uint32),  # max component
        np.array([0, 65535], dtype=np.uint32),  # max gap
        np.arange(64, dtype=np.uint32),  # all-ones gaps
        np.arange(0, 65536, 8192, dtype=np.uint32),  # large uniform gaps
        np.array([7], dtype=np.uint32),
        np.arange(9, dtype=np.uint32),  # DotVByte remainder path (9 = 8+1)
    ],
)
def test_roundtrip_edges(name, comps):
    codec = get_codec(name)
    assert np.array_equal(codec.decode_doc(codec.encode_doc(comps), len(comps)), comps)


def test_gap_transform_inverse():
    def prop(comps):
        if len(comps) == 0:
            return
        assert np.array_equal(components_from_gaps(gaps_from_components(comps)), comps)

    run_property(prop, sorted_unique_ints(500, 0, 65536, min_n=1), seed=3)


def test_gap_transform_rejects_unsorted():
    with pytest.raises(ValueError):
        gaps_from_components(np.array([5, 3], dtype=np.uint32))
    with pytest.raises(ValueError):
        gaps_from_components(np.array([3, 3], dtype=np.uint32))


def _zipf_docs(n_docs=150, dim=30522, nnz=119, seed=0):
    """Clustered Zipf-ish components — realistic gap distribution."""
    rng = np.random.default_rng(seed)
    w = 1.0 / np.arange(1, dim + 1) ** 1.1
    w /= w.sum()
    docs = []
    for _ in range(n_docs):
        c = np.unique(rng.choice(dim, size=nnz, p=w))
        docs.append(c.astype(np.uint32))
    return docs


def test_paper_size_orderings():
    """Table 1 qualitative structure: every codec < 16 bits; zeta is the
    smallest of the entropy codes; dotvbyte ≤ streamvbyte (1-bit vs 2-bit
    controls); uncompressed is exactly 16."""
    docs = _zipf_docs()
    bpc = {n: get_codec(n).bits_per_component(docs) for n in ALL_CODECS}
    assert bpc["uncompressed"] == 16.0
    for n in ALL_CODECS:
        if n != "uncompressed":
            assert bpc[n] < 16.0, (n, bpc[n])
    assert bpc["dotvbyte"] <= bpc["streamvbyte"] + 1e-9
    assert bpc["zeta"] < bpc["vbyte"]


def test_dotvbyte_alignment_invariants():
    """Per-document alignment (§2.2): n8 components compressed, ≤7 raw."""

    def prop(comps):
        if len(comps) == 0:
            return
        ctrl, data, rem = encode_doc_arrays(comps)
        n8 = (len(comps) // 8) * 8
        assert len(ctrl) == n8 // 8
        assert len(rem) == len(comps) - n8 <= 7
        popcnt = int(np.unpackbits(ctrl).sum()) if len(ctrl) else 0
        assert len(data) == n8 + popcnt  # 1 byte + 1 extra per 2-byte gap
        assert np.array_equal(decode_doc_arrays(ctrl, data, rem), comps)

    run_property(prop, sorted_unique_ints(200, 0, 65536, min_n=1), seed=11)


def test_bitpack_block_roundtrip_all_widths():
    rng = np.random.default_rng(0)
    for width in range(1, 18):
        vals = rng.integers(0, 1 << width, size=128).astype(np.uint32)
        words = pack_block(vals, width)
        assert len(words) == (128 * width + 31) // 32
        out = unpack_block(words, width, 128)
        assert np.array_equal(out, vals), width


def test_codec_sizes_count_all_streams():
    comps = np.arange(0, 330, 3, dtype=np.uint32)  # 110 comps
    codec = get_codec("dotvbyte")
    assert codec.encoded_size_bytes(comps) == len(codec.encode_doc(comps))
