"""Layout-subsystem tests: every registered layout codec must round-trip
through BOTH fixed-shape forms (packed blocks [B,T] and doc rows [N+1,L])
against the numpy exact-scoring oracle, including the shapes the codecs
historically mishandled — empty documents, single-element documents, and
gaps wider than 16 bits (StreamVByte's 3–4-byte cases, which DotVByte
cannot represent)."""

import numpy as np
import pytest

from proptest import run_property, integers, sorted_unique_ints
from repro.core import layout
from repro.core.forward_index import ForwardIndex
from repro.core.scoring import score_doc_rows, score_packed

ALL_LAYOUTS = layout.available_layouts()
WIDE_GAP_LAYOUTS = [n for n in ALL_LAYOUTS if n != "dotvbyte"]  # >16-bit gaps


def _fwd_from_docs(docs, dim, value_format="f16"):
    return ForwardIndex.from_docs(docs, dim, value_format=value_format)


def _random_docs(rng, n_docs, dim, max_nnz, allow_empty=True):
    docs = []
    for _ in range(n_docs):
        n = int(rng.integers(0 if allow_empty else 1, max_nnz + 1))
        c = np.sort(rng.choice(dim, size=min(n, dim // 2), replace=False))
        v = rng.gamma(2.0, 0.5, size=len(c)).astype(np.float32) + 0.05
        docs.append((c.astype(np.uint32), v))
    if all(len(c) == 0 for c, _ in docs):
        docs[0] = (np.array([3], np.uint32), np.array([1.0], np.float32))
    return docs


def _query(rng, dim, nnz=32):
    q = np.zeros(dim, dtype=np.float32)
    qc = rng.choice(dim, size=min(nnz, dim), replace=False)
    q[qc] = rng.gamma(2.0, 0.5, size=len(qc)) + 0.05
    return q


def _check_both_forms(fwd, codec, q, atol=2e-3):
    want = fwd.exact_scores(q)
    packed = layout.pack_blocks(fwd, codec=codec, block_size=128)
    got_blocks = np.asarray(score_packed(q, packed))
    np.testing.assert_allclose(got_blocks, want, atol=atol, rtol=1e-3)

    rows = layout.pack_rows(fwd, codec=codec)
    arrays = rows.arrays()
    if "comps_rows" in arrays:
        comps = arrays["comps_rows"]
    else:
        import jax.numpy as jnp

        streams = {
            k[: -len("_rows")]: v
            for k, v in rows.payload.items()
            if k.endswith("_rows")
        }
        gaps = layout.get_layout(codec).decode(streams, rows.l_max)
        comps = jnp.cumsum(gaps, axis=1)  # row-first gap is absolute
    got_rows = np.asarray(
        score_doc_rows(
            q, np.asarray(comps), arrays["vals_rows"], arrays["nnz_rows"],
            float(fwd.value_format.scale),
        )
    )[: fwd.n_docs]
    np.testing.assert_allclose(got_rows, want, atol=atol, rtol=1e-3)


# ---------------------------------------------------------------------------
# property: block AND row scoring match the exact oracle, every codec
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("codec", ALL_LAYOUTS)
def test_block_and_row_scoring_match_exact_property(codec):
    dim = 4096

    def prop(seed):
        rng = np.random.default_rng(seed)
        docs = _random_docs(rng, n_docs=12, dim=dim, max_nnz=200)
        fwd = _fwd_from_docs(docs, dim)
        _check_both_forms(fwd, codec, _query(rng, dim))

    run_property(prop, integers(0, 1 << 30), n_cases=8, seed=13)


@pytest.mark.parametrize("codec", ALL_LAYOUTS)
def test_edge_docs_empty_and_single(codec):
    """Empty docs score 0 through both forms; single-element docs carry
    their absolute component through the gap transform."""
    dim = 2048
    docs = [
        (np.zeros(0, np.uint32), np.zeros(0, np.float32)),  # empty
        (np.array([0], np.uint32), np.array([1.5], np.float32)),  # component 0
        (np.array([2047], np.uint32), np.array([2.0], np.float32)),  # max comp
        (np.zeros(0, np.uint32), np.zeros(0, np.float32)),  # empty again
        (np.array([7, 9], np.uint32), np.array([1.0, 1.0], np.float32)),
    ]
    fwd = _fwd_from_docs(docs, dim, value_format="f32")
    q = np.ones(dim, dtype=np.float32)
    _check_both_forms(fwd, codec, q, atol=1e-5)
    assert fwd.exact_scores(q)[0] == 0.0  # the empty doc really scores 0


@pytest.mark.parametrize("codec", WIDE_GAP_LAYOUTS)
def test_gaps_beyond_16_bits(codec):
    """StreamVByte's 3- and 4-byte branches (gap > 0xFFFF / > 0xFFFFFF):
    exact through blocks and rows on a 2^25-dim space."""
    dim = 1 << 25
    docs = [
        (np.array([5, 5 + 70_000, 5 + 70_000 + 20_000_000], np.uint32),
         np.array([1.0, 2.0, 3.0], np.float32)),
        (np.array([0xFFFF + 1], np.uint32), np.array([4.0], np.float32)),
        (np.array([1, 2, 3], np.uint32), np.array([1.0, 1.0, 1.0], np.float32)),
    ]
    fwd = _fwd_from_docs(docs, dim, value_format="f32")
    q = np.zeros(dim, dtype=np.float32)
    for c, _ in docs:
        q[c] += 1.0
    _check_both_forms(fwd, codec, q, atol=1e-5)


def test_dotvbyte_rejects_wide_gaps():
    """DotVByte is 16-bit by construction (§2.2) — wide gaps must fail
    loudly at pack time, not corrupt silently."""
    dim = 1 << 20
    fwd = _fwd_from_docs(
        [(np.array([0, 0x10000], np.uint32), np.array([1.0, 1.0], np.float32))], dim
    )
    with pytest.raises(ValueError):
        layout.pack_blocks(fwd, codec="dotvbyte", block_size=128)
    with pytest.raises(ValueError):
        layout.pack_rows(fwd, codec="dotvbyte")


def test_unknown_codec_rejected():
    fwd = _fwd_from_docs([(np.array([1], np.uint32), np.array([1.0], np.float32))], 16)
    with pytest.raises(ValueError):
        layout.pack_blocks(fwd, codec="zeta")  # bit-oriented: no device layout


# ---------------------------------------------------------------------------
# codec encoders round-trip at the gap-matrix level
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("codec", ["dotvbyte", "streamvbyte", "bitpack"])
def test_gap_matrix_roundtrip(codec):
    lc = layout.get_layout(codec)
    hi = 0xFFFF if codec == "dotvbyte" else (1 << 28)

    def prop(comps):
        T = 64
        n = min(len(comps), T)
        gaps = np.zeros((1, T), dtype=np.uint32)
        if n:
            c = comps[:n].astype(np.int64)
            gaps[0, 0] = c[0]
            gaps[0, 1:n] = np.diff(c)
        streams = lc.encode(gaps)
        out = np.asarray(lc.decode(streams, T))
        assert out.shape == (1, T)
        assert np.array_equal(out.astype(np.uint32), gaps), codec

    run_property(prop, sorted_unique_ints(64, 0, hi, min_n=0), n_cases=30, seed=5)


# ---------------------------------------------------------------------------
# shared shard stacking
# ---------------------------------------------------------------------------


def test_pad_stack_pads_every_axis_to_max():
    a = {"x": np.ones((2, 3), np.int32), "y": np.full((4,), 7, np.int8)}
    b = {"x": np.ones((3, 2), np.int32), "y": np.full((1,), 7, np.int8)}
    out = layout.pad_stack([a, b], pad_values={"x": -1})
    assert out["x"].shape == (2, 3, 3) and out["y"].shape == (2, 4)
    assert out["x"][0, 2, 0] == -1 and out["x"][1, 0, 2] == -1  # pad value
    assert out["x"][1, :3, :2].sum() == 6  # payload intact
    assert out["y"][1, 1] == 0  # default pad


def test_pad_stack_rejects_mismatched_fields():
    with pytest.raises(ValueError):
        layout.pad_stack([{"x": np.zeros(1)}, {"z": np.zeros(1)}])


def test_sharded_block_packing_matches_unsharded_scores():
    """pack_blocks_sharded + per-shard local scoring == exact, for a
    stream codec AND the decode-free layout."""
    from repro.core.scoring import (
        combine_block_scores,
        components_from_gaps,
        block_products,
        decode_block_gaps,
        dequantise_values,
    )
    import jax.numpy as jnp

    rng = np.random.default_rng(11)
    dim = 2048
    docs = _random_docs(rng, 23, dim, 120, allow_empty=True)
    fwd = _fwd_from_docs(docs, dim)
    q = _query(rng, dim)
    want = fwd.exact_scores(q)
    for codec in ("streamvbyte", "uncompressed"):
        arrays, docs_local = layout.pack_blocks_sharded(fwd, 4, codec=codec, block_size=128)
        got = np.zeros(4 * docs_local, dtype=np.float32)
        for s in range(4):
            sub = {k: jnp.asarray(v[s]) for k, v in arrays.items()}
            if codec == "uncompressed":
                comps = sub["comps"]
            else:
                gaps = decode_block_gaps(codec, sub, 128)
                comps = components_from_gaps(
                    gaps, sub["seg"], sub["start_pos"], sub["start_abs"]
                )
            prod = block_products(
                jnp.asarray(q), comps, dequantise_values(sub["vals"], 1.0), sub["seg"]
            )
            local = combine_block_scores(prod, sub["seg"], sub["doc_ids"], docs_local)
            got[s * docs_local : (s + 1) * docs_local] = np.asarray(local)
        np.testing.assert_allclose(got[: fwd.n_docs], want, atol=2e-3, rtol=1e-3)
