"""Overlapped serving (DESIGN.md §11): host prefetch on the
out-of-core sharded path, background compaction with queries racing
the commit flip, thread-safety of the serving surfaces
(Pipeline/ResultCache/ServeStats), and the tombstone-aware mesh.

The correctness bar everywhere: overlap is a latency mechanism, never
an answer mechanism — every path must stay byte-identical to its
synchronous twin, and every counter must account honestly for work
that moved off the hot path."""

from __future__ import annotations

import os
import subprocess
import sys
import textwrap
import threading
import time

import numpy as np
import pytest

from repro.data.synthetic import SyntheticConfig, generate_collection
from repro.dist.sharding import tombstone_budget
from repro.serve.api import Retriever, RetrieverConfig, open_retriever
from repro.serve.pipeline import ResultCache, ServeStats
from repro.serve.segments import InjectedCrash, MergeHandle, MutableRetriever


def _coll(n_docs=60, n_queries=6, seed=3):
    return generate_collection(
        SyntheticConfig(name="overlap", dim=128, n_docs=n_docs,
                        n_queries=n_queries, doc_nnz_mean=16.0,
                        query_nnz_mean=6.0, seed=seed),
        value_format="f16",
    )


def _queries(col):
    return np.stack([col.query_dense(i) for i in range(col.n_queries)])


# ---------------------------------------------------------------------------
# host prefetch: parity, counters, staged-buffer hygiene
# ---------------------------------------------------------------------------


def test_prefetch_parity_and_counters(tmp_path):
    """Prefetch on/off answer byte-identically at max_resident=1; the
    prefetcher actually consumes staged shards (hits) while the
    disabled path records neither hits nor misses."""
    col = _coll()
    Q = _queries(col)
    cfg = RetrieverConfig(engine="flat", codec="streamvbyte", k=10,
                          n_shards=3)
    tree = tmp_path / "tree"
    Retriever.build(col.fwd, cfg).save(tree)

    off = open_retriever(tree)
    off.use_mesh = False
    off.max_resident = 1
    off.prefetch = False
    for _ in range(2):
        ids_off, sc_off = map(np.asarray, off.search(Q))
    assert off.prefetch_hits == 0 and off.prefetch_misses == 0

    on = open_retriever(tree)
    on.use_mesh = False
    on.max_resident = 1
    on.prefetch = True
    for _ in range(2):
        ids_on, sc_on = map(np.asarray, on.search(Q))
    np.testing.assert_array_equal(ids_on, ids_off)
    np.testing.assert_array_equal(sc_on, sc_off)
    # the very first rotation can never hit (nothing staged yet); by
    # the second pass the wrap-around stage has landed, so rotations
    # consume staged shards from there on
    assert on.prefetch_hits > 0
    assert on.prefetch_misses >= 1


def test_prefetch_peak_counts_staged_bytes(tmp_path):
    """Double-buffering is not free residency: the staged shard's bytes
    count into peak_resident_bytes, so prefetch-on peaks strictly above
    the prefetch-off single-shard peak."""
    col = _coll()
    Q = _queries(col)
    cfg = RetrieverConfig(engine="flat", codec="streamvbyte", k=10,
                          n_shards=3)
    tree = tmp_path / "tree"
    Retriever.build(col.fwd, cfg).save(tree)
    peaks = {}
    for prefetch in (False, True):
        r = open_retriever(tree)
        r.use_mesh = False
        r.max_resident = 1
        r.prefetch = prefetch
        for _ in range(2):
            r.search(Q)
        peaks[prefetch] = r.peak_resident_bytes
    assert peaks[True] > peaks[False]


def test_prefetch_staged_discard_on_budget_change(tmp_path):
    """A tombstone-set change retires any staged build whose candidate
    budget went stale — its compiles fold into the honest eviction
    accounting and the next rotation pages in at the new budget,
    answering byte-identically to a fresh retriever with the same
    tombstones."""
    col = _coll()
    Q = _queries(col)
    cfg = RetrieverConfig(engine="flat", codec="streamvbyte", k=10,
                          n_shards=3)
    tree = tmp_path / "tree"
    Retriever.build(col.fwd, cfg).save(tree)

    r = open_retriever(tree)
    r.use_mesh = False
    r.max_resident = 1
    r.prefetch = True
    r.search(Q)  # leaves the wrap-around shard staged
    victims = np.asarray([0, 25, 59], np.int64)
    r.set_tombstones(victims)
    assert r._staged is None  # the stale staged build was retired
    ids, sc = map(np.asarray, r.search(Q))

    fresh = open_retriever(tree)
    fresh.use_mesh = False
    fresh.max_resident = 1
    fresh.prefetch = False
    fresh.set_tombstones(victims)
    ids_f, sc_f = map(np.asarray, fresh.search(Q))
    np.testing.assert_array_equal(ids, ids_f)
    np.testing.assert_array_equal(sc, sc_f)
    assert not np.intersect1d(ids.ravel(), victims).size


def test_uniform_tombstone_budgets():
    """Budgets are UNIFORM across shards — min(n_docs_s, k + total) —
    because byte-parity between the mesh (one SPMD k_local) and the
    sequential rotation requires identical per-shard candidate sets."""
    col = _coll()
    cfg = RetrieverConfig(engine="flat", codec="streamvbyte", k=10,
                          n_shards=3)
    r = Retriever.build(col.fwd, cfg)
    assert r._shard_k == [min(sh.n_docs, 10) for sh in r.shards]
    victims = np.asarray([0, 1, 59], np.int64)  # shards 0 and 2 only
    r.set_tombstones(victims)
    assert r._shard_k == [
        min(sh.n_docs, 10 + len(victims)) for sh in r.shards
    ]
    # per-shard tombstone ROUTING counts stay local (shard 1 is clean);
    # only the candidate budget is uniform
    assert r._shard_tombs[1] == 0 and sum(r._shard_tombs) == len(victims)


def test_tombstone_budget_contract():
    assert tombstone_budget(10, 100, 0) == 10
    assert tombstone_budget(10, 100, 5) == 15
    assert tombstone_budget(10, 12, 5) == 12  # capped at the shard
    for bad in [(0, 10, 0), (10, 0, 0), (10, 10, -1)]:
        with pytest.raises(ValueError):
            tombstone_budget(*bad)


# ---------------------------------------------------------------------------
# background compaction: handle semantics, parity through the flip
# ---------------------------------------------------------------------------


def _mutable(col, n_base=45):
    cfg = RetrieverConfig(engine="flat", codec="streamvbyte", k=10)
    m = MutableRetriever.create(col.fwd.slice(0, n_base), cfg)
    m.insert([col.fwd.doc(i) for i in range(n_base, col.fwd.n_docs)])
    m.delete([1, 3, n_base + 1])
    return m


def test_background_merge_commits_and_prewarms():
    col = _coll()
    Q = _queries(col)
    m = _mutable(col)
    ids0, sc0 = map(np.asarray, m.search(Q))
    gen0, epoch0 = m.generation, m.epoch

    handle = m.merge(background=True)
    assert isinstance(handle, MergeHandle)
    new_base = handle.result(timeout=600)
    assert handle.done()
    assert m.generation == gen0 + 1 and m.epoch == epoch0 + 1
    assert not m.segments and new_base is m.base
    # the worker pre-warmed the next generation's plans: serving it
    # must reuse the wrapper the merge built, not compile a fresh one
    assert "base" in m._wrappers
    compiles = m.plans.compiles
    ids1, sc1 = map(np.asarray, m.search(Q))
    assert m.plans.compiles == compiles
    np.testing.assert_array_equal(ids1, ids0)
    np.testing.assert_array_equal(sc1, sc0)
    assert m.merge_wall_us > 0 and m.blocked_swap_us > 0


def test_background_merge_crash_surfaces_in_result():
    col = _coll()
    Q = _queries(col)
    m = _mutable(col)
    ids0 = np.asarray(m.search(Q)[0])
    gen0, n_segs = m.generation, len(m.segments)

    handle = m.merge(background=True, crash_before_flip=True)
    with pytest.raises(InjectedCrash):
        handle.result(timeout=600)
    # the crash never reached the commit: state intact, still servable
    assert m.generation == gen0 and len(m.segments) == n_segs
    np.testing.assert_array_equal(np.asarray(m.search(Q)[0]), ids0)
    # a retry merges cleanly
    m.merge()
    assert m.generation == gen0 + 1
    np.testing.assert_array_equal(np.asarray(m.search(Q)[0]), ids0)


def test_merge_handle_result_timeout():
    col = _coll()
    m = _mutable(col)
    handle = m.merge(background=True)
    try:
        handle.result(timeout=0.0)
    except TimeoutError:
        pass  # caught it mid-build — the interesting branch
    assert handle.result(timeout=600) is m.base


def test_background_merge_excludes_writers():
    """Single-writer discipline: a mutation issued while a background
    merge runs blocks on the write lock and lands AFTER the flip."""
    col = _coll(n_docs=200)
    m = _mutable(col, n_base=180)
    handle = m.merge(background=True)
    # wait for the (niced) worker to actually take the write lock, so
    # the insert below contends with a merge in flight rather than
    # sneaking in before it starts
    deadline = time.monotonic() + 60
    while time.monotonic() < deadline:
        if m._write_lock.acquire(blocking=False):
            m._write_lock.release()
            if handle.done():
                break
            time.sleep(0.002)
        else:
            break
    ids = m.insert([col.fwd.doc(0)])  # blocks until the merge commits
    assert handle.done(), "insert returned while the merge still ran"
    handle.result(timeout=600)
    assert m.generation == 1
    assert len(m.segments) == 1 and m.segments[0].ids[0] == ids[0]


# ---------------------------------------------------------------------------
# thread-safety of the serving surfaces
# ---------------------------------------------------------------------------


def test_result_cache_thread_hammer():
    cache = ResultCache(capacity=32)
    errors: list = []
    n_iters = 300

    def worker(seed: int) -> None:
        rng = np.random.default_rng(seed)
        try:
            for i in range(n_iters):
                key = bytes([int(rng.integers(64))])
                roll = rng.random()
                if roll < 0.1:
                    cache.invalidate(epoch=i)
                elif roll < 0.55:
                    cache.put(key, np.arange(4), np.ones(4))
                else:
                    got = cache.get(key)
                    if got is not None:
                        assert got[0].shape == (4,)
        except BaseException as e:  # pragma: no cover
            errors.append(e)

    threads = [threading.Thread(target=worker, args=(s,)) for s in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors, errors
    assert len(cache) <= 32
    assert cache.lookups >= cache.hits
    assert cache.invalidations >= 1


def test_serve_stats_thread_hammer():
    stats = ServeStats(clock=time.perf_counter)
    n_threads, n_iters = 4, 500

    def worker() -> None:
        for i in range(n_iters):
            stats.record_query(float(i % 97))
            stats.record_dispatch(8, 5)
            if i % 50 == 0:
                stats.percentile(95)
                stats.snapshot()

    threads = [threading.Thread(target=worker) for _ in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    snap = stats.snapshot()
    assert snap["n_queries"] == n_threads * n_iters
    assert stats.dispatches[8] == n_threads * n_iters
    assert stats.occupancy[8] == 5 * n_threads * n_iters


def test_pipeline_stress_during_background_merge():
    """Several threads hammer Pipeline.submit while another invalidates
    the cache and reads stats, and a background merge builds + commits
    mid-storm. Every response — in every phase — must equal the
    constant oracle (compaction does not change the live corpus), and
    the commit's epoch bump must reach the result cache."""
    col = _coll()
    Q = _queries(col)
    m = _mutable(col)
    pipe = m.pipeline(deadline_us=300.0, cache_size=32)
    pipe.warm()
    oracle_ids, oracle_sc = map(np.asarray, m.search(Q))

    stop = threading.Event()
    failures: list = []
    served = [0, 0]

    def submitter(tid: int) -> None:
        rng = np.random.default_rng(tid)
        try:
            while not stop.is_set():
                qi = int(rng.integers(Q.shape[0]))
                ids, sc = pipe.submit(Q[qi]).result()
                if not (np.array_equal(np.asarray(ids), oracle_ids[qi])
                        and np.array_equal(np.asarray(sc), oracle_sc[qi])):
                    failures.append(f"thread {tid} query {qi} diverged")
                    stop.set()
                    return
                served[tid] += 1
        except BaseException as e:  # pragma: no cover
            failures.append(repr(e))
            stop.set()

    def chaos() -> None:
        while not stop.is_set():
            pipe.cache.invalidate()
            pipe.snapshot()
            pipe.stats.percentile(95)
            time.sleep(0.001)

    threads = [threading.Thread(target=submitter, args=(i,))
               for i in range(2)]
    threads.append(threading.Thread(target=chaos))
    for t in threads:
        t.start()
    try:
        handle = m.merge(background=True)
        handle.result(timeout=600)
        # keep the storm going past the flip so post-commit serving is
        # exercised under the same load
        targets = [n + 3 for n in served]
        deadline = time.monotonic() + 120
        while (any(served[t] < targets[t] for t in range(2))
               and not stop.is_set() and time.monotonic() < deadline):
            time.sleep(0.001)
    finally:
        stop.set()
        for t in threads:
            t.join()

    assert not failures, failures
    assert all(n > 0 for n in served)
    assert m.generation == 1
    # one post-storm submission syncs the cache epoch to the retriever
    ids, _ = pipe.submit(Q[0]).result()
    np.testing.assert_array_equal(np.asarray(ids), oracle_ids[0])
    assert pipe.cache.epoch == m.epoch


# ---------------------------------------------------------------------------
# mesh path with live tombstones (8 forced host devices, subprocess)
# ---------------------------------------------------------------------------

_ENV = {
    **os.environ,
    "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
    "PYTHONPATH": os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src")]
        + os.environ.get("PYTHONPATH", "").split(os.pathsep)
    ),
}


def test_mesh_serves_live_tombstones():
    """With ≥ n_shards devices and live tombstones the dispatch STAYS
    on the shard_map path (use_mesh=True raises on fallback) and
    answers byte-identically to the sequential rotation — for a
    dedupe engine and a disjoint-range engine, and again after the
    tombstone set is replaced."""
    script = textwrap.dedent(
        """
        import numpy as np
        from repro.data.synthetic import SyntheticConfig, generate_collection
        from repro.serve.api import Retriever, RetrieverConfig

        coll = generate_collection(
            SyntheticConfig(name="mesh-tombs", dim=256, n_docs=48,
                            n_queries=4, doc_nnz_mean=24.0,
                            query_nnz_mean=8.0, seed=3),
            value_format="f16",
        )
        Q = np.stack([coll.query_dense(i) for i in range(4)])
        cases = [
            ("flat", {}),
            ("seismic", dict(cut=16, block_budget=512, n_probe=512,
                             n_postings=10000, block_size=8)),
        ]
        for engine, params in cases:
            cfg = RetrieverConfig(engine=engine, k=10, n_shards=4,
                                  params=params)
            r = Retriever.build(coll.fwd, cfg)
            for victims in ([0, 11, 12, 30, 47], [1, 13, 14, 31, 46]):
                victims = np.asarray(victims, np.int64)
                r.set_tombstones(victims)
                r.use_mesh = False
                ids_s, sc_s = map(np.asarray, r.search(Q))
                r.use_mesh = True  # raises instead of falling back
                ids_m, sc_m = map(np.asarray, r.search(Q))
                assert np.array_equal(ids_s, ids_m), engine
                assert np.array_equal(sc_s, sc_m), engine
                dead = np.intersect1d(ids_m.ravel(), victims)
                assert not dead.size, (engine, dead)
        print("mesh tombstone parity OK")
        """
    )
    proc = subprocess.run(
        [sys.executable, "-c", script], env=_ENV, capture_output=True,
        text=True, timeout=900,
    )
    assert proc.returncode == 0, (
        f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    )
    assert "mesh tombstone parity OK" in proc.stdout
