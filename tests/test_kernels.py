"""Per-kernel validation: shape/dtype sweeps, Pallas (interpret=True)
vs the pure-jnp oracle in repro.kernels.ref, end-to-end vs the CSR
numpy ground truth, and the fused rows-rescoring kernels vs the jnp
``score_candidate_rows`` chain (every registry codec, empty-row and
sentinel-doc-id edge cases included)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import layout
from repro.core.forward_index import ForwardIndex, pack_forward_index
from repro.core.scoring import score_candidate_rows, score_packed, score_packed_batch
from repro.kernels.bitpack_dot import bitpack_block_scores, bitpack_block_scores_w
from repro.kernels.dotvbyte_dot import dotvbyte_block_scores
from repro.kernels.ops import (
    pad_to,
    score_bitpack,
    score_bitpack_bucketed,
    score_dotvbyte,
    score_dotvbyte_batch,
    score_streamvbyte,
    score_streamvbyte_batch,
)
from repro.kernels.ref import (
    bitpack_block_scores_ref,
    dotvbyte_block_scores_ref,
    streamvbyte_block_scores_ref,
)
from repro.kernels.registry import available_kernels, get_kernels
from repro.kernels.streamvbyte_dot import streamvbyte_block_scores


def _collection(rng, n_docs, dim, max_nnz, value_format):
    docs = []
    for _ in range(n_docs):
        n = int(rng.integers(1, max_nnz))
        c = np.sort(rng.choice(dim, size=min(n, dim // 2), replace=False))
        v = rng.gamma(2.0, 0.5, size=len(c)).astype(np.float32) + 0.05
        docs.append((c, v))
    return ForwardIndex.from_docs(docs, dim, value_format=value_format)


def _query(rng, dim, nnz=40):
    q = np.zeros(dim, dtype=np.float32)
    qc = rng.choice(dim, nnz, replace=False)
    q[qc] = rng.gamma(2.0, 0.5, size=nnz)
    return q


SWEEP = [
    # (dim, block_size, n_docs, max_nnz, value_format)
    (2048, 128, 40, 60, "f32"),
    (8192, 256, 60, 200, "f16"),
    (30522, 512, 80, 300, "fixedu8"),
    (512, 128, 10, 500, "f16"),  # docs spanning many blocks
]


@pytest.mark.parametrize("dim,bs,n_docs,max_nnz,vf", SWEEP)
def test_dotvbyte_kernel_vs_ref(dim, bs, n_docs, max_nnz, vf):
    rng = np.random.default_rng(dim + bs)
    fwd = _collection(rng, n_docs, dim, max_nnz, vf)
    packed = pack_forward_index(fwd, codec="dotvbyte", block_size=bs)
    q = _query(rng, dim)
    qpad = np.zeros(((dim + 127) // 128) * 128, np.float32)
    qpad[:dim] = q
    args = (
        jnp.asarray(qpad),
        jnp.asarray(packed.ctrl),
        jnp.asarray(pad_to(packed.data, 128, axis=1)),
        jnp.asarray(packed.seg),
        jnp.asarray(packed.start_pos),
        jnp.asarray(packed.start_abs),
        jnp.asarray(packed.vals),
    )
    scale = float(packed.value_format.scale)
    kern = dotvbyte_block_scores(*args, scale=scale, interpret=True)
    ref = dotvbyte_block_scores_ref(*args, scale=scale)
    np.testing.assert_allclose(np.asarray(kern), np.asarray(ref), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dim,bs,n_docs,max_nnz,vf", SWEEP)
def test_bitpack_kernel_vs_ref(dim, bs, n_docs, max_nnz, vf):
    rng = np.random.default_rng(dim * 3 + bs)
    fwd = _collection(rng, n_docs, dim, max_nnz, vf)
    packed = pack_forward_index(fwd, codec="bitpack", block_size=bs)
    q = _query(rng, dim)
    qpad = np.zeros(((dim + 127) // 128) * 128, np.float32)
    qpad[:dim] = q
    words = pad_to(packed.words, 128, axis=1)
    scale = float(packed.value_format.scale)
    kern = bitpack_block_scores(
        jnp.asarray(qpad), jnp.asarray(words), jnp.asarray(packed.widths),
        jnp.asarray(packed.seg), jnp.asarray(packed.start_pos),
        jnp.asarray(packed.start_abs), jnp.asarray(packed.vals),
        scale=scale, interpret=True,
    )
    ref = bitpack_block_scores_ref(
        jnp.asarray(qpad), jnp.asarray(words), jnp.asarray(packed.widths),
        jnp.asarray(packed.seg), jnp.asarray(packed.start_pos),
        jnp.asarray(packed.start_abs), jnp.asarray(packed.vals), scale=scale,
    )
    np.testing.assert_allclose(np.asarray(kern), np.asarray(ref), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dim,bs,n_docs,max_nnz,vf", SWEEP)
def test_streamvbyte_kernel_vs_ref(dim, bs, n_docs, max_nnz, vf):
    rng = np.random.default_rng(dim * 7 + bs)
    fwd = _collection(rng, n_docs, dim, max_nnz, vf)
    packed = pack_forward_index(fwd, codec="streamvbyte", block_size=bs)
    q = _query(rng, dim)
    qpad = np.zeros(((dim + 127) // 128) * 128, np.float32)
    qpad[:dim] = q
    args = (
        jnp.asarray(qpad),
        jnp.asarray(packed.ctrl),
        jnp.asarray(pad_to(packed.data, 128, axis=1)),
        jnp.asarray(packed.seg),
        jnp.asarray(packed.start_pos),
        jnp.asarray(packed.start_abs),
        jnp.asarray(packed.vals),
    )
    scale = float(packed.value_format.scale)
    kern = streamvbyte_block_scores(*args, scale=scale, interpret=True)
    ref = streamvbyte_block_scores_ref(*args, scale=scale)
    np.testing.assert_allclose(np.asarray(kern), np.asarray(ref), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("vf", ["f32", "f16", "fixedu8"])
def test_kernel_paths_end_to_end(vf):
    """Kernel wrappers vs numpy CSR ground truth, all value formats."""
    rng = np.random.default_rng(99)
    dim = 30522
    fwd = _collection(rng, 120, dim, 250, vf)
    q = _query(rng, dim)
    want = fwd.exact_scores(q)
    pd = pack_forward_index(fwd, codec="dotvbyte")
    ps = pack_forward_index(fwd, codec="streamvbyte")
    pb = pack_forward_index(fwd, codec="bitpack")
    for name, got in [
        ("dotvbyte", score_dotvbyte(q, pd, interpret=True)),
        ("streamvbyte", score_streamvbyte(q, ps, interpret=True)),
        ("bitpack", score_bitpack(q, pb, interpret=True)),
        ("bitpack_bucketed", score_bitpack_bucketed(q, pb, interpret=True)),
    ]:
        np.testing.assert_allclose(
            np.asarray(got), want, atol=5e-3, rtol=2e-3, err_msg=name
        )


def test_batched_scan_kernels_match_single():
    """Decode-once/score-many variants == per-query single kernel, and
    the vmapped ``score_packed_batch`` == stacked ``score_packed``."""
    rng = np.random.default_rng(17)
    dim = 4096
    fwd = _collection(rng, 60, dim, 120, "f16")
    Q = np.stack([_query(rng, dim) for _ in range(3)])
    pd = pack_forward_index(fwd, codec="dotvbyte", block_size=128)
    ps = pack_forward_index(fwd, codec="streamvbyte", block_size=128)
    for packed, single, batch in [
        (pd, score_dotvbyte, score_dotvbyte_batch),
        (ps, score_streamvbyte, score_streamvbyte_batch),
    ]:
        got = np.asarray(batch(Q, packed, interpret=True))
        want = np.stack([np.asarray(single(q, packed, interpret=True)) for q in Q])
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
    got = np.asarray(score_packed_batch(Q, ps))
    want = np.stack([np.asarray(score_packed(q, ps)) for q in Q])
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# fused candidate-row rescoring kernels (registry + rows_dot)
# ---------------------------------------------------------------------------


def _rows_fixture(rng, dim=2048, n_docs=50):
    """Collection with an empty document; candidate set with the
    sentinel id, duplicates, and the empty doc — the edge cases the
    serve engines rely on being neutral."""
    docs = []
    for _ in range(n_docs):
        n = int(rng.integers(1, 90))
        c = np.sort(rng.choice(dim, size=min(n, dim // 2), replace=False))
        v = rng.gamma(2.0, 0.5, size=len(c)).astype(np.float32) + 0.05
        docs.append((c, v))
    empty_id = len(docs)
    docs.append((np.zeros(0, np.uint32), np.zeros(0, np.float32)))
    fwd = ForwardIndex.from_docs(docs, dim, value_format="f16")
    n = fwd.n_docs
    cand = np.concatenate(
        [rng.choice(n, min(24, n), replace=False), [n, empty_id, 3, 3, n]]
    ).astype(np.int32)
    return fwd, cand


@pytest.mark.parametrize("codec", available_kernels())
def test_rows_kernel_matches_jnp_chain(codec):
    rng = np.random.default_rng(sum(codec.encode()))
    fwd, cand = _rows_fixture(rng)
    arrays = {k: jnp.asarray(v) for k, v in layout.pack_rows(fwd, codec=codec).arrays().items()}
    q = _query(rng, fwd.dim)
    scale = float(fwd.value_format.scale)
    want = score_candidate_rows(
        codec, arrays, jnp.asarray(cand), jnp.asarray(q), scale, backend="jnp"
    )
    got = get_kernels(codec).rows_scores(
        arrays, jnp.asarray(cand), jnp.asarray(q), scale, True
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)
    # sentinel and empty rows score exactly 0 on both paths
    sent = np.asarray(got)[np.asarray(cand) >= fwd.n_docs]
    np.testing.assert_array_equal(sent, np.zeros_like(sent))


@pytest.mark.parametrize("codec", ["streamvbyte", "bitpack"])
def test_rows_kernel_batch_matches_vmapped_single(codec):
    """The explicit query-batched rows kernel == vmap of the single-
    query entry (the form the jit'd Retriever search path uses)."""
    rng = np.random.default_rng(23)
    fwd, cand = _rows_fixture(rng)
    arrays = {k: jnp.asarray(v) for k, v in layout.pack_rows(fwd, codec=codec).arrays().items()}
    Q = jnp.asarray(np.stack([_query(rng, fwd.dim) for _ in range(4)]))
    scale = float(fwd.value_format.scale)
    ks = get_kernels(codec)
    got = ks.rows_scores_batch(arrays, jnp.asarray(cand), Q, scale, True)
    want = jax.vmap(
        lambda q: ks.rows_scores(arrays, jnp.asarray(cand), q, scale, True)
    )(Q)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6, atol=1e-6)


def test_kernel_registry_surface():
    """Registry mirrors the layout registry: every layout codec is
    fused, unknown names raise listing the known ones, and an
    unregistered codec falls back to jnp with ONE warning."""
    assert set(available_kernels()) == set(layout.available_layouts())
    with pytest.raises(ValueError, match=r"bitpack.*streamvbyte"):
        get_kernels("zstd")
    # fallback: pallas backend on a codec with no rows kernel
    from repro.core import scoring
    from repro.kernels import registry

    rng = np.random.default_rng(3)
    fwd, cand = _rows_fixture(rng, n_docs=10)
    arrays = {k: jnp.asarray(v) for k, v in layout.pack_rows(fwd, codec="dotvbyte").arrays().items()}
    q = jnp.asarray(_query(rng, fwd.dim))
    scale = float(fwd.value_format.scale)
    saved_kernels = registry._KERNELS.pop("dotvbyte")
    saved_warned = set(scoring._NO_ROWS_KERNEL_WARNED)
    scoring._NO_ROWS_KERNEL_WARNED.clear()
    try:
        with pytest.warns(RuntimeWarning, match="no fused rows kernel"):
            got = score_candidate_rows(
                "dotvbyte", arrays, jnp.asarray(cand), q, scale, backend="pallas"
            )
        import warnings as _w

        with _w.catch_warnings():  # second call: warning already issued
            _w.simplefilter("error", RuntimeWarning)
            score_candidate_rows(
                "dotvbyte", arrays, jnp.asarray(cand), q, scale, backend="pallas"
            )
    finally:
        registry._KERNELS["dotvbyte"] = saved_kernels
        scoring._NO_ROWS_KERNEL_WARNED.clear()
        scoring._NO_ROWS_KERNEL_WARNED.update(saved_warned)
    want = score_candidate_rows(
        "dotvbyte", arrays, jnp.asarray(cand), q, scale, backend="jnp"
    )
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    with pytest.raises(ValueError, match="unknown scoring backend"):
        score_candidate_rows("dotvbyte", arrays, jnp.asarray(cand), q, scale,
                             backend="mosaic")


def test_bucketed_width_kernel_tight_words():
    """Static-width kernel must accept tight (per-width) word arrays."""
    rng = np.random.default_rng(5)
    dim, T = 4096, 128
    fwd = _collection(rng, 60, dim, 100, "f16")
    packed = pack_forward_index(fwd, codec="bitpack", block_size=T)
    q = _query(rng, dim)
    got = score_bitpack_bucketed(q, packed, interpret=True)
    np.testing.assert_allclose(
        np.asarray(got), fwd.exact_scores(q), atol=2e-3, rtol=1e-3
    )
    assert len(set(int(w) for w in packed.widths)) >= 2  # multiple buckets hit


def test_kernel_single_block_degenerate():
    dim = 256
    docs = [(np.array([0, 255], dtype=np.uint32), np.array([1.0, 2.0], np.float32))]
    fwd = ForwardIndex.from_docs(docs, dim)
    packed = pack_forward_index(fwd, codec="dotvbyte", block_size=128)
    q = np.zeros(dim, np.float32)
    q[0], q[255] = 3.0, 4.0
    got = np.asarray(score_dotvbyte(q, packed, interpret=True))
    np.testing.assert_allclose(got, [3.0 + 8.0], rtol=1e-6)


# ---------------------------------------------------------------------------
# execution-mode axis (repro.kernels.modes) + tiled edge shapes
# ---------------------------------------------------------------------------

from repro.kernels import modes as kernel_modes  # noqa: E402
from repro.kernels.tiles import Q_TILE, R_TILE  # noqa: E402

_SCAN_WRAPPER = {
    "dotvbyte": score_dotvbyte,
    "streamvbyte": score_streamvbyte,
    "bitpack": score_bitpack_bucketed,
}


def test_mode_resolution():
    """Mode normalisation: None → compiled, legacy booleans map onto
    the two pallas modes, bad spellings raise with the valid list."""
    assert kernel_modes.resolve_mode(None) == "pallas_compiled"
    assert kernel_modes.resolve_mode(True) == "pallas_interpret"
    assert kernel_modes.resolve_mode(False) == "pallas_compiled"
    for m in kernel_modes.MODES:
        assert kernel_modes.resolve_mode(m) == m
    with pytest.raises(ValueError, match="unknown kernel mode"):
        kernel_modes.resolve_mode("fast")
    assert kernel_modes.backend_mode("jnp") == "jnp"
    assert kernel_modes.backend_mode("pallas") is None  # auto
    assert kernel_modes.backend_mode("pallas_interpret") == "pallas_interpret"
    assert kernel_modes.backend_mode("pallas_compiled") == "pallas_compiled"
    with pytest.raises(ValueError, match="unknown scoring backend"):
        kernel_modes.backend_mode("cuda")
    assert kernel_modes.resolve_lowering("jnp") == "jnp"
    assert kernel_modes.resolve_lowering("pallas_interpret") == "interpret"
    assert kernel_modes.resolve_lowering("pallas_compiled") in ("mosaic", "xla")


def test_xla_fallback_warns_once():
    """Without Mosaic, pallas_compiled lowers through XLA with exactly
    one RuntimeWarning for the whole process."""
    if kernel_modes.mosaic_available():
        pytest.skip("Mosaic backend attached: no fallback on this host")
    saved = set(kernel_modes._XLA_FALLBACK_WARNED)
    kernel_modes._XLA_FALLBACK_WARNED.clear()
    try:
        with pytest.warns(RuntimeWarning, match="through XLA"):
            assert kernel_modes.resolve_lowering("pallas_compiled") == "xla"
        import warnings as _w

        with _w.catch_warnings():  # second resolve: already warned
            _w.simplefilter("error", RuntimeWarning)
            assert kernel_modes.resolve_lowering("pallas_compiled") == "xla"
    finally:
        kernel_modes._XLA_FALLBACK_WARNED.clear()
        kernel_modes._XLA_FALLBACK_WARNED.update(saved)


@pytest.mark.parametrize("codec", ["dotvbyte", "streamvbyte", "bitpack"])
def test_scan_modes_parity_edge_shapes(codec):
    """Block counts that are NOT a multiple of the tile height (the
    DMA scan pads with neutral tiles) and a single-doc corpus: all
    three execution modes reproduce the jnp scores."""
    rng = np.random.default_rng(41)
    scorer = _SCAN_WRAPPER[codec]
    for n_docs in (11, 1):
        fwd = _collection(rng, n_docs, 512, 60, "f16")
        packed = pack_forward_index(fwd, codec=codec, block_size=128)
        assert packed.seg.shape[0] % R_TILE != 0  # the shape under test
        q = _query(rng, 512, nnz=20)
        want = np.asarray(scorer(q, packed, mode="jnp"))
        for mode in ("pallas_interpret", "pallas_compiled"):
            got = np.asarray(scorer(q, packed, mode=mode))
            np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5,
                                       err_msg=f"{codec} [{mode}]")


@pytest.mark.parametrize("codec", available_kernels())
def test_rows_kernel_modes_parity(codec):
    """Candidate sets with duplicate ids, the sentinel, an empty row,
    and a length far from the rescoring tile width: interpret and
    compiled both reproduce the jnp chain."""
    rng = np.random.default_rng(7 + sum(codec.encode()))
    fwd, cand = _rows_fixture(rng, dim=1024, n_docs=21)
    arrays = {k: jnp.asarray(v) for k, v in layout.pack_rows(fwd, codec=codec).arrays().items()}
    q = _query(rng, fwd.dim)
    scale = float(fwd.value_format.scale)
    ks = get_kernels(codec)
    want = np.asarray(score_candidate_rows(
        codec, arrays, jnp.asarray(cand), jnp.asarray(q), scale, backend="jnp"
    ))
    for mode in ("pallas_interpret", "pallas_compiled"):
        got = np.asarray(ks.rows_scores(
            arrays, jnp.asarray(cand), jnp.asarray(q), scale, mode
        ))
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6,
                                   err_msg=f"{codec} [{mode}]")


def test_batched_kernels_compiled_mode_parity():
    """Compiled batched grids at nq not a multiple of the query tile:
    scan == vmapped score_packed, rows == the jnp chain per query."""
    rng = np.random.default_rng(67)
    fwd = _collection(rng, 30, 1024, 80, "f16")
    nq = Q_TILE - 3  # forces query-axis padding in the batched grid
    Q = np.stack([_query(rng, 1024, nnz=24) for _ in range(nq)])
    for codec, batch_fn in [("dotvbyte", score_dotvbyte_batch),
                            ("streamvbyte", score_streamvbyte_batch)]:
        packed = pack_forward_index(fwd, codec=codec, block_size=128)
        got = np.asarray(batch_fn(Q, packed, mode="pallas_compiled"))
        want = np.asarray(score_packed_batch(Q, packed))
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5, err_msg=codec)

    from repro.core.scoring import score_candidate_rows_batch

    cand = np.array([5, 5, 0, 30, 29, 7, 1], np.int32)  # dups + sentinel
    for codec in ("streamvbyte", "bitpack"):
        arrays = {k: jnp.asarray(v)
                  for k, v in layout.pack_rows(fwd, codec=codec).arrays().items()}
        scale = float(fwd.value_format.scale)
        got = np.asarray(get_kernels(codec).rows_scores_batch(
            arrays, jnp.asarray(cand), jnp.asarray(Q), scale, "pallas_compiled"
        ))
        want = np.asarray(score_candidate_rows_batch(
            codec, arrays, jnp.asarray(cand), jnp.asarray(Q), scale, backend="jnp"
        ))
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6, err_msg=codec)


def test_rows_single_doc_corpus_modes():
    """One-document corpus (row table is just the doc + sentinel):
    every mode scores the duplicate/sentinel candidate list alike."""
    docs = [(np.array([1, 200], np.uint32), np.array([1.5, 2.0], np.float32))]
    fwd = ForwardIndex.from_docs(docs, 256, value_format="f32")
    cand = np.array([0, 0, 1], np.int32)  # dup + sentinel row
    q = np.zeros(256, np.float32)
    q[1], q[200] = 2.0, 1.0
    for codec in available_kernels():
        arrays = {k: jnp.asarray(v)
                  for k, v in layout.pack_rows(fwd, codec=codec).arrays().items()}
        scale = float(fwd.value_format.scale)
        for mode in ("jnp", "pallas_interpret", "pallas_compiled"):
            got = np.asarray(score_candidate_rows(
                codec, arrays, jnp.asarray(cand), jnp.asarray(q), scale,
                backend=mode if mode != "jnp" else "jnp",
            ))
            np.testing.assert_allclose(got, [5.0, 5.0, 0.0], rtol=1e-5,
                                       err_msg=f"{codec} [{mode}]")
