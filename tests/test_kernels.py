"""Per-kernel validation: shape/dtype sweeps, Pallas (interpret=True)
vs the pure-jnp oracle in repro.kernels.ref, and end-to-end vs the CSR
numpy ground truth."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.forward_index import ForwardIndex, pack_forward_index
from repro.kernels.bitpack_dot import bitpack_block_scores, bitpack_block_scores_w
from repro.kernels.dotvbyte_dot import dotvbyte_block_scores
from repro.kernels.ops import pad_to, score_bitpack, score_bitpack_bucketed, score_dotvbyte
from repro.kernels.ref import bitpack_block_scores_ref, dotvbyte_block_scores_ref


def _collection(rng, n_docs, dim, max_nnz, value_format):
    docs = []
    for _ in range(n_docs):
        n = int(rng.integers(1, max_nnz))
        c = np.sort(rng.choice(dim, size=min(n, dim // 2), replace=False))
        v = rng.gamma(2.0, 0.5, size=len(c)).astype(np.float32) + 0.05
        docs.append((c, v))
    return ForwardIndex.from_docs(docs, dim, value_format=value_format)


def _query(rng, dim, nnz=40):
    q = np.zeros(dim, dtype=np.float32)
    qc = rng.choice(dim, nnz, replace=False)
    q[qc] = rng.gamma(2.0, 0.5, size=nnz)
    return q


SWEEP = [
    # (dim, block_size, n_docs, max_nnz, value_format)
    (2048, 128, 40, 60, "f32"),
    (8192, 256, 60, 200, "f16"),
    (30522, 512, 80, 300, "fixedu8"),
    (512, 128, 10, 500, "f16"),  # docs spanning many blocks
]


@pytest.mark.parametrize("dim,bs,n_docs,max_nnz,vf", SWEEP)
def test_dotvbyte_kernel_vs_ref(dim, bs, n_docs, max_nnz, vf):
    rng = np.random.default_rng(dim + bs)
    fwd = _collection(rng, n_docs, dim, max_nnz, vf)
    packed = pack_forward_index(fwd, codec="dotvbyte", block_size=bs)
    q = _query(rng, dim)
    qpad = np.zeros(((dim + 127) // 128) * 128, np.float32)
    qpad[:dim] = q
    args = (
        jnp.asarray(qpad),
        jnp.asarray(packed.ctrl),
        jnp.asarray(pad_to(packed.data, 128, axis=1)),
        jnp.asarray(packed.seg),
        jnp.asarray(packed.start_pos),
        jnp.asarray(packed.start_abs),
        jnp.asarray(packed.vals),
    )
    scale = float(packed.value_format.scale)
    kern = dotvbyte_block_scores(*args, scale=scale, interpret=True)
    ref = dotvbyte_block_scores_ref(*args, scale=scale)
    np.testing.assert_allclose(np.asarray(kern), np.asarray(ref), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dim,bs,n_docs,max_nnz,vf", SWEEP)
def test_bitpack_kernel_vs_ref(dim, bs, n_docs, max_nnz, vf):
    rng = np.random.default_rng(dim * 3 + bs)
    fwd = _collection(rng, n_docs, dim, max_nnz, vf)
    packed = pack_forward_index(fwd, codec="bitpack", block_size=bs)
    q = _query(rng, dim)
    qpad = np.zeros(((dim + 127) // 128) * 128, np.float32)
    qpad[:dim] = q
    words = pad_to(packed.words, 128, axis=1)
    scale = float(packed.value_format.scale)
    kern = bitpack_block_scores(
        jnp.asarray(qpad), jnp.asarray(words), jnp.asarray(packed.widths),
        jnp.asarray(packed.seg), jnp.asarray(packed.start_pos),
        jnp.asarray(packed.start_abs), jnp.asarray(packed.vals),
        scale=scale, interpret=True,
    )
    ref = bitpack_block_scores_ref(
        jnp.asarray(qpad), jnp.asarray(words), jnp.asarray(packed.widths),
        jnp.asarray(packed.seg), jnp.asarray(packed.start_pos),
        jnp.asarray(packed.start_abs), jnp.asarray(packed.vals), scale=scale,
    )
    np.testing.assert_allclose(np.asarray(kern), np.asarray(ref), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("vf", ["f32", "f16", "fixedu8"])
def test_kernel_paths_end_to_end(vf):
    """Kernel wrappers vs numpy CSR ground truth, all value formats."""
    rng = np.random.default_rng(99)
    dim = 30522
    fwd = _collection(rng, 120, dim, 250, vf)
    q = _query(rng, dim)
    want = fwd.exact_scores(q)
    pd = pack_forward_index(fwd, codec="dotvbyte")
    pb = pack_forward_index(fwd, codec="bitpack")
    for name, got in [
        ("dotvbyte", score_dotvbyte(q, pd, interpret=True)),
        ("bitpack", score_bitpack(q, pb, interpret=True)),
        ("bitpack_bucketed", score_bitpack_bucketed(q, pb, interpret=True)),
    ]:
        np.testing.assert_allclose(
            np.asarray(got), want, atol=5e-3, rtol=2e-3, err_msg=name
        )


def test_bucketed_width_kernel_tight_words():
    """Static-width kernel must accept tight (per-width) word arrays."""
    rng = np.random.default_rng(5)
    dim, T = 4096, 128
    fwd = _collection(rng, 60, dim, 100, "f16")
    packed = pack_forward_index(fwd, codec="bitpack", block_size=T)
    q = _query(rng, dim)
    got = score_bitpack_bucketed(q, packed, interpret=True)
    np.testing.assert_allclose(
        np.asarray(got), fwd.exact_scores(q), atol=2e-3, rtol=1e-3
    )
    assert len(set(int(w) for w in packed.widths)) >= 2  # multiple buckets hit


def test_kernel_single_block_degenerate():
    dim = 256
    docs = [(np.array([0, 255], dtype=np.uint32), np.array([1.0, 2.0], np.float32))]
    fwd = ForwardIndex.from_docs(docs, dim)
    packed = pack_forward_index(fwd, codec="dotvbyte", block_size=128)
    q = np.zeros(dim, np.float32)
    q[0], q[255] = 3.0, 4.0
    got = np.asarray(score_dotvbyte(q, packed, interpret=True))
    np.testing.assert_allclose(got, [3.0 + 8.0], rtol=1e-6)
