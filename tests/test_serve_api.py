"""Unified Retriever API tests (DESIGN.md §7): engine registry,
artifact lifecycle, codec parity through the one serving surface.

Covers the ISSUE-3 acceptance criteria: save→open round-trip yields
identical top-k for every engine×codec pair (bitpack and the flat
engine included), unknown engine/codec names raise listing the known
ones, and a manifest version mismatch fails loudly rather than
mis-decoding."""

import json

import numpy as np
import pytest

from repro.core.layout import available_layouts
from repro.core.seismic import exact_top_k, recall_at_k
from repro.data.synthetic import SyntheticConfig, generate_collection
from repro.serve.api import (
    MANIFEST_VERSION,
    ArtifactError,
    Retriever,
    RetrieverConfig,
    available_engines,
    get_engine,
    open_retriever,
)

#: per-engine knobs sized for the tiny test collection
ENGINE_PARAMS = {
    "seismic": dict(cut=8, block_budget=256, n_probe=48, n_postings=300,
                    block_size=16),
    "hnsw": dict(beam=48, iters=48, n_seeds=4, m=8, ef_construction=32),
    "flat": {},
}


@pytest.fixture(scope="module")
def collection():
    cfg = SyntheticConfig(
        name="test", dim=1024, n_docs=300, n_queries=6,
        doc_nnz_mean=40.0, query_nnz_mean=12.0, seed=0,
    )
    return generate_collection(cfg, value_format="f16")


@pytest.fixture(scope="module")
def queries(collection):
    return np.stack([collection.query_dense(i) for i in range(collection.n_queries)])


@pytest.fixture(scope="module")
def host_indexes(collection):
    """One host build per engine; codecs sweep over it."""
    out = {}
    for name in available_engines():
        impl = get_engine(name)
        if hasattr(impl, "host_index"):
            cfg = RetrieverConfig(engine=name, params=ENGINE_PARAMS[name])
            out[name] = impl.host_index(collection.fwd, cfg)
    return out


def _retriever(collection, host_indexes, engine, codec, k=10, backend="jnp"):
    cfg = RetrieverConfig(engine=engine, codec=codec, k=k, backend=backend,
                          params=ENGINE_PARAMS[engine])
    if engine in host_indexes:
        return Retriever.from_host_index(host_indexes[engine], cfg)
    return Retriever.build(collection.fwd, cfg)


def test_registry_is_complete():
    assert {"seismic", "hnsw", "flat"} <= set(available_engines())


@pytest.mark.parametrize("engine", ["seismic", "hnsw", "flat"])
@pytest.mark.parametrize("codec", available_layouts())
def test_save_open_round_trip(collection, queries, host_indexes, tmp_path,
                              engine, codec):
    """The acceptance criterion: a saved artifact reopened in a fresh
    Retriever returns byte-identical top-k to the in-memory build, for
    every registered engine×codec pair."""
    r = _retriever(collection, host_indexes, engine, codec)
    ids, scores = r.search(queries)
    art = r.save(tmp_path / f"{engine}-{codec}")
    r2 = open_retriever(art)
    assert r2.cfg == r.cfg
    assert (r2.n_docs, r2.dim, r2.value_format) == (r.n_docs, r.dim, r.value_format)
    ids2, scores2 = r2.search(queries)
    assert np.array_equal(np.asarray(ids), np.asarray(ids2))
    assert np.array_equal(np.asarray(scores), np.asarray(scores2))


@pytest.mark.parametrize("engine", ["seismic", "hnsw", "flat"])
def test_bitpack_topk_parity(collection, queries, host_indexes, engine):
    """bitpack is served (not just registered): identical top-k to the
    uncompressed rows on every engine."""
    base = _retriever(collection, host_indexes, engine, "uncompressed")
    packed = _retriever(collection, host_indexes, engine, "bitpack")
    ids_u, sc_u = base.search(queries)
    ids_b, sc_b = packed.search(queries)
    assert np.array_equal(np.asarray(ids_u), np.asarray(ids_b))
    np.testing.assert_allclose(np.asarray(sc_u), np.asarray(sc_b), rtol=1e-5)


@pytest.mark.parametrize("engine", ["seismic", "hnsw", "flat"])
@pytest.mark.parametrize("codec", available_layouts())
def test_pallas_backend_topk_parity(collection, queries, host_indexes,
                                    engine, codec):
    """The ISSUE-4 acceptance criterion: ``backend="pallas"`` (fused
    scalar-prefetch rows kernels, interpret mode here) returns
    byte-identical top-k ids — and matching scores — to the jnp
    reference backend, for every registered engine×codec pair."""
    rj = _retriever(collection, host_indexes, engine, codec)
    rp = _retriever(collection, host_indexes, engine, codec, backend="pallas")
    ids_j, sc_j = rj.search(queries)
    ids_p, sc_p = rp.search(queries)
    assert np.array_equal(np.asarray(ids_j), np.asarray(ids_p))
    np.testing.assert_allclose(np.asarray(sc_j), np.asarray(sc_p),
                               rtol=1e-5, atol=1e-6)


def test_pallas_backend_empty_and_sentinel_rows(queries):
    """Edge cases through the full pallas-backend serve path: a
    collection containing empty documents (nnz=0 rows) still returns
    the exact oracle answer, and sentinel gathers stay neutral."""
    from repro.core.forward_index import ForwardIndex

    rng = np.random.default_rng(11)
    docs = []
    for i in range(40):
        if i % 7 == 0:  # sprinkle empty docs through the id space
            docs.append((np.zeros(0, np.uint32), np.zeros(0, np.float32)))
            continue
        n = int(rng.integers(1, 30))
        c = np.sort(rng.choice(1024, size=n, replace=False)).astype(np.uint32)
        docs.append((c, rng.gamma(2.0, 0.5, size=n).astype(np.float32) + 0.05))
    fwd = ForwardIndex.from_docs(docs, 1024, value_format="f16")
    for codec in available_layouts():
        rj = Retriever.build(fwd, RetrieverConfig(engine="flat", codec=codec, k=5))
        rp = Retriever.build(fwd, RetrieverConfig(engine="flat", codec=codec,
                                                  k=5, backend="pallas"))
        ids_j, sc_j = rj.search(queries[:, :1024])
        ids_p, sc_p = rp.search(queries[:, :1024])
        assert np.array_equal(np.asarray(ids_j), np.asarray(ids_p)), codec
        np.testing.assert_allclose(np.asarray(sc_j), np.asarray(sc_p),
                                   rtol=1e-5, atol=1e-6)


def test_unknown_backend_rejected(collection):
    with pytest.raises(ValueError, match=r"unknown backend.*jnp.*pallas"):
        Retriever.build(collection.fwd,
                        RetrieverConfig(engine="flat", backend="mosaic"))


def test_artifact_round_trip_preserves_backend(collection, queries,
                                               host_indexes, tmp_path):
    """A pallas-backend artifact reopens as pallas and still matches
    the jnp backend's top-k (the backend is a serving choice, not an
    index format — the payload is identical)."""
    rp = _retriever(collection, host_indexes, "seismic", "streamvbyte",
                    backend="pallas")
    art = rp.save(tmp_path / "pallas-art")
    r2 = open_retriever(art)
    assert r2.cfg.backend == "pallas"
    ids_p, _ = r2.search(queries)
    rj = _retriever(collection, host_indexes, "seismic", "streamvbyte")
    ids_j, _ = rj.search(queries)
    assert np.array_equal(np.asarray(ids_j), np.asarray(ids_p))


def test_flat_is_exact_oracle(collection, queries, host_indexes):
    """The flat engine's top-k is the exact answer — the on-device
    recall oracle matches the numpy ground truth."""
    r = _retriever(collection, host_indexes, "flat", "streamvbyte")
    ids, scores = r.search(queries)
    for i in range(collection.n_queries):
        true_ids, true_scores = exact_top_k(collection.fwd, queries[i], 10)
        assert recall_at_k(true_ids, np.asarray(ids[i])) == 1.0
        np.testing.assert_allclose(
            np.sort(np.asarray(scores[i])), np.sort(true_scores), rtol=1e-3, atol=1e-3
        )


def test_search_k_slicing(collection, queries, host_indexes):
    r = _retriever(collection, host_indexes, "flat", "uncompressed")
    ids, scores = r.search(queries, k=3)
    assert ids.shape == scores.shape == (collection.n_queries, 3)
    with pytest.raises(ValueError, match="static cfg.k"):
        r.search(queries, k=99)


def test_unknown_engine_lists_known(collection):
    with pytest.raises(ValueError, match=r"flat.*hnsw.*seismic"):
        Retriever.build(collection.fwd, RetrieverConfig(engine="faiss"))


def test_unknown_codec_lists_known(collection):
    with pytest.raises(ValueError, match=r"bitpack.*streamvbyte"):
        Retriever.build(collection.fwd,
                        RetrieverConfig(engine="flat", codec="zstd"))


def test_unknown_engine_param_rejected(collection):
    with pytest.raises(ValueError, match="unknown 'seismic' engine params"):
        Retriever.build(collection.fwd,
                        RetrieverConfig(engine="seismic", params={"cutt": 8}))


def test_manifest_version_mismatch_fails_loudly(collection, host_indexes,
                                                tmp_path):
    r = _retriever(collection, host_indexes, "flat", "uncompressed")
    art = r.save(tmp_path / "vmm")
    mf = art / "manifest.json"
    manifest = json.loads(mf.read_text())
    manifest["version"] = MANIFEST_VERSION + 1
    mf.write_text(json.dumps(manifest))
    with pytest.raises(ArtifactError, match="incompatible"):
        open_retriever(art)


def test_tampered_array_shape_fails_loudly(collection, host_indexes, tmp_path):
    """dtype/shape drift between manifest and payload must not silently
    mis-decode."""
    r = _retriever(collection, host_indexes, "flat", "streamvbyte")
    art = r.save(tmp_path / "tamper")
    mf = art / "manifest.json"
    manifest = json.loads(mf.read_text())
    manifest["arrays"]["nnz_rows"]["shape"] = [1]
    mf.write_text(json.dumps(manifest))
    with pytest.raises(ArtifactError, match="nnz_rows"):
        open_retriever(art)


def test_missing_artifact_raises(tmp_path):
    with pytest.raises(ArtifactError, match="manifest.json"):
        open_retriever(tmp_path / "nowhere")


def test_sharded_driver_matches_local_flat(collection, queries):
    """Generic sharded build path (single-device degenerate mesh): the
    flat engine through api.build_shard_arrays keeps disjoint ranges
    mapping back to global ids."""
    from repro.serve.api import build_shard_arrays

    cfg = RetrieverConfig(engine="flat", codec="dotvbyte", k=10)
    arrays, idmap, n_local = build_shard_arrays(collection.fwd, cfg, n_shards=4)
    assert idmap.shape == (4, n_local + 1)
    gids = np.asarray(idmap)[:, :-1].reshape(-1)
    gids = gids[gids < collection.fwd.n_docs]
    assert np.array_equal(np.sort(gids), np.arange(collection.fwd.n_docs))
    assert arrays["vals_rows"].shape[0] == 4
