"""Live index mutation tests (DESIGN.md §10) — the ISSUE-8 acceptance
suite:

* **mutation parity** — for every engine × codec, a
  ``MutableRetriever`` at {0, 1, 3} live delta segments (with
  tombstones in base AND segments, plus an update-in-place) returns
  BYTE-identical top-k ids and scores to an oracle ``Retriever.build``
  over the post-mutation corpus, both before and after
  merge/compaction (stable id ``live_ids[pos]`` ↔ oracle position).
* **id semantics** — delete-then-reinsert serves the NEW rows under
  the reused stable id without resurrecting the old copy;
  update-in-place keeps the id; inserting a live id / deleting a dead
  one fail loudly.
* **shard boundaries** — tombstones over a sharded base route to the
  owning shards by doc range (including whole-shard and
  boundary-straddling deletes) and the shard merge masks them without
  losing live candidates.
* **crash injection** — a crash between the segment/generation write
  and the atomic commit (``state.json`` / ``CURRENT`` flip) leaves the
  previous state loadable via ``open_retriever``, and a retry
  reclaims the orphan directory.
* **cache staleness** — a ResultCache answer never survives a
  mutation or a generation flip (epoch-tag invalidation), and the
  fan-out plan is retired (``gen`` key component) on merge.
"""

import numpy as np
import pytest

from repro.core.forward_index import ForwardIndex
from repro.core.layout import available_layouts
from repro.data.synthetic import SyntheticConfig, generate_collection
from repro.serve.api import (
    ArtifactError,
    Retriever,
    RetrieverConfig,
    available_engines,
    open_retriever,
)
from repro.serve.segments import InjectedCrash, MutableRetriever

#: budgets EXHAUSTIVE for the 50-doc collection (same recipe as the
#: sharded suite): mutable fan-out and oracle see identical candidate
#: sets, so the top-k must match byte-for-byte.
ENGINE_PARAMS = {
    "seismic": dict(cut=16, block_budget=512, n_probe=512, n_postings=10000,
                    block_size=8),
    "hnsw": dict(beam=64, iters=64, n_seeds=4, m=8, ef_construction=48),
    "flat": {},
}

N_BASE = 40


def _cfg(engine, codec="uncompressed", n_shards=1, k=10):
    return RetrieverConfig(engine=engine, codec=codec, k=k, n_shards=n_shards,
                           params=ENGINE_PARAMS[engine])


@pytest.fixture(scope="module")
def collection():
    cfg = SyntheticConfig(
        name="segments-test", dim=256, n_docs=50, n_queries=4,
        doc_nnz_mean=24.0, query_nnz_mean=8.0, seed=7,
    )
    return generate_collection(cfg, value_format="f16")


@pytest.fixture(scope="module")
def queries(collection):
    return np.stack(
        [collection.query_dense(i) for i in range(collection.n_queries)]
    )


def _assert_oracle_parity(m, cfg, Q, label):
    """Mutable top-k == oracle over the live corpus, byte-for-byte."""
    live_fwd, live = m.live_corpus()
    oracle = Retriever.build(live_fwd, cfg.replace(n_shards=1))
    oi, osc = map(np.asarray, oracle.search(Q))
    mi, ms = map(np.asarray, m.search(Q))
    np.testing.assert_array_equal(mi, live[oi], err_msg=f"{label}: ids")
    np.testing.assert_array_equal(ms, osc, err_msg=f"{label}: scores")


@pytest.mark.parametrize("engine", available_engines())
@pytest.mark.parametrize("codec", available_layouts())
def test_mutation_parity_segment_sweep(collection, queries, engine, codec):
    """0 → 1 → 3 live segments (tombstones in base and segments, one
    update-in-place), parity at every step, then merge + parity."""
    fwd = collection.fwd
    cfg = _cfg(engine, codec, k=5)
    m = MutableRetriever.create(fwd.slice(0, N_BASE), cfg)
    assert len(m.segments) == 0
    m.delete([3, 17])  # tombstones at 0 segments
    _assert_oracle_parity(m, cfg, queries, f"{engine}/{codec} 0 segments")

    m.insert([fwd.doc(i) for i in range(N_BASE, N_BASE + 4)])
    assert len(m.segments) == 1
    _assert_oracle_parity(m, cfg, queries, f"{engine}/{codec} 1 segment")

    m.insert([fwd.doc(i) for i in range(44, 47)])
    m.delete([41, 45])  # tombstones inside segments
    m.update([fwd.doc(47)], ids=[10])  # → the third segment
    assert len(m.segments) == 3
    _assert_oracle_parity(m, cfg, queries, f"{engine}/{codec} 3 segments")

    expect_live = m.live_ids()
    m.merge()
    assert len(m.segments) == 0 and m.generation == 1
    np.testing.assert_array_equal(m.base_ids, expect_live)
    _assert_oracle_parity(m, cfg, queries, f"{engine}/{codec} post-merge")


def test_delete_then_reinsert_and_update_semantics(collection, queries):
    fwd = collection.fwd
    cfg = _cfg("flat", "streamvbyte", k=5)
    m = MutableRetriever.create(fwd.slice(0, N_BASE), cfg)

    # a live id cannot be inserted again without a delete
    with pytest.raises(ValueError, match="still live"):
        m.insert([fwd.doc(41)], ids=[7])
    with pytest.raises(KeyError):
        m.delete([N_BASE + 99])

    # delete-then-reinsert under the same stable id serves the NEW
    # content — the tombstoned base copy must not resurface
    m.delete([7])
    assert 7 not in set(m.live_ids())
    m.insert([fwd.doc(44)], ids=[7])
    assert 7 in set(m.live_ids())
    _assert_oracle_parity(m, cfg, queries, "reinserted id")

    # the served score for id 7 is the NEW row's score
    c, v = fwd.doc(44)
    q = np.zeros(fwd.dim, np.float32)
    q[c] = 1.0
    ids, scores = map(np.asarray, m.search(q[None, :]))
    row = np.flatnonzero(ids[0] == 7)
    assert row.size == 1
    assert np.isclose(scores[0][row[0]], np.float32(v.sum()), rtol=1e-3)

    # update-in-place: same id, double deletion of the old copy fails
    m.update([fwd.doc(45)], ids=[7])
    assert 7 in set(m.live_ids())
    _assert_oracle_parity(m, cfg, queries, "updated id")
    # the update's tombstone landed on the SEGMENT copy (newest wins):
    # deleting once more kills the updated row, then the id is gone
    m.delete([7])
    with pytest.raises(KeyError):
        m.delete([7])
    assert m.n_live == N_BASE - 1
    _assert_oracle_parity(m, cfg, queries, "after final delete")


@pytest.mark.parametrize("engine", available_engines())
def test_tombstone_masking_at_shard_boundaries(collection, queries, engine):
    """Sharded base: deletes routed per shard by doc range — boundary
    docs, a whole shard's range, and the id-space extremes — never
    lose live candidates or resurrect dead ones."""
    fwd = collection.fwd
    cfg = _cfg(engine, "dotvbyte", n_shards=5, k=5)
    m = MutableRetriever.create(fwd.slice(0, N_BASE), cfg)
    base = m.base
    ranges = [(sh.doc_lo, sh.doc_hi) for sh in base.shards]
    # boundary docs of shard 1 + the WHOLE of shard 2 + the extremes
    lo1, hi1 = ranges[1]
    lo2, hi2 = ranges[2]
    victims = sorted({0, lo1, hi1 - 1, *range(lo2, hi2), N_BASE - 1})
    m.delete(victims)
    _assert_oracle_parity(m, cfg, queries, f"{engine} shard-boundary dels")
    # tombstones routed to their owning shards by doc range (installed
    # lazily at fan-out time, so assert after the search)
    assert sum(base._shard_tombs) == len(victims)
    assert base._shard_tombs[2] == hi2 - lo2

    # fold into generation 1 and mutate again: the fresh sharded base
    # re-routes tombstones over its NEW doc ranges
    m.merge()
    m.delete([int(m.live_ids()[0])])
    _assert_oracle_parity(m, cfg, queries, f"{engine} post-merge delete")


def test_crash_between_write_and_flip_preserves_generation(
    collection, queries, tmp_path
):
    fwd = collection.fwd
    cfg = _cfg("flat", "bitpack", k=5)
    root = tmp_path / "idx"
    m = MutableRetriever.create(fwd.slice(0, N_BASE), cfg, root=root)
    m.insert([fwd.doc(40)])
    m.delete([5])
    want_ids, want_sc = map(np.asarray, m.search(queries))

    # crash between the segment write and the state.json commit: the
    # orphan directory must be invisible to open and reclaimed on retry
    with pytest.raises(InjectedCrash):
        m.insert([fwd.doc(41)], _crash_before_commit=True)
    r = open_retriever(root)
    assert isinstance(r, MutableRetriever)
    assert len(r.segments) == 1 and r.n_live == m.n_live
    np.testing.assert_array_equal(np.asarray(r.search(queries)[0]), want_ids)
    m.insert([fwd.doc(41)])  # retry reclaims segment_0001

    # crash between the generation write and the CURRENT flip: the
    # previous generation (with its segments + tombstones) still opens
    with pytest.raises(InjectedCrash):
        m.merge(crash_before_flip=True)
    r = open_retriever(root)
    assert r.generation == 0 and len(r.segments) == 2
    a, b = map(np.asarray, r.search(queries))
    c, d = map(np.asarray, m.search(queries))
    np.testing.assert_array_equal(a, c)
    np.testing.assert_array_equal(b, d)

    # the retried merge flips cleanly; the reopened handle serves the
    # new generation byte-identically
    m.merge()
    r = open_retriever(root)
    assert r.generation == 1 and not r.segments
    np.testing.assert_array_equal(
        np.asarray(r.search(queries)[0]), np.asarray(m.search(queries)[0])
    )

    # a CURRENT pointing at a missing generation fails loudly
    (root / "CURRENT").write_text("generation_0099")
    with pytest.raises(ArtifactError, match="generation"):
        open_retriever(root)


def test_result_cache_staleness_and_plan_retirement(collection, queries):
    """A cached answer must not survive a mutation or a generation
    flip — the epoch-tag invalidation regression."""
    fwd = collection.fwd
    cfg = _cfg("flat", "uncompressed", k=5)
    m = MutableRetriever.create(fwd.slice(0, N_BASE), cfg)
    pipe = m.pipeline(cache_size=64, deadline_us=0.0)
    q = queries[0]

    t1 = pipe.submit(q); pipe.flush()
    t2 = pipe.submit(q); pipe.flush()
    assert t2.from_cache
    ids_before = np.asarray(t1.ids)

    # tombstone the top hit: the cached answer is now a lie
    m.delete([int(ids_before[0])])
    t3 = pipe.submit(q); pipe.flush()
    assert not t3.from_cache, "cached answer survived a mutation"
    assert int(np.asarray(t3.ids)[0]) != int(ids_before[0])
    live_fwd, live = m.live_corpus()
    oracle = Retriever.build(live_fwd, cfg)
    oi, osc = map(np.asarray, oracle.search(q[None, :]))
    np.testing.assert_array_equal(np.asarray(t3.ids), live[oi[0]])
    np.testing.assert_array_equal(np.asarray(t3.scores), osc[0])
    snap = pipe.snapshot()
    assert snap["cache_invalidations"] >= 1
    assert snap["cache_invalidated_entries"] >= 1

    # generation flip: cache flushed again AND the fan-out plan retires
    t4 = pipe.submit(q); pipe.flush()
    assert t4.from_cache
    retired_before = m.plans.retired
    m.merge()
    t5 = pipe.submit(q); pipe.flush()
    assert not t5.from_cache, "cached answer survived a generation flip"
    np.testing.assert_array_equal(np.asarray(t5.ids), np.asarray(t4.ids))
    assert m.plans.retired > retired_before
    key = m.plans.get(pipe.plans.bucket_for(1)).key
    assert key.gen == f"g{m.generation}" and key.shard == "mut"


def test_forward_index_concat_select_append():
    """The merge primitives: concat/select/append round-trip the CSR
    rows (values kept in the stored dtype, bytes untouched)."""
    rng = np.random.default_rng(0)
    docs = []
    for _ in range(12):
        n = int(rng.integers(0, 6))
        docs.append((np.sort(rng.choice(64, size=n, replace=False)),
                     rng.random(n).astype(np.float32)))
    whole = ForwardIndex.from_docs(docs, dim=64, value_format="f16")
    parts = [whole.slice(0, 5), whole.slice(5, 8), whole.slice(8, 12)]
    cat = ForwardIndex.concat(parts)
    np.testing.assert_array_equal(cat.components, whole.components)
    np.testing.assert_array_equal(cat.values, whole.values)
    np.testing.assert_array_equal(cat.offsets, whole.offsets)
    assert parts[0].append(parts[1]).n_docs == 8

    idx = np.array([11, 0, 7, 7, 3])
    sel = whole.select(idx)
    assert sel.n_docs == len(idx)
    for r, src in enumerate(idx):
        np.testing.assert_array_equal(sel.doc(r)[0], whole.doc(src)[0])
        np.testing.assert_array_equal(sel.doc_raw_values(r),
                                      whole.doc_raw_values(src))
    with pytest.raises(ValueError):
        whole.select(np.array([12]))
    with pytest.raises(ValueError):
        ForwardIndex.concat([whole,
                             ForwardIndex.from_docs(docs, 32, "f16")])
    with pytest.raises(ValueError):
        ForwardIndex.concat([whole,
                             ForwardIndex.from_docs(docs, 64, "f32")])
