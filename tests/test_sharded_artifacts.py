"""Sharded artifact & out-of-core serving tests (DESIGN.md §9).

The ISSUE-7 acceptance suite, four layers deep:

* **scale-sweep parity** — for every engine × codec × n_shards ∈
  {1, 2, 4, 7}, the sharded retriever's top-k is BYTE-identical (ids
  and scores) to the unsharded oracle under exhaustive engine budgets;
  n_shards=7 over 50 docs exercises the ragged last shard, and a
  dedicated sweep drives shards all the way down to one document each.
* **artifact properties** — ``shard_ranges`` tiles ``[0, n_docs)``
  contiguously with balanced sizes and rejects empty shards
  (property-tested via ``proptest``); ``save`` → ``open_retriever``
  memory-maps every shard payload (``np.memmap``, O(metadata) open)
  and still answers byte-identically.
* **fault injection** — truncated shard npz, shard-count mismatch,
  overlapping/gapped doc ranges, engine skew and manifest version skew
  all raise ``ArtifactError`` with an actionable message instead of a
  silent wrong answer.
* **global-id regression** — shard-local ids ≥ the shard size and -1
  padding sentinels survive ``map_local_ids`` + ``merge_topk`` without
  aliasing real documents, for both ``dedupe_merge`` settings (the
  clip-gather bug class), plus a randomized merge-vs-numpy property.

The mesh path (shard_map over ≥ n_shards forced host devices) runs in
a subprocess, following the ``test_dist`` idiom, so the main process
keeps seeing one device.
"""

import json
import os
import shutil
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np
import pytest

from proptest import integers, run_property
from repro.core.layout import available_layouts
from repro.data.synthetic import SyntheticConfig, generate_collection
from repro.serve.api import (
    MANIFEST_VERSION,
    ArtifactError,
    Retriever,
    RetrieverConfig,
    map_local_ids,
    merge_topk,
    open_retriever,
)
from repro.serve.sharded import ShardedRetriever, mmap_npz, shard_ranges

SHARD_COUNTS = [1, 2, 4, 7]

#: budgets EXHAUSTIVE for the 50-doc collection: every query component
#: probed, every block scored, the whole graph walkable — so sharded
#: and unsharded searches see identical candidate sets and the top-k
#: must match byte-for-byte, not just in recall.
ENGINE_PARAMS = {
    "seismic": dict(cut=16, block_budget=512, n_probe=512, n_postings=10000,
                    block_size=8),
    "hnsw": dict(beam=56, iters=56, n_seeds=4, m=8, ef_construction=48),
    "flat": {},
}


def _cfg(engine, codec="uncompressed", n_shards=1, k=10):
    return RetrieverConfig(engine=engine, codec=codec, k=k, n_shards=n_shards,
                           params=ENGINE_PARAMS[engine])


@pytest.fixture(scope="module")
def collection():
    cfg = SyntheticConfig(
        name="shard-test", dim=256, n_docs=50, n_queries=4,
        doc_nnz_mean=24.0, query_nnz_mean=8.0, seed=7,
    )
    return generate_collection(cfg, value_format="f16")


@pytest.fixture(scope="module")
def queries(collection):
    return np.stack(
        [collection.query_dense(i) for i in range(collection.n_queries)]
    )


@pytest.fixture(scope="module")
def oracle_cache():
    """(engine, codec) → unsharded top-k, built lazily once per module."""
    return {}


def _oracle(collection, queries, cache, engine, codec):
    key = (engine, codec)
    if key not in cache:
        r = Retriever.build(collection.fwd, _cfg(engine, codec, n_shards=1))
        ids, scores = r.search(queries)
        cache[key] = (np.asarray(ids), np.asarray(scores))
    return cache[key]


# ---------------------------------------------------------------------------
# shard_ranges: the partition contract (property-tested)
# ---------------------------------------------------------------------------

def test_shard_ranges_properties():
    """Ranges tile [0, n) contiguously, sizes balanced within one doc,
    the ragged shard (if any) is the LAST one; infeasible splits raise."""

    def prop(n_docs, n_shards):
        if n_shards > n_docs:
            with pytest.raises(ValueError):
                shard_ranges(n_docs, n_shards)
            return
        ranges = shard_ranges(n_docs, n_shards)
        assert len(ranges) == n_shards
        assert ranges[0][0] == 0 and ranges[-1][1] == n_docs
        sizes = [hi - lo for lo, hi in ranges]
        assert all(s >= 1 for s in sizes)
        assert max(sizes) - min(sizes) <= 1
        assert sizes == sorted(sizes, reverse=True)  # ragged shard is last
        for (_, hi), (lo, _) in zip(ranges, ranges[1:]):
            assert hi == lo  # contiguous, no gaps/overlaps

    run_property(prop, integers(1, 200), integers(1, 40), seed=11)


def test_empty_shards_rejected(collection):
    """n_shards > n_docs would leave empty shards — rejected at build
    time with an actionable message, not discovered at query time."""
    with pytest.raises(ValueError, match="at least one document"):
        shard_ranges(5, 8)
    with pytest.raises(ValueError, match="n_shards"):
        shard_ranges(10, 0)
    with pytest.raises(ValueError, match="at least one document"):
        Retriever.build(collection.fwd, _cfg("flat", n_shards=51))


# ---------------------------------------------------------------------------
# scale-sweep parity: engine × codec × n_shards, byte-identical top-k
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_shards", SHARD_COUNTS)
@pytest.mark.parametrize("codec", available_layouts())
@pytest.mark.parametrize("engine", ["seismic", "hnsw", "flat"])
def test_sharded_matches_unsharded_oracle(collection, queries, oracle_cache,
                                          engine, codec, n_shards):
    """The tentpole criterion: sharding is invisible to the caller —
    ids AND scores byte-identical to the monolithic build. n_shards=7
    over 50 docs makes the last shard ragged (8-doc and 7-doc shards
    coexist, so per-shard array shapes differ and plan keys must not
    collide)."""
    ids_o, sc_o = _oracle(collection, queries, oracle_cache, engine, codec)
    r = Retriever.build(collection.fwd, _cfg(engine, codec, n_shards))
    if n_shards == 1:
        assert isinstance(r, Retriever)
    else:
        assert isinstance(r, ShardedRetriever)
        assert [sh.n_docs for sh in r.shards] == [
            hi - lo for lo, hi in shard_ranges(collection.fwd.n_docs, n_shards)
        ]
    ids, scores = r.search(queries)
    assert np.array_equal(np.asarray(ids), ids_o)
    assert np.array_equal(np.asarray(scores), sc_o)


@pytest.mark.parametrize("engine", ["seismic", "hnsw", "flat"])
def test_single_doc_shards(engine):
    """The degenerate scale point: n_shards == n_docs, every shard owns
    exactly one document (shard size < k, so the per-shard k cap and
    the merge's sentinel padding both engage)."""
    coll = generate_collection(
        SyntheticConfig(name="tiny", dim=128, n_docs=10, n_queries=3,
                        doc_nnz_mean=16.0, query_nnz_mean=6.0, seed=13),
        value_format="f16",
    )
    Q = np.stack([coll.query_dense(i) for i in range(3)])
    cfg = RetrieverConfig(engine=engine, k=5, params=ENGINE_PARAMS[engine])
    ids_o, sc_o = Retriever.build(coll.fwd, cfg).search(Q)
    r = Retriever.build(coll.fwd, cfg.replace(n_shards=10))
    assert all(sh.n_docs == 1 for sh in r.shards)
    ids, scores = r.search(Q)
    assert np.array_equal(np.asarray(ids), np.asarray(ids_o))
    assert np.array_equal(np.asarray(scores), np.asarray(sc_o))


def test_pipeline_search_batch_parity(collection, queries, oracle_cache):
    """The micro-batching pipeline works unmodified over shards: same
    answers through ``search_batch`` as through the oracle."""
    ids_o, sc_o = _oracle(collection, queries, oracle_cache, "flat",
                          "uncompressed")
    r = Retriever.build(collection.fwd, _cfg("flat", n_shards=4))
    ids, scores = r.search_batch(queries)
    assert np.array_equal(np.asarray(ids), ids_o)
    assert np.array_equal(np.asarray(scores), sc_o)


def test_out_of_core_lru_parity(collection, queries, oracle_cache):
    """max_resident=1 forces strict out-of-core round-robin: every
    query batch re-admits each shard in turn. Answers stay identical;
    evictions and the peak-residency bound are observable."""
    ids_o, sc_o = _oracle(collection, queries, oracle_cache, "flat",
                          "uncompressed")
    r = Retriever.build(collection.fwd, _cfg("flat", n_shards=4))
    full = sum(sh.disk_bytes() for sh in r.shards)
    r.max_resident = 1
    ids, scores = r.search(queries)
    assert np.array_equal(np.asarray(ids), ids_o)
    assert np.array_equal(np.asarray(scores), sc_o)
    assert len(r._resident) == 1
    assert r.evictions >= 3
    assert 0 < r.peak_resident_bytes < full
    # a second pass recompiles evicted plans; the counter stays honest
    before = r.plans.compiles
    r.search(queries)
    assert r.plans.compiles > before


def test_plan_keys_carry_shard_topology(collection, queries):
    """Plan keys grow the shard-topology component: the facade plan is
    keyed ``*/S`` and each resident shard's plans are keyed ``s/S``, so
    ragged shards (different array shapes) never collide on an
    executable."""
    r = Retriever.build(collection.fwd, _cfg("flat", n_shards=2))
    r.search(queries)
    bucket = r.plans.bucket_for(queries.shape[0])
    assert r.plans.get(bucket).key.shard == "*/2"
    assert {sr.plans.get(bucket).key.shard
            for sr in r._resident.values()} == {"0/2", "1/2"}
    assert r.plans.compiles >= 2  # one per shard at least


# ---------------------------------------------------------------------------
# artifact tree: mmap'd open + round-trip parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", ["seismic", "hnsw", "flat"])
def test_save_open_memory_mapped(collection, queries, tmp_path, engine):
    """``open_retriever`` on a sharded tree memory-maps every shard
    payload — O(metadata) open, no array bytes touched until admission
    — and the reopened tree answers byte-identically."""
    r = Retriever.build(collection.fwd, _cfg(engine, n_shards=3))
    ids, scores = r.search(queries)
    art = r.save(tmp_path / f"tree-{engine}")
    r2 = open_retriever(art)
    assert isinstance(r2, ShardedRetriever)
    assert r2.cfg == r.cfg and r2.n_docs == r.n_docs
    for sh in r2.shards:
        mapped = [a for a in sh.arrays.values() if isinstance(a, np.memmap)]
        assert mapped, "shard arrays must be memory-mapped views"
        assert all(isinstance(a, np.memmap) for a in sh.arrays.values()
                   if a.size > 0)
    ids2, scores2 = r2.search(queries)
    assert np.array_equal(np.asarray(ids), np.asarray(ids2))
    assert np.array_equal(np.asarray(scores), np.asarray(scores2))


@pytest.fixture(scope="module")
def saved_tree(collection, tmp_path_factory):
    """One pristine flat-engine tree; fault tests copy and corrupt."""
    r = Retriever.build(collection.fwd, _cfg("flat", n_shards=3))
    return r.save(tmp_path_factory.mktemp("pristine") / "tree")


def _corrupt_copy(saved_tree, tmp_path, mutate):
    tree = tmp_path / "tree"
    shutil.copytree(saved_tree, tree)
    mutate(tree)
    return tree


def _edit_json(path, fn):
    mf = json.loads(path.read_text())
    fn(mf)
    path.write_text(json.dumps(mf))


def test_truncated_shard_payload_fails(saved_tree, tmp_path):
    def mutate(tree):
        npz = tree / "shard_0000" / "arrays.npz"
        data = npz.read_bytes()
        npz.write_bytes(data[: len(data) // 2])

    tree = _corrupt_copy(saved_tree, tmp_path, mutate)
    with pytest.raises(ArtifactError, match="truncat|corrupt"):
        open_retriever(tree)


def test_missing_shard_payload_fails(saved_tree, tmp_path):
    tree = _corrupt_copy(
        saved_tree, tmp_path,
        lambda t: (t / "shard_0001" / "arrays.npz").unlink(),
    )
    with pytest.raises(ArtifactError, match="missing shard payload"):
        open_retriever(tree)


def test_shard_count_mismatch_fails(saved_tree, tmp_path):
    """Top-level n_shards disagrees with the listed shard entries."""
    tree = _corrupt_copy(
        saved_tree, tmp_path,
        lambda t: _edit_json(t / "manifest.json",
                             lambda mf: mf.__setitem__("n_shards", 4)),
    )
    with pytest.raises(ArtifactError, match="shard-count mismatch"):
        open_retriever(tree)


def test_foreign_shard_rejected(saved_tree, tmp_path):
    """A shard whose own manifest says it belongs to a different-sized
    tree (n_shards skew) is rejected — it cannot silently serve here."""
    tree = _corrupt_copy(
        saved_tree, tmp_path,
        lambda t: _edit_json(t / "shard_0000" / "manifest.json",
                             lambda mf: mf.__setitem__("n_shards", 5)),
    )
    with pytest.raises(ArtifactError, match="shard-count mismatch"):
        open_retriever(tree)


@pytest.mark.parametrize("delta", [-1, +1], ids=["overlap", "gap"])
def test_bad_doc_ranges_fail(saved_tree, tmp_path, delta):
    def mutate(tree):
        _edit_json(
            tree / "manifest.json",
            lambda mf: mf["shards"][1].__setitem__(
                "doc_lo", mf["shards"][1]["doc_lo"] + delta
            ),
        )

    tree = _corrupt_copy(saved_tree, tmp_path, mutate)
    with pytest.raises(ArtifactError, match="tile"):
        open_retriever(tree)


def test_shard_range_disagreement_fails(saved_tree, tmp_path):
    """Top-level and per-shard manifests disagree on the doc range."""
    tree = _corrupt_copy(
        saved_tree, tmp_path,
        lambda t: _edit_json(t / "shard_0002" / "manifest.json",
                             lambda mf: mf.__setitem__(
                                 "doc_lo", mf["doc_lo"] + 1)),
    )
    with pytest.raises(ArtifactError, match="doc range disagrees"):
        open_retriever(tree)


@pytest.mark.parametrize("where", ["manifest.json",
                                   os.path.join("shard_0001", "manifest.json")],
                         ids=["top", "shard"])
def test_version_skew_fails(saved_tree, tmp_path, where):
    tree = _corrupt_copy(
        saved_tree, tmp_path,
        lambda t: _edit_json(t / where,
                             lambda mf: mf.__setitem__(
                                 "version", MANIFEST_VERSION + 1)),
    )
    with pytest.raises(ArtifactError, match="version"):
        open_retriever(tree)


def test_engine_skew_fails(saved_tree, tmp_path):
    tree = _corrupt_copy(
        saved_tree, tmp_path,
        lambda t: _edit_json(t / "shard_0001" / "manifest.json",
                             lambda mf: mf.__setitem__("engine", "hnsw")),
    )
    with pytest.raises(ArtifactError, match="skew"):
        open_retriever(tree)


def test_compressed_payload_not_mappable(collection, tmp_path):
    """A tree written with compress=True loads fine through the normal
    reader path? No — mmap needs ZIP_STORED; the error says how to fix
    it rather than serving garbage."""
    r = Retriever.build(collection.fwd, _cfg("flat", n_shards=2))
    art = r.save(tmp_path / "tree", compress=True)
    with pytest.raises(ArtifactError, match="compress=False"):
        mmap_npz(art / "shard_0000" / "arrays.npz")


# ---------------------------------------------------------------------------
# global-id regression: sentinels through the merge, both dedupe modes
# ---------------------------------------------------------------------------

def test_map_local_ids_never_aliases():
    """The clip-gather bug class: -1 padding must NOT alias local doc 0
    and ids ≥ the shard size must NOT alias the shard's last doc — both
    map to the out-of-corpus sentinel."""
    # shard owns global docs [40, 45); idmap slot 5 is the sentinel
    idmap = jnp.asarray(np.array([40, 41, 42, 43, 44, 100], np.int32))
    ids = jnp.asarray([[-1, 0, 4, 5, 6, 2]], jnp.int32)
    out = np.asarray(map_local_ids(idmap, ids, 100))
    assert out.tolist() == [[100, 40, 44, 100, 100, 42]]


@pytest.mark.parametrize("dedupe", [False, True])
def test_sentinels_survive_merge_without_aliasing(dedupe):
    """-1 and ≥ n_docs ids carry the HIGHEST raw scores here; the merge
    must mask them to -inf so they never displace a real document, in
    both dedupe modes."""
    n, k = 100, 4
    flat_ids = jnp.asarray([[7, -1, 7, 99, 100, 3]], jnp.int32)
    flat_scores = jnp.asarray([[5.0, 9.0, 5.0, 1.0, 9.0, 2.0]], jnp.float32)
    ids, scores = merge_topk(flat_ids, flat_scores, k,
                             dedupe=dedupe, n_docs_global=n)
    ids, scores = np.asarray(ids)[0], np.asarray(scores)[0]
    finite = np.isfinite(scores)
    # no out-of-corpus id ever carries a finite score
    assert all(0 <= i < n for i in ids[finite])
    if dedupe:
        assert ids[finite].tolist() == [7, 3, 99]
        assert scores[finite].tolist() == [5.0, 2.0, 1.0]
    else:
        assert ids[finite].tolist() == [7, 7, 3, 99]
        assert scores[finite].tolist() == [5.0, 5.0, 2.0, 1.0]


def test_merge_topk_matches_numpy_reference():
    """Randomized merge property: ids drawn from [-3, n_docs + 3) with
    per-id-deterministic scores (shards re-score exactly, so duplicates
    agree) — the merged finite prefix must equal a numpy reference
    top-k over the valid (unique, when deduping) candidates."""

    def prop(n_docs, width, case_seed):
        rng = np.random.default_rng(case_seed)
        k = min(5, width)
        flat_ids = rng.integers(-3, n_docs + 3, size=(2, width)).astype(np.int32)
        score_of = lambda i: 1.0 + 0.5 * i  # injective in the id
        flat_scores = score_of(flat_ids.astype(np.float32))
        for dedupe in (False, True):
            ids, scores = merge_topk(
                jnp.asarray(flat_ids), jnp.asarray(flat_scores), k,
                dedupe=dedupe, n_docs_global=n_docs,
            )
            ids, scores = np.asarray(ids), np.asarray(scores)
            for q in range(2):
                valid = flat_ids[q][(flat_ids[q] >= 0)
                                    & (flat_ids[q] < n_docs)]
                if dedupe:
                    valid = np.unique(valid)
                want = np.sort(valid)[::-1][:k]  # injective ⇒ sort by id
                finite = np.isfinite(scores[q])
                assert ids[q][finite].tolist() == want.tolist(), (
                    f"dedupe={dedupe} q={q}"
                )
                np.testing.assert_array_equal(
                    scores[q][finite], score_of(want.astype(np.float32))
                )

    run_property(prop, integers(4, 60), integers(1, 24),
                 integers(0, 10**6), n_cases=30, seed=5)


# ---------------------------------------------------------------------------
# mesh path: shard_map parity on 8 forced host devices (subprocess)
# ---------------------------------------------------------------------------

_ENV = {
    **os.environ,
    "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
    "PYTHONPATH": os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src")]
        + os.environ.get("PYTHONPATH", "").split(os.pathsep)
    ),
}


def test_mesh_matches_sequential():
    """With ≥ n_shards devices the dispatch takes the shard_map path;
    answers must match the sequential out-of-core path byte-for-byte
    (a dedupe engine and a disjoint-range engine both covered)."""
    script = textwrap.dedent(
        """
        import numpy as np
        from repro.data.synthetic import SyntheticConfig, generate_collection
        from repro.serve.api import Retriever, RetrieverConfig

        coll = generate_collection(
            SyntheticConfig(name="mesh", dim=256, n_docs=48, n_queries=4,
                            doc_nnz_mean=24.0, query_nnz_mean=8.0, seed=3),
            value_format="f16",
        )
        Q = np.stack([coll.query_dense(i) for i in range(4)])
        cases = [
            ("flat", {}),
            ("seismic", dict(cut=16, block_budget=512, n_probe=512,
                             n_postings=10000, block_size=8)),
        ]
        for engine, params in cases:
            cfg = RetrieverConfig(engine=engine, k=10, n_shards=4,
                                  params=params)
            seq = Retriever.build(coll.fwd, cfg)
            seq.use_mesh = False
            ids_s, sc_s = seq.search(Q)
            mesh = Retriever.build(coll.fwd, cfg)
            mesh.use_mesh = True
            ids_m, sc_m = mesh.search(Q)
            assert np.array_equal(np.asarray(ids_s), np.asarray(ids_m)), engine
            assert np.array_equal(np.asarray(sc_s), np.asarray(sc_m)), engine
        print("mesh parity OK")
        """
    )
    proc = subprocess.run(
        [sys.executable, "-c", script], env=_ENV, capture_output=True,
        text=True, timeout=900,
    )
    assert proc.returncode == 0, f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
