"""Pytest config: tests run on ONE CPU device (multi-device cases spawn
subprocesses with their own XLA_FLAGS — see test_dist.py). The dry-run
(512 devices) is exercised only via python -m repro.launch.dryrun."""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))
