"""repro — Forward Index Compression for Learned Sparse Retrieval,
as a production-grade JAX/Pallas framework. See DESIGN.md."""

from . import compat as _compat

_compat.install()

__version__ = "1.1.0"
