"""repro — Forward Index Compression for Learned Sparse Retrieval,
as a production-grade JAX/Pallas framework. See DESIGN.md."""

__version__ = "1.0.0"
