import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)
# ^ MUST precede every other import (jax locks the device count on first
#   backend init). Smoke tests / benches never import this module, so
#   they keep seeing 1 CPU device.

"""Multi-pod dry-run (deliverable e).

For every (architecture × input shape) cell — 40 assigned cells + the
paper's retrieval cells — lower and compile the step on the production
meshes:

    single-pod : (16, 16)      ("data", "model")        = 256 chips
    multi-pod  : (2, 16, 16)   ("pod", "data", "model") = 512 chips

Inputs are ShapeDtypeStructs (no allocation). Success proves the
sharding rules are coherent (no mismatched collectives, layouts or
specs); the printed ``memory_analysis()`` proves per-device fit and
``cost_analysis()`` feeds §Roofline. Results land in
``experiments/dryrun/<mesh>/<arch>__<shape>.json``.

Usage::

    PYTHONPATH=src python -m repro.launch.dryrun                # all cells
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --mesh multi --skip-retrieval
"""

import argparse
import json
import time
import traceback

import jax

from repro.configs import ARCH_IDS, RETRIEVAL_IDS, get_arch
from repro.launch.hlo_stats import HW, count_hlo_costs, parse_collectives, roofline_terms
from repro.launch.mesh import make_production_mesh


def run_cell(arch_id: str, shape: str, mesh, *, save_dir: str | None, mesh_tag: str,
             keep_hlo: bool = False) -> dict:
    arch = get_arch(arch_id)
    t0 = time.time()
    cell = arch.build_cell(shape, mesh)
    n_chips = mesh.devices.size
    with jax.set_mesh(mesh):
        jitted = jax.jit(
            cell.fn,
            in_shardings=cell.in_shardings,
            out_shardings=cell.out_shardings,
        )
        lowered = jitted.lower(*cell.input_structs)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()  # NOTE: counts while bodies once
    hlo = compiled.as_text()
    hc = count_hlo_costs(hlo)  # trip-count-aware (hlo_stats.py)

    device_flops = float(hc["flops"])
    device_bytes = float(hc["bytes"])
    coll_bytes = float(hc["collective_bytes"])
    rec = {
        "arch": arch_id,
        "shape": shape,
        "kind": cell.kind,
        "mesh": mesh_tag,
        "n_chips": int(n_chips),
        "ok": True,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": int(mem.argument_size_in_bytes),
            "output_bytes": int(mem.output_size_in_bytes),
            "temp_bytes": int(mem.temp_size_in_bytes),
            "alias_bytes": int(mem.alias_size_in_bytes),
            "peak_device_bytes": int(
                mem.argument_size_in_bytes
                + mem.output_size_in_bytes
                + mem.temp_size_in_bytes
                - mem.alias_size_in_bytes
            ),
        },
        "cost": {
            "device_flops": device_flops,
            "device_bytes": device_bytes,
            "xla_cost_analysis_flops_unscaled": float(cost.get("flops", 0.0)),
            "xla_cost_analysis_bytes_unscaled": float(cost.get("bytes accessed", 0.0)),
        },
        "collectives": {
            "by_op": hc["collectives_by_op"],
            "total_bytes_per_device": coll_bytes,
        },
        "roofline": roofline_terms(
            global_flops=device_flops * n_chips,
            device_flops=device_flops,
            device_bytes=device_bytes,
            collective_bytes=coll_bytes,
            n_chips=n_chips,
            model_flops=cell.model_flops,
        ),
        "meta": cell.meta,
    }
    if save_dir:
        d = os.path.join(save_dir, mesh_tag)
        os.makedirs(d, exist_ok=True)
        with open(os.path.join(d, f"{arch_id}__{shape}.json"), "w") as f:
            json.dump(rec, f, indent=1)
        if keep_hlo:
            with open(os.path.join(d, f"{arch_id}__{shape}.hlo.txt"), "w") as f:
                f.write(hlo)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--skip-retrieval", action="store_true")
    ap.add_argument("--keep-hlo", action="store_true")
    ap.add_argument("--fail-fast", action="store_true")
    args = ap.parse_args()

    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("pod256", make_production_mesh(multi_pod=False)))
    if args.mesh in ("multi", "both"):
        meshes.append(("pod512x2", make_production_mesh(multi_pod=True)))

    arch_ids = [args.arch] if args.arch else list(
        ARCH_IDS + (() if args.skip_retrieval else RETRIEVAL_IDS)
    )

    n_ok = n_fail = 0
    for mesh_tag, mesh in meshes:
        for arch_id in arch_ids:
            arch = get_arch(arch_id)
            shapes = [args.shape] if args.shape else list(arch.shape_names)
            for shape in shapes:
                tag = f"[{mesh_tag}] {arch_id} × {shape}"
                try:
                    rec = run_cell(
                        arch_id, shape, mesh,
                        save_dir=args.out, mesh_tag=mesh_tag, keep_hlo=args.keep_hlo,
                    )
                    r = rec["roofline"]
                    print(
                        f"OK  {tag:60s} compile={rec['compile_s']:6.1f}s "
                        f"mem/dev={rec['memory']['peak_device_bytes']/2**30:6.2f}GiB "
                        f"terms(c/m/n)=({r['compute_s']:.2e},{r['memory_s']:.2e},"
                        f"{r['collective_s']:.2e})s dominant={r['dominant']}"
                    , flush=True)
                    n_ok += 1
                except Exception as e:  # noqa: BLE001 — report and continue
                    n_fail += 1
                    print(f"FAIL {tag}: {type(e).__name__}: {e}", flush=True)
                    traceback.print_exc()
                    if args.fail_fast:
                        raise
    print(f"\ndry-run complete: {n_ok} ok, {n_fail} failed")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
