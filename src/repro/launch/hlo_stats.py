"""Post-SPMD HLO statistics: collective bytes + roofline terms.

``collective_bytes`` is not part of ``compiled.cost_analysis()`` — per
the brief it is recovered by parsing the optimized (partitioned) HLO
text and summing the result-shape bytes of every collective op:

    all-gather / all-reduce / reduce-scatter / all-to-all /
    collective-permute

Conventions (documented in EXPERIMENTS.md §Roofline):

* shapes in the partitioned module are PER-DEVICE, so summed bytes are
  per-device wire traffic — exactly what the collective roofline term
  wants (bytes / link_bw per chip);
* all-reduce counts 2× its result bytes (ring reduce-scatter +
  all-gather decomposition); others count 1× their shape bytes;
* tuple-shaped collectives sum their component shapes.

Hardware constants are TPU v5e: 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI (3D-torus, per the brief).
"""

from __future__ import annotations

import dataclasses
import re

__all__ = ["HW", "CollectiveStats", "parse_collectives", "roofline_terms"]


@dataclasses.dataclass(frozen=True)
class HW:
    peak_flops: float = 197e12  # bf16 / chip
    hbm_bw: float = 819e9  # bytes/s / chip
    ici_bw: float = 50e9  # bytes/s / link


_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^\s]*)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\("
)


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


# ---------------------------------------------------------------------------
# Trip-count-aware HLO cost model
# ---------------------------------------------------------------------------
# XLA's ``compiled.cost_analysis()`` counts a ``while`` body ONCE, which
# under-counts scanned models (layers × microbatches) by orders of
# magnitude. This counter walks the optimized HLO text recursively:
# ``while`` costs multiply by the trip count recovered from the loop
# condition's comparison constant (jax scans lower to ``i < N``);
# ``fusion``/``call`` recurse into their computations. FLOPs count dot /
# convolution ops (they dominate these models; elementwise adds 1 flop
# per output element). Bytes follow XLA's convention: per instruction,
# operand bytes + result bytes.

_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*((?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\])\S*)\s+([\w\-]+)\((.*)$"
)
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_CALL_TARGET_RE = re.compile(r"(?:calls|body|condition|to_apply)=%?([\w\.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_CONST_RE = re.compile(r"constant\((\d+)\)")

_ELEMENTWISE_FLOP_OPS = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "exponential",
    "log", "rsqrt", "sqrt", "tanh", "power", "negate", "compare", "select",
}


def _dims(shape_txt: str) -> list[int]:
    m = _SHAPE_RE.search(shape_txt)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


def _elems(shape_txt: str) -> int:
    n = 1
    for d in _dims(shape_txt):
        n *= d
    return n


def count_hlo_costs(hlo_text: str) -> dict:
    """→ {"flops": device_flops, "bytes": device_bytes} with while-loop
    trip counts applied. Shapes in the partitioned module are per-device."""
    # --- split into computations -------------------------------------
    comps: dict[str, list[str]] = {}
    cur = None
    for line in hlo_text.splitlines():
        hdr = _COMP_HDR_RE.match(line.strip()) if line and not line.startswith(" ") else None
        if hdr is not None and "->" in line and line.rstrip().endswith("{"):
            cur = hdr.group(1)
            comps[cur] = []
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is not None:
            comps[cur].append(line)

    # --- shape table for operand lookup --------------------------------
    shapes: dict[str, str] = {}
    for lines in comps.values():
        for ln in lines:
            m = _INSTR_RE.match(ln)
            if m:
                shapes[m.group(1)] = m.group(2)

    def trip_count(cond_name: str) -> int:
        """Largest integer constant in the loop condition ≈ trip count
        (jax scans lower to ``i < N``)."""
        best = 1
        for ln in comps.get(cond_name, []):
            for c in _CONST_RE.findall(ln):
                best = max(best, int(c))
        return best

    _COLL_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                 "collective-permute")
    memo: dict[str, tuple[float, float, dict]] = {}
    sliced_memo: dict[int, dict] = {}

    def _sliced_params(comp_lines: list[str]) -> dict[int, int]:
        """Map fusion-parameter index → slice bytes, for parameters that
        are only read through dynamic-slice / gather inside the fusion."""
        key = id(comp_lines)
        if key in sliced_memo:
            return sliced_memo[key]
        param_idx: dict[str, int] = {}
        uses: dict[str, list[tuple[str, int]]] = {}
        for ln2 in comp_lines:
            m2 = _INSTR_RE.match(ln2)
            if not m2:
                continue
            res2, shape2, op2, rest2 = m2.groups()
            if op2 == "parameter":
                pm = re.search(r"parameter\((\d+)\)", ln2)
                if pm:
                    param_idx[res2] = int(pm.group(1))
            ops2 = _OPERAND_RE.findall(rest2.split(")", 1)[0])
            for o2 in ops2:
                uses.setdefault(o2, []).append((op2, _shape_bytes(shape2)))
        out: dict[int, int] = {}
        for pname, idx in param_idx.items():
            us = uses.get(pname, [])
            if us and all(u[0] in ("dynamic-slice", "gather") for u in us):
                out[idx] = sum(u[1] for u in us)
        sliced_memo[key] = out
        return out

    def comp_cost(name: str) -> tuple[float, float, dict]:
        if name in memo:
            return memo[name]
        memo[name] = (0.0, 0.0, {})  # cycle guard
        flops = bytes_ = 0.0
        coll: dict[str, float] = {}
        for ln in comps.get(name, []):
            m = _INSTR_RE.match(ln)
            if not m:
                continue
            _res, shape_txt, op, rest = m.groups()
            out_elems = _elems(shape_txt)
            out_bytes = _shape_bytes(shape_txt)
            paren = rest.split(")", 1)[0]
            operands = _OPERAND_RE.findall(paren)
            op_bytes = sum(_shape_bytes(shapes.get(o, "")) for o in operands)

            base = op.replace("-start", "") if op.endswith("-start") else op
            if op.endswith("-done"):
                continue  # async pair: cost attributed at -start
            if base in _COLL_OPS:
                b = out_bytes * (2 if base == "all-reduce" else 1)
                coll[base] = coll.get(base, 0.0) + b
                bytes_ += op_bytes + out_bytes
                continue
            if op == "dot":
                contract = 1
                cm = _CONTRACT_RE.search(ln)
                if cm and operands:
                    lhs_dims = _dims(shapes.get(operands[0], ""))
                    for idx in cm.group(1).split(","):
                        if idx and int(idx) < len(lhs_dims):
                            contract *= lhs_dims[int(idx)]
                flops += 2.0 * out_elems * contract
                bytes_ += op_bytes + out_bytes
            elif op == "convolution":
                flops += 2.0 * out_elems
                bytes_ += op_bytes + out_bytes
            elif op == "while":
                body = cond = None
                for kind, tgt in re.findall(r"(body|condition)=%?([\w\.\-]+)", ln):
                    body, cond = (tgt, cond) if kind == "body" else (body, tgt)
                trips = trip_count(cond) if cond else 1
                bf, bb, bc = comp_cost(body) if body else (0.0, 0.0, {})
                cf, cb, _cc = comp_cost(cond) if cond else (0.0, 0.0, {})
                flops += trips * (bf + cf)
                bytes_ += trips * (bb + cb)
                for k, v in bc.items():
                    coll[k] = coll.get(k, 0.0) + trips * v
            elif op in ("dynamic-slice", "gather"):
                # traffic = the slice actually moved, not the full operand
                # (scan bodies read per-layer weights by dynamic-slice from
                # the [L, …] stack — counting the stack would overcount L×)
                bytes_ += 2 * out_bytes
            elif op == "dynamic-update-slice":
                upd = _shape_bytes(shapes.get(operands[1], "")) if len(operands) > 1 else 0
                bytes_ += 2 * upd  # read+write the updated region only
            elif op in ("fusion", "call", "custom-call", "map", "reduce",
                        "reduce-window", "sort", "scatter", "select-and-scatter",
                        "conditional"):
                for t in _CALL_TARGET_RE.findall(ln):
                    tf_, _tb, tc = comp_cost(t)
                    flops += tf_  # fused inner traffic stays in VMEM
                    for k, v in tc.items():
                        coll[k] = coll.get(k, 0.0) + v
                flops += out_elems  # ~1 flop per produced element
                # per-operand bytes, slice-aware: a fusion parameter only
                # consumed via dynamic-slice/gather inside contributes its
                # slice size, not its full (possibly [L, …]-stacked) size
                tgt = _CALL_TARGET_RE.findall(ln)
                sliced = _sliced_params(comps.get(tgt[0], [])) if tgt else {}
                op_bytes2 = 0
                for i, o in enumerate(operands):
                    if i in sliced:
                        op_bytes2 += sliced[i]
                    else:
                        op_bytes2 += _shape_bytes(shapes.get(o, ""))
                bytes_ += op_bytes2 + out_bytes
            elif op in _ELEMENTWISE_FLOP_OPS:
                flops += out_elems
                bytes_ += op_bytes + out_bytes
            elif op in ("parameter", "constant", "get-tuple-element", "tuple",
                        "bitcast", "copy-start", "copy-done"):
                continue  # no HBM traffic attributed
            else:
                bytes_ += op_bytes + out_bytes
        memo[name] = (flops, bytes_, coll)
        return memo[name]

    entry = None
    for ln in hlo_text.splitlines():
        if ln.startswith("ENTRY"):
            m = _COMP_HDR_RE.match(ln.strip())
            if m:
                entry = m.group(1)
            break
    if entry is None:
        entry = max(comps, key=lambda k: len(comps[k])) if comps else ""
    f, b, c = comp_cost(entry)
    return {
        "flops": f,
        "bytes": b,
        "collective_bytes": sum(c.values()),
        "collectives_by_op": c,
    }


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_op: dict
    total_bytes: float

    def __str__(self) -> str:
        parts = ", ".join(f"{k}={v/1e6:.1f}MB" for k, v in sorted(self.bytes_by_op.items()))
        return f"collectives: {parts} (total {self.total_bytes/1e6:.1f}MB/device)"


def parse_collectives(hlo_text: str) -> CollectiveStats:
    by_op: dict[str, float] = {}
    for m in _COLL_RE.finditer(hlo_text):
        shape_txt, op, suffix = m.group(1), m.group(2), m.group(3)
        if suffix == "-done":
            continue  # async pair: count the -start only
        b = _shape_bytes(shape_txt)
        if op == "all-reduce":
            b *= 2  # ring = reduce-scatter + all-gather
        by_op[op] = by_op.get(op, 0.0) + b
    return CollectiveStats(by_op, sum(by_op.values()))


def roofline_terms(
    *,
    global_flops: float,
    device_flops: float,
    device_bytes: float,
    collective_bytes: float,
    n_chips: int,
    model_flops: float,
    hw: HW = HW(),
) -> dict:
    """The three §Roofline terms (seconds) + derived quantities."""
    compute_s = device_flops / hw.peak_flops
    memory_s = device_bytes / hw.hbm_bw
    collective_s = collective_bytes / hw.ici_bw
    dominant = max(
        ("compute", compute_s), ("memory", memory_s), ("collective", collective_s),
        key=lambda kv: kv[1],
    )[0]
    bound = max(compute_s, memory_s, collective_s)
    useful = model_flops / max(global_flops, 1.0)
    return {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "dominant": dominant,
        "step_lower_bound_s": bound,
        "model_flops": model_flops,
        "hlo_flops_global": global_flops,
        "useful_flops_ratio": useful,
        "roofline_fraction": compute_s / bound if bound > 0 else 0.0,
        "mfu_upper_bound": (model_flops / n_chips / hw.peak_flops) / bound if bound > 0 else 0.0,
    }
