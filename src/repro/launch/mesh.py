"""Production mesh definitions.

``make_production_mesh()`` is a FUNCTION (never a module constant) so
importing this module never touches jax device state — critical because
smoke tests must see 1 CPU device while the dry-run forces 512
placeholder devices via XLA_FLAGS before any jax import.
"""

from __future__ import annotations

import jax
from jax.sharding import AxisType

__all__ = ["make_production_mesh", "make_debug_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips/pod ("data", "model"); ×2 pods when multi_pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_debug_mesh(shape=(2, 4), axes=("data", "model")):
    """Small mesh for multi-device CPU tests (8 forced host devices)."""
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))
