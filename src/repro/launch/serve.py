"""Serving launcher: build (or load) an ANNS index over a synthetic
MsMarco-like collection and serve batched queries through the unified
``repro.serve.api`` Retriever surface.

``python -m repro.launch.serve --engine seismic --codec dotvbyte
--n-docs 20000 --n-queries 64`` builds the collection + index, runs
batched searches, and reports recall@10 + latency. Engine choices come
straight from the registry (plus ``both`` = seismic+hnsw and ``all`` =
every registered engine, ``flat`` included); codec choices come from
``repro.core.layout.available_layouts()``, so a newly registered
engine or codec reaches this CLI with zero edits. ``--compare-codecs``
sweeps every serving codec over the same host index.

The build/serve split (DESIGN.md §7): ``--save-index DIR`` writes one
artifact per engine×codec under ``DIR/<engine>-<codec>/`` (manifest +
packed arrays + the top-k of this run); ``--load-index DIR`` skips the
build, serves from the artifacts, and — when the saved top-k is
present — verifies the reopened index returns byte-identical results
(the ``make serve-roundtrip`` smoke).

``--pipeline`` switches to the online-serving load generator
(DESIGN.md §8): a seeded synthetic traffic trace (one request at a
time, a Zipf-ish repeat-heavy head to exercise the result cache,
optional ``--trace-qps`` pacing) is driven through the micro-batching
scheduler; every response is verified byte-identical to a direct
``Retriever.search`` of the same query, then the ServeStats block
(QPS, p50/p95/p99, hit rate, bucket occupancy, recompiles) is
reported — the ``make pipeline-smoke`` gate.

``--mutate`` is the live-mutation load generator (DESIGN.md §10): a
seeded insert/delete/update stream interleaved with the query trace,
served through the micro-batching pipeline over a ``MutableRetriever``
(delta segments + tombstones). At every checkpoint — after each
mutation round and again after the final merge/compaction — EVERY
response since the previous checkpoint is verified byte-identical to a
freshly built oracle index over the post-mutation corpus, and the
ResultCache must show an epoch invalidation per round (a cached answer
never survives a mutation). Engine budgets are forced exhaustive so
parity is byte-exact — keep ``--n-docs`` small (≲ 200) in this mode.

The HNSW host build is a few ms per document — prefer ``--n-docs``
in the low thousands when sweeping the graph engine interactively.
"""

from __future__ import annotations

import argparse
import pathlib
import time

import numpy as np


def _report(name, codec, k, recs, dt_us, col, extra=""):
    comp_bytes = col.fwd.storage_bytes(codec)["components"]
    raw_bytes = col.fwd.storage_bytes("uncompressed")["components"]
    print(
        f"{name:8s} codec={codec:13s} recall@{k}={np.mean(recs):.3f} "
        f"latency={dt_us:7.0f}µs/q (CPU) "
        f"components={comp_bytes/2**20:.1f}MiB ({8*comp_bytes/col.fwd.total_nnz:.1f} "
        f"bits/comp vs 16.0 raw, {100*(1-comp_bytes/raw_bytes):.0f}% saved){extra}"
    )


def _pipeline_loadgen(retriever, Q, args, rng) -> str:
    """Drive a synthetic traffic trace through the micro-batching
    scheduler and verify every response against direct search.

    The trace is repeat-heavy (``--repeat-frac`` of requests re-ask one
    of a few head queries — the shape of real query logs) so the
    result cache sees hits; ``--trace-qps`` > 0 paces arrivals in real
    time, 0 means closed-loop back-to-back (deadline dispatches then
    fire while previous batches compute). Returns the stats summary;
    raises AssertionError on any parity violation."""
    from repro.serve.pipeline import ServeStats, synthetic_trace

    trace = synthetic_trace(rng, args.requests, Q.shape[0],
                            repeat_frac=args.repeat_frac)
    direct_ids, direct_scores = retriever.search(Q)
    direct_ids, direct_scores = np.asarray(direct_ids), np.asarray(direct_scores)

    pipe = retriever.pipeline(deadline_us=args.deadline_us,
                              cache_size=args.cache_size)
    # compile cost out of the measured trace (benchmarks/common.py
    # warmup discipline): p50/p95/p99 below cover warm dispatches only
    warm = pipe.warm()
    gap = 1.0 / args.trace_qps if args.trace_qps > 0 else 0.0
    tickets = []
    for qi in trace:
        if gap:
            time.sleep(gap)
        pipe.poll()  # fire expired deadlines before admitting
        tickets.append(pipe.submit(Q[qi]))
    pipe.flush()

    for qi, t in zip(trace, tickets):
        assert np.array_equal(t.ids, direct_ids[qi]), (
            f"pipeline top-k ids diverge from direct search (query {qi})")
        assert np.array_equal(t.scores, direct_scores[qi]), (
            f"pipeline top-k scores diverge from direct search (query {qi})")
    snap = pipe.snapshot()
    return (f"{ServeStats.summary(snap)} "
            f"warm_compiles={warm} "
            f"trace_recompiles={snap['recompiles'] - warm}")


def _mutate_loadgen(col, name, codec, args, rng) -> None:
    """Live-mutation load generator (DESIGN.md §10).

    Base index over the leading ~60% of the collection; the rest is
    the insert pool. ``--mutations`` seeded events (insert / delete /
    update) run in three rounds, each followed by a query burst
    through the micro-batching pipeline and a CHECKPOINT: a fresh
    oracle ``Retriever.build`` over the current live corpus must match
    every burst response byte-for-byte (stable id ``live_ids[pos]`` ↔
    oracle position ``pos``). The final merge runs in the BACKGROUND
    (DESIGN.md §11) with queries streaming through the commit; those
    during-merge responses join the post-merge checkpoint (compaction
    does not change the live corpus, so one oracle covers both sides
    of the flip). Raises AssertionError on any divergence."""
    from repro.serve.api import Retriever, RetrieverConfig
    from repro.serve.pipeline import ServeStats, synthetic_trace
    from repro.serve.segments import MutableRetriever

    fwd = col.fwd
    n_docs = fwd.n_docs
    # budgets exhaustive for the whole mutated corpus: candidate sets
    # must be identical mutable vs oracle for byte parity
    exhaustive = {
        "seismic": dict(cut=16, block_budget=1024, n_probe=1024,
                        n_postings=100000, block_size=8),
        "hnsw": dict(beam=n_docs + 8, iters=n_docs + 8, n_seeds=4, m=8,
                     ef_construction=48),
        "flat": {},
    }
    cfg = RetrieverConfig(engine=name, codec=codec, k=args.k,
                          backend=args.backend or "jnp",
                          n_shards=args.n_shards,
                          params=exhaustive.get(name, {}))
    n_base = max(args.k + 4, (2 * n_docs) // 3)
    pool = list(range(n_base, n_docs))  # un-inserted doc pool
    m = MutableRetriever.create(fwd.slice(0, n_base), cfg)
    pipe = m.pipeline(deadline_us=args.deadline_us,
                      cache_size=args.cache_size)
    Q = np.stack([col.query_dense(i) for i in range(col.n_queries)])

    def mutate_one() -> str:
        live = m.live_ids()
        ops = ["delete", "update"] + (["insert"] if pool else [])
        # never shrink below k + margin (the oracle needs k live docs)
        if len(live) <= args.k + 2:
            ops = ["insert"] if pool else ["update"]
        op = ops[int(rng.integers(len(ops)))]
        if op == "insert":
            take = [pool.pop(0) for _ in range(min(len(pool),
                                                   int(rng.integers(1, 4))))]
            m.insert([fwd.doc(i) for i in take])
        elif op == "delete":
            m.delete(int(live[int(rng.integers(len(live)))]))
        else:  # update-in-place: new content under the same stable id
            victim = int(live[int(rng.integers(len(live)))])
            c, v = fwd.doc(int(rng.integers(n_docs)))
            m.update([(c, v)], ids=[victim])
        return op

    def burst_and_checkpoint(label: str, pre=()) -> int:
        # fresh segment/part plans compile on first touch — warm them
        # out of the burst (same discipline as the --pipeline trace)
        pipe.warm()
        trace = synthetic_trace(rng, max(8, args.requests // 4),
                                Q.shape[0], repeat_frac=args.repeat_frac)
        tickets = []
        for qi in trace:
            pipe.poll()
            tickets.append(pipe.submit(Q[qi]))
        pipe.flush()
        live_fwd, live = m.live_corpus()
        oracle = Retriever.build(live_fwd, cfg.replace(n_shards=1))
        oids, osc = map(np.asarray, oracle.search(Q))
        for qi, t in list(pre) + list(zip(trace, tickets)):
            assert np.array_equal(np.asarray(t.ids), live[oids[qi]]), (
                f"{name}/{codec} {label}: mutable top-k ids diverge from "
                f"the post-mutation oracle (query {qi})")
            assert np.array_equal(np.asarray(t.scores), osc[qi]), (
                f"{name}/{codec} {label}: mutable top-k scores diverge "
                f"from the post-mutation oracle (query {qi})")
        return len(pre) + len(trace)

    served = burst_and_checkpoint("pre-mutation")
    rounds, ops = 3, []
    for r in range(rounds):
        lo = (args.mutations * r) // rounds
        hi = (args.mutations * (r + 1)) // rounds
        ops += [mutate_one() for _ in range(lo, hi)]
        served += burst_and_checkpoint(f"round {r + 1}")
    # background compaction with queries streaming THROUGH the commit
    # (DESIGN.md §11): responses served while the merge builds + flips
    # join the post-merge parity set — compaction must not perturb them
    handle = m.merge(background=True)
    during = []
    while not handle.done() and len(during) < 4 * args.requests:
        pipe.poll()
        qi = int(rng.integers(Q.shape[0]))
        during.append((qi, pipe.submit(Q[qi])))
    pipe.flush()
    handle.result()
    served += burst_and_checkpoint("post-merge", pre=during)
    snap = pipe.snapshot()
    # one epoch invalidation per mutated round + one for the merge
    rounds = min(args.mutations, rounds)
    assert snap["cache_invalidations"] >= rounds + 1, (
        f"{name}/{codec}: ResultCache survived a mutation "
        f"(invalidations={snap['cache_invalidations']})")
    from collections import Counter

    mix = ",".join(f"{k}={v}" for k, v in sorted(Counter(ops).items()))
    print(f"{name:8s} codec={codec:13s} mutation parity OK "
          f"({served} responses, {len(during)} during background merge, "
          f"{args.mutations} mutations [{mix}], "
          f"{len(m.base_ids)} docs after merge, gen={m.generation}) "
          f"[{ServeStats.summary(snap)}]")


def main() -> None:
    from repro.core.layout import available_layouts
    from repro.serve.api import available_engines

    engines_known = available_engines()
    codecs_known = available_layouts()

    ap = argparse.ArgumentParser()
    ap.add_argument("--encoder", choices=["splade", "lilsr"], default="splade")
    ap.add_argument("--engine", choices=[*engines_known, "both", "all"],
                    default="seismic",
                    help="a registered engine, 'both' (seismic+hnsw) or 'all'")
    ap.add_argument("--codec", default="dotvbyte", choices=codecs_known)
    ap.add_argument("--backend", default=None,
                    choices=["jnp", "pallas", "pallas_interpret",
                             "pallas_compiled"],
                    help="candidate-rescoring path: jnp reference or the "
                         "fused kernel registry (DESIGN.md §3); 'pallas' = "
                         "the kernels' default compiled mode, or pin the "
                         "mode explicitly; default jnp, or the artifact's "
                         "saved backend under --load-index")
    ap.add_argument("--compare-codecs", action="store_true",
                    help="sweep every registered serving codec over the same index")
    ap.add_argument("--pipeline", action="store_true",
                    help="online-serving load generator (DESIGN.md §8): "
                         "drive a synthetic traffic trace through the "
                         "micro-batching scheduler, verify parity vs "
                         "direct search, report ServeStats")
    ap.add_argument("--mutate", action="store_true",
                    help="live-mutation load generator (DESIGN.md §10): "
                         "seeded insert/delete/update stream interleaved "
                         "with the query trace over a MutableRetriever, "
                         "per-response parity vs a fresh oracle at every "
                         "checkpoint, then merge + parity again; "
                         "exhaustive budgets — keep --n-docs small")
    ap.add_argument("--mutations", type=int, default=12,
                    help="--mutate stream length (events across 3 rounds)")
    ap.add_argument("--requests", type=int, default=256,
                    help="trace length for --pipeline")
    ap.add_argument("--deadline-us", type=float, default=1000.0,
                    help="--pipeline batch-filling deadline (µs)")
    ap.add_argument("--trace-qps", type=float, default=0.0,
                    help="--pipeline arrival pacing; 0 = closed-loop")
    ap.add_argument("--repeat-frac", type=float, default=0.25,
                    help="--pipeline fraction of requests re-asking a "
                         "head query (result-cache exercise)")
    ap.add_argument("--cache-size", type=int, default=1024,
                    help="--pipeline result-cache capacity (0 disables)")
    ap.add_argument("--save-index", metavar="DIR", default=None,
                    help="save each built index artifact under DIR/<engine>-<codec>/")
    ap.add_argument("--load-index", metavar="DIR", default=None,
                    help="serve from artifacts under DIR instead of building")
    ap.add_argument("--n-shards", type=int, default=1,
                    help="index shards (DESIGN.md §9): > 1 builds/serves "
                         "a sharded artifact tree — per-shard sub-indexes "
                         "over contiguous doc ranges, memory-mapped on "
                         "--load-index, searched over a device mesh when "
                         "devices ≥ shards else via the out-of-core "
                         "resident-shard LRU")
    ap.add_argument("--max-resident", type=int, default=None,
                    help="bound on simultaneously-resident shards "
                         "(sequential sharded path; default: all)")
    ap.add_argument("--no-prefetch", action="store_true",
                    help="disable the background shard prefetcher on "
                         "the sequential sharded path (DESIGN.md §11); "
                         "every rotation then pages in on the hot path")
    ap.add_argument("--n-docs", type=int, default=20000)
    ap.add_argument("--n-queries", type=int, default=64)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--cut", type=int, default=8)
    ap.add_argument("--n-probe", type=int, default=64)
    ap.add_argument("--beam", type=int, default=64, help="HNSW beam width (static ef)")
    ap.add_argument("--iters", type=int, default=64, help="HNSW nodes expanded per query")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.save_index and args.load_index:
        ap.error("--save-index and --load-index are mutually exclusive")
    if args.pipeline and (args.save_index or args.load_index):
        ap.error("--pipeline is a serving-loop mode; run it without "
                 "--save-index/--load-index")
    if args.mutate and (args.pipeline or args.save_index or args.load_index):
        ap.error("--mutate is a serving-loop mode; run it without "
                 "--pipeline/--save-index/--load-index")

    from repro.core.seismic import exact_top_k, recall_at_k
    from repro.data.synthetic import generate_collection, lilsr_config, splade_config
    from repro.serve.api import Retriever, RetrieverConfig, open_retriever

    cfg_fn = splade_config if args.encoder == "splade" else lilsr_config
    print(f"generating {args.n_docs}-doc synthetic {args.encoder} collection…")
    col = generate_collection(cfg_fn(args.n_docs, args.n_queries, args.seed),
                              value_format="f16")
    print(f"(nnz/doc={col.fwd.total_nnz/col.fwd.n_docs:.0f})")

    if args.engine == "both":
        engines = ("seismic", "hnsw")
    elif args.engine == "all":
        engines = tuple(engines_known)
    else:
        engines = (args.engine,)
    codecs = codecs_known if args.compare_codecs else (args.codec,)

    if args.mutate:
        for name in engines:
            for codec in codecs:
                _mutate_loadgen(col, name, codec, args,
                                np.random.default_rng(args.seed + 2))
        return

    search_params = {
        "seismic": dict(cut=args.cut, block_budget=512, n_probe=args.n_probe,
                        n_postings=2000, block_size=64),
        "hnsw": dict(beam=args.beam, iters=args.iters, n_seeds=8,
                     m=16, ef_construction=48),
        "flat": {},
    }

    # host indexes build once per engine; codecs sweep over them
    # (a sharded build constructs per-range sub-indexes instead)
    host_indexes: dict[str, object] = {}
    if not args.load_index and args.n_shards == 1:
        from repro.serve.api import get_engine

        for name in engines:
            impl = get_engine(name)
            if not hasattr(impl, "host_index"):
                continue
            t0 = time.time()
            cfg = RetrieverConfig(engine=name, k=args.k,
                                  params=search_params.get(name, {}))
            host_indexes[name] = impl.host_index(col.fwd, cfg)
            print(f"{name}: host index built in {time.time()-t0:.1f}s")

    Q = np.stack([col.query_dense(i) for i in range(col.n_queries)])
    truth = [exact_top_k(col.fwd, Q[i], args.k)[0] for i in range(col.n_queries)]

    roundtrip_checked = 0
    for name in engines:
        for codec in codecs:
            cfg = RetrieverConfig(engine=name, codec=codec, k=args.k,
                                  backend=args.backend or "jnp",
                                  n_shards=args.n_shards,
                                  params=search_params.get(name, {}))
            backend_overridden = False
            if args.load_index:
                art = pathlib.Path(args.load_index) / f"{name}-{codec}"
                retriever = open_retriever(art)
                if args.max_resident is not None and hasattr(
                    retriever, "max_resident"
                ):
                    retriever.max_resident = args.max_resident
                if args.no_prefetch and hasattr(retriever, "prefetch"):
                    retriever.prefetch = False
                # the backend is a serving choice, not an index format
                # (DESIGN.md §7): an explicit --backend re-wraps the
                # loaded arrays under the requested path (monolithic
                # artifacts; a sharded tree serves its saved backend)
                if (args.backend and args.backend != retriever.cfg.backend
                        and not hasattr(retriever, "shards")):
                    backend_overridden = True
                    retriever = Retriever(
                        retriever.cfg.replace(backend=args.backend),
                        retriever.arrays,
                        n_docs=retriever.n_docs,
                        dim=retriever.dim,
                        value_scale=retriever.value_scale,
                        value_format=retriever.value_format,
                    )
            elif name in host_indexes:
                retriever = Retriever.from_host_index(host_indexes[name], cfg)
            else:
                retriever = Retriever.build(col.fwd, cfg)
                if args.max_resident is not None and hasattr(
                    retriever, "max_resident"
                ):
                    retriever.max_resident = args.max_resident
                if args.no_prefetch and hasattr(retriever, "prefetch"):
                    retriever.prefetch = False
            if args.pipeline:
                rng = np.random.default_rng(args.seed + 1)
                summary = _pipeline_loadgen(retriever, Q, args, rng)
                print(f"{name:8s} codec={codec:13s} pipeline parity OK "
                      f"({args.requests} requests) [{summary}]")
                continue
            ids, scores = retriever.search(Q)  # compile
            t0 = time.time()
            ids, scores = retriever.search(Q)
            ids = np.asarray(ids)
            dt = time.time() - t0

            recs = [recall_at_k(truth[i], ids[i]) for i in range(col.n_queries)]
            extra = ""
            if args.save_index:
                art = pathlib.Path(args.save_index) / f"{name}-{codec}"
                retriever.save(art)
                np.savez(art / "topk.npz", ids=ids, scores=np.asarray(scores))
                extra = f" saved→{art}"
            if args.load_index:
                ref = pathlib.Path(args.load_index) / f"{name}-{codec}" / "topk.npz"
                if ref.is_file():
                    with np.load(ref) as npz:
                        assert np.array_equal(npz["ids"], ids), (
                            f"{name}/{codec}: reopened top-k ids differ from the "
                            f"build-time run")
                        if backend_overridden:
                            # cross-backend scores agree to rounding, not bytes
                            assert np.allclose(npz["scores"], np.asarray(scores),
                                               rtol=1e-5, atol=1e-6), (
                                f"{name}/{codec}: cross-backend top-k scores differ")
                            extra = " roundtrip=ids-identical (backend overridden)"
                        else:
                            assert np.array_equal(npz["scores"], np.asarray(scores)), (
                                f"{name}/{codec}: reopened top-k scores differ")
                            extra = " roundtrip=byte-identical"
                    roundtrip_checked += 1
            _report(name, codec, args.k, recs, 1e6 * dt / col.n_queries, col, extra)
    if args.load_index:
        print(f"serve-roundtrip OK: {roundtrip_checked} artifact(s) verified "
              f"against their build-time top-k")


if __name__ == "__main__":
    main()
