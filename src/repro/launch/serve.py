"""Serving launcher: build an ANNS index over a synthetic MsMarco-like
collection and serve batched queries through the static TPU engines.

``python -m repro.launch.serve --engine seismic --codec dotvbyte
--n-docs 20000 --n-queries 64`` builds the collection + index, runs
batched searches, and reports recall@10 + latency; ``--engine hnsw`` serves the
same collection through the graph engine (DESIGN.md §5) instead, and
``--engine both`` compares them head to head. ``--compare-codecs``
sweeps every component codec (the quickstart of the serving stack).

The HNSW host build is a few ms per document — prefer ``--n-docs``
in the low thousands when sweeping the graph engine interactively.
"""

from __future__ import annotations

import argparse
import time

import jax.numpy as jnp
import numpy as np


ENGINE_CODECS = ("uncompressed", "dotvbyte", "streamvbyte")


def _report(name, codec, k, recs, dt_us, col, extra=""):
    comp_bytes = col.fwd.storage_bytes(codec)["components"]
    raw_bytes = col.fwd.storage_bytes("uncompressed")["components"]
    print(
        f"{name:8s} codec={codec:13s} recall@{k}={np.mean(recs):.3f} "
        f"latency={dt_us:7.0f}µs/q (CPU) "
        f"components={comp_bytes/2**20:.1f}MiB ({8*comp_bytes/col.fwd.total_nnz:.1f} "
        f"bits/comp vs 16.0 raw, {100*(1-comp_bytes/raw_bytes):.0f}% saved){extra}"
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--encoder", choices=["splade", "lilsr"], default="splade")
    ap.add_argument("--engine", choices=["seismic", "hnsw", "both"], default="seismic")
    ap.add_argument("--codec", default="dotvbyte", choices=list(ENGINE_CODECS))
    ap.add_argument("--compare-codecs", action="store_true",
                    help="sweep every engine codec over the same index")
    ap.add_argument("--n-docs", type=int, default=20000)
    ap.add_argument("--n-queries", type=int, default=64)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--cut", type=int, default=8)
    ap.add_argument("--n-probe", type=int, default=64)
    ap.add_argument("--beam", type=int, default=64, help="HNSW beam width (static ef)")
    ap.add_argument("--iters", type=int, default=64, help="HNSW nodes expanded per query")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from repro.core.hnsw import HNSWIndex, HNSWParams
    from repro.core.seismic import SeismicIndex, SeismicParams, exact_top_k, recall_at_k
    from repro.data.synthetic import generate_collection, lilsr_config, splade_config
    from repro.serve.engine import BatchedSeismic, EngineConfig
    from repro.serve.graph_engine import BatchedHNSW, GraphConfig

    cfg_fn = splade_config if args.encoder == "splade" else lilsr_config
    print(f"generating {args.n_docs}-doc synthetic {args.encoder} collection…")
    col = generate_collection(cfg_fn(args.n_docs, args.n_queries, args.seed),
                              value_format="f16")
    print(f"(nnz/doc={col.fwd.total_nnz/col.fwd.n_docs:.0f})")

    engines = ("seismic", "hnsw") if args.engine == "both" else (args.engine,)
    indexes = {}
    if "seismic" in engines:
        t0 = time.time()
        indexes["seismic"] = SeismicIndex.build(
            col.fwd, SeismicParams(n_postings=2000, block_size=64)
        )
        print(f"Seismic: {indexes['seismic'].n_blocks} blocks in {time.time()-t0:.1f}s")
    if "hnsw" in engines:
        t0 = time.time()
        indexes["hnsw"] = HNSWIndex.build(col.fwd, HNSWParams(m=16, ef_construction=48))
        print(f"HNSW: {indexes['hnsw'].n_edges} edges in {time.time()-t0:.1f}s")

    Q = np.stack([col.query_dense(i) for i in range(col.n_queries)])
    truth = [exact_top_k(col.fwd, Q[i], args.k)[0] for i in range(col.n_queries)]
    codecs = ENGINE_CODECS if args.compare_codecs else (args.codec,)
    for name in engines:
        for codec in codecs:
            if name == "seismic":
                engine = BatchedSeismic(
                    indexes[name],
                    EngineConfig(cut=args.cut, block_budget=512, n_probe=args.n_probe,
                                 k=args.k, codec=codec),
                )
            else:
                engine = BatchedHNSW(
                    indexes[name],
                    GraphConfig(beam=args.beam, iters=args.iters, n_seeds=8,
                                k=args.k, codec=codec),
                )
            ids, scores = engine.search_batch(jnp.asarray(Q))  # compile
            t0 = time.time()
            ids, scores = engine.search_batch(jnp.asarray(Q))
            ids = np.asarray(ids)
            dt = time.time() - t0

            recs = [recall_at_k(truth[i], ids[i]) for i in range(col.n_queries)]
            _report(name, codec, args.k, recs, 1e6 * dt / col.n_queries, col)


if __name__ == "__main__":
    main()
