"""Training launcher: ``python -m repro.launch.train --arch <id> …``.

Real-run counterpart of the dry-run: builds the arch's train cell on the
requested mesh (or single-host CPU for local runs), initialises params,
and drives the fault-tolerant Runner (checkpoint/restart/elastic —
repro.train.elastic) over a deterministic synthetic data stream.

On a real TPU fleet this process is launched once per host by the
cluster scheduler with ``jax.distributed.initialize()`` (flag
``--distributed``); everything else — mesh, shardings, checkpoint
commit protocol — is identical to what the dry-run proved.
"""

from __future__ import annotations

import argparse

import jax
import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--checkpoint-dir", default="checkpoints")
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--smoke", action="store_true",
                    help="use the arch's reduced config (CPU-sized)")
    ap.add_argument("--distributed", action="store_true",
                    help="multi-host: call jax.distributed.initialize()")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.distributed:
        jax.distributed.initialize()

    from repro.configs import get_arch
    from repro.train.elastic import Runner, RunnerConfig
    from repro.train.optimizer import make_optimizer
    from repro.train.train_step import init_train_state, make_train_step

    arch = get_arch(args.arch)
    if not args.smoke:
        raise SystemExit(
            "full-scale training needs a TPU fleet; run with --smoke for the "
            "CPU-sized config (the dry-run validates the full-scale graph)"
        )

    if arch.family == "lm":
        import jax.numpy as jnp

        from repro.models import transformer as tf_m

        cfg = arch.smoke_cfg
        key = jax.random.PRNGKey(args.seed)
        params = tf_m.init_params(key, cfg)
        oinit, oupd = make_optimizer(arch.optimizer)
        step = jax.jit(make_train_step(
            lambda p, b: tf_m.lm_loss(p, cfg, b["tokens"], b["labels"]), oupd))

        def batch_fn(i):
            kk = jax.random.fold_in(key, i)
            toks = jax.random.randint(kk, (8, 33), 0, cfg.vocab)
            return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    elif arch.family == "recsys":
        cfg = arch.smoke_cfg
        key = jax.random.PRNGKey(args.seed)
        init_fn, loss_fn_raw, _ = arch._fns(cfg)
        params = init_fn(key, cfg)
        oinit, oupd = make_optimizer(arch.optimizer)
        step = jax.jit(make_train_step(lambda p, b: loss_fn_raw(p, cfg, b), oupd))

        def batch_fn(i):
            return arch._smoke_batch(cfg, 32, jax.random.fold_in(key, i))

    else:
        raise SystemExit(f"--smoke training loop not wired for family {arch.family}")

    runner = Runner(
        RunnerConfig(
            total_steps=args.steps,
            checkpoint_dir=args.checkpoint_dir,
            checkpoint_every=args.checkpoint_every,
        ),
        step,
        batch_fn,
        init_train_state(params, oinit),
    )
    state, hist = runner.run()
    losses = [h["loss"] for h in hist]
    print(f"trained {args.arch} {len(hist)} steps: loss {losses[0]:.4f} → {losses[-1]:.4f}"
          f" (restarts={runner.restarts})")


if __name__ == "__main__":
    main()
