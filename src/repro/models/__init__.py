"""Model zoo: the 10 assigned architectures + the sparse encoder.

LM family  : transformer.py (dense + MoE via moe.py)
GNN        : gnn.py (GAT, segment-op message passing, neighbour sampler)
RecSys     : recsys.py (DeepFM, DCN-v2, SASRec, DIN; EmbeddingBag substrate)
Retrieval  : sparse_encoder.py (SPLADE-style producer of sparse embeddings)
"""

from . import common, gnn, moe, recsys, sparse_encoder, transformer  # noqa: F401
