"""Decoder-only LM family: dense (Qwen3/Yi/DeepSeek-Coder) and MoE
(OLMoE, Kimi-K2) in one implementation.

Design points (see DESIGN.md §4):

* **Stacked-layer params + ``lax.scan``** — compile time is constant in
  depth (the 61-layer/1T-param Kimi config lowers in seconds on one CPU
  core), and remat policy applies per scan step.
* **GQA attention** with RoPE and optional per-head QK-RMSNorm (Qwen3).
* **Attention impls**: ``full`` (XLA-fused, fine ≤ 4k) and ``chunked``
  (flash-style online-softmax scan over KV chunks — O(chunk²) memory,
  used for 32k prefill).
* **Decode path** (``decode_step``) consumes a static-shape KV cache and
  one new token; sequence-sharded flash-decoding lives in
  ``repro.dist.collectives`` and is wired in by the serve step.
* **MoE** layers replace the dense FFN when ``cfg.moe`` is set
  (capacity-based dispatch, expert-parallel over the ``model`` axis).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from .common import apply_rope, dense_init, embed_init, rms_norm, rope_freqs, shard_hint
from .moe import MoEConfig, moe_apply, moe_init

__all__ = ["TransformerConfig", "init_params", "forward", "lm_loss", "decode_step", "init_kv_cache"]


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int | None = None
    qk_norm: bool = False
    rope_theta: float = 10000.0
    moe: MoEConfig | None = None
    attention_impl: str = "full"  # "full" | "chunked"
    attention_chunk: int = 1024
    tie_embeddings: bool = False
    dtype: Any = jnp.bfloat16
    remat: bool = True
    z_loss: float = 1e-4
    # ZeRO-3 just-in-time weight gathering (DESIGN.md §4 / §Perf): wins
    # for token-heavy steps (train, prefill); LMArch turns it OFF for
    # decode cells, where per-step weight traffic would dwarf the tiny
    # activations (weights go TP-only there when they fit).
    jit_weight_gather: bool = True

    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def init_params(key, cfg: TransformerConfig):
    L, D, V = cfg.n_layers, cfg.d_model, cfg.vocab
    H, Hk, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    keys = jax.random.split(key, 8)
    dt = cfg.dtype
    layers = {
        "attn_norm": jnp.ones((L, D), dt),
        "ffn_norm": jnp.ones((L, D), dt),
        "wq": _stacked_dense(keys[1], L, D, H * dh, dt),
        "wk": _stacked_dense(keys[2], L, D, Hk * dh, dt),
        "wv": _stacked_dense(keys[3], L, D, Hk * dh, dt),
        "wo": _stacked_dense(keys[4], L, H * dh, D, dt),
    }
    if cfg.qk_norm:
        layers["q_norm"] = jnp.ones((L, dh), dt)
        layers["k_norm"] = jnp.ones((L, dh), dt)
    if cfg.moe is None:
        layers["w_gate"] = _stacked_dense(keys[5], L, D, cfg.d_ff, dt)
        layers["w_up"] = _stacked_dense(keys[6], L, D, cfg.d_ff, dt)
        layers["w_down"] = _stacked_dense(keys[7], L, cfg.d_ff, D, dt)
    else:
        moe_keys = jax.random.split(keys[5], L)
        moe_stacked = [moe_init(k, cfg.moe, dt) for k in moe_keys]
        layers["moe"] = jax.tree.map(lambda *xs: jnp.stack(xs), *moe_stacked)

    params = {
        "embed": embed_init(jax.random.fold_in(key, 101), V, D, dt),
        "layers": layers,
        "final_norm": jnp.ones((D,), dt),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(jax.random.fold_in(key, 102), D, V, dt)
    return params


def _stacked_dense(key, L, d_in, d_out, dtype):
    s = (2.0 / (d_in + d_out)) ** 0.5
    return (jax.random.normal(key, (L, d_in, d_out)) * s).astype(dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


def _gqa_scores_full(q, k, v, causal: bool, q_offset):
    """q [B,Sq,H,dh], k/v [B,Sk,Hk,dh] → [B,Sq,H,dh]. Full materialised.

    KV heads are broadcast to the full H so every activation keeps a
    TP-shardable head dim (H % mesh.model == 0 even when Hk < mesh.model
    — the Megatron recipe for GQA with tp > kv_heads: replicate KV
    inside each group). shard_hint pins scores to (batch, model) so the
    [B,H,Sq,Sk] transient never replicates across TP (DESIGN.md §4)."""
    B, Sq, H, dh = q.shape
    Sk, Hk = k.shape[1], k.shape[2]
    G = H // Hk
    k = jnp.repeat(k, G, axis=2)  # [B,Sk,H,dh]
    v = jnp.repeat(v, G, axis=2)
    q = shard_hint(q, "batch", None, "model", None)
    k = shard_hint(k, "batch", None, "model", None)
    v = shard_hint(v, "batch", None, "model", None)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32)
    scores = shard_hint(scores, "batch", "model", None, None)
    scores = scores / jnp.sqrt(jnp.float32(dh))
    if causal:
        qpos = q_offset + jnp.arange(Sq)
        kpos = jnp.arange(Sk)
        mask = qpos[:, None] >= kpos[None, :]
        scores = jnp.where(mask[None, None], scores, -1e30)
    attn = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", attn, v)
    return out


def _gqa_scores_chunked(q, k, v, causal: bool, q_offset, chunk: int):
    """Flash-style online softmax over KV chunks (pure JAX, O(chunk²) mem).

    Same flat-head layout + TP sharding hints as the full impl."""
    B, Sq, H, dh = q.shape
    Sk, Hk = k.shape[1], k.shape[2]
    G = H // Hk
    k = jnp.repeat(k, G, axis=2)
    v = jnp.repeat(v, G, axis=2)
    n_chunks = (Sk + chunk - 1) // chunk
    pad = n_chunks * chunk - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    q = shard_hint(q, "batch", None, "model", None)
    kc = k.reshape(B, n_chunks, chunk, H, dh).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n_chunks, chunk, H, dh).transpose(1, 0, 2, 3, 4)
    kc = shard_hint(kc, None, "batch", None, "model", None)
    vc = shard_hint(vc, None, "batch", None, "model", None)
    qpos = q_offset + jnp.arange(Sq)

    def body(carry, xs):
        m, l, acc = carry  # running max, denom, numerator
        kb, vb, c_idx = xs
        kpos = c_idx * chunk + jnp.arange(chunk)
        s = jnp.einsum("bqhd,bkhd->bhqk", q, kb).astype(jnp.float32)
        s = shard_hint(s, "batch", "model", None, None)
        s = s / jnp.sqrt(jnp.float32(dh))
        valid = kpos[None, :] < Sk
        if causal:
            valid = valid & (qpos[:, None] >= kpos[None, :])
        s = jnp.where(valid[None, None], s, -1e30)
        m_new = jnp.maximum(m, s.max(axis=-1))
        scale = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * scale + p.sum(axis=-1)
        acc_new = acc * scale[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p.astype(q.dtype), vb
        ).astype(jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, H, Sq), -1e30, jnp.float32)
    l0 = jnp.zeros((B, H, Sq), jnp.float32)
    a0 = jnp.zeros((B, H, Sq, dh), jnp.float32)
    a0 = shard_hint(a0, "batch", "model", None, None)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0), (kc, vc, jnp.arange(n_chunks))
    )
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 2, 1, 3).astype(q.dtype)


def attention(q, k, v, *, causal: bool, q_offset=0, impl: str = "full", chunk: int = 1024):
    if impl == "chunked":
        return _gqa_scores_chunked(q, k, v, causal, q_offset, chunk)
    return _gqa_scores_full(q, k, v, causal, q_offset)


# ---------------------------------------------------------------------------
# layer + forward
# ---------------------------------------------------------------------------


def _attn_block(lp, cfg: TransformerConfig, x, positions, inv_freq, kv=None):
    """One attention block. kv=None → self-attn over x (training/prefill);
    kv=(k_cache, v_cache, length) → decode against the cache.

    Weights are FSDP-sharded on d_model for STORAGE; ``shard_hint(w,
    None, "model")`` gathers them just-in-time (ZeRO-3) so matmuls never
    partial-sum activations over the data axis — per-layer all-gather of
    ~MBs of weights instead of all-reduce of ~GBs of activations
    (EXPERIMENTS.md §Perf, kimi-k2 iteration)."""
    B, S, D = x.shape
    H, Hk, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    gather = (lambda w, *s_: shard_hint(w, *s_)) if cfg.jit_weight_gather else (lambda w, *s_: w)
    h = rms_norm(x, lp["attn_norm"])
    q = (h @ gather(lp["wq"], None, "model")).reshape(B, S, H, dh)
    k = (h @ gather(lp["wk"], None, "model")).reshape(B, S, Hk, dh)
    v = (h @ gather(lp["wv"], None, "model")).reshape(B, S, Hk, dh)
    if cfg.qk_norm:
        q = rms_norm(q, lp["q_norm"])
        k = rms_norm(k, lp["k_norm"])
    q = apply_rope(q, positions, inv_freq)
    k = apply_rope(k, positions, inv_freq)
    if kv is None:
        out = attention(
            q, k, v, causal=True, q_offset=0, impl=cfg.attention_impl,
            chunk=cfg.attention_chunk,
        )
        new_kv = (k, v)
    else:
        out, new_kv = kv(q, k, v)
    return out.reshape(B, S, H * dh) @ gather(lp["wo"], "model", None), new_kv


def _ffn_block(lp, cfg: TransformerConfig, x):
    gather = (lambda w, *s_: shard_hint(w, *s_)) if cfg.jit_weight_gather else (lambda w, *s_: w)
    h = rms_norm(x, lp["ffn_norm"])
    if cfg.moe is None:
        y = jax.nn.silu(h @ gather(lp["w_gate"], None, "model")) * (
            h @ gather(lp["w_up"], None, "model")
        )
        return y @ gather(lp["w_down"], "model", None), jnp.float32(0.0)
    B, S, D = h.shape
    y, aux = moe_apply(lp["moe"], cfg.moe, h.reshape(B * S, D))
    return y.reshape(B, S, D), aux["load_balance_loss"]


def forward(params, cfg: TransformerConfig, tokens: jnp.ndarray, *, collect_kv: bool = False):
    """tokens [B, S] → (logits [B, S, V], aux dict). Training/prefill path.

    collect_kv=True additionally returns the per-layer K/V stacks —
    the prefill path's KV-cache product ([L, B, S, Hk, dh])."""
    B, S = tokens.shape
    x = params["embed"][tokens]
    positions = jnp.arange(S)[None, :]
    inv_freq = rope_freqs(cfg.head_dim, cfg.rope_theta)

    def layer(x, lp):
        a, kv = _attn_block(lp, cfg, x, positions, inv_freq)
        x = x + a
        f, aux = _ffn_block(lp, cfg, x)
        out = (aux, kv) if collect_kv else aux
        return x + f, out

    if cfg.remat:
        layer = jax.checkpoint(layer)  # noqa: E731 — remat per scan step

    x, ys = jax.lax.scan(layer, x, params["layers"])
    x = rms_norm(x, params["final_norm"])
    head = params.get("lm_head")
    gather = (lambda w, *s_: shard_hint(w, *s_)) if cfg.jit_weight_gather else (lambda w, *s_: w)
    if head is not None:
        logits = x @ gather(head, None, "model")
    else:
        logits = x @ gather(params["embed"], "model", None).T
    if collect_kv:
        aux, (ks, vs) = ys
        return logits, {"load_balance_loss": aux.mean(), "kv_cache": {"k": ks, "v": vs}}
    return logits, {"load_balance_loss": ys.mean()}


def lm_loss(params, cfg: TransformerConfig, tokens, labels):
    """Next-token cross entropy with z-loss; labels -100 are masked."""
    logits, aux = forward(params, cfg, tokens)
    logits = logits.astype(jnp.float32)
    mask = labels >= 0
    labels_safe = jnp.maximum(labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels_safe[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask
    z = cfg.z_loss * (logz**2) * mask
    denom = jnp.maximum(mask.sum(), 1)
    loss = (nll.sum() + z.sum()) / denom
    if cfg.moe is not None:
        loss = loss + 0.01 * aux["load_balance_loss"]
    return loss, {"nll": nll.sum() / denom, "aux": aux}


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------


def init_kv_cache(cfg: TransformerConfig, batch: int, max_len: int, dtype=None):
    dt = dtype or cfg.dtype
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}


def decode_step(params, cfg: TransformerConfig, cache, tokens, lengths, attn_fn=None):
    """One decode step.

    tokens [B, 1] new token ids; lengths [B] current cache fill (the new
    token is written at position ``lengths``). Returns (logits [B, V],
    new_cache). ``attn_fn(q, k_cache, v_cache, lengths)`` may be injected
    by the serve step to run sequence-sharded flash decoding
    (repro.dist.collectives.flash_decode_shardmap); default is the local
    masked-softmax reference.
    """
    B = tokens.shape[0]
    H, Hk, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    x = params["embed"][tokens]  # [B, 1, D]
    inv_freq = rope_freqs(cfg.head_dim, cfg.rope_theta)
    positions = lengths[:, None]
    attn_impl = attn_fn or _decode_attention_ref

    def layer(x, xs):
        lp, kc, vc = xs
        h = rms_norm(x, lp["attn_norm"])
        q = (h @ lp["wq"]).reshape(B, 1, H, dh)
        k = (h @ lp["wk"]).reshape(B, 1, Hk, dh)
        v = (h @ lp["wv"]).reshape(B, 1, Hk, dh)
        if cfg.qk_norm:
            q = rms_norm(q, lp["q_norm"])
            k = rms_norm(k, lp["k_norm"])
        q = apply_rope(q, positions, inv_freq)
        k = apply_rope(k, positions, inv_freq)
        # write new kv at position `lengths` (per-batch dynamic index)
        kc = _cache_write(kc, k, lengths)
        vc = _cache_write(vc, v, lengths)
        a = attn_impl(q, kc, vc, lengths + 1)
        x = x + a.reshape(B, 1, H * dh) @ lp["wo"]
        f, _ = _ffn_block(lp, cfg, x)
        return x + f, (kc, vc)

    x, (new_k, new_v) = jax.lax.scan(layer, x, (params["layers"], cache["k"], cache["v"]))
    x = rms_norm(x, params["final_norm"])
    head = params.get("lm_head")
    logits = x[:, 0, :] @ head if head is not None else x[:, 0, :] @ params["embed"].T
    return logits, {"k": new_k, "v": new_v}


def _cache_write(cache, kv_new, lengths):
    """cache [B,S,Hk,dh]; kv_new [B,1,Hk,dh]; write at per-batch position.

    dynamic_update_slice (not one-hot blending) so the cache write is
    O(1) positions of HBM traffic per step, not O(S)."""

    def one(c, kn, l):
        return jax.lax.dynamic_update_slice(c, kn.astype(c.dtype), (l, 0, 0))

    return jax.vmap(one)(cache, kv_new, lengths)


def _decode_attention_ref(q, k_cache, v_cache, valid_len):
    """Reference masked decode attention. q [B,1,H,dh], caches [B,S,Hk,dh]."""
    B, _, H, dh = q.shape
    S, Hk = k_cache.shape[1], k_cache.shape[2]
    G = H // Hk
    qg = q.reshape(B, Hk, G, dh)
    s = jnp.einsum("bhgd,bkhd->bhgk", qg, k_cache).astype(jnp.float32)
    s = s / jnp.sqrt(jnp.float32(dh))
    mask = jnp.arange(S)[None, :] < valid_len[:, None]
    s = jnp.where(mask[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhgk,bkhd->bhgd", p, v_cache)
    return out.reshape(B, 1, H, dh)
