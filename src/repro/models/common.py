"""Shared neural building blocks (plain-pytree params, no flax).

Every init function takes a jax PRNG key and returns a dict pytree; every
apply function is pure. Initialisation follows the conventions of the
respective papers (truncated-normal embeddings, scaled Xavier for
projections, zero-init output layers where standard).
"""

from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

__all__ = [
    "dense_init",
    "embed_init",
    "rms_norm",
    "layer_norm",
    "mlp_init",
    "mlp_apply",
    "rope_freqs",
    "apply_rope",
    "count_params",
]


def dense_init(key, d_in: int, d_out: int, dtype=jnp.float32, scale: float | None = None):
    s = scale if scale is not None else (2.0 / (d_in + d_out)) ** 0.5
    return (jax.random.normal(key, (d_in, d_out)) * s).astype(dtype)


def embed_init(key, vocab: int, dim: int, dtype=jnp.float32):
    return (jax.random.normal(key, (vocab, dim)) * 0.02).astype(dtype)


def rms_norm(x: jnp.ndarray, weight: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * weight.astype(jnp.float32)).astype(dt)


def layer_norm(x, weight, bias, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * weight + bias).astype(dt)


def mlp_init(key, sizes: Sequence[int], dtype=jnp.float32):
    """Plain MLP: weights + biases for len(sizes)-1 layers."""
    keys = jax.random.split(key, len(sizes) - 1)
    return {
        "w": [dense_init(k, a, b, dtype) for k, a, b in zip(keys, sizes[:-1], sizes[1:])],
        "b": [jnp.zeros((b,), dtype) for b in sizes[1:]],
    }


def mlp_apply(params, x, activation=jax.nn.relu, final_activation=None):
    n = len(params["w"])
    for i, (w, b) in enumerate(zip(params["w"], params["b"])):
        x = x @ w + b
        if i < n - 1:
            x = activation(x)
        elif final_activation is not None:
            x = final_activation(x)
    return x


def rope_freqs(d_head: int, theta: float = 10000.0) -> jnp.ndarray:
    """Inverse frequencies for rotary embeddings. [d_head // 2] f32."""
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, inv_freq: jnp.ndarray):
    """x [..., S, H, Dh]; positions [..., S] → rotated x (paired halves)."""
    angles = positions[..., :, None].astype(jnp.float32) * inv_freq  # [..., S, Dh/2]
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x, 2, axis=-1)
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


def count_params(params) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(params))


def shard_hint(x: jnp.ndarray, *logical: str | None) -> jnp.ndarray:
    """Logical activation-sharding constraint, no-op off-mesh.

    Entries per dim: "batch" → the data-parallel axes present on the
    current mesh (("pod","data") / ("data",)), "model" → the model axis,
    None → replicated. Silently skips when the axis is absent or the dim
    is not divisible — so model code stays mesh-agnostic and smoke tests
    on 1 CPU device are untouched."""
    from jax.sharding import PartitionSpec as P

    try:
        mesh = jax.sharding.get_abstract_mesh()
        axis_names = tuple(mesh.axis_names) if mesh is not None else ()
    except Exception:  # noqa: BLE001 — no mesh context
        return x
    if not axis_names:
        return x

    spec = []
    for dim, name in enumerate(logical):
        if name == "batch":
            axes = tuple(a for a in ("pod", "data") if a in axis_names)
        elif name == "model":
            axes = ("model",) if "model" in axis_names else ()
        else:
            axes = ()
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        if axes and x.shape[dim] % size == 0:
            spec.append(axes if len(axes) > 1 else axes[0])
        else:
            spec.append(None)
    if all(s is None for s in spec):
        return x
    return jax.lax.with_sharding_constraint(x, P(*spec))
