"""SPLADE-style learned sparse encoder (Formal et al., SIGIR 2022).

The model that *produces* the embeddings the paper's forward index
stores: a bidirectional transformer encoder whose MLM head is pooled as

    s = max_over_tokens( log(1 + relu(logits)) )        [vocab]

giving a sparse non-negative vocabulary-grounded vector. Trained with an
in-batch-negative contrastive loss plus SPLADE's FLOPS regulariser
(which drives sparsity, i.e. the very nnz statistics the paper's
compression study depends on).

Used by ``examples/train_sparse_encoder.py`` as the end-to-end driver:
train (~100M params, a few hundred steps) → encode a corpus → build the
Seismic index with DotVByte compression → measure recall.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .common import dense_init, embed_init, rms_norm
from .transformer import attention

__all__ = [
    "SparseEncoderConfig",
    "encoder_init",
    "encode",
    "contrastive_loss",
    "fake_quantize",
    "export_quant_clip",
]


@dataclasses.dataclass(frozen=True)
class SparseEncoderConfig:
    name: str = "sparse-encoder"
    vocab: int = 30522
    n_layers: int = 8
    d_model: int = 512
    n_heads: int = 8
    d_ff: int = 2048
    max_len: int = 128
    flops_lambda: float = 1e-3
    temperature: float = 0.05
    dtype: object = jnp.float32
    #: quantization-aware training (DESIGN.md §12): fake-quantize the
    #: pooled activations with a learnable PACT clip + straight-through
    #: rounding, so the encoder trains against the same value grid the
    #: u8_sq/u4_sq serving codecs store
    quantize: bool = False
    quant_bits: int = 8
    quant_clip_init: float = 4.0  # log1p activations rarely exceed this

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads


def encoder_init(key, cfg: SparseEncoderConfig):
    L, D, V = cfg.n_layers, cfg.d_model, cfg.vocab
    keys = jax.random.split(key, 9)

    def sd(k, a, b):
        return (jax.random.normal(k, (L, a, b)) * (2.0 / (a + b)) ** 0.5).astype(cfg.dtype)

    return {
        "embed": embed_init(keys[0], V, D, cfg.dtype),
        "pos": embed_init(keys[1], cfg.max_len, D, cfg.dtype),
        "layers": {
            "attn_norm": jnp.ones((L, D), cfg.dtype),
            "ffn_norm": jnp.ones((L, D), cfg.dtype),
            "wq": sd(keys[2], D, D),
            "wk": sd(keys[3], D, D),
            "wv": sd(keys[4], D, D),
            "wo": sd(keys[5], D, D),
            "w_up": sd(keys[6], D, cfg.d_ff),
            "w_down": sd(keys[7], cfg.d_ff, D),
        },
        "final_norm": jnp.ones((D,), cfg.dtype),
        "mlm_bias": jnp.zeros((V,), cfg.dtype),  # head tied to embed
        **(
            {"quant_hi": jnp.float32(cfg.quant_clip_init)}
            if cfg.quantize
            else {}
        ),
    }


def fake_quantize(acts, hi, bits: int):
    """PACT fake-quant with a straight-through estimator.

    Forward: clip to ``[0, hi]``, snap to the ``2**bits - 1``-level
    grid (exactly the u8_sq/u4_sq serving grid with ``lo = 0``,
    DESIGN.md §12). Backward: the rounding is identity (STE), so
    gradients flow to the activations inside the clip and to ``hi``
    through the clip boundary — PACT's learnable-range rule."""
    hi = jnp.maximum(hi, 1e-6)  # keep the grid step finite
    maxcode = (1 << bits) - 1
    clipped = jnp.clip(acts, 0.0, hi)
    step = hi / maxcode
    q = jnp.round(clipped / step) * step
    return clipped + jax.lax.stop_gradient(q - clipped)


def export_quant_clip(params, cfg: SparseEncoderConfig, storage_scale: float = 1.0):
    """Trained quantizer → the pack-time clip override (DESIGN.md §12).

    Returns the ``(lo, hi)`` pair for ``layout.pack_rows(...,
    vq_clip=...)`` in STORAGE units: the learned PACT range is in TRUE
    activation units, and quantized rows store codes over storage-unit
    values (``raw · storage_scale⁻¹``), so the range divides by the
    collection's ``value_format.scale``."""
    if "quant_hi" not in params:
        raise ValueError(
            "params carry no quantizer; train with cfg.quantize=True"
        )
    hi = float(params["quant_hi"]) / float(storage_scale)
    return (0.0, hi)


def encode(params, cfg: SparseEncoderConfig, tokens, mask):
    """tokens i32 [B, S], mask bool [B, S] → sparse embeddings [B, vocab]."""
    B, S = tokens.shape
    H, dh = cfg.n_heads, cfg.head_dim
    x = params["embed"][tokens] + params["pos"][None, :S]

    def layer(x, lp):
        h = rms_norm(x, lp["attn_norm"])
        q = (h @ lp["wq"]).reshape(B, S, H, dh)
        k = (h @ lp["wk"]).reshape(B, S, H, dh)
        v = (h @ lp["wv"]).reshape(B, S, H, dh)
        a = attention(q, k, v, causal=False)  # bidirectional
        x = x + a.reshape(B, S, H * dh) @ lp["wo"]
        h = rms_norm(x, lp["ffn_norm"])
        x = x + jax.nn.gelu(h @ lp["w_up"]) @ lp["w_down"]
        return x, None

    x, _ = jax.lax.scan(layer, x, params["layers"])
    x = rms_norm(x, params["final_norm"])
    logits = x @ params["embed"].T + params["mlm_bias"]  # [B, S, V]
    acts = jnp.log1p(jax.nn.relu(logits.astype(jnp.float32)))
    acts = jnp.where(mask[..., None], acts, 0.0)
    pooled = acts.max(axis=1)  # SPLADE-max pooling → [B, V]
    if cfg.quantize:
        pooled = fake_quantize(pooled, params["quant_hi"], cfg.quant_bits)
    return pooled


def contrastive_loss(params, cfg: SparseEncoderConfig, batch):
    """In-batch negatives: query i ↔ doc i positive, others negative."""
    q = encode(params, cfg, batch["q_tokens"], batch["q_mask"])  # [B, V]
    d = encode(params, cfg, batch["d_tokens"], batch["d_mask"])  # [B, V]
    scores = (q @ d.T) / cfg.temperature  # [B, B]
    labels = jnp.arange(q.shape[0])
    logz = jax.nn.logsumexp(scores, axis=-1)
    nll = (logz - jnp.take_along_axis(scores, labels[:, None], axis=1)[:, 0]).mean()
    # SPLADE FLOPS regulariser: (mean activation per vocab dim)², summed
    flops = (jnp.square(q.mean(axis=0)).sum() + jnp.square(d.mean(axis=0)).sum())
    acc = (scores.argmax(-1) == labels).mean()
    nnz_q = (q > 0).sum(-1).mean()
    nnz_d = (d > 0).sum(-1).mean()
    return nll + cfg.flops_lambda * flops, {
        "contrastive_acc": acc,
        "nnz_query": nnz_q,
        "nnz_doc": nnz_d,
    }
