"""SPLADE-style learned sparse encoder (Formal et al., SIGIR 2022).

The model that *produces* the embeddings the paper's forward index
stores: a bidirectional transformer encoder whose MLM head is pooled as

    s = max_over_tokens( log(1 + relu(logits)) )        [vocab]

giving a sparse non-negative vocabulary-grounded vector. Trained with an
in-batch-negative contrastive loss plus SPLADE's FLOPS regulariser
(which drives sparsity, i.e. the very nnz statistics the paper's
compression study depends on).

Used by ``examples/train_sparse_encoder.py`` as the end-to-end driver:
train (~100M params, a few hundred steps) → encode a corpus → build the
Seismic index with DotVByte compression → measure recall.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .common import dense_init, embed_init, rms_norm
from .transformer import attention

__all__ = ["SparseEncoderConfig", "encoder_init", "encode", "contrastive_loss"]


@dataclasses.dataclass(frozen=True)
class SparseEncoderConfig:
    name: str = "sparse-encoder"
    vocab: int = 30522
    n_layers: int = 8
    d_model: int = 512
    n_heads: int = 8
    d_ff: int = 2048
    max_len: int = 128
    flops_lambda: float = 1e-3
    temperature: float = 0.05
    dtype: object = jnp.float32

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads


def encoder_init(key, cfg: SparseEncoderConfig):
    L, D, V = cfg.n_layers, cfg.d_model, cfg.vocab
    keys = jax.random.split(key, 9)

    def sd(k, a, b):
        return (jax.random.normal(k, (L, a, b)) * (2.0 / (a + b)) ** 0.5).astype(cfg.dtype)

    return {
        "embed": embed_init(keys[0], V, D, cfg.dtype),
        "pos": embed_init(keys[1], cfg.max_len, D, cfg.dtype),
        "layers": {
            "attn_norm": jnp.ones((L, D), cfg.dtype),
            "ffn_norm": jnp.ones((L, D), cfg.dtype),
            "wq": sd(keys[2], D, D),
            "wk": sd(keys[3], D, D),
            "wv": sd(keys[4], D, D),
            "wo": sd(keys[5], D, D),
            "w_up": sd(keys[6], D, cfg.d_ff),
            "w_down": sd(keys[7], cfg.d_ff, D),
        },
        "final_norm": jnp.ones((D,), cfg.dtype),
        "mlm_bias": jnp.zeros((V,), cfg.dtype),  # head tied to embed
    }


def encode(params, cfg: SparseEncoderConfig, tokens, mask):
    """tokens i32 [B, S], mask bool [B, S] → sparse embeddings [B, vocab]."""
    B, S = tokens.shape
    H, dh = cfg.n_heads, cfg.head_dim
    x = params["embed"][tokens] + params["pos"][None, :S]

    def layer(x, lp):
        h = rms_norm(x, lp["attn_norm"])
        q = (h @ lp["wq"]).reshape(B, S, H, dh)
        k = (h @ lp["wk"]).reshape(B, S, H, dh)
        v = (h @ lp["wv"]).reshape(B, S, H, dh)
        a = attention(q, k, v, causal=False)  # bidirectional
        x = x + a.reshape(B, S, H * dh) @ lp["wo"]
        h = rms_norm(x, lp["ffn_norm"])
        x = x + jax.nn.gelu(h @ lp["w_up"]) @ lp["w_down"]
        return x, None

    x, _ = jax.lax.scan(layer, x, params["layers"])
    x = rms_norm(x, params["final_norm"])
    logits = x @ params["embed"].T + params["mlm_bias"]  # [B, S, V]
    acts = jnp.log1p(jax.nn.relu(logits.astype(jnp.float32)))
    acts = jnp.where(mask[..., None], acts, 0.0)
    return acts.max(axis=1)  # SPLADE-max pooling → [B, V]


def contrastive_loss(params, cfg: SparseEncoderConfig, batch):
    """In-batch negatives: query i ↔ doc i positive, others negative."""
    q = encode(params, cfg, batch["q_tokens"], batch["q_mask"])  # [B, V]
    d = encode(params, cfg, batch["d_tokens"], batch["d_mask"])  # [B, V]
    scores = (q @ d.T) / cfg.temperature  # [B, B]
    labels = jnp.arange(q.shape[0])
    logz = jax.nn.logsumexp(scores, axis=-1)
    nll = (logz - jnp.take_along_axis(scores, labels[:, None], axis=1)[:, 0]).mean()
    # SPLADE FLOPS regulariser: (mean activation per vocab dim)², summed
    flops = (jnp.square(q.mean(axis=0)).sum() + jnp.square(d.mean(axis=0)).sum())
    acc = (scores.argmax(-1) == labels).mean()
    nnz_q = (q > 0).sum(-1).mean()
    nnz_d = (d > 0).sum(-1).mean()
    return nll + cfg.flops_lambda * flops, {
        "contrastive_acc": acc,
        "nnz_query": nnz_q,
        "nnz_doc": nnz_d,
    }
