"""GAT (Veličković et al., 2018) via edge-scatter message passing.

JAX has no sparse CSR kernels (BCOO only), so message passing is built —
as the brief requires — from first principles on ``jax.ops.segment_sum``
/ ``segment_max`` over an edge index:

    SDDMM   : per-edge attention logits  e_ij = LeakyReLU(a_s·h_i + a_d·h_j)
    softmax : segment-max + segment-sum over incoming edges per dst
    SpMM    : segment-sum of α_ij · h_src over dst

Shapes are static: graphs are padded to a fixed edge/node budget with a
``-1``-style sentinel (edges pointing at node ``n_nodes``), which the
segment ops drop into an overflow bucket.

The minibatch path uses a real CSR uniform neighbour sampler
(fanout-per-hop, GraphSAGE-style) implemented host-side in numpy.

Distribution: edges are sharded over the whole mesh; each shard computes
partial per-node aggregates and a ``psum``-style scatter-reduce combines
them (wired in repro/dist/sharding.py through sharding constraints).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from .common import dense_init

__all__ = [
    "GATConfig",
    "gat_init",
    "gat_forward",
    "gat_loss",
    "Graph",
    "pad_graph",
    "NeighborSampler",
]


@dataclasses.dataclass(frozen=True)
class GATConfig:
    name: str
    n_layers: int = 2
    d_in: int = 1433
    d_hidden: int = 8
    n_heads: int = 8
    n_classes: int = 7
    negative_slope: float = 0.2
    dtype: object = jnp.float32


@dataclasses.dataclass
class Graph:
    """Static-shape graph batch. Sentinel edges point src=dst=n_nodes."""

    x: jnp.ndarray  # [N(+1), F] node features (last row may be padding)
    edge_src: jnp.ndarray  # i32 [E]
    edge_dst: jnp.ndarray  # i32 [E]
    labels: jnp.ndarray  # i32 [N(+1)]
    train_mask: jnp.ndarray  # bool [N(+1)]


def gat_init(key, cfg: GATConfig):
    keys = jax.random.split(key, cfg.n_layers * 3 + 1)
    layers = []
    d_in = cfg.d_in
    for l in range(cfg.n_layers):
        last = l == cfg.n_layers - 1
        d_out = cfg.n_classes if last else cfg.d_hidden
        H = cfg.n_heads
        layers.append(
            {
                "w": dense_init(keys[3 * l], d_in, H * d_out, cfg.dtype),
                "a_src": (jax.random.normal(keys[3 * l + 1], (H, d_out)) * 0.1).astype(cfg.dtype),
                "a_dst": (jax.random.normal(keys[3 * l + 2], (H, d_out)) * 0.1).astype(cfg.dtype),
            }
        )
        d_in = d_out * H if not last else d_out
    return {"layers": layers}


def _gat_layer(lp, cfg: GATConfig, x, edge_src, edge_dst, n_nodes: int, *, concat: bool):
    H = cfg.n_heads
    d_out = lp["w"].shape[1] // H
    h = (x @ lp["w"]).reshape(-1, H, d_out)  # [N+1, H, d]
    # SDDMM: per-edge logits from gathered endpoint projections
    alpha_src = (h * lp["a_src"][None]).sum(-1)  # [N+1, H]
    alpha_dst = (h * lp["a_dst"][None]).sum(-1)
    e = alpha_src[edge_src] + alpha_dst[edge_dst]  # [E, H]
    e = jax.nn.leaky_relu(e, cfg.negative_slope)
    # segment softmax over incoming edges of each dst (+1 overflow bucket)
    seg = edge_dst
    e_max = jax.ops.segment_max(e, seg, num_segments=n_nodes + 1)
    e_max = jnp.where(jnp.isfinite(e_max), e_max, 0.0)
    p = jnp.exp(e - e_max[seg])
    denom = jax.ops.segment_sum(p, seg, num_segments=n_nodes + 1)
    attn = p / jnp.maximum(denom[seg], 1e-9)  # [E, H]
    # SpMM: weighted scatter of source messages
    msg = h[edge_src] * attn[..., None]  # [E, H, d]
    out = jax.ops.segment_sum(msg, seg, num_segments=n_nodes + 1)  # [N+1, H, d]
    if concat:
        return out.reshape(n_nodes + 1, H * d_out)
    return out.mean(axis=1)  # average heads (output layer, per the paper)


def gat_forward(params, cfg: GATConfig, g: Graph):
    """→ logits [N+1, n_classes] (last row is the padding bucket)."""
    n_nodes = g.x.shape[0] - 1
    x = g.x
    for l, lp in enumerate(params["layers"]):
        last = l == len(params["layers"]) - 1
        x = _gat_layer(lp, cfg, x, g.edge_src, g.edge_dst, n_nodes, concat=not last)
        if not last:
            x = jax.nn.elu(x)
    return x


def gat_graph_loss(params, cfg: GATConfig, g: Graph, graph_ids, graph_labels, n_graphs: int):
    """Graph-level classification for batched small graphs (molecule):
    node logits → mean-pool readout per graph via segment_sum → CE."""
    logits = gat_forward(params, cfg, g).astype(jnp.float32)  # [N+1, C]
    gid = jnp.where(graph_ids >= 0, graph_ids, n_graphs)
    pooled = jax.ops.segment_sum(logits, gid, num_segments=n_graphs + 1)[:n_graphs]
    counts = jax.ops.segment_sum(
        jnp.ones_like(gid, jnp.float32), gid, num_segments=n_graphs + 1
    )[:n_graphs]
    pooled = pooled / jnp.maximum(counts, 1.0)[:, None]
    logz = jax.nn.logsumexp(pooled, axis=-1)
    gold = jnp.take_along_axis(pooled, graph_labels[:, None], axis=-1)[:, 0]
    nll = (logz - gold).mean()
    acc = (pooled.argmax(-1) == graph_labels).mean()
    return nll, {"acc": acc}


def gat_loss(params, cfg: GATConfig, g: Graph):
    logits = gat_forward(params, cfg, g).astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, jnp.maximum(g.labels, 0)[:, None], axis=-1)[:, 0]
    nll = (logz - gold) * g.train_mask
    denom = jnp.maximum(g.train_mask.sum(), 1)
    acc = ((logits.argmax(-1) == g.labels) * g.train_mask).sum() / denom
    return nll.sum() / denom, {"acc": acc}


def pad_graph(
    x: np.ndarray,
    edge_index: np.ndarray,
    labels: np.ndarray,
    train_mask: np.ndarray,
    *,
    edge_budget: int | None = None,
) -> Graph:
    """Numpy graph → static-shape padded Graph (sentinel = node N)."""
    N = x.shape[0]
    E = edge_index.shape[1]
    budget = edge_budget or E
    if budget < E:
        raise ValueError("edge budget below edge count")
    src = np.full(budget, N, dtype=np.int32)
    dst = np.full(budget, N, dtype=np.int32)
    src[:E] = edge_index[0]
    dst[:E] = edge_index[1]
    xp = np.concatenate([x, np.zeros((1, x.shape[1]), x.dtype)], axis=0)
    lp = np.concatenate([labels.astype(np.int32), np.array([-1], np.int32)])
    mp = np.concatenate([train_mask.astype(bool), np.array([False])])
    return Graph(
        x=jnp.asarray(xp),
        edge_src=jnp.asarray(src),
        edge_dst=jnp.asarray(dst),
        labels=jnp.asarray(lp),
        train_mask=jnp.asarray(mp),
    )


def partition_edges_by_dst(
    edge_index: np.ndarray, n_nodes_pad: int, n_shards: int
) -> tuple[np.ndarray, np.ndarray, int]:
    """Host-side prep for the §Perf edge-sharded layer: nodes are split
    into ``n_shards`` equal ranges; every edge is routed to the shard
    owning its *destination* (so the aggregation scatter is device-local)
    and its dst id is made range-local. Returns (edge_src_global [S*Ep],
    edge_dst_local [S*Ep], Ep) with sentinel padding (src = n_nodes_pad-1,
    dst_local = N_loc)."""
    src, dst = edge_index
    n_loc = n_nodes_pad // n_shards
    owner = np.minimum(dst // n_loc, n_shards - 1).astype(np.int64)
    order = np.argsort(owner, kind="stable")
    src, dst, owner = src[order], dst[order], owner[order]
    counts = np.bincount(owner, minlength=n_shards)
    ep = int(((counts.max(initial=1) + 127) // 128) * 128)
    out_src = np.full((n_shards, ep), n_nodes_pad - 1, dtype=np.int32)
    out_dst = np.full((n_shards, ep), n_loc, dtype=np.int32)  # overflow bucket
    pos = 0
    for s in range(n_shards):
        c = int(counts[s])
        out_src[s, :c] = src[pos : pos + c]
        out_dst[s, :c] = dst[pos : pos + c] - s * n_loc
        pos += c
    return out_src.reshape(-1), out_dst.reshape(-1), ep


def gat_loss_edge_sharded(
    params,
    cfg: GATConfig,
    batch,
    mesh,
    axes=("data", "model"),
    gather_dtype=None,
    min_side_gather: bool = False,
):
    """§Perf variant: dst-aligned edge sharding via shard_map.

    batch: x [N_pad, F] node rows sharded over ``axes``; edge_src
    (global ids) / edge_dst_local [S·Ep] sharded over ``axes``; labels /
    train_mask [N_pad] sharded. Collectives per layer: ONE all-gather of
    the projected features (+ its reduce-scatter transpose in bwd) —
    the scatter/softmax are local by the dst-alignment contract."""
    from jax.sharding import PartitionSpec as P

    n_shards = 1
    for a in axes:
        n_shards *= mesh.shape[a]

    def local(params, x_loc, esrc, edst, labels_loc, mask_loc):
        N_pad = x_loc.shape[0] * n_shards
        n_loc = x_loc.shape[0]
        h_in = x_loc
        for li, lp in enumerate(params["layers"]):
            last = li == len(params["layers"]) - 1
            H = cfg.n_heads
            d_out = lp["w"].shape[1] // H
            hp_loc = (h_in @ lp["w"]).reshape(n_loc, H, d_out)
            # ONE collective per layer. §Perf opt2 ("min-side gather"):
            # gather whichever side of the projection is smaller — for the
            # output layer d_in=64 ≪ H·C=376, so gathering pre-projection
            # rows and re-projecting replicated cuts wire bytes 5.6×
            # (the replicated matmul is free: compute is 1000× off the
            # bottleneck on this cell).
            d_in_cur = h_in.shape[1]
            if min_side_gather and d_in_cur < H * d_out:
                h_in_full = jax.lax.all_gather(h_in, axes, tiled=True)  # [N_pad,d_in]
                h_full = (h_in_full @ lp["w"]).reshape(-1, H, d_out)
            elif gather_dtype is not None:
                h_full = jax.lax.all_gather(
                    hp_loc.astype(gather_dtype), axes, tiled=True
                ).astype(hp_loc.dtype)
            else:
                h_full = jax.lax.all_gather(hp_loc, axes, tiled=True)  # [N_pad,H,d]
            alpha_src = (h_full * lp["a_src"][None]).sum(-1)  # [N_pad, H]
            alpha_dst_loc = (hp_loc * lp["a_dst"][None]).sum(-1)  # [n_loc, H]
            e = alpha_src[esrc] + alpha_dst_loc[jnp.clip(edst, 0, n_loc - 1)]
            e = jax.nn.leaky_relu(e, cfg.negative_slope)
            seg = edst  # LOCAL dst ids (n_loc = overflow)
            e_max = jax.ops.segment_max(e, seg, num_segments=n_loc + 1)
            e_max = jnp.where(jnp.isfinite(e_max), e_max, 0.0)
            p = jnp.exp(e - e_max[seg])
            denom = jax.ops.segment_sum(p, seg, num_segments=n_loc + 1)
            attn = p / jnp.maximum(denom[seg], 1e-9)
            msg = h_full[esrc] * attn[..., None]
            out = jax.ops.segment_sum(msg, seg, num_segments=n_loc + 1)[:n_loc]
            h_in = out.reshape(n_loc, H * d_out) if not last else out.mean(axis=1)
            if not last:
                h_in = jax.nn.elu(h_in)
        logits = h_in.astype(jnp.float32)  # [n_loc, C]
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, jnp.maximum(labels_loc, 0)[:, None], axis=-1)[:, 0]
        nll = ((logz - gold) * mask_loc).sum()
        cnt = mask_loc.sum()
        acc = ((logits.argmax(-1) == labels_loc) * mask_loc).sum()
        nll, cnt, acc = (jax.lax.psum(t, axes) for t in (nll, cnt, acc))
        denom = jnp.maximum(cnt, 1)
        return nll / denom, {"acc": acc / denom}

    return jax.shard_map(
        local,
        mesh=mesh,
        in_specs=(P(), P(axes, None), P(axes), P(axes), P(axes), P(axes)),
        out_specs=(P(), {"acc": P()}),
        check_vma=False,
    )(params, batch["x"], batch["edge_src"], batch["edge_dst"], batch["labels"], batch["train_mask"])


class NeighborSampler:
    """CSR uniform neighbour sampler (GraphSAGE-style, host-side).

    Produces fixed-fanout static-shape subgraph batches: for seed set S
    and fanouts (f1, f2, …), hop h samples ≤ f_h neighbours per frontier
    node. Missing neighbours are padded with the sentinel node.
    """

    def __init__(self, edge_index: np.ndarray, n_nodes: int, seed: int = 0):
        src, dst = edge_index
        order = np.argsort(dst, kind="stable")
        self.src_sorted = src[order].astype(np.int64)
        self.indptr = np.searchsorted(dst[order], np.arange(n_nodes + 1))
        self.n_nodes = n_nodes
        self.rng = np.random.default_rng(seed)

    def sample(self, seeds: np.ndarray, fanouts: tuple[int, ...]):
        """→ (node_ids [M], edge_src_local, edge_dst_local) numpy arrays.

        node_ids[0:len(seeds)] are the seeds; edges are directed src→dst
        into sampled frontier order, padded with sentinel M."""
        nodes = list(seeds.astype(np.int64))
        index = {int(n): i for i, n in enumerate(nodes)}
        e_src: list[int] = []
        e_dst: list[int] = []
        frontier = list(seeds.astype(np.int64))
        for f in fanouts:
            nxt: list[int] = []
            for u in frontier:
                s, e = int(self.indptr[u]), int(self.indptr[u + 1])
                neigh = self.src_sorted[s:e]
                if len(neigh) > f:
                    neigh = self.rng.choice(neigh, size=f, replace=False)
                for v in neigh:
                    v = int(v)
                    if v not in index:
                        index[v] = len(nodes)
                        nodes.append(v)
                        nxt.append(v)
                    e_src.append(index[v])
                    e_dst.append(index[u])
            frontier = nxt
        return (
            np.asarray(nodes, dtype=np.int64),
            np.asarray(e_src, dtype=np.int32),
            np.asarray(e_dst, dtype=np.int32),
        )

    def sample_padded(
        self, seeds: np.ndarray, fanouts: tuple[int, ...], node_budget: int, edge_budget: int
    ):
        nodes, es, ed = self.sample(seeds, fanouts)
        if len(nodes) > node_budget or len(es) > edge_budget:
            raise ValueError(
                f"budget too small: need {len(nodes)} nodes / {len(es)} edges"
            )
        node_ids = np.full(node_budget, -1, dtype=np.int64)
        node_ids[: len(nodes)] = nodes
        src = np.full(edge_budget, node_budget, dtype=np.int32)
        dst = np.full(edge_budget, node_budget, dtype=np.int32)
        src[: len(es)] = es
        dst[: len(ed)] = ed
        return node_ids, src, dst
