"""RecSys archs: DeepFM, DCN-v2, SASRec, DIN (+ EmbeddingBag substrate).

JAX has no native ``nn.EmbeddingBag`` — per the brief it is built here
from ``jnp.take`` + ``jax.ops.segment_sum``. All four models share one
*combined* embedding table per config ([Σ field vocab, dim], per-field
offsets), the standard layout for row-sharding huge tables over the
``model`` mesh axis (DESIGN.md §4).

Batch conventions (all static shapes):

* CTR models (DeepFM, DCN-v2): ``{"sparse": i32 [B, F], "dense": f32
  [B, 13] (DCN only), "label": f32 [B]}`` — ids are *field-local*;
  the combined-table offset is added inside the model.
* SASRec: ``{"seq": i32 [B, S], "pos_label": i32 [B, S], "neg_label":
  i32 [B, S, K]}`` (0 = padding item).
* DIN: ``{"hist": i32 [B, S], "target": i32 [B], "label": f32 [B]}``.

``serve`` returns scores/logits; ``score_candidates`` implements the
``retrieval_cand`` shape (1 user × 10⁶ candidates) as a batched matmul /
batched forward, never a loop.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp

from .common import dense_init, embed_init, layer_norm, mlp_apply, mlp_init

__all__ = [
    "embedding_bag",
    "RecsysConfig",
    "DeepFMConfig",
    "DCNv2Config",
    "SASRecConfig",
    "DINConfig",
]


def embedding_bag(
    table: jnp.ndarray,
    ids: jnp.ndarray,
    *,
    mode: str = "sum",
    valid: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """EmbeddingBag(sum|mean) over the last axis of ``ids``.

    ids [..., L] → [..., dim]. Built from gather + segment-sum as the
    taxonomy prescribes: rows are gathered with ``jnp.take`` and reduced
    by bag via ``jax.ops.segment_sum`` over a flattened bag index.
    """
    shape = ids.shape
    L = shape[-1]
    flat = ids.reshape(-1)  # [n_bags * L]
    n_bags = flat.shape[0] // L
    rows = jnp.take(table, flat, axis=0)  # [n_bags*L, dim]
    if valid is not None:
        rows = rows * valid.reshape(-1, 1).astype(rows.dtype)
    bag = jnp.repeat(jnp.arange(n_bags, dtype=jnp.int32), L)
    out = jax.ops.segment_sum(rows, bag, num_segments=n_bags)
    if mode == "mean":
        counts = (
            jax.ops.segment_sum(
                valid.reshape(-1).astype(rows.dtype), bag, num_segments=n_bags
            )
            if valid is not None
            else jnp.full((n_bags,), float(L), rows.dtype)
        )
        out = out / jnp.maximum(counts, 1.0)[:, None]
    return out.reshape(*shape[:-1], table.shape[1])


def _field_offsets(vocab_sizes: Sequence[int]) -> jnp.ndarray:
    off = [0]
    for v in vocab_sizes[:-1]:
        off.append(off[-1] + v)
    return jnp.asarray(off, dtype=jnp.int32)


def bce_loss(logits: jnp.ndarray, labels: jnp.ndarray):
    logits = logits.astype(jnp.float32)
    nll = jnp.maximum(logits, 0) - logits * labels + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    auc_proxy = ((logits > 0) == (labels > 0.5)).mean()
    return nll.mean(), {"accuracy": auc_proxy}


class RecsysConfig:
    """Marker base for recsys configs."""


# ---------------------------------------------------------------------------
# DeepFM  [arXiv:1703.04247]
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DeepFMConfig(RecsysConfig):
    name: str = "deepfm"
    vocab_sizes: tuple[int, ...] = (100_000,) * 39  # 39 sparse fields
    embed_dim: int = 10
    mlp: tuple[int, ...] = (400, 400, 400)
    dtype: object = jnp.float32

    @property
    def n_fields(self) -> int:
        return len(self.vocab_sizes)

    @property
    def total_vocab(self) -> int:
        return sum(self.vocab_sizes)


def deepfm_init(key, cfg: DeepFMConfig):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    F, D = cfg.n_fields, cfg.embed_dim
    return {
        "embed": embed_init(k1, cfg.total_vocab, D, cfg.dtype),
        "linear": (jax.random.normal(k2, (cfg.total_vocab,)) * 0.01).astype(cfg.dtype),
        "mlp": mlp_init(k3, (F * D, *cfg.mlp, 1), cfg.dtype),
        "bias": jnp.zeros((), cfg.dtype),
    }


def deepfm_forward(params, cfg: DeepFMConfig, sparse_ids: jnp.ndarray) -> jnp.ndarray:
    """sparse_ids i32 [B, F] (field-local) → logits [B]."""
    ids = sparse_ids + _field_offsets(cfg.vocab_sizes)[None, :]
    e = jnp.take(params["embed"], ids, axis=0)  # [B, F, D]
    first = jnp.take(params["linear"], ids, axis=0).sum(-1)  # [B]
    s = e.sum(axis=1)
    fm = 0.5 * ((s * s).sum(-1) - (e * e).sum(axis=(1, 2)))  # [B]
    deep = mlp_apply(params["mlp"], e.reshape(e.shape[0], -1))[:, 0]
    return first + fm + deep + params["bias"]


def deepfm_loss(params, cfg: DeepFMConfig, batch):
    return bce_loss(deepfm_forward(params, cfg, batch["sparse"]), batch["label"])


def deepfm_score_candidates(params, cfg: DeepFMConfig, user_sparse, cand_ids, cand_field: int):
    """retrieval_cand: one user row [1, F] × candidate values of one field.

    cand_ids i32 [N] are field-local ids for field ``cand_field``;
    scoring broadcasts the fixed user features — a batched forward, not
    a loop (the 1M-candidate offline-scoring shape)."""
    N = cand_ids.shape[0]
    rows = jnp.broadcast_to(user_sparse, (N, cfg.n_fields))
    rows = rows.at[:, cand_field].set(cand_ids)
    return deepfm_forward(params, cfg, rows)


# ---------------------------------------------------------------------------
# DCN-v2  [arXiv:2008.13535]
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DCNv2Config(RecsysConfig):
    name: str = "dcn-v2"
    n_dense: int = 13
    vocab_sizes: tuple[int, ...] = (100_000,) * 26
    embed_dim: int = 16
    n_cross_layers: int = 3
    mlp: tuple[int, ...] = (1024, 1024, 512)
    dtype: object = jnp.float32

    @property
    def n_fields(self) -> int:
        return len(self.vocab_sizes)

    @property
    def total_vocab(self) -> int:
        return sum(self.vocab_sizes)

    @property
    def d_interact(self) -> int:
        return self.n_dense + self.n_fields * self.embed_dim


def dcnv2_init(key, cfg: DCNv2Config):
    keys = jax.random.split(key, cfg.n_cross_layers + 3)
    d = cfg.d_interact
    return {
        "embed": embed_init(keys[0], cfg.total_vocab, cfg.embed_dim, cfg.dtype),
        "cross_w": [dense_init(keys[1 + i], d, d, cfg.dtype) for i in range(cfg.n_cross_layers)],
        "cross_b": [jnp.zeros((d,), cfg.dtype) for _ in range(cfg.n_cross_layers)],
        "mlp": mlp_init(keys[-2], (d, *cfg.mlp), cfg.dtype),
        "head": dense_init(keys[-1], cfg.mlp[-1], 1, cfg.dtype),
    }


def dcnv2_forward(params, cfg: DCNv2Config, dense, sparse_ids):
    ids = sparse_ids + _field_offsets(cfg.vocab_sizes)[None, :]
    e = jnp.take(params["embed"], ids, axis=0).reshape(sparse_ids.shape[0], -1)
    x0 = jnp.concatenate([dense.astype(cfg.dtype), e], axis=-1)  # [B, d]
    x = x0
    for w, b in zip(params["cross_w"], params["cross_b"]):
        x = x0 * (x @ w + b) + x  # DCN-v2 full-matrix cross
    h = mlp_apply(params["mlp"], x, final_activation=jax.nn.relu)
    return (h @ params["head"])[:, 0]


def dcnv2_loss(params, cfg: DCNv2Config, batch):
    return bce_loss(
        dcnv2_forward(params, cfg, batch["dense"], batch["sparse"]), batch["label"]
    )


def dcnv2_score_candidates(params, cfg: DCNv2Config, user_dense, user_sparse, cand_ids, cand_field: int):
    N = cand_ids.shape[0]
    dense = jnp.broadcast_to(user_dense, (N, cfg.n_dense))
    rows = jnp.broadcast_to(user_sparse, (N, cfg.n_fields))
    rows = rows.at[:, cand_field].set(cand_ids)
    return dcnv2_forward(params, cfg, dense, rows)


# ---------------------------------------------------------------------------
# SASRec  [arXiv:1808.09781]
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SASRecConfig(RecsysConfig):
    name: str = "sasrec"
    n_items: int = 1_000_000  # item 0 = padding
    embed_dim: int = 50
    n_blocks: int = 2
    n_heads: int = 1
    seq_len: int = 50
    n_negatives: int = 128
    dtype: object = jnp.float32


def sasrec_init(key, cfg: SASRecConfig):
    keys = jax.random.split(key, 2 + cfg.n_blocks)
    D = cfg.embed_dim
    blocks = []
    for k in keys[2:]:
        ks = jax.random.split(k, 4)
        blocks.append(
            {
                "ln1_w": jnp.ones((D,), cfg.dtype),
                "ln1_b": jnp.zeros((D,), cfg.dtype),
                "wqkv": dense_init(ks[0], D, 3 * D, cfg.dtype),
                "wo": dense_init(ks[1], D, D, cfg.dtype),
                "ln2_w": jnp.ones((D,), cfg.dtype),
                "ln2_b": jnp.zeros((D,), cfg.dtype),
                "ff1": dense_init(ks[2], D, D, cfg.dtype),
                "ff2": dense_init(ks[3], D, D, cfg.dtype),
            }
        )
    return {
        "item_embed": embed_init(keys[0], cfg.n_items, D, cfg.dtype),
        "pos_embed": embed_init(keys[1], cfg.seq_len, D, cfg.dtype),
        "final_ln_w": jnp.ones((D,), cfg.dtype),
        "final_ln_b": jnp.zeros((D,), cfg.dtype),
        "blocks": blocks,
    }


def sasrec_encode(params, cfg: SASRecConfig, seq: jnp.ndarray) -> jnp.ndarray:
    """seq i32 [B, S] (0 = pad) → user states [B, S, D]."""
    B, S = seq.shape
    H = cfg.n_heads
    D = cfg.embed_dim
    dh = D // H
    x = jnp.take(params["item_embed"], seq, axis=0) + params["pos_embed"][None, :S]
    pad = (seq == 0)[..., None]
    x = jnp.where(pad, 0.0, x)
    causal = jnp.tril(jnp.ones((S, S), bool))
    for blk in params["blocks"]:
        h = layer_norm(x, blk["ln1_w"], blk["ln1_b"])
        qkv = (h @ blk["wqkv"]).reshape(B, S, 3, H, dh)
        q, k, v = qkv[:, :, 0], qkv[:, :, 1], qkv[:, :, 2]
        s = jnp.einsum("bqhd,bkhd->bhqk", q, k) / jnp.sqrt(jnp.float32(dh))
        s = jnp.where(causal[None, None], s.astype(jnp.float32), -1e30)
        a = jax.nn.softmax(s, axis=-1).astype(x.dtype)
        o = jnp.einsum("bhqk,bkhd->bqhd", a, v).reshape(B, S, D)
        x = x + o @ blk["wo"]
        h = layer_norm(x, blk["ln2_w"], blk["ln2_b"])
        x = x + jax.nn.relu(h @ blk["ff1"]) @ blk["ff2"]
        x = jnp.where(pad, 0.0, x)
    return layer_norm(x, params["final_ln_w"], params["final_ln_b"])


def sasrec_loss(params, cfg: SASRecConfig, batch):
    """Sampled-softmax next-item loss (pos + K sampled negatives)."""
    states = sasrec_encode(params, cfg, batch["seq"])  # [B, S, D]
    pos = jnp.take(params["item_embed"], batch["pos_label"], axis=0)  # [B,S,D]
    neg = jnp.take(params["item_embed"], batch["neg_label"], axis=0)  # [B,S,K,D]
    pos_logit = (states * pos).sum(-1)  # [B,S]
    neg_logit = jnp.einsum("bsd,bskd->bsk", states, neg)
    mask = (batch["pos_label"] > 0).astype(jnp.float32)
    logits = jnp.concatenate([pos_logit[..., None], neg_logit], axis=-1).astype(jnp.float32)
    nll = jax.nn.logsumexp(logits, axis=-1) - pos_logit.astype(jnp.float32)
    denom = jnp.maximum(mask.sum(), 1.0)
    loss = (nll * mask).sum() / denom
    hit = ((pos_logit[..., None] > neg_logit).all(-1) * mask).sum() / denom
    return loss, {"hit_rate": hit}


def sasrec_score_candidates(params, cfg: SASRecConfig, seq, cand_ids):
    """retrieval_cand: dense MIPS — user state × 10⁶ item embeddings."""
    states = sasrec_encode(params, cfg, seq)  # [B, S, D]
    user = states[:, -1]  # [B, D]
    cand = jnp.take(params["item_embed"], cand_ids, axis=0)  # [N, D]
    return user @ cand.T  # [B, N]


# ---------------------------------------------------------------------------
# DIN  [arXiv:1706.06978]
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DINConfig(RecsysConfig):
    name: str = "din"
    n_items: int = 1_000_000
    embed_dim: int = 18
    seq_len: int = 100
    attn_mlp: tuple[int, ...] = (80, 40)
    mlp: tuple[int, ...] = (200, 80)
    dtype: object = jnp.float32


def din_init(key, cfg: DINConfig):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    D = cfg.embed_dim
    return {
        "item_embed": embed_init(k1, cfg.n_items, D, cfg.dtype),
        "attn_mlp": mlp_init(k2, (4 * D, *cfg.attn_mlp, 1), cfg.dtype),
        # input: [attended interest, mean-pooled history, target]
        "mlp": mlp_init(k3, (3 * D, *cfg.mlp, 1), cfg.dtype),
    }


def din_forward(params, cfg: DINConfig, hist, target):
    """hist i32 [B, S] (0 = pad), target i32 [B] → logits [B]."""
    h = jnp.take(params["item_embed"], hist, axis=0)  # [B, S, D]
    t = jnp.take(params["item_embed"], target, axis=0)  # [B, D]
    tb = jnp.broadcast_to(t[:, None], h.shape)
    feats = jnp.concatenate([h, tb, h - tb, h * tb], axis=-1)  # [B,S,4D]
    w = mlp_apply(params["attn_mlp"], feats, activation=jax.nn.sigmoid)[..., 0]
    w = jnp.where(hist > 0, w.astype(jnp.float32), -1e30)
    # DIN uses un-normalised weights; we use masked softmax (stable variant)
    a = jax.nn.softmax(w, axis=-1).astype(h.dtype)
    interest = (a[..., None] * h).sum(axis=1)  # weighted-sum pooling [B, D]
    # mean-pooled history through the EmbeddingBag substrate as a second
    # interest feature (gather + segment-sum, per the taxonomy)
    hist_mean = embedding_bag(
        params["item_embed"], hist, mode="mean", valid=(hist > 0)
    )
    z = jnp.concatenate([interest, hist_mean, t], axis=-1)
    return mlp_apply(params["mlp"], z)[:, 0]


def din_loss(params, cfg: DINConfig, batch):
    return bce_loss(din_forward(params, cfg, batch["hist"], batch["target"]), batch["label"])


def din_score_candidates(params, cfg: DINConfig, hist, cand_ids):
    """retrieval_cand: one user history × N candidate targets (batched)."""
    N = cand_ids.shape[0]
    histb = jnp.broadcast_to(hist, (N, cfg.seq_len))
    return din_forward(params, cfg, histb, cand_ids)
