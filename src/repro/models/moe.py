"""Mixture-of-Experts FFN: top-k routing with capacity-based dispatch.

TPU-native static-shape formulation (GShard/Switch lineage adapted to
gather/scatter rather than one-hot einsum, so it scales to 384-expert
configs like Kimi-K2):

1. router logits → top-k experts per token + softmax weights;
2. tokens grouped by expert via a stable argsort; each expert keeps at
   most ``capacity = ceil(T·k/E · capacity_factor)`` tokens, the rest are
   dropped (contribute only through other experts they route to);
3. gathered [E, C, D] batch runs the expert SwiGLU in one batched einsum
   — sharded over the ``model`` mesh axis this IS expert parallelism,
   and the gather/scatter lower to all-to-alls;
4. outputs scatter-add back weighted by the router probabilities.

Optionally adds shared experts (DeepSeek/Kimi style) that process every
token densely.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .common import dense_init, shard_hint

__all__ = ["MoEConfig", "moe_init", "moe_apply"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_model: int
    d_ff: int  # per-expert hidden size
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    router_dtype: str = "float32"
    # ZeRO-3 just-in-time expert-weight gathering. Wins when tokens/step
    # outweigh expert params/layer (prefill, bulk serve); loses under
    # microbatched training where the scan re-gathers per microbatch —
    # LMArch flips it per cell kind (EXPERIMENTS.md §Perf, kimi-k2).
    jit_weight_gather: bool = True


def moe_init(key, cfg: MoEConfig, dtype=jnp.float32):
    kr, k1, k2, k3, ks = jax.random.split(key, 5)
    E, D, F = cfg.n_experts, cfg.d_model, cfg.d_ff
    p = {
        "router": dense_init(kr, D, E, jnp.float32),  # router kept f32
        "w_gate": (jax.random.normal(k1, (E, D, F)) * (2.0 / (D + F)) ** 0.5).astype(dtype),
        "w_up": (jax.random.normal(k2, (E, D, F)) * (2.0 / (D + F)) ** 0.5).astype(dtype),
        "w_down": (jax.random.normal(k3, (E, F, D)) * (2.0 / (D + F)) ** 0.5).astype(dtype),
    }
    if cfg.n_shared_experts:
        S = cfg.n_shared_experts
        p["shared_gate"] = (jax.random.normal(ks, (D, S * F)) * (2.0 / (D + F)) ** 0.5).astype(dtype)
        p["shared_up"] = (jax.random.normal(jax.random.fold_in(ks, 1), (D, S * F)) * (2.0 / (D + F)) ** 0.5).astype(dtype)
        p["shared_down"] = (jax.random.normal(jax.random.fold_in(ks, 2), (S * F, D)) * (2.0 / (D + F)) ** 0.5).astype(dtype)
    return p


def _capacity(tokens: int, cfg: MoEConfig) -> int:
    c = int(tokens * cfg.top_k * cfg.capacity_factor / cfg.n_experts) + 1
    return max((c + 7) // 8 * 8, 8)  # lane-align


def moe_apply(params, cfg: MoEConfig, x: jnp.ndarray):
    """x [T, D] → (y [T, D], aux) with aux = load-balancing loss terms."""
    T, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    C = _capacity(T, cfg)

    logits = (x.astype(jnp.float32) @ params["router"]).astype(jnp.float32)  # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_w, expert_idx = jax.lax.top_k(probs, K)  # [T, K]
    gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)

    # ---- group tokens by expert (stable sort ⇒ deterministic drops) ----
    flat_e = expert_idx.reshape(-1)  # [T*K]
    flat_t = jnp.repeat(jnp.arange(T, dtype=jnp.int32), K)
    flat_w = gate_w.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    se, st, sw = flat_e[order], flat_t[order], flat_w[order]
    starts = jnp.searchsorted(se, jnp.arange(E, dtype=se.dtype))  # [E]
    rank = jnp.arange(T * K, dtype=jnp.int32) - starts[se].astype(jnp.int32)
    keep = rank < C
    slot = jnp.where(keep, se.astype(jnp.int32) * C + rank, E * C)  # drop → sentinel

    dispatch_tok = jnp.full((E * C + 1,), T, dtype=jnp.int32).at[slot].set(st, mode="drop")
    dispatch_w = jnp.zeros((E * C + 1,), jnp.float32).at[slot].set(sw, mode="drop")
    dispatch_tok = dispatch_tok[: E * C]
    dispatch_w = dispatch_w[: E * C]

    # ---- expert compute: batched SwiGLU over [E, C, D] -----------------
    # expert weights are FSDP-sharded on D for storage; optionally gather
    # them just-in-time (ZeRO-3) so the einsums never partial-sum the
    # [E,C,*] activations over the data axis (§Perf, kimi-k2 iteration)
    if cfg.jit_weight_gather:
        wg = shard_hint(params["w_gate"], "model", None, None)
        wu = shard_hint(params["w_up"], "model", None, None)
        wd = shard_hint(params["w_down"], "model", None, None)
    else:
        wg, wu, wd = params["w_gate"], params["w_up"], params["w_down"]
    x_pad = jnp.concatenate([x, jnp.zeros((1, D), x.dtype)], axis=0)
    xe = x_pad[dispatch_tok].reshape(E, C, D)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, wg)) * jnp.einsum(
        "ecd,edf->ecf", xe, wu
    )
    ye = jnp.einsum("ecf,efd->ecd", h, wd)  # [E, C, D]

    # ---- combine: weighted scatter-add back to tokens -------------------
    y = (
        jnp.zeros((T + 1, D), ye.dtype)
        .at[dispatch_tok]
        .add(ye.reshape(E * C, D) * dispatch_w[:, None].astype(ye.dtype))
    )[:T]

    if cfg.n_shared_experts:
        hs = jax.nn.silu(x @ params["shared_gate"]) * (x @ params["shared_up"])
        y = y + hs @ params["shared_down"]

    # GShard aux load-balance loss: E * Σ_e (fraction routed)·(mean prob)
    me = probs.mean(axis=0)  # [E]
    ce = jnp.zeros((E,), jnp.float32).at[flat_e].add(1.0) / (T * K)
    aux = E * jnp.sum(me * ce)
    return y, {"load_balance_loss": aux}
