"""DotNibble — the paper's future-work direction, implemented.

§4 of the paper: "we plan to incorporate sub-byte capability into
DotVByte for small, frequent dgaps, to further improve the compression
ratio." This codec does exactly that while keeping every property that
makes DotVByte fast:

* a 2-bit control per value selects a {4, 8, 12, 16}-bit code — the
  natural sub-byte extension of DotVByte's 1-bit {8, 16} scheme;
* one control byte covers FOUR values (vs DotVByte's eight), still
  byte-aligned and shuffle/gather-decodable;
* data is a *nibble* stream; per-value nibble offsets come from the same
  prefix-sum trick the TPU decode uses for byte offsets (DESIGN.md §3);
* per-document alignment: groups of 4 compressed, ≤3 remainder values
  stored raw u16 — no control byte ever spans documents.

After RGB re-ordering most SPLADE gaps fit 4–8 bits, which is where
DotVByte pays its 1-byte floor; DotNibble removes that floor at the cost
of one extra control bit per value. Measured in benchmarks/table1.
"""

from __future__ import annotations

import numpy as np

from .base import Codec, components_from_gaps, gaps_from_components, register

__all__ = ["DotNibbleCodec", "encode_doc_arrays", "decode_doc_arrays"]

_WIDTH_BITS = (4, 8, 12, 16)  # code 0..3 → bits


def _codes_for(gaps: np.ndarray) -> np.ndarray:
    g = np.asarray(gaps, dtype=np.uint64)
    if np.any(g > 0xFFFF):
        raise ValueError("DotNibble requires 16-bit gaps (d <= 65536)")
    codes = np.zeros(len(g), dtype=np.uint8)
    codes[g > 0xF] = 1
    codes[g > 0xFF] = 2
    codes[g > 0xFFF] = 3
    return codes


def encode_doc_arrays(components: np.ndarray):
    """-> (controls u8[n4/4], nibbles u8[ceil(total_nibbles/2)],
    remainder u16[<4]). Nibble stream is LSN-first within each byte."""
    c = np.asarray(components, dtype=np.uint32)
    n = len(c)
    n4 = (n // 4) * 4
    gaps = gaps_from_components(c)[:n4].astype(np.uint64)
    codes = _codes_for(gaps)
    # controls: 2 bits per value, 4 values per byte, value i → bits 2i..2i+1
    ctrl = np.zeros(n4 // 4, dtype=np.uint8)
    for lane in range(4):
        ctrl |= (codes[lane::4] & 0x3) << (2 * lane)
    # nibble stream
    nib_len = codes.astype(np.int64) + 1
    starts = np.concatenate([[0], np.cumsum(nib_len)[:-1]]) if n4 else np.zeros(0, np.int64)
    total = int(nib_len.sum()) if n4 else 0
    nibbles = np.zeros(total, dtype=np.uint8)
    for k in range(4):  # k-th nibble of each value (LS nibble first)
        take = nib_len > k
        nibbles[starts[take] + k] = ((gaps[take] >> (4 * k)) & 0xF).astype(np.uint8)
    # pack two nibbles per byte, LSN first
    if total % 2:
        nibbles = np.concatenate([nibbles, np.zeros(1, np.uint8)])
    packed = (nibbles[0::2] | (nibbles[1::2] << 4)).astype(np.uint8)
    rem = c[n4:].astype(np.uint16)
    return ctrl, packed, rem


def decode_doc_arrays(ctrl: np.ndarray, packed: np.ndarray, rem: np.ndarray, n4: int):
    """Vectorised reference decode → absolute components (uint32)."""
    if n4:
        lanes = np.arange(n4)
        codes = (ctrl[lanes // 4] >> (2 * (lanes % 4))) & 0x3
        nib_len = codes.astype(np.int64) + 1
        starts = np.concatenate([[0], np.cumsum(nib_len)[:-1]])
        # unpack nibble stream (LSN first) with over-read margin
        nibbles = np.zeros(2 * len(packed) + 4, dtype=np.uint32)
        nibbles[0 : 2 * len(packed) : 2] = packed & 0xF
        nibbles[1 : 2 * len(packed) : 2] = packed >> 4
        gaps = np.zeros(n4, dtype=np.uint32)
        for k in range(4):
            take = nib_len > k
            gaps[take] |= nibbles[starts[take] + k] << (4 * k)
        comps = components_from_gaps(gaps)
    else:
        comps = np.zeros(0, dtype=np.uint32)
    return np.concatenate([comps, np.asarray(rem, dtype=np.uint32)])


@register("dotnibble")
class DotNibbleCodec(Codec):
    name = "dotnibble"
    supports_zero = True

    def encode_doc(self, components: np.ndarray) -> bytes:
        ctrl, packed, rem = encode_doc_arrays(components)
        return ctrl.tobytes() + packed.tobytes() + rem.astype("<u2").tobytes()

    def decode_doc(self, buf: bytes, n: int) -> np.ndarray:
        n4 = (n // 4) * 4
        n_ctrl = n4 // 4
        raw = np.frombuffer(buf, dtype=np.uint8)
        ctrl = raw[:n_ctrl]
        if n4:
            lanes = np.arange(n4)
            codes = (ctrl[lanes // 4] >> (2 * (lanes % 4))) & 0x3
            total_nib = int((codes.astype(np.int64) + 1).sum())
            n_packed = (total_nib + 1) // 2
        else:
            n_packed = 0
        packed = raw[n_ctrl : n_ctrl + n_packed]
        rem = raw[n_ctrl + n_packed :].view("<u2")[: n - n4]
        return decode_doc_arrays(ctrl, packed, rem, n4)
