"""Zeta_k codes (Boldi & Vigna, 2005) — power-law-tuned universal codes.

zeta_k(x), x >= 1: let h = floor(log2 x / k) (the "shard"); write
unary(h), then the minimal-binary ("truncated binary") code of
x - 2^{hk} within the interval [0, 2^{(h+1)k} - 2^{hk}).  k = 3 is the
classic web-graph default and what `compressed-intvec` uses; the paper's
"Zeta" row is reproduced with k=3 (configurable).
"""

from __future__ import annotations

import numpy as np

from .base import Codec, components_from_gaps, gaps_from_components, register
from .bitio import BitReader, BitWriter

__all__ = ["ZetaCodec"]


def _minimal_binary_write(w: BitWriter, x: int, z: int) -> None:
    """Truncated binary code of x in [0, z)."""
    if z <= 0 or not (0 <= x < z):
        raise ValueError("minimal binary domain error")
    s = z.bit_length() - 1  # floor(log2 z)
    m = (1 << (s + 1)) - z  # count of short (s-bit) codewords
    if x < m:
        w.write_bits(x, s)
    else:
        w.write_bits(x + m, s + 1)


def _minimal_binary_read(r: BitReader, z: int) -> int:
    s = z.bit_length() - 1
    m = (1 << (s + 1)) - z
    x = r.read_bits(s)
    if x < m:
        return x
    return ((x << 1) | r.read_bit()) - m


def _zeta_write(w: BitWriter, x: int, k: int) -> None:
    if x < 1:
        raise ValueError("zeta codes positive integers only")
    h = (x.bit_length() - 1) // k
    w.write_unary(h)
    lo = 1 << (h * k)
    hi = 1 << ((h + 1) * k)
    _minimal_binary_write(w, x - lo, hi - lo)


def _zeta_read(r: BitReader, k: int) -> int:
    h = r.read_unary()
    lo = 1 << (h * k)
    hi = 1 << ((h + 1) * k)
    return lo + _minimal_binary_read(r, hi - lo)


@register("zeta")
class ZetaCodec(Codec):
    name = "zeta"
    supports_zero = False

    def __init__(self, k: int = 3) -> None:
        if k < 1:
            raise ValueError("zeta shard size k must be >= 1")
        self.k = k

    def encode_doc(self, components: np.ndarray) -> bytes:
        gaps = gaps_from_components(components)
        w = BitWriter()
        for g in gaps:
            _zeta_write(w, int(g) + 1, self.k)
        return w.getvalue()

    def decode_doc(self, buf: bytes, n: int) -> np.ndarray:
        r = BitReader(buf)
        gaps = np.fromiter(
            (_zeta_read(r, self.k) - 1 for _ in range(n)), dtype=np.uint32, count=n
        )
        return components_from_gaps(gaps)
