"""Codec protocol + d-gap transforms shared by every forward-index codec.

A codec encodes ONE document's sorted ``components`` (strictly increasing
uint16/uint32 coordinate ids) into a byte string, and decodes it back.
Documents are d-gap transformed first, per §2 of the paper: the gap
sequence is ``g[0] = c[0]`` and ``g[i] = c[i] - c[i-1]`` (strictly
positive for i > 0; g[0] may be zero when component 0 is present).

Bit-oriented universal codes (Elias gamma/delta, Zeta) cannot encode 0,
so those codecs encode ``g + 1``; byte-oriented codecs (VByte,
StreamVByte, DotVByte, bitpack) encode gaps verbatim.
"""

from __future__ import annotations

from typing import Callable, Dict

import numpy as np

__all__ = [
    "gaps_from_components",
    "components_from_gaps",
    "Codec",
    "register",
    "get_codec",
    "available_codecs",
]


def gaps_from_components(components: np.ndarray) -> np.ndarray:
    """d-gap transform; components must be sorted strictly increasing."""
    c = np.asarray(components, dtype=np.int64)
    if c.ndim != 1:
        raise ValueError("components must be 1-D")
    if len(c) == 0:
        return c.astype(np.uint32)
    if np.any(np.diff(c) <= 0):
        raise ValueError("components must be strictly increasing")
    gaps = np.empty_like(c)
    gaps[0] = c[0]
    gaps[1:] = np.diff(c)
    return gaps.astype(np.uint32)


def components_from_gaps(gaps: np.ndarray) -> np.ndarray:
    return np.cumsum(np.asarray(gaps, dtype=np.int64)).astype(np.uint32)


class Codec:
    """Interface implemented by every forward-index components codec."""

    #: registry key, e.g. "dotvbyte"
    name: str = "abstract"
    #: True when the codec encodes raw gaps (can represent 0), False when
    #: it encodes gaps+1 (bit-oriented universal codes).
    supports_zero: bool = True

    # --- per-document API (host-side build / reference decode) ---------
    def encode_doc(self, components: np.ndarray) -> bytes:
        raise NotImplementedError

    def decode_doc(self, buf: bytes, n: int) -> np.ndarray:
        """Decode ``n`` components from ``buf`` (absolute ids, uint32)."""
        raise NotImplementedError

    # --- accounting -----------------------------------------------------
    def encoded_size_bytes(self, components: np.ndarray) -> int:
        return len(self.encode_doc(components))

    def bits_per_component(self, docs: list[np.ndarray]) -> float:
        total_bits = 0
        total_comps = 0
        for c in docs:
            if len(c) == 0:
                continue
            total_bits += 8 * self.encoded_size_bytes(c)
            total_comps += len(c)
        return total_bits / max(total_comps, 1)


_REGISTRY: Dict[str, Callable[[], Codec]] = {}


def register(name: str) -> Callable:
    def deco(factory: Callable[[], Codec]):
        _REGISTRY[name] = factory
        return factory

    return deco


def get_codec(name: str, **kwargs) -> Codec:
    try:
        factory = _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown codec {name!r}; have {sorted(_REGISTRY)}") from None
    return factory(**kwargs)


def available_codecs() -> list[str]:
    return sorted(_REGISTRY)
