"""Elias universal codes (Elias, 1975): gamma and delta.

gamma(x), x >= 1:  unary(len) ++ binary(x without leading 1), where
len = floor(log2 x).  delta(x): gamma(len+1) ++ binary(x without leading
1).  Gaps are encoded as g+1 so that g = 0 (component id 0 opening a
document) remains representable.
"""

from __future__ import annotations

import numpy as np

from .base import Codec, components_from_gaps, gaps_from_components, register
from .bitio import BitReader, BitWriter

__all__ = ["EliasGammaCodec", "EliasDeltaCodec"]


def _gamma_write(w: BitWriter, x: int) -> None:
    if x < 1:
        raise ValueError("gamma codes positive integers only")
    nbits = x.bit_length() - 1  # floor(log2 x)
    w.write_unary(nbits)
    w.write_bits(x, nbits)  # low bits (the leading 1 is implicit)


def _gamma_read(r: BitReader) -> int:
    nbits = r.read_unary()
    return (1 << nbits) | r.read_bits(nbits)


def _delta_write(w: BitWriter, x: int) -> None:
    if x < 1:
        raise ValueError("delta codes positive integers only")
    nbits = x.bit_length() - 1
    _gamma_write(w, nbits + 1)
    w.write_bits(x, nbits)


def _delta_read(r: BitReader) -> int:
    nbits = _gamma_read(r) - 1
    return (1 << nbits) | r.read_bits(nbits)


class _EliasBase(Codec):
    supports_zero = False
    _write = staticmethod(_gamma_write)
    _read = staticmethod(_gamma_read)

    def encode_doc(self, components: np.ndarray) -> bytes:
        gaps = gaps_from_components(components)
        w = BitWriter()
        for g in gaps:
            self._write(w, int(g) + 1)
        return w.getvalue()

    def decode_doc(self, buf: bytes, n: int) -> np.ndarray:
        r = BitReader(buf)
        gaps = np.fromiter((self._read(r) - 1 for _ in range(n)), dtype=np.uint32, count=n)
        return components_from_gaps(gaps)


@register("elias_gamma")
class EliasGammaCodec(_EliasBase):
    name = "elias_gamma"


@register("elias_delta")
class EliasDeltaCodec(_EliasBase):
    name = "elias_delta"
    _write = staticmethod(_delta_write)
    _read = staticmethod(_delta_read)
