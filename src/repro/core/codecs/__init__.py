"""Forward-index components codecs (paper §2).

Registry of integer codecs applied to d-gap-encoded component sequences:

* ``uncompressed`` — raw u16, the paper's baseline (16 bits/component)
* ``vbyte``        — Thiel & Heaps byte-aligned varint
* ``elias_gamma`` / ``elias_delta`` — Elias universal codes
* ``zeta``         — Boldi-Vigna zeta_k (k=3 default)
* ``streamvbyte``  — Lemire et al., 2-bit controls, 4 values/control
* ``dotvbyte``     — the paper's contribution: 1-bit controls, 8
                     values/control, per-document alignment, decode fused
                     with the inner product
* ``dotnibble``    — the paper's FUTURE WORK, implemented: sub-byte
                     {4,8,12,16}-bit codes, 2-bit controls (§4)
* ``bitpack``      — beyond-paper TPU-native fixed-width block packing
"""

from .base import (
    Codec,
    available_codecs,
    components_from_gaps,
    gaps_from_components,
    get_codec,
    register,
)
from .bitpack import BitpackCodec
from .dotnibble import DotNibbleCodec
from .dotvbyte import DotVByteCodec
from .elias import EliasDeltaCodec, EliasGammaCodec
from .streamvbyte import StreamVByteCodec
from .vbyte import VByteCodec
from .zeta import ZetaCodec

import numpy as np


@register("uncompressed")
class UncompressedCodec(Codec):
    """Raw u16 components — the paper's 16-bits-per-component baseline."""

    name = "uncompressed"
    supports_zero = True

    def encode_doc(self, components: np.ndarray) -> bytes:
        c = np.asarray(components, dtype=np.uint32)
        if np.any(c > 0xFFFF):
            raise ValueError("uncompressed codec stores 16-bit components")
        return c.astype("<u2").tobytes()

    def decode_doc(self, buf: bytes, n: int) -> np.ndarray:
        return np.frombuffer(buf, dtype="<u2", count=n).astype(np.uint32)


__all__ = [
    "Codec",
    "available_codecs",
    "components_from_gaps",
    "gaps_from_components",
    "get_codec",
    "register",
    "UncompressedCodec",
    "VByteCodec",
    "EliasGammaCodec",
    "EliasDeltaCodec",
    "ZetaCodec",
    "StreamVByteCodec",
    "DotVByteCodec",
    "DotNibbleCodec",
    "BitpackCodec",
]
