"""VByte (Thiel & Heaps, 1972) — classic byte-aligned varint.

Each integer x is stored in L+1 bytes b_0..b_L; the MSB of b_i is a
continuation flag (1 = more bytes follow). Decoding:
``x = sum_i (b_i mod 128) * 128**i`` (little-endian 7-bit groups).

Encoding is host-side numpy; ``decode_doc`` is the numpy reference and
``decode_gaps_np`` exposes the flat gap decode used by benchmarks.
"""

from __future__ import annotations

import numpy as np

from .base import Codec, components_from_gaps, gaps_from_components, register

__all__ = ["VByteCodec", "encode_gaps", "decode_gaps"]


def encode_gaps(gaps: np.ndarray) -> bytes:
    out = bytearray()
    for g in np.asarray(gaps, dtype=np.uint64):
        g = int(g)
        while True:
            byte = g & 0x7F
            g >>= 7
            if g:
                out.append(byte | 0x80)
            else:
                out.append(byte)
                break
    return bytes(out)


def decode_gaps(buf: bytes, n: int) -> np.ndarray:
    """Vectorised numpy decode of n varints from buf."""
    raw = np.frombuffer(buf, dtype=np.uint8)
    cont = (raw & 0x80) != 0
    payload = (raw & 0x7F).astype(np.uint64)
    # terminator positions = bytes whose continuation bit is clear
    ends = np.flatnonzero(~cont)
    if len(ends) < n:
        raise ValueError("buffer truncated")
    ends = ends[:n]
    starts = np.concatenate([[0], ends[:-1] + 1])
    values = np.zeros(n, dtype=np.uint64)
    # byte position within its varint = index - start_of_its_varint
    owner = np.zeros(len(raw), dtype=np.int64)
    owner[starts] = 1
    owner = np.cumsum(owner) - 1  # varint id per byte
    valid = owner < n
    idx = np.arange(len(raw), dtype=np.int64)
    within = idx - starts[np.clip(owner, 0, n - 1)]
    contrib = payload << (7 * within.astype(np.uint64))
    np.add.at(values, owner[valid], contrib[valid])
    return values.astype(np.uint32)


@register("vbyte")
class VByteCodec(Codec):
    name = "vbyte"
    supports_zero = True

    def encode_doc(self, components: np.ndarray) -> bytes:
        return encode_gaps(gaps_from_components(components))

    def decode_doc(self, buf: bytes, n: int) -> np.ndarray:
        return components_from_gaps(decode_gaps(buf, n))
