"""Bit-level I/O for bit-oriented codecs (Elias gamma/delta, Zeta).

Index *build* is host-side (numpy); only the query path is JAX. These
writers/readers are therefore plain-python/numpy, optimised for clarity
and vectorised where cheap. MSB-first bit order within each byte, matching
the classical descriptions in Elias (1975) and Boldi-Vigna (2005).
"""

from __future__ import annotations

import numpy as np

__all__ = ["BitWriter", "BitReader"]


class BitWriter:
    """Append-only MSB-first bit buffer."""

    def __init__(self) -> None:
        self._bits: list[int] = []

    def __len__(self) -> int:  # number of bits written
        return len(self._bits)

    def write_bit(self, bit: int) -> None:
        self._bits.append(bit & 1)

    def write_bits(self, value: int, width: int) -> None:
        """Write ``width`` low bits of ``value``, MSB first."""
        if width < 0:
            raise ValueError("width must be >= 0")
        for shift in range(width - 1, -1, -1):
            self._bits.append((value >> shift) & 1)

    def write_unary(self, n: int) -> None:
        """n zeros followed by a one (Elias gamma prefix convention)."""
        self._bits.extend([0] * n)
        self._bits.append(1)

    def getvalue(self) -> bytes:
        """Pack to bytes, zero-padded to a byte boundary."""
        bits = np.asarray(self._bits, dtype=np.uint8)
        pad = (-len(bits)) % 8
        if pad:
            bits = np.concatenate([bits, np.zeros(pad, dtype=np.uint8)])
        return np.packbits(bits).tobytes()


class BitReader:
    """MSB-first bit reader over a byte buffer."""

    def __init__(self, buf: bytes | np.ndarray) -> None:
        arr = np.frombuffer(bytes(buf), dtype=np.uint8)
        self._bits = np.unpackbits(arr)
        self._pos = 0

    @property
    def pos(self) -> int:
        return self._pos

    def remaining(self) -> int:
        return len(self._bits) - self._pos

    def read_bit(self) -> int:
        b = int(self._bits[self._pos])
        self._pos += 1
        return b

    def read_bits(self, width: int) -> int:
        if width == 0:
            return 0
        chunk = self._bits[self._pos : self._pos + width]
        self._pos += width
        value = 0
        for b in chunk:
            value = (value << 1) | int(b)
        return value

    def read_unary(self) -> int:
        """Count zeros up to (and consuming) the terminating one."""
        # vectorised scan for the next set bit
        rest = self._bits[self._pos :]
        nz = np.flatnonzero(rest)
        if len(nz) == 0:
            raise EOFError("unary code ran off the end of the buffer")
        n = int(nz[0])
        self._pos += n + 1
        return n
