"""DotVByte — the paper's contribution (§2.2).

A StreamVByte specialisation exploiting that forward-index components are
16-bit: a single control *bit* per value (0 → 1 byte, 1 → 2 bytes) lets
one control byte govern EIGHT values, and on x86 one
``_mm_shuffle_epi8`` decode 8 components into a 128-bit register, with
the scroll amount free via ``popcnt(control)``. Decode is fused with the
inner product (decode → gather query → FMA) and never materialises a
decoded buffer.

Per-document alignment (faithful to §2.2): only ``n8 = (nnz // 8) * 8``
components are compressed; the ≤7 remaining components are stored
uncompressed (u16 LE) after the data stream, so a control byte is never
shared between documents.

Layout of ``encode_doc`` output::

    [ controls: n8/8 bytes ][ data: n8 + popcount(controls) bytes ]
    [ remainder: 2 * (nnz - n8) bytes, raw u16 components (absolute) ]

This module is the host-side build + numpy reference; the TPU-adapted
fused decode+dot kernel lives in ``repro/kernels/dotvbyte_dot.py`` and
the batched jnp decode in ``repro/core/scoring.py``.
"""

from __future__ import annotations

import numpy as np

from .base import Codec, components_from_gaps, gaps_from_components, register

__all__ = [
    "DotVByteCodec",
    "encode_doc_arrays",
    "decode_doc_arrays",
    "control_bits",
]


def control_bits(gaps: np.ndarray) -> np.ndarray:
    """1 iff the gap needs two bytes. Gaps must fit 16 bits."""
    g = np.asarray(gaps, dtype=np.uint64)
    if np.any(g > 0xFFFF):
        raise ValueError("DotVByte requires 16-bit gaps (d <= 65536)")
    return (g > 0xFF).astype(np.uint8)


def encode_doc_arrays(components: np.ndarray) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """-> (controls u8[n8/8], data u8[n8+popcnt], remainder u16[<8]).

    ``remainder`` holds ABSOLUTE component ids (they are read directly,
    no gap decode, exactly as "processed normally" in the paper).
    """
    c = np.asarray(components, dtype=np.uint32)
    n = len(c)
    n8 = (n // 8) * 8
    gaps = gaps_from_components(c)[:n8]
    bits = control_bits(gaps)
    ctrl = np.packbits(bits.reshape(-1, 8), axis=1, bitorder="little").reshape(-1)
    # data stream: 1 or 2 LE bytes per gap
    lens = bits.astype(np.int64) + 1
    starts = np.concatenate([[0], np.cumsum(lens)[:-1]]) if n8 else np.zeros(0, np.int64)
    data = np.zeros(int(lens.sum()) if n8 else 0, dtype=np.uint8)
    g64 = gaps.astype(np.uint64)
    if n8:
        data[starts] = (g64 & 0xFF).astype(np.uint8)
        two = bits.astype(bool)
        data[starts[two] + 1] = ((g64[two] >> 8) & 0xFF).astype(np.uint8)
    rem = c[n8:].astype(np.uint16)
    return ctrl, data, rem


def decode_doc_arrays(
    ctrl: np.ndarray, data: np.ndarray, rem: np.ndarray
) -> np.ndarray:
    """Vectorised reference decode: controls+data -> absolute components."""
    n8 = len(ctrl) * 8
    if n8:
        bits = np.unpackbits(ctrl, bitorder="little").astype(np.int64)
        lens = bits + 1
        starts = np.concatenate([[0], np.cumsum(lens)[:-1]])
        dpad = np.concatenate([data, np.zeros(1, dtype=np.uint8)]).astype(np.uint32)
        gaps = dpad[starts] + (dpad[starts + 1] << 8) * bits.astype(np.uint32)
        comps = components_from_gaps(gaps)
    else:
        comps = np.zeros(0, dtype=np.uint32)
    return np.concatenate([comps, np.asarray(rem, dtype=np.uint32)])


@register("dotvbyte")
class DotVByteCodec(Codec):
    name = "dotvbyte"
    supports_zero = True

    def encode_doc(self, components: np.ndarray) -> bytes:
        ctrl, data, rem = encode_doc_arrays(components)
        return ctrl.tobytes() + data.tobytes() + rem.astype("<u2").tobytes()

    def decode_doc(self, buf: bytes, n: int) -> np.ndarray:
        n8 = (n // 8) * 8
        n_ctrl = n8 // 8
        raw = np.frombuffer(buf, dtype=np.uint8)
        ctrl = raw[:n_ctrl]
        popcnt = int(np.unpackbits(ctrl).sum()) if n_ctrl else 0
        n_data = n8 + popcnt
        data = raw[n_ctrl : n_ctrl + n_data]
        rem = raw[n_ctrl + n_data :].view("<u2")[: n - n8]
        return decode_doc_arrays(ctrl, data, rem)
