"""bitpack — beyond-paper TPU-native codec (Frame-of-Reference packing).

Byte-granular codes (VByte/StreamVByte/DotVByte) are a CPU sweet spot:
shuffles + scrolls. The TPU sweet spot is *lane-parallel fixed-width*
arithmetic, so this codec packs each block of ``block`` gaps at the
block's max bit-width b (NewPFor-style, without exceptions): decode is a
pure shift+mask with no data-dependent offsets at all — no prefix sum,
no gather for the decode itself. This realises the paper's future-work
direction ("sub-byte capability ... for small, frequent dgaps") in the
form the hardware wants.

Per-document layout (encode_doc)::

    [ widths: u8 per block ][ words: u32 LE, ceil(block*b/32) per block ]

Padding gaps inside the final block are 0 (decode to repeated component,
value-0-neutral in the fused dot — same trick as DotVByte alignment).
"""

from __future__ import annotations

import numpy as np

from .base import Codec, components_from_gaps, gaps_from_components, register

__all__ = ["BitpackCodec", "pack_block", "unpack_block"]


def _width(gaps: np.ndarray) -> int:
    m = int(gaps.max(initial=0))
    return max(int(m).bit_length(), 1)


def pack_block(gaps: np.ndarray, width: int) -> np.ndarray:
    """Pack len(gaps) values at ``width`` bits into u32 words (LSB-first)."""
    g = np.asarray(gaps, dtype=np.uint64)
    n = len(g)
    total_bits = n * width
    n_words = (total_bits + 31) // 32
    bitpos = np.arange(n, dtype=np.int64) * width
    words = np.zeros(n_words, dtype=np.uint64)
    wi = bitpos // 32
    off = (bitpos % 32).astype(np.uint64)
    lo = (g << off) & 0xFFFFFFFF
    # values can straddle a word boundary (width <= 32 → at most two words)
    np.add.at(words, wi, lo)
    straddle = (off + width) > 32
    np.add.at(words, wi[straddle] + 1, (g[straddle] >> (np.uint64(32) - off[straddle])))
    return words.astype(np.uint32)


def unpack_block(words: np.ndarray, width: int, n: int) -> np.ndarray:
    w = np.concatenate([words.astype(np.uint64), np.zeros(1, dtype=np.uint64)])
    bitpos = np.arange(n, dtype=np.int64) * width
    wi = bitpos // 32
    off = (bitpos % 32).astype(np.uint64)
    mask = np.uint64((1 << width) - 1)
    lo = w[wi] >> off
    hi = np.where(off > 0, w[wi + 1] << (np.uint64(32) - off), 0)
    return ((lo | hi) & mask).astype(np.uint32)


@register("bitpack")
class BitpackCodec(Codec):
    name = "bitpack"
    supports_zero = True

    def __init__(self, block: int = 32) -> None:
        # 32-gap blocks: fine enough that one outlier gap doesn't inflate
        # the whole block's width (classic FoR weakness; PFor exceptions
        # would go further — see EXPERIMENTS.md §Perf for the trade-off)
        if block % 32:
            raise ValueError("block must be a multiple of 32 for aligned words")
        self.block = block

    def encode_doc(self, components: np.ndarray) -> bytes:
        gaps = gaps_from_components(components)
        n = len(gaps)
        n_blocks = (n + self.block - 1) // self.block
        widths = bytearray()
        words = []
        for b in range(n_blocks):
            blk = gaps[b * self.block : (b + 1) * self.block]
            pad = self.block - len(blk)
            if pad:
                blk = np.concatenate([blk, np.zeros(pad, dtype=blk.dtype)])
            w = _width(blk)
            widths.append(w)
            words.append(pack_block(blk, w))
        body = np.concatenate(words).astype("<u4").tobytes() if words else b""
        return bytes(widths) + body

    def decode_doc(self, buf: bytes, n: int) -> np.ndarray:
        n_blocks = (n + self.block - 1) // self.block
        widths = np.frombuffer(buf[:n_blocks], dtype=np.uint8)
        words = np.frombuffer(buf[n_blocks:], dtype="<u4")
        gaps = np.zeros(n_blocks * self.block, dtype=np.uint32)
        pos = 0
        for b in range(n_blocks):
            w = int(widths[b])
            n_words = (self.block * w + 31) // 32
            gaps[b * self.block : (b + 1) * self.block] = unpack_block(
                words[pos : pos + n_words], w, self.block
            )
            pos += n_words
        return components_from_gaps(gaps[:n])
