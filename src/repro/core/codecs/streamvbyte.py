"""StreamVByte (Lemire, Kurz & Rupp, 2018).

General-purpose 32-bit variant, faithful to the original: a 2-bit control
per value records its byte length minus one (1..4 bytes, little-endian);
controls for four values share one control byte (value i of a quad uses
bits 2i..2i+1); the control stream is stored contiguously ahead of the
data stream so decodes are branch-free table lookups — on x86, a
``_mm_shuffle_epi8``; here, a vectorised prefix-sum + gather (see
``kernels/`` for the TPU treatment and DESIGN.md §3 for the adaptation).
"""

from __future__ import annotations

import numpy as np

from .base import Codec, components_from_gaps, gaps_from_components, register

__all__ = ["StreamVByteCodec", "encode_gaps", "decode_gaps", "split_streams"]


def _byte_lengths(gaps: np.ndarray) -> np.ndarray:
    g = np.asarray(gaps, dtype=np.uint64)
    n = np.ones(len(g), dtype=np.uint8)
    n[g > 0xFF] = 2
    n[g > 0xFFFF] = 3
    n[g > 0xFFFFFF] = 4
    return n


def encode_gaps(gaps: np.ndarray) -> bytes:
    """-> control stream ++ data stream (lengths derivable from n)."""
    g = np.asarray(gaps, dtype=np.uint64)
    n = len(g)
    lens = _byte_lengths(g)
    n_ctrl = (n + 3) // 4
    ctrl = np.zeros(n_ctrl, dtype=np.uint8)
    codes = (lens - 1).astype(np.uint8)
    for i in range(n):
        ctrl[i // 4] |= codes[i] << (2 * (i % 4))
    # data: little-endian bytes, lens[i] bytes per value
    le = g.astype("<u8").view(np.uint8).reshape(n, 8)
    data = bytearray()
    for i in range(n):
        data.extend(le[i, : lens[i]].tobytes())
    return ctrl.tobytes() + bytes(data)


def split_streams(buf: bytes, n: int) -> tuple[np.ndarray, np.ndarray]:
    n_ctrl = (n + 3) // 4
    raw = np.frombuffer(buf, dtype=np.uint8)
    return raw[:n_ctrl].copy(), raw[n_ctrl:].copy()


def decode_gaps(buf: bytes, n: int) -> np.ndarray:
    """Vectorised numpy decode (the scalar spec is the oracle in tests)."""
    if n == 0:
        return np.zeros(0, dtype=np.uint32)
    ctrl, data = split_streams(buf, n)
    # per-value 2-bit codes
    quads = np.arange(n)
    codes = (ctrl[quads // 4] >> (2 * (quads % 4))) & 0x3
    lens = codes.astype(np.int64) + 1
    starts = np.concatenate([[0], np.cumsum(lens)[:-1]])
    data_pad = np.concatenate([data, np.zeros(4, dtype=np.uint8)]).astype(np.uint64)
    vals = np.zeros(n, dtype=np.uint64)
    for b in range(4):
        take = lens > b
        vals[take] += data_pad[starts[take] + b] << (8 * b)
    return vals.astype(np.uint32)


@register("streamvbyte")
class StreamVByteCodec(Codec):
    name = "streamvbyte"
    supports_zero = True

    def encode_doc(self, components: np.ndarray) -> bytes:
        return encode_gaps(gaps_from_components(components))

    def decode_doc(self, buf: bytes, n: int) -> np.ndarray:
        return components_from_gaps(decode_gaps(buf, n))
