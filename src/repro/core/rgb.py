"""Recursive Graph Bisection (Dhulipala et al., KDD 2016) — §2 of the paper.

Re-orders *components* to minimise the log-gaps of every document's
component sequence, exactly the paper's formulation: components are the
"data" vertices of a bipartite graph, documents the "query" vertices.
The classic inverted-index use re-orders documents; here the roles are
swapped, but the algorithm is identical, so this implementation is
generic over the bipartite CSR it is given.

Vectorised numpy implementation of the standard algorithm:
recursively split the data-vertex ordering in half; for ``max_iters``
rounds compute per-vertex move gains from the degree-based cost model

    B(n, d) = d * log2(n / (d + 1))

sort both halves by gain and swap the top pairs while the combined gain
is positive; recurse until partitions reach ``leaf_size``.

Build-time/host-side only (like the Rust implementation the paper uses).
"""

from __future__ import annotations

import numpy as np

__all__ = ["recursive_graph_bisection", "apply_permutation_dense", "log_gap_cost"]


def _csr_from_docs(doc_comps: list[np.ndarray], dim: int):
    """component → docs inverted CSR from per-doc component arrays."""
    counts = np.zeros(dim, dtype=np.int64)
    for c in doc_comps:
        counts[c] += 1
    indptr = np.zeros(dim + 1, dtype=np.int64)
    np.cumsum(counts, out=indptr[1:])
    docs = np.zeros(int(indptr[-1]), dtype=np.int32)
    cursor = indptr[:-1].copy()
    for d, c in enumerate(doc_comps):
        docs[cursor[c]] = d
        cursor[c] += 1
    return indptr, docs


def _bits(n: int, deg: np.ndarray) -> np.ndarray:
    """Cost model B(n, d) = d * log2(n / (d+1)); deg may be float."""
    d = np.maximum(deg, 0.0)
    return d * np.log2(np.maximum(n, 2) / (d + 1.0))


def log_gap_cost(doc_comps: list[np.ndarray]) -> float:
    """Σ log2(gap+1) over all docs — the quantity RGB minimises (proxy)."""
    total = 0.0
    for c in doc_comps:
        if len(c) == 0:
            continue
        gaps = np.empty(len(c), dtype=np.int64)
        gaps[0] = c[0]
        gaps[1:] = np.diff(np.asarray(c, dtype=np.int64))
        total += float(np.log2(gaps + 1.0).sum())
    return total


def recursive_graph_bisection(
    doc_comps: list[np.ndarray],
    dim: int,
    *,
    max_iters: int = 20,
    leaf_size: int = 32,
    max_depth: int | None = None,
    seed: int = 0,
) -> np.ndarray:
    """Return permutation ``pi`` with new_component_id = pi[old_id].

    Components that never occur keep a stable order at the tail of each
    partition (they cost nothing either way).
    """
    indptr, adj_docs = _csr_from_docs(doc_comps, dim)
    n_docs = len(doc_comps)
    order = np.arange(dim, dtype=np.int64)  # order[rank] = component id
    rng = np.random.default_rng(seed)
    if max_depth is None:
        max_depth = max(int(np.ceil(np.log2(max(dim, 2)))), 1)

    degA = np.zeros(n_docs, dtype=np.float64)
    degB = np.zeros(n_docs, dtype=np.float64)

    def vertex_docs(vs: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """Concatenate inverted lists of vertices vs → (docs, owner_idx)."""
        lens = (indptr[vs + 1] - indptr[vs]).astype(np.int64)
        total = int(lens.sum())
        docs = np.zeros(total, dtype=np.int32)
        owner = np.zeros(total, dtype=np.int64)
        pos = 0
        for i, v in enumerate(vs):
            s, e = int(indptr[v]), int(indptr[v + 1])
            docs[pos : pos + (e - s)] = adj_docs[s:e]
            owner[pos : pos + (e - s)] = i
            pos += e - s
        return docs, owner

    def bisect(lo: int, hi: int, depth: int) -> None:
        n = hi - lo
        if n <= leaf_size or depth >= max_depth:
            return
        mid = lo + n // 2
        A = order[lo:mid]
        B = order[mid:hi]
        nA, nB = len(A), len(B)
        docsA, ownerA = vertex_docs(A)
        docsB, ownerB = vertex_docs(B)
        degA.fill(0.0)
        degB.fill(0.0)
        np.add.at(degA, docsA, 1.0)
        np.add.at(degB, docsB, 1.0)

        for _ in range(max_iters):
            # move gains: remove v from its side, add to the other
            curA = _bits(nA, degA) + _bits(nB, degB)
            gainA_per_doc = curA - (_bits(nA, degA - 1) + _bits(nB, degB + 1))
            gainB_per_doc = curA - (_bits(nA, degA + 1) + _bits(nB, degB - 1))
            gA = np.zeros(nA)
            gB = np.zeros(nB)
            np.add.at(gA, ownerA, gainA_per_doc[docsA])
            np.add.at(gB, ownerB, gainB_per_doc[docsB])
            ia = np.argsort(-gA)
            ib = np.argsort(-gB)
            pair_gain = gA[ia] + gB[ib[: len(ia)]] if nA <= nB else gA[ia[: len(ib)]] + gB[ib]
            k = int(np.searchsorted(-pair_gain, 0.0))  # first non-positive
            if k == 0:
                break
            sa, sb = ia[:k], ib[:k]
            # swap vertex sets
            A_swap = A[sa].copy()
            A[sa] = B[sb]
            B[sb] = A_swap
            # recompute adjacency slices + degrees for the new split
            docsA, ownerA = vertex_docs(A)
            docsB, ownerB = vertex_docs(B)
            degA.fill(0.0)
            degB.fill(0.0)
            np.add.at(degA, docsA, 1.0)
            np.add.at(degB, docsB, 1.0)

        order[lo:mid] = A
        order[mid:hi] = B
        bisect(lo, mid, depth + 1)
        bisect(mid, hi, depth + 1)

    bisect(0, dim, 0)
    pi = np.empty(dim, dtype=np.uint32)
    pi[order] = np.arange(dim, dtype=np.uint32)  # new id of old component
    return pi


def apply_permutation_dense(q_dense: np.ndarray, pi: np.ndarray) -> np.ndarray:
    """Permute a dense query vector: out[pi[c]] = q[c] (paper §2)."""
    out = np.zeros_like(q_dense)
    out[pi] = q_dense
    return out
