"""Seismic (Bruch et al., SIGIR 2024) — the sparse ANNS engine the paper
plugs its compressed forward index into (§3 "Application to Seismic").

Build pipeline (faithful to the published description):

1. **Static pruning** — each component's inverted list keeps only its
   top ``n_postings`` postings by value.
2. **Geometric blocking** — postings of a list are partitioned into
   blocks of ≤ ``block_size`` documents that are geometrically cohesive.
   We sort a list's documents by a global random projection of their
   sparse vectors and chunk (deterministic, cheap; the original uses a
   clustering pass — same role).
3. **Summaries** — each block stores an element-wise max "summary"
   vector, pruned to the smallest component set covering
   ``summary_mass`` of its value mass and quantised to fixedU8.

Query processing (``search``): take the query's top-``cut`` components;
walk their blocks; score a block's summary against the query; if the
upper-bound estimate beats ``heap_factor ×`` the current k-th best
score, score every document of the block *exactly* through the forward
index — this is where the decode speed of the components codec shows up,
and why the paper optimises it.

This module is the host-side (numpy) reference engine with faithful
heap semantics; the batched static-shape TPU serving path is the
``seismic`` entry of the engine registry (``repro.serve.engines.
seismic``, served through ``repro.serve.api`` — DESIGN.md §7).
"""

from __future__ import annotations

import dataclasses
import heapq

import numpy as np

from .codecs import get_codec
from .forward_index import ForwardIndex

__all__ = ["SeismicParams", "SeismicIndex", "exact_top_k", "recall_at_k"]


@dataclasses.dataclass(frozen=True)
class SeismicParams:
    n_postings: int = 4000  # λ: postings kept per inverted list
    block_size: int = 64  # max docs per block
    summary_mass: float = 0.5  # fraction of value mass kept in summaries
    summary_scale: float = 1.0 / 32.0  # fixedU8 quantisation step
    proj_dims: int = 1  # random-projection dims used for blocking
    seed: int = 0


def exact_top_k(fwd: ForwardIndex, q_dense: np.ndarray, k: int):
    scores = fwd.exact_scores(q_dense)
    ids = np.argpartition(-scores, min(k, len(scores) - 1))[:k]
    ids = ids[np.argsort(-scores[ids])]
    return ids, scores[ids]


def recall_at_k(true_ids: np.ndarray, got_ids: np.ndarray) -> float:
    return len(set(true_ids.tolist()) & set(got_ids.tolist())) / max(len(true_ids), 1)


@dataclasses.dataclass
class SeismicIndex:
    params: SeismicParams
    fwd: ForwardIndex
    dim: int
    # inverted structure: component → contiguous range of blocks
    comp_block_indptr: np.ndarray  # i64 [dim+1]
    # block → docs
    block_doc_indptr: np.ndarray  # i64 [n_blocks+1]
    block_docs: np.ndarray  # i32 [total_block_postings]
    # block → summary (sparse, quantised)
    summary_indptr: np.ndarray  # i64 [n_blocks+1]
    summary_comps: np.ndarray  # i32
    summary_vals: np.ndarray  # u8 (fixedU8, scale=params.summary_scale)
    # decoded-doc cache for the codec-timed rescoring path
    _decoded: dict | None = None

    # ------------------------------------------------------------------
    @property
    def n_blocks(self) -> int:
        return len(self.block_doc_indptr) - 1

    @staticmethod
    def build(fwd: ForwardIndex, params: SeismicParams = SeismicParams()) -> "SeismicIndex":
        rng = np.random.default_rng(params.seed)
        dim, n_docs = fwd.dim, fwd.n_docs

        # --- global random projection for geometric blocking ------------
        proj = rng.normal(size=(dim, params.proj_dims)).astype(np.float32)
        coords = np.zeros((n_docs, params.proj_dims), dtype=np.float32)
        for d in range(n_docs):
            c, v = fwd.doc(d)
            coords[d] = v @ proj[c]

        # --- inverted lists with static pruning -------------------------
        doc_of = np.repeat(np.arange(n_docs, dtype=np.int32), np.diff(fwd.offsets))
        comps = fwd.components
        vals = fwd.value_format.dequantise(fwd.values)
        order = np.argsort(comps, kind="stable")
        sorted_comps = comps[order]
        list_starts = np.searchsorted(sorted_comps, np.arange(dim + 1))

        comp_block_indptr = np.zeros(dim + 1, dtype=np.int64)
        block_doc_indptr = [0]
        block_docs: list[np.ndarray] = []
        summary_indptr = [0]
        summary_comps: list[np.ndarray] = []
        summary_vals: list[np.ndarray] = []

        n_blocks = 0
        for c in range(dim):
            s, e = int(list_starts[c]), int(list_starts[c + 1])
            comp_block_indptr[c] = n_blocks
            if e == s:
                continue
            idx = order[s:e]
            docs_c = doc_of[idx]
            vals_c = vals[idx]
            # static pruning: top-λ by value
            if len(docs_c) > params.n_postings:
                keep = np.argpartition(-vals_c, params.n_postings)[: params.n_postings]
                docs_c, vals_c = docs_c[keep], vals_c[keep]
            # geometric blocking: sort by projection, chunk
            by_geo = np.argsort(coords[docs_c, 0], kind="stable")
            docs_c = docs_c[by_geo]
            for b0 in range(0, len(docs_c), params.block_size):
                blk = np.sort(docs_c[b0 : b0 + params.block_size])
                block_docs.append(blk)
                block_doc_indptr.append(block_doc_indptr[-1] + len(blk))
                sc, sv = _summarise(fwd, blk, params)
                summary_comps.append(sc)
                summary_vals.append(sv)
                summary_indptr.append(summary_indptr[-1] + len(sc))
                n_blocks += 1
        comp_block_indptr[dim] = n_blocks

        return SeismicIndex(
            params=params,
            fwd=fwd,
            dim=dim,
            comp_block_indptr=comp_block_indptr,
            block_doc_indptr=np.asarray(block_doc_indptr, dtype=np.int64),
            block_docs=(
                np.concatenate(block_docs).astype(np.int32)
                if block_docs
                else np.zeros(0, np.int32)
            ),
            summary_indptr=np.asarray(summary_indptr, dtype=np.int64),
            summary_comps=(
                np.concatenate(summary_comps).astype(np.int32)
                if summary_comps
                else np.zeros(0, np.int32)
            ),
            summary_vals=(
                np.concatenate(summary_vals).astype(np.uint8)
                if summary_vals
                else np.zeros(0, np.uint8)
            ),
        )

    # ------------------------------------------------------------------
    def prepare_codec(self, codec_name: str) -> None:
        """Pre-encode every document with ``codec_name`` for rescoring."""
        from .layout import encode_docs

        self._decoded = {"codec": codec_name, "bufs": encode_docs(self.fwd, codec_name)}

    def _doc_components(self, d: int, codec_name: str) -> np.ndarray:
        """Decode doc d's components with the configured codec (timed path)."""
        if codec_name == "uncompressed" or self._decoded is None:
            s, e = int(self.fwd.offsets[d]), int(self.fwd.offsets[d + 1])
            return self.fwd.components[s:e]
        codec = get_codec(self._decoded["codec"])
        return codec.decode_doc(self._decoded["bufs"][d], self.fwd.nnz(d))

    def search(
        self,
        q_dense: np.ndarray,
        k: int = 10,
        heap_factor: float = 0.9,
        cut: int = 8,
        codec: str = "uncompressed",
    ) -> tuple[np.ndarray, np.ndarray]:
        """Faithful Seismic query processing (numpy reference engine)."""
        q = np.asarray(q_dense, dtype=np.float32)
        qc = np.flatnonzero(q)
        if len(qc) == 0:
            return np.zeros(0, np.int64), np.zeros(0, np.float32)
        qc = qc[np.argsort(-np.abs(q[qc]), kind="stable")][:cut]
        sscale = np.float32(self.params.summary_scale)
        vf = self.fwd.value_format

        heap: list[float] = []  # min-heap of top-k scores
        best: dict[int, float] = {}
        visited: set[int] = set()
        for c in qc:
            for b in range(
                int(self.comp_block_indptr[c]), int(self.comp_block_indptr[c + 1])
            ):
                ss, se = int(self.summary_indptr[b]), int(self.summary_indptr[b + 1])
                est = float(
                    q[self.summary_comps[ss:se]]
                    @ (self.summary_vals[ss:se].astype(np.float32) * sscale)
                )
                threshold = heap[0] if len(heap) == k else -np.inf
                if est <= heap_factor * threshold:
                    continue
                ds, de = int(self.block_doc_indptr[b]), int(self.block_doc_indptr[b + 1])
                for d in self.block_docs[ds:de]:
                    d = int(d)
                    if d in visited:
                        continue
                    visited.add(d)
                    comps = self._doc_components(d, codec)
                    s0, e0 = int(self.fwd.offsets[d]), int(self.fwd.offsets[d + 1])
                    score = float(q[comps] @ vf.dequantise(self.fwd.values[s0:e0]))
                    best[d] = score
                    if len(heap) < k:
                        heapq.heappush(heap, score)
                    elif score > heap[0]:
                        heapq.heapreplace(heap, score)
        ids = np.asarray(sorted(best, key=lambda d: -best[d])[:k], dtype=np.int64)
        return ids, np.asarray([best[int(d)] for d in ids], dtype=np.float32)

    # ------------------------------------------------------------------
    def index_bytes(self, codec_name: str = "uncompressed") -> dict[str, int]:
        """Index size accounting mirroring Table 2's GB column."""
        fwd_sizes = self.fwd.storage_bytes(codec_name)
        inverted = int(
            self.block_docs.nbytes
            + self.block_doc_indptr.nbytes
            + self.comp_block_indptr.nbytes
        )
        summaries = int(
            self.summary_comps.nbytes * 2 // 4 + self.summary_vals.nbytes
        )  # comps storable as u16
        return {
            "forward_components": fwd_sizes["components"],
            "forward_values": fwd_sizes["values"],
            "forward_offsets": fwd_sizes["offsets"],
            "inverted": inverted,
            "summaries": summaries,
            "total": fwd_sizes["components"]
            + fwd_sizes["values"]
            + fwd_sizes["offsets"]
            + inverted
            + summaries,
        }


def _summarise(fwd: ForwardIndex, docs: np.ndarray, params: SeismicParams):
    """Element-wise-max summary, α-mass pruned, fixedU8 quantised."""
    spans = [
        (int(fwd.offsets[d]), int(fwd.offsets[d + 1])) for d in np.asarray(docs)
    ]
    cs = np.concatenate([fwd.components[s:e] for s, e in spans]).astype(np.int32)
    vs = fwd.value_format.dequantise(
        np.concatenate([fwd.values[s:e] for s, e in spans])
    )
    order = np.argsort(cs, kind="stable")
    cs, vs = cs[order], vs[order]
    first = np.ones(len(cs), dtype=bool)
    first[1:] = cs[1:] != cs[:-1]
    starts = np.flatnonzero(first)
    comps = cs[starts]
    vals = np.maximum.reduceat(vs, starts) if len(starts) else vs[:0]
    order = np.argsort(-vals, kind="stable")
    comps, vals = comps[order], vals[order]
    mass = np.cumsum(vals)
    keep = int(np.searchsorted(mass, params.summary_mass * mass[-1])) + 1 if len(vals) else 0
    comps, vals = comps[:keep], vals[:keep]
    q = np.clip(np.round(vals / params.summary_scale), 0, 255).astype(np.uint8)
    by_comp = np.argsort(comps, kind="stable")
    return comps[by_comp], q[by_comp]
