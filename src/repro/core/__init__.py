"""Core: the paper's contribution — forward-index compression for
learned sparse retrieval, plus the Seismic ANNS engine it plugs into."""

from .forward_index import (
    VALUE_FORMATS,
    ForwardIndex,
    PackedBlocks,
    pack_forward_index,
)

__all__ = [
    "VALUE_FORMATS",
    "ForwardIndex",
    "PackedBlocks",
    "pack_forward_index",
]
