"""Core: the paper's contribution — forward-index compression for
learned sparse retrieval, plus the two ANNS engines it plugs into
(inverted-index Seismic and graph-based HNSW)."""

from .forward_index import (
    VALUE_FORMATS,
    ForwardIndex,
    PackedBlocks,
    pack_forward_index,
)
from .hnsw import HNSWIndex, HNSWParams

__all__ = [
    "VALUE_FORMATS",
    "ForwardIndex",
    "HNSWIndex",
    "HNSWParams",
    "PackedBlocks",
    "pack_forward_index",
]
