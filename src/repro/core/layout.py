"""Codec-pluggable packed layouts (DESIGN.md §3) — the ONE place a gap
stream becomes device arrays.

A ``ForwardIndex`` reaches the TPU in two fixed-shape forms:

* **block form** ``[B, T]`` — documents greedily packed into
  self-contained blocks for the full-scan / Pallas path
  (``pack_blocks`` → ``PackedBlocks``);
* **row form** ``[N+1, L]`` — one fixed-capacity row per document for
  the serve-engine candidate-rescoring path (``pack_rows`` →
  ``PackedRows``; the ``+1`` row is the all-zero sentinel that absorbs
  out-of-range gathers).

Both forms reduce to the same primitive: a 2-D matrix of d-gaps, one
row per block/document, padded with zeros.  A ``LayoutCodec`` turns
that matrix into named byte/word streams (and back, in jnp, on
device).  Registering a codec here makes it available to *every*
consumer — ``pack_forward_index``, the sharded scan, every registry
engine — which is what lets ``RetrieverConfig(codec=…)`` swap the
forward-index wire format without touching the serving code.

Gap conventions (DESIGN.md §3):

* block rows: the fragment-first gap is forced to 0 and the absolute
  component lives out-of-band in ``start_abs`` → every block decodes
  independently;
* doc rows: the first gap IS the absolute component (per-document
  alignment), so ``cumsum`` alone rebuilds the ids.

``pad_stack`` is the shared shard-stacking helper: pad every field to
the across-shard max shape and stack with a leading shard dim — used by
``pack_blocks_sharded`` (doc-aligned scan) and
``serve.api.build_shard_arrays`` (every engine's sharded search).

Tile-shape / DMA contract (DESIGN.md §3): every stream a kernel DMAs is
laid out lane-aligned AT PACK TIME — trailing dims of the control
(``ctrl``), data (``data``, via ``_byte_scatter``) and word (``words``)
streams are padded to a ``LANE_MULTIPLE`` (=128) multiple, and the row
capacity ``l_max`` is itself rounded to a lane multiple — so a Mosaic
tile of any stream starts on a lane boundary and reads whole aligned
words.  Decoders therefore receive *wider-than-tight* control streams
and must slice their gap output to the logical length (``block_size`` /
``l_max``); ``scoring.decode_block_gaps`` and the ``LayoutCodec.decode``
methods slice the control stream *tight before decoding* so the padding
costs bytes, never decode work.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Mapping, Sequence

import numpy as np

from . import values as value_codecs
from .codecs import get_codec
from .codecs.bitpack import pack_block
from .codecs.dotvbyte import control_bits
from .forward_index import ForwardIndex, PackedBlocks, ValueFormat

__all__ = [
    "LayoutCodec",
    "register_layout",
    "get_layout",
    "available_layouts",
    "PackedRows",
    "pack_blocks",
    "pack_rows",
    "pack_blocks_sharded",
    "pad_stack",
    "encode_docs",
    "BLOCK_PAD_VALUES",
    "LANE_MULTIPLE",
]

_LANES = 128  # TPU lane count: every DMA'd stream width is padded to this

#: public name for the pack-time stream alignment (DESIGN.md §3)
LANE_MULTIPLE = _LANES


def _round_up(n: int, m: int) -> int:
    return (n + m - 1) // m * m


def _lane_pad(arr: np.ndarray) -> np.ndarray:
    """Pad a stream's trailing dim to the lane multiple (pack-time
    alignment — kernels then read whole aligned words)."""
    pad = (-arr.shape[-1]) % _LANES
    if pad == 0:
        return arr
    widths = [(0, 0)] * (arr.ndim - 1) + [(0, pad)]
    return np.pad(arr, widths)


# ---------------------------------------------------------------------------
# layout-codec registry
# ---------------------------------------------------------------------------


class LayoutCodec:
    """Vectorised gap-matrix ⇄ device-stream transform for one codec.

    ``encode`` consumes a padded u32 gap matrix ``[R, T]`` (zeros past
    each row's payload) and returns named numpy arrays, all with leading
    dim R.  ``decode`` is the jnp inverse used on device; it must be
    jit-traceable and return i32 gaps ``[R, T]``.  ``decode_free``
    codecs store absolute component ids directly and skip decode on the
    hot path (the packers special-case them)."""

    name: str = "abstract"
    #: row length must be a multiple of this (control-byte grouping)
    block_multiple: int = 1
    #: stores absolute components; no per-query decode work
    decode_free: bool = False

    def encode(self, gaps: np.ndarray) -> Dict[str, np.ndarray]:
        raise NotImplementedError

    def decode(self, arrays: Mapping, block_size: int):
        raise NotImplementedError

    # -- shared encode plumbing ----------------------------------------
    @staticmethod
    def _byte_scatter(
        gaps: np.ndarray, lens: np.ndarray, n_over_read: int
    ) -> np.ndarray:
        """Scatter each gap's ``lens`` LE bytes into a dense [R, DP]
        stream (DP = max row length + over-read, lane-padded)."""
        R, T = gaps.shape
        ends = np.cumsum(lens, axis=1)
        starts = ends - lens
        max_end = int(np.max(ends[:, -1], initial=0)) if T else 0
        DP = max(_round_up(max_end + n_over_read, _LANES), _LANES)
        data = np.zeros((R, DP), dtype=np.uint8)
        rows = np.broadcast_to(np.arange(R)[:, None], (R, T))
        g64 = gaps.astype(np.uint64)
        for b in range(int(lens.max(initial=1))):
            sel = lens > b
            data[rows[sel], starts[sel] + b] = (g64[sel] >> (8 * b)).astype(np.uint8)
        return data


_LAYOUTS: Dict[str, Callable[[], LayoutCodec]] = {}


def register_layout(name: str):
    def deco(factory: Callable[[], LayoutCodec]):
        _LAYOUTS[name] = factory
        return factory

    return deco


def get_layout(name: str) -> LayoutCodec:
    try:
        return _LAYOUTS[name]()
    except KeyError:
        raise ValueError(
            f"no packed layout for codec {name!r}; have {sorted(_LAYOUTS)}"
        ) from None


def available_layouts() -> list[str]:
    return sorted(_LAYOUTS)


@register_layout("uncompressed")
class UncompressedLayout(LayoutCodec):
    """Raw gaps as i32 — the packers replace them with absolute
    components (decode-free hot path, the paper's baseline)."""

    name = "uncompressed"
    decode_free = True

    def encode(self, gaps: np.ndarray) -> Dict[str, np.ndarray]:
        return {"gaps": gaps.astype(np.int32)}

    def decode(self, arrays: Mapping, block_size: int):
        return arrays["gaps"]


@register_layout("dotvbyte")
class DotVByteLayout(LayoutCodec):
    """1-bit controls, 8 gaps per control byte, 1–2 data bytes per gap
    (paper §2.2). Requires 16-bit gaps."""

    name = "dotvbyte"
    block_multiple = 8

    def encode(self, gaps: np.ndarray) -> Dict[str, np.ndarray]:
        R, T = gaps.shape
        bits = control_bits(gaps.reshape(-1)).reshape(R, T)
        ctrl = np.packbits(
            bits.reshape(R, T // 8, 8), axis=2, bitorder="little"
        ).reshape(R, T // 8)
        lens = bits.astype(np.int64) + 1
        return {"ctrl": _lane_pad(ctrl), "data": self._byte_scatter(gaps, lens, 1)}

    def decode(self, arrays: Mapping, block_size: int):
        from .scoring import decode_gaps_dotvbyte

        ctrl = arrays["ctrl"]
        if block_size:  # lane-padded ctrl: slice tight before decoding
            ctrl = ctrl[:, : block_size // 8]
        return decode_gaps_dotvbyte(ctrl, arrays["data"])


@register_layout("streamvbyte")
class StreamVByteLayout(LayoutCodec):
    """2-bit controls, 4 gaps per control byte, 1–4 data bytes per gap
    (Lemire et al.) — the paper's headline general-purpose codec, full
    32-bit gap range (no 16-bit ceiling)."""

    name = "streamvbyte"
    block_multiple = 4

    def encode(self, gaps: np.ndarray) -> Dict[str, np.ndarray]:
        R, T = gaps.shape
        g = gaps.astype(np.uint64)
        codes = np.zeros((R, T), dtype=np.uint8)
        codes[g > 0xFF] = 1
        codes[g > 0xFFFF] = 2
        codes[g > 0xFFFFFF] = 3
        q = codes.reshape(R, T // 4, 4).astype(np.uint8)
        ctrl = (q[..., 0] | (q[..., 1] << 2) | (q[..., 2] << 4) | (q[..., 3] << 6))
        lens = codes.astype(np.int64) + 1
        return {"ctrl": _lane_pad(ctrl), "data": self._byte_scatter(gaps, lens, 3)}

    def decode(self, arrays: Mapping, block_size: int):
        from .scoring import decode_gaps_streamvbyte

        ctrl = arrays["ctrl"]
        if block_size:  # lane-padded ctrl: slice tight before decoding
            ctrl = ctrl[:, : block_size // 4]
        return decode_gaps_streamvbyte(ctrl, arrays["data"])


@register_layout("bitpack")
class BitpackLayout(LayoutCodec):
    """Per-row fixed-width word packing (TPU-native shift+mask decode);
    words are packed by the single ``codecs.bitpack.pack_block``
    implementation at each row's own width."""

    name = "bitpack"

    def encode(self, gaps: np.ndarray) -> Dict[str, np.ndarray]:
        R, T = gaps.shape
        widths = np.maximum(
            [int(g.max(initial=0)).bit_length() for g in gaps], 1
        ).astype(np.int32)
        w_max = int(widths.max(initial=1))
        n_words = (T * w_max + 31) // 32
        words = np.zeros((R, n_words), dtype=np.uint32)
        for r in range(R):
            wr = pack_block(gaps[r], int(widths[r]))
            words[r, : len(wr)] = wr
        return {"words": _lane_pad(words), "widths": widths}

    def decode(self, arrays: Mapping, block_size: int):
        from .scoring import decode_gaps_bitpack

        return decode_gaps_bitpack(arrays["words"], arrays["widths"], block_size)


# ---------------------------------------------------------------------------
# block form  [B, T]
# ---------------------------------------------------------------------------

#: pad values for stacking block arrays across shards
BLOCK_PAD_VALUES = {"seg": -1, "doc_ids": -1}


def _fragments(
    fwd: ForwardIndex, block_size: int, max_docs: int
) -> list[list[tuple[int, int, int]]]:
    """Greedy first-fit packing of doc fragments into blocks.

    Returns per-block lists of (doc_id, start_nnz, end_nnz) fragments.
    A block closes when T components or D doc slots are used."""
    blocks: list[list[tuple[int, int, int]]] = []
    cur: list[tuple[int, int, int]] = []
    used = 0
    for d in range(fwd.n_docs):
        n = fwd.nnz(d)
        pos = 0
        while pos < n:
            if used == block_size or len(cur) == max_docs:
                blocks.append(cur)
                cur, used = [], 0
            take = min(n - pos, block_size - used)
            cur.append((d, pos, pos + take))
            used += take
            pos += take
    if cur:
        blocks.append(cur)
    return blocks


def _resolve_absolute(gaps, seg, start_pos, start_abs):
    """numpy mirror of ``scoring.components_from_gaps`` for the
    decode-free layout: gaps + out-of-band absolutes → component ids."""
    D = start_pos.shape[1]
    t = np.cumsum(gaps.astype(np.int64), axis=1)
    tp = np.take_along_axis(t, start_pos.astype(np.int64), axis=1)
    segc = np.clip(seg, 0, D - 1).astype(np.int64)
    base = np.take_along_axis(start_abs.astype(np.int64), segc, axis=1)
    tseg = np.take_along_axis(tp, segc, axis=1)
    return np.where(seg >= 0, base + t - tseg, 0).astype(np.int32)


def pack_blocks(
    fwd: ForwardIndex,
    codec: str = "dotvbyte",
    block_size: int = 512,
    max_docs_per_block: int | None = None,
    seg_dtype=np.int32,
    vq: str = "f16",
    vq_clip: tuple[float, float] | None = None,
) -> PackedBlocks:
    """Build the TPU packed block layout under any registered codec.

    ``seg_dtype=np.int8`` is the §Perf "metadata slimming" layout: the
    per-element doc-slot id fits i8 whenever max_docs_per_block ≤ 127,
    cutting the dominant metadata stream 4×.

    ``vq`` selects the VALUE codec (DESIGN.md §12, ``core/values``):
    ``"f16"`` stores the raw storage dtype (today's layout, bit-exact);
    the quantized codecs replace ``vals`` with u8 codes (width divided
    by the pack factor) plus per-block clip ranges (``vq_lo``/
    ``vq_scale``) or a shared ``vq_codebook``.  ``vq_clip`` overrides
    the fitted ranges with one global (lo, hi) in STORAGE units — the
    QAT export path."""
    value_codecs.check_vq(vq)
    lc = get_layout(codec)
    if block_size % 128:
        raise ValueError("block_size must be a multiple of 128 (TPU lanes)")
    T = block_size
    D = max_docs_per_block or T // 8
    if np.dtype(seg_dtype) == np.int8 and D > 127:
        raise ValueError("int8 seg needs max_docs_per_block <= 127")
    frags = _fragments(fwd, T, D)
    B = len(frags)

    seg = np.full((B, T), -1, dtype=seg_dtype)
    start_pos = np.zeros((B, D), dtype=np.int32)
    start_abs = np.zeros((B, D), dtype=np.int32)
    vals = np.zeros((B, T), dtype=fwd.values.dtype)
    doc_ids = np.full((B, D), -1, dtype=np.int32)
    gaps_all = np.zeros((B, T), dtype=np.uint32)

    for b, frag_list in enumerate(frags):
        pos = 0
        for s_idx, (d, lo, hi) in enumerate(frag_list):
            off = int(fwd.offsets[d])
            comps = fwd.components[off + lo : off + hi].astype(np.int64)
            n = len(comps)
            g = np.empty(n, dtype=np.uint32)
            g[0] = 0  # fragment-first gap forced to 0; absolute out-of-band
            g[1:] = np.diff(comps).astype(np.uint32)
            gaps_all[b, pos : pos + n] = g
            seg[b, pos : pos + n] = s_idx
            vals[b, pos : pos + n] = fwd.values[off + lo : off + hi]
            start_pos[b, s_idx] = pos
            start_abs[b, s_idx] = comps[0]
            doc_ids[b, s_idx] = d
            pos += n

    vals, vq_extras = value_codecs.encode_block_values(vals, seg, vq, clip=vq_clip)
    out = PackedBlocks(
        codec=codec,
        block_size=T,
        n_docs=fwd.n_docs,
        dim=fwd.dim,
        value_format=fwd.value_format,
        seg=seg,
        start_pos=start_pos,
        start_abs=start_abs,
        vals=vals,
        doc_ids=doc_ids,
        vq=vq,
    )
    for field, arr in vq_extras.items():
        setattr(out, field, arr)
    if lc.decode_free:
        out.comps = _resolve_absolute(gaps_all, seg, start_pos, start_abs)
        return out
    for field, arr in lc.encode(gaps_all).items():
        setattr(out, field, arr)
    return out


def pack_blocks_sharded(
    fwd: ForwardIndex,
    n_shards: int,
    codec: str = "dotvbyte",
    block_size: int = 512,
    seg_dtype=np.int32,
) -> tuple[dict, int]:
    """Doc-aligned sharded packing (§Perf opt1, EXPERIMENTS.md).

    Splits documents into ``n_shards`` contiguous equal ranges, packs
    each range independently with range-LOCAL doc ids, and ``pad_stack``s
    every array to a leading shard dim. Feed to
    ``scoring.make_doc_aligned_scan`` with the arrays sharded over the
    mesh. Returns (arrays, docs_local)."""
    n = fwd.n_docs
    docs_local = (n + n_shards - 1) // n_shards
    dicts = []
    for s in range(n_shards):
        lo, hi = s * docs_local, min((s + 1) * docs_local, n)
        sub_docs = [fwd.doc(d) for d in range(lo, hi)]
        while len(sub_docs) < docs_local:  # tail padding: empty doc
            sub_docs.append((np.array([0], np.uint32), np.array([0.0], np.float32)))
        sub = ForwardIndex.from_docs(sub_docs, fwd.dim, value_format=fwd.value_format.name)
        dicts.append(
            pack_blocks(sub, codec=codec, block_size=block_size, seg_dtype=seg_dtype).as_dict()
        )
    return pad_stack(dicts, BLOCK_PAD_VALUES), docs_local


# ---------------------------------------------------------------------------
# row form  [N+1, L]
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class PackedRows:
    """Fixed-capacity per-document rows for candidate rescoring.

    ``vals_rows``/``nnz_rows`` are codec-independent; ``payload`` holds
    the codec streams keyed engine-style (``comps_rows`` |
    ``ctrl_rows`` + ``data_rows``). Row N is the all-zero sentinel."""

    codec: str
    n_docs: int
    dim: int
    l_max: int
    value_format: ValueFormat
    vals_rows: np.ndarray
    nnz_rows: np.ndarray
    payload: dict[str, np.ndarray]
    #: value codec (DESIGN.md §12): quantized vqs store codes in
    #: ``vals_rows`` (u8, width l_max // code_factor) and their clip
    #: ranges / codebook in ``payload``
    vq: str = "f16"

    def arrays(self) -> dict[str, np.ndarray]:
        return {"vals_rows": self.vals_rows, "nnz_rows": self.nnz_rows, **self.payload}


def _row_gap_matrix(fwd: ForwardIndex, l_max: int):
    """CSR → padded [N+1, l_max] gap/value matrices, vectorised.

    Row-first gaps are ABSOLUTE (per-document alignment): cumsum alone
    rebuilds component ids; padding gaps are 0."""
    N = fwd.n_docs
    nnz = np.diff(fwd.offsets).astype(np.int64)
    total = int(fwd.total_nnz)
    doc_of = np.repeat(np.arange(N), nnz)
    pos = np.arange(total) - np.repeat(fwd.offsets[:-1].astype(np.int64), nnz)
    comps = fwd.components.astype(np.int64)
    gaps_flat = np.zeros(total, dtype=np.int64)
    if total:
        gaps_flat[1:] = comps[1:] - comps[:-1]
        starts = fwd.offsets[:-1][nnz > 0].astype(np.int64)
        gaps_flat[starts] = comps[starts]
    gaps = np.zeros((N + 1, l_max), dtype=np.uint32)
    gaps[doc_of, pos] = gaps_flat
    vals = np.zeros((N + 1, l_max), dtype=fwd.values.dtype)
    vals[doc_of, pos] = fwd.values
    return gaps, vals, np.concatenate([nnz, [0]]).astype(np.int32)


def pack_rows(
    fwd: ForwardIndex,
    codec: str = "uncompressed",
    l_max: int | None = None,
    doc_range: tuple[int, int] | None = None,
    vq: str = "f16",
    vq_clip: tuple[float, float] | None = None,
) -> PackedRows:
    """Build the per-document row layout under any registered codec.

    ``doc_range=(lo, hi)`` packs only that contiguous doc slice with
    shard-LOCAL row ids (row 0 = doc ``lo``) — the per-shard pack-offset
    path of the sharded artifact layer (DESIGN.md §9). Doc-row gaps are
    per-document (the first gap is the absolute component), so a row
    packed from a slice is byte-identical to the same doc's row in a
    whole-collection pack at equal row capacity.

    ``vq`` selects the VALUE codec (DESIGN.md §12, ``core/values``):
    quantized vqs replace ``vals_rows`` with u8 codes and add the clip
    ranges / codebook to the payload.  Scalar-quant clip ranges are
    fitted per row on each row's own live values, so the per-document
    byte-parity invariant above holds for value bytes too (PQ codebooks
    are per-build — see DESIGN.md §12).  ``vq_clip=(lo, hi)`` overrides
    the fit with one global range in STORAGE units (the QAT export
    path); the row capacity rounds to ``LANE_MULTIPLE · code_factor``
    so stored code widths stay lane-aligned."""
    value_codecs.check_vq(vq)
    if doc_range is not None:
        fwd = fwd.slice(*doc_range)
    lc = get_layout(codec)
    nnz_max = int(np.diff(fwd.offsets).max(initial=1))
    cap = max(l_max or 0, nnz_max, 1)
    # lane-aligned row capacity (DMA contract, DESIGN.md §3): a row tile
    # of any stream starts on a lane boundary; also covers every codec's
    # control grouping (8).  Sub-byte / PQ value codecs round by their
    # pack factor too, so the STORED code width is itself lane-aligned.
    cap = _round_up(cap, _LANES * value_codecs.code_factor(vq))
    gaps, vals_rows, nnz_rows = _row_gap_matrix(fwd, cap)
    if lc.decode_free:
        comps = np.cumsum(gaps.astype(np.int64), axis=1)
        live = np.arange(cap)[None, :] < nnz_rows[:, None]
        payload = {"comps_rows": np.where(live, comps, 0).astype(np.int32)}
    else:
        payload = {f"{k}_rows": v for k, v in lc.encode(gaps).items()}
    vals_rows, vq_extras = value_codecs.encode_rows_values(
        vals_rows, nnz_rows, vq, clip=vq_clip
    )
    payload.update(vq_extras)
    return PackedRows(
        codec=codec,
        n_docs=fwd.n_docs,
        dim=fwd.dim,
        l_max=cap,
        value_format=fwd.value_format,
        vals_rows=vals_rows,
        nnz_rows=nnz_rows,
        payload=payload,
        vq=vq,
    )


# ---------------------------------------------------------------------------
# shared shard stacking + host-side doc encoding
# ---------------------------------------------------------------------------


def pad_stack(
    dicts: Sequence[Mapping[str, np.ndarray]],
    pad_values: Mapping[str, int] | None = None,
) -> dict[str, np.ndarray]:
    """Stack per-shard array dicts with a leading shard dim, padding
    every axis to the across-shard max (block counts and data-stream
    widths legitimately differ between shards)."""
    pad_values = pad_values or {}
    keys = list(dicts[0])
    for d in dicts[1:]:
        if list(d) != keys:
            raise ValueError("shard dicts must share the same fields")
    out: dict[str, np.ndarray] = {}
    for k in keys:
        arrs = [np.asarray(d[k]) for d in dicts]
        nd = arrs[0].ndim
        target = tuple(max(a.shape[i] for a in arrs) for i in range(nd))
        buf = np.full((len(arrs), *target), pad_values.get(k, 0), dtype=arrs[0].dtype)
        for s, a in enumerate(arrs):
            buf[(s, *(slice(0, d) for d in a.shape))] = a
        out[k] = buf
    return out


def encode_docs(fwd: ForwardIndex, codec_name: str) -> list[bytes]:
    """Host-side per-document byte encoding (reference engine / size
    accounting) through the codec registry — one implementation for
    ``SeismicIndex.prepare_codec`` and friends."""
    codec = get_codec(codec_name)
    offs = fwd.offsets
    return [
        codec.encode_doc(fwd.components[int(s) : int(e)])
        for s, e in zip(offs[:-1], offs[1:])
    ]
