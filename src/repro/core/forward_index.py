"""The forward index (paper §1-§2): doc_id → sparse vector, CSR layout.

Three arrays, exactly as the paper describes: ``components`` (nonzero
coordinate ids), ``values`` (their values), ``offsets`` (row pointers).
Values may be stored as f32, f16 or fixedU8 (8-bit fixed point; the
paper's "fixedU8" column in Table 2) — quantisation is applied at build
time and dequantisation fused into the scoring path.

Also defines the TPU *packed block layout* used by the jnp scorers and
the Pallas kernels: documents are split into self-contained blocks of
``block_size`` components. Each document fragment opens with its
absolute first component stored out-of-band (``start_abs``), so every
block decodes independently — the TPU analogue of DotVByte's
per-document alignment (DESIGN.md §3).
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

import numpy as np

from .codecs import get_codec
from .codecs.base import gaps_from_components
from .codecs.bitpack import pack_block
from .codecs.dotvbyte import control_bits

__all__ = [
    "ValueFormat",
    "ForwardIndex",
    "PackedBlocks",
    "pack_forward_index",
    "VALUE_FORMATS",
]


@dataclasses.dataclass(frozen=True)
class ValueFormat:
    """Storage format for the values array."""

    name: str
    dtype: np.dtype
    scale: float  # dequantised value = stored * scale

    def quantise(self, v: np.ndarray) -> np.ndarray:
        if self.name == "fixedu8":
            q = np.clip(np.round(v / self.scale), 0, 255)
            return q.astype(np.uint8)
        return v.astype(self.dtype)

    def dequantise(self, q: np.ndarray) -> np.ndarray:
        return q.astype(np.float32) * np.float32(self.scale)


VALUE_FORMATS = {
    "f32": ValueFormat("f32", np.dtype(np.float32), 1.0),
    "f16": ValueFormat("f16", np.dtype(np.float16), 1.0),
    # U3F5-style fixed point: range [0, 8), resolution 1/32 — covers
    # SPLADE/LILSR activation ranges (positive, < 8).
    "fixedu8": ValueFormat("fixedu8", np.dtype(np.uint8), 1.0 / 32.0),
}


@dataclasses.dataclass
class ForwardIndex:
    """Uncompressed CSR forward index (the paper's baseline layout)."""

    components: np.ndarray  # u32 [total_nnz], sorted per doc
    values: np.ndarray  # stored dtype [total_nnz]
    offsets: np.ndarray  # i64 [n_docs + 1]
    dim: int
    value_format: ValueFormat = VALUE_FORMATS["f32"]

    # -- construction -----------------------------------------------------
    @staticmethod
    def from_docs(
        docs: Iterable[tuple[np.ndarray, np.ndarray]],
        dim: int,
        value_format: str = "f32",
    ) -> "ForwardIndex":
        vf = VALUE_FORMATS[value_format]
        comps, vals, offs = [], [], [0]
        for c, v in docs:
            c = np.asarray(c, dtype=np.uint32)
            v = np.asarray(v, dtype=np.float32)
            order = np.argsort(c, kind="stable")
            comps.append(c[order])
            vals.append(vf.quantise(v[order]))
            offs.append(offs[-1] + len(c))
        return ForwardIndex(
            components=np.concatenate(comps) if comps else np.zeros(0, np.uint32),
            values=np.concatenate(vals) if vals else np.zeros(0, vf.dtype),
            offsets=np.asarray(offs, dtype=np.int64),
            dim=dim,
            value_format=vf,
        )

    # -- accessors ---------------------------------------------------------
    @property
    def n_docs(self) -> int:
        return len(self.offsets) - 1

    @property
    def total_nnz(self) -> int:
        return int(self.offsets[-1])

    def nnz(self, i: int) -> int:
        return int(self.offsets[i + 1] - self.offsets[i])

    def doc(self, i: int) -> tuple[np.ndarray, np.ndarray]:
        s, e = int(self.offsets[i]), int(self.offsets[i + 1])
        return self.components[s:e], self.value_format.dequantise(self.values[s:e])

    def doc_raw_values(self, i: int) -> np.ndarray:
        s, e = int(self.offsets[i]), int(self.offsets[i + 1])
        return self.values[s:e]

    def iter_docs(self) -> Iterable[tuple[np.ndarray, np.ndarray]]:
        for i in range(self.n_docs):
            yield self.doc(i)

    def densify(self, i: int) -> np.ndarray:
        c, v = self.doc(i)
        out = np.zeros(self.dim, dtype=np.float32)
        out[c] = v
        return out

    # -- exact scoring (numpy oracle for everything downstream) ------------
    def exact_scores(self, q_dense: np.ndarray) -> np.ndarray:
        """⟨q, x⟩ for every doc — the numpy ground truth."""
        q = np.asarray(q_dense, dtype=np.float32)
        contrib = q[self.components] * self.value_format.dequantise(self.values)
        out = np.zeros(self.n_docs, dtype=np.float32)
        np.add.at(out, np.repeat(np.arange(self.n_docs), np.diff(self.offsets)), contrib)
        return out

    # -- component re-ordering (RGB, §2) ------------------------------------
    def apply_component_permutation(self, pi: np.ndarray) -> "ForwardIndex":
        """Relabel component c as pi[c] and re-sort each doc.

        The same permutation must be applied to query vectors; see
        ``repro.core.rgb``.
        """
        pi = np.asarray(pi, dtype=np.uint32)
        if len(pi) != self.dim:
            raise ValueError("permutation length must equal dim")
        new_comp = pi[self.components]
        comps = np.empty_like(new_comp)
        vals = np.empty_like(self.values)
        for i in range(self.n_docs):
            s, e = int(self.offsets[i]), int(self.offsets[i + 1])
            order = np.argsort(new_comp[s:e], kind="stable")
            comps[s:e] = new_comp[s:e][order]
            vals[s:e] = self.values[s:e][order]
        return ForwardIndex(comps, vals, self.offsets.copy(), self.dim, self.value_format)

    # -- size accounting -----------------------------------------------------
    def storage_bytes(self, codec_name: str = "uncompressed") -> dict[str, int]:
        codec = get_codec(codec_name)
        comp_bytes = sum(
            len(codec.encode_doc(self.components[int(s):int(e)]))
            for s, e in zip(self.offsets[:-1], self.offsets[1:])
            if e > s
        )
        return {
            "components": comp_bytes,
            "values": int(self.values.nbytes),
            "offsets": int(self.offsets.nbytes),
        }


# ---------------------------------------------------------------------------
# TPU packed block layout
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class PackedBlocks:
    """Self-contained fixed-size blocks for lane-parallel scoring.

    Shapes (B = n_blocks, T = block_size, D = max docs/block):

    ============  =========  ==================================================
    field         shape      meaning
    ============  =========  ==================================================
    seg           i32 [B,T]  local doc-slot id per element, -1 for padding
    start_pos     i32 [B,D]  element index of each slot's first element
    start_abs     i32 [B,D]  absolute first component of each fragment
    vals          [B,T]      stored-dtype values (0 for padding)
    doc_ids       i32 [B,D]  global doc id per slot, -1 for unused slots
    ctrl          u8 [B,T/8] DotVByte control bits (codec="dotvbyte")
    data          u8 [B,DP]  DotVByte byte stream, padded (codec="dotvbyte")
    words         u32[B,W]   bitpack words (codec="bitpack")
    widths        i32 [B]    bitpack bit-width per block (codec="bitpack")
    comps         i32 [B,T]  raw components (codec="uncompressed")
    ============  =========  ==================================================

    Gap streams encode the *within-fragment* gaps with the fragment-first
    gap forced to 0; absolutes live in ``start_abs`` (DESIGN.md §3).
    """

    codec: str
    block_size: int
    n_docs: int
    dim: int
    value_format: ValueFormat
    seg: np.ndarray
    start_pos: np.ndarray
    start_abs: np.ndarray
    vals: np.ndarray
    doc_ids: np.ndarray
    ctrl: np.ndarray | None = None
    data: np.ndarray | None = None
    words: np.ndarray | None = None
    widths: np.ndarray | None = None
    comps: np.ndarray | None = None

    @property
    def n_blocks(self) -> int:
        return self.seg.shape[0]

    @property
    def max_docs_per_block(self) -> int:
        return self.doc_ids.shape[1]

    def payload_bytes(self) -> int:
        """Bytes the scoring path actually streams from HBM (roofline)."""
        total = self.seg.nbytes + self.start_pos.nbytes + self.start_abs.nbytes
        total += self.vals.nbytes + self.doc_ids.nbytes
        for a in (self.ctrl, self.data, self.words, self.widths, self.comps):
            if a is not None:
                total += a.nbytes
        return total


def _fragments(
    fwd: ForwardIndex, block_size: int, max_docs: int
) -> list[list[tuple[int, int, int]]]:
    """Greedy first-fit packing of doc fragments into blocks.

    Returns per-block lists of (doc_id, start_nnz, end_nnz) fragments.
    A block closes when T components or D doc slots are used.
    """
    blocks: list[list[tuple[int, int, int]]] = []
    cur: list[tuple[int, int, int]] = []
    used = 0
    for d in range(fwd.n_docs):
        n = fwd.nnz(d)
        pos = 0
        while pos < n:
            if used == block_size or len(cur) == max_docs:
                blocks.append(cur)
                cur, used = [], 0
            take = min(n - pos, block_size - used)
            cur.append((d, pos, pos + take))
            used += take
            pos += take
    if cur:
        blocks.append(cur)
    return blocks


def pack_forward_index(
    fwd: ForwardIndex,
    codec: str = "dotvbyte",
    block_size: int = 512,
    max_docs_per_block: int | None = None,
    seg_dtype=np.int32,
) -> PackedBlocks:
    """Build the TPU packed block layout from a CSR forward index.

    ``seg_dtype=np.int8`` is the §Perf "metadata slimming" layout: the
    per-element doc-slot id fits i8 whenever max_docs_per_block ≤ 127,
    cutting the dominant metadata stream 4×."""
    if codec not in ("dotvbyte", "bitpack", "uncompressed"):
        raise ValueError(f"no packed layout for codec {codec!r}")
    if block_size % 128:
        raise ValueError("block_size must be a multiple of 128 (TPU lanes)")
    T = block_size
    D = max_docs_per_block or T // 8
    if np.dtype(seg_dtype) == np.int8 and D > 127:
        raise ValueError("int8 seg needs max_docs_per_block <= 127")
    frags = _fragments(fwd, T, D)
    B = len(frags)

    seg = np.full((B, T), -1, dtype=seg_dtype)
    start_pos = np.zeros((B, D), dtype=np.int32)
    start_abs = np.zeros((B, D), dtype=np.int32)
    vals = np.zeros((B, T), dtype=fwd.values.dtype)
    doc_ids = np.full((B, D), -1, dtype=np.int32)
    gaps_all = np.zeros((B, T), dtype=np.uint32)

    for b, frag_list in enumerate(frags):
        pos = 0
        for s_idx, (d, lo, hi) in enumerate(frag_list):
            off = int(fwd.offsets[d])
            comps = fwd.components[off + lo : off + hi].astype(np.int64)
            n = len(comps)
            g = np.empty(n, dtype=np.uint32)
            g[0] = 0  # fragment-first gap forced to 0; absolute out-of-band
            g[1:] = np.diff(comps).astype(np.uint32)
            gaps_all[b, pos : pos + n] = g
            seg[b, pos : pos + n] = s_idx
            vals[b, pos : pos + n] = fwd.values[off + lo : off + hi]
            start_pos[b, s_idx] = pos
            start_abs[b, s_idx] = comps[0]
            doc_ids[b, s_idx] = d
            pos += n

    out = PackedBlocks(
        codec=codec,
        block_size=T,
        n_docs=fwd.n_docs,
        dim=fwd.dim,
        value_format=fwd.value_format,
        seg=seg,
        start_pos=start_pos,
        start_abs=start_abs,
        vals=vals,
        doc_ids=doc_ids,
    )

    if codec == "uncompressed":
        # decode-free path: reconstruct absolute components directly
        t = np.cumsum(gaps_all.astype(np.int64), axis=1)
        tp = np.take_along_axis(t, start_pos.astype(np.int64), axis=1)
        segc = np.clip(seg, 0, D - 1)
        base = np.take_along_axis(start_abs.astype(np.int64), segc, axis=1)
        tseg = np.take_along_axis(tp, segc, axis=1)
        comps = np.where(seg >= 0, base + t - tseg, 0)
        out.comps = comps.astype(np.int32)
        return out

    if codec == "dotvbyte":
        bits = control_bits(gaps_all.reshape(-1)).reshape(B, T)
        out.ctrl = np.packbits(
            bits.reshape(B, T // 8, 8), axis=2, bitorder="little"
        ).reshape(B, T // 8)
        lens = bits.astype(np.int64) + 1
        data_len = lens.sum(axis=1)
        DP = int(data_len.max(initial=1)) + 1  # +1: safe hi-byte over-read
        data = np.zeros((B, DP), dtype=np.uint8)
        for b in range(B):
            starts = np.concatenate([[0], np.cumsum(lens[b])[:-1]])
            g64 = gaps_all[b].astype(np.uint64)
            data[b, starts] = (g64 & 0xFF).astype(np.uint8)
            two = bits[b].astype(bool)
            data[b, starts[two] + 1] = ((g64[two] >> 8) & 0xFF).astype(np.uint8)
        out.data = data
        return out

    return _bitpack_tail(out, gaps_all, T, B)


def pack_forward_index_sharded(
    fwd: ForwardIndex,
    n_shards: int,
    codec: str = "dotvbyte",
    block_size: int = 512,
    seg_dtype=np.int32,
) -> tuple[dict, int]:
    """Doc-aligned sharded packing (§Perf opt1, EXPERIMENTS.md).

    Splits documents into ``n_shards`` contiguous equal ranges, packs
    each range independently with range-LOCAL doc ids, pads per-shard
    block counts/data widths to a common size, and stacks every array
    with a leading shard dim. Feed to ``scoring.make_doc_aligned_scan``
    with the arrays sharded over the mesh. Returns (arrays, docs_local)."""
    n = fwd.n_docs
    docs_local = (n + n_shards - 1) // n_shards
    packs = []
    for s in range(n_shards):
        lo, hi = s * docs_local, min((s + 1) * docs_local, n)
        sub_docs = []
        for d in range(lo, hi):
            c, v = fwd.doc(d)
            sub_docs.append((c, v))
        while len(sub_docs) < docs_local:  # tail padding: empty doc
            sub_docs.append((np.array([0], np.uint32), np.array([0.0], np.float32)))
        sub = ForwardIndex.from_docs(sub_docs, fwd.dim, value_format=fwd.value_format.name)
        packs.append(pack_forward_index(sub, codec=codec, block_size=block_size,
                                        seg_dtype=seg_dtype))
    B = max(p.n_blocks for p in packs)
    DP = max(p.data.shape[1] for p in packs) if codec == "dotvbyte" else 0
    out: dict[str, np.ndarray] = {}

    def stack(field, pad_value=0):
        arrs = []
        for p in packs:
            a = getattr(p, field)
            buf = np.full((B, *a.shape[1:]), pad_value, dtype=a.dtype)
            buf[: a.shape[0]] = a
            arrs.append(buf)
        return np.stack(arrs)

    T = block_size
    for field, pad in (("seg", -1), ("start_pos", 0), ("start_abs", 0),
                       ("vals", 0), ("doc_ids", -1)):
        out[field] = stack(field, pad)
    if codec == "dotvbyte":
        # pad data width to the common max (+over-read byte preserved)
        datas = []
        ctrls = []
        for p in packs:
            d = np.zeros((B, DP), np.uint8)
            d[: p.data.shape[0], : p.data.shape[1]] = p.data
            datas.append(d)
            c = np.zeros((B, T // 8), np.uint8)
            c[: p.ctrl.shape[0]] = p.ctrl
            ctrls.append(c)
        out["data"] = np.stack(datas)
        out["ctrl"] = np.stack(ctrls)
    return out, docs_local


def _bitpack_tail(out, gaps_all, T, B):
    # bitpack: one width per block, bucket-friendly (DESIGN.md §3)
    widths = np.maximum(
        [int(g.max(initial=0)).bit_length() for g in gaps_all], 1
    ).astype(np.int32)
    Wmax = int(widths.max(initial=1))
    n_words = (T * Wmax + 31) // 32
    words = np.zeros((B, n_words), dtype=np.uint32)
    for b in range(B):
        wb = pack_block(gaps_all[b], int(widths[b]))
        words[b, : len(wb)] = wb
    out.words = words
    out.widths = widths
    return out
