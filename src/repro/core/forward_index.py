"""The forward index (paper §1-§2): doc_id → sparse vector, CSR layout.

Three arrays, exactly as the paper describes: ``components`` (nonzero
coordinate ids), ``values`` (their values), ``offsets`` (row pointers).
Values may be stored as f32, f16 or fixedU8 (8-bit fixed point; the
paper's "fixedU8" column in Table 2) — quantisation is applied at build
time and dequantisation fused into the scoring path.

Also defines the TPU *packed block layout* used by the jnp scorers and
the Pallas kernels: documents are split into self-contained blocks of
``block_size`` components. Each document fragment opens with its
absolute first component stored out-of-band (``start_abs``), so every
block decodes independently — the TPU analogue of DotVByte's
per-document alignment (DESIGN.md §3).
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

import numpy as np

from .codecs import get_codec

__all__ = [
    "ValueFormat",
    "ForwardIndex",
    "PackedBlocks",
    "pack_forward_index",
    "pack_forward_index_sharded",
    "VALUE_FORMATS",
]


@dataclasses.dataclass(frozen=True)
class ValueFormat:
    """Storage format for the values array."""

    name: str
    dtype: np.dtype
    scale: float  # dequantised value = stored * scale

    def quantise(self, v: np.ndarray) -> np.ndarray:
        if self.name == "fixedu8":
            q = np.clip(np.round(v / self.scale), 0, 255)
            return q.astype(np.uint8)
        return v.astype(self.dtype)

    def dequantise(self, q: np.ndarray) -> np.ndarray:
        return q.astype(np.float32) * np.float32(self.scale)


VALUE_FORMATS = {
    "f32": ValueFormat("f32", np.dtype(np.float32), 1.0),
    "f16": ValueFormat("f16", np.dtype(np.float16), 1.0),
    # U3F5-style fixed point: range [0, 8), resolution 1/32 — covers
    # SPLADE/LILSR activation ranges (positive, < 8).
    "fixedu8": ValueFormat("fixedu8", np.dtype(np.uint8), 1.0 / 32.0),
}


@dataclasses.dataclass
class ForwardIndex:
    """Uncompressed CSR forward index (the paper's baseline layout)."""

    components: np.ndarray  # u32 [total_nnz], sorted per doc
    values: np.ndarray  # stored dtype [total_nnz]
    offsets: np.ndarray  # i64 [n_docs + 1]
    dim: int
    value_format: ValueFormat = VALUE_FORMATS["f32"]

    # -- construction -----------------------------------------------------
    @staticmethod
    def from_docs(
        docs: Iterable[tuple[np.ndarray, np.ndarray]],
        dim: int,
        value_format: str = "f32",
    ) -> "ForwardIndex":
        vf = VALUE_FORMATS[value_format]
        comps, vals, offs = [], [], [0]
        for c, v in docs:
            c = np.asarray(c, dtype=np.uint32)
            v = np.asarray(v, dtype=np.float32)
            order = np.argsort(c, kind="stable")
            comps.append(c[order])
            vals.append(vf.quantise(v[order]))
            offs.append(offs[-1] + len(c))
        return ForwardIndex(
            components=np.concatenate(comps) if comps else np.zeros(0, np.uint32),
            values=np.concatenate(vals) if vals else np.zeros(0, vf.dtype),
            offsets=np.asarray(offs, dtype=np.int64),
            dim=dim,
            value_format=vf,
        )

    # -- accessors ---------------------------------------------------------
    @property
    def n_docs(self) -> int:
        return len(self.offsets) - 1

    @property
    def total_nnz(self) -> int:
        return int(self.offsets[-1])

    def nnz(self, i: int) -> int:
        return int(self.offsets[i + 1] - self.offsets[i])

    def doc(self, i: int) -> tuple[np.ndarray, np.ndarray]:
        s, e = int(self.offsets[i]), int(self.offsets[i + 1])
        return self.components[s:e], self.value_format.dequantise(self.values[s:e])

    def doc_raw_values(self, i: int) -> np.ndarray:
        s, e = int(self.offsets[i]), int(self.offsets[i + 1])
        return self.values[s:e]

    def iter_docs(self) -> Iterable[tuple[np.ndarray, np.ndarray]]:
        for i in range(self.n_docs):
            yield self.doc(i)

    def slice(self, lo: int, hi: int) -> "ForwardIndex":
        """CSR view of the contiguous doc range ``[lo, hi)``.

        Zero-copy on components/values (numpy slices share the buffer);
        only the rebased offsets allocate. This is the primitive the
        sharded artifact builder (DESIGN.md §9) partitions a collection
        with — per-shard pack offsets come from here, so shard packing
        never round-trips through per-doc python lists."""
        if not 0 <= lo <= hi <= self.n_docs:
            raise ValueError(
                f"doc range [{lo}, {hi}) outside collection [0, {self.n_docs})"
            )
        s, e = int(self.offsets[lo]), int(self.offsets[hi])
        return ForwardIndex(
            components=self.components[s:e],
            values=self.values[s:e],
            offsets=(self.offsets[lo : hi + 1] - s).astype(np.int64),
            dim=self.dim,
            value_format=self.value_format,
        )

    def padded(self, n_docs: int) -> "ForwardIndex":
        """This index extended with empty documents up to ``n_docs``
        rows (zero-copy on components/values) — the shard builders pad
        ragged ranges to a common local size this way; empty rows score
        0 and are sentinel-mapped out of every merge."""
        if n_docs < self.n_docs:
            raise ValueError(
                f"cannot pad {self.n_docs} docs down to {n_docs}"
            )
        if n_docs == self.n_docs:
            return self
        return ForwardIndex(
            components=self.components,
            values=self.values,
            offsets=np.concatenate(
                [
                    self.offsets,
                    np.full(n_docs - self.n_docs, self.offsets[-1], np.int64),
                ]
            ),
            dim=self.dim,
            value_format=self.value_format,
        )

    @staticmethod
    def concat(parts: Sequence["ForwardIndex"]) -> "ForwardIndex":
        """Row-wise concatenation of CSR indexes (same dim + format).

        The mutable-index merge step (DESIGN.md §10) stitches the base
        store and every delta segment with this before re-selecting the
        live rows — one vectorised pass, no per-doc python loop."""
        if not parts:
            raise ValueError("concat needs at least one part")
        dim = parts[0].dim
        vf = parts[0].value_format
        for p in parts[1:]:
            if p.dim != dim:
                raise ValueError(f"dim mismatch: {p.dim} != {dim}")
            if p.value_format.name != vf.name:
                raise ValueError(
                    f"value_format mismatch: {p.value_format.name} != {vf.name}"
                )
        if len(parts) == 1:
            return parts[0]
        offs = [np.zeros(1, np.int64)]
        base = 0
        for p in parts:
            offs.append(p.offsets[1:].astype(np.int64) + base)
            base += int(p.offsets[-1])
        return ForwardIndex(
            components=np.concatenate([p.components for p in parts]),
            values=np.concatenate([p.values for p in parts]),
            offsets=np.concatenate(offs),
            dim=dim,
            value_format=vf,
        )

    def append(self, other: "ForwardIndex") -> "ForwardIndex":
        """``concat([self, other])`` — segment-build convenience."""
        return ForwardIndex.concat([self, other])

    def select(self, idx: np.ndarray) -> "ForwardIndex":
        """Row gather: a new index whose row ``r`` is ``self`` row
        ``idx[r]``, in the given order (repeats allowed). Vectorised —
        the merge/compaction path extracts live rows in stable-id order
        with this (DESIGN.md §10)."""
        idx = np.asarray(idx, dtype=np.int64)
        if idx.size and (idx.min() < 0 or idx.max() >= self.n_docs):
            raise ValueError(
                f"row index outside [0, {self.n_docs}): "
                f"[{idx.min()}, {idx.max()}]"
            )
        lens = np.diff(self.offsets)[idx]
        new_off = np.zeros(len(idx) + 1, np.int64)
        np.cumsum(lens, out=new_off[1:])
        total = int(new_off[-1])
        # element positions: for each output row, a run of consecutive
        # source positions starting at the source row's first element
        starts = self.offsets[:-1][idx]
        pos = (
            np.repeat(starts, lens)
            + np.arange(total, dtype=np.int64)
            - np.repeat(new_off[:-1], lens)
        )
        return ForwardIndex(
            components=self.components[pos],
            values=self.values[pos],
            offsets=new_off,
            dim=self.dim,
            value_format=self.value_format,
        )

    def densify(self, i: int) -> np.ndarray:
        c, v = self.doc(i)
        out = np.zeros(self.dim, dtype=np.float32)
        out[c] = v
        return out

    # -- exact scoring (numpy oracle for everything downstream) ------------
    def exact_scores(self, q_dense: np.ndarray) -> np.ndarray:
        """⟨q, x⟩ for every doc — the numpy ground truth."""
        q = np.asarray(q_dense, dtype=np.float32)
        contrib = q[self.components] * self.value_format.dequantise(self.values)
        out = np.zeros(self.n_docs, dtype=np.float32)
        np.add.at(out, np.repeat(np.arange(self.n_docs), np.diff(self.offsets)), contrib)
        return out

    # -- component re-ordering (RGB, §2) ------------------------------------
    def apply_component_permutation(self, pi: np.ndarray) -> "ForwardIndex":
        """Relabel component c as pi[c] and re-sort each doc.

        The same permutation must be applied to query vectors; see
        ``repro.core.rgb``.
        """
        pi = np.asarray(pi, dtype=np.uint32)
        if len(pi) != self.dim:
            raise ValueError("permutation length must equal dim")
        new_comp = pi[self.components]
        comps = np.empty_like(new_comp)
        vals = np.empty_like(self.values)
        for i in range(self.n_docs):
            s, e = int(self.offsets[i]), int(self.offsets[i + 1])
            order = np.argsort(new_comp[s:e], kind="stable")
            comps[s:e] = new_comp[s:e][order]
            vals[s:e] = self.values[s:e][order]
        return ForwardIndex(comps, vals, self.offsets.copy(), self.dim, self.value_format)

    # -- size accounting -----------------------------------------------------
    def storage_bytes(self, codec_name: str = "uncompressed") -> dict[str, int]:
        codec = get_codec(codec_name)
        comp_bytes = sum(
            len(codec.encode_doc(self.components[int(s):int(e)]))
            for s, e in zip(self.offsets[:-1], self.offsets[1:])
            if e > s
        )
        return {
            "components": comp_bytes,
            "values": int(self.values.nbytes),
            "offsets": int(self.offsets.nbytes),
        }


# ---------------------------------------------------------------------------
# TPU packed block layout
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class PackedBlocks:
    """Self-contained fixed-size blocks for lane-parallel scoring.

    Shapes (B = n_blocks, T = block_size, D = max docs/block):

    ============  =========  ==================================================
    field         shape      meaning
    ============  =========  ==================================================
    seg           i32 [B,T]  local doc-slot id per element, -1 for padding
    start_pos     i32 [B,D]  element index of each slot's first element
    start_abs     i32 [B,D]  absolute first component of each fragment
    vals          [B,T]      stored-dtype values (0 for padding)
    doc_ids       i32 [B,D]  global doc id per slot, -1 for unused slots
    ctrl          u8 [B,T/8] DotVByte controls — or [B,T/4] StreamVByte
                             2-bit controls (codec="streamvbyte")
    data          u8 [B,DP]  byte stream, padded (dotvbyte/streamvbyte)
    words         u32[B,W]   bitpack words (codec="bitpack")
    widths        i32 [B]    bitpack bit-width per block (codec="bitpack")
    comps         i32 [B,T]  raw components (codec="uncompressed")
    ============  =========  ==================================================

    Gap streams encode the *within-fragment* gaps with the fragment-first
    gap forced to 0; absolutes live in ``start_abs`` (DESIGN.md §3).
    Built exclusively by ``repro.core.layout.pack_blocks`` — the codec
    byte-packing itself lives in the layout registry.
    """

    codec: str
    block_size: int
    n_docs: int
    dim: int
    value_format: ValueFormat
    seg: np.ndarray
    start_pos: np.ndarray
    start_abs: np.ndarray
    vals: np.ndarray
    doc_ids: np.ndarray
    ctrl: np.ndarray | None = None
    data: np.ndarray | None = None
    words: np.ndarray | None = None
    widths: np.ndarray | None = None
    comps: np.ndarray | None = None
    #: value codec (DESIGN.md §12): quantized vqs store u8 codes in
    #: ``vals`` plus per-block clip ranges or a shared codebook
    vq: str = "f16"
    vq_lo: np.ndarray | None = None
    vq_scale: np.ndarray | None = None
    vq_codebook: np.ndarray | None = None

    @property
    def n_blocks(self) -> int:
        return self.seg.shape[0]

    @property
    def max_docs_per_block(self) -> int:
        return self.doc_ids.shape[1]

    def as_dict(self) -> dict[str, np.ndarray]:
        """Every populated array field, keyed by name (shard stacking)."""
        out = {
            "seg": self.seg,
            "start_pos": self.start_pos,
            "start_abs": self.start_abs,
            "vals": self.vals,
            "doc_ids": self.doc_ids,
        }
        for k in ("ctrl", "data", "words", "widths", "comps",
                  "vq_lo", "vq_scale", "vq_codebook"):
            a = getattr(self, k)
            if a is not None:
                out[k] = a
        return out

    def payload_bytes(self) -> int:
        """Bytes the scoring path actually streams from HBM (roofline)."""
        return sum(int(a.nbytes) for a in self.as_dict().values())


def pack_forward_index(
    fwd: ForwardIndex,
    codec: str = "dotvbyte",
    block_size: int = 512,
    max_docs_per_block: int | None = None,
    seg_dtype=np.int32,
    vq: str = "f16",
    vq_clip=None,
) -> PackedBlocks:
    """Build the TPU packed block layout from a CSR forward index.

    Thin alias for ``repro.core.layout.pack_blocks`` (kept here for the
    historical import path); any codec registered in the layout registry
    works — uncompressed, bitpack, dotvbyte, streamvbyte.

    ``seg_dtype=np.int8`` is the §Perf "metadata slimming" layout: the
    per-element doc-slot id fits i8 whenever max_docs_per_block ≤ 127,
    cutting the dominant metadata stream 4×.  ``vq`` selects the VALUE
    codec (DESIGN.md §12): quantized block values are per-block
    scalar-quant codes / PQ codes riding in ``vals``."""
    from .layout import pack_blocks

    return pack_blocks(
        fwd,
        codec=codec,
        block_size=block_size,
        max_docs_per_block=max_docs_per_block,
        seg_dtype=seg_dtype,
        vq=vq,
        vq_clip=vq_clip,
    )


def pack_forward_index_sharded(
    fwd: ForwardIndex,
    n_shards: int,
    codec: str = "dotvbyte",
    block_size: int = 512,
    seg_dtype=np.int32,
) -> tuple[dict, int]:
    """Doc-aligned sharded packing (§Perf opt1, EXPERIMENTS.md).

    Thin alias for ``repro.core.layout.pack_blocks_sharded``. Feed the
    result to ``scoring.make_doc_aligned_scan`` with the arrays sharded
    over the mesh. Returns (arrays, docs_local)."""
    from .layout import pack_blocks_sharded

    return pack_blocks_sharded(
        fwd, n_shards, codec=codec, block_size=block_size, seg_dtype=seg_dtype
    )
