"""HNSW (Malkov & Yashunin) over sparse learned embeddings — the
graph-based sparse MIPS engine the paper names alongside Seismic ("the
inverted index-based Seismic and the graph-based HNSW", §1).

Where Seismic re-scores whole geometric *blocks* of candidate documents
through the forward index, a graph traversal touches documents
**one neighbour list at a time**: every hop gathers the ≤ M adjacent
doc ids and needs their exact inner products immediately.  That makes
per-document decode latency — the quantity the paper's codecs optimise —
the hot path of the whole search, which is why this engine reuses the
row form of the packed layout (``layout.pack_rows``) unmodified.

Build pipeline (standard HNSW, inner-product "distance" = −⟨x, y⟩):

1. **level sampling** — node levels are geometric with multiplier
   ``1/ln(M)``;
2. **greedy descent** — insertion walks from the global entry point down
   through the upper layers with ef = 1;
3. **beam search + heuristic selection** — on each layer ≤ the node's
   level, an ``ef_construction`` beam collects candidates and the
   classic diversity heuristic keeps ≤ ``M`` of them (a candidate is
   kept only if it is closer to the new node than to every neighbour
   already selected; pruned candidates back-fill);
4. **bidirectional links** — over-full neighbour lists re-shrink with
   the same heuristic.

All document–document inner products go through ``ForwardIndex`` (one
side densified per insertion, the other gathered sparse), so the builder
never materialises a dense matrix.

This module is the host-side (numpy) reference engine with faithful
heap semantics; the batched static-shape TPU serving path is the
``hnsw`` entry of the engine registry (``repro.serve.engines.hnsw``,
served through ``repro.serve.api`` — DESIGN.md §5/§7, EXPERIMENTS.md
§Graph).
"""

from __future__ import annotations

import dataclasses
import heapq
import math

import numpy as np

from .codecs import get_codec
from .forward_index import ForwardIndex

__all__ = ["HNSWParams", "HNSWIndex"]


@dataclasses.dataclass(frozen=True)
class HNSWParams:
    m: int = 16  # max degree on layers ≥ 1; selection budget at insert
    m0: int | None = None  # base-layer max degree (default 2·m)
    ef_construction: int = 64  # insertion beam width
    seed: int = 0

    @property
    def level_mult(self) -> float:
        return 1.0 / math.log(self.m)

    def degree(self, layer: int) -> int:
        return (self.m0 or 2 * self.m) if layer == 0 else self.m


@dataclasses.dataclass
class HNSWIndex:
    """Hierarchical small-world graph over the forward index.

    ``graph[layer]`` maps node → neighbour list (≤ ``degree(layer)``).
    Determinism: levels come from one seeded ``default_rng``; every heap
    and sort breaks ties by ascending doc id, so identical (fwd, params)
    builds are bit-identical (tested in tests/test_hnsw.py).
    """

    params: HNSWParams
    fwd: ForwardIndex
    dim: int
    levels: np.ndarray  # i32 [n_docs]
    entry: int = -1
    max_level: int = -1
    graph: list[dict[int, list[int]]] = dataclasses.field(default_factory=list)
    # host-encoded docs for the codec-timed reference search (cf. Seismic)
    _decoded: dict | None = None
    # dequantised values cache: _score runs thousands of times per insert
    _vals_f32: np.ndarray | None = None

    # ------------------------------------------------------------------
    @staticmethod
    def build(fwd: ForwardIndex, params: HNSWParams = HNSWParams()) -> "HNSWIndex":
        rng = np.random.default_rng(params.seed)
        u = rng.uniform(size=fwd.n_docs)
        levels = np.floor(
            -np.log(np.clip(u, 1e-12, None)) * params.level_mult
        ).astype(np.int32)
        index = HNSWIndex(params=params, fwd=fwd, dim=fwd.dim, levels=levels)
        for i in range(fwd.n_docs):
            index._insert(i)
        return index

    @property
    def n_edges(self) -> int:
        return sum(len(nbrs) for layer in self.graph for nbrs in layer.values())

    # -- scoring -------------------------------------------------------
    def prepare_codec(self, codec_name: str) -> None:
        """Pre-encode every document with ``codec_name`` for the timed
        reference-search path (mirrors ``SeismicIndex.prepare_codec``)."""
        from .layout import encode_docs

        self._decoded = {"codec": codec_name, "bufs": encode_docs(self.fwd, codec_name)}

    def _doc_components(self, d: int, codec_name: str) -> np.ndarray:
        if codec_name == "uncompressed":
            s, e = int(self.fwd.offsets[d]), int(self.fwd.offsets[d + 1])
            return self.fwd.components[s:e]
        if self._decoded is None or self._decoded["codec"] != codec_name:
            self.prepare_codec(codec_name)  # lazy, so timings stay honest
        codec = get_codec(codec_name)
        return codec.decode_doc(self._decoded["bufs"][d], self.fwd.nnz(d))

    def _score(self, q_dense: np.ndarray, d: int, codec: str = "uncompressed") -> float:
        if self._vals_f32 is None:
            self._vals_f32 = self.fwd.value_format.dequantise(self.fwd.values)
        comps = self._doc_components(d, codec)
        s, e = int(self.fwd.offsets[d]), int(self.fwd.offsets[d + 1])
        return float(q_dense[comps] @ self._vals_f32[s:e])

    # -- build internals -----------------------------------------------
    def _greedy(self, q: np.ndarray, ep: int, layer: int, codec: str = "uncompressed") -> int:
        """ef=1 hill climb on one layer (the upper-layer descent)."""
        cur, cur_s = ep, self._score(q, ep, codec)
        improved = True
        while improved:
            improved = False
            for nb in self.graph[layer].get(cur, ()):
                s = self._score(q, nb, codec)
                if s > cur_s:
                    cur, cur_s, improved = nb, s, True
        return cur

    def _search_layer(
        self, q: np.ndarray, eps: list[int], ef: int, layer: int,
        codec: str = "uncompressed",
    ) -> list[tuple[float, int]]:
        """Beam search on one layer → candidates sorted by score desc."""
        graph = self.graph[layer]
        visited = set(eps)
        cand: list[tuple[float, int]] = []  # max-heap by score (negated)
        res: list[tuple[float, int]] = []  # min-heap of the ef best
        for e in eps:
            s = self._score(q, e, codec)
            heapq.heappush(cand, (-s, e))
            heapq.heappush(res, (s, e))
            if len(res) > ef:
                heapq.heappop(res)
        while cand:
            ns, c = heapq.heappop(cand)
            if len(res) >= ef and -ns < res[0][0]:
                break
            for nb in graph.get(c, ()):
                if nb in visited:
                    continue
                visited.add(nb)
                s = self._score(q, nb, codec)
                if len(res) < ef or s > res[0][0]:
                    heapq.heappush(cand, (-s, nb))
                    heapq.heappush(res, (s, nb))
                    if len(res) > ef:
                        heapq.heappop(res)
        return sorted(res, key=lambda t: (-t[0], t[1]))

    def _select_heuristic(
        self, cands: list[tuple[float, int]], m: int
    ) -> list[int]:
        """Diversity heuristic: keep a candidate only if its similarity
        to every already-selected neighbour is below its similarity to
        the query point; pruned candidates back-fill up to ``m``."""
        selected: list[int] = []
        skipped: list[int] = []
        for s, c in cands:
            if len(selected) == m:
                break
            c_dense = self.fwd.densify(c)
            diverse = True
            for sd in selected:
                scs, svs = self.fwd.doc(sd)
                if float(c_dense[scs] @ svs) >= s:
                    diverse = False
                    break
            (selected if diverse else skipped).append(c)
        for c in skipped:
            if len(selected) == m:
                break
            selected.append(c)
        return selected

    def _shrink(self, node: int, layer: int) -> None:
        """Re-select an over-full neighbour list with the heuristic."""
        qd = self.fwd.densify(node)
        cands = sorted(
            ((self._score(qd, n), n) for n in self.graph[layer][node]),
            key=lambda t: (-t[0], t[1]),
        )
        self.graph[layer][node] = self._select_heuristic(
            cands, self.params.degree(layer)
        )

    def _insert(self, i: int) -> None:
        l = int(self.levels[i])
        while len(self.graph) <= l:
            self.graph.append({})
        for layer in range(l + 1):
            self.graph[layer].setdefault(i, [])
        if self.entry < 0:
            self.entry, self.max_level = i, l
            return
        q = self.fwd.densify(i)
        ep = self.entry
        for layer in range(self.max_level, l, -1):
            ep = self._greedy(q, ep, layer)
        eps = [ep]
        for layer in range(min(l, self.max_level), -1, -1):
            cands = self._search_layer(q, eps, self.params.ef_construction, layer)
            cands = [(s, c) for s, c in cands if c != i]
            for j in self._select_heuristic(cands, self.params.m):
                self.graph[layer][i].append(j)
                self.graph[layer][j].append(i)
                if len(self.graph[layer][j]) > self.params.degree(layer):
                    self._shrink(j, layer)
            eps = [c for _, c in cands]
        if l > self.max_level:
            self.entry, self.max_level = i, l

    # -- query processing (reference path) ------------------------------
    def search(
        self, q_dense: np.ndarray, k: int = 10, ef: int = 64,
        codec: str = "uncompressed",
    ) -> tuple[np.ndarray, np.ndarray]:
        """Faithful HNSW query processing (numpy reference engine).

        ``codec`` routes every candidate's component decode through the
        host codec, so decode cost sits inside the measured search —
        same methodology as ``SeismicIndex.search``."""
        if self.entry < 0:
            return np.zeros(0, np.int64), np.zeros(0, np.float32)
        q = np.asarray(q_dense, dtype=np.float32)
        ep = self.entry
        for layer in range(self.max_level, 0, -1):
            ep = self._greedy(q, ep, layer, codec)
        cands = self._search_layer(q, [ep], max(ef, k), 0, codec)[:k]
        ids = np.asarray([c for _, c in cands], dtype=np.int64)
        return ids, np.asarray([s for s, _ in cands], dtype=np.float32)

    # -- serving exports -----------------------------------------------
    def adjacency(self, layer: int = 0, sentinel: int | None = None) -> np.ndarray:
        """Fixed-degree adjacency ``[n_docs+1, degree(layer)]`` padded
        with ``sentinel`` (default n_docs); row n_docs is all-sentinel —
        the out-of-range absorber the static engine gathers through."""
        n = self.fwd.n_docs
        deg = self.params.degree(layer)
        sent = n if sentinel is None else sentinel
        adj = np.full((n + 1, deg), sent, dtype=np.int32)
        if layer < len(self.graph):
            for node, nbrs in self.graph[layer].items():
                adj[node, : min(len(nbrs), deg)] = nbrs[:deg]
        return adj

    def seed_nodes(self, n_seeds: int, sentinel: int | None = None) -> np.ndarray:
        """Static entry points for the serve-time beam: the global entry
        point plus the highest-level nodes (the hierarchy's natural
        hubs), sentinel-padded to ``n_seeds``."""
        sent = self.fwd.n_docs if sentinel is None else sentinel
        order = np.argsort(-self.levels, kind="stable")
        if self.entry >= 0:
            seeds = np.concatenate(
                [[self.entry], order[order != self.entry][: n_seeds - 1]]
            )[:n_seeds]
        else:
            seeds = order[:n_seeds]
        return np.concatenate(
            [seeds, np.full(n_seeds - len(seeds), sent)]
        ).astype(np.int32)

    # ------------------------------------------------------------------
    def index_bytes(self, codec_name: str = "uncompressed") -> dict[str, int]:
        """Index size accounting (graph edges at i32 + level array),
        mirroring ``SeismicIndex.index_bytes``."""
        fwd_sizes = self.fwd.storage_bytes(codec_name)
        graph = int(4 * self.n_edges + self.levels.nbytes)
        return {
            "forward_components": fwd_sizes["components"],
            "forward_values": fwd_sizes["values"],
            "forward_offsets": fwd_sizes["offsets"],
            "graph": graph,
            "total": fwd_sizes["components"]
            + fwd_sizes["values"]
            + fwd_sizes["offsets"]
            + graph,
        }
