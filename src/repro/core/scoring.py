"""Batched inner-product scoring over packed forward-index blocks.

Pure-jnp reference implementations of the decode+dot paths. These are
(a) the scorers used by the Seismic query processor on CPU, and (b) the
oracles the Pallas kernels in ``repro/kernels`` are validated against.

All functions are jit-friendly: they take plain arrays (from
``PackedBlocks``) plus static ints. The decode semantics mirror
DESIGN.md §3: gaps → prefix sum → per-fragment rebase via out-of-band
absolutes → gather query → FMA → segment reduction.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .forward_index import PackedBlocks

__all__ = [
    "dequantise_values",
    "decode_gaps_dotvbyte",
    "decode_gaps_streamvbyte",
    "decode_gaps_bitpack",
    "decode_block_gaps",
    "components_from_gaps",
    "block_products",
    "combine_block_scores",
    "block_slot_scores",
    "score_packed",
    "score_packed_batch",
    "decode_doc_rows",
    "score_candidate_rows",
    "score_candidate_rows_batch",
]


def dequantise_values(vals: jnp.ndarray, scale: float) -> jnp.ndarray:
    return vals.astype(jnp.float32) * jnp.float32(scale)


def decode_gaps_dotvbyte(ctrl: jnp.ndarray, data: jnp.ndarray) -> jnp.ndarray:
    """DotVByte decode, vectorised (DESIGN.md §3).

    ctrl u8 [B, T/8], data u8 [B, DP] (DP ≥ T + popcount + 1).
    Returns gaps i32 [B, T].

    The x86 byte-scroll is replaced by an exclusive prefix sum of the
    control bits; the ``_mm_shuffle_epi8`` by two byte gathers.
    """
    B, nc = ctrl.shape
    bits = (ctrl[:, :, None].astype(jnp.int32) >> jnp.arange(8, dtype=jnp.int32)) & 1
    bits = bits.reshape(B, nc * 8)  # LSB-first within each control byte
    lens = bits + 1
    ends = jnp.cumsum(lens, axis=1)
    starts = ends - lens
    d = data.astype(jnp.int32)
    lo = jnp.take_along_axis(d, starts, axis=1)
    hi = jnp.take_along_axis(d, starts + 1, axis=1) * bits
    return lo + (hi << 8)


def decode_gaps_streamvbyte(ctrl: jnp.ndarray, data: jnp.ndarray) -> jnp.ndarray:
    """StreamVByte decode, vectorised — same shape contract as the
    DotVByte decoder (DESIGN.md §3).

    ctrl u8 [B, T/4] (2-bit codes, value i of a quad in bits 2i..2i+1),
    data u8 [B, DP] (DP ≥ total data bytes + 3 over-read).
    Returns gaps i32 [B, T].

    The x86 ``_mm_shuffle_epi8`` table decode becomes: 2-bit controls →
    prefix-sum byte offsets → up-to-4-byte gathers masked by the code.
    """
    B, nc = ctrl.shape
    codes = (ctrl[:, :, None].astype(jnp.int32) >> (2 * jnp.arange(4, dtype=jnp.int32))) & 0x3
    codes = codes.reshape(B, nc * 4)  # quad-local value i ↔ bits 2i..2i+1
    lens = codes + 1
    ends = jnp.cumsum(lens, axis=1)
    starts = ends - lens
    d = data.astype(jnp.int32)
    out = jnp.take_along_axis(d, starts, axis=1)
    out = out | (jnp.take_along_axis(d, starts + 1, axis=1) * (codes >= 1)) << 8
    out = out | (jnp.take_along_axis(d, starts + 2, axis=1) * (codes >= 2)) << 16
    out = out | (jnp.take_along_axis(d, starts + 3, axis=1) * (codes >= 3)) << 24
    return out


def decode_gaps_bitpack(
    words: jnp.ndarray, widths: jnp.ndarray, block_size: int
) -> jnp.ndarray:
    """Fixed-width unpack: pure shift+mask, no data-dependent offsets.

    words u32 [B, W], widths i32 [B] → gaps i32 [B, T].
    """
    B = words.shape[0]
    T = block_size
    w32 = jnp.concatenate(
        [words.astype(jnp.uint32), jnp.zeros((B, 1), dtype=jnp.uint32)], axis=1
    )
    width = widths[:, None].astype(jnp.uint32)  # [B,1]
    bitpos = jnp.arange(T, dtype=jnp.uint32)[None, :] * width  # [B,T]
    wi = (bitpos // 32).astype(jnp.int32)
    off = bitpos % 32
    lo = jnp.take_along_axis(w32, wi, axis=1) >> off
    hi_shift = jnp.where(off > 0, jnp.uint32(32) - off, jnp.uint32(0))
    hi_raw = jnp.take_along_axis(w32, wi + 1, axis=1)
    hi = jnp.where(off > 0, hi_raw << hi_shift, jnp.uint32(0))
    mask = (jnp.uint32(1) << width) - jnp.uint32(1)
    return ((lo | hi) & mask).astype(jnp.int32)


def decode_block_gaps(codec: str, arrays, block_size: int) -> jnp.ndarray:
    """Codec-dispatching gap decode over a dict of layout arrays.

    ``codec`` must be static under jit (it selects the traced graph).
    The arrays carry the fields the layout codec produced — ctrl/data
    (dotvbyte, streamvbyte) or words/widths (bitpack)."""
    if codec == "dotvbyte":
        # ctrl streams are lane-padded at pack time (layout.LANE_MULTIPLE);
        # slice tight so alignment costs bytes, never decode work
        return decode_gaps_dotvbyte(arrays["ctrl"][:, : block_size // 8], arrays["data"])
    if codec == "streamvbyte":
        return decode_gaps_streamvbyte(arrays["ctrl"][:, : block_size // 4], arrays["data"])
    if codec == "bitpack":
        return decode_gaps_bitpack(arrays["words"], arrays["widths"], block_size)
    raise ValueError(f"no device decoder for codec {codec!r}")


def components_from_gaps(
    gaps: jnp.ndarray,
    seg: jnp.ndarray,
    start_pos: jnp.ndarray,
    start_abs: jnp.ndarray,
) -> jnp.ndarray:
    """Segmented prefix-sum rebase: gaps → absolute component ids.

    comp[i] = start_abs[seg[i]] + t[i] - t[start_pos[seg[i]]] with
    t = inclusive cumsum of gaps; padding (seg = -1) maps to component 0
    (value 0 ⇒ contribution 0, the DotVByte alignment trick).
    """
    seg = seg.astype(jnp.int32)  # i8 in the slim metadata layout
    D = start_pos.shape[1]
    t = jnp.cumsum(gaps, axis=1)
    tp = jnp.take_along_axis(t, start_pos, axis=1)  # [B,D]
    segc = jnp.clip(seg, 0, D - 1)
    base = jnp.take_along_axis(start_abs, segc, axis=1)
    tseg = jnp.take_along_axis(tp, segc, axis=1)
    return jnp.where(seg >= 0, base + t - tseg, 0)


def block_products(
    q: jnp.ndarray, comps: jnp.ndarray, vals_f: jnp.ndarray, seg: jnp.ndarray
) -> jnp.ndarray:
    """q-gather · values, zeroed on padding. [B,T] f32."""
    qv = jnp.take(q, comps, axis=0)
    return qv * vals_f * (seg >= 0)


def combine_block_scores(
    prod_or_scores: jnp.ndarray,
    seg: jnp.ndarray,
    doc_ids: jnp.ndarray,
    n_docs: int,
) -> jnp.ndarray:
    """Reduce per-element products to per-document scores.

    prod [B,T] + seg [B,T] + doc_ids [B,D] → scores [n_docs] via a
    single global segment-sum (the Pallas kernels instead do a per-block
    one-hot MXU matmul; results identical).
    """
    seg = seg.astype(jnp.int32)
    D = doc_ids.shape[1]
    segc = jnp.clip(seg, 0, D - 1)
    gdoc = jnp.take_along_axis(doc_ids, segc, axis=1)  # [B,T]
    gdoc = jnp.where(seg >= 0, gdoc, n_docs)  # padding → overflow bucket
    flat = jax.ops.segment_sum(
        prod_or_scores.reshape(-1), gdoc.reshape(-1), num_segments=n_docs + 1
    )
    return flat[:n_docs]


def scatter_block_scores(
    block_scores: jnp.ndarray, doc_ids: jnp.ndarray, n_docs: int
) -> jnp.ndarray:
    """[B,D] per-block scores + [B,D] doc ids → [n_docs] global scores."""
    ids = jnp.where(doc_ids >= 0, doc_ids, n_docs)
    out = jax.ops.segment_sum(
        block_scores.reshape(-1), ids.reshape(-1), num_segments=n_docs + 1
    )
    return out[:n_docs]


def block_slot_scores(prod: jnp.ndarray, start_pos: jnp.ndarray) -> jnp.ndarray:
    """Per-element products → per-slot (fragment) scores, [.., B, D].

    The tiled kernels' reduction (DESIGN.md §3): inside a block the pack
    loop assigns slots in position order, so each slot's fragment is one
    CONTIGUOUS run ``[start_pos[d], start_pos[d+1])`` (the last used slot
    runs to T, where only zero padding follows).  A slot's score is then
    a difference of the exclusive prefix sum of the products — B·D
    reduced values instead of the B·T-element global segment sum, the
    ~8× smaller scatter the compiled scan wins on.  Slot usage is
    derivable from start_pos alone (slot 0 always starts at 0; every
    later used slot starts strictly after it), so unused slots are
    zeroed without needing doc_ids.  Works on [B,T] and [nq,B,T]
    product arrays (start_pos broadcasts)."""
    T = prod.shape[-1]
    lead = prod.shape[:-2]
    cz = jnp.concatenate(
        [jnp.zeros((*lead, prod.shape[-2], 1), prod.dtype), jnp.cumsum(prod, axis=-1)],
        axis=-1,
    )
    nxt = jnp.concatenate(
        [start_pos[..., 1:], jnp.zeros((*start_pos.shape[:-1], 1), start_pos.dtype)],
        axis=-1,
    )
    ends = jnp.where(nxt > start_pos, nxt, T)
    used = jnp.concatenate(
        [jnp.ones_like(start_pos[..., :1], jnp.bool_), start_pos[..., 1:] > 0],
        axis=-1,
    )
    ends = jnp.broadcast_to(ends, (*lead, *ends.shape[-2:]))
    starts = jnp.broadcast_to(start_pos, ends.shape)
    scores = jnp.take_along_axis(cz, ends, axis=-1) - jnp.take_along_axis(
        cz, starts, axis=-1
    )
    return scores * used.astype(scores.dtype)


@partial(
    jax.jit, static_argnames=("codec", "block_size", "n_docs", "scale", "vq")
)
def _score_packed(
    q,
    seg,
    start_pos,
    start_abs,
    vals,
    doc_ids,
    ctrl,
    data,
    words,
    widths,
    comps,
    vq_lo,
    vq_scale,
    vq_codebook,
    *,
    codec: str,
    block_size: int,
    n_docs: int,
    scale: float,
    vq: str = "f16",
):
    if codec == "uncompressed":  # decode-free layout
        c = comps
    else:
        gaps = decode_block_gaps(
            codec, {"ctrl": ctrl, "data": data, "words": words, "widths": widths},
            block_size,
        )
        c = components_from_gaps(gaps, seg, start_pos, start_abs)
    if vq == "f16":
        vals_f = dequantise_values(vals, scale)
    else:  # quantized values: codes → storage-unit f32 → value scale
        from . import values as value_codecs

        cb = vq_codebook.reshape(-1) if vq == "pq" else None
        vals_f = value_codecs.decode_codes(
            vq, vals, vq_lo, vq_scale, cb
        ) * jnp.float32(scale)
    prod = block_products(q, c, vals_f, seg)
    return combine_block_scores(prod, seg, doc_ids, n_docs)


def _packed_device_args(packed: PackedBlocks):
    """The (arrays, static-kwargs) pair ``_score_packed`` consumes."""
    zero_u8 = np.zeros((packed.n_blocks, 1), dtype=np.uint8)
    zero_u32 = np.zeros((packed.n_blocks, 1), dtype=np.uint32)
    zero_i32 = np.zeros((packed.n_blocks,), dtype=np.int32)
    zero_f32 = np.zeros((packed.n_blocks, 1), dtype=np.float32)
    arrays = (
        jnp.asarray(packed.seg),
        jnp.asarray(packed.start_pos),
        jnp.asarray(packed.start_abs),
        jnp.asarray(packed.vals),
        jnp.asarray(packed.doc_ids),
        jnp.asarray(packed.ctrl if packed.ctrl is not None else zero_u8),
        jnp.asarray(packed.data if packed.data is not None else zero_u8),
        jnp.asarray(packed.words if packed.words is not None else zero_u32),
        jnp.asarray(packed.widths if packed.widths is not None else zero_i32),
        jnp.asarray(
            packed.comps
            if packed.comps is not None
            else np.zeros(packed.seg.shape, dtype=np.int32)
        ),
        jnp.asarray(packed.vq_lo if packed.vq_lo is not None else zero_f32),
        jnp.asarray(
            packed.vq_scale if packed.vq_scale is not None else zero_f32
        ),
        jnp.asarray(
            packed.vq_codebook
            if packed.vq_codebook is not None
            else np.zeros((1,), dtype=np.float32)
        ),
    )
    static = dict(
        codec=packed.codec,
        block_size=packed.block_size,
        n_docs=packed.n_docs,
        scale=float(packed.value_format.scale),
        vq=getattr(packed, "vq", "f16"),
    )
    return arrays, static


def score_packed(q_dense, packed: PackedBlocks) -> jnp.ndarray:
    """Scores of every document for one dense query. [n_docs] f32."""
    arrays, static = _packed_device_args(packed)
    return _score_packed(jnp.asarray(q_dense, dtype=jnp.float32), *arrays, **static)


def score_packed_batch(Q, packed: PackedBlocks) -> jnp.ndarray:
    """Scores for a batch of dense queries. [n_queries, n_docs].

    One ``vmap`` over the jit'd scorer — a single dispatch per batch
    (the decode is still re-traced per query inside the batched graph;
    the *fused* decode-once path is the batched kernel in
    ``repro.kernels``)."""
    arrays, static = _packed_device_args(packed)
    return jax.vmap(lambda q: _score_packed(q, *arrays, **static))(
        jnp.asarray(Q, dtype=jnp.float32)
    )


def make_doc_aligned_scan(
    mesh, axes: tuple[str, ...], docs_local: int, scale: float,
    codec: str = "dotvbyte",
):
    """§Perf opt1: doc-aligned sharded scan (EXPERIMENTS.md).

    Each device owns a contiguous range of ``docs_local`` documents AND
    exactly the packed blocks containing them (arrays carry an explicit
    leading shard dim sharded over ``axes``; doc_ids are range-LOCAL),
    so the score scatter is device-local and the scan path carries ZERO
    collectives. Queries replicate. Any layout codec works — the arrays
    come from ``layout.pack_blocks_sharded(codec=…)``.
    fn(arrays, Q [nq, dim_pad]) → [nq, n_shards·docs_local]."""
    from jax.sharding import PartitionSpec as P

    def local_scan(arrays, Q):
        arrays = jax.tree.map(lambda a: a[0], arrays)  # drop shard dim
        if codec == "uncompressed":
            comps = arrays["comps"]
        else:
            gaps = decode_block_gaps(codec, arrays, arrays["seg"].shape[-1])
            comps = components_from_gaps(
                gaps, arrays["seg"], arrays["start_pos"], arrays["start_abs"]
            )
        vals_f = dequantise_values(arrays["vals"], scale)

        def one(q):
            prod = block_products(q, comps, vals_f, arrays["seg"])
            return combine_block_scores(prod, arrays["seg"], arrays["doc_ids"], docs_local)

        return jax.vmap(one)(Q)

    return jax.shard_map(
        local_scan,
        mesh=mesh,
        in_specs=(P(axes), P(None, None)),
        out_specs=P(None, axes),
        check_vma=False,
    )


# ---------------------------------------------------------------------------
# per-document row layout (serve-engine rescoring path)
# ---------------------------------------------------------------------------
# Candidate re-scoring in the batched serve engines gathers a fixed-
# capacity row per candidate document (built by ``layout.pack_rows``).
# Rows are either raw components (uncompressed) or a codec stream —
# (ctrl, data) for DotVByte/StreamVByte, (words, widths) for bitpack —
# decoded on the fly; the decode is identical to the block path but row
# gaps carry their absolute first component, so a plain cumsum rebuilds
# the ids.

#: row-form fields every codec shares (vals/nnz); everything else in a
#: ``pack_rows`` output is codec payload (``<stream>_rows``)
_ROW_COMMON_KEYS = ("vals_rows", "nnz_rows", "comps_rows")


def decode_doc_rows(codec: str, payload, l_max: int | None = None) -> jnp.ndarray:
    """Row-payload streams → absolute comps i32 [N, L].

    Dispatches through the layout registry (``layout.get_layout``), so
    ANY codec registered in core/layout.py decodes rows with zero edits
    here: ``payload`` maps the codec's ``<stream>_rows`` fields (as
    emitted by ``layout.pack_rows`` — ctrl/data for the byte codecs,
    words/widths for bitpack) to the gathered arrays; ``l_max`` is the
    row capacity (needed by fixed-width codecs). Row gaps are encoded
    with the first gap absolute (per-doc alignment), so a plain cumsum
    rebuilds the ids; padding gaps are 0 with value 0, the usual
    neutral trick.

    Back-compat: the PR-2 positional form ``decode_doc_rows(codec,
    ctrl_rows, data_rows)`` still works (DeprecationWarning)."""
    if not hasattr(payload, "items"):  # legacy (codec, ctrl, data) form
        import warnings

        warnings.warn(
            "decode_doc_rows(codec, ctrl_rows, data_rows) is deprecated; "
            "pass a payload mapping of <stream>_rows arrays",
            DeprecationWarning,
            stacklevel=2,
        )
        payload, l_max = {"ctrl_rows": payload, "data_rows": l_max}, None
    from .layout import get_layout

    lc = get_layout(codec)
    if lc.decode_free:
        raise ValueError(
            f"codec {codec!r} is decode-free; rows store absolute components"
        )
    streams = {
        (k[: -len("_rows")] if k.endswith("_rows") else k): v
        for k, v in payload.items()
    }
    gaps = lc.decode(streams, 0 if l_max is None else int(l_max))
    return jnp.cumsum(gaps, axis=1)


def decode_doc_rows_dotvbyte(ctrl_rows: jnp.ndarray, data_rows: jnp.ndarray) -> jnp.ndarray:
    return decode_doc_rows("dotvbyte", {"ctrl_rows": ctrl_rows, "data_rows": data_rows})


#: codecs already warned about missing fused rows kernels (warn once)
_NO_ROWS_KERNEL_WARNED: set = set()


def _check_rows_backend(backend: str) -> None:
    from repro.kernels.modes import SCORING_BACKENDS

    if backend not in SCORING_BACKENDS:
        raise ValueError(
            f"unknown scoring backend {backend!r}; have {list(SCORING_BACKENDS)}"
        )


def _warn_no_rows_kernel(codec: str) -> None:
    if codec not in _NO_ROWS_KERNEL_WARNED:
        import warnings

        _NO_ROWS_KERNEL_WARNED.add(codec)
        warnings.warn(
            f"codec {codec!r} has no fused rows kernel registered; "
            f"serving backend='pallas' through the jnp path",
            RuntimeWarning,
            stacklevel=3,
        )


def _gather_decode_rows(codec: str, arrays, docs: jnp.ndarray):
    """Gather + decode the packed rows of ``docs`` → (comps, vals,
    nnz) — the ONE row-materialisation both the single-query and the
    batched jnp rescoring paths share (so a codec/layout change lands
    in exactly one place).

    The VALUE codec is inferred from the payload keys
    (``values.infer_rows_vq``, DESIGN.md §12): quantized rows gather
    their u8 codes + per-row clip columns (or the shared codebook) and
    dequantize through the same ``values.decode_codes`` helpers the
    fused kernels run, so every execution mode computes identical
    value bits.  Decoded values are storage-unit f32; the downstream
    ``value_scale`` FMA applies unchanged."""
    from . import values as value_codecs
    from .layout import get_layout

    vq = value_codecs.infer_rows_vq(arrays)
    vals = jnp.take(arrays["vals_rows"], docs, axis=0)
    nnz = jnp.take(arrays["nnz_rows"], docs, axis=0)
    if vq != "f16":
        lo = step = cb = None
        if vq == "pq":
            cb = jnp.asarray(arrays["vq_codebook"], jnp.float32).reshape(-1)
        else:
            lo_key, sc_key = value_codecs.sq_keys(vq)
            lo = jnp.take(arrays[lo_key], docs, axis=0)
            step = jnp.take(arrays[sc_key], docs, axis=0)
        vals = value_codecs.decode_codes(vq, vals, lo, step, cb)
    if get_layout(codec).decode_free:  # absolute components stored raw
        comps = jnp.take(arrays["comps_rows"], docs, axis=0)
    else:
        payload = {
            k: jnp.take(arrays[k], docs, axis=0)
            for k in arrays
            if k.endswith("_rows")
            and k not in _ROW_COMMON_KEYS
            and not k.startswith("vq_")
        }
        comps = decode_doc_rows(codec, payload, l_max=vals.shape[-1])
    return comps, vals, nnz


def score_candidate_rows(
    codec: str,
    arrays,
    docs: jnp.ndarray,
    q: jnp.ndarray,
    scale: float,
    backend: str = "jnp",
) -> jnp.ndarray:
    """Gather the packed rows of ``docs`` and score them exactly.

    The ONE candidate-rescoring path shared by every serve engine
    (DESIGN.md §7): ``arrays`` holds the row form produced by
    ``layout.pack_rows`` under any registered codec — possibly
    alongside engine-specific fields, which are ignored. Sentinel doc
    ids gather the all-zero row and score 0; mask them afterwards.

    ``backend`` selects the execution path (DESIGN.md §3, §7): ``"jnp"``
    is the take→decode→dot reference below; ``"pallas"`` dispatches to
    the codec's fused rows kernel from ``repro.kernels.registry``
    (scalar-prefetch HBM→VMEM row gather, decode and dot in VMEM —
    decoded components never touch HBM) in its default — compiled —
    mode, while ``"pallas_interpret"`` / ``"pallas_compiled"`` pin the
    kernel ``mode`` explicitly (``repro.kernels.modes``).  Codecs with
    no registered rows kernel fall back to jnp with a one-time warning.
    All paths return identical scores (asserted by the parity suite
    and ``make kernel-parity``)."""
    _check_rows_backend(backend)
    if backend != "jnp":
        from repro.kernels.modes import backend_mode
        from repro.kernels.registry import rows_scorer

        fn = rows_scorer(codec)
        if fn is not None:
            return fn(arrays, docs, q, scale, backend_mode(backend))
        _warn_no_rows_kernel(codec)
    comps, vals, nnz = _gather_decode_rows(codec, arrays, docs)
    return score_doc_rows(q, comps, vals, nnz, scale)


def score_candidate_rows_batch(
    codec: str,
    arrays,
    docs: jnp.ndarray,
    Q: jnp.ndarray,
    scale: float,
    backend: str = "jnp",
) -> jnp.ndarray:
    """Rescore ONE candidate set against a whole query batch → [nq, C].

    The decode-once/score-many form of ``score_candidate_rows``
    (DESIGN.md §8): when every query in a batch shares the candidate
    set (the flat engine's full scan; shard-replicated rescoring), the
    candidate rows are gathered and decoded once and dotted against
    every resident query. ``backend="pallas"`` dispatches to the codec's
    ``rows_scores_batch`` kernel registry entry, which keeps each
    decoded row in VMEM across the whole query batch; the jnp path
    hoists the decode out of a ``vmap`` over ``score_doc_rows``, so
    per-query scores are bitwise those of the single-query path."""
    _check_rows_backend(backend)
    if backend != "jnp":
        from repro.kernels.modes import backend_mode
        from repro.kernels.registry import rows_batch_scorer

        fn = rows_batch_scorer(codec)
        if fn is not None:
            return fn(arrays, docs, Q, scale, backend_mode(backend))
        _warn_no_rows_kernel(codec)
    comps, vals, nnz = _gather_decode_rows(codec, arrays, docs)
    # comps/vals/nnz carry no query axis → the decode stays un-batched
    # under vmap (computed once); only the q-gather + FMA replicate
    return jax.vmap(lambda q: score_doc_rows(q, comps, vals, nnz, scale))(Q)


def score_doc_rows(
    q: jnp.ndarray,
    comps_rows: jnp.ndarray,  # i32 [N, L]
    vals_rows: jnp.ndarray,  # [N, L] storage dtype
    nnz: jnp.ndarray,  # i32 [N]
    scale: float,
) -> jnp.ndarray:
    """Exact ⟨q, doc⟩ for N gathered candidate rows → [N] f32."""
    L = comps_rows.shape[1]
    mask = jnp.arange(L)[None, :] < nnz[:, None]
    qv = jnp.take(q, comps_rows, axis=0)
    vals = dequantise_values(vals_rows, scale)
    return (qv * vals * mask).sum(axis=1)
