"""Value codecs (DESIGN.md §12) — the quantization axis ORTHOGONAL to
the id codec in ``core/layout.py``.

Every layout codec compresses the doc-id gap stream; the value stream
rode as raw storage dtype (f16/u8) until now.  A value codec ``vq``
replaces the value stream with quantized codes *in the same arrays*:

======== ===================== =======================================
vq       codes per stored byte decode
======== ===================== =======================================
f16      —                     pass-through (today's layout, bit-exact)
u8_sq    1                     per-row clip range: lo + code·step
u4_sq    2 (nibble-packed)     per-row clip range, 4-bit codes
pq       ``PQ_M``              codebook gather: sub-vectors of PQ_M
                               consecutive values → one u8 code
======== ===================== =======================================

The codes ride **inside** ``vals_rows`` / ``PackedBlocks.vals`` itself
(dtype u8, width divided by the pack factor), and the per-row clip
ranges / the codebook ride as ordinary payload arrays —
``vq_lo_rows``/``vq_scale_rows`` (u8), ``vq_lo4_rows``/
``vq_scale4_rows`` (u4) f32 ``[N+1, 1]`` columns, ``vq_codebook`` f32
``[PQ_K, PQ_M]`` — so ``pad_stack``, shard stacking, ``mmap_npz`` and
the artifact manifest carry them with zero edits.  The vq of a row
array dict is INFERRED from which of these keys are present
(:func:`infer_rows_vq`), which is what lets every engine and the
sharded/segment/mutable wrappers serve quantized values with zero
per-engine edits.

Parity contract: the scalar quantizers fit each row's clip range on
that row's OWN live values, so a document's code bytes depend only on
its own values — a row packed inside a shard, a delta segment or a
monolithic build is byte-identical (the same invariant the per-doc gap
alignment gives the id streams).  PQ codebooks are fit per *build*
(deterministic seeded k-means), so PQ bytes are reproducible for a
given build input but NOT byte-stable across different shardings —
documented in DESIGN.md §12.

The decode helpers below are pure elementwise jnp (FMA / nibble
unpack / flat codebook gather) shared VERBATIM by the jnp reference
path, the XLA lowering and the in-kernel Pallas dequant stage — one
implementation, so the three execution modes stay byte-identical to
each other at every vq.  Decoded values are in STORAGE units: the
downstream ``value_scale`` FMA applies unchanged.
"""

from __future__ import annotations

from typing import Mapping

import numpy as np

__all__ = [
    "VALUE_CODECS",
    "PQ_K",
    "PQ_M",
    "code_factor",
    "n_vq_streams",
    "check_vq",
    "encode_rows_values",
    "encode_block_values",
    "fit_pq_codebook",
    "unpack_nibbles",
    "dequant_sq",
    "dequant_pq",
    "decode_codes",
    "infer_rows_vq",
    "rows_vq_streams",
    "value_payload_bytes",
]

#: registered value codecs (RetrieverConfig.vq / pack-time knob)
VALUE_CODECS = ("f16", "u8_sq", "u4_sq", "pq")

#: PQ codebook entries (codes are u8) and sub-vector width
PQ_K = 256
PQ_M = 2

#: per-row clip-range payload keys by vq (f32 [N+1, 1] columns)
_SQ_KEYS = {
    "u8_sq": ("vq_lo_rows", "vq_scale_rows"),
    "u4_sq": ("vq_lo4_rows", "vq_scale4_rows"),
}

_MAXCODE = {"u8_sq": 255, "u4_sq": 15}


def sq_keys(vq: str) -> tuple[str, str]:
    """The (lo, scale) payload key names of a scalar-quant vq."""
    return _SQ_KEYS[vq]


def check_vq(vq: str) -> str:
    if vq not in VALUE_CODECS:
        raise ValueError(f"unknown value codec {vq!r}; have {list(VALUE_CODECS)}")
    return vq


def code_factor(vq: str) -> int:
    """Logical values per stored byte column: the value array's stored
    width is ``logical_width // code_factor(vq)``."""
    check_vq(vq)
    if vq == "u4_sq":
        return 2
    if vq == "pq":
        return PQ_M
    return 1


def n_vq_streams(vq: str) -> int:
    """How many extra payload streams the rows kernel threads for vq
    (lo+scale columns for scalar quant, the resident codebook for PQ)."""
    check_vq(vq)
    if vq in _SQ_KEYS:
        return 2
    return 1 if vq == "pq" else 0


# ---------------------------------------------------------------------------
# pack-time encoders (numpy, host side)
# ---------------------------------------------------------------------------


def _fit_clip(
    vals: np.ndarray, live: np.ndarray, maxcode: int,
    clip: tuple[float, float] | None,
):
    """Per-row clip range on each row's OWN live values → (lo, step),
    f32 [R, 1].  ``clip=(lo, hi)`` overrides with one global range
    (the QAT export path) — still STORED per row, so the per-document
    byte-parity invariant is unchanged."""
    v = vals.astype(np.float32)
    if clip is not None:
        lo = np.full((v.shape[0], 1), np.float32(clip[0]))
        hi = np.full((v.shape[0], 1), np.float32(clip[1]))
    else:
        big, small = np.float32(np.finfo(np.float32).max), np.float32(
            np.finfo(np.float32).min
        )
        lo = np.where(live, v, big).min(axis=1, keepdims=True)
        hi = np.where(live, v, small).max(axis=1, keepdims=True)
        none_live = ~live.any(axis=1, keepdims=True)
        lo = np.where(none_live, 0.0, lo).astype(np.float32)
        hi = np.where(none_live, 0.0, hi).astype(np.float32)
    step = np.where(hi > lo, (hi - lo) / np.float32(maxcode), 1.0).astype(
        np.float32
    )
    return lo.astype(np.float32), step


def _sq_codes(
    vals: np.ndarray, live: np.ndarray, maxcode: int,
    clip: tuple[float, float] | None,
):
    lo, step = _fit_clip(vals, live, maxcode, clip)
    v = vals.astype(np.float32)
    codes = np.clip(np.rint((v - lo) / step), 0, maxcode).astype(np.uint8)
    return np.where(live, codes, 0).astype(np.uint8), lo, step


def pack_nibbles(codes: np.ndarray) -> np.ndarray:
    """4-bit codes [..., 2W] → packed bytes [..., W]: element ``2i`` in
    the low nibble, ``2i+1`` in the high nibble of byte ``i``."""
    if codes.shape[-1] % 2:
        raise ValueError("nibble packing needs an even trailing dim")
    pairs = codes.reshape(*codes.shape[:-1], -1, 2)
    return (pairs[..., 0] | (pairs[..., 1] << 4)).astype(np.uint8)


def fit_pq_codebook(
    subvecs: np.ndarray, seed: int = 0, iters: int = 8, sample: int = 4096
) -> np.ndarray:
    """Deterministic seeded Lloyd k-means over [S, PQ_M] sub-vectors →
    f32 codebook [PQ_K, PQ_M].  Fixed iteration count, deterministic
    subsample, argmin ties to the lowest index — the same inputs always
    produce the same codebook bytes."""
    sv = np.asarray(subvecs, np.float32).reshape(-1, PQ_M)
    if len(sv) == 0:
        return np.zeros((PQ_K, PQ_M), np.float32)
    rng = np.random.default_rng(seed)
    if len(sv) > sample:
        sv = sv[rng.choice(len(sv), size=sample, replace=False)]
    # init: evenly spaced points of the norm-sorted sample (deterministic
    # spread; duplicates are fine — empty clusters keep their centroid)
    order = np.argsort(np.einsum("ij,ij->i", sv, sv), kind="stable")
    idx = np.linspace(0, len(sv) - 1, PQ_K).astype(np.int64)
    cb = sv[order[idx]].copy()
    for _ in range(iters):
        d2 = ((sv[:, None, :] - cb[None, :, :]) ** 2).sum(-1)  # [S, K]
        assign = np.argmin(d2, axis=1)
        for k in range(PQ_K):
            members = sv[assign == k]
            if len(members):
                cb[k] = members.mean(axis=0)
    return cb.astype(np.float32)


def _pq_codes(vals: np.ndarray, codebook: np.ndarray) -> np.ndarray:
    """Nearest-centroid assignment of every PQ_M sub-vector → u8 codes
    [..., W/PQ_M] (ties to the lowest index, matching the fit)."""
    v = vals.astype(np.float32)
    sv = v.reshape(*v.shape[:-1], -1, PQ_M)
    d2 = ((sv[..., None, :] - codebook[None, :, :]) ** 2).sum(-1)
    return np.argmin(d2, axis=-1).astype(np.uint8)


def encode_rows_values(
    vals_rows: np.ndarray,  # [N+1, cap] storage dtype (row N = sentinel)
    nnz_rows: np.ndarray,  # i32 [N+1]
    vq: str,
    clip: tuple[float, float] | None = None,
    pq_seed: int = 0,
):
    """Quantize a packed row value matrix → (codes u8 [N+1, cap/factor],
    payload extras dict).  ``cap`` must be a multiple of
    ``LANE_MULTIPLE * code_factor(vq)`` (``layout.pack_rows`` rounds it)
    so stored code widths stay lane-aligned."""
    check_vq(vq)
    if vq == "f16":
        return vals_rows, {}
    cap = vals_rows.shape[1]
    if cap % code_factor(vq):
        raise ValueError(
            f"row capacity {cap} not a multiple of the {vq} pack factor "
            f"{code_factor(vq)}"
        )
    live = np.arange(cap)[None, :] < np.asarray(nnz_rows)[:, None]
    if vq in _SQ_KEYS:
        codes, lo, step = _sq_codes(vals_rows, live, _MAXCODE[vq], clip)
        if vq == "u4_sq":
            codes = pack_nibbles(codes)
        lo_key, sc_key = _SQ_KEYS[vq]
        return codes, {lo_key: lo, sc_key: step}
    # pq: fit on live sub-vectors only (a sub-vector is live when its
    # first element is — trailing dead halves carry the padded zero the
    # row matrix already holds, masked by nnz at score time anyway)
    v = np.where(live, vals_rows.astype(np.float32), 0.0)
    sub_live = live[:, ::PQ_M]
    cb = fit_pq_codebook(
        v.reshape(-1, PQ_M)[sub_live.reshape(-1)], seed=pq_seed
    )
    codes = _pq_codes(v, cb)
    return np.where(sub_live, codes, 0).astype(np.uint8), {"vq_codebook": cb}


def encode_block_values(
    vals: np.ndarray,  # [B, T] storage dtype
    seg: np.ndarray,  # [B, T], -1 = padding
    vq: str,
    clip: tuple[float, float] | None = None,
    pq_seed: int = 0,
):
    """Block-form mirror of :func:`encode_rows_values`: per-BLOCK clip
    ranges (``vq_lo``/``vq_scale`` f32 [B, 1]) or a shared codebook.
    Live mask is ``seg >= 0``."""
    check_vq(vq)
    if vq == "f16":
        return vals, {}
    live = np.asarray(seg) >= 0
    if vq in _SQ_KEYS:
        codes, lo, step = _sq_codes(vals, live, _MAXCODE[vq], clip)
        if vq == "u4_sq":
            codes = pack_nibbles(codes)
        return codes, {"vq_lo": lo, "vq_scale": step}
    v = np.where(live, vals.astype(np.float32), 0.0)
    sub_live = live[:, ::PQ_M]
    cb = fit_pq_codebook(
        v.reshape(-1, PQ_M)[sub_live.reshape(-1)], seed=pq_seed
    )
    codes = _pq_codes(v, cb)
    return np.where(sub_live, codes, 0).astype(np.uint8), {"vq_codebook": cb}


# ---------------------------------------------------------------------------
# decode (jnp, shared by jnp reference / XLA lowering / Pallas kernels)
# ---------------------------------------------------------------------------


def unpack_nibbles(codes):
    """Packed bytes [..., W] → interleaved 4-bit codes i32 [..., 2W]
    (low nibble first — the inverse of :func:`pack_nibbles`)."""
    import jax.numpy as jnp

    c = codes.astype(jnp.int32)
    return jnp.stack([c & 0xF, (c >> 4) & 0xF], axis=-1).reshape(
        *codes.shape[:-1], -1
    )


def dequant_sq(codes, lo, step):
    """code → clip-range FMA: ``lo + code·step`` in f32.  ``lo``/``step``
    broadcast ([R, 1] columns on the batched path, scalars in-kernel) —
    pure elementwise, so every execution mode computes identical bits."""
    import jax.numpy as jnp

    return lo + codes.astype(jnp.float32) * step


def dequant_pq(codes, codebook_flat):
    """u8 codes [..., W] + flat codebook f32 [PQ_K·PQ_M] → values
    f32 [..., W·PQ_M] via a flat gather (code·M + lane offset)."""
    import jax.numpy as jnp

    c = codes.astype(jnp.int32)
    idx = c[..., None] * PQ_M + jnp.arange(PQ_M, dtype=jnp.int32)
    flat = jnp.take(codebook_flat, idx.reshape(*c.shape[:-1], -1), axis=0)
    return flat


def decode_codes(vq: str, codes, lo=None, step=None, codebook_flat=None):
    """One dequant dispatch for all three execution modes: quantized
    codes [..., W] → f32 storage-unit values [..., W·factor]."""
    if vq == "f16":
        import jax.numpy as jnp

        return codes.astype(jnp.float32)
    if vq == "u8_sq":
        return dequant_sq(codes, lo, step)
    if vq == "u4_sq":
        return dequant_sq(unpack_nibbles(codes), lo, step)
    if vq == "pq":
        return dequant_pq(codes, codebook_flat)
    raise ValueError(f"unknown value codec {vq!r}; have {list(VALUE_CODECS)}")


# ---------------------------------------------------------------------------
# rows-array plumbing (vq inference + kernel stream marshalling)
# ---------------------------------------------------------------------------

#: every payload key a value codec can add to a rows dict
VQ_ROW_KEYS = ("vq_lo_rows", "vq_scale_rows", "vq_lo4_rows",
               "vq_scale4_rows", "vq_codebook")


def infer_rows_vq(arrays: Mapping) -> str:
    """Which value codec a packed rows dict carries — inferred from the
    payload keys, so serving needs no side-channel: ``vq_codebook`` →
    pq, ``vq_lo4_rows`` → u4_sq, ``vq_lo_rows`` → u8_sq, else f16."""
    if "vq_codebook" in arrays:
        return "pq"
    if "vq_lo4_rows" in arrays:
        return "u4_sq"
    if "vq_lo_rows" in arrays:
        return "u8_sq"
    return "f16"


def rows_vq_streams(vq: str, arrays: Mapping) -> list:
    """The ordered extra operand streams the rows kernel threads for
    ``vq``: the per-row lo/scale columns (gathered per grid step like
    any row stream) or the grid-resident flat codebook ``[1, K·M]``."""
    import jax.numpy as jnp

    if vq in _SQ_KEYS:
        lo_key, sc_key = _SQ_KEYS[vq]
        return [jnp.asarray(arrays[lo_key]), jnp.asarray(arrays[sc_key])]
    if vq == "pq":
        cb = jnp.asarray(arrays["vq_codebook"], jnp.float32)
        return [cb.reshape(1, PQ_K * PQ_M)]
    return []


def value_payload_bytes(arrays: Mapping) -> int:
    """Per-candidate value bytes of a rows dict: code bytes per row +
    clip-range columns, with the (read-once) codebook amortised by the
    caller.  Used by the bench bits/posting accounting."""
    per_row = int(np.asarray(arrays["vals_rows"]).dtype.itemsize) * int(
        np.asarray(arrays["vals_rows"]).shape[-1]
    )
    for k in ("vq_lo_rows", "vq_scale_rows", "vq_lo4_rows", "vq_scale4_rows"):
        if k in arrays:
            per_row += int(np.asarray(arrays[k]).dtype.itemsize)
    return per_row
