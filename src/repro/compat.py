"""Forward-compatibility shims for newer jax APIs on jax 0.4.x.

The codebase is written against the current jax surface (``jax.set_mesh``,
``jax.shard_map`` with ``check_vma``, ``jax.sharding.AxisType``,
``jax.sharding.get_abstract_mesh``, ``jax.make_mesh(..., axis_types=…)``).
This container ships jax 0.4.37, where those names either do not exist or
have older spellings.  ``install()`` (run once on ``import repro``) fills
the gaps with thin adapters; on a new-enough jax every branch is a no-op,
so upgrading jax silently drops the shims.

Each adapter is behavioural, not cosmetic-only:

* ``set_mesh(mesh)``     → the mesh itself (``Mesh`` is a context manager
  that installs the thread-resource env, which is what the new API does).
* ``shard_map(..., check_vma=)`` → ``jax.experimental.shard_map.shard_map``
  with ``check_rep`` carrying the flag (same replication-check semantics).
* ``AxisType``           → minimal enum; 0.4 meshes are always "auto".
* ``make_mesh``          → accepts and drops ``axis_types``.
* ``get_abstract_mesh``  → the thread-local physical mesh (axis_names /
  shape are the only fields our callers read).
"""

from __future__ import annotations

import enum
import functools

import jax
import jax.sharding as _jshard

__all__ = ["install"]


class _AxisType(enum.Enum):
    Auto = "auto"
    Explicit = "explicit"
    Manual = "manual"


def _get_abstract_mesh():
    from jax._src.mesh import thread_resources

    return thread_resources.env.physical_mesh


def install() -> None:
    if not hasattr(_jshard, "AxisType"):
        _jshard.AxisType = _AxisType

    if not hasattr(_jshard, "get_abstract_mesh"):
        _jshard.get_abstract_mesh = _get_abstract_mesh

    native_make_mesh = getattr(jax, "make_mesh", None)
    if native_make_mesh is not None:
        import inspect

        try:
            takes_axis_types = "axis_types" in inspect.signature(native_make_mesh).parameters
        except (TypeError, ValueError):
            takes_axis_types = True
        if not takes_axis_types:

            @functools.wraps(native_make_mesh)
            def make_mesh(axis_shapes, axis_names, *, axis_types=None, **kw):
                return native_make_mesh(axis_shapes, axis_names, **kw)

            jax.make_mesh = make_mesh

    if not hasattr(jax, "set_mesh"):
        # Mesh.__enter__ installs the thread-resource env — exactly the
        # scope the new context manager provides.
        jax.set_mesh = lambda mesh: mesh

    if not hasattr(jax, "shard_map"):
        from jax.experimental.shard_map import shard_map as _shard_map

        def shard_map(f, *, mesh, in_specs, out_specs, check_vma=True, **kw):
            return _shard_map(
                f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                check_rep=check_vma, **kw,
            )

        jax.shard_map = shard_map

    # Compiled.cost_analysis(): newer jax returns one flat dict; 0.4.x
    # returns a per-program list of dicts.
    try:
        import jax.stages as _stages

        native_cost = _stages.Compiled.cost_analysis

        def cost_analysis(self):
            out = native_cost(self)
            if isinstance(out, (list, tuple)):
                return out[0] if out else {}
            return out

        if getattr(native_cost, "__name__", "") != "cost_analysis_compat":
            cost_analysis.__name__ = "cost_analysis_compat"
            _stages.Compiled.cost_analysis = cost_analysis
    except Exception:  # pragma: no cover - future jax restructures
        pass
