"""Online serving pipeline (DESIGN.md §8): compiled-plan cache,
bucketed micro-batching scheduler, result cache, and serving metrics.

The paper's claim is that forward-index compression must not
compromise inner-product latency; this module is where that claim
meets *traffic* instead of one frozen batch. Four layers, stacked:

* ``PlanCache`` — the compile layer extracted from
  ``Retriever.__init__``: ONE executable per
  ``(engine, codec, backend, k, bucket)`` key. Arbitrary query-batch
  sizes are padded up to the smallest covering bucket (default
  ``DEFAULT_BUCKETS``, extended by the ``RetrieverConfig.batch_size``
  hint), so steady-state traffic always hits a warm compiled plan —
  a fresh batch shape costs a bucket-pad, not an XLA recompile.
  ``compiles`` counts plan creations (the recompile metric).

* ``Pipeline`` — the host-side micro-batching scheduler: ``submit``
  admits one query at a time, the queue coalesces into the smallest
  covering bucket (padded slots carry the zero query and are sliced
  away on the way out), a full largest-bucket queue dispatches
  immediately, and ``deadline_us`` bounds how long a lone query waits
  for batch-mates — latency-sensitive traffic is never starved by
  batch-filling. Batched work dispatches through the plan cache into
  the engines' ``search_batch`` (the kernel registry's ``*_batch``
  rows entries under ``backend="pallas"``), and per-query top-k is
  de-multiplexed back to each ticket in submission order.

* ``ResultCache`` — an LRU over the *quantized sparse query* (nonzero
  component ids + values rounded to the index's storage dtype): the
  repeat-heavy head of real query logs short-circuits dispatch
  entirely and replays the exact top-k previously served. A cached
  answer is only valid for the index state that produced it:
  ``invalidate()`` flushes every entry, and the ``epoch`` tag lets the
  pipeline invalidate automatically whenever the owning retriever's
  ``epoch`` attribute moves (a ``MutableRetriever`` bumps it on every
  insert/delete/update and on each generation flip — DESIGN.md §10),
  so a mutation can never replay a pre-mutation top-k.

* ``ServeStats`` — the metrics contract: QPS, p50/p95/p99 end-to-end
  latency, result-cache hit rate, per-bucket dispatch counts and
  occupancy (real queries / bucket capacity), the plan-cache recompile
  count, and the result-cache invalidation counters (flushes and
  entries dropped).

Determinism contract (tests/test_pipeline.py, ``make pipeline-smoke``):
bucketed/padded/cached serving returns byte-identical top-k ids and
scores to a direct ``Retriever.search`` of the same queries, for every
engine × codec × backend.

The wall clock is injectable (``clock=``) so deadline semantics are
testable with a fake clock; production uses ``time.perf_counter``.

Threading model (DESIGN.md §11): every layer here is safe to drive
from multiple threads — ``PlanCache.get`` creates plans under a lock,
``ResultCache`` serializes get/put/invalidate, ``ServeStats`` guards
its counters, and ``Pipeline`` holds one scheduler lock across
admission/dispatch (one dispatcher at a time; submitters from other
threads queue on the lock, never on a torn queue). The overlap
counters (``prefetch_hits``/``prefetch_misses``/``merge_wall_us``/
``blocked_swap_us``) are synced off the serving stack at snapshot
time, so the prefetch and background-merge wins are observable, not
just benchmarked.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from collections import OrderedDict, deque
from typing import TYPE_CHECKING, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

if TYPE_CHECKING:  # import cycle: api.py imports this module at runtime
    from .api import Retriever

__all__ = [
    "DEFAULT_BUCKETS",
    "plan_buckets",
    "PlanKey",
    "SearchPlan",
    "PlanCache",
    "ResultCache",
    "ServeStats",
    "Pipeline",
    "quantized_query_key",
    "synthetic_trace",
]

#: default padding buckets — arbitrary batch sizes round up to the
#: smallest covering entry; power-of-two spacing bounds pad waste < 2×
DEFAULT_BUCKETS: Tuple[int, ...] = (1, 2, 4, 8, 16, 32, 64, 128)


def plan_buckets(
    batch_size: Optional[int] = None,
    buckets: Optional[Sequence[int]] = None,
) -> Tuple[int, ...]:
    """The sorted bucket set: an explicit ``buckets`` sequence (used
    verbatim), or ``DEFAULT_BUCKETS`` extended by the
    ``RetrieverConfig.batch_size`` hint (the expected steady-state
    batch gets an exact-fit plan)."""
    if buckets is not None:
        out = set(buckets)
    else:
        out = set(DEFAULT_BUCKETS)
        if batch_size is not None:
            out.add(int(batch_size))
    if not out or any(
        not isinstance(b, (int, np.integer)) or isinstance(b, bool) or b < 1
        for b in out
    ):
        raise ValueError(
            f"buckets must be a non-empty set of positive ints, got "
            f"{sorted(out)}"
        )
    return tuple(sorted(int(b) for b in out))


def synthetic_trace(
    rng: np.random.Generator,
    n_requests: int,
    n_queries: int,
    repeat_frac: float = 0.25,
) -> np.ndarray:
    """Repeat-heavy query-id trace — the ONE synthetic workload shape
    the load generator (``launch/serve.py --pipeline``) and the
    Table-4 scheduler benchmark share, so both gates measure the same
    traffic: ``repeat_frac`` of requests re-ask one of a small head
    (``n_queries // 4`` hot queries, the skew of real query logs), the
    rest draw uniformly. Returns i64 [n_requests] query indices."""
    n_head = max(1, n_queries // 4)
    return np.where(
        rng.random(n_requests) < repeat_frac,
        rng.integers(0, n_head, size=n_requests),
        rng.integers(0, n_queries, size=n_requests),
    )


# ---------------------------------------------------------------------------
# plan cache — the compile layer
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PlanKey:
    """Identity of one compiled search executable.

    ``mode`` is the RESOLVED kernel execution mode
    (``repro.kernels.modes.MODES``) the backend string maps to — the
    auto ``backend="pallas"`` resolves to ``"pallas_compiled"`` here, so
    plan identity tracks what actually compiles, not how it was asked
    for.

    ``shard`` is the shard-topology component (DESIGN.md §9): ``""``
    for a monolithic index, ``"<shard>/<n_shards>"`` for a per-shard
    sub-retriever inside a ``ShardedRetriever`` — shards of one tree
    (whose array shapes may differ, e.g. the ragged last shard) never
    collide on a plan key.

    ``gen`` is the index-generation component (DESIGN.md §10): ``""``
    for an immutable index, ``"g<generation>"`` for the fan-out facade
    of a ``MutableRetriever`` — a generation flip (merge/compaction
    commit) changes the component, so stale facade plans are retired
    rather than silently reused against the new base."""

    engine: str
    codec: str
    backend: str
    mode: str
    k: int
    bucket: int
    shard: str = ""
    gen: str = ""
    #: value codec (DESIGN.md §12) — the traced dequant stage differs
    #: per vq, so executables must not collide across value codecs
    vq: str = "f16"


class SearchPlan:
    """One warm executable: pad a ``[n ≤ bucket, dim]`` query batch to
    the bucket shape, run the jit'd engine ``search_batch``, slice the
    padding back off. Padded slots carry the zero query — ``vmap``
    keeps per-query results independent, so padding never perturbs the
    real rows (asserted by the parity suite).

    ``warm(dim)`` ahead-of-time compiles the bucket-shaped executable
    (``jit.lower(...).compile()``) without running a search — the
    prefetcher (DESIGN.md §11) stages compiles off the serving hot
    path. Calls whose padded batch matches the warmed shape/dtype run
    the AOT executable directly; anything else falls back to ordinary
    jit dispatch (which shares XLA's compilation cache, so nothing
    compiles twice)."""

    __slots__ = ("key", "_fn", "_compiled", "_warm_sig", "_lock")

    def __init__(self, key: PlanKey, fn: Callable):
        self.key = key
        self._fn = fn
        self._compiled: Optional[Callable] = None
        self._warm_sig: Optional[Tuple[int, int, np.dtype]] = None
        self._lock = threading.Lock()

    def warm(self, dim: int, dtype=jnp.float32) -> bool:
        """AOT-compile this plan for ``[bucket, dim]`` batches of
        ``dtype``. Idempotent; returns True iff a compile happened.
        Only jit-backed plans can lower — facade plans (sharded /
        mutable fan-out dispatch through sub-plans) return False and
        are warmed by executing instead (``Pipeline.warm``)."""
        if not hasattr(self._fn, "lower"):
            return False
        with self._lock:
            if self._compiled is not None:
                return False
            spec = jax.ShapeDtypeStruct((self.key.bucket, int(dim)), dtype)
            compiled = self._fn.lower(spec).compile()
            self._warm_sig = (self.key.bucket, int(dim), np.dtype(dtype))
            self._compiled = compiled
            return True

    def __call__(self, Q) -> Tuple[jnp.ndarray, jnp.ndarray]:
        Q = jnp.asarray(Q)
        n, bucket = Q.shape[0], self.key.bucket
        if n > bucket:
            raise ValueError(f"batch of {n} exceeds plan bucket {bucket}")
        if n < bucket:
            Q = jnp.concatenate(
                [Q, jnp.zeros((bucket - n, Q.shape[1]), Q.dtype)]
            )
        fn = self._fn
        if (self._compiled is not None
                and (bucket, Q.shape[1], np.dtype(Q.dtype)) == self._warm_sig):
            fn = self._compiled
        ids, scores = fn(Q)
        return ids[:n], scores[:n]


class PlanCache:
    """Compiled executables of ONE retriever, keyed by padding bucket.

    Holds the jit'd ``impl.search_batch`` (the compile logic that used
    to live inline in ``Retriever.__init__``) and hands out
    ``SearchPlan``s per bucket; jax's executable cache is keyed by the
    padded shape, so plan keys and compiled programs are 1:1.
    ``compiles`` counts plan creations — the serving-metrics recompile
    counter. Batches beyond the largest bucket round up to the next
    power of two, which joins the bucket set (counted as a compile)."""

    def __init__(self, retriever: "Retriever", buckets: Optional[Sequence[int]] = None):
        import jax
        from functools import partial

        from repro.kernels.modes import backend_mode, resolve_mode

        cfg = retriever.cfg
        self.buckets = plan_buckets(cfg.batch_size, buckets)
        self.k = cfg.k
        mode = resolve_mode(backend_mode(cfg.backend))
        self._key = partial(
            PlanKey, cfg.engine, cfg.codec, cfg.backend, mode, cfg.k,
            shard=getattr(retriever, "shard", ""), vq=cfg.vq,
        )
        self._dispatch = jax.jit(
            partial(
                retriever.impl.search_batch,
                cfg,
                retriever.n_docs,
                retriever.value_scale,
                retriever.arrays,
            )
        )
        self._plans: Dict[int, SearchPlan] = {}
        self.compiles = 0
        self._lock = threading.Lock()

    def bucket_for(self, n: int) -> int:
        """Smallest covering bucket; beyond the largest, the next power
        of two (one dispatch, never a silent truncation)."""
        if n < 1:
            raise ValueError(f"batch size must be ≥ 1, got {n}")
        for b in self.buckets:
            if b >= n:
                return b
        return 1 << (n - 1).bit_length()

    def get(self, bucket: int) -> SearchPlan:
        """The plan for ``bucket``, compiled on first request. Ad hoc
        beyond-the-largest buckets get a cached plan too, but the
        configured bucket SET stays fixed — a one-off oversized batch
        must not raise the scheduler's dispatch threshold. Thread-safe:
        concurrent first requests for one bucket create one plan."""
        with self._lock:
            plan = self._plans.get(bucket)
            if plan is None:
                plan = SearchPlan(self._key(bucket=bucket), self._dispatch)
                self._plans[bucket] = plan
                self.compiles += 1
            return plan

    def search(self, Q) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Pad ``Q`` to its covering bucket and run the warm plan.
        An empty batch short-circuits to empty ``(0, k)`` results."""
        Q = jnp.asarray(Q)
        if Q.shape[0] == 0:
            return (jnp.zeros((0, self.k), jnp.int32),
                    jnp.zeros((0, self.k), jnp.float32))
        return self.get(self.bucket_for(Q.shape[0]))(Q)


# ---------------------------------------------------------------------------
# result cache — quantized-query LRU
# ---------------------------------------------------------------------------


def quantized_query_key(q, value_dtype=np.float16) -> bytes:
    """Cache key of one dense query: the *quantized sparse* form —
    nonzero component ids + values rounded to ``value_dtype``.

    Sub-f32 keying is a DELIBERATE tolerance, not an exactness claim:
    scoring uses the full-precision query, so two queries that collide
    after rounding can have (slightly) different true scores. That is
    why ``Pipeline`` only defaults to an f16 key when the index itself
    stores f16 values — the collapse then treats queries within one
    f16 ulp per component as the same ask, an error of the same order
    as the value quantization the index already accepts — and keys
    exactly (f32, identity rounding) otherwise. Exact replays of a
    served query always hit their own byte-identical entry."""
    qv = np.asarray(q, dtype=value_dtype)
    nz = np.flatnonzero(qv).astype(np.int32)
    return nz.tobytes() + qv[nz].tobytes()


class ResultCache:
    """Bounded LRU of per-query top-k results.

    Keys come from ``quantized_query_key``; values are the
    ``(ids [k], scores [k])`` numpy pair exactly as served, so a hit
    replays byte-identical results. Entries are stored as read-only
    COPIES: a caller mutating the arrays it was handed can never
    corrupt later replays (and cached rows don't pin whole dispatch
    batches alive). ``capacity=0`` disables caching (every lookup
    misses, nothing is stored).

    A cached result is a statement about ONE index state.
    ``invalidate()`` flushes the cache when that state changes (the
    index mutated, a merge committed a new generation); the ``epoch``
    attribute tags which index epoch the current entries belong to, so
    the pipeline can compare it against the owning retriever's
    ``epoch`` and invalidate lazily on the next admission
    (DESIGN.md §10). ``invalidations`` / ``invalidated_entries`` count
    flushes and the entries they dropped — surfaced in
    ``ServeStats.snapshot`` as the staleness-hygiene metric."""

    def __init__(self, capacity: int = 1024):
        if capacity < 0:
            raise ValueError(f"capacity must be ≥ 0, got {capacity}")
        self.capacity = int(capacity)
        self._items: "OrderedDict[bytes, Tuple[np.ndarray, np.ndarray]]" = OrderedDict()
        self.hits = 0
        self.lookups = 0
        #: index epoch the current entries were computed against
        self.epoch: int = 0
        self.invalidations = 0
        self.invalidated_entries = 0
        # get/put/invalidate race between serving threads and a
        # background-merge commit (DESIGN.md §11); RLock so a holder
        # can re-enter through the property accessors
        self._lock = threading.RLock()

    def __len__(self) -> int:
        with self._lock:
            return len(self._items)

    def get(self, key: bytes) -> Optional[Tuple[np.ndarray, np.ndarray]]:
        with self._lock:
            self.lookups += 1
            got = self._items.get(key)
            if got is None:
                return None
            self._items.move_to_end(key)
            self.hits += 1
            return got

    def put(self, key: bytes, ids: np.ndarray, scores: np.ndarray) -> None:
        if self.capacity == 0:
            return
        ids, scores = np.array(ids), np.array(scores)  # own the memory
        ids.flags.writeable = scores.flags.writeable = False
        with self._lock:
            self._items[key] = (ids, scores)
            self._items.move_to_end(key)
            while len(self._items) > self.capacity:
                self._items.popitem(last=False)

    def invalidate(self, epoch: Optional[int] = None) -> int:
        """Flush every entry; returns how many were dropped.

        ``epoch`` (when given) records the index epoch the cache is now
        current for — the pipeline passes the retriever's epoch so the
        flush happens exactly once per index change, not per lookup.
        An empty flush still counts as an invalidation: the caller
        declared the previous state dead, whether or not anything was
        cached under it. Atomic: a concurrent ``get`` sees either the
        pre-flush entries (tagged stale by the epoch check upstream) or
        an empty cache, never a torn map."""
        with self._lock:
            n = len(self._items)
            self._items.clear()
            self.invalidations += 1
            self.invalidated_entries += n
            if epoch is not None:
                self.epoch = int(epoch)
            return n

    @property
    def hit_rate(self) -> float:
        return self.hits / self.lookups if self.lookups else 0.0


# ---------------------------------------------------------------------------
# serving metrics
# ---------------------------------------------------------------------------


class ServeStats:
    """The pipeline metrics block (DESIGN.md §8 metrics contract).

    Latency samples are end-to-end per query (submit → result
    de-multiplexed), in µs under the pipeline's clock, kept in a
    bounded sliding window (``window`` most recent — a long-lived
    pipeline must not grow without bound, and recent percentiles are
    the ones that matter operationally). ``snapshot()`` returns one
    flat dict: qps, p50/p95/p99_us, cache_hit_rate, n_queries,
    dispatches + occupancy per bucket, recompiles, and the overlap
    counters (``prefetch_hits/prefetch_misses`` from the sharded
    prefetcher, ``merge_wall_us/blocked_swap_us`` from background
    compaction — DESIGN.md §11). Recording is lock-guarded so serving
    threads and a background merge can feed one stats block."""

    def __init__(self, clock: Callable[[], float], window: int = 8192):
        self._clock = clock
        self.t_start = clock()
        self.n_queries = 0  # completed (cache hits included)
        self.latencies_us = deque(maxlen=window)
        self.dispatches: Dict[int, int] = {}  # bucket → dispatch count
        self.occupancy: Dict[int, int] = {}  # bucket → Σ real queries
        # overlap counters (DESIGN.md §11) — synced off the serving
        # stack by ``sync_overlap`` / set directly by owners
        self.prefetch_hits = 0       # shard rotations served from the staged buffer
        self.prefetch_misses = 0     # rotations that paid page-in on the hot path
        self.merge_wall_us = 0.0     # Σ background-merge build wall-clock
        self.blocked_swap_us = 0.0   # Σ time queries were blocked on commit swaps
        self._lock = threading.RLock()

    def reset_clock(self) -> None:
        """Restart the QPS clock (e.g. after ``Pipeline.warm`` so the
        warmup wall-clock doesn't dilute the measured trace)."""
        with self._lock:
            self.t_start = self._clock()

    def record_dispatch(self, bucket: int, n_real: int) -> None:
        with self._lock:
            self.dispatches[bucket] = self.dispatches.get(bucket, 0) + 1
            self.occupancy[bucket] = self.occupancy.get(bucket, 0) + n_real

    def record_query(self, latency_us: float) -> None:
        with self._lock:
            self.n_queries += 1
            self.latencies_us.append(latency_us)

    def percentile(self, p: float) -> float:
        with self._lock:
            if not self.latencies_us:
                return float("nan")
            samples = np.asarray(list(self.latencies_us))
        return float(np.percentile(samples, p))

    def sync_overlap(self, retriever) -> None:
        """Pull the overlap counters off the serving stack: prefetch
        hits/misses live on a ``ShardedRetriever`` (possibly the base
        of a ``MutableRetriever``), merge/swap timings on a
        ``MutableRetriever``. Objects without the attributes contribute
        zero, so this is safe over any retriever."""
        srcs = [retriever, getattr(retriever, "base", None)]
        srcs = [r for r in srcs if r is not None]
        with self._lock:
            self.prefetch_hits = sum(
                int(getattr(r, "prefetch_hits", 0)) for r in srcs)
            self.prefetch_misses = sum(
                int(getattr(r, "prefetch_misses", 0)) for r in srcs)
            self.merge_wall_us = sum(
                float(getattr(r, "merge_wall_us", 0.0)) for r in srcs)
            self.blocked_swap_us = sum(
                float(getattr(r, "blocked_swap_us", 0.0)) for r in srcs)

    def snapshot(self, cache: Optional[ResultCache] = None,
                 plans: Optional[PlanCache] = None) -> dict:
        with self._lock:
            elapsed = max(self._clock() - self.t_start, 1e-9)
            dispatches = dict(sorted(self.dispatches.items()))
            occ = {
                b: self.occupancy[b] / (b * dispatches[b])
                for b in dispatches
            }
            overlap = {
                "prefetch_hits": self.prefetch_hits,
                "prefetch_misses": self.prefetch_misses,
                "merge_wall_us": self.merge_wall_us,
                "blocked_swap_us": self.blocked_swap_us,
            }
            n_queries = self.n_queries
        return {
            "n_queries": n_queries,
            "qps": n_queries / elapsed,
            "p50_us": self.percentile(50),
            "p95_us": self.percentile(95),
            "p99_us": self.percentile(99),
            "cache_hit_rate": cache.hit_rate if cache is not None else 0.0,
            "cache_invalidations": (
                cache.invalidations if cache is not None else 0
            ),
            "cache_invalidated_entries": (
                cache.invalidated_entries if cache is not None else 0
            ),
            "dispatches": dispatches,
            "bucket_occupancy": occ,
            "recompiles": plans.compiles if plans is not None else 0,
            **overlap,
        }

    @staticmethod
    def summary(snap: dict) -> str:
        occ = " ".join(
            f"b{b}×{snap['dispatches'][b]}@{snap['bucket_occupancy'][b]:.0%}"
            for b in snap["dispatches"]
        )
        out = (
            f"served={snap['n_queries']} qps={snap['qps']:.0f} "
            f"p50={snap['p50_us']:.0f}µs p95={snap['p95_us']:.0f}µs "
            f"p99={snap['p99_us']:.0f}µs hit_rate={snap['cache_hit_rate']:.0%} "
            f"invalidations={snap.get('cache_invalidations', 0)} "
            f"recompiles={snap['recompiles']} buckets[{occ}]"
        )
        pf = snap.get("prefetch_hits", 0) + snap.get("prefetch_misses", 0)
        if pf:
            out += (f" prefetch={snap['prefetch_hits']}h/"
                    f"{snap['prefetch_misses']}m")
        if snap.get("merge_wall_us", 0.0):
            out += (f" merge_wall={snap['merge_wall_us'] / 1e3:.0f}ms"
                    f" blocked_swap={snap['blocked_swap_us']:.0f}µs")
        return out


# ---------------------------------------------------------------------------
# micro-batching scheduler
# ---------------------------------------------------------------------------


class PendingQuery:
    """Ticket returned by ``Pipeline.submit``; ``result()`` flushes the
    owning pipeline if the query is still queued (closed-loop callers
    never deadlock on an under-filled bucket)."""

    __slots__ = ("q", "key", "t_submit", "done", "ids", "scores", "from_cache",
                 "_pipeline")

    def __init__(self, pipeline: "Pipeline", q: np.ndarray, key: bytes,
                 t_submit: float):
        self._pipeline = pipeline
        self.q = q
        self.key = key
        self.t_submit = t_submit
        self.done = False
        self.from_cache = False
        self.ids: Optional[np.ndarray] = None
        self.scores: Optional[np.ndarray] = None

    def result(self) -> Tuple[np.ndarray, np.ndarray]:
        if not self.done:
            self._pipeline.flush()
        assert self.done, "flush() must complete every queued ticket"
        return self.ids, self.scores

    def _complete(self, ids: np.ndarray, scores: np.ndarray, now: float,
                  stats: ServeStats) -> None:
        self.ids, self.scores = ids, scores
        self.done = True
        stats.record_query(1e6 * (now - self.t_submit))


class Pipeline:
    """Host-side micro-batching scheduler over one ``Retriever``.

    Admission → coalescing → dispatch → de-multiplex:

    * ``submit(q)`` checks the result cache (a hit completes the
      ticket immediately), else enqueues; a queue at the largest
      bucket's capacity dispatches at once.
    * ``poll()`` fires the deadline: once the OLDEST queued query has
      waited ``deadline_us``, the queue dispatches into its smallest
      covering bucket rather than waiting for batch-mates. Call it on
      every scheduler turn (the load generator calls it before each
      arrival).
    * ``flush()`` dispatches whatever is queued (end of trace /
      ``result()`` on a queued ticket).
    * ``search_batch(Q)`` is the synchronous convenience loop:
      submit every row, flush, return results stacked in submission
      order — the surface ``Retriever.search_batch`` reroutes to.

    The plan cache is shared with the owning retriever (a direct
    ``retriever.search`` and the pipeline warm the same executables).
    """

    def __init__(
        self,
        retriever: "Retriever",
        *,
        buckets: Optional[Sequence[int]] = None,
        deadline_us: float = 1000.0,
        cache_size: int = 1024,
        key_dtype=None,
        clock: Callable[[], float] = time.perf_counter,
    ):
        if deadline_us < 0:
            raise ValueError(f"deadline_us must be ≥ 0, got {deadline_us}")
        self.retriever = retriever
        # ask the retriever for its plan surface rather than building a
        # PlanCache directly: a ShardedRetriever answers with its
        # shard-fanning facade (same bucket_for/get/search/compiles
        # contract), so the scheduler works unmodified over shards
        self.plans = (
            retriever.plans if buckets is None
            else retriever.make_plans(buckets)
        )
        self.deadline_us = float(deadline_us)
        self.cache = ResultCache(cache_size)
        if key_dtype is None:
            # match the cache tolerance to the index's own value
            # quantization: f16 keys for f16-valued rows, exact (f32)
            # keys for everything else — see quantized_query_key
            key_dtype = (
                np.float16
                if getattr(retriever, "value_format", None) == "f16"
                else np.float32
            )
        self.key_dtype = key_dtype  # result-cache tolerance knob
        self._clock = clock
        self.stats = ServeStats(clock)
        self._queue: List[PendingQuery] = []
        # one scheduler lock across admission + dispatch: submitters
        # from other threads serialize here, so the queue is never torn
        # and at most one dispatch runs at a time (DESIGN.md §11);
        # RLock because submit → _dispatch re-enters
        self._lock = threading.RLock()

    # -- warmup ---------------------------------------------------------
    def warm(self) -> int:
        """Pre-build every configured bucket's plan by executing a
        zero-query batch through it — compile cost moves out of the
        measured trace, the same discipline as ``benchmarks/common.py``
        ``timeit_us(warmup=…)``. Bypasses stats and the result cache
        (the zero query would otherwise pollute both) and restarts the
        QPS clock. Returns the number of plans the warmup created
        (recompiles during the subsequent trace stay visible in
        ``snapshot()['recompiles']`` on top of this baseline)."""
        dim = int(self.retriever.dim)
        before = self.plans.compiles
        for b in self.plans.buckets:
            plan = self.plans.get(b)
            np.asarray(plan(np.zeros((1, dim), np.float32))[0])
        self.stats.reset_clock()
        return self.plans.compiles - before

    # -- admission ------------------------------------------------------
    def submit(self, q) -> PendingQuery:
        q = np.asarray(q, dtype=np.float32)
        now = self._clock()
        with self._lock:
            # epoch sync: a mutable retriever bumps ``epoch`` on every
            # index change (insert/delete/merge commit); any cached
            # answer predating the bump is stale and must not be served
            # (DESIGN.md §10) — under the scheduler lock, so a commit
            # landing mid-admission can't interleave a stale hit
            ep = getattr(self.retriever, "epoch", None)
            if ep is not None and ep != self.cache.epoch:
                self.cache.invalidate(epoch=ep)
            # key computation is an O(dim) scan — skip it entirely when
            # the cache is disabled (the strict-exactness path stays lean)
            caching = self.cache.capacity > 0
            key = quantized_query_key(q, self.key_dtype) if caching else b""
            ticket = PendingQuery(self, q, key, now)
            if caching:
                hit = self.cache.get(ticket.key)
                if hit is not None:
                    ticket.from_cache = True
                    ticket._complete(hit[0], hit[1], self._clock(), self.stats)
                    return ticket
            self._queue.append(ticket)
            if len(self._queue) >= self.plans.buckets[-1]:
                self._dispatch()
            return ticket

    # -- scheduling -----------------------------------------------------
    def poll(self) -> int:
        """Fire the deadline if the oldest queued query has expired;
        returns how many queries were dispatched."""
        with self._lock:
            if not self._queue:
                return 0
            waited_us = 1e6 * (self._clock() - self._queue[0].t_submit)
            if waited_us >= self.deadline_us:
                return self._dispatch()
            return 0

    def flush(self) -> int:
        """Dispatch every queued query (possibly several buckets)."""
        with self._lock:
            n = 0
            while self._queue:
                n += self._dispatch()
            return n

    def _dispatch(self) -> int:
        """Coalesce the queue head into its smallest covering bucket,
        run the plan, de-multiplex per-query top-k, feed the cache.
        Callers hold ``_lock``."""
        if not self._queue:
            return 0
        cap = self.plans.buckets[-1]
        batch, self._queue = self._queue[:cap], self._queue[cap:]
        bucket = self.plans.bucket_for(len(batch))
        Q = np.stack([t.q for t in batch])
        ids, scores = self.plans.get(bucket)(Q)
        ids, scores = np.asarray(ids), np.asarray(scores)
        now = self._clock()
        self.stats.record_dispatch(bucket, len(batch))
        caching = self.cache.capacity > 0
        for i, t in enumerate(batch):
            t._complete(ids[i], scores[i], now, self.stats)
            if caching:
                self.cache.put(t.key, ids[i], scores[i])
        return len(batch)

    # -- synchronous convenience surface --------------------------------
    def search_batch(self, Q) -> Tuple[np.ndarray, np.ndarray]:
        """Serve a whole query batch through the scheduler: results
        stacked in submission order, byte-identical to a direct
        ``Retriever.search`` of the same rows (the parity invariant)."""
        Q = np.asarray(Q)
        if Q.shape[0] == 0:
            k = self.retriever.cfg.k
            return np.zeros((0, k), np.int32), np.zeros((0, k), np.float32)
        tickets = [self.submit(q) for q in Q]
        self.flush()
        ids = np.stack([t.ids for t in tickets])
        scores = np.stack([t.scores for t in tickets])
        return ids, scores

    def snapshot(self) -> dict:
        self.stats.sync_overlap(self.retriever)
        return self.stats.snapshot(cache=self.cache, plans=self.plans)
