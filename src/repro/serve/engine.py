"""DEPRECATED shim — the batched Seismic engine now lives behind the
engine registry in ``repro.serve.api`` (DESIGN.md §7).

Everything here delegates to ``api.Retriever`` /
``api.get_engine("seismic")`` and is kept for ONE release so external
callers of the PR-1/PR-2 surface keep working. New code should use:

    from repro.serve.api import Retriever, RetrieverConfig
    r = Retriever.build(fwd, RetrieverConfig(engine="seismic", codec=...))
    ids, scores = r.search(Q)
"""

from __future__ import annotations

import dataclasses
import warnings

from . import api
from .api import RetrieverConfig

__all__ = ["BatchedSeismic", "EngineConfig", "search_one_fn", "engine_array_specs",
           "make_sharded_search", "build_shard_arrays"]


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Legacy Seismic search config; superseded by ``RetrieverConfig``."""

    cut: int = 8
    block_budget: int = 512
    n_probe: int = 64
    k: int = 10
    codec: str = "uncompressed"

    def to_retriever(self) -> RetrieverConfig:
        return RetrieverConfig(
            engine="seismic",
            codec=self.codec,
            k=self.k,
            params={"cut": self.cut, "block_budget": self.block_budget,
                    "n_probe": self.n_probe},
        )


def _warn(old: str, new: str) -> None:
    warnings.warn(
        f"repro.serve.engine.{old} is deprecated; use {new}",
        DeprecationWarning,
        stacklevel=3,
    )


def search_one_fn(cfg: EngineConfig, n_docs: int, value_scale: float, arrays: dict, q):
    return api.get_engine("seismic").search_one(
        cfg.to_retriever(), n_docs, value_scale, arrays, q
    )


def engine_array_specs(cfg: EngineConfig, **dims) -> dict:
    return api.get_engine("seismic").array_specs(cfg.to_retriever(), **dims)


class BatchedSeismic(api.Retriever):
    """Legacy wrapper: SeismicIndex + EngineConfig → ``api.Retriever``."""

    def __init__(self, index, cfg: EngineConfig):
        _warn("BatchedSeismic", "api.Retriever.from_host_index")
        r = api.Retriever.from_host_index(index, cfg.to_retriever())
        self.__dict__.update(r.__dict__)
        self.legacy_cfg = cfg


def make_sharded_search(mesh, cfg: EngineConfig, n_docs_local, n_docs_global,
                        value_scale, *, index_axis="model", query_axes=("data",)):
    _warn("make_sharded_search", "api.make_sharded_search")
    return api.make_sharded_search(
        mesh, cfg.to_retriever(), n_docs_local, n_docs_global, value_scale,
        index_axis=index_axis, query_axes=query_axes,
    )


def build_shard_arrays(index, cfg: EngineConfig, n_shards: int):
    _warn("build_shard_arrays", "api.build_shard_arrays")
    return api.build_shard_arrays(
        index.fwd, cfg.to_retriever(), n_shards, host_index=index
    )
