"""Batched, static-shape Seismic serving engine (TPU adaptation).

The host-side reference (repro.core.seismic) has faithful heap-and-
early-exit semantics but data-dependent control flow. TPUs want static
shapes and batches, so serving uses the standard two-phase static
relaxation of the same algorithm:

  phase 1  for each query: gather the blocks of its top-``cut``
           components (≤ ``block_budget``), score every summary
           (gather + FMA), take the top-``n_probe`` blocks — this
           replaces the heap_factor pruning test with a fixed probe
           budget (the Seismic papers' own batching trick);
  phase 2  gather the ≤ n_probe·block_size candidate documents, dedupe
           (sort by id, mask repeats), re-score *exactly* against the
           forward index rows — uncompressed, DotVByte- or StreamVByte-
           decoded (any codec registered in core/layout.py), the paper's
           hot path — and take the global top-k.

``search_one_fn`` is a *pure* function of (arrays, query) so the same
code serves the jit'd production path, the multi-pod dry-run
(ShapeDtypeStruct arrays), and the tests. Distribution (DESIGN.md §4):
index arrays row-shard over the flat mesh; queries shard over ``data``;
per-shard top-k merges with an O(k) all-gather.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import layout
from repro.core.scoring import decode_doc_rows, score_doc_rows
from repro.core.seismic import SeismicIndex

__all__ = ["BatchedSeismic", "EngineConfig", "search_one_fn", "engine_array_specs"]

#: codecs with a (ctrl, data) row stream decoded on the fly
_STREAM_CODECS = ("dotvbyte", "streamvbyte")


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    cut: int = 8  # query components probed
    block_budget: int = 512  # max candidate blocks per query (phase 1)
    n_probe: int = 64  # blocks exactly re-scored (phase 2)
    k: int = 10
    codec: str = "uncompressed"  # "uncompressed" | "dotvbyte" | "streamvbyte"


def search_one_fn(cfg: EngineConfig, n_docs: int, value_scale: float, arrays: dict, q):
    """One dense query → (ids [k], scores [k]). Pure and static-shape.

    arrays: cbs/cbl [dim], sum_comps/sum_vals [n_blocks, s_max],
    block_docs [n_blocks, bs_max], vals_rows [N+1, l_max],
    nnz_rows [N+1], and comps_rows | (ctrl_rows, data_rows)."""
    # top-cut query components
    qv, qc = jax.lax.top_k(jnp.abs(q), cfg.cut)
    live = qv > 0
    # candidate blocks: fixed budget round-robin over the cut comps
    starts = arrays["cbs"][qc]  # [cut]
    lens = jnp.where(live, arrays["cbl"][qc], 0)
    per = cfg.block_budget // cfg.cut
    offs = jnp.arange(per)[None, :]  # [1, per]
    cand = starts[:, None] + offs  # [cut, per]
    valid = offs < lens[:, None]
    cand = jnp.where(valid, cand, -1).reshape(-1)  # [budget]

    # phase 1: summary upper bounds
    sc = jnp.take(arrays["sum_comps"], jnp.maximum(cand, 0), axis=0)
    sv = jnp.take(arrays["sum_vals"], jnp.maximum(cand, 0), axis=0)
    est = (jnp.take(q, sc, axis=0) * sv).sum(-1)
    est = jnp.where(cand >= 0, est, -jnp.inf)
    _, probe = jax.lax.top_k(est, cfg.n_probe)
    probe_blocks = jnp.take(cand, probe)

    # phase 2: gather candidate docs, dedupe, exact re-score
    docs = jnp.take(arrays["block_docs"], jnp.maximum(probe_blocks, 0), axis=0)
    docs = jnp.where((probe_blocks >= 0)[:, None], docs, n_docs).reshape(-1)
    docs = jnp.sort(docs)
    dup = jnp.concatenate([jnp.zeros(1, bool), docs[1:] == docs[:-1]])
    docs = jnp.where(dup, n_docs, docs)

    vals = jnp.take(arrays["vals_rows"], docs, axis=0)
    nnz = jnp.take(arrays["nnz_rows"], docs, axis=0)
    if cfg.codec in _STREAM_CODECS:
        ctrl = jnp.take(arrays["ctrl_rows"], docs, axis=0)
        data = jnp.take(arrays["data_rows"], docs, axis=0)
        comps = decode_doc_rows(cfg.codec, ctrl, data)
    else:
        comps = jnp.take(arrays["comps_rows"], docs, axis=0)
    scores = score_doc_rows(q, comps, vals, nnz, value_scale)
    scores = jnp.where(docs < n_docs, scores, -jnp.inf)
    top_s, idx = jax.lax.top_k(scores, cfg.k)
    return jnp.take(docs, idx), top_s


def engine_array_specs(
    cfg: EngineConfig,
    *,
    dim: int,
    n_docs: int,
    n_blocks: int,
    s_max: int,
    bs_max: int,
    l_max: int,
    d_max: int,
    value_dtype=jnp.float16,
) -> dict:
    """ShapeDtypeStruct stand-ins for the engine arrays (dry-run)."""
    sds = jax.ShapeDtypeStruct
    arrays = {
        "cbs": sds((dim,), jnp.int32),
        "cbl": sds((dim,), jnp.int32),
        "sum_comps": sds((n_blocks, s_max), jnp.int32),
        "sum_vals": sds((n_blocks, s_max), jnp.float32),
        "block_docs": sds((n_blocks, bs_max), jnp.int32),
        "vals_rows": sds((n_docs + 1, l_max), value_dtype),
        "nnz_rows": sds((n_docs + 1,), jnp.int32),
    }
    if cfg.codec in _STREAM_CODECS:
        ctrl_group = 8 if cfg.codec == "dotvbyte" else 4
        arrays["ctrl_rows"] = sds((n_docs + 1, l_max // ctrl_group), jnp.uint8)
        arrays["data_rows"] = sds((n_docs + 1, d_max), jnp.uint8)
    else:
        arrays["comps_rows"] = sds((n_docs + 1, l_max), jnp.int32)
    return arrays


class BatchedSeismic:
    """Static-array view of a SeismicIndex + jit'd batched search."""

    def __init__(self, index: SeismicIndex, cfg: EngineConfig):
        if cfg.codec != "uncompressed" and cfg.codec not in _STREAM_CODECS:
            raise ValueError(
                f"engine codec must be one of {('uncompressed', *_STREAM_CODECS)}, "
                f"got {cfg.codec!r}"
            )
        self.cfg = cfg
        self.dim = index.dim
        self.n_docs = index.fwd.n_docs
        self.value_scale = float(index.fwd.value_format.scale)
        self.arrays = self._build_arrays(index)
        self._search = jax.jit(
            jax.vmap(
                partial(search_one_fn, cfg, self.n_docs, self.value_scale, self.arrays)
            )
        )

    # ------------------------------------------------------------------
    def _build_arrays(self, index: SeismicIndex) -> dict:
        cfg = self.cfg
        fwd = index.fwd
        n_blocks = index.n_blocks

        s_len = np.diff(index.summary_indptr)
        s_max = int(max(s_len.max(initial=1), 1))
        sum_comps = np.zeros((n_blocks, s_max), dtype=np.int32)
        sum_vals = np.zeros((n_blocks, s_max), dtype=np.float32)
        for b in range(n_blocks):
            s, e = int(index.summary_indptr[b]), int(index.summary_indptr[b + 1])
            sum_comps[b, : e - s] = index.summary_comps[s:e]
            sum_vals[b, : e - s] = (
                index.summary_vals[s:e].astype(np.float32) * index.params.summary_scale
            )

        b_len = np.diff(index.block_doc_indptr)
        bs_max = int(max(b_len.max(initial=1), 1))
        block_docs = np.full((n_blocks, bs_max), self.n_docs, dtype=np.int32)
        for b in range(n_blocks):
            s, e = int(index.block_doc_indptr[b]), int(index.block_doc_indptr[b + 1])
            block_docs[b, : e - s] = index.block_docs[s:e]

        arrays = {
            "cbs": jnp.asarray(index.comp_block_indptr[:-1].astype(np.int32)),
            "cbl": jnp.asarray(np.diff(index.comp_block_indptr).astype(np.int32)),
            "sum_comps": jnp.asarray(sum_comps),
            "sum_vals": jnp.asarray(sum_vals),
            "block_docs": jnp.asarray(block_docs),
        }
        # per-doc rescoring rows under the configured codec — one shared
        # layout implementation for every codec (core/layout.py)
        rows = layout.pack_rows(fwd, codec=cfg.codec)
        arrays.update({k: jnp.asarray(v) for k, v in rows.arrays().items()})
        return arrays

    # ------------------------------------------------------------------
    def search_batch(self, Q: jnp.ndarray):
        """[nq, dim] dense queries → (ids [nq, k], scores [nq, k])."""
        return self._search(Q)


def make_sharded_search(
    mesh,
    cfg: EngineConfig,
    n_docs_local: int,
    n_docs_global: int,
    value_scale: float,
    *,
    index_axis: str = "model",
    query_axes: tuple[str, ...] = ("data",),
):
    """Distributed two-phase search (DESIGN.md §4).

    The index is pre-partitioned into ``mesh.shape[index_axis]``
    self-contained sub-indexes (arrays carry a leading shard dim,
    sharded over ``index_axis``; ``idmap`` [n_shards, n_docs_local+1]
    maps local → global doc ids, sentinel → n_docs_global). Queries
    shard over ``query_axes`` and replicate across index shards; each
    device searches its shard, then an O(k) all-gather + top-k merge
    produces the global result. Collective bytes per query: 8·k·n_shards."""
    from jax.sharding import PartitionSpec as P

    def local(arrays, idmap, Q):
        arrays = jax.tree.map(lambda a: a[0], arrays)  # drop shard dim
        idmap = idmap[0]
        ids, scores = jax.vmap(
            partial(search_one_fn, cfg, n_docs_local, value_scale, arrays)
        )(Q)
        gids = jnp.take(idmap, ids)  # [nq_local, k] global ids
        # merge across index shards: all-gather per-shard top-k
        ag_s = jax.lax.all_gather(scores, index_axis)  # [S, nq, k]
        ag_i = jax.lax.all_gather(gids, index_axis)
        S, nq, k = ag_s.shape
        flat_s = ag_s.transpose(1, 0, 2).reshape(nq, S * k)
        flat_i = ag_i.transpose(1, 0, 2).reshape(nq, S * k)
        # a document's blocks scatter across shards → the same doc can be
        # reported by several shards; dedupe by id before the final top-k
        order = jnp.argsort(flat_i, axis=1)
        si = jnp.take_along_axis(flat_i, order, axis=1)
        ss = jnp.take_along_axis(flat_s, order, axis=1)
        dup = jnp.concatenate(
            [jnp.zeros((nq, 1), bool), si[:, 1:] == si[:, :-1]], axis=1
        )
        ss = jnp.where(dup | (si >= n_docs_global), -jnp.inf, ss)
        top_s, pos = jax.lax.top_k(ss, cfg.k)
        top_i = jnp.take_along_axis(si, pos, axis=1)
        return top_i, top_s

    qa = query_axes or None
    return jax.shard_map(
        local,
        mesh=mesh,
        in_specs=(P(index_axis), P(index_axis), P(qa, None)),
        out_specs=(P(qa, None), P(qa, None)),
        check_vma=False,
    )


def build_shard_arrays(index: SeismicIndex, cfg: EngineConfig, n_shards: int):
    """Partition a SeismicIndex into ``n_shards`` self-contained
    sub-indexes (blocks round-robin, docs by ownership) and stack their
    engine arrays with a leading shard dim. Returns (arrays, idmap,
    n_docs_local)."""
    full = BatchedSeismic(index, cfg)
    A = full.arrays
    n_blocks = int(A["block_docs"].shape[0])
    dim = index.dim

    shard_arrays, idmaps, docs_local_max = [], [], 0
    shard_docs: list[np.ndarray] = []
    for s in range(n_shards):
        blocks = np.arange(s, n_blocks, n_shards)
        docs = np.unique(np.asarray(A["block_docs"])[blocks])
        docs = docs[docs < full.n_docs]
        shard_docs.append(docs)
        docs_local_max = max(docs_local_max, len(docs))

    for s in range(n_shards):
        blocks = np.arange(s, n_blocks, n_shards)
        docs = shard_docs[s]
        g2l = np.full(full.n_docs + 1, docs_local_max, dtype=np.int32)
        g2l[docs] = np.arange(len(docs), dtype=np.int32)
        # comp → local block ranges: blocks of comp c in this shard are
        # contiguous in the round-robin order
        cbs = np.asarray(A["cbs"])
        cbl = np.asarray(A["cbl"])
        lcbs = (cbs - s + n_shards - 1) // n_shards
        lcbl = (cbs + cbl - s + n_shards - 1) // n_shards - lcbs
        sub = {
            "cbs": lcbs.astype(np.int32),
            "cbl": np.maximum(lcbl, 0).astype(np.int32),
            "sum_comps": np.asarray(A["sum_comps"])[blocks],
            "sum_vals": np.asarray(A["sum_vals"])[blocks],
            "block_docs": g2l[np.asarray(A["block_docs"])[blocks]],
        }
        row_keys = [k for k in ("vals_rows", "nnz_rows", "comps_rows", "ctrl_rows", "data_rows") if k in A]
        pad_rows = np.concatenate([docs, np.full(docs_local_max - len(docs) + 1, full.n_docs)])
        for k in row_keys:
            sub[k] = np.asarray(A[k])[pad_rows]
        shard_arrays.append(sub)
        idmap = np.full(docs_local_max + 1, full.n_docs, dtype=np.int32)
        idmap[: len(docs)] = docs
        idmaps.append(idmap)

    stacked = {
        k: jnp.asarray(v)
        for k, v in layout.pad_stack(
            shard_arrays, pad_values={"block_docs": docs_local_max}
        ).items()
    }
    return stacked, jnp.asarray(np.stack(idmaps)), docs_local_max
