"""Batched, static-shape HNSW serving engine (TPU adaptation).

The host-side reference (repro.core.hnsw) has faithful heap-and-early-
exit semantics but data-dependent control flow. Serving uses the static
beam-search relaxation (DESIGN.md §5):

* the hierarchy collapses to the base-layer fixed-degree adjacency
  ``adj [N+1, M]`` plus ``n_seeds`` query-independent entry hubs (the
  global entry point and the highest-level nodes);
* the heap becomes a fixed-width beam: each of ``iters`` loop steps
  (``lax.fori_loop``) expands the best not-yet-expanded beam node,
  gathers its M neighbours, masks the already-seen ones with a visited
  bitmask ``[N+1]``, scores the rest exactly, and top-k-merges them
  back into the beam;
* candidate scoring gathers the candidate's ROW of the packed row form
  (``layout.pack_rows``) and decodes it on the fly with whatever codec
  is configured — ``scoring.decode_doc_rows`` — so every codec
  registered in core/layout.py works unmodified. This is the paper's
  hot path on a graph access pattern: one row decoded per visited
  node, no block reuse to amortise against.

``search_one_fn`` is a *pure* function of (arrays, query), mirroring
``repro.serve.engine.search_one_fn``: the same code serves the jit'd
production path, dry-run ShapeDtypeStructs, and the tests.
Distribution (DESIGN.md §4): documents split into contiguous ranges,
one self-contained sub-graph per range, arrays row-sharded over the
flat mesh; per-shard top-k merges with an O(k) all-gather.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import layout
from repro.core.forward_index import ForwardIndex
from repro.core.hnsw import HNSWIndex, HNSWParams
from repro.core.scoring import decode_doc_rows, score_doc_rows

__all__ = [
    "BatchedHNSW",
    "GraphConfig",
    "search_one_fn",
    "graph_array_specs",
    "make_sharded_search",
    "build_shard_arrays",
]

#: codecs with a (ctrl, data) row stream decoded on the fly
_STREAM_CODECS = ("dotvbyte", "streamvbyte")


@dataclasses.dataclass(frozen=True)
class GraphConfig:
    beam: int = 64  # beam width (the static ef)
    iters: int = 64  # nodes expanded (fori_loop trip count)
    n_seeds: int = 8  # query-independent entry hubs
    k: int = 10
    codec: str = "uncompressed"  # "uncompressed" | "dotvbyte" | "streamvbyte"


def search_one_fn(cfg: GraphConfig, n_docs: int, value_scale: float, arrays: dict, q):
    """One dense query → (ids [k], scores [k]). Pure and static-shape.

    arrays: adj [N+1, M], seeds [n_seeds], vals_rows [N+1, L],
    nnz_rows [N+1], and comps_rows | (ctrl_rows, data_rows).
    Sentinel id ``n_docs`` gathers the all-zero row / all-sentinel
    adjacency row and scores −inf, so padding is self-absorbing."""

    def score_docs(docs):
        vals = jnp.take(arrays["vals_rows"], docs, axis=0)
        nnz = jnp.take(arrays["nnz_rows"], docs, axis=0)
        if cfg.codec in _STREAM_CODECS:
            ctrl = jnp.take(arrays["ctrl_rows"], docs, axis=0)
            data = jnp.take(arrays["data_rows"], docs, axis=0)
            comps = decode_doc_rows(cfg.codec, ctrl, data)
        else:
            comps = jnp.take(arrays["comps_rows"], docs, axis=0)
        return score_doc_rows(q, comps, vals, nnz, value_scale)

    seeds = arrays["seeds"]  # i32 [n_seeds], sentinel-padded
    live = seeds < n_docs
    ids = jnp.concatenate(
        [seeds, jnp.full((cfg.beam - seeds.shape[0],), n_docs, jnp.int32)]
    )
    scores = jnp.concatenate(
        [
            jnp.where(live, score_docs(seeds), -jnp.inf),
            jnp.full((cfg.beam - seeds.shape[0],), -jnp.inf),
        ]
    )
    expanded = ids >= n_docs  # sentinel slots never expand
    visited = jnp.zeros(n_docs + 1, bool).at[seeds].set(True)

    def body(_, carry):
        ids, scores, expanded, visited = carry
        # best not-yet-expanded beam node (−inf everywhere ⇒ harmless
        # re-pick of slot 0: its neighbours are all visited or sentinel)
        b = jnp.argmax(jnp.where(expanded, -jnp.inf, scores))
        v = ids[b]
        expanded = expanded.at[b].set(True)
        nbrs = jnp.take(arrays["adj"], v, axis=0)  # [M]
        fresh = (nbrs < n_docs) & ~visited[nbrs]
        nbrs = jnp.where(fresh, nbrs, n_docs)
        visited = visited.at[nbrs].set(True)
        ns = jnp.where(fresh, score_docs(nbrs), -jnp.inf)
        # top-k merge of beam ∪ neighbours (ids unique by visited-mask)
        all_ids = jnp.concatenate([ids, nbrs])
        all_s = jnp.concatenate([scores, ns])
        all_e = jnp.concatenate([expanded, ~fresh])
        top_s, idx = jax.lax.top_k(all_s, cfg.beam)
        return jnp.take(all_ids, idx), top_s, jnp.take(all_e, idx), visited

    ids, scores, _, _ = jax.lax.fori_loop(
        0, cfg.iters, body, (ids, scores, expanded, visited)
    )
    top_s, idx = jax.lax.top_k(scores, cfg.k)
    return jnp.take(ids, idx), top_s


def graph_array_specs(
    cfg: GraphConfig,
    *,
    n_docs: int,
    degree: int,
    l_max: int,
    d_max: int,
    value_dtype=jnp.float16,
) -> dict:
    """ShapeDtypeStruct stand-ins for the engine arrays (dry-run)."""
    sds = jax.ShapeDtypeStruct
    arrays = {
        "adj": sds((n_docs + 1, degree), jnp.int32),
        "seeds": sds((cfg.n_seeds,), jnp.int32),
        "vals_rows": sds((n_docs + 1, l_max), value_dtype),
        "nnz_rows": sds((n_docs + 1,), jnp.int32),
    }
    if cfg.codec in _STREAM_CODECS:
        ctrl_group = 8 if cfg.codec == "dotvbyte" else 4
        arrays["ctrl_rows"] = sds((n_docs + 1, l_max // ctrl_group), jnp.uint8)
        arrays["data_rows"] = sds((n_docs + 1, d_max), jnp.uint8)
    else:
        arrays["comps_rows"] = sds((n_docs + 1, l_max), jnp.int32)
    return arrays


class BatchedHNSW:
    """Static-array view of an HNSWIndex + jit'd batched beam search."""

    def __init__(self, index: HNSWIndex, cfg: GraphConfig):
        if cfg.codec != "uncompressed" and cfg.codec not in _STREAM_CODECS:
            raise ValueError(
                f"engine codec must be one of {('uncompressed', *_STREAM_CODECS)}, "
                f"got {cfg.codec!r}"
            )
        if cfg.n_seeds > cfg.beam:
            raise ValueError("n_seeds must not exceed beam width")
        self.cfg = cfg
        self.dim = index.dim
        self.n_docs = index.fwd.n_docs
        self.value_scale = float(index.fwd.value_format.scale)
        self.arrays = self._build_arrays(index)
        self._search = jax.jit(
            jax.vmap(
                partial(search_one_fn, cfg, self.n_docs, self.value_scale, self.arrays)
            )
        )

    def _build_arrays(self, index: HNSWIndex) -> dict:
        arrays = {
            "adj": jnp.asarray(index.adjacency(0)),
            "seeds": jnp.asarray(index.seed_nodes(self.cfg.n_seeds)),
        }
        rows = layout.pack_rows(index.fwd, codec=self.cfg.codec)
        arrays.update({k: jnp.asarray(v) for k, v in rows.arrays().items()})
        return arrays

    def search_batch(self, Q):
        """[nq, dim] dense queries → (ids [nq, k], scores [nq, k])."""
        return self._search(jnp.asarray(Q))


def make_sharded_search(
    mesh,
    cfg: GraphConfig,
    n_docs_local: int,
    n_docs_global: int,
    value_scale: float,
    *,
    index_axis: str = "model",
    query_axes: tuple[str, ...] = ("data",),
):
    """Distributed graph search (DESIGN.md §4 / §5).

    Each of ``mesh.shape[index_axis]`` shards owns a contiguous doc
    range with its own self-contained sub-graph (arrays carry a leading
    shard dim; ``idmap`` [n_shards, n_docs_local+1] maps local → global
    ids, sentinel → n_docs_global). Queries shard over ``query_axes``
    and replicate across index shards; doc ranges are disjoint so the
    merge is a plain all-gather + top-k, no dedupe. Collective bytes
    per query: 8·k·n_shards."""
    from jax.sharding import PartitionSpec as P

    def local(arrays, idmap, Q):
        arrays = jax.tree.map(lambda a: a[0], arrays)  # drop shard dim
        idmap = idmap[0]
        ids, scores = jax.vmap(
            partial(search_one_fn, cfg, n_docs_local, value_scale, arrays)
        )(Q)
        gids = jnp.take(idmap, ids)  # [nq_local, k] global ids
        ag_s = jax.lax.all_gather(scores, index_axis)  # [S, nq, k]
        ag_i = jax.lax.all_gather(gids, index_axis)
        S, nq, k = ag_s.shape
        flat_s = ag_s.transpose(1, 0, 2).reshape(nq, S * k)
        flat_i = ag_i.transpose(1, 0, 2).reshape(nq, S * k)
        flat_s = jnp.where(flat_i >= n_docs_global, -jnp.inf, flat_s)
        top_s, pos = jax.lax.top_k(flat_s, cfg.k)
        return jnp.take_along_axis(flat_i, pos, axis=1), top_s

    qa = query_axes or None
    return jax.shard_map(
        local,
        mesh=mesh,
        in_specs=(P(index_axis), P(index_axis), P(qa, None)),
        out_specs=(P(qa, None), P(qa, None)),
        check_vma=False,
    )


def build_shard_arrays(
    fwd: ForwardIndex,
    cfg: GraphConfig,
    n_shards: int,
    params: HNSWParams = HNSWParams(),
):
    """Split documents into ``n_shards`` contiguous ranges, build one
    self-contained HNSW sub-graph per range (range-LOCAL ids), and
    ``pad_stack`` the engine arrays with a leading shard dim. Returns
    (arrays, idmap, n_docs_local)."""
    n = fwd.n_docs
    docs_local = (n + n_shards - 1) // n_shards
    dicts, idmaps = [], []
    for s in range(n_shards):
        lo, hi = s * docs_local, min((s + 1) * docs_local, n)
        sub_docs = [fwd.doc(d) for d in range(lo, hi)]
        n_real = len(sub_docs)
        sub = ForwardIndex.from_docs(sub_docs, fwd.dim, value_format=fwd.value_format.name)
        index = HNSWIndex.build(sub, params)
        # embed the sub-graph into the padded local id space: rows past
        # n_real stay all-sentinel (= docs_local), unreachable by search
        adj = np.full(
            (docs_local + 1, params.degree(0)), docs_local, dtype=np.int32
        )
        adj[:n_real] = index.adjacency(0, sentinel=docs_local)[:n_real]
        # tail padding: empty docs, so the row arrays reach docs_local+1
        while len(sub_docs) < docs_local:
            sub_docs.append((np.zeros(0, np.uint32), np.zeros(0, np.float32)))
        padded = ForwardIndex.from_docs(
            sub_docs, fwd.dim, value_format=fwd.value_format.name
        )
        rows = layout.pack_rows(padded, codec=cfg.codec)
        dicts.append(
            {
                "adj": adj,
                "seeds": index.seed_nodes(cfg.n_seeds, sentinel=docs_local),
                **rows.arrays(),
            }
        )
        idmap = np.full(docs_local + 1, n, dtype=np.int32)
        idmap[:n_real] = np.arange(lo, hi, dtype=np.int32)
        idmaps.append(idmap)

    stacked = {
        k: jnp.asarray(v)
        for k, v in layout.pad_stack(
            dicts, pad_values={"adj": docs_local, "seeds": docs_local}
        ).items()
    }
    return stacked, jnp.asarray(np.stack(idmaps)), docs_local
