"""DEPRECATED shim — the batched HNSW engine now lives behind the
engine registry in ``repro.serve.api`` (DESIGN.md §7).

Everything here delegates to ``api.Retriever`` /
``api.get_engine("hnsw")`` and is kept for ONE release so external
callers of the PR-2 surface keep working. New code should use:

    from repro.serve.api import Retriever, RetrieverConfig
    r = Retriever.build(fwd, RetrieverConfig(engine="hnsw", codec=...))
    ids, scores = r.search(Q)
"""

from __future__ import annotations

import dataclasses
import warnings

from repro.core.forward_index import ForwardIndex
from repro.core.hnsw import HNSWParams

from . import api
from .api import RetrieverConfig

__all__ = ["BatchedHNSW", "GraphConfig", "search_one_fn", "graph_array_specs",
           "make_sharded_search", "build_shard_arrays"]


@dataclasses.dataclass(frozen=True)
class GraphConfig:
    """Legacy HNSW search config; superseded by ``RetrieverConfig``."""

    beam: int = 64
    iters: int = 64
    n_seeds: int = 8
    k: int = 10
    codec: str = "uncompressed"

    def to_retriever(self, params: HNSWParams | None = None) -> RetrieverConfig:
        knobs = {"beam": self.beam, "iters": self.iters, "n_seeds": self.n_seeds}
        if params is not None:
            knobs.update(m=params.m, m0=params.m0,
                         ef_construction=params.ef_construction, seed=params.seed)
        return RetrieverConfig(engine="hnsw", codec=self.codec, k=self.k, params=knobs)


def _warn(old: str, new: str) -> None:
    warnings.warn(
        f"repro.serve.graph_engine.{old} is deprecated; use {new}",
        DeprecationWarning,
        stacklevel=3,
    )


def search_one_fn(cfg: GraphConfig, n_docs: int, value_scale: float, arrays: dict, q):
    return api.get_engine("hnsw").search_one(
        cfg.to_retriever(), n_docs, value_scale, arrays, q
    )


def graph_array_specs(cfg: GraphConfig, **dims) -> dict:
    return api.get_engine("hnsw").array_specs(cfg.to_retriever(), **dims)


class BatchedHNSW(api.Retriever):
    """Legacy wrapper: HNSWIndex + GraphConfig → ``api.Retriever``."""

    def __init__(self, index, cfg: GraphConfig):
        _warn("BatchedHNSW", "api.Retriever.from_host_index")
        r = api.Retriever.from_host_index(index, cfg.to_retriever())
        self.__dict__.update(r.__dict__)
        self.legacy_cfg = cfg


def make_sharded_search(mesh, cfg: GraphConfig, n_docs_local, n_docs_global,
                        value_scale, *, index_axis="model", query_axes=("data",)):
    _warn("make_sharded_search", "api.make_sharded_search")
    return api.make_sharded_search(
        mesh, cfg.to_retriever(), n_docs_local, n_docs_global, value_scale,
        index_axis=index_axis, query_axes=query_axes,
    )


def build_shard_arrays(fwd: ForwardIndex, cfg: GraphConfig, n_shards: int,
                       params: HNSWParams = HNSWParams()):
    _warn("build_shard_arrays", "api.build_shard_arrays")
    return api.build_shard_arrays(fwd, cfg.to_retriever(params), n_shards)
