"""Live index mutation: delta segments, tombstones, crash-safe merge
(DESIGN.md §10).

Every index in the serving layer used to be write-once: build → save →
open, with a full rebuild the only way to change a document. This
module layers the Lucene-style mutable-index model over the existing
artifact format without touching the engines:

* ``MutableRetriever`` wraps a *base* ``Retriever`` (or
  ``ShardedRetriever``) plus an ordered list of immutable **delta
  segments** — each a self-contained sub-index built through the same
  ``EngineImpl.build_arrays`` path and saved as an ordinary
  ``manifest.json + arrays.npz`` artifact — and per-part **tombstone
  masks** for deletes/updates. Because the paper's compressed forward
  index is the unit of immutability, StreamVByte/DotVByte compression
  carries over to segments unchanged.
* ``search`` fans a query batch over base + segments, maps part-local
  candidate ids through per-part id maps to *stable* doc ids (dead
  rows map to the ``-1`` sentinel at ``-inf``) and merges with the
  sentinel-safe ``api.merge_topk`` contract — top-k stays
  byte-identical to an oracle ``Retriever.build`` over the
  post-mutation corpus (live docs in stable-id order) for every
  engine × codec, enforced by ``make mutation-parity``.
* ``merge()`` — compaction — folds segments + tombstones back into the
  base via a vectorised ``ForwardIndex.concat``/``select`` pass and
  commits with an **atomic generation flip**: write
  ``generation_NNNN/`` completely, then atomically repoint the
  ``CURRENT`` file (``os.replace``). A crash anywhere before the flip
  leaves the previous generation intact and loadable (fault-injection
  tested via ``InjectedCrash`` hooks); orphan directories are ignored
  on open and reclaimed on retry.
* Every mutation and every generation flip bumps ``epoch`` — the
  pipeline's ``ResultCache`` auto-invalidates on the next ``submit``
  (a cached answer can never outlive the index state that produced
  it), and the fan-out plan key carries a ``gen`` component so a flip
  retires stale facade plans instead of silently reusing them.

Per-part candidate budgets extend by the part's own tombstone count
(``k_part = min(n_part, k + dead_part)``) so ``k`` *live* candidates
always survive the mask — the same parity-preserving rule the sharded
driver applies per shard (``ShardedRetriever.set_tombstones``).

On-disk layout under a mutable root (``open_retriever`` dispatches on
the ``CURRENT`` file)::

    root/CURRENT                     ← name of the live generation dir
    root/generation_0000/
        state.json                   ← atomic rewrite per mutation
        store.npz                    ← base CSR rows + stable ids
        base/                        ← ordinary (or sharded) artifact
        segment_0000/                ← ordinary artifact + store.npz
        segment_0001/…
"""

from __future__ import annotations

import dataclasses
import json
import os
import pathlib
import shutil
import threading
import time
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.forward_index import VALUE_FORMATS, ForwardIndex

from . import api
from . import pipeline as serve_pipeline
from .api import ArtifactError, Retriever, RetrieverConfig
from .sharded import ShardedRetriever

__all__ = [
    "InjectedCrash",
    "DeltaSegment",
    "MergeHandle",
    "MutablePlanCache",
    "MutableRetriever",
    "open_mutable",
    "MUTABLE_VERSION",
]

#: bumped whenever the mutable state layout changes incompatibly
MUTABLE_VERSION = 1
_MUTABLE_FORMAT = "repro.serve.mutable"
CURRENT_FILE = "CURRENT"
GEN_DIR_FMT = "generation_{:04d}"
SEGMENT_DIR_FMT = "segment_{:04d}"
STATE_FILE = "state.json"
STORE_FILE = "store.npz"


class InjectedCrash(RuntimeError):
    """Raised by the fault-injection hooks (``_crash_before_commit`` /
    ``crash_before_flip``) to simulate a process death between the
    payload write and the atomic commit — the window the crash-safety
    tests pin down."""


class MergeHandle:
    """Handle on a background compaction (``merge(background=True)``,
    DESIGN.md §11): the generation build runs on a worker thread while
    queries keep serving generation N; ``result()`` joins and returns
    the new base (re-raising anything the merge raised — an injected
    crash surfaces here, not in the serving threads).

    The worker demotes itself to a higher nice value (per-thread on
    Linux), so on a saturated host the compaction soaks up idle cycles
    between query bursts instead of time-slicing evenly against the
    serving path — the standard background-maintenance discipline."""

    #: nice increment for the merge worker (0 disables the demotion)
    NICENESS = 10

    def __init__(self, run):
        self._result = None
        self._exc: Optional[BaseException] = None
        self._thread = threading.Thread(
            target=self._run, args=(run,), name="mutable-merge", daemon=True
        )
        self._thread.start()

    def _run(self, run) -> None:
        try:
            if self.NICENESS:
                # Linux scopes setpriority to a single thread when
                # given a thread id; elsewhere this raises and the
                # merge simply runs at normal priority
                os.setpriority(
                    os.PRIO_PROCESS, threading.get_native_id(),
                    os.getpriority(os.PRIO_PROCESS, 0) + self.NICENESS,
                )
        except (AttributeError, OSError):
            pass
        try:
            self._result = run()
        except BaseException as e:  # surfaces via result()
            self._exc = e

    def done(self) -> bool:
        return not self._thread.is_alive()

    def result(self, timeout: Optional[float] = None):
        self._thread.join(timeout)
        if self._thread.is_alive():
            raise TimeoutError(f"merge still running after {timeout}s")
        if self._exc is not None:
            raise self._exc
        return self._result


def _atomic_write(path: pathlib.Path, text: str) -> None:
    """Write-then-rename: the commit primitive. ``os.replace`` is
    atomic on POSIX, so readers observe either the old or the new
    content, never a partial file."""
    tmp = path.with_name(path.name + ".tmp")
    tmp.write_text(text, encoding="utf-8")
    os.replace(tmp, path)


def _store_dict(fwd: ForwardIndex, ids: np.ndarray) -> Dict[str, np.ndarray]:
    return {
        "components": fwd.components,
        "values": fwd.values,
        "offsets": fwd.offsets,
        "ids": np.asarray(ids, np.int64),
    }


def _load_store(path: pathlib.Path, dim: int, value_format: str
                ) -> Tuple[ForwardIndex, np.ndarray]:
    if not path.is_file():
        raise ArtifactError(f"missing row store {path}")
    with np.load(path) as z:
        fwd = ForwardIndex(
            components=z["components"],
            values=z["values"],
            offsets=z["offsets"],
            dim=dim,
            value_format=VALUE_FORMATS[value_format],
        )
        ids = z["ids"]
    if fwd.n_docs != len(ids):
        raise ArtifactError(
            f"row store {path} holds {fwd.n_docs} rows but {len(ids)} ids"
        )
    return fwd, ids


@dataclasses.dataclass
class DeltaSegment:
    """One immutable delta segment: its stable doc ids, CSR row store
    (for merge/compaction), engine arrays (the servable sub-index),
    and the per-row tombstone mask."""

    ids: np.ndarray  # i64 [n] stable doc ids
    fwd: ForwardIndex  # the segment's own rows (merge source)
    arrays: Mapping[str, np.ndarray]  # EngineImpl.build_arrays output
    dead: np.ndarray  # bool [n]

    @property
    def n_docs(self) -> int:
        return len(self.ids)


@dataclasses.dataclass(frozen=True)
class _Part:
    """One fan-out target: a plan surface (``PlanCache`` or the
    sharded facade — same search contract) plus the part-local →
    stable id map (i32 [n_local + 1], dead rows and the sentinel slot
    hold -1)."""

    plans: object
    idmap: jnp.ndarray
    n_local: int


class MutablePlanCache:
    """Pipeline-facing plan surface of a ``MutableRetriever`` — the
    same ``buckets``/``bucket_for``/``get``/``search``/``compiles``
    contract as ``pipeline.PlanCache``, so the micro-batching
    scheduler serves a mutable index unmodified.

    Each plan fans the dispatch over base + segments; its key carries
    ``shard="mut"`` and the generation component ``gen="g<N>"`` — a
    merge/compaction flip changes the component, so the facade plan is
    *retired* (counted in ``retired``) and recreated against the new
    base rather than silently reused. ``compiles`` aggregates every
    part's plan-cache counter plus everything retired parts had
    compiled: mutation-driven recompiles are the honest cost of
    serving a moving corpus."""

    def __init__(
        self,
        retriever: "MutableRetriever",
        buckets: Optional[Sequence[int]] = None,
    ):
        cfg = retriever.cfg
        self.retriever = retriever
        self.buckets = serve_pipeline.plan_buckets(cfg.batch_size, buckets)
        self.k = cfg.k
        self._plans: Dict[int, serve_pipeline.SearchPlan] = {}
        self.retired = 0
        self._lock = threading.Lock()

    bucket_for = serve_pipeline.PlanCache.bucket_for

    @property
    def compiles(self) -> int:
        return self.retriever._part_compiles()

    def get(self, bucket: int) -> serve_pipeline.SearchPlan:
        with self._lock:
            gen = f"g{self.retriever.generation}"
            plan = self._plans.get(bucket)
            if plan is not None and plan.key.gen != gen:
                self.retired += 1
                plan = None
            if plan is None:
                from repro.kernels.modes import backend_mode, resolve_mode

                cfg = self.retriever.cfg
                key = serve_pipeline.PlanKey(
                    cfg.engine, cfg.codec, cfg.backend,
                    resolve_mode(backend_mode(cfg.backend)), cfg.k, bucket,
                    shard="mut", gen=gen, vq=cfg.vq,
                )
                plan = serve_pipeline.SearchPlan(key, self.retriever._dispatch)
                self._plans[bucket] = plan
            return plan

    def search(self, Q):
        Q = jnp.asarray(Q)
        if Q.shape[0] == 0:
            return (jnp.zeros((0, self.k), jnp.int32),
                    jnp.zeros((0, self.k), jnp.float32))
        return self.get(self.bucket_for(Q.shape[0]))(Q)


class MutableRetriever:
    """Serving handle over a mutable index: the ``search`` /
    ``pipeline`` / ``search_batch`` / ``make_plans`` surface of
    ``Retriever`` plus ``insert`` / ``delete`` / ``update`` /
    ``merge``. Construct with ``MutableRetriever.create`` (fresh
    corpus, optionally persisted under a root directory) or
    ``open_retriever`` on a mutable root.

    Doc identity is the *stable id*: ``search`` returns stable ids,
    which survive merges (unlike base-local positions). ``next_id`` is
    the id-space high-water mark — the out-of-corpus sentinel for the
    merge contract — and ``epoch`` counts index-state changes (the
    ResultCache invalidation trigger)."""

    def __init__(
        self,
        cfg: RetrieverConfig,
        base,
        *,
        base_fwd: ForwardIndex,
        base_ids: np.ndarray,
        base_dead: Optional[np.ndarray] = None,
        segments: Optional[List[DeltaSegment]] = None,
        next_id: Optional[int] = None,
        generation: int = 0,
        epoch: int = 0,
        root=None,
    ):
        if base_fwd.n_docs != len(base_ids):
            raise ValueError(
                f"base store holds {base_fwd.n_docs} rows but "
                f"{len(base_ids)} ids"
            )
        self.cfg = cfg
        self.impl = api.get_engine(cfg.engine)
        self.base = base
        self.base_fwd = base_fwd
        self.base_ids = np.asarray(base_ids, np.int64)
        self.base_dead = (
            np.zeros(len(self.base_ids), bool)
            if base_dead is None else np.asarray(base_dead, bool).copy()
        )
        self.segments: List[DeltaSegment] = list(segments or [])
        all_ids = [self.base_ids] + [s.ids for s in self.segments]
        top = max((int(a.max()) for a in all_ids if a.size), default=-1)
        self.next_id = int(next_id) if next_id is not None else top + 1
        if self.next_id <= top:
            raise ValueError(f"next_id={next_id} ≤ live id {top}")
        self.generation = int(generation)
        self.epoch = int(epoch)
        self.root = pathlib.Path(root) if root is not None else None
        self.dim = base.dim
        self.value_scale = base.value_scale
        self.value_format = base.value_format
        self._handles: Optional[List[_Part]] = None
        self._wrappers: Dict[object, Retriever] = {}
        self._retired_compiles = 0
        # threading model (DESIGN.md §11): single writer — every
        # mutation (insert/delete/update/merge) holds _write_lock for
        # its whole run, so a background merge freezes the logical
        # corpus without read-side locks; _state_lock guards only the
        # brief in-memory windows readers race (part-list build, the
        # post-flip field swap, tombstone-mask flips)
        self._write_lock = threading.RLock()
        self._state_lock = threading.RLock()
        #: overlap counters (surfaced via ServeStats.sync_overlap):
        #: Σ merge build wall-clock, Σ commit-swap critical-section
        #: wall-clock (the bound on how long any query can block on a
        #: generation flip)
        self.merge_wall_us = 0.0
        self.blocked_swap_us = 0.0
        self.plans = MutablePlanCache(self)
        self._pipeline: serve_pipeline.Pipeline | None = None

    # -- construction ---------------------------------------------------
    @classmethod
    def create(cls, fwd: ForwardIndex, cfg: RetrieverConfig, root=None
               ) -> "MutableRetriever":
        """Build generation 0 from a fresh corpus: base index via the
        ordinary ``Retriever.build`` (sharded iff ``cfg.n_shards>1``),
        stable ids ``0..n_docs-1``. With ``root``, the generation
        directory + ``CURRENT`` pointer are committed immediately."""
        base = Retriever.build(fwd, cfg)
        m = cls(
            cfg, base, base_fwd=fwd,
            base_ids=np.arange(fwd.n_docs, dtype=np.int64), root=root,
        )
        if m.root is not None:
            m._write_generation(base, fwd, m.base_ids, m.generation)
            _atomic_write(
                m.root / CURRENT_FILE, GEN_DIR_FMT.format(m.generation)
            )
        return m

    # -- id bookkeeping --------------------------------------------------
    @property
    def n_docs(self) -> int:
        """Id-space size (the merge sentinel), NOT the live count."""
        return self.next_id

    @property
    def n_live(self) -> int:
        return int((~self.base_dead).sum()) + sum(
            int((~s.dead).sum()) for s in self.segments
        )

    def live_ids(self) -> np.ndarray:
        """Sorted stable ids of every live document."""
        parts = [self.base_ids[~self.base_dead]] + [
            s.ids[~s.dead] for s in self.segments
        ]
        ids = np.concatenate(parts) if parts else np.zeros(0, np.int64)
        return np.sort(ids)

    def live_corpus(self) -> Tuple[ForwardIndex, np.ndarray]:
        """(live rows in stable-id order, their sorted stable ids) —
        exactly the corpus an oracle ``Retriever.build`` sees: oracle
        doc position ``r`` is stable id ``live_ids[r]`` (the parity
        harness' mapping)."""
        big = ForwardIndex.concat(
            [self.base_fwd] + [s.fwd for s in self.segments]
        )
        all_ids = np.concatenate(
            [self.base_ids] + [s.ids for s in self.segments]
        )
        all_dead = np.concatenate(
            [self.base_dead] + [s.dead for s in self.segments]
        )
        live_pos = np.flatnonzero(~all_dead)
        live = all_ids[live_pos]
        order = np.argsort(live, kind="stable")
        return big.select(live_pos[order]), live[order]

    def _find_live(self, doc_id: int):
        """→ ("seg", index, row) | ("base", None, row) | None — where
        the live copy of ``doc_id`` lives (at most one across parts)."""
        for si in range(len(self.segments) - 1, -1, -1):
            s = self.segments[si]
            pos = np.flatnonzero((s.ids == doc_id) & ~s.dead)
            if pos.size:
                return ("seg", si, int(pos[0]))
        pos = np.flatnonzero((self.base_ids == doc_id) & ~self.base_dead)
        if pos.size:
            return ("base", None, int(pos[0]))
        return None

    # -- mutation --------------------------------------------------------
    def insert(self, docs, ids=None, *, _crash_before_commit: bool = False
               ) -> np.ndarray:
        """Insert a batch of documents as ONE new delta segment.

        ``docs`` is a ``ForwardIndex`` or an iterable of
        ``(components, values)`` pairs; ``ids`` assigns explicit stable
        ids (fresh by default) — reusing an id requires its previous
        copy to be deleted first (update-in-place =
        ``update``). Returns the assigned stable ids. Commit protocol:
        the segment artifact is written completely, then ``state.json``
        flips atomically — a crash in between leaves an orphan
        directory that open ignores and a retry reclaims."""
        with self._write_lock:
            return self._insert_locked(docs, ids, _crash_before_commit)

    def _insert_locked(self, docs, ids, _crash_before_commit: bool
                       ) -> np.ndarray:
        seg_fwd = (
            docs if isinstance(docs, ForwardIndex)
            else ForwardIndex.from_docs(docs, self.dim, self.value_format)
        )
        if seg_fwd.dim != self.dim:
            raise ValueError(f"segment dim {seg_fwd.dim} != index {self.dim}")
        if seg_fwd.value_format.name != self.value_format:
            raise ValueError(
                f"segment value_format {seg_fwd.value_format.name!r} != "
                f"index {self.value_format!r}"
            )
        n = seg_fwd.n_docs
        if n == 0:
            raise ValueError("cannot insert an empty segment")
        if ids is None:
            ids = np.arange(self.next_id, self.next_id + n, dtype=np.int64)
        else:
            ids = np.asarray(ids, np.int64).reshape(-1)
            if len(ids) != n:
                raise ValueError(f"{n} docs but {len(ids)} ids")
            if len(np.unique(ids)) != n or (ids < 0).any():
                raise ValueError("ids must be unique and ≥ 0")
            for i in ids:
                if self._find_live(int(i)) is not None:
                    raise ValueError(
                        f"doc id {int(i)} is still live; delete it first "
                        f"(or use update)"
                    )
        cfg1 = self.cfg.replace(n_shards=1)
        arrays = self.impl.build_arrays(seg_fwd, cfg1)
        name = SEGMENT_DIR_FMT.format(len(self.segments))
        if self.root is not None:
            sdir = self._gen_dir() / name
            if sdir.exists():  # orphan of a crashed earlier attempt
                shutil.rmtree(sdir)
            host = {k: np.asarray(v) for k, v in arrays.items()}
            api.write_artifact(
                sdir,
                api.manifest_dict(
                    cfg1, host, n_docs=n, dim=self.dim,
                    value_scale=self.value_scale,
                    value_format=self.value_format,
                ),
                host, compress=False,
            )
            np.savez(sdir / STORE_FILE, **_store_dict(seg_fwd, ids))
        if _crash_before_commit:
            raise InjectedCrash(f"crash before committing {name}")
        with self._state_lock:
            self.segments.append(
                DeltaSegment(ids=ids, fwd=seg_fwd, arrays=arrays,
                             dead=np.zeros(n, bool))
            )
            self.next_id = max(self.next_id, int(ids.max()) + 1)
            self._commit_memory()
        self._write_state()
        return ids

    def delete(self, ids) -> None:
        """Tombstone the live copy of every given stable id (KeyError
        if one is not live). Deletes touch only ``state.json`` — the
        segment/base payloads stay immutable."""
        ids = np.atleast_1d(np.asarray(ids, np.int64))
        with self._write_lock:
            with self._state_lock:
                for i in ids:
                    hit = self._find_live(int(i))
                    if hit is None:
                        raise KeyError(f"doc id {int(i)} is not live")
                    kind, si, row = hit
                    if kind == "seg":
                        self.segments[si].dead[row] = True
                    else:
                        self.base_dead[row] = True
                self._commit_memory()
            self._write_state()

    def update(self, docs, ids) -> np.ndarray:
        """Update-in-place: tombstone the live copies, re-insert the
        new rows as a delta segment under the SAME stable ids."""
        ids = np.atleast_1d(np.asarray(ids, np.int64))
        with self._write_lock:
            self.delete(ids)
            return self.insert(docs, ids=ids)

    def _commit_memory(self) -> None:
        """In-memory commit of a mutation: epoch bump + part-list
        invalidation, under ``_state_lock`` (callers hold it) so a
        concurrent reader sees either the old or the new index state,
        never a torn one."""
        self.epoch += 1
        self._handles = None

    # -- merge / compaction ---------------------------------------------
    def merge(self, *, crash_before_flip: bool = False,
              background: bool = False):
        """Fold every segment + tombstone into a fresh base index and
        commit via the atomic generation flip: write
        ``generation_{g+1}/`` completely (base artifact, row store,
        ``state.json``), then atomically repoint ``CURRENT``. A crash
        before the flip (``crash_before_flip`` injects one) leaves the
        previous generation untouched and loadable; in-memory state
        mutates only after the flip succeeds. Returns the new base.

        ``background=True`` (DESIGN.md §11) runs the whole build on a
        worker thread and returns a ``MergeHandle`` immediately:
        queries keep serving generation N throughout (compaction does
        not change the live corpus, so their answers stay correct and
        oracle-identical), other writers block on the write lock, and
        the commit swaps fields under ``_state_lock`` — a critical
        section of plain assignments, timed into ``blocked_swap_us``.
        The epoch bump makes the pipeline drop cached results on its
        next admission, exactly as a foreground merge does. A
        background merge also pre-builds the next generation's base
        wrapper and AOT-warms its bucket plans on the worker thread, so
        the first post-flip query pays a dispatch, not a compile."""
        if background:
            return MergeHandle(
                lambda: self._merge_sync(crash_before_flip, prewarm=True)
            )
        return self._merge_sync(crash_before_flip)

    def _merge_sync(self, crash_before_flip: bool, *, prewarm: bool = False):
        with self._write_lock:
            t0 = time.perf_counter()
            merged, new_ids = self.live_corpus()
            if merged.n_docs == 0:
                raise ValueError("merge would produce an empty corpus")
            cfg = self.cfg
            if cfg.n_shards > merged.n_docs:
                # every shard must own ≥ 1 doc; a shrunken corpus falls
                # back to fewer shards rather than failing the merge
                cfg = cfg.replace(n_shards=max(1, merged.n_docs))
            new_base = Retriever.build(merged, cfg)
            next_gen = self.generation + 1
            if self.root is not None:
                gdir = self.root / GEN_DIR_FMT.format(next_gen)
                if gdir.exists():  # orphan of a crashed earlier merge
                    shutil.rmtree(gdir)
                self._write_generation(new_base, merged, new_ids, next_gen)
                if crash_before_flip:
                    raise InjectedCrash(
                        f"crash before flipping CURRENT to generation "
                        f"{next_gen}"
                    )
                _atomic_write(
                    self.root / CURRENT_FILE, GEN_DIR_FMT.format(next_gen)
                )
            elif crash_before_flip:
                raise InjectedCrash(
                    "crash before the in-memory generation flip"
                )
            new_wrapper = None
            if prewarm and not isinstance(new_base, ShardedRetriever):
                # stage generation N+1's serving plans on THIS (worker)
                # thread before the flip (DESIGN.md §11): build the
                # post-merge base wrapper and AOT-compile its bucket
                # plans, so the swap below installs warm executables and
                # no query pays the first-touch compile of a fresh
                # generation
                k_b = min(new_base.n_docs, cfg.k)
                new_wrapper = Retriever(
                    cfg.replace(n_shards=1, k=k_b), new_base.arrays,
                    n_docs=new_base.n_docs, dim=self.dim,
                    value_scale=self.value_scale,
                    value_format=self.value_format, shard="mut:base",
                )
                for b in self.plans.buckets:
                    new_wrapper.plans.get(b).warm(int(self.dim))
            # ---- memory commit (post-flip only): plain assignments
            # under the state lock, so a concurrent reader sees either
            # generation N or N+1 in full, never a mix ----
            new_dead = np.zeros(len(new_ids), bool)
            with self._state_lock:
                # timed INSIDE the lock: this is the only window a
                # reader can be blocked by the commit (waiting for the
                # lock before it is ours measures readers blocking US,
                # which is them making progress, not an outage)
                t_swap = time.perf_counter()
                self._retire_parts()
                if new_wrapper is not None:
                    self._wrappers["base"] = new_wrapper
                self.cfg = cfg
                self.base = new_base
                self.base_fwd = merged
                self.base_ids = new_ids
                self.base_dead = new_dead
                self.segments = []
                self.generation = next_gen
                self.epoch += 1
                self._handles = None
                self.blocked_swap_us += (
                    time.perf_counter() - t_swap) * 1e6
            self.merge_wall_us += (time.perf_counter() - t0) * 1e6
            return new_base

    def _retire_parts(self) -> None:
        """Fold every live part's compile counter into the retired
        total before dropping the part (honest recompile accounting
        across generation flips)."""
        for r in self._wrappers.values():
            self._retired_compiles += r.plans.compiles
        self._wrappers.clear()
        if isinstance(self.base, ShardedRetriever):
            self._retired_compiles += self.base.plans.compiles

    # -- persistence -----------------------------------------------------
    def _gen_dir(self) -> pathlib.Path:
        return self.root / GEN_DIR_FMT.format(self.generation)

    def _write_generation(self, base, fwd: ForwardIndex, ids: np.ndarray,
                          generation: int) -> None:
        gdir = self.root / GEN_DIR_FMT.format(generation)
        gdir.mkdir(parents=True, exist_ok=True)
        base.save(gdir / "base", compress=False)
        np.savez(gdir / STORE_FILE, **_store_dict(fwd, ids))
        self._write_state(gdir=gdir, generation=generation, segments=[],
                          dead={"base": []},
                          epoch=self.epoch + (generation != self.generation))

    def _write_state(self, *, gdir: Optional[pathlib.Path] = None,
                     generation: Optional[int] = None,
                     segments: Optional[list] = None,
                     dead: Optional[dict] = None,
                     epoch: Optional[int] = None) -> None:
        if self.root is None:
            return
        if gdir is None:
            gdir = self._gen_dir()
        if segments is None:
            segments = [
                SEGMENT_DIR_FMT.format(i) for i in range(len(self.segments))
            ]
            dead = {"base": np.flatnonzero(self.base_dead).tolist()}
            for i, s in enumerate(self.segments):
                dead[SEGMENT_DIR_FMT.format(i)] = (
                    np.flatnonzero(s.dead).tolist()
                )
        state = {
            "format": _MUTABLE_FORMAT,
            "version": MUTABLE_VERSION,
            "generation": self.generation if generation is None else generation,
            "epoch": self.epoch if epoch is None else epoch,
            "next_id": self.next_id,
            "segments": segments,
            "dead": dead,
        }
        _atomic_write(gdir / STATE_FILE, json.dumps(state, indent=1,
                                                    sort_keys=True))

    # -- fan-out ---------------------------------------------------------
    def _wrapper(self, key, arrays, n_local: int, k_part: int,
                 label: str) -> Retriever:
        """Per-part serving wrapper at candidate budget ``k_part``
        (re-used while the budget holds; a budget change — the part's
        tombstone count moved — retires the old wrapper's compiles)."""
        cur = self._wrappers.get(key)
        if cur is not None and cur.cfg.k == k_part:
            return cur
        if cur is not None:
            self._retired_compiles += cur.plans.compiles
        r = Retriever(
            self.cfg.replace(n_shards=1, k=k_part), arrays,
            n_docs=n_local, dim=self.dim, value_scale=self.value_scale,
            value_format=self.value_format, shard=f"mut:{label}",
        )
        self._wrappers[key] = r
        return r

    def _idmap(self, ids: np.ndarray, dead: np.ndarray) -> jnp.ndarray:
        m = np.full(len(ids) + 1, -1, np.int32)
        m[:-1] = np.where(dead, -1, ids).astype(np.int32)
        return jnp.asarray(m)

    def _parts(self) -> List[_Part]:
        """The current fan-out part list, built (and memoized) under
        ``_state_lock``: a reader gets a SNAPSHOT — a plain list whose
        parts stay valid even if a merge commits mid-dispatch (the old
        generation's arrays/plans live as long as the list does, and
        compaction does not change the live corpus, so in-flight
        queries against the old parts stay oracle-correct)."""
        with self._state_lock:
            if self._handles is not None:
                return self._handles
            k = self.cfg.k
            parts: List[_Part] = []
            n_base = len(self.base_ids)
            if isinstance(self.base, ShardedRetriever):
                # the sharded base filters its own tombstones in the
                # shard merge (uniform tombstone-extended budgets) and
                # already returns its top-k LIVE candidates — no budget
                # extension needed at this level
                self.base.set_tombstones(np.flatnonzero(self.base_dead))
                parts.append(_Part(
                    self.base.plans,
                    self._idmap(self.base_ids, self.base_dead), n_base,
                ))
            else:
                k_b = min(n_base, k + int(self.base_dead.sum()))
                r = self._wrapper("base", self.base.arrays, n_base, k_b,
                                  "base")
                parts.append(_Part(
                    r.plans, self._idmap(self.base_ids, self.base_dead),
                    n_base,
                ))
            for i, s in enumerate(self.segments):
                k_s = min(s.n_docs, k + int(s.dead.sum()))
                r = self._wrapper(("seg", i), s.arrays, s.n_docs, k_s,
                                  f"seg{i}")
                parts.append(_Part(
                    r.plans, self._idmap(s.ids, s.dead), s.n_docs,
                ))
            self._handles = parts
            return parts

    def _part_compiles(self) -> int:
        with self._state_lock:
            n = self._retired_compiles + sum(
                r.plans.compiles for r in self._wrappers.values()
            )
            if isinstance(self.base, ShardedRetriever):
                n += self.base.plans.compiles
            return n

    def _dispatch(self, Q):
        """One padded ``[bucket, dim]`` batch → merged stable-id top-k
        over base + segments: per-part search, id-map to stable ids
        (dead rows and sentinels → -1 at -inf), sentinel-safe dedupe
        merge keyed on stable id — ties break toward the lower stable
        id, matching the oracle's positional tie-break over its
        stable-id-ordered corpus. Parts and the id-space sentinel are
        snapshotted together, so a merge committing mid-dispatch can't
        mix generations within one batch."""
        with self._state_lock:
            parts = self._parts()
            sentinel = self.next_id
        flat_i, flat_s = [], []
        for p in parts:
            ids, scores = p.plans.search(Q)
            valid = (ids >= 0) & (ids <= p.n_local)
            gids = jnp.take(p.idmap, jnp.clip(ids, 0, p.n_local))
            gids = jnp.where(valid, gids, jnp.int32(-1))
            scores = jnp.where(gids >= 0, scores, -jnp.inf)
            flat_i.append(gids)
            flat_s.append(scores)
        flat_i = jnp.concatenate(flat_i, axis=1)
        flat_s = jnp.concatenate(flat_s, axis=1)
        if flat_i.shape[1] < self.cfg.k:
            pad = self.cfg.k - flat_i.shape[1]
            flat_i = jnp.pad(flat_i, ((0, 0), (0, pad)), constant_values=-1)
            flat_s = jnp.pad(flat_s, ((0, 0), (0, pad)),
                             constant_values=-jnp.inf)
        return api.merge_topk(
            flat_i, flat_s, self.cfg.k,
            dedupe=True, n_docs_global=sentinel,
        )

    # -- serving (the Retriever surface) --------------------------------
    def make_plans(self, buckets) -> MutablePlanCache:
        return MutablePlanCache(self, buckets)

    def search(self, Q, k: int | None = None):
        """[nq, dim] queries → (stable ids [nq, k], scores [nq, k]),
        byte-identical to the post-mutation oracle under exhaustive
        engine budgets (the mutation-parity gate; oracle position
        ``r`` ↔ stable id ``live_ids()[r]``)."""
        ids, scores = self.plans.search(jnp.asarray(Q))
        if k is None or k == self.cfg.k:
            return ids, scores
        if k > self.cfg.k:
            raise ValueError(
                f"k={k} exceeds the static cfg.k={self.cfg.k}; rebuild "
                f"with a larger cfg.k"
            )
        return ids[:, :k], scores[:, :k]

    def pipeline(self, **kw) -> serve_pipeline.Pipeline:
        if kw:
            return serve_pipeline.Pipeline(self, **kw)
        if self._pipeline is None:
            self._pipeline = serve_pipeline.Pipeline(self)
        return self._pipeline

    def search_batch(self, Q):
        return self.pipeline().search_batch(Q)


def open_mutable(root) -> MutableRetriever:
    """Open a mutable root at its committed generation: follow
    ``CURRENT`` → ``state.json`` → base artifact + row store + every
    listed segment (+ tombstone masks). Orphan directories from
    crashed commits are ignored; a missing or partially written
    generation raises ``ArtifactError`` rather than serving partial
    state."""
    root = pathlib.Path(root)
    cur = root / CURRENT_FILE
    if not cur.is_file():
        raise ArtifactError(f"no {CURRENT_FILE} under {root}")
    gen_name = cur.read_text(encoding="utf-8").strip()
    gdir = root / gen_name
    sf = gdir / STATE_FILE
    if not sf.is_file():
        raise ArtifactError(
            f"{cur} points at {gen_name!r} but {sf} is missing — the "
            f"committed generation is gone; restore it or rebuild"
        )
    try:
        state = json.loads(sf.read_text(encoding="utf-8"))
    except json.JSONDecodeError as e:
        raise ArtifactError(f"corrupt state at {sf}: {e}") from None
    if state.get("format") != _MUTABLE_FORMAT:
        raise ArtifactError(
            f"{sf} is not a {_MUTABLE_FORMAT} state "
            f"(format={state.get('format')!r})"
        )
    if state.get("version") != MUTABLE_VERSION:
        raise ArtifactError(
            f"mutable state version {state.get('version')!r} at {sf} "
            f"incompatible with this build (expected {MUTABLE_VERSION})"
        )
    base = api.open_retriever(gdir / "base")
    base_fwd, base_ids = _load_store(
        gdir / STORE_FILE, base.dim, base.value_format
    )
    dead_map = state.get("dead", {})

    def _mask(name: str, n: int) -> np.ndarray:
        m = np.zeros(n, bool)
        idx = np.asarray(dead_map.get(name, []), np.int64)
        if idx.size and (idx.min() < 0 or idx.max() >= n):
            raise ArtifactError(
                f"dead row index out of range for {name!r} at {sf}"
            )
        m[idx] = True
        return m

    segments: List[DeltaSegment] = []
    for name in state.get("segments", []):
        seg_r = api.open_retriever(gdir / name)
        seg_fwd, seg_ids = _load_store(
            gdir / name / STORE_FILE, base.dim, base.value_format
        )
        if seg_r.n_docs != len(seg_ids):
            raise ArtifactError(
                f"segment {name!r} artifact holds {seg_r.n_docs} docs "
                f"but its store holds {len(seg_ids)}"
            )
        segments.append(DeltaSegment(
            ids=seg_ids, fwd=seg_fwd, arrays=seg_r.arrays,
            dead=_mask(name, len(seg_ids)),
        ))
    return MutableRetriever(
        base.cfg, base,
        base_fwd=base_fwd, base_ids=base_ids,
        base_dead=_mask("base", len(base_ids)),
        segments=segments,
        next_id=int(state["next_id"]),
        generation=int(state["generation"]),
        epoch=int(state["epoch"]),
        root=root,
    )
