"""One ``Retriever`` API (DESIGN.md §7): engine registry, build/serve
split, and on-disk index artifacts.

The paper's thesis is that forward-index compression is common to all
algorithmic flavors of sparse ANNS; this module is where that becomes
an API contract. Every serving engine is a registry entry —

    @register_engine("seismic")
    class SeismicEngine(EngineImpl): ...

— implementing the ``EngineImpl`` protocol (host-side array build,
pure static-shape ``search_one``, dry-run array specs, shard build)
over one shared ``RetrieverConfig`` (engine, codec, k, shard count,
engine params). The engine-agnostic surface is then:

* ``Retriever.build(fwd, cfg)`` — host-side index construction
  (collection → engine arrays under any codec registered in
  ``core/layout.py``);
* ``retriever.search(Q, k)`` — the jit'd static-shape batched search;
* ``retriever.save(path)`` / ``open_retriever(path)`` — the artifact
  lifecycle: a manifest (engine/codec/params/format version) plus an
  npz payload of the packed arrays, so a serving process loads
  pre-packed arrays without re-encoding anything;
* ``build_shard_arrays`` / ``make_sharded_search`` — ONE generic
  sharded-search driver (DESIGN.md §4): per-shard ``search_one``,
  local→global id map, O(k) all-gather merge; engines only declare
  whether the merge must dedupe doc ids.

Three engines ship registered: ``seismic`` (two-phase block probe),
``hnsw`` (static beam search) and ``flat`` (exact full scan — proof
the registry is open, and the recall oracle).

Execution goes through the online serving pipeline
(``repro.serve.pipeline``, DESIGN.md §8): ``Retriever`` holds a
``PlanCache`` — one compiled executable per ``(engine, codec,
backend, k, bucket)`` — and ``search`` pads any query batch up to its
smallest covering bucket so arbitrary batch sizes hit a warm plan;
``search_batch`` reroutes through the micro-batching scheduler
(deadline coalescing + quantized-query result cache + ServeStats).
The per-engine wrapper shims of PR-1/PR-2 (``repro.serve.engine``,
``repro.serve.graph_engine``) were removed after one deprecation
release.
"""

from __future__ import annotations

import dataclasses
import json
import pathlib
from functools import partial
from typing import Any, Callable, Dict, Mapping

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import layout
from repro.core import values as value_codecs
from repro.core.forward_index import VALUE_FORMATS, ForwardIndex
from repro.kernels import modes as kernel_modes
from repro.serve import pipeline as serve_pipeline

__all__ = [
    "RetrieverConfig",
    "EngineImpl",
    "register_engine",
    "get_engine",
    "available_engines",
    "Retriever",
    "open_retriever",
    "ArtifactError",
    "MANIFEST_VERSION",
    "build_shard_arrays",
    "make_sharded_search",
    "map_local_ids",
    "merge_topk",
    "row_array_specs",
]

#: bumped whenever the artifact layout changes incompatibly; loading a
#: mismatching artifact fails loudly rather than mis-decoding arrays
MANIFEST_VERSION = 1
_MANIFEST_FORMAT = "repro.serve.retriever"
#: top-level manifest magic of a sharded artifact tree (DESIGN.md §9)
_SHARDED_FORMAT = "repro.serve.retriever-sharded"
_MANIFEST_FILE = "manifest.json"
_ARRAYS_FILE = "arrays.npz"


class ArtifactError(ValueError):
    """A saved index artifact is missing, corrupt, or incompatible."""


# ---------------------------------------------------------------------------
# config + engine registry
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RetrieverConfig:
    """Engine-agnostic serving configuration.

    ``params`` carries the engine-specific knobs (build AND search
    time); unknown keys are rejected against the engine's declared
    defaults, so typos fail at construction rather than silently
    serving defaults.

    ``backend`` selects the candidate-rescoring execution path
    (DESIGN.md §3, §7): ``"jnp"`` (reference), ``"pallas"`` (fused
    kernels from ``repro.kernels.registry`` in their default —
    compiled — mode), or an explicit kernel mode
    ``"pallas_interpret"`` / ``"pallas_compiled"``
    (``repro.kernels.modes``). Top-k ids are identical across all
    backends, asserted by the parity suite and ``make kernel-parity``.

    ``batch_size`` is the expected steady-state query-batch size: it
    joins the pipeline's padding-bucket set (DESIGN.md §8) so that
    batch shape gets an exact-fit compiled plan instead of rounding up
    to the next power-of-two bucket.

    ``vq`` is the VALUE codec (DESIGN.md §12), orthogonal to the id
    ``codec``: ``"f16"`` stores raw storage-dtype values; ``"u8_sq"``
    / ``"u4_sq"`` store per-row scalar-quant codes with learned clip
    ranges; ``"pq"`` stores product-quantizer codes plus a shared
    codebook. Quantized values are decoded in-kernel on the rescoring
    path; top-k ids stay identical across backends at every ``vq``
    (asserted by ``make value-parity``)."""

    engine: str = "seismic"
    codec: str = "uncompressed"
    backend: str = "jnp"  # a kernel_modes.SCORING_BACKENDS value
    k: int = 10
    batch_size: int | None = None  # steady-state batch hint → bucket set
    n_shards: int = 1  # index shards for the sharded path
    params: Mapping[str, Any] = dataclasses.field(default_factory=dict)
    vq: str = "f16"  # value codec (core.values.VALUE_CODECS)

    def replace(self, **kw) -> "RetrieverConfig":
        return dataclasses.replace(self, **kw)


class EngineImpl:
    """Protocol every registered engine implements.

    An engine is a *pure-function view* of an index: host-side numpy
    array construction (``build_arrays`` / ``arrays_from_index`` /
    ``shard_build``) plus one static-shape ``search_one`` that serves
    the jit'd batched path, the dry-run (ShapeDtypeStruct arrays, via
    ``array_specs``) and the generic sharded driver unmodified."""

    name: str = "abstract"
    #: engine knob defaults; ``RetrieverConfig.params`` overrides
    defaults: Dict[str, Any] = {}
    #: True when one document can be reported by several index shards
    #: (the generic sharded merge then dedupes by doc id)
    dedupe_merge: bool = False

    # -- config plumbing ------------------------------------------------
    def params(self, cfg: RetrieverConfig) -> Dict[str, Any]:
        unknown = set(cfg.params) - set(self.defaults)
        if unknown:
            raise ValueError(
                f"unknown {self.name!r} engine params {sorted(unknown)}; "
                f"known: {sorted(self.defaults)}"
            )
        return {**self.defaults, **cfg.params}

    # -- host-side build ------------------------------------------------
    def build_arrays(self, fwd: ForwardIndex, cfg: RetrieverConfig) -> Dict[str, np.ndarray]:
        """Collection → engine arrays (numpy), via the host index."""
        raise NotImplementedError

    # -- serving --------------------------------------------------------
    def search_one(self, cfg: RetrieverConfig, n_docs: int, value_scale: float, arrays, q):
        """One dense query → (ids [k], scores [k]). Pure, static-shape."""
        raise NotImplementedError

    def search_batch(self, cfg: RetrieverConfig, n_docs: int, value_scale: float, arrays, Q):
        """A query batch → (ids [nq, k], scores [nq, k]) — the unit the
        pipeline's plan cache compiles (DESIGN.md §8).

        The default is ``vmap(search_one)``: per-query results are
        independent of batch-mates, which is what makes bucket padding
        sound. Engines whose candidate sets are query-independent
        override this with a genuinely batched decode-once/score-many
        dispatch (``FlatEngine`` → ``scoring.score_candidate_rows_
        batch`` → the kernel registry's ``rows_scores_batch``)."""
        return jax.vmap(
            partial(self.search_one, cfg, n_docs, value_scale, arrays)
        )(Q)

    def array_specs(self, cfg: RetrieverConfig, **dims) -> Dict[str, jax.ShapeDtypeStruct]:
        """ShapeDtypeStruct stand-ins for the engine arrays (dry-run)."""
        raise NotImplementedError

    # -- sharded build --------------------------------------------------
    def shard_build(self, fwd: ForwardIndex, cfg: RetrieverConfig, n_shards: int):
        """→ (per-shard array dicts, idmaps, n_docs_local, pad_values).

        ``idmaps[s]`` is i32 [n_docs_local + 1] mapping shard-local doc
        ids to global ones (sentinel → global n_docs); ``pad_values``
        feeds ``layout.pad_stack``."""
        raise NotImplementedError

    def build_shard(
        self, fwd: ForwardIndex, cfg: RetrieverConfig, lo: int, hi: int
    ) -> Dict[str, np.ndarray]:
        """Arrays of ONE self-contained shard over docs ``[lo, hi)``
        with shard-LOCAL ids — the unit the sharded artifact layer
        (DESIGN.md §9) writes per shard directory. The default builds
        the engine's normal arrays over the CSR slice; engines with a
        cheaper range path override (``FlatEngine`` packs rows straight
        from the per-shard pack offsets, no sub-index build)."""
        return self.build_arrays(fwd.slice(lo, hi), cfg)


_ENGINES: Dict[str, Callable[[], EngineImpl]] = {}


def register_engine(name: str):
    """Class decorator: make an ``EngineImpl`` servable by name."""

    def deco(factory: Callable[[], EngineImpl]):
        _ENGINES[name] = factory
        return factory

    return deco


def _ensure_builtin_engines() -> None:
    from . import engines  # noqa: F401  (registers seismic/hnsw/flat)


def get_engine(name: str) -> EngineImpl:
    _ensure_builtin_engines()
    try:
        return _ENGINES[name]()
    except KeyError:
        raise ValueError(
            f"no registered engine {name!r}; have {sorted(_ENGINES)}"
        ) from None


def available_engines() -> list[str]:
    _ensure_builtin_engines()
    return sorted(_ENGINES)


def row_array_specs(
    codec: str,
    *,
    n_docs: int,
    l_max: int,
    d_max: int,
    value_dtype=jnp.float16,
    bitpack_bits: int = 16,
    vq: str = "f16",
) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStructs of the packed row form under ``codec`` — the
    candidate-rescoring arrays every engine shares (dry-run sizing).
    Under a quantized ``vq`` the value stream is u8 codes at
    ``l_max // code_factor`` width plus the clip columns / codebook
    (DESIGN.md §12); ``l_max`` must already be factor-aligned the way
    ``layout.pack_rows`` rounds it."""
    sds = jax.ShapeDtypeStruct
    value_codecs.check_vq(vq)
    factor = value_codecs.code_factor(vq)
    arrays = {
        "vals_rows": (
            sds((n_docs + 1, l_max), value_dtype)
            if vq == "f16"
            else sds((n_docs + 1, l_max // factor), jnp.uint8)
        ),
        "nnz_rows": sds((n_docs + 1,), jnp.int32),
    }
    if vq == "pq":
        arrays["vq_codebook"] = sds(
            (value_codecs.PQ_K, value_codecs.PQ_M), jnp.float32
        )
    elif vq != "f16":
        lo_key, sc_key = value_codecs.sq_keys(vq)
        arrays[lo_key] = sds((n_docs + 1, 1), jnp.float32)
        arrays[sc_key] = sds((n_docs + 1, 1), jnp.float32)
    if codec == "uncompressed":
        arrays["comps_rows"] = sds((n_docs + 1, l_max), jnp.int32)
    elif codec == "bitpack":
        arrays["words_rows"] = sds(
            (n_docs + 1, (l_max * bitpack_bits + 31) // 32), jnp.uint32
        )
        arrays["widths_rows"] = sds((n_docs + 1,), jnp.int32)
    else:  # (ctrl, data) byte-stream codecs
        group = layout.get_layout(codec).block_multiple
        arrays["ctrl_rows"] = sds((n_docs + 1, l_max // group), jnp.uint8)
        arrays["data_rows"] = sds((n_docs + 1, d_max), jnp.uint8)
    return arrays


# ---------------------------------------------------------------------------
# the Retriever surface
# ---------------------------------------------------------------------------


class Retriever:
    """Engine- and codec-agnostic serving handle.

    Holds the static device arrays of ONE engine×codec index plus the
    jit'd batched search. Construct with ``Retriever.build`` (host-side
    build from a ForwardIndex), ``Retriever.from_host_index`` (reuse an
    already-built ``SeismicIndex``/``HNSWIndex`` across codecs), or
    ``open_retriever`` (load a saved artifact, no re-encoding)."""

    def __init__(
        self,
        cfg: RetrieverConfig,
        arrays: Mapping[str, np.ndarray],
        *,
        n_docs: int,
        dim: int,
        value_scale: float,
        value_format: str,
        shard: str = "",
    ):
        self.impl = get_engine(cfg.engine)
        layout.get_layout(cfg.codec)  # raises listing the known codecs
        value_codecs.check_vq(cfg.vq)  # raises listing VALUE_CODECS
        if cfg.backend not in kernel_modes.SCORING_BACKENDS:
            raise ValueError(
                f"unknown backend {cfg.backend!r}; have "
                f"{list(kernel_modes.SCORING_BACKENDS)}"
            )
        if cfg.batch_size is not None and (
            not isinstance(cfg.batch_size, int)
            or isinstance(cfg.batch_size, bool)
            or cfg.batch_size < 1
        ):
            raise ValueError(
                f"batch_size must be a positive int or None, got "
                f"{cfg.batch_size!r}"
            )
        self.impl.params(cfg)  # rejects unknown engine knobs early
        self.cfg = cfg
        self.n_docs = int(n_docs)
        self.dim = int(dim)
        self.value_scale = float(value_scale)
        self.value_format = value_format
        #: shard-topology component of the plan key (DESIGN.md §9):
        #: "" for a monolithic index, "<shard>/<n_shards>" inside a
        #: ShardedRetriever — per-shard executables never collide
        self.shard = shard
        self.arrays = {k: jnp.asarray(v) for k, v in arrays.items()}
        # the compile layer (DESIGN.md §8): one executable per
        # (engine, codec, backend, k, bucket, shard); cfg.batch_size
        # joins the bucket set so the expected batch shape gets an
        # exact fit
        self.plans = serve_pipeline.PlanCache(self)
        self._pipeline: serve_pipeline.Pipeline | None = None

    def make_plans(self, buckets) -> "serve_pipeline.PlanCache":
        """A fresh plan cache with an explicit bucket set (the pipeline
        asks the retriever so sharded handles can answer too)."""
        return serve_pipeline.PlanCache(self, buckets)

    # -- construction ---------------------------------------------------
    @classmethod
    def build(cls, fwd: ForwardIndex, cfg: RetrieverConfig):
        """Host-side index construction: collection → servable arrays.

        With ``cfg.n_shards > 1`` the build routes to the sharded
        artifact layer (DESIGN.md §9): per-shard self-contained
        sub-indexes over contiguous doc ranges, returned as a
        ``ShardedRetriever`` whose ``save``/``open_retriever`` artifact
        tree is one directory per shard."""
        if cfg.n_shards > 1:
            from .sharded import ShardedRetriever

            return ShardedRetriever.build(fwd, cfg)
        impl = get_engine(cfg.engine)
        layout.get_layout(cfg.codec)
        return cls(
            cfg,
            impl.build_arrays(fwd, cfg),
            n_docs=fwd.n_docs,
            dim=fwd.dim,
            value_scale=float(fwd.value_format.scale),
            value_format=fwd.value_format.name,
        )

    @classmethod
    def from_host_index(cls, index, cfg: RetrieverConfig) -> "Retriever":
        """Wrap an already-built host index (``SeismicIndex`` /
        ``HNSWIndex``) — sweeping codecs (or backends) over one build.
        ``cfg``'s build-time params are ignored."""
        impl = get_engine(cfg.engine)
        if not hasattr(impl, "arrays_from_index"):
            raise ValueError(
                f"engine {cfg.engine!r} has no host-index form; use Retriever.build"
            )
        fwd = index.fwd
        return cls(
            cfg,
            impl.arrays_from_index(index, cfg),
            n_docs=fwd.n_docs,
            dim=fwd.dim,
            value_scale=float(fwd.value_format.scale),
            value_format=fwd.value_format.name,
        )

    # -- serving ----------------------------------------------------------
    def search(self, Q, k: int | None = None):
        """[nq, dim] dense queries → (ids [nq, k], scores [nq, k]).

        Dispatches through the plan cache: ``Q`` pads up to its
        smallest covering bucket and runs the warm compiled plan for
        that ``(engine, codec, backend, k, bucket)`` key — padded
        slots carry the zero query and are sliced off, so results are
        byte-identical to an exact-shape dispatch (DESIGN.md §8).

        ``k`` defaults to ``cfg.k`` (the static top-k the search graph
        was traced with); any smaller k is a free slice."""
        ids, scores = self.plans.search(jnp.asarray(Q))
        if k is None or k == self.cfg.k:
            return ids, scores
        if k > self.cfg.k:
            raise ValueError(
                f"k={k} exceeds the static cfg.k={self.cfg.k}; rebuild the "
                f"Retriever with a larger cfg.k"
            )
        return ids[:, :k], scores[:, :k]

    def pipeline(self, **kw) -> "serve_pipeline.Pipeline":
        """The micro-batching scheduler over this retriever
        (DESIGN.md §8). With no arguments, one default instance is
        created lazily and reused (it shares this retriever's plan
        cache); keyword arguments (``buckets``, ``deadline_us``,
        ``cache_size``, ``clock``) construct a fresh pipeline."""
        if kw:
            return serve_pipeline.Pipeline(self, **kw)
        if self._pipeline is None:
            self._pipeline = serve_pipeline.Pipeline(self)
        return self._pipeline

    def search_batch(self, Q):
        """Serve a query batch through the micro-batching pipeline:
        admission (result-cache lookup) → bucket coalescing → plan
        dispatch → per-query de-multiplex, results in submission
        order. Byte-identical to ``search`` (the parity suite); the
        result cache keys at the index's own value-quantization
        tolerance (``pipeline.quantized_query_key``), so on an
        f16-valued index two queries within one f16 ulp per component
        share a cache entry — pass ``cache_size=0`` or
        ``key_dtype=np.float32`` to ``pipeline(...)`` for strict
        exactness."""
        return self.pipeline().search_batch(Q)

    # -- artifact lifecycle ----------------------------------------------
    def save(self, path, *, compress: bool = True) -> pathlib.Path:
        """Write the index artifact: ``manifest.json`` + ``arrays.npz``.

        The npz payload holds the packed codec arrays exactly as served,
        so ``open_retriever`` performs zero re-encoding.
        ``compress=False`` stores npz members raw (ZIP_STORED) — the
        form the sharded artifact layer memory-maps (DESIGN.md §9)."""
        host = {k: np.asarray(v) for k, v in self.arrays.items()}
        return write_artifact(
            path, manifest_dict(self.cfg, host, n_docs=self.n_docs,
                                dim=self.dim, value_scale=self.value_scale,
                                value_format=self.value_format),
            host, compress=compress,
        )


def manifest_dict(
    cfg: RetrieverConfig,
    host_arrays: Mapping[str, np.ndarray],
    *,
    n_docs: int,
    dim: int,
    value_scale: float,
    value_format: str,
    extra: Mapping[str, Any] | None = None,
) -> dict:
    """The monolithic-artifact manifest payload (serving config, corpus
    stats, per-array dtype/shape specs). ``extra`` merges in shard
    bookkeeping (``shard``, ``doc_lo``/``doc_hi``) for per-shard
    directories of a sharded tree (DESIGN.md §9)."""
    manifest = {
        "format": _MANIFEST_FORMAT,
        "version": MANIFEST_VERSION,
        "engine": cfg.engine,
        "codec": cfg.codec,
        "backend": cfg.backend,
        "k": cfg.k,
        "batch_size": cfg.batch_size,
        "n_shards": cfg.n_shards,
        "params": dict(cfg.params),
        "vq": cfg.vq,
        "n_docs": int(n_docs),
        "dim": int(dim),
        "value_scale": float(value_scale),
        "value_format": value_format,
        "arrays": {
            k: {"dtype": str(v.dtype), "shape": list(v.shape)}
            for k, v in host_arrays.items()
        },
    }
    if extra:
        manifest.update(extra)
    return manifest


def write_artifact(
    path,
    manifest: Mapping[str, Any],
    host_arrays: Mapping[str, np.ndarray],
    *,
    compress: bool = True,
) -> pathlib.Path:
    """Write one artifact directory: ``manifest.json`` + ``arrays.npz``.

    ``compress=False`` writes the npz members ZIP_STORED (raw npy bytes
    at a fixed offset inside the zip) — the property ``mmap_npz`` in
    ``repro.serve.sharded`` relies on to memory-map members in place."""
    path = pathlib.Path(path)
    path.mkdir(parents=True, exist_ok=True)
    with open(path / _MANIFEST_FILE, "w", encoding="utf-8") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    saver = np.savez_compressed if compress else np.savez
    saver(path / _ARRAYS_FILE, **dict(host_arrays))
    return path


def load_manifest(path) -> dict:
    """Read + parse ``manifest.json`` under ``path`` (ArtifactError on
    a missing or unparseable file); no semantic validation."""
    path = pathlib.Path(path)
    mf = path / _MANIFEST_FILE
    if not mf.is_file():
        raise ArtifactError(f"no {_MANIFEST_FILE} under {path}")
    try:
        return json.loads(mf.read_text(encoding="utf-8"))
    except json.JSONDecodeError as e:
        raise ArtifactError(f"corrupt manifest at {mf}: {e}") from None


def check_manifest_names(manifest: Mapping[str, Any], where) -> None:
    """Version / engine / codec / value-format validation shared by the
    monolithic and sharded openers. ``where`` names the offending file
    in the error."""
    version = manifest.get("version")
    if version != MANIFEST_VERSION:
        raise ArtifactError(
            f"artifact version {version!r} at {where} incompatible with "
            f"this build (expected {MANIFEST_VERSION}); rebuild the index"
        )
    engine, codec = manifest["engine"], manifest["codec"]
    if engine not in available_engines():
        raise ArtifactError(
            f"artifact engine {engine!r} is not registered; have "
            f"{available_engines()}"
        )
    if codec not in layout.available_layouts():
        raise ArtifactError(
            f"artifact codec {codec!r} is not registered; have "
            f"{layout.available_layouts()}"
        )
    if manifest["value_format"] not in VALUE_FORMATS:
        raise ArtifactError(
            f"unknown value_format {manifest['value_format']!r}; have "
            f"{sorted(VALUE_FORMATS)}"
        )
    vq = manifest.get("vq", "f16")  # pre-value-codec artifacts are f16
    if vq not in value_codecs.VALUE_CODECS:
        raise ArtifactError(
            f"unknown value codec {vq!r} at {where}; have "
            f"{list(value_codecs.VALUE_CODECS)}"
        )


def check_array_spec(
    spec: Mapping[str, Any], arrays: Mapping[str, np.ndarray], where
) -> None:
    """Manifest array specs vs the actual npz payload — names, dtypes
    and shapes must all agree or the artifact is rejected."""
    if set(spec) != set(arrays):
        raise ArtifactError(
            f"array payload mismatch at {where}: manifest lists "
            f"{sorted(spec)}, npz holds {sorted(arrays)}"
        )
    for k, meta in spec.items():
        got = arrays[k]
        if str(got.dtype) != meta["dtype"] or list(got.shape) != meta["shape"]:
            raise ArtifactError(
                f"array {k!r} at {where} is {got.dtype}{list(got.shape)}, "
                f"manifest says {meta['dtype']}{meta['shape']}"
            )


def cfg_from_manifest(manifest: Mapping[str, Any]) -> RetrieverConfig:
    return RetrieverConfig(
        engine=manifest["engine"],
        codec=manifest["codec"],
        backend=manifest.get("backend", "jnp"),  # pre-backend artifacts
        k=int(manifest["k"]),
        batch_size=manifest.get("batch_size"),  # pre-pipeline artifacts
        n_shards=int(manifest.get("n_shards", 1)),
        params=manifest.get("params", {}),
        vq=manifest.get("vq", "f16"),  # pre-value-codec artifacts
    )


def open_retriever(path):
    """Load a saved index artifact into a servable handle.

    Validates the manifest (format magic, version, engine/codec names,
    per-array dtype/shape) before touching the payload — an
    incompatible or tampered artifact raises ``ArtifactError`` instead
    of mis-decoding. A top-level *sharded* manifest
    (``format="repro.serve.retriever-sharded"``, written by
    ``Retriever.build(..., n_shards=S)``) dispatches to
    ``ShardedRetriever.open``, which memory-maps every shard's arrays —
    O(metadata) open regardless of corpus size (DESIGN.md §9).

    A *mutable* root — a directory holding a ``CURRENT`` pointer file
    written by ``MutableRetriever`` (DESIGN.md §10) — dispatches to
    ``segments.open_mutable``, which follows ``CURRENT`` to the live
    generation directory and reopens base + delta segments +
    tombstones exactly as last committed."""
    path = pathlib.Path(path)
    if (path / "CURRENT").is_file():
        from . import segments

        return segments.open_mutable(path)
    manifest = load_manifest(path)
    fmt = manifest.get("format")
    if fmt == _SHARDED_FORMAT:
        from .sharded import ShardedRetriever

        return ShardedRetriever.open(path, manifest)
    if fmt != _MANIFEST_FORMAT:
        raise ArtifactError(
            f"{path / _MANIFEST_FILE} is not a {_MANIFEST_FORMAT} artifact "
            f"(format={fmt!r})"
        )
    check_manifest_names(manifest, path / _MANIFEST_FILE)
    with np.load(path / _ARRAYS_FILE) as npz:
        arrays = {k: npz[k] for k in npz.files}
    check_array_spec(manifest["arrays"], arrays, path / _ARRAYS_FILE)
    cfg = cfg_from_manifest(manifest)
    return Retriever(
        cfg.replace(n_shards=1),  # one directory == one sub-index
        arrays,
        n_docs=manifest["n_docs"],
        dim=manifest["dim"],
        value_scale=manifest["value_scale"],
        value_format=manifest["value_format"],
    )


# ---------------------------------------------------------------------------
# generic sharded driver (DESIGN.md §4 / §7)
# ---------------------------------------------------------------------------


def build_shard_arrays(
    fwd: ForwardIndex,
    cfg: RetrieverConfig,
    n_shards: int | None = None,
    *,
    host_index=None,
):
    """Partition a collection into self-contained per-shard sub-indexes
    and stack their engine arrays with a leading shard dim.

    Returns (stacked jnp arrays, idmap [n_shards, n_docs_local+1],
    n_docs_local). How the split happens is the engine's business
    (Seismic: blocks round-robin + doc ownership; graph/flat:
    contiguous doc ranges); the stacking is shared ``pad_stack``.

    Pass ``host_index`` to reuse an already-built host index instead
    of rebuilding it inside the shard split (engines that partition by
    doc range rebuild per-range structures regardless and ignore it)."""
    impl = get_engine(cfg.engine)
    n_shards = n_shards or cfg.n_shards
    if host_index is not None and hasattr(impl, "shard_from_index"):
        dicts, idmaps, n_docs_local, pad_values = impl.shard_from_index(
            host_index, cfg, n_shards
        )
    else:
        dicts, idmaps, n_docs_local, pad_values = impl.shard_build(fwd, cfg, n_shards)
    stacked = {
        k: jnp.asarray(v) for k, v in layout.pad_stack(dicts, pad_values).items()
    }
    return stacked, jnp.asarray(np.stack(idmaps)), n_docs_local


def map_local_ids(idmap, ids, n_docs_global: int):
    """Shard-local candidate ids → global doc ids, sentinel-safe.

    ``idmap`` is i32 [n_docs_local + 1]: slot ``i < n_docs_local`` holds
    the global id of local doc ``i``, the last slot holds the
    out-of-corpus sentinel ``n_docs_global``. A bare ``jnp.take``
    CLIPS out-of-range indices (jax's default gather mode), so a -1
    padding id or a local id ≥ the shard's true size would silently
    alias doc 0 / the last doc — the global-id bug class the sharded
    regression suite pins down. Every local id outside
    ``[0, n_docs_local]`` maps to ``n_docs_global`` instead, which
    ``merge_topk`` masks to -inf."""
    n_local = idmap.shape[-1] - 1
    valid = (ids >= 0) & (ids <= n_local)
    mapped = jnp.take(idmap, jnp.clip(ids, 0, n_local))
    return jnp.where(valid, mapped, jnp.int32(n_docs_global))


def merge_topk(flat_ids, flat_scores, k: int, *, dedupe: bool, n_docs_global: int):
    """[nq, S·k] gathered per-shard candidates → global (ids, scores).

    The merge contract (DESIGN.md §9): every out-of-corpus id — negative
    padding sentinels *and* ids ≥ n_docs_global — is masked to -inf so it
    can never displace a real document; with ``dedupe`` (engines whose
    shards may report the same doc, e.g. Seismic block round-robin) the
    candidates are sorted by id and repeats masked before the final
    ``top_k``. ``jax.lax.top_k`` breaks score ties toward the lower
    index, so without dedupe the merge is byte-stable in shard order."""
    nq = flat_scores.shape[0]
    invalid = (flat_ids < 0) | (flat_ids >= n_docs_global)
    flat_scores = jnp.where(invalid, -jnp.inf, flat_scores)
    if dedupe:
        order = jnp.argsort(flat_ids, axis=1)
        si = jnp.take_along_axis(flat_ids, order, axis=1)
        ss = jnp.take_along_axis(flat_scores, order, axis=1)
        dup = jnp.concatenate(
            [jnp.zeros((nq, 1), bool), si[:, 1:] == si[:, :-1]], axis=1
        )
        flat_ids = si
        flat_scores = jnp.where(dup, -jnp.inf, ss)
    top_s, pos = jax.lax.top_k(flat_scores, k)
    return jnp.take_along_axis(flat_ids, pos, axis=1), top_s


def make_sharded_search(
    mesh,
    cfg: RetrieverConfig,
    n_docs_local: int,
    n_docs_global: int,
    value_scale: float,
    *,
    index_axis: str = "model",
    query_axes: tuple[str, ...] = ("data",),
    k_local: int | None = None,
):
    """ONE distributed search driver for every registered engine.

    The index is pre-partitioned into ``mesh.shape[index_axis]``
    self-contained sub-indexes (arrays carry a leading shard dim,
    sharded over ``index_axis``; ``idmap`` maps local → global doc
    ids, sentinel → n_docs_global). Queries shard over ``query_axes``
    and replicate across index shards; each device runs the engine's
    ``search_one`` on its shard, then an O(k) all-gather + top-k merge
    produces the global result — deduping by doc id first iff the
    engine declares ``dedupe_merge`` (a Seismic document's blocks
    scatter across shards; graph/flat doc ranges are disjoint).
    Collective bytes per query: 8·k·n_shards.

    ``k_local`` caps the per-shard candidate count below the merge's
    ``cfg.k`` — shards smaller than k serve their whole doc range and
    engines whose score vector is shard-sized (flat) cannot top-k past
    it; the merge sentinel-pads back up to ``cfg.k`` when needed."""
    from jax.sharding import PartitionSpec as P

    impl = get_engine(cfg.engine)
    local_cfg = (
        cfg if k_local is None or k_local == cfg.k else cfg.replace(k=k_local)
    )

    def local(arrays, idmap, Q):
        arrays = jax.tree.map(lambda a: a[0], arrays)  # drop shard dim
        idmap = idmap[0]
        ids, scores = jax.vmap(
            partial(impl.search_one, local_cfg, n_docs_local, value_scale, arrays)
        )(Q)
        gids = map_local_ids(idmap, ids, n_docs_global)  # sentinel-safe
        ag_s = jax.lax.all_gather(scores, index_axis)  # [S, nq, k]
        ag_i = jax.lax.all_gather(gids, index_axis)
        S, nq, k = ag_s.shape
        flat_s = ag_s.transpose(1, 0, 2).reshape(nq, S * k)
        flat_i = ag_i.transpose(1, 0, 2).reshape(nq, S * k)
        if S * k < cfg.k:  # k > corpus: sentinel-pad the merge width
            pad = cfg.k - S * k
            flat_i = jnp.pad(flat_i, ((0, 0), (0, pad)),
                             constant_values=n_docs_global)
            flat_s = jnp.pad(flat_s, ((0, 0), (0, pad)),
                             constant_values=-jnp.inf)
        return merge_topk(
            flat_i, flat_s, cfg.k,
            dedupe=impl.dedupe_merge, n_docs_global=n_docs_global,
        )

    qa = query_axes or None
    return jax.shard_map(
        local,
        mesh=mesh,
        in_specs=(P(index_axis), P(index_axis), P(qa, None)),
        out_specs=(P(qa, None), P(qa, None)),
        check_vma=False,
    )
