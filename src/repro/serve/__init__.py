"""Serving layer: the engine-agnostic ``Retriever`` API (``api``), the
registered engines (``engines``), and the deprecated per-engine shims
(``engine``, ``graph_engine``). See DESIGN.md §7."""
