"""Serving layer: the engine-agnostic ``Retriever`` API (``api``), the
registered engines (``engines``), and the online serving pipeline —
plan cache, micro-batching scheduler, result cache, metrics
(``pipeline``). See DESIGN.md §7–§8."""
