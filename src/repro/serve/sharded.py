"""Sharded artifact tree + out-of-core serving (DESIGN.md §9).

The paper's point is that the forward index dominates index size;
compression buys nothing once the corpus outgrows one host's memory.
This module lifts sharding into the Retriever/artifact layer proper:

* ``Retriever.build(fwd, cfg)`` with ``cfg.n_shards > 1`` partitions
  ``[0, n_docs)`` into contiguous doc ranges (balanced, ragged last
  shard) and builds one SELF-CONTAINED sub-index per range with
  shard-local ids — every engine's ``build_shard`` — returning a
  ``ShardedRetriever``;
* ``save`` writes one directory per shard (an ordinary artifact:
  ``manifest.json`` + ``arrays.npz``, stored UNCOMPRESSED) plus a
  top-level shard manifest carrying per-shard doc ranges, codec, and
  array specs;
* ``open_retriever`` on the tree memory-maps every shard's arrays
  (``mmap_npz``) — O(metadata) open, no array bytes are read until a
  shard is admitted to residency — so a corpus 10–100× larger than
  device memory still opens instantly;
* serving fans a query batch over the shards: ``shard_map`` on a
  ``repro.dist.sharding.index_mesh`` when the host has ≥ n_shards
  devices, otherwise a sequential out-of-core round-robin with a
  bounded resident-shard LRU (``max_resident``); either way the
  per-shard top-k merge is the O(k) ``api.merge_topk`` contract
  (sentinel-safe global ids, dedupe iff the engine asks).

Residency policy: a shard is *resident* when its arrays have been
materialized onto the device as a per-shard ``Retriever`` (with its
own plan cache, keyed by the ``"<shard>/<n_shards>"`` plan-key shard
component). At most ``max_resident`` shards are resident at once;
admission beyond that evicts the least-recently-used shard, dropping
its device arrays AND its compiled plans — re-admission recompiles,
which ``plans.compiles`` keeps counting: recompiles are the honest
cost of running out-of-core. ``resident_bytes()`` /
``peak_resident_bytes`` expose the quantity the LRU bounds
(gated by ``benchmarks/table5_scale.py``).

Prefetch (DESIGN.md §11): the sequential out-of-core loop stages the
NEXT shard on a bounded worker pool while the device scores the
current one — the staging slot is ONE explicit buffer on top of the
``max_resident`` LRU (a classic double buffer: page-in + host→device
transfer + an AOT plan warm happen off the hot path, and the shard
that opens the next rotation is already resident-in-waiting).
``prefetch_hits`` / ``prefetch_misses`` count rotations served from
the staged buffer vs. rotations that paid admission on the critical
path; staged-but-discarded work folds its compiles into the evicted
counter, so recompile accounting stays honest either way.
"""

from __future__ import annotations

import dataclasses
import json
import pathlib
import struct
import threading
import zipfile
from collections import OrderedDict
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Dict, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import layout
from repro.core.forward_index import ForwardIndex

from . import api
from . import pipeline as serve_pipeline
from .api import ArtifactError, Retriever, RetrieverConfig

__all__ = [
    "SHARD_DIR_FMT",
    "shard_ranges",
    "mmap_npz",
    "Shard",
    "ShardedPlanCache",
    "ShardedRetriever",
]

#: on-disk name of shard ``s`` inside a sharded artifact tree
SHARD_DIR_FMT = "shard_{:04d}"

# one bounded staging worker shared by every ShardedRetriever in the
# process: staging tasks are independent and short, and a shared
# daemon pool avoids spawning (and leaking) a thread per retriever —
# tests build hundreds of them
_PREFETCH_POOL: Optional[ThreadPoolExecutor] = None
_PREFETCH_POOL_LOCK = threading.Lock()


def _prefetch_pool() -> ThreadPoolExecutor:
    global _PREFETCH_POOL
    with _PREFETCH_POOL_LOCK:
        if _PREFETCH_POOL is None:
            _PREFETCH_POOL = ThreadPoolExecutor(
                max_workers=1, thread_name_prefix="shard-prefetch"
            )
        return _PREFETCH_POOL


def shard_ranges(n_docs: int, n_shards: int) -> list[tuple[int, int]]:
    """Contiguous doc ranges tiling ``[0, n_docs)`` — balanced sizes
    (``n_docs % n_shards`` leading shards get one extra doc, so the
    last shard is the ragged one). Every shard must own ≥ 1 document:
    an empty shard serves nothing and breaks the static search shapes,
    so it is rejected at build time rather than discovered at query
    time."""
    if n_shards < 1:
        raise ValueError(f"n_shards must be ≥ 1, got {n_shards}")
    if n_shards > n_docs:
        raise ValueError(
            f"n_shards={n_shards} exceeds n_docs={n_docs}: every shard "
            f"must own at least one document — lower n_shards or grow "
            f"the collection"
        )
    base, rem = divmod(n_docs, n_shards)
    bounds = np.cumsum([0] + [base + (1 if s < rem else 0) for s in range(n_shards)])
    return [(int(bounds[s]), int(bounds[s + 1])) for s in range(n_shards)]


def mmap_npz(path) -> Dict[str, np.ndarray]:
    """Memory-map every member of an *uncompressed* ``.npz`` in place.

    ``np.load(..., mmap_mode="r")`` silently ignores ``mmap_mode`` for
    ``.npz`` archives (it only applies to bare ``.npy`` files), so this
    parses the zip structure itself: ``np.savez`` members are
    ZIP_STORED, i.e. the raw ``.npy`` bytes sit verbatim at a fixed
    offset inside the archive — local file header (30 bytes + filename
    + extra field), then the npy magic/header, then the array data.
    Each member becomes an ``np.memmap`` view at that offset: opening
    costs O(metadata) and pages fault in on first touch.

    Zero-length members fall back to ordinary arrays (an empty range
    cannot be mapped). Compressed members, truncated archives and
    malformed npy headers raise ``ArtifactError``."""
    path = pathlib.Path(path)
    try:
        zf = zipfile.ZipFile(path)
    except FileNotFoundError:
        raise ArtifactError(f"missing shard payload {path}") from None
    except (zipfile.BadZipFile, OSError) as e:
        raise ArtifactError(
            f"corrupt npz at {path} ({e}): the payload is unreadable — "
            f"likely a truncated or partial write; rebuild the shard"
        ) from None
    out: Dict[str, np.ndarray] = {}
    file_size = path.stat().st_size
    with zf, open(path, "rb") as f:
        for info in zf.infolist():
            if info.compress_type != zipfile.ZIP_STORED:
                raise ArtifactError(
                    f"npz member {info.filename!r} in {path} is "
                    f"compressed (type {info.compress_type}); sharded "
                    f"artifacts must be written with ``compress=False`` "
                    f"(np.savez, not savez_compressed) to be "
                    f"memory-mappable — re-save the artifact"
                )
            f.seek(info.header_offset)
            hdr = f.read(30)
            if len(hdr) < 30 or hdr[:4] != b"PK\x03\x04":
                raise ArtifactError(
                    f"truncated npz at {path}: local header of member "
                    f"{info.filename!r} is incomplete; rebuild the shard"
                )
            fn_len, extra_len = struct.unpack("<HH", hdr[26:30])
            f.seek(info.header_offset + 30 + fn_len + extra_len)
            try:
                version = np.lib.format.read_magic(f)
                if version == (1, 0):
                    shape, fortran, dtype = np.lib.format.read_array_header_1_0(f)
                elif version == (2, 0):
                    shape, fortran, dtype = np.lib.format.read_array_header_2_0(f)
                else:
                    raise ValueError(f"unsupported npy format version {version}")
            except ArtifactError:
                raise
            except Exception as e:
                raise ArtifactError(
                    f"corrupt npy member {info.filename!r} in {path}: {e}"
                ) from None
            data_off = f.tell()
            nbytes = int(dtype.itemsize * np.prod(shape, dtype=np.int64))
            if data_off + nbytes > file_size:
                raise ArtifactError(
                    f"truncated npz at {path}: member {info.filename!r} "
                    f"needs {nbytes} bytes at offset {data_off} but the "
                    f"file holds {file_size} — partial write or "
                    f"corruption; rebuild the shard"
                )
            name = info.filename
            if name.endswith(".npy"):
                name = name[:-4]
            if nbytes == 0:
                out[name] = np.zeros(shape, dtype=dtype)
            else:
                out[name] = np.memmap(
                    path, dtype=dtype, mode="r", offset=data_off,
                    shape=shape, order="F" if fortran else "C",
                )
    return out


@dataclasses.dataclass
class Shard:
    """One shard of the tree: its global doc range plus its arrays —
    host numpy right after ``build``, ``np.memmap`` views after
    ``open`` (nothing resident until admission)."""

    doc_lo: int
    doc_hi: int
    arrays: Mapping[str, np.ndarray]

    @property
    def n_docs(self) -> int:
        return self.doc_hi - self.doc_lo

    def disk_bytes(self) -> int:
        return sum(int(np.asarray(a).nbytes) for a in self.arrays.values())


class ShardedPlanCache:
    """The pipeline-facing plan surface of a ``ShardedRetriever``.

    Same ``buckets``/``bucket_for``/``get``/``search``/``compiles``
    contract as ``pipeline.PlanCache``, so the micro-batching scheduler
    works unmodified over shards: each plan pads its batch to the
    bucket and fans the dispatch over the shards (mesh or sequential),
    where every shard hits its OWN per-shard plan cache — plan keys
    carry the ``"<shard>/<n_shards>"`` topology component, so shards of
    one tree (whose array shapes differ, e.g. the ragged last shard)
    never collide on an executable. ``compiles`` aggregates the
    per-shard counters plus everything evicted shards had compiled:
    out-of-core re-admission recompiles, and the recompile metric
    counts it honestly."""

    def __init__(
        self,
        retriever: "ShardedRetriever",
        buckets: Optional[Sequence[int]] = None,
    ):
        cfg = retriever.cfg
        self.retriever = retriever
        self.buckets = serve_pipeline.plan_buckets(cfg.batch_size, buckets)
        self.k = cfg.k
        self._plans: Dict[int, serve_pipeline.SearchPlan] = {}
        self._lock = threading.Lock()

    # same covering-bucket policy as the monolithic cache
    bucket_for = serve_pipeline.PlanCache.bucket_for

    @property
    def compiles(self) -> int:
        r = self.retriever
        with r._admit_lock:
            return r._evicted_compiles + sum(
                sr.plans.compiles for sr in r._resident.values()
            )

    def get(self, bucket: int) -> serve_pipeline.SearchPlan:
        with self._lock:
            plan = self._plans.get(bucket)
            if plan is None:
                from repro.kernels.modes import backend_mode, resolve_mode

                cfg = self.retriever.cfg
                key = serve_pipeline.PlanKey(
                    cfg.engine, cfg.codec, cfg.backend,
                    resolve_mode(backend_mode(cfg.backend)), cfg.k, bucket,
                    shard=f"*/{cfg.n_shards}", vq=cfg.vq,
                )
                plan = serve_pipeline.SearchPlan(
                    key, self.retriever._dispatch_shards
                )
                self._plans[bucket] = plan
            return plan

    def search(self, Q):
        Q = jnp.asarray(Q)
        if Q.shape[0] == 0:
            return (jnp.zeros((0, self.k), jnp.int32),
                    jnp.zeros((0, self.k), jnp.float32))
        return self.get(self.bucket_for(Q.shape[0]))(Q)


class ShardedRetriever:
    """Serving handle over a sharded index: same ``search`` /
    ``pipeline`` / ``search_batch`` / ``save`` surface as ``Retriever``
    (the pipeline and launcher never special-case it), fanning every
    dispatch over per-shard sub-indexes and merging with the
    sentinel-safe O(k) contract (``api.merge_topk``).

    Construct with ``Retriever.build(fwd, cfg)`` at ``n_shards > 1``,
    or ``open_retriever(path)`` on a saved tree (memory-mapped)."""

    def __init__(
        self,
        cfg: RetrieverConfig,
        shards: Sequence[Shard],
        *,
        dim: int,
        value_scale: float,
        value_format: str,
        max_resident: int | None = None,
    ):
        if cfg.n_shards != len(shards):
            raise ValueError(
                f"cfg.n_shards={cfg.n_shards} but {len(shards)} shards given"
            )
        self.impl = api.get_engine(cfg.engine)
        layout.get_layout(cfg.codec)
        self.impl.params(cfg)
        self.cfg = cfg
        self.shards = list(shards)
        self.n_docs = self.shards[-1].doc_hi
        self.dim = int(dim)
        self.value_scale = float(value_scale)
        self.value_format = value_format
        #: bound on simultaneously-resident shards (sequential path);
        #: None/n_shards keeps everything warm — set 1 for strict
        #: out-of-core round-robin
        self.max_resident = (
            cfg.n_shards if max_resident is None else max(1, int(max_resident))
        )
        #: None = auto (mesh iff devices ≥ shards); True forces the
        #: mesh path (error when impossible); False forces sequential
        self.use_mesh: bool | None = None
        self._resident: "OrderedDict[int, Retriever]" = OrderedDict()
        self._evicted_compiles = 0
        self.evictions = 0
        self.peak_resident_bytes = 0
        self._mesh_state = None
        self._mesh_static = None  # stacked shard arrays (tombstone-free)
        #: live tombstones (mutable-index integration, DESIGN.md §10):
        #: sorted global doc ids masked to -inf in the shard merge
        self._tombstones = np.zeros(0, np.int64)
        self._tomb_mask = None  # jnp bool [n_docs + 1] when non-empty
        self._shard_tombs = [0] * cfg.n_shards
        # per-shard serving constants, hoisted OUT of the dispatch
        # rotation (admission must cost page-in + compile, not
        # re-derived host-side setup): candidate budget + sub-config
        # per shard, recomputed only when the tombstone set changes
        self._shard_k = [min(sh.n_docs, cfg.k) for sh in self.shards]
        self._shard_cfg = [
            cfg.replace(n_shards=1, k=b) for b in self._shard_k
        ]
        #: overlap the sequential rotation with staging of the next
        #: shard (DESIGN.md §11); flip off for the synchronous baseline
        self.prefetch = True
        self.prefetch_hits = 0
        self.prefetch_misses = 0
        self._staged: Optional[Tuple[int, "Future[Retriever]"]] = None
        # guards _resident/_staged/counters: the scheduler thread and
        # direct .search callers race the staging worker's hand-off
        self._admit_lock = threading.RLock()
        self.plans = ShardedPlanCache(self)
        self._pipeline: serve_pipeline.Pipeline | None = None

    # -- construction ---------------------------------------------------
    @classmethod
    def build(cls, fwd: ForwardIndex, cfg: RetrieverConfig) -> "ShardedRetriever":
        """Partition ``[0, n_docs)`` into ``cfg.n_shards`` contiguous
        ranges and build one self-contained sub-index per range
        (shard-local ids) via the engine's ``build_shard``."""
        impl = api.get_engine(cfg.engine)
        layout.get_layout(cfg.codec)
        impl.params(cfg)
        shards = [
            Shard(lo, hi, impl.build_shard(fwd, cfg, lo, hi))
            for lo, hi in shard_ranges(fwd.n_docs, cfg.n_shards)
        ]
        return cls(
            cfg, shards,
            dim=fwd.dim,
            value_scale=float(fwd.value_format.scale),
            value_format=fwd.value_format.name,
        )

    # -- tombstones (mutable-index integration, DESIGN.md §10) ----------
    def set_tombstones(self, ids) -> None:
        """Install the live tombstone set: global doc ids whose
        candidates must be masked to ``-inf`` in the shard merge (a
        ``MutableRetriever`` over a sharded base routes deletes here).

        Every shard's candidate budget grows by the TOTAL tombstone
        count — ``k_local = min(n_docs_s, k + n_tombs)``
        (``dist.sharding.tombstone_budget``) — so each shard still
        surfaces ``k`` *live* candidates even when every tombstoned doc
        outranks them: the parity-preserving extension of the
        shard-smaller-than-k rule. The budget is deliberately UNIFORM
        rather than per-shard-routed: the mesh path's shard_map bakes
        ONE ``k_local`` across devices (SPMD), and dedupe-merging
        engines tie-break by doc id over the gathered candidate strip,
        so byte-parity between the sequential and mesh paths requires
        both to surface identical per-shard candidate sets. Resident
        (or staged) shards whose budget changed are evicted — their
        compiled plans are stale; re-admission recompiles, counted
        honestly."""
        ids = np.unique(np.asarray(ids, dtype=np.int64).reshape(-1))
        if ids.size and (int(ids[0]) < 0 or int(ids[-1]) >= self.n_docs):
            raise ValueError(
                f"tombstone ids outside [0, {self.n_docs}): "
                f"[{ids[0]}, {ids[-1]}]"
            )
        bounds = [sh.doc_lo for sh in self.shards] + [self.n_docs]
        new_tombs = [int(c) for c in np.diff(np.searchsorted(ids, bounds))]
        new_k = [
            min(sh.n_docs, self.cfg.k + int(ids.size)) for sh in self.shards
        ]
        with self._admit_lock:
            for s in list(self._resident):
                if new_k[s] != self._shard_k[s]:
                    old = self._resident.pop(s)
                    self._evicted_compiles += old.plans.compiles
                    self.evictions += 1
            st = self._staged
            if st is not None and new_k[st[0]] != self._shard_k[st[0]]:
                # the staged build carries the old budget — retire it
                # (compiles fold into the evicted counter, as always)
                self._staged = None
                self._evicted_compiles += st[1].result().plans.compiles
            self._shard_tombs = new_tombs
            self._shard_k = new_k
            self._shard_cfg = [
                self.cfg.replace(n_shards=1, k=b) for b in new_k
            ]
            self._tombstones = ids
            if ids.size:
                # one extra slot so the out-of-corpus sentinel id n_docs
                # indexes cleanly (and reads False: already masked)
                mask = np.zeros(self.n_docs + 1, dtype=bool)
                mask[ids] = True
                self._tomb_mask = jnp.asarray(mask)
            else:
                self._tomb_mask = None
            self._mesh_state = None  # the mesh path bakes k_local at trace

    # -- residency (the out-of-core core) -------------------------------
    def _build_shard(self, s: int) -> Retriever:
        """Materialize shard ``s`` as a sub-``Retriever``: pages the
        (possibly memory-mapped) arrays in and puts them on the device.
        Pure build — no LRU mutation, so the staging worker can run it
        off-thread. A shard smaller than its budget serves its ENTIRE
        doc range as the candidate list — the merge needs no more, and
        engines whose score vector is shard-sized (flat) cannot top-k
        past it (budgets hoisted in ``_shard_cfg``, see
        ``set_tombstones``)."""
        sh = self.shards[s]
        return Retriever(
            self._shard_cfg[s],
            sh.arrays,
            n_docs=sh.n_docs,
            dim=self.dim,
            value_scale=self.value_scale,
            value_format=self.value_format,
            shard=f"{s}/{self.cfg.n_shards}",
        )

    def _stage(self, s: int, bucket: int) -> None:
        """Double-buffer: queue shard ``s`` for staging on the shared
        worker pool — page-in + device put (``_build_shard``) + an AOT
        warm of the ``bucket`` plan — while the caller scores the
        current shard. One staged shard at a time (the explicit extra
        buffer the threading model documents); an already-resident or
        already-staged shard is a no-op, and a stale staging for a
        different shard is retired with its compiles counted."""
        with self._admit_lock:
            if s in self._resident:
                return
            st = self._staged
            if st is not None:
                if st[0] == s:
                    return
                self._staged = None
                self._evicted_compiles += st[1].result().plans.compiles
            dim = self.dim

            def task() -> Retriever:
                r = self._build_shard(s)
                plan = r.plans.get(r.plans.bucket_for(bucket))
                plan.warm(dim)
                return r

            self._staged = (s, _prefetch_pool().submit(task))

    def _consume_staged(self, s: int) -> Optional[Retriever]:
        """Take shard ``s`` out of the staging buffer if it's there —
        blocking on an in-flight build (still a win: the build started
        a rotation ago). A staged retriever whose budget went stale
        between staging and admission is discarded, compiles counted.
        Callers hold ``_admit_lock``."""
        st = self._staged
        if st is None or st[0] != s:
            return None
        self._staged = None
        r = st[1].result()
        if r.cfg.k != self._shard_k[s]:
            self._evicted_compiles += r.plans.compiles
            return None
        return r

    def _staged_bytes(self) -> int:
        st = self._staged
        if st is None or not st[1].done() or st[1].exception() is not None:
            return 0
        return sum(int(a.nbytes) for a in st[1].result().arrays.values())

    def _shard_retriever(self, s: int) -> Retriever:
        """The per-shard sub-``Retriever``, admitted to the bounded
        LRU: served from residency, else from the staging buffer
        (``prefetch_hits``), else built on the critical path
        (``prefetch_misses``); admission beyond ``max_resident`` evicts
        the least-recently-used shard — device arrays and compiled
        plans both drop (re-admission recompiles; ``plans.compiles``
        counts it). ``peak_resident_bytes`` includes a completed staged
        build: the double buffer is real memory the bound must own."""
        with self._admit_lock:
            # sample BEFORE consuming the staging buffer: the moment a
            # staged build completes while the previous shard is still
            # resident is exactly the double-buffer transient the peak
            # must own (sampling after _consume_staged would miss it)
            self.peak_resident_bytes = max(
                self.peak_resident_bytes,
                self.resident_bytes() + self._staged_bytes(),
            )
            r = self._resident.get(s)
            if r is not None:
                self._resident.move_to_end(s)
                return r
            r = self._consume_staged(s)
            if r is not None:
                self.prefetch_hits += 1
            else:
                if self.prefetch and self.cfg.n_shards > 1:
                    self.prefetch_misses += 1
                r = self._build_shard(s)
            self._resident[s] = r
            while len(self._resident) > self.max_resident:
                _, old = self._resident.popitem(last=False)
                self._evicted_compiles += old.plans.compiles
                self.evictions += 1
            self.peak_resident_bytes = max(
                self.peak_resident_bytes,
                self.resident_bytes() + self._staged_bytes(),
            )
            return r

    def resident_bytes(self) -> int:
        """Device bytes currently held by resident shard sub-indexes —
        the quantity ``max_resident`` bounds (the scale benchmark's
        peak-memory gate reads ``peak_resident_bytes``)."""
        return sum(
            sum(int(a.nbytes) for a in r.arrays.values())
            for r in self._resident.values()
        )

    def disk_bytes(self) -> int:
        """Total on-disk array payload across shards (bytes gate)."""
        return sum(sh.disk_bytes() for sh in self.shards)

    # -- shard fan-out ----------------------------------------------------
    def _global_ids(self, s: int, ids):
        """Shard-local → global doc ids, sentinel-safe (the merge
        contract): contiguous ranges make the map an offset add, but
        ONLY for ids inside ``[0, n_local)`` — negative padding
        sentinels and out-of-range ids go to the out-of-corpus sentinel
        ``n_docs``, never through arithmetic (the clip-aliasing bug
        class ``api.map_local_ids`` documents)."""
        sh = self.shards[s]
        valid = (ids >= 0) & (ids < sh.n_docs)
        return jnp.where(valid, ids + sh.doc_lo, jnp.int32(self.n_docs))

    def _dispatch_shards(self, Q):
        """One padded ``[bucket, dim]`` batch → merged global top-k.
        The sequential rotation stages shard ``s+1`` (wrapping — the
        wrap primes the NEXT batch's opening shard during the
        inter-batch gap) while shard ``s`` scores."""
        if self._mesh():
            fn, arrays, idmaps = self._mesh_state
            return fn(arrays, idmaps, Q)
        S = self.cfg.n_shards
        do_prefetch = self.prefetch and S > 1
        bucket = int(Q.shape[0])
        flat_i, flat_s = [], []
        for s in range(S):
            r = self._shard_retriever(s)
            if do_prefetch:
                self._stage((s + 1) % S, bucket)
            ids, scores = r.plans.search(Q)
            gids = self._global_ids(s, ids)
            if self._tomb_mask is not None:
                # tombstone filtering in the shard merge: dead global
                # ids go to the out-of-corpus sentinel at -inf, exactly
                # like padding — merge_topk masks both the same way
                dead = jnp.take(self._tomb_mask, gids)
                gids = jnp.where(dead, jnp.int32(self.n_docs), gids)
                scores = jnp.where(dead, -jnp.inf, scores)
            flat_i.append(gids)
            flat_s.append(scores)
        flat_i = jnp.concatenate(flat_i, axis=1)
        flat_s = jnp.concatenate(flat_s, axis=1)
        if flat_i.shape[1] < self.cfg.k:  # k > n_docs: sentinel-pad
            pad = self.cfg.k - flat_i.shape[1]
            flat_i = jnp.pad(flat_i, ((0, 0), (0, pad)),
                             constant_values=self.n_docs)
            flat_s = jnp.pad(flat_s, ((0, 0), (0, pad)),
                             constant_values=-np.inf)
        return api.merge_topk(
            flat_i,
            flat_s,
            self.cfg.k,
            dedupe=self.impl.dedupe_merge,
            n_docs_global=self.n_docs,
        )

    def _mesh(self):
        """Build (once) and report the mesh path: a
        ``dist.sharding.index_mesh`` + ``api.make_sharded_search``
        driver over the stacked shard arrays, taken when the host has
        ≥ n_shards devices (unless ``use_mesh`` overrides).

        Live tombstones ride the mesh (DESIGN.md §11): dead docs are
        baked into the ID-MAP DATA — their local slot maps to the
        out-of-corpus sentinel, which the merge masks to ``-inf`` —
        and every shard's candidate budget is the uniform
        ``tombstone_budget`` (one ``k_local`` across devices: SPMD).
        Idmaps are runtime arguments, so mutating the tombstone SET
        never re-traces; only a changed budget (the tombstone COUNT
        moved) rebuilds the driver, against the cached stacked
        arrays."""
        if self.use_mesh is False or self.cfg.n_shards == 1:
            return None
        if self._mesh_state is not None:
            return self._mesh_state
        from repro.dist.sharding import index_mesh, tombstone_budget

        mesh = index_mesh(self.cfg.n_shards)
        if mesh is None:
            if self.use_mesh:
                raise ValueError(
                    f"use_mesh=True but only {jax.device_count()} "
                    f"device(s) for {self.cfg.n_shards} shards"
                )
            return None
        n_local = max(sh.n_docs for sh in self.shards)
        if self._mesh_static is None:
            # zero-padding to common shapes is safe: padding rows are
            # unreachable (in-shard ids never exceed the shard's own
            # sentinel) and zero rows score 0 → idmap sends them to the
            # out-of-corpus sentinel, which the merge masks
            self._mesh_static = {
                k: jnp.asarray(v)
                for k, v in layout.pad_stack(
                    [dict(sh.arrays) for sh in self.shards]
                ).items()
            }
        stacked = self._mesh_static
        idmaps = np.full(
            (self.cfg.n_shards, n_local + 1), self.n_docs, dtype=np.int32
        )
        for s, sh in enumerate(self.shards):
            idmaps[s, : sh.n_docs] = np.arange(
                sh.doc_lo, sh.doc_hi, dtype=np.int32
            )
            if self._shard_tombs[s]:
                dead = self._tombstones[
                    (self._tombstones >= sh.doc_lo)
                    & (self._tombstones < sh.doc_hi)
                ]
                idmaps[s, dead - sh.doc_lo] = self.n_docs
        fn = api.make_sharded_search(
            mesh, self.cfg, n_local, self.n_docs, self.value_scale,
            index_axis="model", query_axes=(),
            k_local=tombstone_budget(
                self.cfg.k, n_local, int(self._tombstones.size)
            ),
        )
        self._mesh_state = (fn, stacked, jnp.asarray(idmaps))
        return self._mesh_state

    # -- serving (the Retriever surface) --------------------------------
    def make_plans(self, buckets) -> ShardedPlanCache:
        return ShardedPlanCache(self, buckets)

    def search(self, Q, k: int | None = None):
        """[nq, dim] queries → global (ids [nq, k], scores [nq, k]),
        byte-identical to the unsharded oracle's top-k under exhaustive
        engine budgets (the shard-parity gate)."""
        ids, scores = self.plans.search(jnp.asarray(Q))
        if k is None or k == self.cfg.k:
            return ids, scores
        if k > self.cfg.k:
            raise ValueError(
                f"k={k} exceeds the static cfg.k={self.cfg.k}; rebuild "
                f"with a larger cfg.k"
            )
        return ids[:, :k], scores[:, :k]

    def pipeline(self, **kw) -> serve_pipeline.Pipeline:
        if kw:
            return serve_pipeline.Pipeline(self, **kw)
        if self._pipeline is None:
            self._pipeline = serve_pipeline.Pipeline(self)
        return self._pipeline

    def search_batch(self, Q):
        return self.pipeline().search_batch(Q)

    # -- artifact lifecycle ---------------------------------------------
    def save(self, path, *, compress: bool = False) -> pathlib.Path:
        """Write the sharded artifact tree::

            path/manifest.json           top-level shard manifest
            path/shard_0000/manifest.json  ordinary artifact manifest
            path/shard_0000/arrays.npz     ZIP_STORED → memory-mappable
            path/shard_0001/…

        Per-shard directories are ordinary artifacts (``open_retriever``
        on one serves that shard standalone); the top level carries the
        per-shard doc ranges and array specs. Shard payloads default to
        UNCOMPRESSED npz — the property ``mmap_npz`` needs."""
        path = pathlib.Path(path)
        path.mkdir(parents=True, exist_ok=True)
        entries = []
        for s, sh in enumerate(self.shards):
            host = {k: np.asarray(v) for k, v in sh.arrays.items()}
            sub = api.manifest_dict(
                self.cfg, host,
                n_docs=sh.n_docs, dim=self.dim,
                value_scale=self.value_scale, value_format=self.value_format,
                extra={"shard": s, "doc_lo": sh.doc_lo, "doc_hi": sh.doc_hi},
            )
            sdir = SHARD_DIR_FMT.format(s)
            api.write_artifact(path / sdir, sub, host, compress=compress)
            entries.append(
                {"dir": sdir, "doc_lo": sh.doc_lo, "doc_hi": sh.doc_hi,
                 "arrays": sub["arrays"]}
            )
        top = api.manifest_dict(
            self.cfg, {}, n_docs=self.n_docs, dim=self.dim,
            value_scale=self.value_scale, value_format=self.value_format,
        )
        del top["arrays"]
        top["format"] = api._SHARDED_FORMAT
        top["shards"] = entries
        with open(path / api._MANIFEST_FILE, "w", encoding="utf-8") as f:
            json.dump(top, f, indent=1, sort_keys=True)
        return path

    @classmethod
    def open(cls, path, manifest: Mapping | None = None) -> "ShardedRetriever":
        """Open a sharded artifact tree with every shard's arrays
        MEMORY-MAPPED (``mmap_npz``) — O(metadata): no array bytes are
        read until a shard is admitted to residency.

        Validates before serving, raising ``ArtifactError`` with an
        actionable message on: shard-count mismatch between the
        top-level and per-shard manifests, overlapping/gapped doc
        ranges, per-shard engine/codec/version skew, and truncated or
        compressed shard payloads — never a silent wrong answer."""
        path = pathlib.Path(path)
        if manifest is None:
            manifest = api.load_manifest(path)
        top_mf = path / api._MANIFEST_FILE
        if manifest.get("format") != api._SHARDED_FORMAT:
            raise ArtifactError(
                f"{top_mf} is not a {api._SHARDED_FORMAT} tree "
                f"(format={manifest.get('format')!r})"
            )
        api.check_manifest_names(manifest, top_mf)
        n_shards = int(manifest.get("n_shards", 0))
        entries = manifest.get("shards")
        if not isinstance(entries, list) or not entries:
            raise ArtifactError(f"sharded manifest {top_mf} lists no shards")
        if len(entries) != n_shards:
            raise ArtifactError(
                f"shard-count mismatch at {top_mf}: n_shards={n_shards} "
                f"but {len(entries)} shard entries listed — the tree is "
                f"inconsistent; rebuild it or restore the missing shards"
            )
        n_docs = int(manifest["n_docs"])
        cfg = api.cfg_from_manifest(manifest)
        shards, expect_lo = [], 0
        for s, e in enumerate(entries):
            lo, hi = int(e["doc_lo"]), int(e["doc_hi"])
            if lo != expect_lo or hi <= lo:
                raise ArtifactError(
                    f"shard {s} at {top_mf} covers docs [{lo}, {hi}) but "
                    f"the previous shard ended at {expect_lo}: ranges "
                    f"must tile [0, {n_docs}) contiguously — no gaps, no "
                    f"overlaps; rebuild the tree"
                )
            expect_lo = hi
            sdir = path / e["dir"]
            sub = api.load_manifest(sdir)
            sub_mf = sdir / api._MANIFEST_FILE
            if sub.get("format") != api._MANIFEST_FORMAT:
                raise ArtifactError(
                    f"{sub_mf} is not a shard artifact "
                    f"(format={sub.get('format')!r})"
                )
            api.check_manifest_names(sub, sub_mf)
            for key in ("engine", "codec", "value_format"):
                if sub.get(key) != manifest.get(key):
                    raise ArtifactError(
                        f"shard {s} {key}={sub.get(key)!r} disagrees with "
                        f"the top-level manifest's {manifest.get(key)!r} — "
                        f"mixed-build skew; rebuild the tree consistently"
                    )
            if int(sub.get("n_shards", 1)) != n_shards:
                raise ArtifactError(
                    f"shard-count mismatch: {sub_mf} says "
                    f"n_shards={sub.get('n_shards')}, top-level says "
                    f"{n_shards} — the shard belongs to a different "
                    f"tree; rebuild"
                )
            if (
                int(sub.get("doc_lo", lo)) != lo
                or int(sub.get("doc_hi", hi)) != hi
                or int(sub["n_docs"]) != hi - lo
            ):
                raise ArtifactError(
                    f"shard {s} doc range disagrees between {top_mf} "
                    f"([{lo}, {hi})) and {sub_mf} "
                    f"([{sub.get('doc_lo')}, {sub.get('doc_hi')}), "
                    f"n_docs={sub.get('n_docs')}); rebuild the tree"
                )
            arrays = mmap_npz(sdir / api._ARRAYS_FILE)
            api.check_array_spec(sub["arrays"], arrays, sdir / api._ARRAYS_FILE)
            shards.append(Shard(lo, hi, arrays))
        if expect_lo != n_docs:
            raise ArtifactError(
                f"shard ranges at {top_mf} end at doc {expect_lo} but the "
                f"corpus has {n_docs} docs — a tail shard is missing; "
                f"rebuild the tree"
            )
        return cls(
            cfg, shards,
            dim=int(manifest["dim"]),
            value_scale=float(manifest["value_scale"]),
            value_format=manifest["value_format"],
        )
