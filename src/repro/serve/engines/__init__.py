"""Built-in engine registrations for ``repro.serve.api`` (DESIGN.md §7).

Importing this package registers the three shipped engines — the
inverted-index ``seismic`` two-phase probe, the graph-based ``hnsw``
beam search, and the exact ``flat`` full scan (the recall oracle that
also proves the registry is open). ``api.get_engine`` imports it
lazily, so consumers never need to."""

from . import flat, hnsw, seismic  # noqa: F401

__all__ = ["seismic", "hnsw", "flat"]
