"""Seismic registry entry: batched, static-shape two-phase search
(TPU adaptation of Bruch et al.'s heap-and-early-exit engine).

The host-side reference (repro.core.seismic) has faithful heap
semantics but data-dependent control flow. TPUs want static shapes and
batches, so serving uses the standard two-phase static relaxation:

  phase 1  for each query: gather the blocks of its top-``cut``
           components (≤ ``block_budget``), score every summary
           (gather + FMA), take the top-``n_probe`` blocks — this
           replaces the heap_factor pruning test with a fixed probe
           budget (the Seismic papers' own batching trick);
  phase 2  gather the ≤ n_probe·block_size candidate documents, dedupe
           (sort by id, mask repeats), re-score *exactly* against the
           packed forward-index rows under any codec registered in
           core/layout.py — the paper's hot path — and take the
           global top-k.

``search_one`` is a *pure* function of (arrays, query) so the same
code serves the jit'd production path, the multi-pod dry-run
(ShapeDtypeStruct arrays via ``array_specs``), and the generic sharded
driver (``api.make_sharded_search``). A document's blocks scatter
across shards, so this engine declares ``dedupe_merge``.

Batched dispatch (DESIGN.md §8): each query probes its OWN block set,
so there is no shared candidate set to decode once — the pipeline's
bucketed plans compile the inherited ``EngineImpl.search_batch``
(``vmap(search_one)``), and under ``backend="pallas"`` the vmap
batching rule lifts the query axis into the rows-kernel grid, which
amortises the per-dispatch host overhead the bucket exists to kill.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import layout
from repro.core.scoring import score_candidate_rows
from repro.core.seismic import SeismicIndex, SeismicParams

from ..api import EngineImpl, RetrieverConfig, register_engine, row_array_specs

__all__ = ["SeismicEngine"]


@register_engine("seismic")
class SeismicEngine(EngineImpl):
    name = "seismic"
    dedupe_merge = True
    defaults = {
        # search-time (phase budgets)
        "cut": 8,  # query components probed
        "block_budget": 512,  # max candidate blocks per query (phase 1)
        "n_probe": 64,  # blocks exactly re-scored (phase 2)
        # build-time (host SeismicIndex, used by build/shard_build)
        "n_postings": 4000,
        "block_size": 64,
        "summary_mass": 0.5,
        "summary_scale": 1.0 / 32.0,
        "proj_dims": 1,
        "seed": 0,
    }

    # -- host-side build ------------------------------------------------
    def host_index(self, fwd, cfg: RetrieverConfig) -> SeismicIndex:
        p = self.params(cfg)
        return SeismicIndex.build(
            fwd,
            SeismicParams(
                n_postings=p["n_postings"],
                block_size=p["block_size"],
                summary_mass=p["summary_mass"],
                summary_scale=p["summary_scale"],
                proj_dims=p["proj_dims"],
                seed=p["seed"],
            ),
        )

    def build_arrays(self, fwd, cfg: RetrieverConfig):
        return self.arrays_from_index(self.host_index(fwd, cfg), cfg)

    def arrays_from_index(self, index: SeismicIndex, cfg: RetrieverConfig):
        """SeismicIndex → static engine arrays (numpy): inverted block
        ranges, padded summaries, block→doc lists, plus the shared
        packed row form for phase-2 rescoring."""
        fwd = index.fwd
        n_docs, real_blocks = fwd.n_docs, index.n_blocks
        # an all-empty doc range (a sharded-build corner) yields ZERO
        # blocks, which would zero-size the static search arrays on
        # axis 0; pad to one sentinel block — empty summary, no real
        # docs — that phase 1 can harmlessly gather
        n_blocks = max(real_blocks, 1)

        s_len = np.diff(index.summary_indptr)
        s_max = int(max(s_len.max(initial=1), 1))
        sum_comps = np.zeros((n_blocks, s_max), dtype=np.int32)
        sum_vals = np.zeros((n_blocks, s_max), dtype=np.float32)
        for b in range(real_blocks):
            s, e = int(index.summary_indptr[b]), int(index.summary_indptr[b + 1])
            sum_comps[b, : e - s] = index.summary_comps[s:e]
            sum_vals[b, : e - s] = (
                index.summary_vals[s:e].astype(np.float32) * index.params.summary_scale
            )

        b_len = np.diff(index.block_doc_indptr)
        bs_max = int(max(b_len.max(initial=1), 1))
        block_docs = np.full((n_blocks, bs_max), n_docs, dtype=np.int32)
        for b in range(real_blocks):
            s, e = int(index.block_doc_indptr[b]), int(index.block_doc_indptr[b + 1])
            block_docs[b, : e - s] = index.block_docs[s:e]

        arrays = {
            "cbs": index.comp_block_indptr[:-1].astype(np.int32),
            "cbl": np.diff(index.comp_block_indptr).astype(np.int32),
            "sum_comps": sum_comps,
            "sum_vals": sum_vals,
            "block_docs": block_docs,
        }
        arrays.update(layout.pack_rows(fwd, codec=cfg.codec, vq=cfg.vq).arrays())
        return arrays

    # -- serving --------------------------------------------------------
    def search_one(self, cfg: RetrieverConfig, n_docs: int, value_scale: float, arrays, q):
        """One dense query → (ids [k], scores [k]). Pure and static-shape.

        arrays: cbs/cbl [dim], sum_comps/sum_vals [n_blocks, s_max],
        block_docs [n_blocks, bs_max], plus the packed row form."""
        p = self.params(cfg)
        cut, block_budget, n_probe = p["cut"], p["block_budget"], p["n_probe"]
        # top-cut query components
        qv, qc = jax.lax.top_k(jnp.abs(q), cut)
        live = qv > 0
        # candidate blocks: fixed budget round-robin over the cut comps
        starts = arrays["cbs"][qc]  # [cut]
        lens = jnp.where(live, arrays["cbl"][qc], 0)
        per = block_budget // cut
        offs = jnp.arange(per)[None, :]  # [1, per]
        cand = starts[:, None] + offs  # [cut, per]
        valid = offs < lens[:, None]
        cand = jnp.where(valid, cand, -1).reshape(-1)  # [budget]

        # phase 1: summary upper bounds
        sc = jnp.take(arrays["sum_comps"], jnp.maximum(cand, 0), axis=0)
        sv = jnp.take(arrays["sum_vals"], jnp.maximum(cand, 0), axis=0)
        est = (jnp.take(q, sc, axis=0) * sv).sum(-1)
        est = jnp.where(cand >= 0, est, -jnp.inf)
        _, probe = jax.lax.top_k(est, n_probe)
        probe_blocks = jnp.take(cand, probe)

        # phase 2: gather candidate docs, dedupe, exact re-score
        docs = jnp.take(arrays["block_docs"], jnp.maximum(probe_blocks, 0), axis=0)
        docs = jnp.where((probe_blocks >= 0)[:, None], docs, n_docs).reshape(-1)
        docs = jnp.sort(docs)
        dup = jnp.concatenate([jnp.zeros(1, bool), docs[1:] == docs[:-1]])
        docs = jnp.where(dup, n_docs, docs)

        scores = score_candidate_rows(
            cfg.codec, arrays, docs, q, value_scale, backend=cfg.backend
        )
        scores = jnp.where(docs < n_docs, scores, -jnp.inf)
        top_s, idx = jax.lax.top_k(scores, cfg.k)
        return jnp.take(docs, idx), top_s

    def array_specs(
        self,
        cfg: RetrieverConfig,
        *,
        dim: int,
        n_docs: int,
        n_blocks: int,
        s_max: int,
        bs_max: int,
        l_max: int,
        d_max: int,
        value_dtype=jnp.float16,
    ):
        sds = jax.ShapeDtypeStruct
        arrays = {
            "cbs": sds((dim,), jnp.int32),
            "cbl": sds((dim,), jnp.int32),
            "sum_comps": sds((n_blocks, s_max), jnp.int32),
            "sum_vals": sds((n_blocks, s_max), jnp.float32),
            "block_docs": sds((n_blocks, bs_max), jnp.int32),
        }
        arrays.update(
            row_array_specs(
                cfg.codec, n_docs=n_docs, l_max=l_max, d_max=d_max,
                value_dtype=value_dtype, vq=cfg.vq,
            )
        )
        return arrays

    # -- sharded build --------------------------------------------------
    def shard_build(self, fwd, cfg: RetrieverConfig, n_shards: int):
        return self.shard_from_index(self.host_index(fwd, cfg), cfg, n_shards)

    def shard_from_index(self, index: SeismicIndex, cfg: RetrieverConfig, n_shards: int):
        """Partition a SeismicIndex into ``n_shards`` self-contained
        sub-indexes: blocks round-robin, documents by ownership (a doc
        goes to every shard holding one of its blocks — hence
        ``dedupe_merge``)."""
        A = self.arrays_from_index(index, cfg)
        n_docs = index.fwd.n_docs
        n_blocks = int(A["block_docs"].shape[0])

        shard_docs: list[np.ndarray] = []
        docs_local_max = 0
        for s in range(n_shards):
            blocks = np.arange(s, n_blocks, n_shards)
            docs = np.unique(A["block_docs"][blocks])
            docs = docs[docs < n_docs]
            shard_docs.append(docs)
            docs_local_max = max(docs_local_max, len(docs))

        dicts, idmaps = [], []
        row_keys = [k for k in A if k.endswith("_rows")]
        # shared (non-per-row) value-codec payload — the PQ codebook —
        # is copied verbatim into every shard (DESIGN.md §12)
        shared_vq = {
            k: A[k] for k in A
            if k.startswith("vq_") and not k.endswith("_rows")
        }
        for s in range(n_shards):
            blocks = np.arange(s, n_blocks, n_shards)
            docs = shard_docs[s]
            g2l = np.full(n_docs + 1, docs_local_max, dtype=np.int32)
            g2l[docs] = np.arange(len(docs), dtype=np.int32)
            # comp → local block ranges: blocks of comp c in this shard
            # are contiguous in the round-robin order
            cbs, cbl = A["cbs"], A["cbl"]
            lcbs = (cbs - s + n_shards - 1) // n_shards
            lcbl = (cbs + cbl - s + n_shards - 1) // n_shards - lcbs
            sub = {
                "cbs": lcbs.astype(np.int32),
                "cbl": np.maximum(lcbl, 0).astype(np.int32),
                "sum_comps": A["sum_comps"][blocks],
                "sum_vals": A["sum_vals"][blocks],
                "block_docs": g2l[A["block_docs"][blocks]],
            }
            pad_rows = np.concatenate(
                [docs, np.full(docs_local_max - len(docs) + 1, n_docs)]
            )
            for k in row_keys:
                sub[k] = A[k][pad_rows]
            sub.update(shared_vq)
            dicts.append(sub)
            idmap = np.full(docs_local_max + 1, n_docs, dtype=np.int32)
            idmap[: len(docs)] = docs
            idmaps.append(idmap)
        return dicts, idmaps, docs_local_max, {"block_docs": docs_local_max}
