"""Flat registry entry: exact full scan over the packed row form.

The trivial third engine (DESIGN.md §7): no pruning structure at all —
every query scores every document's row and takes the global top-k.
It exists for two reasons: it proves the ``register_engine`` registry
is open (an engine is just arrays + a pure ``search_one``), and it is
the *recall oracle* — its top-k under any codec is the exact answer
the approximate engines are measured against, computed on device
through the very same decode path they use.

O(N·L) per query, so serve it on small collections (tests, smoke
gates, truth generation) — that is its job.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import layout
from repro.core.forward_index import ForwardIndex
from repro.core.scoring import score_candidate_rows, score_candidate_rows_batch

from ..api import EngineImpl, RetrieverConfig, register_engine, row_array_specs

__all__ = ["FlatEngine"]


@register_engine("flat")
class FlatEngine(EngineImpl):
    name = "flat"
    dedupe_merge = False  # contiguous doc ranges are disjoint
    defaults: dict = {}  # nothing to tune — that is the point

    # -- host-side build ------------------------------------------------
    def build_arrays(self, fwd: ForwardIndex, cfg: RetrieverConfig):
        return layout.pack_rows(fwd, codec=cfg.codec, vq=cfg.vq).arrays()

    # -- serving --------------------------------------------------------
    def search_one(self, cfg: RetrieverConfig, n_docs: int, value_scale: float, arrays, q):
        """One dense query → (ids [k], scores [k]): score ALL rows."""
        docs = jnp.arange(arrays["nnz_rows"].shape[0], dtype=jnp.int32)
        scores = score_candidate_rows(
            cfg.codec, arrays, docs, q, value_scale, backend=cfg.backend
        )
        scores = jnp.where(docs < n_docs, scores, -jnp.inf)
        top_s, idx = jax.lax.top_k(scores, cfg.k)
        return jnp.take(docs, idx), top_s

    def search_batch(self, cfg: RetrieverConfig, n_docs: int, value_scale: float, arrays, Q):
        """Genuinely batched full scan (DESIGN.md §8): every query
        shares the one candidate set (all rows), so the pipeline's
        bucketed dispatch decodes each row ONCE and scores the whole
        resident query batch — ``score_candidate_rows_batch``, the
        kernel registry's ``rows_scores_batch`` under
        ``backend="pallas"``. Per-query results are bitwise those of
        ``vmap(search_one)`` (the parity suite)."""
        docs = jnp.arange(arrays["nnz_rows"].shape[0], dtype=jnp.int32)
        scores = score_candidate_rows_batch(
            cfg.codec, arrays, docs, Q, value_scale, backend=cfg.backend
        )
        scores = jnp.where(docs[None, :] < n_docs, scores, -jnp.inf)
        top_s, idx = jax.lax.top_k(scores, cfg.k)
        return jnp.take(docs, idx), top_s

    def array_specs(
        self,
        cfg: RetrieverConfig,
        *,
        n_docs: int,
        l_max: int,
        d_max: int,
        value_dtype=jnp.float16,
        **_ignored,
    ):
        return row_array_specs(
            cfg.codec, n_docs=n_docs, l_max=l_max, d_max=d_max,
            value_dtype=value_dtype, vq=cfg.vq,
        )

    # -- sharded build --------------------------------------------------
    def build_shard(self, fwd: ForwardIndex, cfg: RetrieverConfig, lo: int, hi: int):
        """One artifact shard (DESIGN.md §9): rows packed straight from
        the per-shard pack offsets (``pack_rows`` over the CSR slice,
        shard-local row ids) — no sub-index structure to rebuild, and
        row bytes identical to the same docs' rows in a monolithic
        pack at equal row capacity."""
        return layout.pack_rows(
            fwd, codec=cfg.codec, doc_range=(lo, hi), vq=cfg.vq
        ).arrays()

    def shard_build(self, fwd: ForwardIndex, cfg: RetrieverConfig, n_shards: int):
        """Contiguous doc ranges, rows padded to a common local size."""
        import numpy as np

        n = fwd.n_docs
        docs_local = (n + n_shards - 1) // n_shards
        dicts, idmaps = [], []
        for s in range(n_shards):
            lo, hi = s * docs_local, min((s + 1) * docs_local, n)
            sub = fwd.slice(lo, hi).padded(docs_local)
            dicts.append(layout.pack_rows(sub, codec=cfg.codec, vq=cfg.vq).arrays())
            idmap = np.full(docs_local + 1, n, dtype=np.int32)
            idmap[: hi - lo] = np.arange(lo, hi, dtype=np.int32)
            idmaps.append(idmap)
        return dicts, idmaps, docs_local, {}
