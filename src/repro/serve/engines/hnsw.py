"""HNSW registry entry: batched, static-shape beam search over the
graph built by ``repro.core.hnsw`` (DESIGN.md §5).

The hierarchy collapses to the base-layer fixed-degree adjacency
``adj [N+1, M]`` plus ``n_seeds`` query-independent entry hubs; the
heap becomes a fixed-width beam: each of ``iters`` ``lax.fori_loop``
steps expands the best not-yet-expanded beam node, gathers its M
neighbours, masks the already-seen ones with a visited bitmask
``[N+1]``, scores the rest exactly through the shared packed row form
(``scoring.score_candidate_rows`` — every codec registered in
core/layout.py works unmodified), and top-k-merges them back into the
beam. This is the paper's hot path on a graph access pattern: one row
decoded per visited node, no block reuse to amortise against.

Distribution (DESIGN.md §4): documents split into contiguous ranges,
one self-contained sub-graph per range; ranges are disjoint so the
generic merge needs no dedupe.

Batched dispatch (DESIGN.md §8): beam trajectories are query-private
(each query walks its own frontier), so the pipeline's bucketed plans
compile the inherited ``EngineImpl.search_batch``
(``vmap(search_one)``) — the win from micro-batching here is one
device dispatch per bucket instead of per query, not a shared decode.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import layout
from repro.core.forward_index import ForwardIndex
from repro.core.hnsw import HNSWIndex, HNSWParams
from repro.core.scoring import score_candidate_rows

from ..api import EngineImpl, RetrieverConfig, register_engine, row_array_specs

__all__ = ["HNSWEngine"]


@register_engine("hnsw")
class HNSWEngine(EngineImpl):
    name = "hnsw"
    dedupe_merge = False  # contiguous doc ranges are disjoint
    defaults = {
        # search-time (static beam)
        "beam": 64,  # beam width (the static ef)
        "iters": 64,  # nodes expanded (fori_loop trip count)
        "n_seeds": 8,  # query-independent entry hubs
        # build-time (host HNSWIndex)
        "m": 16,
        "m0": None,
        "ef_construction": 64,
        "seed": 0,
    }

    def params(self, cfg: RetrieverConfig):
        p = super().params(cfg)
        if p["n_seeds"] > p["beam"]:
            raise ValueError("n_seeds must not exceed beam width")
        return p

    # -- host-side build ------------------------------------------------
    def host_params(self, cfg: RetrieverConfig) -> HNSWParams:
        p = self.params(cfg)
        return HNSWParams(
            m=p["m"], m0=p["m0"], ef_construction=p["ef_construction"], seed=p["seed"]
        )

    def host_index(self, fwd, cfg: RetrieverConfig) -> HNSWIndex:
        return HNSWIndex.build(fwd, self.host_params(cfg))

    def build_arrays(self, fwd, cfg: RetrieverConfig):
        return self.arrays_from_index(self.host_index(fwd, cfg), cfg)

    def arrays_from_index(self, index: HNSWIndex, cfg: RetrieverConfig):
        p = self.params(cfg)
        arrays = {
            "adj": index.adjacency(0),
            "seeds": index.seed_nodes(p["n_seeds"]),
        }
        arrays.update(
            layout.pack_rows(index.fwd, codec=cfg.codec, vq=cfg.vq).arrays()
        )
        return arrays

    # -- serving --------------------------------------------------------
    def search_one(self, cfg: RetrieverConfig, n_docs: int, value_scale: float, arrays, q):
        """One dense query → (ids [k], scores [k]). Pure and static-shape.

        arrays: adj [N+1, M], seeds [n_seeds], plus the packed row form.
        Sentinel id ``n_docs`` gathers the all-zero row / all-sentinel
        adjacency row and scores −inf, so padding is self-absorbing."""
        p = self.params(cfg)
        beam, iters = p["beam"], p["iters"]

        def score_docs(docs):
            return score_candidate_rows(
                cfg.codec, arrays, docs, q, value_scale, backend=cfg.backend
            )

        seeds = arrays["seeds"]  # i32 [n_seeds], sentinel-padded
        live = seeds < n_docs
        ids = jnp.concatenate(
            [seeds, jnp.full((beam - seeds.shape[0],), n_docs, jnp.int32)]
        )
        scores = jnp.concatenate(
            [
                jnp.where(live, score_docs(seeds), -jnp.inf),
                jnp.full((beam - seeds.shape[0],), -jnp.inf),
            ]
        )
        expanded = ids >= n_docs  # sentinel slots never expand
        visited = jnp.zeros(n_docs + 1, bool).at[seeds].set(True)

        def body(_, carry):
            ids, scores, expanded, visited = carry
            # best not-yet-expanded beam node (−inf everywhere ⇒ harmless
            # re-pick of slot 0: its neighbours are all visited/sentinel)
            b = jnp.argmax(jnp.where(expanded, -jnp.inf, scores))
            v = ids[b]
            expanded = expanded.at[b].set(True)
            nbrs = jnp.take(arrays["adj"], v, axis=0)  # [M]
            fresh = (nbrs < n_docs) & ~visited[nbrs]
            nbrs = jnp.where(fresh, nbrs, n_docs)
            visited = visited.at[nbrs].set(True)
            ns = jnp.where(fresh, score_docs(nbrs), -jnp.inf)
            # top-k merge of beam ∪ neighbours (ids unique by visited-mask)
            all_ids = jnp.concatenate([ids, nbrs])
            all_s = jnp.concatenate([scores, ns])
            all_e = jnp.concatenate([expanded, ~fresh])
            top_s, idx = jax.lax.top_k(all_s, beam)
            return jnp.take(all_ids, idx), top_s, jnp.take(all_e, idx), visited

        ids, scores, _, _ = jax.lax.fori_loop(
            0, iters, body, (ids, scores, expanded, visited)
        )
        top_s, idx = jax.lax.top_k(scores, cfg.k)
        return jnp.take(ids, idx), top_s

    def array_specs(
        self,
        cfg: RetrieverConfig,
        *,
        n_docs: int,
        degree: int,
        l_max: int,
        d_max: int,
        value_dtype=jnp.float16,
    ):
        p = self.params(cfg)
        sds = jax.ShapeDtypeStruct
        arrays = {
            "adj": sds((n_docs + 1, degree), jnp.int32),
            "seeds": sds((p["n_seeds"],), jnp.int32),
        }
        arrays.update(
            row_array_specs(
                cfg.codec, n_docs=n_docs, l_max=l_max, d_max=d_max,
                value_dtype=value_dtype, vq=cfg.vq,
            )
        )
        return arrays

    # -- sharded build --------------------------------------------------
    def shard_build(self, fwd: ForwardIndex, cfg: RetrieverConfig, n_shards: int):
        """Split documents into contiguous ranges; build one
        self-contained sub-graph per range (range-LOCAL ids)."""
        p = self.params(cfg)
        hp = self.host_params(cfg)
        n = fwd.n_docs
        docs_local = (n + n_shards - 1) // n_shards
        dicts, idmaps = [], []
        for s in range(n_shards):
            lo, hi = s * docs_local, min((s + 1) * docs_local, n)
            sub = fwd.slice(lo, hi)
            n_real = sub.n_docs
            index = HNSWIndex.build(sub, hp)
            # embed the sub-graph into the padded local id space: rows
            # past n_real stay all-sentinel, unreachable by search
            adj = np.full(
                (docs_local + 1, hp.degree(0)), docs_local, dtype=np.int32
            )
            adj[:n_real] = index.adjacency(0, sentinel=docs_local)[:n_real]
            # tail padding: empty docs, so row arrays reach docs_local+1
            padded = sub.padded(docs_local)
            dicts.append(
                {
                    "adj": adj,
                    "seeds": index.seed_nodes(p["n_seeds"], sentinel=docs_local),
                    **layout.pack_rows(
                        padded, codec=cfg.codec, vq=cfg.vq
                    ).arrays(),
                }
            )
            idmap = np.full(docs_local + 1, n, dtype=np.int32)
            idmap[:n_real] = np.arange(lo, hi, dtype=np.int32)
            idmaps.append(idmap)
        return dicts, idmaps, docs_local, {"adj": docs_local, "seeds": docs_local}
