"""Synthetic MsMarco-statistics collections (no external data offline).

Generates learned-sparse-embedding collections whose first-order
statistics match the paper's two encoders (§3):

* **SPLADE**  — 119 nonzeros per document, 43 per query
* **LILSR**   — 387 nonzeros per document,  6 per query (inference-free,
  heavier document expansion — the paper's stress case for compression)

Realism knobs that matter to the paper's claims and are modelled here:

* **Zipfian component popularity** — vocabulary ids follow a power law,
  so d-gap distributions look like real posting data;
* **topical clustering** — documents mix a few latent topics, giving RGB
  a real co-occurrence structure to exploit and Seismic's geometric
  blocking something to cluster;
* **scrambled labels** — component ids are randomly relabelled so the
  *identity* ordering carries no locality (as with a real BPE vocab);
  RGB has to discover it (cf. §2 of the paper);
* **gamma-distributed activations** — positive, right-skewed values as
  produced by ReLU-style sparse encoders.

Queries are generated from the same topic mixture as a "focus" document,
so exact nearest neighbours are non-trivial and recall@k is meaningful.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.forward_index import ForwardIndex

__all__ = [
    "SyntheticConfig",
    "splade_config",
    "lilsr_config",
    "SparseCollection",
    "generate_collection",
    "densify",
]


@dataclasses.dataclass(frozen=True)
class SyntheticConfig:
    name: str
    dim: int = 30522
    n_docs: int = 20000
    n_queries: int = 100
    doc_nnz_mean: float = 119.0
    query_nnz_mean: float = 43.0
    n_topics: int = 64
    topic_concentration: float = 6.0  # boost of topic components over background
    zipf_a: float = 1.1  # component popularity power law
    value_shape: float = 2.0  # gamma shape for activations
    value_scale: float = 0.5
    seed: int = 0


def splade_config(n_docs: int = 20000, n_queries: int = 100, seed: int = 0) -> SyntheticConfig:
    return SyntheticConfig(
        name="splade",
        n_docs=n_docs,
        n_queries=n_queries,
        doc_nnz_mean=119.0,
        query_nnz_mean=43.0,
        seed=seed,
    )


def lilsr_config(n_docs: int = 20000, n_queries: int = 100, seed: int = 0) -> SyntheticConfig:
    return SyntheticConfig(
        name="lilsr",
        n_docs=n_docs,
        n_queries=n_queries,
        doc_nnz_mean=387.0,
        query_nnz_mean=6.0,
        seed=seed,
    )


@dataclasses.dataclass
class SparseCollection:
    config: SyntheticConfig
    fwd: ForwardIndex
    query_comps: list[np.ndarray]
    query_vals: list[np.ndarray]

    def query_dense(self, i: int) -> np.ndarray:
        q = np.zeros(self.config.dim, dtype=np.float32)
        q[self.query_comps[i]] = self.query_vals[i]
        return q

    @property
    def n_queries(self) -> int:
        return len(self.query_comps)


def densify(dim: int, comps: np.ndarray, vals: np.ndarray) -> np.ndarray:
    q = np.zeros(dim, dtype=np.float32)
    q[comps] = vals
    return q


def _topic_logits(cfg: SyntheticConfig, rng: np.random.Generator) -> tuple[np.ndarray, np.ndarray]:
    """Background Zipf log-weights + per-topic boosted component sets."""
    ranks = np.arange(1, cfg.dim + 1, dtype=np.float64)
    background = -cfg.zipf_a * np.log(ranks)  # popularity by rank
    topic_size = max(cfg.dim // cfg.n_topics, 8)
    topic_comps = np.stack(
        [rng.choice(cfg.dim, size=topic_size, replace=False) for _ in range(cfg.n_topics)]
    )
    return background.astype(np.float32), topic_comps


def _sample_rows(
    logits: np.ndarray, nnz: np.ndarray, rng: np.random.Generator
) -> list[np.ndarray]:
    """Gumbel top-k sampling without replacement, one row per logit row."""
    out = []
    g = rng.gumbel(size=logits.shape).astype(np.float32)
    keys = logits + g
    for i in range(logits.shape[0]):
        k = int(nnz[i])
        idx = np.argpartition(-keys[i], k)[:k]
        out.append(np.sort(idx).astype(np.uint32))
    return out


def generate_collection(
    cfg: SyntheticConfig, value_format: str = "f32", batch: int = 512
) -> SparseCollection:
    rng = np.random.default_rng(cfg.seed)
    background, topic_comps = _topic_logits(cfg, rng)
    # scrambled labels: identity order must carry no locality
    relabel = rng.permutation(cfg.dim).astype(np.uint32)

    def mixture_logits(n_rows: int, doc_topics: np.ndarray) -> np.ndarray:
        lg = np.tile(background, (n_rows, 1))
        for r in range(n_rows):
            for t in doc_topics[r]:
                lg[r, topic_comps[t]] += cfg.topic_concentration
        return lg

    docs: list[tuple[np.ndarray, np.ndarray]] = []
    doc_topic_sets = rng.integers(0, cfg.n_topics, size=(cfg.n_docs, 3))
    for lo in range(0, cfg.n_docs, batch):
        hi = min(lo + batch, cfg.n_docs)
        nnz = np.clip(
            rng.poisson(cfg.doc_nnz_mean, size=hi - lo), 4, cfg.dim // 4
        )
        lg = mixture_logits(hi - lo, doc_topic_sets[lo:hi])
        rows = _sample_rows(lg, nnz, rng)
        for comps in rows:
            vals = rng.gamma(cfg.value_shape, cfg.value_scale, size=len(comps)).astype(
                np.float32
            ) + np.float32(0.05)
            docs.append((np.sort(relabel[comps]), vals))

    # queries share topics with a focus document
    q_comps, q_vals = [], []
    focus = rng.integers(0, cfg.n_docs, size=cfg.n_queries)
    qnnz = np.clip(rng.poisson(cfg.query_nnz_mean, size=cfg.n_queries), 2, cfg.dim // 8)
    lg = mixture_logits(cfg.n_queries, doc_topic_sets[focus])
    rows = _sample_rows(lg, qnnz, rng)
    for comps in rows:
        vals = rng.gamma(cfg.value_shape, cfg.value_scale, size=len(comps)).astype(
            np.float32
        ) + np.float32(0.05)
        q_comps.append(np.sort(relabel[comps]))
        q_vals.append(vals)

    fwd = ForwardIndex.from_docs(docs, cfg.dim, value_format=value_format)
    return SparseCollection(config=cfg, fwd=fwd, query_comps=q_comps, query_vals=q_vals)
