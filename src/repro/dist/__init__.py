"""Distribution layer (DESIGN.md §4).

Three concerns, three modules:

* ``sharding``    — the family sharding RULES: pure functions from
  (config, mesh) to PartitionSpec pytrees, plus the NamedSharding
  plumbing every Cell uses. No jax transformations live here.
* ``collectives`` — hand-written shard_map collectives where jit
  auto-sharding is not enough: sequence-sharded flash decoding.
* ``compression`` — wire-format gradient compression (int8 + error
  feedback) for the pure-DP trainer.
"""

from . import collectives, compression, sharding

__all__ = ["sharding", "collectives", "compression"]
