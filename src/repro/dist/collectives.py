"""Hand-written shard_map collectives (DESIGN.md §4).

``flash_decode_shardmap`` is the sequence-sharded decode-attention step:
each device scores the query against its LOCAL slice of the KV cache,
keeps the flash-attention partial statistics (running max, denominator,
weighted accumulator), and the softmax is completed with one ``pmax``
and two ``psum``s over the sequence axes — O(B·H·dh) collective bytes
per step instead of gathering O(S) cache. This is the standard
flash-decoding decomposition (softmax is an associative reduction over
the key axis), so the result is bit-comparable to the local reference
``models.transformer._decode_attention_ref``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

__all__ = ["flash_decode_shardmap"]


def flash_decode_shardmap(
    mesh: Mesh,
    *,
    batch_axes: tuple[str, ...] = ("data",),
    seq_axes: tuple[str, ...] = ("model",),
):
    """fn(q [B,1,H,dh], k/v caches [B,S,Hk,dh], valid_len [B]) → [B,1,H,dh].

    The cache shards over ``seq_axes`` on S (and ``batch_axes`` on B);
    queries and outputs shard over ``batch_axes`` only. ``seq_axes`` may
    cover every mesh axis (the 500k-context layout, batch replicated)."""
    ba = tuple(batch_axes) or None
    sa = tuple(seq_axes)

    def local(q, k, v, valid_len):
        B, _, H, dh = q.shape
        S_local, Hk = k.shape[1], k.shape[2]
        G = H // Hk
        # global position of this shard's first key (axes major-to-minor,
        # matching how PartitionSpec splits the dimension)
        idx = jnp.int32(0)
        for a in sa:
            idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
        kpos = idx * S_local + jnp.arange(S_local)

        qg = q.reshape(B, Hk, G, dh)
        s = jnp.einsum("bhgd,bkhd->bhgk", qg, k).astype(jnp.float32)
        s = s / jnp.sqrt(jnp.float32(dh))
        mask = kpos[None, :] < valid_len[:, None]  # [B, S_local]
        s = jnp.where(mask[:, None, None, :], s, -1e30)

        m_local = s.max(axis=-1)  # [B, Hk, G]
        m = jax.lax.pmax(m_local, sa)
        p = jnp.exp(s - m[..., None])
        p = jnp.where(mask[:, None, None, :], p, 0.0)
        denom = jax.lax.psum(p.sum(axis=-1), sa)
        acc = jax.lax.psum(
            jnp.einsum("bhgk,bkhd->bhgd", p, v.astype(jnp.float32)), sa
        )
        out = acc / jnp.maximum(denom, 1e-30)[..., None]
        return out.reshape(B, 1, H, dh).astype(q.dtype)

    return jax.shard_map(
        local,
        mesh=mesh,
        in_specs=(
            P(ba, None, None, None),
            P(ba, sa, None, None),
            P(ba, sa, None, None),
            P(ba),
        ),
        out_specs=P(ba, None, None, None),
        check_vma=False,
    )
