"""Family sharding rules: (config, mesh) → PartitionSpec pytrees.

Single home for the placement policy referenced throughout
DESIGN.md §4:

* **LM** — 2-D "data × model": weights column/row-split over ``model``
  (Megatron TP) and, when ``fsdp`` is on, additionally split over the
  data axes on the non-TP dim for storage (ZeRO-3; the just-in-time
  gather back to TP layout happens inside the model via ``shard_hint``).
* **GNN** — parameters replicated (they are tiny), node/edge arrays
  sharded over the data axes.
* **RecSys** — embedding tables row-sharded over ``model`` (the only
  big tensors), dense towers replicated, batches over data.
* **KV caches** — batch over data + sequence over ``model`` for normal
  decode; sequence over EVERY axis for the 500k-context cell (feeds
  ``collectives.flash_decode_shardmap``).

Every rule degrades gracefully: an axis is only used when it divides
the dimension, so the same specs lower on the 8-device debug mesh, the
16×16 pod and the 2×16×16 multi-pod mesh without special-casing.
"""

from __future__ import annotations

import math

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

__all__ = [
    "data_axes",
    "to_shardings",
    "replicate",
    "index_mesh",
    "tombstone_budget",
    "lm_param_specs",
    "kv_cache_spec",
    "gnn_batch_spec",
    "recsys_param_specs",
    "recsys_batch_spec",
]


def data_axes(mesh: Mesh) -> tuple[str, ...]:
    """The data-parallel axes present on this mesh (pod-major)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def index_mesh(n_shards: int, devices=None) -> Mesh | None:
    """The serving mesh for shard-parallel search (DESIGN.md §9): one
    device per index shard on the ``model`` axis (``data`` is a
    size-1 placeholder so the standard 2-D specs apply), matching the
    ``make_sharded_search`` driver's ``index_axis="model"``.

    Returns ``None`` when the host has fewer than ``n_shards`` devices
    — the caller (``ShardedRetriever``) then falls back to the
    sequential out-of-core round-robin instead of a mesh."""
    import numpy as np

    devices = list(jax.devices()) if devices is None else list(devices)
    if n_shards < 1 or len(devices) < n_shards:
        return None
    return Mesh(
        np.asarray(devices[:n_shards]).reshape(1, n_shards),
        ("data", "model"),
    )


def tombstone_budget(k: int, n_local: int, n_tombstones: int) -> int:
    """Per-shard candidate budget under live tombstones
    (DESIGN.md §11): every shard surfaces ``k + n_tombstones``
    candidates (capped at its padded size) so ``k`` LIVE docs survive
    the merge's dead-doc mask even when every tombstoned doc outranks
    them. Uniform across shards by construction — ``shard_map`` bakes
    ONE ``k_local`` into the SPMD program, and byte-parity between the
    mesh and sequential paths requires identical per-shard candidate
    sets — so the budget (hence the trace) only changes when the
    tombstone COUNT changes, never with the set's contents."""
    if k < 1 or n_local < 1 or n_tombstones < 0:
        raise ValueError(
            f"invalid budget inputs: k={k}, n_local={n_local}, "
            f"n_tombstones={n_tombstones}"
        )
    return min(n_local, k + n_tombstones)


def to_shardings(mesh: Mesh, specs):
    """PartitionSpec pytree → NamedSharding pytree (specs are leaves)."""
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), specs, is_leaf=lambda x: isinstance(x, P)
    )


def replicate(tree):
    """A fully-replicated spec for every leaf of ``tree``."""
    return jax.tree.map(lambda _: P(), tree)


def _axis_size(mesh: Mesh, axes) -> int:
    return math.prod(mesh.shape[a] for a in axes)


def _fit(mesh: Mesh, entry, dim: int):
    """Keep a spec entry only when it divides the dimension."""
    if entry is None:
        return None
    axes = entry if isinstance(entry, tuple) else (entry,)
    axes = tuple(a for a in axes if a in mesh.axis_names)
    if not axes or dim % _axis_size(mesh, axes):
        return None
    return axes if len(axes) > 1 else axes[0]


def _spec(mesh: Mesh, shape, *entries):
    fitted = [_fit(mesh, e, d) for e, d in zip(entries, shape)]
    return P(*fitted)


# ---------------------------------------------------------------------------
# LM family
# ---------------------------------------------------------------------------


def lm_param_specs(cfg, mesh: Mesh, fsdp: bool = True):
    """Storage specs for every LM parameter (stacked-layer layout).

    TP over ``model`` on the contraction-free dim; FSDP over the data
    axes on the other dim when ``fsdp`` (train/prefill — decode turns it
    off so weights stay TP-resident)."""
    from repro.models import transformer as tf_m

    abs_params = jax.eval_shape(
        lambda k: tf_m.init_params(k, cfg), jax.random.PRNGKey(0)
    )
    da = data_axes(mesh)
    dsp = da if fsdp else None

    def one(path, leaf):
        name = jax.tree_util.keystr(path)
        shape = leaf.shape
        if leaf.ndim <= 1 or "norm" in name or "router" in name:
            return P()
        if "moe" in name:
            # stacked experts [L, E, d_in, d_out]: expert-parallel over
            # model, FSDP on d_in (gate/up) or d_out (down)
            if leaf.ndim == 4:
                if "w_down" in name:
                    return _spec(mesh, shape, None, "model", None, dsp)
                return _spec(mesh, shape, None, "model", dsp, None)
            if "shared_down" in name:  # [L, S·F, D]
                return _spec(mesh, shape, None, "model", dsp)
            return _spec(mesh, shape, None, dsp, "model")  # shared gate/up
        if "embed" in name:  # [V, D] — vocab-sharded over model
            return _spec(mesh, shape, "model", dsp)
        if "lm_head" in name:  # [D, V]
            return _spec(mesh, shape, dsp, "model")
        if "wo" in name or "w_down" in name:  # [L, X, D] row-parallel
            return _spec(mesh, shape, None, "model", dsp)
        # [L, D, X] column-parallel (wq/wk/wv/w_gate/w_up)
        return _spec(mesh, shape, None, dsp, "model")

    return jax.tree_util.tree_map_with_path(one, abs_params)


def kv_cache_spec(mesh: Mesh, *, batch: int, seq_shard: bool = False):
    """Specs for the [L, B, S, Hk, dh] KV cache dict.

    Normal decode: batch over data, sequence over ``model`` (matches
    ``flash_decode_shardmap(batch_axes=da, seq_axes=("model",))``).
    ``seq_shard`` (500k context): sequence over every axis, batch
    replicated."""
    da = data_axes(mesh)
    if seq_shard:
        spec = P(None, None, (*da, "model"), None, None)
    else:
        ba = _fit(mesh, da, batch)
        spec = P(None, ba, "model", None, None)
    return {"k": spec, "v": spec}


# ---------------------------------------------------------------------------
# GNN family
# ---------------------------------------------------------------------------


def gnn_batch_spec(mesh: Mesh) -> dict:
    """Node/edge arrays shard over the data axes; params are replicated
    by ``replicate`` (they are KBs)."""
    da = data_axes(mesh)
    return {
        "x": P(da, None),
        "edge_src": P(da),
        "edge_dst": P(da),
        "labels": P(da),
        "train_mask": P(da),
    }


# ---------------------------------------------------------------------------
# RecSys family
# ---------------------------------------------------------------------------


def recsys_param_specs(model_name: str, abs_params, mesh: Mesh):
    """Embedding tables row-shard over ``model`` (vocab dim); everything
    else (MLP towers, cross layers, heads) is small enough to replicate."""

    def one(path, leaf):
        name = jax.tree_util.keystr(path)
        if "embed" in name and leaf.ndim >= 1:
            return _spec(mesh, leaf.shape, "model", *([None] * (leaf.ndim - 1)))
        if "linear" in name and leaf.ndim == 1:  # deepfm first-order terms
            return _spec(mesh, leaf.shape, "model")
        return P()

    return jax.tree_util.tree_map_with_path(one, abs_params)


def recsys_batch_spec(model_name: str, mesh: Mesh) -> dict:
    da = data_axes(mesh)
    if model_name == "deepfm":
        return {"sparse": P(da, None), "label": P(da)}
    if model_name == "dcn-v2":
        return {"dense": P(da, None), "sparse": P(da, None), "label": P(da)}
    if model_name == "sasrec":
        return {
            "seq": P(da, None),
            "pos_label": P(da, None),
            "neg_label": P(da, None, None),
        }
    if model_name == "din":
        return {"hist": P(da, None), "target": P(da), "label": P(da)}
    raise KeyError(f"unknown recsys model {model_name!r}")
