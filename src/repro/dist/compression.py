"""Gradient wire compression for the pure-DP trainer.

``compressed_psum_mean`` implements int8 quantised gradient averaging
with error feedback (1-bit-Adam / PowerSGD lineage, the "1000-node
bandwidth trick" in train_step.py):

1. add the carried residual to the fresh gradient (error feedback);
2. per-leaf symmetric int8 quantisation (scale = max|x| / 127) — this is
   the tensor that crosses the interconnect, 4× smaller than f32;
3. the quantisation error becomes the next step's residual, so the
   compression bias telescopes away and convergence matches uncompressed
   SGD/Adam to first order;
4. ``pmean`` over the data axes of the dequantised tensor.

Must be called inside a shard_map over ``axes`` (it uses ``pmean``).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["compressed_psum_mean"]


def compressed_psum_mean(grads, residual, axes: tuple[str, ...]):
    """→ (mean_grads, new_residual); both trees match ``grads``."""

    def one(g, r):
        x = g.astype(jnp.float32) + r
        scale = jnp.maximum(jnp.max(jnp.abs(x)) / 127.0, 1e-12)
        q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
        deq = q.astype(jnp.float32) * scale
        return jax.lax.pmean(deq, axes), x - deq

    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = treedef.flatten_up_to(residual)
    outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    mean = treedef.unflatten([m for m, _ in outs])
    new_residual = treedef.unflatten([r for _, r in outs])
    return mean, new_residual
