"""Fault-tolerant checkpointing: msgpack + zstd/zlib, atomic, resharding-aware.

Layout (one directory per step)::

    <dir>/step_000123/
        meta.msgpack          tree structure, shapes, dtypes, metadata
        shard_p0.msgpack.zst  this process's leaf payloads
    <dir>/LATEST              text file naming the last *committed* step

Commit protocol: payloads are written to ``step_X.tmp/`` and the
directory is atomically renamed, then LATEST is atomically replaced
(write-to-temp + ``os.replace``) — a crash mid-save can never corrupt
the previous checkpoint, and restore always reads a complete step.

Elastic restore: leaves are saved as full (host-gathered) arrays with
their global shape; ``restore`` takes an optional ``shardings`` pytree
and ``jax.device_put``s each leaf to the *new* topology — restoring a
512-chip checkpoint onto a 256-chip mesh (or CPU) just works, which is
the rescale path in repro.train.elastic. Multi-host sharded saving
(process-local shard files, same meta) hooks in via ``process_index``.
"""

from __future__ import annotations

import os
import time
from typing import Any

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

try:  # zstandard is optional — stdlib zlib is the fallback wire format
    import zstandard
except ImportError:  # pragma: no cover - depends on environment
    zstandard = None

__all__ = ["save", "restore", "latest_step", "available_steps", "prune_old"]

_ZSTD_LEVEL = 3
_ZLIB_LEVEL = 6


def _compress(raw: bytes) -> bytes:
    """Self-describing payload: 1-byte codec tag + compressed bytes, so a
    checkpoint written with zstd restores on a zlib-only host and vice
    versa (the tag, not the environment, selects the decompressor)."""
    if zstandard is not None:
        return b"Z" + zstandard.ZstdCompressor(level=_ZSTD_LEVEL).compress(raw)
    import zlib

    return b"z" + zlib.compress(raw, _ZLIB_LEVEL)


def _decompress(payload: bytes) -> bytes:
    tag, body = payload[:1], payload[1:]
    if tag == b"Z":
        if zstandard is None:
            raise RuntimeError(
                "checkpoint was written with zstd but zstandard is not installed"
            )
        return zstandard.ZstdDecompressor().decompress(body)
    if tag == b"z":
        import zlib

        return zlib.decompress(body)
    if payload[:4] == b"\x28\xb5\x2f\xfd":  # legacy untagged zstd frame
        if zstandard is None:
            raise RuntimeError(
                "legacy zstd checkpoint but zstandard is not installed"
            )
        return zstandard.ZstdDecompressor().decompress(payload)
    raise ValueError(f"unknown checkpoint compression tag {tag!r}")


def _tree_paths(tree) -> list[tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [(jax.tree_util.keystr(path), leaf) for path, leaf in flat]


def save(
    directory: str,
    step: int,
    state: Any,
    *,
    metadata: dict | None = None,
    process_index: int = 0,
    keep_last: int | None = 3,
) -> str:
    """Write one atomic checkpoint; returns the committed path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)

    leaves = _tree_paths(state)
    meta = {
        "step": step,
        "time": time.time(),
        "metadata": metadata or {},
        "leaves": [
            {
                "path": path,
                "shape": list(np.shape(leaf)),
                "dtype": str(jnp.asarray(leaf).dtype),
            }
            for path, leaf in leaves
        ],
    }
    with open(os.path.join(tmp, "meta.msgpack"), "wb") as f:
        f.write(msgpack.packb(meta))

    payload = {}
    for path, leaf in leaves:
        arr = np.asarray(jax.device_get(leaf))
        payload[path] = _compress(arr.tobytes())
    with open(os.path.join(tmp, f"shard_p{process_index}.msgpack.zst"), "wb") as f:
        f.write(msgpack.packb(payload))

    os.replace(tmp, final)  # atomic commit of the step directory
    _write_latest(directory, step)
    if keep_last is not None:
        prune_old(directory, keep_last)
    return final


def _write_latest(directory: str, step: int) -> None:
    tmp = os.path.join(directory, "LATEST.tmp")
    with open(tmp, "w") as f:
        f.write(str(step))
    os.replace(tmp, os.path.join(directory, "LATEST"))


def latest_step(directory: str) -> int | None:
    try:
        with open(os.path.join(directory, "LATEST")) as f:
            return int(f.read().strip())
    except (FileNotFoundError, ValueError):
        return None


def available_steps(directory: str) -> list[int]:
    steps = []
    if not os.path.isdir(directory):
        return steps
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            try:
                steps.append(int(name[5:]))
            except ValueError:
                pass
    return sorted(steps)


def prune_old(directory: str, keep_last: int) -> None:
    import shutil

    steps = available_steps(directory)
    for s in steps[:-keep_last] if keep_last else []:
        shutil.rmtree(os.path.join(directory, f"step_{s:08d}"), ignore_errors=True)


def restore(
    directory: str,
    template: Any,
    *,
    step: int | None = None,
    shardings: Any | None = None,
    process_index: int = 0,
) -> tuple[Any, dict]:
    """Restore into the structure of ``template``.

    ``shardings`` (optional pytree of NamedSharding, same structure) puts
    every leaf onto the new topology — the elastic-rescale path."""
    step = latest_step(directory) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no committed checkpoint in {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "meta.msgpack"), "rb") as f:
        meta = msgpack.unpackb(f.read())
    with open(os.path.join(path, f"shard_p{process_index}.msgpack.zst"), "rb") as f:
        payload = msgpack.unpackb(f.read())
    info = {m["path"]: m for m in meta["leaves"]}

    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    shard_flat = (
        treedef.flatten_up_to(shardings) if shardings is not None else [None] * len(flat)
    )
    out = []
    for (kpath, leaf), sh in zip(flat, shard_flat):
        key = jax.tree_util.keystr(kpath)
        if key not in info:
            raise KeyError(f"checkpoint missing leaf {key}")
        m = info[key]
        arr = np.frombuffer(_decompress(payload[key]), dtype=m["dtype"]).reshape(
            m["shape"]
        )
        if sh is not None:
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jnp.asarray(arr))
    return treedef.unflatten(out), meta["metadata"] | {"step": meta["step"]}
