"""Train-step factory: microbatched grad accumulation, remat-aware,
optional compressed data-parallel all-reduce.

Two modes:

* ``make_train_step`` — jit auto-sharding mode. Loss closes over the
  model; gradients accumulate across microbatches inside a ``lax.scan``
  (grads stay resident, ONE reduction epilogue per step that XLA's
  latency-hiding scheduler overlaps with the last microbatch's
  backward); then the optimizer applies.
* ``make_dp_compressed_train_step`` — shard_map mode for pure-DP
  replicas: grads cross the interconnect int8-compressed with error
  feedback (repro.dist.compression), the 1000-node bandwidth trick.

Both return ``step_fn(state, batch) -> (state, metrics)`` with
``state = {"params", "opt", ...}`` so checkpointing sees one pytree.
"""

from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.dist.compression import compressed_psum_mean

__all__ = ["make_train_step", "make_dp_compressed_train_step", "init_train_state"]


def init_train_state(
    params,
    opt_init: Callable,
    *,
    mesh: Mesh | None = None,
    dp_axes: tuple[str, ...] | None = None,
):
    """state pytree; pass mesh+dp_axes to add the error-feedback residual
    (required by make_dp_compressed_train_step)."""
    state = {"params": params, "opt": opt_init(params)}
    if mesh is not None and dp_axes is not None:
        state["residual"] = init_dp_residual(params, mesh, dp_axes)
    return state


def _split_microbatches(batch, n: int):
    def split(x):
        b = x.shape[0]
        if b % n:
            raise ValueError(f"batch {b} not divisible by {n} microbatches")
        return x.reshape(n, b // n, *x.shape[1:])

    return jax.tree.map(split, batch)


def make_train_step(
    loss_fn: Callable,  # (params, batch) -> (scalar, metrics)
    opt_update: Callable,  # (grads, opt_state, params) -> (params, opt, metrics)
    *,
    microbatches: int = 1,
    donate: bool = True,
):
    def step(state, batch):
        params = state["params"]

        if microbatches == 1:
            (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        else:
            mb = _split_microbatches(batch, microbatches)

            def body(carry, mb_i):
                acc, loss_acc = carry
                (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb_i)
                acc = jax.tree.map(
                    lambda a, gi: a + gi.astype(jnp.float32), acc, g
                )
                return (acc, loss_acc + l), None

            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (grads, loss), _ = jax.lax.scan(body, (zero, jnp.float32(0.0)), mb)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss = loss / microbatches
            aux = {}

        new_params, new_opt, opt_metrics = opt_update(grads, state["opt"], params)
        metrics = {"loss": loss, **opt_metrics}
        if isinstance(aux, dict):
            metrics.update(
                {
                    k: v
                    for k, v in aux.items()
                    if hasattr(v, "ndim") and getattr(v, "ndim", 1) == 0
                }
            )
        return {"params": new_params, "opt": new_opt}, metrics

    return step


def make_dp_compressed_train_step(
    loss_fn: Callable,
    opt_update: Callable,
    mesh: Mesh,
    batch_spec,
    *,
    dp_axes: tuple[str, ...] = ("data",),
):
    """Pure-DP trainer with int8+EF compressed gradient all-reduce.

    params/opt are replicated over ``dp_axes`` (which should cover every
    mesh axis for pure DP); the batch is sharded per ``batch_spec``. The
    error-feedback residual is *device-local* state: it is stored with a
    leading ``[n_replicas]`` axis sharded over dp (one slot per replica)
    so shard_map neither reduces nor gathers it."""

    def local_step(params, opt, residual, batch):
        residual = jax.tree.map(lambda r: r[0], residual)  # drop replica axis
        (loss, _aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        mean_grads, new_residual = compressed_psum_mean(grads, residual, dp_axes)
        loss = jax.lax.pmean(loss, dp_axes)
        new_params, new_opt, opt_metrics = opt_update(mean_grads, opt, params)
        new_residual = jax.tree.map(lambda r: r[None], new_residual)
        return new_params, new_opt, new_residual, {"loss": loss, **opt_metrics}

    res_spec = P(dp_axes)
    sm = jax.jit(
        jax.shard_map(
            local_step,
            mesh=mesh,
            in_specs=(P(), P(), res_spec, batch_spec),
            out_specs=(P(), P(), res_spec, P()),
            check_vma=False,
        )
    )

    def step(state, batch):
        new_params, new_opt, new_res, metrics = sm(
            state["params"], state["opt"], state["residual"], batch
        )
        return {"params": new_params, "opt": new_opt, "residual": new_res}, metrics

    return step


def init_dp_residual(params, mesh: Mesh, dp_axes: tuple[str, ...] = ("data",)):
    """Residual with a leading [n_replicas] axis, sharded over dp."""
    n = 1
    for a in dp_axes:
        n *= mesh.shape[a]
    return jax.tree.map(lambda p: jnp.zeros((n, *p.shape), jnp.float32), params)
