"""Hand-rolled optimizers (no optax in the container): AdamW + Adafactor.

Both operate on plain pytrees and keep their state sharded exactly like
the params (the dry-run in/out shardings mirror the param specs), which
is what makes the 1T-param Kimi config fit: Adafactor's factored second
moment stores O(rows+cols) instead of O(rows·cols) per matrix and skips
first-moment state entirely by default.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

__all__ = [
    "OptimizerConfig",
    "warmup_cosine",
    "adamw_init",
    "adamw_update",
    "adafactor_init",
    "adafactor_update",
    "make_optimizer",
]


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"  # "adamw" | "adafactor"
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.01
    grad_clip: float = 1.0
    # adafactor specifics
    decay_rate: float = 0.8
    factored_min_dim: int = 128
    state_dtype: object = jnp.float32  # bf16 state halves optimizer HBM


def warmup_cosine(cfg: OptimizerConfig, step: jnp.ndarray) -> jnp.ndarray:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    return cfg.lr * warm * 0.5 * (1.0 + jnp.cos(jnp.pi * t))


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------


def adamw_init(params, cfg: OptimizerConfig):
    zeros = lambda p: jnp.zeros(p.shape, dtype=cfg.state_dtype)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adamw_update(grads, state, params, cfg: OptimizerConfig):
    step = state["step"] + 1
    lr = warmup_cosine(cfg, step)
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        m32, v32 = m.astype(jnp.float32), v.astype(jnp.float32)
        m_new = b1 * m32 + (1 - b1) * g
        v_new = b2 * v32 + (1 - b2) * g * g
        mh = m_new / bc1
        vh = v_new / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * delta
        return (
            p_new.astype(p.dtype),
            m_new.astype(m.dtype),
            v_new.astype(v.dtype),
        )

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"m": new_m, "v": new_v, "step": step}, {
        "lr": lr,
        "grad_norm": gnorm,
    }


# ---------------------------------------------------------------------------
# Adafactor (Shazeer & Stern, 2018) — for the 1T-param configs
# ---------------------------------------------------------------------------


def _factored(p, cfg: OptimizerConfig) -> bool:
    return p.ndim >= 2 and min(p.shape[-2:]) >= cfg.factored_min_dim


def adafactor_init(params, cfg: OptimizerConfig):
    def one(p):
        if _factored(p, cfg):
            return {
                "vr": jnp.zeros(p.shape[:-1], dtype=cfg.state_dtype),  # row
                "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], dtype=cfg.state_dtype),
            }
        return {"v": jnp.zeros(p.shape, dtype=cfg.state_dtype)}

    return {
        "second": jax.tree.map(one, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adafactor_update(grads, state, params, cfg: OptimizerConfig):
    step = state["step"] + 1
    lr = warmup_cosine(cfg, step)
    decay = 1.0 - (step.astype(jnp.float32) + 1.0) ** (-cfg.decay_rate)
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)

    def upd(p, g, s):
        g2 = g * g + 1e-30
        if "vr" in s:
            vr = decay * s["vr"].astype(jnp.float32) + (1 - decay) * g2.mean(axis=-1)
            vc = decay * s["vc"].astype(jnp.float32) + (1 - decay) * g2.mean(axis=-2)
            denom = jnp.maximum(vr.mean(axis=-1, keepdims=True), 1e-30)
            vhat = (vr[..., None] / denom[..., None]) * vc[..., None, :]
            update = g / jnp.sqrt(vhat + 1e-30)
            new_s = {"vr": vr.astype(s["vr"].dtype), "vc": vc.astype(s["vc"].dtype)}
        else:
            v = decay * s["v"].astype(jnp.float32) + (1 - decay) * g2
            update = g / jnp.sqrt(v + 1e-30)
            new_s = {"v": v.astype(s["v"].dtype)}
        # update clipping (RMS ≤ 1), per the paper
        rms = jnp.sqrt(jnp.mean(jnp.square(update)) + 1e-30)
        update = update / jnp.maximum(1.0, rms)
        p_new = p.astype(jnp.float32) - lr * update
        if p.ndim >= 2:
            p_new = p_new - lr * cfg.weight_decay * p.astype(jnp.float32)
        return p_new.astype(p.dtype), new_s

    leaves_p, treedef = jax.tree.flatten(params)
    leaves_g = treedef.flatten_up_to(grads)
    leaves_s = treedef.flatten_up_to(state["second"])
    pairs = [upd(p, g, s) for p, g, s in zip(leaves_p, leaves_g, leaves_s)]
    new_params = treedef.unflatten([t[0] for t in pairs])
    new_second = treedef.unflatten([t[1] for t in pairs])
    return new_params, {"second": new_second, "step": step}, {
        "lr": lr,
        "grad_norm": gnorm,
    }


def make_optimizer(cfg: OptimizerConfig) -> tuple[Callable, Callable]:
    if cfg.name == "adamw":
        return (lambda p: adamw_init(p, cfg)), (
            lambda g, s, p: adamw_update(g, s, p, cfg)
        )
    if cfg.name == "adafactor":
        return (lambda p: adafactor_init(p, cfg)), (
            lambda g, s, p: adafactor_update(g, s, p, cfg)
        )
    raise KeyError(cfg.name)
