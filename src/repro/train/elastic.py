"""Fault-tolerant, elastic training runner.

What "running on thousands of nodes" actually requires, and how it is
handled here:

* **Crash/restart** — every ``checkpoint_every`` steps the full train
  state is committed atomically (repro.train.checkpoint); ``Runner.run``
  wraps each step in a recovery loop: any exception triggers a restore
  of the last committed step and replay from there. A deterministic
  per-step data stream (``batch_fn(step)``) makes replay exact.
* **Elastic rescale** — restore takes a *new* mesh/shardings pytree:
  checkpoints store host-global arrays, so a job pre-empted on 512
  chips resumes on 256 (or on CPU for debugging) without conversion.
  ``Runner.rescale`` re-jits the step for the new topology.
* **Straggler mitigation** — TPU pods run SPMD-synchronous, so the
  per-step tail is handled by (a) fixed-shape work (no data-dependent
  step time — everything in this framework is static-shape by
  construction), (b) the backup-replica pattern at the scheduler level,
  and (c) bounded step deadlines: ``step_timeout_s`` aborts a wedged
  step (dead host, hung collective) and recovers through the restart
  path rather than blocking the fleet. On real deployments the deadline
  maps to Borg/Slurm health-checking + jax.distributed heartbeats; here
  it is enforced with a monotonic-clock check between steps.
* **Fault injection for tests** — ``FaultInjector`` raises at chosen
  steps so the recovery path is exercised in CI (tests/test_elastic.py).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax

from . import checkpoint

__all__ = ["FaultInjector", "RunnerConfig", "Runner"]


class FaultInjector:
    """Deterministically raise at given global steps (once each)."""

    def __init__(self, fail_at: tuple[int, ...] = ()):
        self.fail_at = set(fail_at)
        self.fired: set[int] = set()

    def maybe_fail(self, step: int) -> None:
        if step in self.fail_at and step not in self.fired:
            self.fired.add(step)
            raise RuntimeError(f"injected fault at step {step}")


@dataclasses.dataclass
class RunnerConfig:
    total_steps: int
    checkpoint_dir: str
    checkpoint_every: int = 50
    keep_last: int = 3
    max_restarts: int = 10
    step_timeout_s: float | None = None  # None → no deadline enforcement


class Runner:
    """Drives step_fn with checkpoint/restart/elastic-rescale semantics."""

    def __init__(
        self,
        cfg: RunnerConfig,
        step_fn: Callable,  # (state, batch) -> (state, metrics)
        batch_fn: Callable,  # (step) -> batch  (deterministic per step!)
        init_state: Any,
        *,
        shardings: Any | None = None,
        fault_injector: FaultInjector | None = None,
    ):
        self.cfg = cfg
        self.step_fn = step_fn
        self.batch_fn = batch_fn
        self.init_state = init_state
        self.shardings = shardings
        self.fault = fault_injector
        self.restarts = 0
        self.history: list[dict] = []

    # -- recovery ----------------------------------------------------------
    def _restore_or_init(self):
        last = checkpoint.latest_step(self.cfg.checkpoint_dir)
        if last is None:
            return self.init_state, 0
        state, meta = checkpoint.restore(
            self.cfg.checkpoint_dir, self.init_state, shardings=self.shardings
        )
        return state, int(meta["step"]) + 1

    def rescale(self, new_shardings: Any) -> None:
        """Adopt a new topology: subsequent restores device_put onto it."""
        self.shardings = new_shardings

    # -- main loop -----------------------------------------------------------
    def run(self) -> tuple[Any, list[dict]]:
        state, start = self._restore_or_init()
        step = start
        while step < self.cfg.total_steps:
            try:
                t0 = time.monotonic()
                if self.fault is not None:
                    self.fault.maybe_fail(step)
                batch = self.batch_fn(step)
                state, metrics = self.step_fn(state, batch)
                if self.cfg.step_timeout_s is not None:
                    jax.block_until_ready(metrics)
                    dt = time.monotonic() - t0
                    if dt > self.cfg.step_timeout_s:
                        raise TimeoutError(
                            f"step {step} exceeded deadline ({dt:.1f}s)"
                        )
                self.history.append(
                    {"step": step, **{k: float(v) for k, v in metrics.items()}}
                )
                if (step + 1) % self.cfg.checkpoint_every == 0 or step + 1 == self.cfg.total_steps:
                    checkpoint.save(
                        self.cfg.checkpoint_dir,
                        step,
                        state,
                        keep_last=self.cfg.keep_last,
                    )
                step += 1
            except Exception:
                self.restarts += 1
                if self.restarts > self.cfg.max_restarts:
                    raise
                state, step = self._restore_or_init()
        return state, self.history
