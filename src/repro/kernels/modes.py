"""Kernel execution modes (DESIGN.md §3, §7).

Every fused kernel entry point — the block-scan wrappers in ``ops.py``,
the rows kernels in ``rows_dot.py``, the registry ``KernelSet`` fields
and ``scoring.score_candidate_rows{,_batch}`` — takes one ``mode`` axis:

* ``"jnp"``             — the pure-jnp reference path (``scoring.py``);
* ``"pallas_interpret"`` — the Pallas kernels under ``interpret=True``:
  the Python-level emulator that validates kernel *semantics* (DMA
  ordering included) on any host, at emulator speed;
* ``"pallas_compiled"`` — the compiled tile program.  On a Mosaic-
  capable backend (TPU) this is the real ``pallas_call`` lowering —
  double-buffered HBM→VMEM DMA block scan, queries×tiles batched grids.
  On hosts without Mosaic (this container is CPU-only XLA) the SAME
  tile program is lowered through XLA instead — a jit'd ``lax.scan``
  over the identical lane-aligned tiles, so the working set stays
  cache-resident exactly where the TPU pipeline keeps it VMEM-resident
  — with a one-time warning.  Either way the caller gets genuinely
  compiled machine code, never the interpreter.

``mode=None`` (and the back-compat booleans: ``interpret=True`` ↦
``pallas_interpret``, ``interpret=False`` ↦ ``pallas_compiled``) resolve
via :func:`resolve_mode`; the None default picks the compiled path —
serving should never sit on the emulator by accident.
"""

from __future__ import annotations

import warnings

import jax

__all__ = [
    "MODES",
    "SCORING_BACKENDS",
    "mosaic_available",
    "resolve_mode",
    "resolve_lowering",
    "backend_mode",
]

#: kernel execution modes, the §7 knob axis
MODES = ("jnp", "pallas_interpret", "pallas_compiled")

#: values ``scoring.score_candidate_rows{,_batch}`` / RetrieverConfig
#: accept; "pallas" = auto (compiled when available — resolve_mode(None))
SCORING_BACKENDS = ("jnp", "pallas", "pallas_interpret", "pallas_compiled")


def mosaic_available() -> bool:
    """True when pallas_call(interpret=False) can target real Mosaic."""
    return jax.default_backend() == "tpu"


def resolve_mode(mode) -> str:
    """Normalise a mode spec to one of :data:`MODES`.

    Accepts a mode string, None (→ compiled; the serving default), or
    the pre-mode-axis booleans: ``True`` was "interpret the kernel"
    and ``False`` "compile it", so they map onto the two pallas modes.
    """
    if mode is None:
        return "pallas_compiled"
    if isinstance(mode, bool):
        return "pallas_interpret" if mode else "pallas_compiled"
    if mode not in MODES:
        raise ValueError(f"unknown kernel mode {mode!r}; have {list(MODES)}")
    return mode


#: emitted the warning about compiling through XLA already (warn once)
_XLA_FALLBACK_WARNED: set = set()


def resolve_lowering(mode) -> str:
    """Resolved mode → how the tile program actually executes:
    ``"interpret"`` | ``"mosaic"`` | ``"xla"`` (| ``"jnp"``).

    ``pallas_compiled`` without a Mosaic-capable backend lowers the tile
    program through XLA (see module docstring) and warns once.
    """
    mode = resolve_mode(mode)
    if mode == "jnp":
        return "jnp"
    if mode == "pallas_interpret":
        return "interpret"
    if mosaic_available():
        return "mosaic"
    if "xla" not in _XLA_FALLBACK_WARNED:
        _XLA_FALLBACK_WARNED.add("xla")
        warnings.warn(
            "mode='pallas_compiled' requested but no Mosaic-capable backend "
            f"is attached (jax backend: {jax.default_backend()!r}); lowering "
            "the tiled kernels through XLA instead — same tile program, "
            "compiled, without the VMEM DMA pipeline",
            RuntimeWarning,
            stacklevel=3,
        )
    return "xla"


def backend_mode(backend: str):
    """A scoring/Retriever ``backend`` value → the kernel ``mode`` to
    request (None = auto for the plain ``"pallas"`` spelling, which
    resolves to the compiled path without the explicit-request warning
    semantics changing)."""
    if backend not in SCORING_BACKENDS:
        raise ValueError(
            f"unknown scoring backend {backend!r}; have {list(SCORING_BACKENDS)}"
        )
    if backend == "jnp":
        return "jnp"
    return None if backend == "pallas" else backend
