"""Pallas TPU kernel: fused StreamVByte decode + gather + inner product.

StreamVByte (Lemire et al.) is the paper's headline general-purpose
codec: 2-bit controls, four gaps per control byte, 1–4 data bytes per
gap — full 32-bit gap range with byte-aligned decode. The TPU
adaptation keeps the same fusion discipline as ``dotvbyte_dot``:

  2-bit codes ──unpack──► per-value byte counts ──prefix-sum──► offsets
  offsets ──up-to-4 byte-gathers (masked by code)──► gaps
  gaps ──segmented cumsum──► components ──gather q──► qv ──FMA──► prod
  prod ──contiguous-fragment prefix-sum diff──► per-slot scores

Kernels are TILED (PR 6, ``tiles.py``): every step consumes ``R_TILE``
lane-aligned blocks.  The single-query scan runs the explicit
double-buffered HBM→VMEM DMA pipeline (:func:`tiles.dma_block_scan`);
the batched variant maps a queries×tiles grid
(:func:`tiles.grid_batch_scores`) so each decoded tile scores a
resident query tile (decode-once/score-many).  The ctrl stream is
lane-padded at pack time (``layout.LANE_MULTIPLE``); tile functions
slice it tight (``T // 4`` bytes) before decoding, and the data stream
keeps its 3-byte over-read pad so the 4-byte gather never reads out of
bounds.

``interpret=True`` validates the pipeline on any host; the XLA-compiled
lowering of the same tile program lives in ``ops.py``
(mode="pallas_compiled" off-TPU).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.scoring import decode_gaps_streamvbyte

from . import tiles

__all__ = [
    "streamvbyte_block_scores",
    "streamvbyte_block_scores_batch",
    "streamvbyte_block_scores_xla",
    "streamvbyte_block_scores_xla_batch",
]


def decode_vec(ctrl: jnp.ndarray, data: jnp.ndarray, T: int) -> jnp.ndarray:
    """One row's (ctrl [≥T/4] u8, data [DP] u8) → gaps i32 [T]; used by
    the rows-rescoring kernel (``rows_dot``)."""
    gaps = decode_gaps_streamvbyte(ctrl[None, : T // 4], data[None, :])
    return gaps[0]


def tile_gaps(ctrl: jnp.ndarray, data: jnp.ndarray, T: int) -> jnp.ndarray:
    """[R, ≥T/4] ctrl + [R, DP] data → gaps i32 [R, T] (lane padding
    sliced tight before the decode)."""
    return decode_gaps_streamvbyte(ctrl[:, : T // 4], data)


def _tile_fn(q, ctrl, data, seg, sp, sa, vals, *, scale: float):
    return tiles.tile_scores(q, tile_gaps(ctrl, data, seg.shape[-1]), seg, sp, sa, vals, scale)


def _tile_fn_batch(Q, ctrl, data, seg, sp, sa, vals, *, scale: float):
    return tiles.tile_scores_batch(Q, tile_gaps(ctrl, data, seg.shape[-1]), seg, sp, sa, vals, scale)


def _pad_block_streams(ctrl, data, seg, start_pos, start_abs, vals):
    pad = functools.partial(tiles.pad_axis, multiple=tiles.R_TILE, axis=0)
    return (
        pad(ctrl), pad(data), pad(seg, fill=-1), pad(start_pos), pad(start_abs), pad(vals),
    )


@functools.partial(jax.jit, static_argnames=("scale", "interpret"))
def streamvbyte_block_scores(
    q: jnp.ndarray,  # [vocab_pad] f32, vocab_pad % 128 == 0
    ctrl: jnp.ndarray,  # [B, ≥T/4] u8, lane-padded
    data: jnp.ndarray,  # [B, DP] u8, DP % 128 == 0, ≥ 3 over-read bytes
    seg: jnp.ndarray,  # [B, T] i32 (or i8, slim layout)
    start_pos: jnp.ndarray,  # [B, D] i32
    start_abs: jnp.ndarray,  # [B, D] i32
    vals: jnp.ndarray,  # [B, T] storage dtype
    *,
    scale: float = 1.0,
    interpret: bool = True,
) -> jnp.ndarray:
    """Per-block document scores [B, D] via the double-buffered DMA
    scan (combine with ``scatter_block_scores``)."""
    B = ctrl.shape[0]
    D = start_pos.shape[1]
    streams = _pad_block_streams(ctrl, data, seg, start_pos, start_abs, vals)
    out = tiles.dma_block_scan(
        functools.partial(_tile_fn, scale=scale), q, streams, D, interpret
    )
    return out[:B]


@functools.partial(jax.jit, static_argnames=("scale", "interpret"))
def streamvbyte_block_scores_batch(
    Q: jnp.ndarray,  # [nq, vocab_pad] f32
    ctrl: jnp.ndarray,
    data: jnp.ndarray,
    seg: jnp.ndarray,
    start_pos: jnp.ndarray,
    start_abs: jnp.ndarray,
    vals: jnp.ndarray,
    *,
    scale: float = 1.0,
    interpret: bool = True,
) -> jnp.ndarray:
    """[nq, B, D] per-block scores for a query batch: a queries×tiles
    grid, each block tile decoded once per query tile."""
    nq = Q.shape[0]
    B = ctrl.shape[0]
    D = start_pos.shape[1]
    Qp = tiles.pad_axis(Q, tiles.Q_TILE, axis=0)
    streams = _pad_block_streams(ctrl, data, seg, start_pos, start_abs, vals)
    out = tiles.grid_batch_scores(
        functools.partial(_tile_fn_batch, scale=scale), Qp, streams, D, interpret
    )
    return out[:nq, :B]


@functools.partial(jax.jit, static_argnames=("scale",))
def streamvbyte_block_scores_xla(
    q, ctrl, data, seg, start_pos, start_abs, vals, *, scale: float = 1.0
):
    """The same tile program lowered through XLA — mode="pallas_compiled"
    off-TPU."""
    B = ctrl.shape[0]
    D = start_pos.shape[1]
    streams = _pad_block_streams(ctrl, data, seg, start_pos, start_abs, vals)
    return tiles.xla_block_scores(
        functools.partial(_tile_fn, scale=scale), q, streams, D
    )[:B]


@functools.partial(jax.jit, static_argnames=("scale",))
def streamvbyte_block_scores_xla_batch(
    Q, ctrl, data, seg, start_pos, start_abs, vals, *, scale: float = 1.0
):
    """XLA lowering of the batched tile program → [nq, B, D]."""
    B = ctrl.shape[0]
    D = start_pos.shape[1]
    streams = _pad_block_streams(ctrl, data, seg, start_pos, start_abs, vals)
    return tiles.xla_block_scores_batch(
        functools.partial(_tile_fn_batch, scale=scale), Q, streams, D
    )[:, :B]
