"""Pallas TPU kernel: fused StreamVByte decode + gather + inner product.

StreamVByte (Lemire et al.) is the paper's headline general-purpose
codec: 2-bit controls, four gaps per control byte, 1–4 data bytes per
gap — full 32-bit gap range with byte-aligned decode. The TPU
adaptation keeps the same fusion discipline as ``dotvbyte_dot``:

  2-bit codes ──unpack──► per-value byte counts ──prefix-sum──► offsets
  offsets ──up-to-4 byte-gathers (masked by code)──► gaps
  gaps ──segmented cumsum──► components ──gather q──► qv ──FMA──► prod
  prod ──one-hot MXU matmul──► per-block document scores

Everything for one packed block lives in VMEM for one grid step;
decoded gaps/components never touch HBM. The batched variant decodes
each block ONCE and scores the whole VMEM-resident query batch against
it (decode-once-score-many, EXPERIMENTS.md §Perf opt3 — the fused
analogue).

Grid: one step per packed block; block shapes are (1, X) rows of the
packed arrays (T % 128 == 0 ⇒ T/4 % 32 == 0). The data stream carries
a 3-byte over-read pad (layout ``_byte_scatter``) so the 4-byte gather
never reads out of bounds.

Validated against ``repro.kernels.ref`` in interpret mode (CPU-only
container); like DotVByte, the data-dependent byte gather is the op to
watch under real Mosaic lowering (EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["streamvbyte_block_scores", "streamvbyte_block_scores_batch"]


def _decode(ctrl_ref, data_ref):
    """One block's (ctrl, data) refs → gaps i32 [T]."""
    T4 = ctrl_ref.shape[1]
    T = T4 * 4
    ctrl = ctrl_ref[0, :].astype(jnp.int32)  # [T/4]
    codes = (ctrl[:, None] >> (2 * jax.lax.broadcasted_iota(jnp.int32, (1, 4), 1))) & 0x3
    codes = codes.reshape(T)  # quad-local value i ↔ bits 2i..2i+1
    lens = codes + 1
    ends = jnp.cumsum(lens)
    starts = ends - lens
    data = data_ref[0, :].astype(jnp.int32)  # [DP], ≥ 3-byte over-read
    gaps = jnp.take(data, starts, axis=0)
    gaps = gaps | (jnp.take(data, starts + 1, axis=0) * (codes >= 1)) << 8
    gaps = gaps | (jnp.take(data, starts + 2, axis=0) * (codes >= 2)) << 16
    gaps = gaps | (jnp.take(data, starts + 3, axis=0) * (codes >= 3)) << 24
    return gaps


def _rebase(gaps, seg_ref, sp_ref, sa_ref, D):
    """Gaps → absolute components via the out-of-band block absolutes."""
    seg = seg_ref[0, :].astype(jnp.int32)  # i8 in the slim layout
    t = jnp.cumsum(gaps)
    segc = jnp.clip(seg, 0, D - 1)
    tp = jnp.take(t, sp_ref[0, :], axis=0)
    comp = jnp.where(seg >= 0, jnp.take(sa_ref[0, :], segc) + t - jnp.take(tp, segc), 0)
    return seg, comp


def _kernel(q_ref, ctrl_ref, data_ref, seg_ref, sp_ref, sa_ref, vals_ref, out_ref, *, scale: float):
    T = ctrl_ref.shape[1] * 4
    D = sp_ref.shape[1]
    gaps = _decode(ctrl_ref, data_ref)
    seg, comp = _rebase(gaps, seg_ref, sp_ref, sa_ref, D)
    q = q_ref[0, :]
    qv = jnp.take(q, comp, axis=0)
    vals = vals_ref[0, :].astype(jnp.float32) * jnp.float32(scale)
    prod = qv * vals * (seg >= 0).astype(jnp.float32)  # [T]
    onehot = (seg[:, None] == jax.lax.broadcasted_iota(jnp.int32, (T, D), 1)).astype(
        jnp.float32
    )
    out_ref[0, :] = jnp.dot(prod[None, :], onehot, preferred_element_type=jnp.float32)[0]


def _kernel_batch(q_ref, ctrl_ref, data_ref, seg_ref, sp_ref, sa_ref, vals_ref, out_ref, *, scale: float):
    """Decode ONCE per block, score every VMEM-resident query against it."""
    T = ctrl_ref.shape[1] * 4
    D = sp_ref.shape[1]
    gaps = _decode(ctrl_ref, data_ref)
    seg, comp = _rebase(gaps, seg_ref, sp_ref, sa_ref, D)
    Q = q_ref[...]  # [nq, V] resident across the whole grid
    vals = vals_ref[0, :].astype(jnp.float32) * jnp.float32(scale)
    w = vals * (seg >= 0).astype(jnp.float32)
    qv = jnp.take(Q, comp, axis=1)  # [nq, T]
    prod = qv * w[None, :]
    onehot = (seg[:, None] == jax.lax.broadcasted_iota(jnp.int32, (T, D), 1)).astype(
        jnp.float32
    )
    out_ref[0] = jnp.dot(prod, onehot, preferred_element_type=jnp.float32)  # [nq, D]


@functools.partial(jax.jit, static_argnames=("scale", "interpret"))
def streamvbyte_block_scores(
    q: jnp.ndarray,  # [vocab_pad] f32, vocab_pad % 128 == 0
    ctrl: jnp.ndarray,  # [B, T/4] u8
    data: jnp.ndarray,  # [B, DP] u8, DP % 128 == 0, ≥ 3 over-read bytes
    seg: jnp.ndarray,  # [B, T] i32 (or i8, slim layout)
    start_pos: jnp.ndarray,  # [B, D] i32
    start_abs: jnp.ndarray,  # [B, D] i32
    vals: jnp.ndarray,  # [B, T] storage dtype
    *,
    scale: float = 1.0,
    interpret: bool = True,
) -> jnp.ndarray:
    """Per-block document scores [B, D] (combine with scatter_block_scores)."""
    B, T4 = ctrl.shape
    T = T4 * 4
    D = start_pos.shape[1]
    DP = data.shape[1]
    V = q.shape[0]
    row = lambda width: pl.BlockSpec((1, width), lambda b: (b, 0))
    return pl.pallas_call(
        functools.partial(_kernel, scale=scale),
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, V), lambda b: (0, 0)),  # q resident across grid
            row(T4),
            row(DP),
            row(T),
            row(D),
            row(D),
            row(T),
        ],
        out_specs=row(D),
        out_shape=jax.ShapeDtypeStruct((B, D), jnp.float32),
        interpret=interpret,
    )(q[None, :], ctrl, data, seg, start_pos, start_abs, vals)


@functools.partial(jax.jit, static_argnames=("scale", "interpret"))
def streamvbyte_block_scores_batch(
    Q: jnp.ndarray,  # [nq, vocab_pad] f32
    ctrl: jnp.ndarray,
    data: jnp.ndarray,
    seg: jnp.ndarray,
    start_pos: jnp.ndarray,
    start_abs: jnp.ndarray,
    vals: jnp.ndarray,
    *,
    scale: float = 1.0,
    interpret: bool = True,
) -> jnp.ndarray:
    """[B, nq, D] per-block scores for a query batch (decode once/block)."""
    B, T4 = ctrl.shape
    T = T4 * 4
    D = start_pos.shape[1]
    DP = data.shape[1]
    nq, V = Q.shape
    row = lambda width: pl.BlockSpec((1, width), lambda b: (b, 0))
    return pl.pallas_call(
        functools.partial(_kernel_batch, scale=scale),
        grid=(B,),
        in_specs=[
            pl.BlockSpec((nq, V), lambda b: (0, 0)),
            row(T4),
            row(DP),
            row(T),
            row(D),
            row(D),
            row(T),
        ],
        out_specs=pl.BlockSpec((1, nq, D), lambda b: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, nq, D), jnp.float32),
        interpret=interpret,
    )(Q, ctrl, data, seg, start_pos, start_abs, vals)
