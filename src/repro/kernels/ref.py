"""Pure-jnp oracles for the Pallas kernels (per-kernel allclose targets).

Each ``*_block_scores_ref`` mirrors the corresponding kernel's contract
exactly — same inputs, same [B, D] output — built from the shared
decode/score primitives in ``repro.core.scoring`` plus the same one-hot
reduction the kernels run on the MXU.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.scoring import (
    block_products,
    components_from_gaps,
    decode_gaps_bitpack,
    decode_gaps_dotvbyte,
    decode_gaps_streamvbyte,
    dequantise_values,
)

__all__ = [
    "dotvbyte_block_scores_ref",
    "streamvbyte_block_scores_ref",
    "bitpack_block_scores_ref",
]


def _onehot_reduce(prod: jnp.ndarray, seg: jnp.ndarray, D: int) -> jnp.ndarray:
    onehot = (seg[:, :, None] == jnp.arange(D)[None, None, :]).astype(jnp.float32)
    return jnp.einsum("bt,btd->bd", prod, onehot)


@jax.jit
def dotvbyte_block_scores_ref(q, ctrl, data, seg, start_pos, start_abs, vals, scale=1.0):
    gaps = decode_gaps_dotvbyte(ctrl[:, : seg.shape[1] // 8], data)
    comps = components_from_gaps(gaps, seg, start_pos, start_abs)
    prod = block_products(q, comps, dequantise_values(vals, scale), seg)
    return _onehot_reduce(prod, seg, start_pos.shape[1])


@jax.jit
def streamvbyte_block_scores_ref(q, ctrl, data, seg, start_pos, start_abs, vals, scale=1.0):
    gaps = decode_gaps_streamvbyte(ctrl[:, : seg.shape[1] // 4], data)
    comps = components_from_gaps(gaps, seg, start_pos, start_abs)
    prod = block_products(q, comps, dequantise_values(vals, scale), seg)
    return _onehot_reduce(prod, seg, start_pos.shape[1])


@jax.jit
def bitpack_block_scores_ref(q, words, widths, seg, start_pos, start_abs, vals, scale=1.0):
    gaps = decode_gaps_bitpack(words, widths, seg.shape[1])
    comps = components_from_gaps(gaps, seg, start_pos, start_abs)
    prod = block_products(q, comps, dequantise_values(vals, scale), seg)
    return _onehot_reduce(prod, seg, start_pos.shape[1])
