"""Pallas TPU kernel: fused DotVByte decode + gather + inner product.

The paper's DotVByte (§2.2) decodes 8 components per ``_mm_shuffle_epi8``
and keeps the whole decode→gather→FMA chain in SIMD registers. The TPU
adaptation (DESIGN.md §3) keeps the fusion but restructures the decode:

  control bits ──unpack──► per-value byte counts ──prefix-sum──► offsets
  offsets ──dual byte-gather──► gaps ──segmented cumsum──► components
  components ──gather q (VMEM-resident)──► qv ──FMA vals──► products
  products ──one-hot MXU matmul──► per-block document scores

Everything happens on one VMEM-resident block per grid step; decoded
components never touch HBM (the paper's "no intermediate buffer"
property). The query is densified once and stays in VMEM across the
whole grid (vocab ≤ 2¹⁶ ⇒ ≤ 256 KB f32 ≪ 16 MB VMEM).

Grid: one step per packed block. Block shapes are (1, X) rows of the
packed arrays — lane-aligned because T % 128 == 0, T/8 % 8 == 0.

Validated against ``repro.kernels.ref`` in interpret mode (this container
is CPU-only); the data-dependent byte gather is the op to watch when
lowering on real Mosaic (see EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["dotvbyte_block_scores", "dotvbyte_block_scores_batch"]


def _decode(ctrl_ref, data_ref):
    """One row's (ctrl, data) refs → gaps i32 [T]: control bits → byte
    offsets (exclusive prefix sum = the "scroll" amounts) → dual byte
    gather. Shared by the block kernels here and ``rows_dot``."""
    T8 = ctrl_ref.shape[1]
    T = T8 * 8
    ctrl = ctrl_ref[0, :].astype(jnp.int32)  # [T/8]
    bits = (ctrl[:, None] >> jax.lax.broadcasted_iota(jnp.int32, (1, 8), 1)) & 1
    bits = bits.reshape(T)  # LSB-first, one bit per value
    lens = bits + 1
    ends = jnp.cumsum(lens)
    starts = ends - lens
    data = data_ref[0, :].astype(jnp.int32)  # [DP]
    lo = jnp.take(data, starts, axis=0)
    hi = jnp.take(data, starts + 1, axis=0) * bits
    return lo + (hi << 8)


def _kernel(q_ref, ctrl_ref, data_ref, seg_ref, sp_ref, sa_ref, vals_ref, out_ref, *, scale: float):
    T8 = ctrl_ref.shape[1]
    T = T8 * 8
    D = sp_ref.shape[1]
    gaps = _decode(ctrl_ref, data_ref)

    # --- segmented rebase: gaps → absolute components --------------------
    seg = seg_ref[0, :].astype(jnp.int32)  # [T] (i8 in the slim layout)
    t = jnp.cumsum(gaps)
    segc = jnp.clip(seg, 0, D - 1)
    tp = jnp.take(t, sp_ref[0, :], axis=0)  # [D] cumsum at fragment starts
    comp = jnp.where(seg >= 0, jnp.take(sa_ref[0, :], segc) + t - jnp.take(tp, segc), 0)

    # --- fused dot: gather query, FMA, one-hot reduce on the MXU ---------
    q = q_ref[0, :]
    qv = jnp.take(q, comp, axis=0)
    vals = vals_ref[0, :].astype(jnp.float32) * jnp.float32(scale)
    prod = qv * vals * (seg >= 0).astype(jnp.float32)  # [T]
    onehot = (seg[:, None] == jax.lax.broadcasted_iota(jnp.int32, (T, D), 1)).astype(
        jnp.float32
    )
    out_ref[0, :] = jnp.dot(prod[None, :], onehot, preferred_element_type=jnp.float32)[0]


def _kernel_batch(q_ref, ctrl_ref, data_ref, seg_ref, sp_ref, sa_ref, vals_ref, out_ref, *, scale: float):
    """Batched-query variant: decode ONCE per block, score every query
    against it in VMEM (§Perf opt4 — the scan's decode and intermediates
    never touch HBM; per-step HBM traffic = index payload + Q + scores)."""
    T8 = ctrl_ref.shape[1]
    T = T8 * 8
    D = sp_ref.shape[1]
    gaps = _decode(ctrl_ref, data_ref)
    seg = seg_ref[0, :].astype(jnp.int32)
    t = jnp.cumsum(gaps)
    segc = jnp.clip(seg, 0, D - 1)
    tp = jnp.take(t, sp_ref[0, :], axis=0)
    comp = jnp.where(seg >= 0, jnp.take(sa_ref[0, :], segc) + t - jnp.take(tp, segc), 0)

    Q = q_ref[...]  # [nq, V] resident in VMEM across the whole grid
    vals = vals_ref[0, :].astype(jnp.float32) * jnp.float32(scale)
    w = vals * (seg >= 0).astype(jnp.float32)
    qv = jnp.take(Q, comp, axis=1)  # [nq, T]
    prod = qv * w[None, :]
    onehot = (seg[:, None] == jax.lax.broadcasted_iota(jnp.int32, (T, D), 1)).astype(
        jnp.float32
    )
    out_ref[0] = jnp.dot(prod, onehot, preferred_element_type=jnp.float32)  # [nq, D]


@functools.partial(jax.jit, static_argnames=("scale", "interpret"))
def dotvbyte_block_scores_batch(
    Q: jnp.ndarray,  # [nq, vocab_pad] f32
    ctrl: jnp.ndarray,
    data: jnp.ndarray,
    seg: jnp.ndarray,
    start_pos: jnp.ndarray,
    start_abs: jnp.ndarray,
    vals: jnp.ndarray,
    *,
    scale: float = 1.0,
    interpret: bool = True,
) -> jnp.ndarray:
    """[B, nq, D] per-block scores for a query batch."""
    B, T8 = ctrl.shape
    T = T8 * 8
    D = start_pos.shape[1]
    DP = data.shape[1]
    nq, V = Q.shape
    row = lambda width: pl.BlockSpec((1, width), lambda b: (b, 0))
    return pl.pallas_call(
        functools.partial(_kernel_batch, scale=scale),
        grid=(B,),
        in_specs=[
            pl.BlockSpec((nq, V), lambda b: (0, 0)),
            row(T8),
            row(DP),
            row(T),
            row(D),
            row(D),
            row(T),
        ],
        out_specs=pl.BlockSpec((1, nq, D), lambda b: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, nq, D), jnp.float32),
        interpret=interpret,
    )(Q, ctrl, data, seg, start_pos, start_abs, vals)


@functools.partial(jax.jit, static_argnames=("scale", "interpret"))
def dotvbyte_block_scores(
    q: jnp.ndarray,  # [vocab_pad] f32, vocab_pad % 128 == 0
    ctrl: jnp.ndarray,  # [B, T/8] u8
    data: jnp.ndarray,  # [B, DP] u8, DP % 128 == 0, ≥ 1 over-read byte
    seg: jnp.ndarray,  # [B, T] i32
    start_pos: jnp.ndarray,  # [B, D] i32
    start_abs: jnp.ndarray,  # [B, D] i32
    vals: jnp.ndarray,  # [B, T] storage dtype
    *,
    scale: float = 1.0,
    interpret: bool = True,
) -> jnp.ndarray:
    """Per-block document scores [B, D] (combine with scatter_block_scores)."""
    B, T8 = ctrl.shape
    T = T8 * 8
    D = start_pos.shape[1]
    DP = data.shape[1]
    V = q.shape[0]

    grid = (B,)
    row = lambda width: pl.BlockSpec((1, width), lambda b: (b, 0))
    return pl.pallas_call(
        functools.partial(_kernel, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, V), lambda b: (0, 0)),  # q resident across grid
            row(T8),
            row(DP),
            row(T),
            row(D),
            row(D),
            row(T),
        ],
        out_specs=row(D),
        out_shape=jax.ShapeDtypeStruct((B, D), jnp.float32),
        interpret=interpret,
    )(q[None, :], ctrl, data, seg, start_pos, start_abs, vals)
