"""Pallas TPU kernel: fixed-width (bitpack) decode + fused inner product.

The beyond-paper TPU-native codec (DESIGN.md §3): each block of T gaps is
packed at one bit-width, so the decode is a pure shift+mask with *no*
data-dependent offsets — every lane knows statically which word and bit
it reads. Two variants:

* ``bitpack_block_scores``      — runtime per-block width (one kernel for
  the whole index; widths arrive as a (1,1) scalar block).
* ``bitpack_block_scores_w``    — compile-time width (one kernel per
  width bucket; tight word arrays, no over-read — the §Perf layout).

Fusion (decode → q gather → FMA → one-hot MXU reduce) matches
``dotvbyte_dot``; only the gap decode differs.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["bitpack_block_scores", "bitpack_block_scores_w"]


def _decode_fixed(words: jnp.ndarray, width: jnp.ndarray, T: int) -> jnp.ndarray:
    """Unpack T values of ``width`` bits from u32 words (LSB-first)."""
    w32 = words.astype(jnp.uint32)
    wu = width.astype(jnp.uint32)
    bitpos = jax.lax.iota(jnp.uint32, T) * wu
    wi = (bitpos >> 5).astype(jnp.int32)
    off = bitpos & 31
    lo = jnp.take(w32, wi, axis=0) >> off
    hi_raw = jnp.take(w32, wi + 1, axis=0)
    hi = jnp.where(off > 0, hi_raw << (jnp.uint32(32) - off), jnp.uint32(0))
    mask = (jnp.uint32(1) << wu) - jnp.uint32(1)
    return ((lo | hi) & mask).astype(jnp.int32)


def _body(q, words, width, seg, sp, sa, vals, scale, T, D):
    seg = seg.astype(jnp.int32)  # i8 in the slim metadata layout
    gaps = _decode_fixed(words, width, T)
    t = jnp.cumsum(gaps)
    segc = jnp.clip(seg, 0, D - 1)
    tp = jnp.take(t, sp, axis=0)
    comp = jnp.where(seg >= 0, jnp.take(sa, segc) + t - jnp.take(tp, segc), 0)
    qv = jnp.take(q, comp, axis=0)
    prod = qv * vals.astype(jnp.float32) * jnp.float32(scale)
    prod = prod * (seg >= 0).astype(jnp.float32)
    onehot = (seg[:, None] == jax.lax.broadcasted_iota(jnp.int32, (T, D), 1)).astype(
        jnp.float32
    )
    return jnp.dot(prod[None, :], onehot, preferred_element_type=jnp.float32)[0]


def _kernel_dyn(q_ref, words_ref, width_ref, seg_ref, sp_ref, sa_ref, vals_ref, out_ref, *, scale):
    T = seg_ref.shape[1]
    D = sp_ref.shape[1]
    # pad one word for the straddle read
    words = jnp.concatenate([words_ref[0, :], jnp.zeros((1,), jnp.uint32)])
    out_ref[0, :] = _body(
        q_ref[0, :], words, width_ref[0, 0], seg_ref[0, :], sp_ref[0, :],
        sa_ref[0, :], vals_ref[0, :], scale, T, D,
    )


def _kernel_static(q_ref, words_ref, seg_ref, sp_ref, sa_ref, vals_ref, out_ref, *, scale, width):
    T = seg_ref.shape[1]
    D = sp_ref.shape[1]
    words = jnp.concatenate([words_ref[0, :], jnp.zeros((1,), jnp.uint32)])
    out_ref[0, :] = _body(
        q_ref[0, :], words, jnp.uint32(width), seg_ref[0, :], sp_ref[0, :],
        sa_ref[0, :], vals_ref[0, :], scale, T, D,
    )


def _row(width):
    return pl.BlockSpec((1, width), lambda b: (b, 0))


@functools.partial(jax.jit, static_argnames=("scale", "interpret"))
def bitpack_block_scores(
    q, words, widths, seg, start_pos, start_abs, vals, *, scale=1.0, interpret=True
):
    """Runtime-width variant. widths i32 [B]. Returns [B, D] f32."""
    B, W = words.shape
    T = seg.shape[1]
    D = start_pos.shape[1]
    V = q.shape[0]
    return pl.pallas_call(
        functools.partial(_kernel_dyn, scale=scale),
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, V), lambda b: (0, 0)),
            _row(W),
            pl.BlockSpec((1, 1), lambda b: (b, 0)),
            _row(T),
            _row(D),
            _row(D),
            _row(T),
        ],
        out_specs=_row(D),
        out_shape=jax.ShapeDtypeStruct((B, D), jnp.float32),
        interpret=interpret,
    )(q[None, :], words, widths[:, None], seg, start_pos, start_abs, vals)


@functools.partial(jax.jit, static_argnames=("scale", "width", "interpret"))
def bitpack_block_scores_w(
    q, words, seg, start_pos, start_abs, vals, *, width: int, scale=1.0, interpret=True
):
    """Compile-time-width variant for width-bucketed indexes. [B, D] f32."""
    B, W = words.shape
    T = seg.shape[1]
    D = start_pos.shape[1]
    V = q.shape[0]
    return pl.pallas_call(
        functools.partial(_kernel_static, scale=scale, width=width),
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, V), lambda b: (0, 0)),
            _row(W),
            _row(T),
            _row(D),
            _row(D),
            _row(T),
        ],
        out_specs=_row(D),
        out_shape=jax.ShapeDtypeStruct((B, D), jnp.float32),
        interpret=interpret,
    )(q[None, :], words, seg, start_pos, start_abs, vals)
