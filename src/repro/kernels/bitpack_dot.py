"""Pallas TPU kernel: fixed-width (bitpack) decode + fused inner product.

The beyond-paper TPU-native codec (DESIGN.md §3): each block of T gaps is
packed at one bit-width, so the decode is a pure shift+mask with *no*
data-dependent offsets — every lane knows statically which word and bit
it reads. Two variants:

* ``bitpack_block_scores``      — runtime per-block width (one kernel for
  the whole index; widths ride along as a [B, 1] i32 stream).
* ``bitpack_block_scores_w``    — compile-time width (one kernel per
  width bucket; tight word arrays, no over-read — the §Perf layout).

Kernels are TILED like ``dotvbyte_dot`` (PR 6, ``tiles.py``): the
single-query scan runs the double-buffered HBM→VMEM DMA pipeline
(:func:`tiles.dma_block_scan`), the batched variant a queries×tiles
grid (:func:`tiles.grid_batch_scores`).  The word stream is lane-padded
at pack time; the decode masks off padding words via the T bound, and
the fused epilogue (q gather → FMA → contiguous-fragment prefix-sum
slot reduce) is the shared tile program in ``tiles``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.scoring import decode_gaps_bitpack

from . import tiles

__all__ = [
    "bitpack_block_scores",
    "bitpack_block_scores_batch",
    "bitpack_block_scores_w",
    "bitpack_block_scores_xla",
    "bitpack_block_scores_xla_batch",
    "bitpack_block_scores_w_xla",
]


def _decode_fixed(words: jnp.ndarray, width: jnp.ndarray, T: int) -> jnp.ndarray:
    """Unpack T values of ``width`` bits from u32 words (LSB-first).
    1-D form used by the rows-rescoring kernel (``rows_dot``); the tiled
    block kernels use the [R, W] matrix decoder from ``scoring``.
    ``words`` must carry ≥ 1 spare word for the straddle read."""
    w32 = words.astype(jnp.uint32)
    wu = width.astype(jnp.uint32)
    bitpos = jax.lax.iota(jnp.uint32, T) * wu
    wi = (bitpos >> 5).astype(jnp.int32)
    off = bitpos & 31
    lo = jnp.take(w32, wi, axis=0) >> off
    hi_raw = jnp.take(w32, wi + 1, axis=0)
    hi = jnp.where(off > 0, hi_raw << (jnp.uint32(32) - off), jnp.uint32(0))
    mask = (jnp.uint32(1) << wu) - jnp.uint32(1)
    return ((lo | hi) & mask).astype(jnp.int32)


def tile_gaps(words: jnp.ndarray, widths: jnp.ndarray, T: int) -> jnp.ndarray:
    """[R, W] words + [R] widths → gaps i32 [R, T]."""
    return decode_gaps_bitpack(words, widths, T)


def _tile_fn(q, words, widths2, seg, sp, sa, vals, *, scale: float):
    gaps = tile_gaps(words, widths2[:, 0], seg.shape[-1])
    return tiles.tile_scores(q, gaps, seg, sp, sa, vals, scale)


def _tile_fn_batch(Q, words, widths2, seg, sp, sa, vals, *, scale: float):
    gaps = tile_gaps(words, widths2[:, 0], seg.shape[-1])
    return tiles.tile_scores_batch(Q, gaps, seg, sp, sa, vals, scale)


def _pad_block_streams(words, widths2, seg, start_pos, start_abs, vals):
    pad = functools.partial(tiles.pad_axis, multiple=tiles.R_TILE, axis=0)
    return (
        pad(words), pad(widths2, fill=1), pad(seg, fill=-1),
        pad(start_pos), pad(start_abs), pad(vals),
    )


@functools.partial(jax.jit, static_argnames=("scale", "interpret"))
def bitpack_block_scores(
    q, words, widths, seg, start_pos, start_abs, vals, *, scale=1.0, interpret=True
):
    """Runtime-width variant. widths i32 [B]. Returns [B, D] f32 via the
    double-buffered DMA scan."""
    B = words.shape[0]
    D = start_pos.shape[1]
    streams = _pad_block_streams(
        words, widths.astype(jnp.int32)[:, None], seg, start_pos, start_abs, vals
    )
    out = tiles.dma_block_scan(
        functools.partial(_tile_fn, scale=scale), q, streams, D, interpret
    )
    return out[:B]


@functools.partial(jax.jit, static_argnames=("scale", "interpret"))
def bitpack_block_scores_batch(
    Q, words, widths, seg, start_pos, start_abs, vals, *, scale=1.0, interpret=True
):
    """[nq, B, D] batched runtime-width scores via the queries×tiles grid."""
    nq = Q.shape[0]
    B = words.shape[0]
    D = start_pos.shape[1]
    Qp = tiles.pad_axis(Q, tiles.Q_TILE, axis=0)
    streams = _pad_block_streams(
        words, widths.astype(jnp.int32)[:, None], seg, start_pos, start_abs, vals
    )
    out = tiles.grid_batch_scores(
        functools.partial(_tile_fn_batch, scale=scale), Qp, streams, D, interpret
    )
    return out[:nq, :B]


@functools.partial(jax.jit, static_argnames=("scale", "width", "interpret"))
def bitpack_block_scores_w(
    q, words, seg, start_pos, start_abs, vals, *, width: int, scale=1.0, interpret=True
):
    """Compile-time-width variant for width-bucketed indexes. [B, D] f32."""
    B = words.shape[0]
    D = start_pos.shape[1]

    def tile_fn(q_, words_, seg_, sp_, sa_, vals_):
        gaps = tile_gaps(words_, jnp.full((words_.shape[0],), width, jnp.int32), seg_.shape[-1])
        return tiles.tile_scores(q_, gaps, seg_, sp_, sa_, vals_, scale)

    pad = functools.partial(tiles.pad_axis, multiple=tiles.R_TILE, axis=0)
    streams = (pad(words), pad(seg, fill=-1), pad(start_pos), pad(start_abs), pad(vals))
    out = tiles.dma_block_scan(tile_fn, q, streams, D, interpret)
    return out[:B]


@functools.partial(jax.jit, static_argnames=("scale",))
def bitpack_block_scores_xla(
    q, words, widths, seg, start_pos, start_abs, vals, *, scale=1.0
):
    """The same runtime-width tile program lowered through XLA."""
    B = words.shape[0]
    D = start_pos.shape[1]
    streams = _pad_block_streams(
        words, widths.astype(jnp.int32)[:, None], seg, start_pos, start_abs, vals
    )
    return tiles.xla_block_scores(
        functools.partial(_tile_fn, scale=scale), q, streams, D
    )[:B]


@functools.partial(jax.jit, static_argnames=("scale",))
def bitpack_block_scores_xla_batch(
    Q, words, widths, seg, start_pos, start_abs, vals, *, scale=1.0
):
    """XLA lowering of the batched runtime-width tile program → [nq, B, D]."""
    B = words.shape[0]
    D = start_pos.shape[1]
    streams = _pad_block_streams(
        words, widths.astype(jnp.int32)[:, None], seg, start_pos, start_abs, vals
    )
    return tiles.xla_block_scores_batch(
        functools.partial(_tile_fn_batch, scale=scale), Q, streams, D
    )[:, :B]


@functools.partial(jax.jit, static_argnames=("scale", "width"))
def bitpack_block_scores_w_xla(
    q, words, seg, start_pos, start_abs, vals, *, width: int, scale=1.0
):
    """XLA lowering of the compile-time-width tile program. [B, D] f32."""
    B = words.shape[0]
    D = start_pos.shape[1]

    def tile_fn(q_, words_, seg_, sp_, sa_, vals_):
        gaps = tile_gaps(words_, jnp.full((words_.shape[0],), width, jnp.int32), seg_.shape[-1])
        return tiles.tile_scores(q_, gaps, seg_, sp_, sa_, vals_, scale)

    pad = functools.partial(tiles.pad_axis, multiple=tiles.R_TILE, axis=0)
    streams = (pad(words), pad(seg, fill=-1), pad(start_pos), pad(start_abs), pad(vals))
    return tiles.xla_block_scores(tile_fn, q, streams, D)[:B]
