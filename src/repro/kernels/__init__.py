"""Pallas TPU kernels for the forward-index scoring hot path.

``dotvbyte_dot``    — the paper's DotVByte, TPU-adapted (DESIGN.md §3)
``streamvbyte_dot`` — the paper's headline byte codec, fused the same way
``bitpack_dot``     — beyond-paper fixed-width codec, runtime + bucketed
``rows_dot``        — generic fused candidate-row rescoring (scalar-
                      prefetch HBM→VMEM gather + decode + dot), every codec
``registry``        — codec → ``KernelSet`` registry; the dispatch point
                      ``RetrieverConfig(backend="pallas")`` routes through
``ops``             — jit wrappers (padding, mode resolution, combine)
``modes``           — the mode axis: jnp | pallas_interpret | pallas_compiled
``tiles``           — shared tiled scan machinery (DMA pipeline, grids, XLA)
``ref``             — pure-jnp oracles each kernel is asserted against
"""

from .bitpack_dot import bitpack_block_scores, bitpack_block_scores_w
from .dotvbyte_dot import dotvbyte_block_scores, dotvbyte_block_scores_batch
from .modes import MODES, SCORING_BACKENDS, mosaic_available, resolve_mode
from .ops import (
    default_interpret,
    score_bitpack,
    score_bitpack_batch,
    score_bitpack_bucketed,
    score_dotvbyte,
    score_dotvbyte_batch,
    score_streamvbyte,
    score_streamvbyte_batch,
)
from .ref import (
    bitpack_block_scores_ref,
    dotvbyte_block_scores_ref,
    streamvbyte_block_scores_ref,
)
from .registry import KernelSet, available_kernels, get_kernels, register_kernels
from .rows_dot import rows_scores, rows_scores_batch
from .streamvbyte_dot import streamvbyte_block_scores, streamvbyte_block_scores_batch

__all__ = [
    "MODES",
    "SCORING_BACKENDS",
    "mosaic_available",
    "resolve_mode",
    "bitpack_block_scores",
    "bitpack_block_scores_w",
    "dotvbyte_block_scores",
    "dotvbyte_block_scores_batch",
    "streamvbyte_block_scores",
    "streamvbyte_block_scores_batch",
    "rows_scores",
    "rows_scores_batch",
    "KernelSet",
    "register_kernels",
    "get_kernels",
    "available_kernels",
    "default_interpret",
    "score_dotvbyte",
    "score_dotvbyte_batch",
    "score_streamvbyte",
    "score_streamvbyte_batch",
    "score_bitpack",
    "score_bitpack_batch",
    "score_bitpack_bucketed",
    "bitpack_block_scores_ref",
    "dotvbyte_block_scores_ref",
    "streamvbyte_block_scores_ref",
]
