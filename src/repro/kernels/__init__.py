"""Pallas TPU kernels for the forward-index scoring hot path.

``dotvbyte_dot``  — the paper's DotVByte, TPU-adapted (DESIGN.md §3)
``bitpack_dot``   — beyond-paper fixed-width codec, runtime + bucketed
``ops``           — jit wrappers (padding, interpret-mode, combine)
``ref``           — pure-jnp oracles each kernel is asserted against
"""

from .bitpack_dot import bitpack_block_scores, bitpack_block_scores_w
from .dotvbyte_dot import dotvbyte_block_scores
from .ops import (
    default_interpret,
    score_bitpack,
    score_bitpack_bucketed,
    score_dotvbyte,
)
from .ref import bitpack_block_scores_ref, dotvbyte_block_scores_ref

__all__ = [
    "bitpack_block_scores",
    "bitpack_block_scores_w",
    "dotvbyte_block_scores",
    "default_interpret",
    "score_bitpack",
    "score_bitpack_bucketed",
    "score_dotvbyte",
    "bitpack_block_scores_ref",
    "dotvbyte_block_scores_ref",
]
