"""Kernel-backend registry (DESIGN.md §3) — the fused-Pallas mirror of
the layout registry in ``core/layout.py``.

A codec registered in ``core/layout.py`` tells the system how its gap
streams look; a codec registered HERE tells the system how to *serve*
them fused. Each entry is a ``KernelSet``:

* ``block_scores`` / ``block_scores_batch`` — the full-scan path: one
  fused decode→gather→FMA→reduce kernel over the packed block form
  (``(q_dense, PackedBlocks) → [n_docs]`` and the decode-once/
  score-many query-batched variant ``(Q, PackedBlocks) → [nq,
  n_docs]``);
* ``rows_scores`` — the candidate-rescoring path every serve engine's
  phase 2 runs through (``(arrays, docs, q, scale) → [C]``): the
  scalar-prefetch gather kernel in ``rows_dot.py``. This is the entry
  ``scoring.score_candidate_rows`` dispatches to when
  ``RetrieverConfig(backend="pallas")`` routes a Retriever through the
  fused path;
* ``rows_scores_batch`` — same, for a query batch sharing one
  candidate set (``(arrays, docs, Q, scale) → [nq, C]``).

Registering a ``KernelSet`` under a layout codec's name makes EVERY
engine serve that codec fused with zero engine edits — the exact
contract the layout registry established for the jnp path. Codecs
without an entry (or without the relevant field) fall back to jnp with
a one-time warning (``scoring.score_candidate_rows``).

Every entry's last parameter is the kernel execution ``mode``
(``repro.kernels.modes``): a mode string, ``None`` (auto → compiled),
or the pre-mode-axis booleans (``True`` ↦ pallas_interpret, ``False`` ↦
pallas_compiled) — so the same registry serves the jnp reference, the
CPU semantics-check (interpret) and the compiled lowering (Mosaic on
TPU, XLA elsewhere).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional

import jax.numpy as jnp

from . import rows_dot
from .modes import resolve_lowering
from .ops import (
    default_interpret,
    pad_query_lanes,
    score_bitpack,
    score_bitpack_batch,
    score_dotvbyte,
    score_dotvbyte_batch,
    score_streamvbyte,
    score_streamvbyte_batch,
)

__all__ = [
    "KernelSet",
    "register_kernels",
    "get_kernels",
    "available_kernels",
    "rows_scorer",
    "rows_batch_scorer",
]


@dataclasses.dataclass(frozen=True)
class KernelSet:
    """Fused kernel entry points for one codec (None = not fused)."""

    codec: str
    #: (q_dense, PackedBlocks, mode=None) → [n_docs] f32
    block_scores: Optional[Callable] = None
    #: (Q [nq, dim], PackedBlocks, mode=None) → [nq, n_docs] f32
    block_scores_batch: Optional[Callable] = None
    #: (arrays, docs [C], q [dim], scale, mode=None) → [C] f32
    rows_scores: Optional[Callable] = None
    #: (arrays, docs [C], Q [nq, dim], scale, mode=None) → [nq, C]
    rows_scores_batch: Optional[Callable] = None


_KERNELS: Dict[str, Callable[[], KernelSet]] = {}


def register_kernels(name: str):
    """Decorator: register a ``KernelSet`` factory under a codec name."""

    def deco(factory: Callable[[], KernelSet]):
        _KERNELS[name] = factory
        return factory

    return deco


def get_kernels(name: str) -> KernelSet:
    try:
        return _KERNELS[name]()
    except KeyError:
        raise ValueError(
            f"no fused kernels for codec {name!r}; have {sorted(_KERNELS)}"
        ) from None


def available_kernels() -> list[str]:
    return sorted(_KERNELS)


def rows_scorer(codec: str) -> Optional[Callable]:
    """The fused rows-rescoring entry for ``codec``, or None when the
    codec has no registered rows kernel (callers then fall back to
    jnp — see ``scoring.score_candidate_rows``)."""
    factory = _KERNELS.get(codec)
    if factory is None:
        return None
    return factory().rows_scores


def rows_batch_scorer(codec: str) -> Optional[Callable]:
    """The fused decode-once/score-many rows entry for ``codec`` —
    one shared candidate set, a resident query batch — or None when
    unregistered (callers fall back to the jnp batch path — see
    ``scoring.score_candidate_rows_batch``)."""
    factory = _KERNELS.get(codec)
    if factory is None:
        return None
    return factory().rows_scores_batch


# ---------------------------------------------------------------------------
# built-in entries
# ---------------------------------------------------------------------------


def _rows_arrays(arrays) -> dict:
    """The row-form fields of an engine array dict (drop engine extras
    so the jit'd XLA rows graph keys on a stable pytree).  Value-codec
    payload (``vq_*``, DESIGN.md §12) rides along — it includes the
    non-``_rows`` ``vq_codebook``."""
    keep = ("vals_rows", "nnz_rows")
    return {
        k: arrays[k]
        for k in arrays
        if k in keep or k.endswith("_rows") or k.startswith("vq_")
    }


def _make_rows(codec: str):
    def rows(arrays, docs, q, scale, mode=None):
        from repro.core import values as value_codecs

        low = resolve_lowering(mode)
        vq = value_codecs.infer_rows_vq(arrays)
        qp = pad_query_lanes(jnp.asarray(q, jnp.float32))
        if low == "jnp":
            from repro.core.scoring import _gather_decode_rows, score_doc_rows

            comps, vals, nnz = _gather_decode_rows(codec, arrays, docs)
            return score_doc_rows(qp, comps, vals, nnz, float(scale))
        if low == "xla":
            return rows_dot.rows_scores_xla(
                codec, qp, docs, _rows_arrays(arrays), float(scale)
            )
        return rows_dot.rows_scores(
            codec,
            qp,
            docs,
            arrays["vals_rows"],
            arrays["nnz_rows"],
            *value_codecs.rows_vq_streams(vq, arrays),
            *rows_dot._payload_streams(codec, arrays),
            scale=float(scale),
            vq=vq,
            interpret=low == "interpret",
        )

    return rows


def _make_rows_batch(codec: str):
    def rows_batch(arrays, docs, Q, scale, mode=None):
        from repro.core import values as value_codecs

        low = resolve_lowering(mode)
        vq = value_codecs.infer_rows_vq(arrays)
        Qp = pad_query_lanes(jnp.asarray(Q, jnp.float32))
        if low == "jnp":
            import jax

            from repro.core.scoring import _gather_decode_rows, score_doc_rows

            comps, vals, nnz = _gather_decode_rows(codec, arrays, docs)
            return jax.vmap(
                lambda q: score_doc_rows(q, comps, vals, nnz, float(scale))
            )(Qp)
        if low == "xla":
            return rows_dot.rows_scores_xla_batch(
                codec, Qp, docs, _rows_arrays(arrays), float(scale)
            )
        return rows_dot.rows_scores_batch(
            codec,
            Qp,
            docs,
            arrays["vals_rows"],
            arrays["nnz_rows"],
            *value_codecs.rows_vq_streams(vq, arrays),
            *rows_dot._payload_streams(codec, arrays),
            scale=float(scale),
            vq=vq,
            interpret=low == "interpret",
        )

    return rows_batch


@register_kernels("dotvbyte")
def _dotvbyte_kernels() -> KernelSet:
    return KernelSet(
        codec="dotvbyte",
        block_scores=score_dotvbyte,
        block_scores_batch=score_dotvbyte_batch,
        rows_scores=_make_rows("dotvbyte"),
        rows_scores_batch=_make_rows_batch("dotvbyte"),
    )


@register_kernels("streamvbyte")
def _streamvbyte_kernels() -> KernelSet:
    return KernelSet(
        codec="streamvbyte",
        block_scores=score_streamvbyte,
        block_scores_batch=score_streamvbyte_batch,
        rows_scores=_make_rows("streamvbyte"),
        rows_scores_batch=_make_rows_batch("streamvbyte"),
    )


@register_kernels("bitpack")
def _bitpack_kernels() -> KernelSet:
    return KernelSet(
        codec="bitpack",
        block_scores=score_bitpack,
        block_scores_batch=score_bitpack_batch,
        rows_scores=_make_rows("bitpack"),
        rows_scores_batch=_make_rows_batch("bitpack"),
    )


@register_kernels("uncompressed")
def _uncompressed_kernels() -> KernelSet:
    # decode-free: the block scan has nothing to fuse beyond what the
    # jnp path already is (gather + FMA); only the rescoring gather is
    # worth a kernel (HBM→VMEM row DMA via scalar prefetch).
    return KernelSet(
        codec="uncompressed",
        rows_scores=_make_rows("uncompressed"),
        rows_scores_batch=_make_rows_batch("uncompressed"),
    )
