"""Pallas TPU kernel: fused candidate-row gather + decode + rescore.

The serve engines' phase-2 hot path (DESIGN.md §7) re-scores a static
set of candidate documents against the packed row form ``[N+1, L]``
(``layout.pack_rows``). The pure-jnp path (``scoring.score_candidate_
rows``) is a take→decode→dot chain whose intermediates — the gathered
codec payload AND the decoded i32 components — materialise in HBM.

This kernel keeps the whole chain fused (DESIGN.md §3): the candidate
doc ids arrive as a *scalar-prefetch* operand, so the grid ``index_map``
itself performs the HBM→VMEM row gather — grid step ``i`` DMAs exactly
the rows of document ``docs[i]`` into VMEM, where they are decoded
(streamvbyte / dotvbyte / bitpack) and dotted against the VMEM-resident
query batch in one step. Decoded components never touch HBM; per-query
HBM traffic is the encoded candidate payload + Q + C scores.

  docs (scalar prefetch) ──index_map──► row DMA HBM→VMEM
  row payload ──codec decode──► gaps ──cumsum──► absolute components
  components ──gather q──► qv ──FMA vals·mask──► Σ ──► scores[i]

Row-gap convention: the first gap IS the absolute component
(per-document alignment), so a plain cumsum rebuilds the ids; the
sentinel row N is all-zero and scores exactly 0 (callers mask it).
Row payload streams are lane-padded at pack time (``layout.pack_rows``
rounds ``l_max`` to ``LANE_MULTIPLE`` and the codec encoders lane-pad
their ctrl/word streams); the per-codec decoders below slice the
control stream tight for ``L`` values before decoding.

All four registered codecs have a rows kernel; the query-batched
variants decode each candidate row ONCE and score the whole resident
query batch (decode-once-score-many on the rescoring path). Single-
query calls compose with ``jax.vmap`` — the batching rule lifts the
query axis into the grid — which is how the jit'd vmapped
``Retriever.search`` serves ``backend="pallas"`` unmodified.

``rows_scores_xla{,_batch}`` lower the SAME fused chain through XLA —
one jit'd gather→decode→dot graph, candidate-tiled so the decoded
working set stays cache-resident — which is what
``mode="pallas_compiled"`` runs on hosts without Mosaic
(``repro.kernels.modes``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import tiles
from .bitpack_dot import _decode_fixed
from .dotvbyte_dot import decode_vec as _decode_vec_dotvbyte
from .streamvbyte_dot import decode_vec as _decode_vec_streamvbyte

__all__ = [
    "rows_scores",
    "rows_scores_batch",
    "rows_scores_xla",
    "rows_scores_xla_batch",
]


# ---------------------------------------------------------------------------
# per-codec row decoders: payload refs → absolute components i32 [L]
# (the ctrl→gaps decodes are the SAME helpers the block kernels run —
# row gaps just cumsum directly because the first gap is absolute)
# ---------------------------------------------------------------------------


def _comps_uncompressed(refs, L):
    (comps_ref,) = refs
    return comps_ref[0, :]


def _comps_dotvbyte(refs, L):
    ctrl_ref, data_ref = refs
    return jnp.cumsum(_decode_vec_dotvbyte(ctrl_ref[0, :], data_ref[0, :], L))


def _comps_streamvbyte(refs, L):
    ctrl_ref, data_ref = refs
    return jnp.cumsum(_decode_vec_streamvbyte(ctrl_ref[0, :], data_ref[0, :], L))


def _comps_bitpack(refs, L):
    words_ref, widths_ref = refs
    # pad one word for the straddle read (same trick as bitpack_dot)
    words = jnp.concatenate([words_ref[0, :], jnp.zeros((1,), jnp.uint32)])
    gaps = _decode_fixed(words, widths_ref[0, 0], L)
    return jnp.cumsum(gaps)


_DECODERS = {
    "uncompressed": _comps_uncompressed,
    "dotvbyte": _comps_dotvbyte,
    "streamvbyte": _comps_streamvbyte,
    "bitpack": _comps_bitpack,
}


def _dequant_row(vq: str, vals_ref, vq_refs):
    """In-kernel dequant stage (DESIGN.md §12): the VMEM-resident code
    row → f32 storage-unit values, through the SAME ``values.decode_
    codes`` helpers the jnp reference runs — quantized bytes are what
    crossed HBM; f32 value rows exist only in VMEM."""
    from repro.core import values as value_codecs

    codes = vals_ref[0, :]
    if vq == "f16":
        return codes.astype(jnp.float32)
    if vq == "pq":
        (cb_ref,) = vq_refs  # [1, K·M] flat codebook, grid-resident
        return value_codecs.decode_codes(vq, codes, codebook_flat=cb_ref[0, :])
    lo_ref, sc_ref = vq_refs  # per-row clip range, gathered with the row
    return value_codecs.decode_codes(vq, codes, lo_ref[0, 0], sc_ref[0, 0])


def _kernel(
    docs_ref, q_ref, vals_ref, nnz_ref, *rest,
    scale: float, codec: str, vq: str,
):
    from repro.core import values as value_codecs

    n_vq = value_codecs.n_vq_streams(vq)
    vq_refs, payload_refs, out_ref = rest[:n_vq], rest[n_vq:-1], rest[-1]
    vals = _dequant_row(vq, vals_ref, vq_refs) * jnp.float32(scale)
    L = vals.shape[0]  # LOGICAL row capacity (codes decode 1:factor)
    comps = _DECODERS[codec](payload_refs, L)
    mask = jax.lax.iota(jnp.int32, L) < nnz_ref[0, 0]
    Q = q_ref[...]  # [nq, V] resident across the whole grid
    qv = jnp.take(Q, comps, axis=1)  # [nq, L]
    out_ref[0, :] = (qv * (vals * mask)[None, :]).sum(axis=1)  # [nq]


def _payload_streams(codec: str, arrays) -> list[jnp.ndarray]:
    """Ordered codec payload streams of the packed row form, shaped for
    (1, width) blocks (scalar-per-row fields become [N+1, 1])."""
    if codec == "uncompressed":
        return [arrays["comps_rows"]]
    if codec == "bitpack":
        return [arrays["words_rows"], arrays["widths_rows"][:, None]]
    return [arrays["ctrl_rows"], arrays["data_rows"]]


@functools.partial(
    jax.jit, static_argnames=("codec", "scale", "vq", "interpret")
)
def rows_scores_batch(
    codec: str,
    Q: jnp.ndarray,  # [nq, vocab_pad] f32
    docs: jnp.ndarray,  # i32 [C] candidate doc ids (sentinel = row N)
    vals_rows: jnp.ndarray,  # [N+1, W] storage dtype / u8 codes
    nnz_rows: jnp.ndarray,  # i32 [N+1]
    *streams,  # vq streams (values.rows_vq_streams) + codec payload
    scale: float = 1.0,
    vq: str = "f16",
    interpret: bool = True,
) -> jnp.ndarray:
    """Fused rescoring of C candidate rows against a query batch.

    Returns scores f32 [nq, C]. ``docs`` is consumed as scalar prefetch:
    the grid index_map gathers row ``docs[i]`` HBM→VMEM at step ``i``.

    Under a quantized ``vq`` the value operand carries u8 codes (the
    only value bytes that cross HBM); the scalar-quant clip columns are
    gathered per row like any stream, the PQ codebook is grid-resident
    like Q, and the in-kernel dequant stage rebuilds f32 values in VMEM
    before the dot (DESIGN.md §12)."""
    from repro.core import values as value_codecs

    C = docs.shape[0]
    nq, V = Q.shape
    W = vals_rows.shape[1]  # stored width (logical // code_factor)
    n_vq = value_codecs.n_vq_streams(vq)
    vq_streams, payload = streams[:n_vq], streams[n_vq:]
    gathered = lambda width: pl.BlockSpec((1, width), lambda i, docs: (docs[i], 0))
    if vq == "pq":  # flat codebook, resident across the whole grid
        vq_specs = [
            pl.BlockSpec(vq_streams[0].shape, lambda i, docs: (0, 0))
        ]
    else:  # per-row lo/scale columns gather with the row
        vq_specs = [gathered(1) for _ in vq_streams]
    in_specs = [
        pl.BlockSpec((nq, V), lambda i, docs: (0, 0)),  # Q resident
        gathered(W),  # vals / codes
        gathered(1),  # nnz
    ] + vq_specs + [gathered(p.shape[1]) for p in payload]
    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, codec=codec, vq=vq),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(C,),
            in_specs=in_specs,
            out_specs=pl.BlockSpec((1, nq), lambda i, docs: (i, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((C, nq), jnp.float32),
        interpret=interpret,
    )(docs.astype(jnp.int32), Q, vals_rows, nnz_rows[:, None], *streams)
    return out.T


def rows_scores(
    codec: str,
    q: jnp.ndarray,  # [vocab_pad] f32
    docs: jnp.ndarray,
    vals_rows: jnp.ndarray,
    nnz_rows: jnp.ndarray,
    *streams,
    scale: float = 1.0,
    vq: str = "f16",
    interpret: bool = True,
) -> jnp.ndarray:
    """Single-query fused rescoring → scores f32 [C]."""
    return rows_scores_batch(
        codec, q[None, :], docs, vals_rows, nnz_rows, *streams,
        scale=scale, vq=vq, interpret=interpret,
    )[0]


# ---------------------------------------------------------------------------
# XLA lowering: the same fused chain as one jit'd candidate-tiled graph
# ---------------------------------------------------------------------------

#: candidate rows per XLA tile — bounds the decoded working set the way
#: the scalar-prefetch grid bounds it to one row per step
C_TILE = 128


@functools.partial(jax.jit, static_argnames=("codec", "scale"))
def rows_scores_xla_batch(
    codec: str,
    Q: jnp.ndarray,  # [nq, dim] f32 (lane padding not required)
    docs: jnp.ndarray,  # i32 [C]
    arrays,  # dict with vals_rows/nnz_rows + codec payload
    scale: float = 1.0,
) -> jnp.ndarray:
    """One compiled gather→decode→dot graph → scores f32 [nq, C].

    The whole chain fuses under jit (no eager HBM materialisation of
    the gathered payload or decoded components between dispatches);
    candidate sets larger than ``C_TILE`` stream through a ``lax.scan``
    so the per-step working set stays cache-resident."""
    from repro.core.scoring import _gather_decode_rows, score_doc_rows

    C = docs.shape[0]
    if C <= C_TILE:
        comps, vals, nnz = _gather_decode_rows(codec, arrays, docs)
        return jax.vmap(lambda q: score_doc_rows(q, comps, vals, nnz, scale))(Q)
    sentinel = arrays["vals_rows"].shape[0] - 1  # all-zero row, scores 0
    dt = tiles.pad_axis(docs, C_TILE, fill=sentinel).reshape(-1, C_TILE)

    def step(carry, d):
        comps, vals, nnz = _gather_decode_rows(codec, arrays, d)
        return carry, jax.vmap(lambda q: score_doc_rows(q, comps, vals, nnz, scale))(Q)

    _, out = jax.lax.scan(step, 0, dt)  # [nt, nq, C_TILE]
    return out.transpose(1, 0, 2).reshape(Q.shape[0], -1)[:, :C]


def rows_scores_xla(codec, q, docs, arrays, scale=1.0):
    """Single-query form of :func:`rows_scores_xla_batch` → [C] f32."""
    return rows_scores_xla_batch(codec, q[None, :], docs, arrays, scale)[0]
