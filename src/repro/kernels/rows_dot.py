"""Pallas TPU kernel: fused candidate-row gather + decode + rescore.

The serve engines' phase-2 hot path (DESIGN.md §7) re-scores a static
set of candidate documents against the packed row form ``[N+1, L]``
(``layout.pack_rows``). The pure-jnp path (``scoring.score_candidate_
rows``) is a take→decode→dot chain whose intermediates — the gathered
codec payload AND the decoded i32 components — materialise in HBM.

This kernel keeps the whole chain fused (DESIGN.md §3): the candidate
doc ids arrive as a *scalar-prefetch* operand, so the grid ``index_map``
itself performs the HBM→VMEM row gather — grid step ``i`` DMAs exactly
the rows of document ``docs[i]`` into VMEM, where they are decoded
(streamvbyte / dotvbyte / bitpack) and dotted against the VMEM-resident
query batch in one step. Decoded components never touch HBM; per-query
HBM traffic is the encoded candidate payload + Q + C scores.

  docs (scalar prefetch) ──index_map──► row DMA HBM→VMEM
  row payload ──codec decode──► gaps ──cumsum──► absolute components
  components ──gather q──► qv ──FMA vals·mask──► Σ ──► scores[i]

Row-gap convention: the first gap IS the absolute component
(per-document alignment), so a plain cumsum rebuilds the ids; the
sentinel row N is all-zero and scores exactly 0 (callers mask it).

All four registered codecs have a rows kernel; the query-batched
variants decode each candidate row ONCE and score the whole resident
query batch (decode-once-score-many on the rescoring path). Single-
query calls compose with ``jax.vmap`` — the batching rule lifts the
query axis into the grid — which is how the jit'd vmapped
``Retriever.search`` serves ``backend="pallas"`` unmodified.

Validated against the jnp oracle in interpret mode (CPU-only
container); the scalar-prefetch row DMA is the op to watch under real
Mosaic lowering (EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .bitpack_dot import _decode_fixed
from .dotvbyte_dot import _decode as _decode_dotvbyte
from .streamvbyte_dot import _decode as _decode_streamvbyte

__all__ = ["rows_scores", "rows_scores_batch"]


# ---------------------------------------------------------------------------
# per-codec row decoders: payload refs → absolute components i32 [L]
# (the ctrl→gaps decodes are the SAME helpers the block kernels run —
# row gaps just cumsum directly because the first gap is absolute)
# ---------------------------------------------------------------------------


def _comps_uncompressed(refs, L):
    (comps_ref,) = refs
    return comps_ref[0, :]


def _comps_dotvbyte(refs, L):
    ctrl_ref, data_ref = refs
    return jnp.cumsum(_decode_dotvbyte(ctrl_ref, data_ref))


def _comps_streamvbyte(refs, L):
    ctrl_ref, data_ref = refs
    return jnp.cumsum(_decode_streamvbyte(ctrl_ref, data_ref))


def _comps_bitpack(refs, L):
    words_ref, widths_ref = refs
    # pad one word for the straddle read (same trick as bitpack_dot)
    words = jnp.concatenate([words_ref[0, :], jnp.zeros((1,), jnp.uint32)])
    gaps = _decode_fixed(words, widths_ref[0, 0], L)
    return jnp.cumsum(gaps)


_DECODERS = {
    "uncompressed": _comps_uncompressed,
    "dotvbyte": _comps_dotvbyte,
    "streamvbyte": _comps_streamvbyte,
    "bitpack": _comps_bitpack,
}


def _kernel(docs_ref, q_ref, vals_ref, nnz_ref, *rest, scale: float, codec: str):
    *payload_refs, out_ref = rest
    L = vals_ref.shape[1]
    comps = _DECODERS[codec](payload_refs, L)
    vals = vals_ref[0, :].astype(jnp.float32) * jnp.float32(scale)
    mask = jax.lax.iota(jnp.int32, L) < nnz_ref[0, 0]
    Q = q_ref[...]  # [nq, V] resident across the whole grid
    qv = jnp.take(Q, comps, axis=1)  # [nq, L]
    out_ref[0, :] = (qv * (vals * mask)[None, :]).sum(axis=1)  # [nq]


def _payload_streams(codec: str, arrays) -> list[jnp.ndarray]:
    """Ordered codec payload streams of the packed row form, shaped for
    (1, width) blocks (scalar-per-row fields become [N+1, 1])."""
    if codec == "uncompressed":
        return [arrays["comps_rows"]]
    if codec == "bitpack":
        return [arrays["words_rows"], arrays["widths_rows"][:, None]]
    return [arrays["ctrl_rows"], arrays["data_rows"]]


@functools.partial(jax.jit, static_argnames=("codec", "scale", "interpret"))
def rows_scores_batch(
    codec: str,
    Q: jnp.ndarray,  # [nq, vocab_pad] f32
    docs: jnp.ndarray,  # i32 [C] candidate doc ids (sentinel = row N)
    vals_rows: jnp.ndarray,  # [N+1, L] storage dtype
    nnz_rows: jnp.ndarray,  # i32 [N+1]
    *payload,  # codec streams, see _payload_streams
    scale: float = 1.0,
    interpret: bool = True,
) -> jnp.ndarray:
    """Fused rescoring of C candidate rows against a query batch.

    Returns scores f32 [nq, C]. ``docs`` is consumed as scalar prefetch:
    the grid index_map gathers row ``docs[i]`` HBM→VMEM at step ``i``.
    """
    C = docs.shape[0]
    nq, V = Q.shape
    L = vals_rows.shape[1]
    gathered = lambda width: pl.BlockSpec((1, width), lambda i, docs: (docs[i], 0))
    in_specs = [
        pl.BlockSpec((nq, V), lambda i, docs: (0, 0)),  # Q resident
        gathered(L),  # vals
        gathered(1),  # nnz
    ] + [gathered(p.shape[1]) for p in payload]
    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, codec=codec),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(C,),
            in_specs=in_specs,
            out_specs=pl.BlockSpec((1, nq), lambda i, docs: (i, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((C, nq), jnp.float32),
        interpret=interpret,
    )(docs.astype(jnp.int32), Q, vals_rows, nnz_rows[:, None], *payload)
    return out.T


def rows_scores(
    codec: str,
    q: jnp.ndarray,  # [vocab_pad] f32
    docs: jnp.ndarray,
    vals_rows: jnp.ndarray,
    nnz_rows: jnp.ndarray,
    *payload,
    scale: float = 1.0,
    interpret: bool = True,
) -> jnp.ndarray:
    """Single-query fused rescoring → scores f32 [C]."""
    return rows_scores_batch(
        codec, q[None, :], docs, vals_rows, nnz_rows, *payload,
        scale=scale, interpret=interpret,
    )[0]
