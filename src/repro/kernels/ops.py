"""jit'd public wrappers around the Pallas kernels.

Handle the lane-alignment plumbing (pad query / data streams to
128-multiples), pick interpret mode on CPU automatically, and combine
per-block scores into global document scores.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.forward_index import PackedBlocks
from repro.core.scoring import scatter_block_scores

from .bitpack_dot import bitpack_block_scores, bitpack_block_scores_w
from .dotvbyte_dot import dotvbyte_block_scores

__all__ = [
    "default_interpret",
    "pad_to",
    "score_dotvbyte",
    "score_bitpack",
    "score_bitpack_bucketed",
]


def default_interpret() -> bool:
    """interpret=True unless running on a real TPU backend."""
    return jax.default_backend() != "tpu"


def pad_to(x: np.ndarray, multiple: int, axis: int = -1) -> np.ndarray:
    size = x.shape[axis]
    pad = (-size) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return np.pad(x, widths)


def _padded_query(q_dense, dim: int) -> jnp.ndarray:
    q = np.zeros(((dim + 127) // 128) * 128, dtype=np.float32)
    q[:dim] = np.asarray(q_dense, dtype=np.float32)[:dim]
    return jnp.asarray(q)


def score_dotvbyte(q_dense, packed: PackedBlocks, interpret: bool | None = None):
    """Full fused-kernel scoring path: [n_docs] f32."""
    assert packed.codec == "dotvbyte"
    interp = default_interpret() if interpret is None else interpret
    q = _padded_query(q_dense, packed.dim)
    data = pad_to(packed.data, 128, axis=1)
    block = dotvbyte_block_scores(
        q,
        jnp.asarray(packed.ctrl),
        jnp.asarray(data),
        jnp.asarray(packed.seg),
        jnp.asarray(packed.start_pos),
        jnp.asarray(packed.start_abs),
        jnp.asarray(packed.vals),
        scale=float(packed.value_format.scale),
        interpret=interp,
    )
    return scatter_block_scores(block, jnp.asarray(packed.doc_ids), packed.n_docs)


def score_bitpack(q_dense, packed: PackedBlocks, interpret: bool | None = None):
    """Runtime-width bitpack kernel path: [n_docs] f32."""
    assert packed.codec == "bitpack"
    interp = default_interpret() if interpret is None else interpret
    q = _padded_query(q_dense, packed.dim)
    words = pad_to(packed.words, 128, axis=1)
    block = bitpack_block_scores(
        q,
        jnp.asarray(words),
        jnp.asarray(packed.widths),
        jnp.asarray(packed.seg),
        jnp.asarray(packed.start_pos),
        jnp.asarray(packed.start_abs),
        jnp.asarray(packed.vals),
        scale=float(packed.value_format.scale),
        interpret=interp,
    )
    return scatter_block_scores(block, jnp.asarray(packed.doc_ids), packed.n_docs)


def score_bitpack_bucketed(q_dense, packed: PackedBlocks, interpret: bool | None = None):
    """Width-bucketed path: one static-width kernel per distinct width.

    Word arrays are sliced tight per bucket (ceil(T·w/32) words, padded to
    the 128 lane multiple) so HBM traffic tracks the true compressed
    size — the §Perf layout.
    """
    assert packed.codec == "bitpack"
    interp = default_interpret() if interpret is None else interpret
    q = _padded_query(q_dense, packed.dim)
    T = packed.block_size
    n_docs = packed.n_docs
    total = jnp.zeros((n_docs,), dtype=jnp.float32)
    for w in sorted(set(int(x) for x in packed.widths)):
        sel = np.flatnonzero(packed.widths == w)
        tight = (T * w + 31) // 32
        words = pad_to(packed.words[sel, :tight], 128, axis=1)
        block = bitpack_block_scores_w(
            q,
            jnp.asarray(words),
            jnp.asarray(packed.seg[sel]),
            jnp.asarray(packed.start_pos[sel]),
            jnp.asarray(packed.start_abs[sel]),
            jnp.asarray(packed.vals[sel]),
            width=w,
            scale=float(packed.value_format.scale),
            interpret=interp,
        )
        total = total + scatter_block_scores(
            block, jnp.asarray(packed.doc_ids[sel]), n_docs
        )
    return total
