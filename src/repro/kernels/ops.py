"""jit'd public wrappers around the Pallas kernels.

Handle the lane-alignment plumbing (pad query / data streams to
128-multiples), resolve the kernel execution ``mode`` (``repro.kernels.
modes``: jnp | pallas_interpret | pallas_compiled), and combine
per-block scores into global document scores.

Every ``score_*`` wrapper takes the mode axis through its third
parameter: a mode string, ``None`` (auto → compiled), or the
pre-mode-axis booleans (``interpret=True`` ↦ pallas_interpret,
``False`` ↦ pallas_compiled). ``mode="jnp"`` routes to the reference
scorers in ``scoring.py``; ``pallas_compiled`` runs the real Mosaic
lowering on TPU and the XLA lowering of the same tile program elsewhere
(one-time warning — see ``modes.resolve_lowering``).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.forward_index import PackedBlocks
from repro.core.scoring import scatter_block_scores, score_packed, score_packed_batch

from .bitpack_dot import (
    bitpack_block_scores,
    bitpack_block_scores_batch,
    bitpack_block_scores_w,
    bitpack_block_scores_w_xla,
    bitpack_block_scores_xla,
    bitpack_block_scores_xla_batch,
)
from .dotvbyte_dot import (
    dotvbyte_block_scores,
    dotvbyte_block_scores_batch,
    dotvbyte_block_scores_xla,
    dotvbyte_block_scores_xla_batch,
)
from .modes import resolve_lowering
from .streamvbyte_dot import (
    streamvbyte_block_scores,
    streamvbyte_block_scores_batch,
    streamvbyte_block_scores_xla,
    streamvbyte_block_scores_xla_batch,
)

__all__ = [
    "default_interpret",
    "pad_to",
    "score_dotvbyte",
    "score_dotvbyte_batch",
    "score_streamvbyte",
    "score_streamvbyte_batch",
    "score_bitpack",
    "score_bitpack_batch",
    "score_bitpack_bucketed",
]


def default_interpret() -> bool:
    """interpret=True unless running on a real TPU backend."""
    return jax.default_backend() != "tpu"


def pad_to(x: np.ndarray, multiple: int, axis: int = -1) -> np.ndarray:
    size = x.shape[axis]
    pad = (-size) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return np.pad(x, widths)


def pad_query_lanes(q: jnp.ndarray) -> jnp.ndarray:
    """Zero-pad the dense query's trailing axis to a 128 multiple —
    jit-traceable, any rank (the registry's rows-kernel entries run it
    on traced values inside the serve graph)."""
    pad = (-q.shape[-1]) % 128
    if pad == 0:
        return q
    return jnp.pad(q, [(0, 0)] * (q.ndim - 1) + [(0, pad)])


def _padded_queries(Q, dim: int) -> jnp.ndarray:
    """Host-side batch form: truncate to ``dim``, then one whole-batch
    lane pad: [nq, ≥dim] → [nq, round_up(dim, 128)]."""
    Q = jnp.asarray(np.asarray(Q, dtype=np.float32)[:, :dim])
    return pad_query_lanes(Q)


def _padded_query(q_dense, dim: int) -> jnp.ndarray:
    return _padded_queries(np.asarray(q_dense, dtype=np.float32)[None, :], dim)[0]


def _lowering(interpret, mode) -> str:
    """Resolve the wrapper's (interpret, mode) pair — ``mode`` wins when
    given; the positional slot keeps accepting the legacy booleans AND
    mode strings (the registry KernelSet calling convention)."""
    return resolve_lowering(mode if mode is not None else interpret)


#: value codecs already warned about the block-scan jnp fallback
_BLOCK_VQ_WARNED: set = set()


def _block_lowering(interpret, mode, packed: PackedBlocks) -> str:
    """Like ``_lowering``, but quantized-value blocks (``packed.vq`` ≠
    f16, DESIGN.md §12) route to the jnp reference: the codec block
    kernels stream raw-dtype value tiles, and only the rows-rescoring
    kernels (the path every engine serves) carry the in-kernel dequant
    stage.  One-time warning, same contract as the missing-rows-kernel
    fallback in ``scoring``."""
    low = _lowering(interpret, mode)
    vq = getattr(packed, "vq", "f16")
    if low != "jnp" and vq != "f16":
        if (packed.codec, vq) not in _BLOCK_VQ_WARNED:
            import warnings

            _BLOCK_VQ_WARNED.add((packed.codec, vq))
            warnings.warn(
                f"codec {packed.codec!r} block scan has no fused "
                f"vq={vq!r} kernel; scoring through the jnp reference "
                f"(the rows-rescoring path decodes vq in-kernel)",
                RuntimeWarning,
                stacklevel=3,
            )
        return "jnp"
    return low


def score_dotvbyte(q_dense, packed: PackedBlocks, interpret=None, *, mode=None):
    """Full fused-kernel scoring path: [n_docs] f32."""
    assert packed.codec == "dotvbyte"
    low = _block_lowering(interpret, mode, packed)
    if low == "jnp":
        return score_packed(q_dense, packed)
    q = _padded_query(q_dense, packed.dim)
    data = pad_to(packed.data, 128, axis=1)
    args = (
        q,
        jnp.asarray(packed.ctrl),
        jnp.asarray(data),
        jnp.asarray(packed.seg),
        jnp.asarray(packed.start_pos),
        jnp.asarray(packed.start_abs),
        jnp.asarray(packed.vals),
    )
    scale = float(packed.value_format.scale)
    if low == "xla":
        block = dotvbyte_block_scores_xla(*args, scale=scale)
    else:
        block = dotvbyte_block_scores(*args, scale=scale, interpret=low == "interpret")
    return scatter_block_scores(block, jnp.asarray(packed.doc_ids), packed.n_docs)


def _combine_batch(block, doc_ids, n_docs: int):
    """[nq, B, D] per-block batch scores → [nq, n_docs] global scores."""
    return jax.vmap(lambda blk: scatter_block_scores(blk, doc_ids, n_docs))(block)


def score_dotvbyte_batch(Q, packed: PackedBlocks, interpret=None, *, mode=None):
    """Decode-once/score-many fused path for a query batch: [nq, n_docs]."""
    assert packed.codec == "dotvbyte"
    low = _block_lowering(interpret, mode, packed)
    if low == "jnp":
        return score_packed_batch(Q, packed)
    Qp = _padded_queries(Q, packed.dim)
    data = pad_to(packed.data, 128, axis=1)
    args = (
        Qp,
        jnp.asarray(packed.ctrl),
        jnp.asarray(data),
        jnp.asarray(packed.seg),
        jnp.asarray(packed.start_pos),
        jnp.asarray(packed.start_abs),
        jnp.asarray(packed.vals),
    )
    scale = float(packed.value_format.scale)
    if low == "xla":
        block = dotvbyte_block_scores_xla_batch(*args, scale=scale)
    else:
        block = dotvbyte_block_scores_batch(*args, scale=scale, interpret=low == "interpret")
    return _combine_batch(block, jnp.asarray(packed.doc_ids), packed.n_docs)


def score_streamvbyte(q_dense, packed: PackedBlocks, interpret=None, *, mode=None):
    """Full fused-kernel StreamVByte scoring path: [n_docs] f32."""
    assert packed.codec == "streamvbyte"
    low = _block_lowering(interpret, mode, packed)
    if low == "jnp":
        return score_packed(q_dense, packed)
    q = _padded_query(q_dense, packed.dim)
    data = pad_to(packed.data, 128, axis=1)
    args = (
        q,
        jnp.asarray(packed.ctrl),
        jnp.asarray(data),
        jnp.asarray(packed.seg),
        jnp.asarray(packed.start_pos),
        jnp.asarray(packed.start_abs),
        jnp.asarray(packed.vals),
    )
    scale = float(packed.value_format.scale)
    if low == "xla":
        block = streamvbyte_block_scores_xla(*args, scale=scale)
    else:
        block = streamvbyte_block_scores(*args, scale=scale, interpret=low == "interpret")
    return scatter_block_scores(block, jnp.asarray(packed.doc_ids), packed.n_docs)


def score_streamvbyte_batch(Q, packed: PackedBlocks, interpret=None, *, mode=None):
    """Decode-once/score-many fused StreamVByte path: [nq, n_docs]."""
    assert packed.codec == "streamvbyte"
    low = _block_lowering(interpret, mode, packed)
    if low == "jnp":
        return score_packed_batch(Q, packed)
    Qp = _padded_queries(Q, packed.dim)
    data = pad_to(packed.data, 128, axis=1)
    args = (
        Qp,
        jnp.asarray(packed.ctrl),
        jnp.asarray(data),
        jnp.asarray(packed.seg),
        jnp.asarray(packed.start_pos),
        jnp.asarray(packed.start_abs),
        jnp.asarray(packed.vals),
    )
    scale = float(packed.value_format.scale)
    if low == "xla":
        block = streamvbyte_block_scores_xla_batch(*args, scale=scale)
    else:
        block = streamvbyte_block_scores_batch(*args, scale=scale, interpret=low == "interpret")
    return _combine_batch(block, jnp.asarray(packed.doc_ids), packed.n_docs)


def score_bitpack(q_dense, packed: PackedBlocks, interpret=None, *, mode=None):
    """Runtime-width bitpack kernel path: [n_docs] f32."""
    assert packed.codec == "bitpack"
    low = _block_lowering(interpret, mode, packed)
    if low == "jnp":
        return score_packed(q_dense, packed)
    q = _padded_query(q_dense, packed.dim)
    words = pad_to(packed.words, 128, axis=1)
    args = (
        q,
        jnp.asarray(words),
        jnp.asarray(packed.widths),
        jnp.asarray(packed.seg),
        jnp.asarray(packed.start_pos),
        jnp.asarray(packed.start_abs),
        jnp.asarray(packed.vals),
    )
    scale = float(packed.value_format.scale)
    if low == "xla":
        block = bitpack_block_scores_xla(*args, scale=scale)
    else:
        block = bitpack_block_scores(*args, scale=scale, interpret=low == "interpret")
    return scatter_block_scores(block, jnp.asarray(packed.doc_ids), packed.n_docs)


def score_bitpack_batch(Q, packed: PackedBlocks, interpret=None, *, mode=None):
    """Decode-once/score-many runtime-width bitpack path: [nq, n_docs]."""
    assert packed.codec == "bitpack"
    low = _block_lowering(interpret, mode, packed)
    if low == "jnp":
        return score_packed_batch(Q, packed)
    Qp = _padded_queries(Q, packed.dim)
    words = pad_to(packed.words, 128, axis=1)
    args = (
        Qp,
        jnp.asarray(words),
        jnp.asarray(packed.widths),
        jnp.asarray(packed.seg),
        jnp.asarray(packed.start_pos),
        jnp.asarray(packed.start_abs),
        jnp.asarray(packed.vals),
    )
    scale = float(packed.value_format.scale)
    if low == "xla":
        block = bitpack_block_scores_xla_batch(*args, scale=scale)
    else:
        block = bitpack_block_scores_batch(*args, scale=scale, interpret=low == "interpret")
    return _combine_batch(block, jnp.asarray(packed.doc_ids), packed.n_docs)


def score_bitpack_bucketed(q_dense, packed: PackedBlocks, interpret=None, *, mode=None):
    """Width-bucketed path: one static-width kernel per distinct width.

    Word arrays are sliced tight per bucket (ceil(T·w/32) words, padded to
    the 128 lane multiple) so HBM traffic tracks the true compressed
    size — the §Perf layout.
    """
    assert packed.codec == "bitpack"
    low = _block_lowering(interpret, mode, packed)
    if low == "jnp":
        return score_packed(q_dense, packed)
    q = _padded_query(q_dense, packed.dim)
    T = packed.block_size
    n_docs = packed.n_docs
    scale = float(packed.value_format.scale)
    total = jnp.zeros((n_docs,), dtype=jnp.float32)
    for w in sorted(set(int(x) for x in packed.widths)):
        sel = np.flatnonzero(packed.widths == w)
        tight = (T * w + 31) // 32
        words = pad_to(packed.words[sel, :tight], 128, axis=1)
        args = (
            q,
            jnp.asarray(words),
            jnp.asarray(packed.seg[sel]),
            jnp.asarray(packed.start_pos[sel]),
            jnp.asarray(packed.start_abs[sel]),
            jnp.asarray(packed.vals[sel]),
        )
        if low == "xla":
            block = bitpack_block_scores_w_xla(*args, width=w, scale=scale)
        else:
            block = bitpack_block_scores_w(
                *args, width=w, scale=scale, interpret=low == "interpret"
            )
        total = total + scatter_block_scores(
            block, jnp.asarray(packed.doc_ids[sel]), n_docs
        )
    return total
