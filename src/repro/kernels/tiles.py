"""Tiled block-scan machinery shared by every codec kernel (DESIGN.md §3).

The PR-6 restructuring: instead of one grid step per packed block (a
``(1, X)`` row at a time — sublane-starved on real Mosaic), every kernel
now processes *tiles* of ``R_TILE`` blocks whose streams were lane-
aligned at pack time (``layout.LANE_MULTIPLE``).  One per-codec **tile
function** — decode a tile's gaps, rebase, gather the query, FMA, and
reduce per-slot via the contiguous-fragment prefix-sum difference
(``scoring.block_slot_scores``) — is shared verbatim by all three
executions of the same program:

* :func:`dma_block_scan` — the Pallas kernel: inputs stay in HBM
  (``memory_space=ANY``); an explicit **double-buffered DMA pipeline**
  copies tile *i+1* HBM→VMEM while tile *i* decodes and scores
  (``pltpu.make_async_copy`` + a 2-slot scratch per stream + DMA
  semaphores).  ``interpret=True`` validates the exact pipeline on any
  host; ``interpret=False`` is the real Mosaic lowering.
* :func:`grid_batch_scores` — the batched Pallas kernel: a 2-D
  **queries×tiles grid** (``Q_TILE`` query rows × ``R_TILE`` blocks per
  step), so each decoded tile scores a whole query tile while Mosaic's
  grid pipeline prefetches the next (decode-once/score-many).
* :func:`xla_block_scores` / :func:`xla_block_scores_batch` — the same
  tile program lowered through XLA: a jit'd ``lax.scan`` over the
  identical tiles.  This is what ``mode="pallas_compiled"`` runs on
  hosts without a Mosaic backend — compiled machine code whose per-tile
  working set stays cache-resident exactly where the TPU pipeline keeps
  it VMEM-resident.

Why the slot reduction wins: the jnp reference reduces B·T products
with one global segment-sum; the tile program reduces each tile to
``[R_TILE, D]`` slot scores first (a prefix-sum difference over the
contiguous fragments) and scatters only B·D values — ~T/D ≈ 8× fewer
elements through the serial scatter, which profiling shows dominates
the jnp scan wall-clock.
"""

from __future__ import annotations

from typing import Callable, Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.scoring import block_slot_scores, components_from_gaps

__all__ = [
    "R_TILE",
    "Q_TILE",
    "tile_scores",
    "tile_scores_batch",
    "pad_axis",
    "dma_block_scan",
    "grid_batch_scores",
    "xla_block_scores",
    "xla_block_scores_batch",
]

#: packed blocks per scan/grid step — 8 f32 sublanes' worth of tiles
R_TILE = 8

#: query rows per grid step in the batched queries×tiles grids
Q_TILE = 8


def pad_axis(x: jnp.ndarray, multiple: int, axis: int = 0, fill=0) -> jnp.ndarray:
    """Trace-time pad of ``axis`` to a multiple (tile-grid alignment).
    ``fill=-1`` builds neutral blocks: seg=-1 elements carry no product
    and doc_ids=-1 slots land in the scatter's overflow bucket."""
    pad = (-x.shape[axis]) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=fill)


# ---------------------------------------------------------------------------
# the shared tile program (gaps already decoded by the codec)
# ---------------------------------------------------------------------------


def _tile_values(vals, scale: float, vq: str, vq_lo, vq_scale, vq_cb):
    """The tile program's dequant stage (DESIGN.md §12): the value tile
    — raw storage dtype under ``vq="f16"``, u8 codes otherwise — →
    scaled f32, through the shared ``values.decode_codes`` helpers, so
    quantized value bytes are what the tile DMA'd and f32 rows exist
    only in the tile working set."""
    if vq == "f16":
        return vals.astype(jnp.float32) * jnp.float32(scale)
    from repro.core import values as value_codecs

    cb = vq_cb.reshape(-1) if vq == "pq" else None
    return value_codecs.decode_codes(
        vq, vals, vq_lo, vq_scale, cb
    ) * jnp.float32(scale)


def tile_scores(
    q, gaps, seg, sp, sa, vals, scale: float,
    vq: str = "f16", vq_lo=None, vq_scale=None, vq_cb=None,
) -> jnp.ndarray:
    """One tile, one query: [R, T] streams → [R, D] slot scores."""
    comps = components_from_gaps(gaps, seg, sp, sa)
    qv = jnp.take(q, comps, axis=0)
    prod = qv * _tile_values(vals, scale, vq, vq_lo, vq_scale, vq_cb)
    prod = prod * (seg >= 0).astype(jnp.float32)
    return block_slot_scores(prod, sp)


def tile_scores_batch(
    Q, gaps, seg, sp, sa, vals, scale: float,
    vq: str = "f16", vq_lo=None, vq_scale=None, vq_cb=None,
) -> jnp.ndarray:
    """One tile, a query tile: decode once, score [nq, R, D]."""
    comps = components_from_gaps(gaps, seg, sp, sa)
    w = _tile_values(vals, scale, vq, vq_lo, vq_scale, vq_cb)
    w = w * (seg >= 0).astype(jnp.float32)
    qv = jnp.take(Q, comps, axis=1)  # [nq, R, T]
    return block_slot_scores(qv * w[None], sp)


# ---------------------------------------------------------------------------
# Pallas: double-buffered HBM→VMEM DMA block scan (single query)
# ---------------------------------------------------------------------------


def dma_block_scan(
    tile_fn: Callable,
    q: jnp.ndarray,  # [V] f32, V % 128 == 0 (VMEM-resident)
    streams: Sequence[jnp.ndarray],  # each [Bp, W_s], Bp % R_TILE == 0
    out_dim: int,  # D
    interpret: bool,
) -> jnp.ndarray:
    """Run ``tile_fn(q, *stream_tiles) → [R_TILE, D]`` over all tiles
    with an explicit two-slot DMA pipeline: tile i+1's streams are
    in flight HBM→VMEM while tile i decodes and scores.  Streams stay
    in HBM (``memory_space=ANY``); only the 2-slot scratch and the
    [Bp, D] output live in VMEM.  Returns [Bp, D] slot scores."""
    n_s = len(streams)
    Bp = streams[0].shape[0]
    nt = Bp // R_TILE
    V = q.shape[0]

    def kernel(q_ref, *refs):
        stream_refs, out_ref = refs[:n_s], refs[n_s]

        def scoped(*args):
            scratches, sem = args[:-1], args[-1]

            def copies(slot, i):
                return [
                    pltpu.make_async_copy(
                        stream_refs[s].at[pl.ds(i * R_TILE, R_TILE)],
                        scratches[s].at[slot],
                        sem.at[slot, s],
                    )
                    for s in range(n_s)
                ]

            for c in copies(0, 0):  # warm-up: tile 0 in flight
                c.start()

            def body(i, carry):
                slot = jax.lax.rem(i, 2)

                @pl.when(i + 1 < nt)
                def _():  # prefetch tile i+1 into the other slot
                    for c in copies(jax.lax.rem(i + 1, 2), i + 1):
                        c.start()

                for c in copies(slot, i):  # wait for tile i
                    c.wait()
                tiles = [scratches[s][slot] for s in range(n_s)]
                out_ref[pl.ds(i * R_TILE, R_TILE), :] = tile_fn(q_ref[0], *tiles)
                return carry

            jax.lax.fori_loop(0, nt, body, 0)

        pl.run_scoped(
            scoped,
            *[pltpu.VMEM((2, R_TILE, s.shape[1]), s.dtype) for s in streams],
            pltpu.SemaphoreType.DMA((2, n_s)),
        )

    return pl.pallas_call(
        kernel,
        in_specs=[pl.BlockSpec((1, V), lambda: (0, 0))]
        + [pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.ANY)] * n_s,
        out_specs=pl.BlockSpec((Bp, out_dim), lambda: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((Bp, out_dim), jnp.float32),
        interpret=interpret,
    )(q[None, :], *streams)


# ---------------------------------------------------------------------------
# Pallas: queries×tiles batched grid (decode once, score a query tile)
# ---------------------------------------------------------------------------


def grid_batch_scores(
    tile_fn_batch: Callable,
    Q: jnp.ndarray,  # [nqp, V] f32, nqp % Q_TILE == 0
    streams: Sequence[jnp.ndarray],  # each [Bp, W_s], Bp % R_TILE == 0
    out_dim: int,
    interpret: bool,
) -> jnp.ndarray:
    """2-D grid (query tiles × block tiles); each step decodes one
    block tile and scores one resident query tile against it
    (``tile_fn_batch(Q_tile, *stream_tiles) → [Q_TILE, R_TILE, D]``).
    Mosaic's grid pipeline double-buffers the tile streams between
    steps.  Returns [nqp, Bp, D]."""
    nqp, V = Q.shape
    Bp = streams[0].shape[0]
    grid = (nqp // Q_TILE, Bp // R_TILE)

    def kernel(q_ref, *refs):
        stream_refs, out_ref = refs[:-1], refs[-1]
        out_ref[...] = tile_fn_batch(q_ref[...], *[r[...] for r in stream_refs])

    in_specs = [pl.BlockSpec((Q_TILE, V), lambda qi, bi: (qi, 0))] + [
        pl.BlockSpec((R_TILE, s.shape[1]), lambda qi, bi: (bi, 0)) for s in streams
    ]
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((Q_TILE, R_TILE, out_dim), lambda qi, bi: (qi, bi, 0)),
        out_shape=jax.ShapeDtypeStruct((nqp, Bp, out_dim), jnp.float32),
        interpret=interpret,
    )(Q, *streams)


# ---------------------------------------------------------------------------
# XLA lowering: the same tile program as a jit'd lax.scan
# ---------------------------------------------------------------------------


def xla_block_scores(
    tile_fn: Callable, q: jnp.ndarray, streams: Sequence[jnp.ndarray], out_dim: int
) -> jnp.ndarray:
    """``lax.scan`` of the tile program over [nt, R_TILE, W] views —
    the compiled fallback of :func:`dma_block_scan`. [Bp, D]."""
    Bp = streams[0].shape[0]
    nt = Bp // R_TILE
    tiles = tuple(s.reshape(nt, R_TILE, s.shape[1]) for s in streams)

    def step(carry, ts):
        return carry, tile_fn(q, *ts)

    _, out = jax.lax.scan(step, 0, tiles)
    return out.reshape(Bp, out_dim)


def xla_block_scores_batch(
    tile_fn_batch: Callable,
    Q: jnp.ndarray,
    streams: Sequence[jnp.ndarray],
    out_dim: int,
) -> jnp.ndarray:
    """Batched form of :func:`xla_block_scores`: decode each tile once,
    score the whole query batch. [nq, Bp, D]."""
    Bp = streams[0].shape[0]
    nt = Bp // R_TILE
    tiles = tuple(s.reshape(nt, R_TILE, s.shape[1]) for s in streams)

    def step(carry, ts):
        return carry, tile_fn_batch(Q, *ts)

    _, out = jax.lax.scan(step, 0, tiles)  # [nt, nq, R, D]
    return out.transpose(1, 0, 2, 3).reshape(Q.shape[0], Bp, out_dim)
