"""olmoe-1b-7b — OLMoE: 7B total / 1B active MoE LM.

16L d_model=2048 16H (GQA kv=16 ⇒ MHA) d_ff=1024/expert vocab=50304,
MoE 64 experts top-8.  [arXiv:2409.02060; hf]
"""

import jax.numpy as jnp

from repro.models.moe import MoEConfig
from repro.models.transformer import TransformerConfig
from repro.train.optimizer import OptimizerConfig

from .base import LMArch

ARCH = LMArch(
    name="olmoe-1b-7b",
    cfg=TransformerConfig(
        name="olmoe-1b-7b",
        n_layers=16,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        d_ff=1024,
        vocab=50304,
        qk_norm=True,  # OLMoE uses QK-norm
        moe=MoEConfig(n_experts=64, top_k=8, d_model=2048, d_ff=1024),
        dtype=jnp.bfloat16,
    ),
    optimizer=OptimizerConfig(name="adamw", lr=4e-4, warmup_steps=2000, total_steps=500_000),
    microbatches=8,
    smoke_cfg=TransformerConfig(
        name="olmoe-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=32,
        vocab=256,
        qk_norm=True,
        moe=MoEConfig(n_experts=8, top_k=2, d_model=64, d_ff=32),
        dtype=jnp.float32,
    ),
)
