"""yi-6b — llama-architecture dense LM with aggressive GQA.

32L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000.
[arXiv:2403.04652; hf]
"""

import jax.numpy as jnp

from repro.models.transformer import TransformerConfig
from repro.train.optimizer import OptimizerConfig

from .base import LMArch

ARCH = LMArch(
    name="yi-6b",
    cfg=TransformerConfig(
        name="yi-6b",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=4,
        d_ff=11008,
        vocab=64000,
        dtype=jnp.bfloat16,
    ),
    optimizer=OptimizerConfig(name="adamw", lr=3e-4, warmup_steps=2000, total_steps=500_000),
    microbatches=8,
    smoke_cfg=TransformerConfig(
        name="yi-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab=256,
        dtype=jnp.float32,
    ),
)
