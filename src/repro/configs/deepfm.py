"""deepfm — DeepFM CTR model (Guo et al., IJCAI 2017).

39 sparse fields, embed_dim=10, MLP 400-400-400, FM interaction.
Criteo-scale heterogeneous vocabularies (~20.6M total rows) exercise the
row-sharded embedding path.  [arXiv:1703.04247; paper]
"""

from repro.models.recsys import DeepFMConfig
from repro.train.optimizer import OptimizerConfig

from .base import RecsysArch

_VOCABS = (
    (10_000_000, 4_000_000, 2_000_000, 1_000_000)
    + (500_000,) * 5
    + (100_000,) * 10
    + (10_000,) * 10
    + (1_000,) * 10
)
assert len(_VOCABS) == 39

ARCH = RecsysArch(
    name="deepfm",
    cfg=DeepFMConfig(vocab_sizes=_VOCABS, embed_dim=10, mlp=(400, 400, 400)),
    optimizer=OptimizerConfig(name="adamw", lr=1e-3, warmup_steps=100, total_steps=100_000),
    smoke_cfg=DeepFMConfig(vocab_sizes=(64,) * 39, embed_dim=4, mlp=(16, 16, 16)),
)
