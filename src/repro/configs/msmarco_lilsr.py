"""msmarco-lilsr — the paper's inference-free encoder workload.

LILSR (Nardini et al., SIGIR 2025): no query expansion (6 nnz/query)
but 3.2× heavier document expansion (387 nnz/doc) — the compression
stress case in Table 2.
"""

from .retrieval import RetrievalArch

ARCH = RetrievalArch(
    name="msmarco-lilsr",
    dim=30522,
    n_docs=8_842_240,  # 8,841,823 MsMarco passages, padded to /512
    doc_nnz=387,
    query_nnz=6,
    l_max=768,
)
