"""qwen3-8b — dense LM with QK-norm and GQA.

36L d_model=4096 32H (GQA kv=8) d_ff=12288 vocab=151936.
[hf:Qwen/Qwen3-8B; hf]
"""

import jax.numpy as jnp

from repro.models.transformer import TransformerConfig
from repro.train.optimizer import OptimizerConfig

from .base import LMArch

ARCH = LMArch(
    name="qwen3-8b",
    cfg=TransformerConfig(
        name="qwen3-8b",
        n_layers=36,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_head=128,
        d_ff=12288,
        vocab=151936,
        qk_norm=True,
        rope_theta=1_000_000.0,
        dtype=jnp.bfloat16,
    ),
    optimizer=OptimizerConfig(name="adamw", lr=3e-4, warmup_steps=2000, total_steps=500_000),
    microbatches=8,
    smoke_cfg=TransformerConfig(
        name="qwen3-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab=256,
        qk_norm=True,
        dtype=jnp.float32,
    ),
)
