"""msmarco-splade — the paper's primary evaluation workload.

MsMarco passages (8.84M docs) encoded with SPLADE (Formal et al.):
119 nonzeros per document, 43 per query, vocab 30522 (§3 of the paper).
"""

from .retrieval import RetrievalArch

ARCH = RetrievalArch(
    name="msmarco-splade",
    dim=30522,
    n_docs=8_842_240,  # 8,841,823 MsMarco passages, padded to /512
    doc_nnz=119,
    query_nnz=43,
    l_max=384,
)
