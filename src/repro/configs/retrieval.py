"""RetrievalArch — the paper's own workload as first-class configs.

Three cells per config (extra rows beyond the assigned 40):

* ``scan_100q``  — Table 1's hot loop: decode+dot of EVERY document
  against a query batch through the DotVByte packed-block path (the
  jnp lowering of the fused kernel semantics; the Pallas kernel is the
  Mosaic-targeted version of exactly this graph).
* ``serve_4096q`` — the production two-phase batched Seismic search,
  index sharded over ``model`` (16 self-contained sub-indexes), queries
  sharded over ``data``, O(k) all-gather merge.
* ``graph_4096q`` — the batched HNSW beam search (DESIGN.md §5) over
  the same sharding layout: per-shard sub-graphs over ``model``,
  queries over ``data``, O(k) all-gather merge.

Array sizes derive from MsMarco statistics (8.84M passages; SPLADE
119 nnz/doc, LILSR 387 nnz/doc — §3 of the paper).
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.core.scoring import (
    block_products,
    combine_block_scores,
    components_from_gaps,
    decode_block_gaps,
    dequantise_values,
)
from repro.dist import sharding as shd
from repro.serve.api import RetrieverConfig, get_engine, make_sharded_search

from .base import BaseArch, Cell

__all__ = ["RetrievalArch", "RETRIEVAL_SHAPES"]

RETRIEVAL_SHAPES = {
    "scan_100q": dict(kind="serve", n_queries=100),
    "serve_4096q": dict(kind="serve", n_queries=4096),
    "graph_4096q": dict(kind="serve", n_queries=4096),
}


@dataclasses.dataclass
class RetrievalArch(BaseArch):
    name: str
    dim: int = 30522
    n_docs: int = 8_841_823
    doc_nnz: int = 119
    query_nnz: int = 43
    block_size: int = 512
    docs_per_block: int = 64
    l_max: int = 384  # per-doc row capacity (p100 nnz, 8-aligned)
    graph_degree: int = 32  # HNSW base-layer degree (2·m, m=16)
    value_scale: float = 1.0
    codec: str = "dotvbyte"  # any core/layout.py stream codec
    family: str = "retrieval"
    shape_names: tuple[str, ...] = tuple(RETRIEVAL_SHAPES)
    # §Perf opt levels for scan_100q (EXPERIMENTS.md):
    #   0 = paper-faithful baseline (jit auto-sharding, global segment-sum)
    #   1 = + doc-aligned shard_map (scatter stays device-local, no
    #       collectives on the scan path)
    #   2 = + i8 seg metadata (4× smaller dominant stream)
    #   3 = + decode-once/score-many (hoist the DotVByte decode out of
    #       the query vmap — amortises decode traffic over the batch)
    opt: int = 0

    # ------------------------------------------------------------------
    @property
    def n_blocks(self) -> int:
        # ~5% fragmentation overhead from per-document block boundaries,
        # rounded up to the 512-chip flat mesh for even sharding
        raw = int(self.n_docs * self.doc_nnz / self.block_size * 1.05) + 1
        return (raw + 511) // 512 * 512

    def packed_structs(self) -> dict:
        """ShapeDtypeStructs of the packed-block index — codec stream
        fields mirror what ``layout.pack_blocks(codec=…)`` produces."""
        sds = jax.ShapeDtypeStruct
        B, T, D = self.n_blocks, self.block_size, self.docs_per_block
        seg_dt = jnp.int8 if self.opt >= 2 else jnp.int32
        structs = {
            "seg": sds((B, T), seg_dt),
            "start_pos": sds((B, D), jnp.int32),
            "start_abs": sds((B, D), jnp.int32),
            "vals": sds((B, T), jnp.float16),
            "doc_ids": sds((B, D), jnp.int32),
        }
        if self.codec == "uncompressed":
            structs["comps"] = sds((B, T), jnp.int32)
        elif self.codec == "bitpack":
            # per-block width ≤ 16 bits for a 30522-dim vocabulary
            structs["words"] = sds((B, (T * 16 + 31) // 32), jnp.uint32)
            structs["widths"] = sds((B,), jnp.int32)
        else:  # dotvbyte (1-bit ctrl) | streamvbyte (2-bit ctrl)
            ctrl_group = 8 if self.codec == "dotvbyte" else 4
            DP = ((T + T // 2) // 128 + 1) * 128  # ~1.5 B/component + over-read
            structs["ctrl"] = sds((B, T // ctrl_group), jnp.uint8)
            structs["data"] = sds((B, DP), jnp.uint8)
        return structs

    def model_flops(self, shape: str) -> float:
        nq = RETRIEVAL_SHAPES[shape]["n_queries"]
        if shape == "scan_100q":
            # useful work: 2 flops per (query × nonzero)
            return 2.0 * self.n_docs * self.doc_nnz * nq
        if shape == "graph_4096q":
            gp = self._graph_cfg().params
            # one neighbour list scored per expanded node
            per_q = (gp["iters"] * self.graph_degree + gp["n_seeds"]) * self.l_max * 2
            return float(per_q) * nq
        ep = self._engine_cfg().params
        per_q = ep["block_budget"] * 64 * 2 + ep["n_probe"] * 64 * self.l_max * 2
        return float(per_q) * nq

    def _engine_cfg(self) -> RetrieverConfig:
        # every codec registered in core/layout.py serves the row form
        return RetrieverConfig(engine="seismic", codec=self.codec, k=10,
                               params=dict(cut=8, block_budget=512, n_probe=64))

    def _graph_cfg(self) -> RetrieverConfig:
        return RetrieverConfig(engine="hnsw", codec=self.codec, k=10,
                               params=dict(beam=64, iters=64, n_seeds=8))

    # ------------------------------------------------------------------
    def build_cell(self, shape: str, mesh: Mesh) -> Cell:
        da = shd.data_axes(mesh)
        flat = (*da, "model")
        nq = RETRIEVAL_SHAPES[shape]["n_queries"]
        dim_pad = ((self.dim + 127) // 128) * 128

        if shape == "scan_100q":
            n_docs, T, scale = self.n_docs, self.block_size, self.value_scale
            codec = self.codec

            if self.opt == 0:
                # paper-faithful baseline: jit auto-sharding; the global
                # segment-sum scatters block partials across shards
                def scan_fn(arrays, Q):
                    def one(q):
                        if codec == "uncompressed":
                            comps = arrays["comps"]
                        else:
                            gaps = decode_block_gaps(codec, arrays, T)
                            comps = components_from_gaps(
                                gaps, arrays["seg"], arrays["start_pos"],
                                arrays["start_abs"],
                            )
                        prod = block_products(
                            q, comps, dequantise_values(arrays["vals"], scale), arrays["seg"]
                        )
                        return combine_block_scores(prod, arrays["seg"], arrays["doc_ids"], n_docs)

                    return jax.vmap(one)(Q)

                fn = scan_fn
            else:
                # §Perf opt≥1: doc-aligned shard_map — each device owns a
                # contiguous doc range AND exactly the blocks packing those
                # docs, so the scatter is device-local and the scan path
                # has ZERO collectives (queries replicated). Arrays carry
                # an explicit leading shard dim (pack_forward_index_sharded
                # builds them; scoring.make_doc_aligned_scan consumes; see
                # tests/test_dist.py for the real-data exactness check).
                # Note opt3's decode-once hoist is subsumed: XLA LICM
                # already hoists the query-invariant decode (§Perf log).
                from repro.core.scoring import make_doc_aligned_scan

                n_shards = 1
                for a in flat:
                    n_shards *= mesh.shape[a]
                docs_local = self.n_docs // n_shards
                fn = make_doc_aligned_scan(mesh, flat, docs_local, scale, codec=codec)

            base_structs = self.packed_structs()
            if self.opt >= 1:
                n_shards = 1
                for a in flat:
                    n_shards *= mesh.shape[a]
                structs_idx = {
                    k: jax.ShapeDtypeStruct(
                        (n_shards, v.shape[0] // n_shards, *v.shape[1:]), v.dtype
                    )
                    for k, v in base_structs.items()
                }
                arr_specs = {k: P(flat, *([None] * v.ndim))
                             for k, v in base_structs.items()}
            else:
                structs_idx = base_structs
                arr_specs = {k: P(flat, *([None] * (v.ndim - 1)))
                             for k, v in base_structs.items()}
            structs = (
                structs_idx,
                jax.ShapeDtypeStruct((nq, dim_pad), jnp.float32),
            )
            return Cell(
                self.name, shape, "serve", fn, structs,
                (shd.to_shardings(mesh, arr_specs), shd.to_shardings(mesh, P(None, None))),
                shd.to_shardings(mesh, P(None, flat)),
                self.model_flops(shape),
                {"n_docs": self.n_docs, "payload_bytes": self._payload_bytes(),
                 "opt": self.opt},
            )

        if shape == "graph_4096q":
            # sharded HNSW beam search (DESIGN.md §5): per-shard
            # sub-graphs over ``model``, same row arrays as serve_4096q
            gcfg = self._graph_cfg()
            n_shards = mesh.shape["model"]
            n_docs_local = self.n_docs // n_shards + 1
            arr = get_engine("hnsw").array_specs(
                gcfg,
                n_docs=n_docs_local,
                degree=self.graph_degree,
                l_max=self.l_max,
                d_max=((self.l_max + self.l_max // 2) // 128 + 1) * 128,
            )
            arr_stacked = {
                k: jax.ShapeDtypeStruct((n_shards, *v.shape), v.dtype)
                for k, v in arr.items()
            }
            idmap = jax.ShapeDtypeStruct((n_shards, n_docs_local + 1), jnp.int32)
            fn = make_sharded_search(
                mesh, gcfg, n_docs_local, self.n_docs, self.value_scale,
                index_axis="model", query_axes=da,
            )
            structs = (arr_stacked, idmap, jax.ShapeDtypeStruct((nq, self.dim), jnp.float32))
            in_sh = (
                shd.to_shardings(mesh, {k: P("model") for k in arr_stacked}),
                shd.to_shardings(mesh, P("model")),
                shd.to_shardings(mesh, P(da, None)),
            )
            out_sh = shd.to_shardings(mesh, (P(da, None), P(da, None)))
            return Cell(
                self.name, shape, "serve", fn, structs, in_sh, out_sh,
                self.model_flops(shape),
                {"n_docs": self.n_docs, "n_shards": n_shards},
            )

        # serve_4096q — sharded two-phase search
        ecfg = self._engine_cfg()
        n_shards = mesh.shape["model"]
        n_docs_local = self.n_docs // n_shards + 1
        n_blocks_inv = int(min(self.dim * 4000, self.n_docs * self.doc_nnz) / 64) + 1
        arr = get_engine("seismic").array_specs(
            ecfg,
            dim=self.dim,
            n_docs=n_docs_local,
            n_blocks=n_blocks_inv // n_shards + 1,
            s_max=64,
            bs_max=64,
            l_max=self.l_max,
            d_max=((self.l_max + self.l_max // 2) // 128 + 1) * 128,
        )
        arr_stacked = {
            k: jax.ShapeDtypeStruct((n_shards, *v.shape), v.dtype) for k, v in arr.items()
        }
        idmap = jax.ShapeDtypeStruct((n_shards, n_docs_local + 1), jnp.int32)
        fn = make_sharded_search(
            mesh, ecfg, n_docs_local, self.n_docs, self.value_scale,
            index_axis="model", query_axes=da,
        )
        structs = (arr_stacked, idmap, jax.ShapeDtypeStruct((nq, self.dim), jnp.float32))
        in_sh = (
            shd.to_shardings(mesh, {k: P("model") for k in arr_stacked}),
            shd.to_shardings(mesh, P("model")),
            shd.to_shardings(mesh, P(da, None)),
        )
        out_sh = shd.to_shardings(mesh, (P(da, None), P(da, None)))
        return Cell(
            self.name, shape, "serve", fn, structs, in_sh, out_sh,
            self.model_flops(shape),
            {"n_docs": self.n_docs, "n_shards": n_shards},
        )

    def _payload_bytes(self) -> int:
        s = self.packed_structs()
        return sum(int(jnp.dtype(v.dtype).itemsize) * int(jnp.prod(jnp.array(v.shape)))
                   for v in s.values())

    # ------------------------------------------------------------------
    def smoke(self, seed: int = 0) -> dict:
        """End-to-end mini pipeline: synth collection → pack → score."""
        import numpy as np

        from repro.core.forward_index import ForwardIndex, pack_forward_index
        from repro.core.scoring import score_packed
        from repro.data.synthetic import SyntheticConfig, generate_collection

        cfg = SyntheticConfig(
            name="smoke", dim=2048, n_docs=200, n_queries=4,
            doc_nnz_mean=min(float(self.doc_nnz), 60.0),
            query_nnz_mean=float(min(self.query_nnz, 16)), seed=seed,
        )
        col = generate_collection(cfg, value_format="f16")
        packed = pack_forward_index(col.fwd, codec=self.codec, block_size=128)
        q = col.query_dense(0)
        got = np.asarray(score_packed(q, packed))
        want = col.fwd.exact_scores(q)
        err = float(np.abs(got - want).max())
        assert err < 2e-3, err
        return {"max_err": err}
