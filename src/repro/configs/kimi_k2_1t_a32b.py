"""kimi-k2-1t-a32b — Kimi K2: trillion-param MoE, 32B active.

61L d_model=7168 64H (GQA kv=8) d_ff=2048/expert vocab=163840,
MoE 384 experts top-8 (+1 shared expert per public spec).
[arXiv:2501.kimi2; unverified — paper-table config]

Adafactor (factored second moment, bf16 state) + bf16 params keep the
optimizer+param HBM inside a v5e pod: AdamW f32 m/v alone would need
8 TB (16 GB/chip on 512 chips) before params and activations.
"""

import jax.numpy as jnp

from repro.models.moe import MoEConfig
from repro.models.transformer import TransformerConfig
from repro.train.optimizer import OptimizerConfig

from .base import LMArch

ARCH = LMArch(
    name="kimi-k2-1t-a32b",
    cfg=TransformerConfig(
        name="kimi-k2-1t-a32b",
        n_layers=61,
        d_model=7168,
        n_heads=64,
        n_kv_heads=8,
        d_head=112,  # 7168 / 64
        d_ff=2048,
        vocab=163840,
        moe=MoEConfig(
            n_experts=384, top_k=8, d_model=7168, d_ff=2048, n_shared_experts=1
        ),
        dtype=jnp.bfloat16,
    ),
    optimizer=OptimizerConfig(
        name="adafactor",
        lr=2e-4,
        warmup_steps=2000,
        total_steps=500_000,
        state_dtype=jnp.bfloat16,
    ),
    microbatches=8,  # grad accumulation: activations / 8 per microbatch
    smoke_cfg=TransformerConfig(
        name="kimi-smoke",
        n_layers=2,
        d_model=64,
        n_heads=8,
        n_kv_heads=2,
        d_head=8,
        d_ff=32,
        vocab=256,
        moe=MoEConfig(n_experts=8, top_k=2, d_model=64, d_ff=32, n_shared_experts=1),
        dtype=jnp.float32,
    ),
)
