"""dcn-v2 — Deep & Cross Network v2 (Wang et al., WWW 2021).

13 dense + 26 sparse fields, embed_dim=16, 3 full-matrix cross layers,
MLP 1024-1024-512 (stacked). [arXiv:2008.13535; paper]
"""

from repro.models.recsys import DCNv2Config
from repro.train.optimizer import OptimizerConfig

from .base import RecsysArch

_VOCABS = (
    (10_000_000, 4_000_000, 2_000_000, 1_000_000)
    + (500_000,) * 4
    + (100_000,) * 8
    + (10_000,) * 10
)
assert len(_VOCABS) == 26

ARCH = RecsysArch(
    name="dcn-v2",
    cfg=DCNv2Config(
        vocab_sizes=_VOCABS, embed_dim=16, n_cross_layers=3, mlp=(1024, 1024, 512)
    ),
    optimizer=OptimizerConfig(name="adamw", lr=1e-3, warmup_steps=100, total_steps=100_000),
    smoke_cfg=DCNv2Config(vocab_sizes=(64,) * 26, embed_dim=4, n_cross_layers=2, mlp=(16, 16)),
)
