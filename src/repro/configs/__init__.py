"""Config registry: ``--arch <id>`` resolution for every launcher.

The 10 assigned architectures (×4 shapes each = 40 dry-run cells) plus
the paper's own retrieval configs (extra cells)."""

from __future__ import annotations

from importlib import import_module

__all__ = ["ARCH_IDS", "RETRIEVAL_IDS", "get_arch", "all_cells"]

ARCH_IDS = (
    # LM family
    "olmoe-1b-7b",
    "kimi-k2-1t-a32b",
    "qwen3-8b",
    "yi-6b",
    "deepseek-coder-33b",
    # GNN
    "gat-cora",
    # RecSys
    "deepfm",
    "sasrec",
    "dcn-v2",
    "din",
)

RETRIEVAL_IDS = ("msmarco-splade", "msmarco-lilsr")

_MODULES = {
    "olmoe-1b-7b": "olmoe_1b_7b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "qwen3-8b": "qwen3_8b",
    "yi-6b": "yi_6b",
    "deepseek-coder-33b": "deepseek_coder_33b",
    "gat-cora": "gat_cora",
    "deepfm": "deepfm",
    "sasrec": "sasrec",
    "dcn-v2": "dcn_v2",
    "din": "din",
    "msmarco-splade": "msmarco_splade",
    "msmarco-lilsr": "msmarco_lilsr",
}


def get_arch(arch_id: str):
    """Resolve an arch id. Retrieval configs accept a ``-optN`` suffix
    selecting the §Perf optimisation level (see configs/retrieval.py)."""
    opt = 0
    base = arch_id
    if "-opt" in arch_id:
        base, _, lvl = arch_id.rpartition("-opt")
        opt = int(lvl)
    if base not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; have {sorted(_MODULES)}")
    arch = import_module(f"repro.configs.{_MODULES[base]}").ARCH
    if opt:
        import dataclasses

        arch = dataclasses.replace(arch, name=arch_id, opt=opt)
    return arch


def all_cells(include_retrieval: bool = True):
    """Yield (arch_id, shape_name) for every dry-run cell."""
    ids = ARCH_IDS + (RETRIEVAL_IDS if include_retrieval else ())
    for a in ids:
        arch = get_arch(a)
        for s in arch.shape_names:
            yield a, s
