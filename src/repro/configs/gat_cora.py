"""gat-cora — Graph Attention Network (Veličković et al., ICLR 2018).

2 layers, d_hidden=8, 8 heads, attention aggregator. d_in/n_classes
track the per-shape dataset (Cora / Reddit / ogbn-products / molecule).
[arXiv:1710.10903; paper]
"""

from .base import GNNArch

ARCH = GNNArch(name="gat-cora", n_layers=2, d_hidden=8, n_heads=8)
