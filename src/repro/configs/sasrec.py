"""sasrec — Self-Attentive Sequential Recommendation (Kang & McAuley,
ICDM 2018).

embed_dim=50, 2 blocks, 1 head, seq_len=50, self-attention over the
user's item sequence; 10⁶-item embedding table row-sharded.
[arXiv:1808.09781; paper]
"""

from repro.models.recsys import SASRecConfig
from repro.train.optimizer import OptimizerConfig

from .base import RecsysArch

ARCH = RecsysArch(
    name="sasrec",
    cfg=SASRecConfig(
        n_items=1_000_000, embed_dim=50, n_blocks=2, n_heads=1, seq_len=50,
        n_negatives=128,
    ),
    optimizer=OptimizerConfig(name="adamw", lr=1e-3, warmup_steps=100, total_steps=100_000),
    smoke_cfg=SASRecConfig(n_items=512, embed_dim=16, n_blocks=2, n_heads=1, seq_len=12, n_negatives=4),
)
