"""din — Deep Interest Network (Zhou et al., KDD 2018).

embed_dim=18, history seq_len=100, target-attention MLP 80-40, final
MLP 200-80. [arXiv:1706.06978; paper]
"""

from repro.models.recsys import DINConfig
from repro.train.optimizer import OptimizerConfig

from .base import RecsysArch

ARCH = RecsysArch(
    name="din",
    cfg=DINConfig(
        n_items=1_000_000, embed_dim=18, seq_len=100, attn_mlp=(80, 40), mlp=(200, 80)
    ),
    optimizer=OptimizerConfig(name="adamw", lr=1e-3, warmup_steps=100, total_steps=100_000),
    smoke_cfg=DINConfig(n_items=512, embed_dim=8, seq_len=10, attn_mlp=(16, 8), mlp=(32, 16)),
)
