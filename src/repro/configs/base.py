"""Arch/Cell abstraction: every assigned architecture is a selectable
config exposing, per input shape, everything the dry-run needs:

    build_cell(shape, mesh) -> Cell(fn, input_structs, in_shardings,
                                    out_shardings, meta)

``fn`` is the jit-able step (train_step / prefill / decode / serve);
``input_structs`` are ShapeDtypeStructs (weak-type-correct, never
allocated); shardings are NamedShardings built from the family rules in
repro.dist.sharding. ``jax.jit(fn, in_shardings=…).lower(*structs)
.compile()`` must succeed on the 16×16 and 2×16×16 meshes — that is the
multi-pod dry-run contract.

MODEL_FLOPS (6·N·D dense / 6·N_active·D MoE) is reported per cell for
the §Roofline useful-compute ratio.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.dist import sharding as shd
from repro.models import gnn as gnn_m
from repro.models import recsys as rec_m
from repro.models import transformer as tf_m
from repro.models.moe import MoEConfig
from repro.train.optimizer import OptimizerConfig, make_optimizer
from repro.train.train_step import make_train_step

__all__ = ["Cell", "BaseArch", "LMArch", "GNNArch", "RecsysArch", "count_abstract_params"]


@dataclasses.dataclass
class Cell:
    arch: str
    shape: str
    kind: str  # train | prefill | decode | serve
    fn: Callable
    input_structs: tuple
    in_shardings: tuple
    out_shardings: Any
    model_flops: float  # 6·N·D (per executed step, global)
    meta: dict


def count_abstract_params(tree) -> int:
    return sum(int(np.prod(l.shape)) for l in jax.tree.leaves(tree))


def _sds(tree_of_abstract):
    return jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), tree_of_abstract
    )


class BaseArch:
    # NOTE: bare annotations only — assigning defaults here would leak
    # into subclass dataclass field defaults via getattr().
    name: str
    family: str
    shape_names: tuple[str, ...]

    def build_cell(self, shape: str, mesh: Mesh) -> Cell:
        raise NotImplementedError

    # smoke-test hook: return (loss_value, metrics) on a tiny CPU config
    def smoke(self, seed: int = 0) -> dict:
        raise NotImplementedError


# ---------------------------------------------------------------------------
# optimizer-state spec mirroring
# ---------------------------------------------------------------------------


def _adamw_state_specs(param_specs):
    return {"m": param_specs, "v": param_specs, "step": P()}


def _adafactor_state_specs(param_specs, abstract_params, opt_cfg: OptimizerConfig):
    from repro.train.optimizer import _factored

    def one(spec, p):
        if _factored(p, opt_cfg):
            return {
                "vr": P(*spec[: p.ndim - 1]) if len(spec) else P(),
                "vc": P(*spec[: p.ndim - 2], *spec[p.ndim - 1 : p.ndim]) if len(spec) else P(),
            }
        return {"v": spec}

    second = jax.tree.map(
        one, param_specs, abstract_params, is_leaf=lambda x: isinstance(x, P)
    )
    return {"second": second, "step": P()}


def _state_specs(param_specs, abstract_params, opt_cfg: OptimizerConfig):
    if opt_cfg.name == "adamw":
        opt = _adamw_state_specs(param_specs)
    else:
        opt = _adafactor_state_specs(param_specs, abstract_params, opt_cfg)
    return {"params": param_specs, "opt": opt}


# ---------------------------------------------------------------------------
# LM family
# ---------------------------------------------------------------------------

LM_SHAPES = {
    "train_4k": dict(kind="train", seq_len=4096, global_batch=256),
    "prefill_32k": dict(kind="prefill", seq_len=32768, global_batch=32),
    "decode_32k": dict(kind="decode", seq_len=32768, global_batch=128),
    "long_500k": dict(kind="decode", seq_len=524288, global_batch=1),
}


@dataclasses.dataclass
class LMArch(BaseArch):
    name: str
    cfg: tf_m.TransformerConfig
    optimizer: OptimizerConfig
    family: str = "lm"
    microbatches: int = 1
    shape_names: tuple[str, ...] = tuple(LM_SHAPES)
    smoke_cfg: tf_m.TransformerConfig | None = None

    # -- abstract state ---------------------------------------------------
    def abstract_params(self):
        return jax.eval_shape(lambda k: tf_m.init_params(k, self.cfg), jax.random.PRNGKey(0))

    def model_flops(self, shape: str) -> float:
        """6 · N_active · tokens (train counts fwd+bwd ⇒ 3× fwd pair)."""
        sh = LM_SHAPES[shape]
        n = self._active_params()
        tokens = sh["global_batch"] * (sh["seq_len"] if sh["kind"] != "decode" else 1)
        per_tok = 6.0 * n if sh["kind"] == "train" else 2.0 * n
        return per_tok * tokens

    def _active_params(self) -> float:
        c = self.cfg
        dh = c.head_dim
        attn = c.d_model * dh * (2 * c.n_heads + 2 * c.n_kv_heads)
        if c.moe is None:
            ffn = 3 * c.d_model * c.d_ff
        else:
            ffn = 3 * c.d_model * c.moe.d_ff * (c.moe.top_k + c.moe.n_shared_experts)
            ffn += c.d_model * c.moe.n_experts  # router
        body = c.n_layers * (attn + ffn)
        embed = c.vocab * c.d_model * (1 if c.tie_embeddings else 2)
        return float(body + embed)

    # -- cells -------------------------------------------------------------
    def build_cell(self, shape: str, mesh: Mesh) -> Cell:
        sh = LM_SHAPES[shape]
        cfg = self.cfg
        if shape == "prefill_32k":
            cfg = dataclasses.replace(cfg, attention_impl="chunked", attention_chunk=2048)
        if cfg.moe is not None and sh["kind"] == "train":
            # microbatched training re-gathers per microbatch → ZeRO-3
            # expert gathering loses there (EXPERIMENTS.md §Perf)
            cfg = dataclasses.replace(
                cfg, moe=dataclasses.replace(cfg.moe, jit_weight_gather=False)
            )
        pspecs = shd.lm_param_specs(cfg, mesh)
        da = shd.data_axes(mesh)
        B, S = sh["global_batch"], sh["seq_len"]
        abs_params = self.abstract_params()

        if sh["kind"] == "train":
            oinit, oupd = make_optimizer(self.optimizer)
            loss_fn = lambda p, b: tf_m.lm_loss(p, cfg, b["tokens"], b["labels"])
            step = make_train_step(loss_fn, oupd, microbatches=self.microbatches)
            abs_state = jax.eval_shape(
                lambda p: {"params": p, "opt": oinit(p)}, abs_params
            )
            sspecs = _state_specs(pspecs, abs_params, self.optimizer)
            bspec = {"tokens": P(da, None), "labels": P(da, None)}
            structs = (
                _sds(abs_state),
                {
                    "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
                    "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
                },
            )
            return Cell(
                self.name, shape, "train", step, structs,
                (shd.to_shardings(mesh, sspecs), shd.to_shardings(mesh, bspec)),
                (shd.to_shardings(mesh, sspecs), None),
                self.model_flops(shape),
                {"tokens_per_step": B * S, "params": count_abstract_params(abs_params)},
            )

        if sh["kind"] == "prefill":
            def prefill(params, tokens):
                logits, aux = tf_m.forward(params, cfg, tokens, collect_kv=True)
                return logits[:, -1, :], aux["kv_cache"]

            cache_spec = shd.kv_cache_spec(mesh, batch=B, seq_shard=False)
            structs = (
                _sds(abs_params),
                jax.ShapeDtypeStruct((B, S), jnp.int32),
            )
            out_spec = (P(da, "model"), cache_spec)
            return Cell(
                self.name, shape, "prefill", prefill, structs,
                (shd.to_shardings(mesh, pspecs), shd.to_shardings(mesh, P(da, None))),
                shd.to_shardings(mesh, out_spec),
                self.model_flops(shape),
                {"tokens_per_step": B * S, "params": count_abstract_params(abs_params)},
            )

        # decode: weights TP-only when they fit (no per-step FSDP weight
        # traffic); gathering hints off either way (§Perf, decode cells)
        param_bytes = count_abstract_params(abs_params) * 2  # bf16
        tp_fits = param_bytes / mesh.shape["model"] <= 8 * 2**30
        moe_cfg = cfg.moe
        if moe_cfg is not None:
            moe_cfg = dataclasses.replace(moe_cfg, jit_weight_gather=False)
        cfg = dataclasses.replace(cfg, jit_weight_gather=False, moe=moe_cfg)
        pspecs = shd.lm_param_specs(cfg, mesh, fsdp=not tp_fits)
        seq_shard = shape == "long_500k"
        cache_spec = shd.kv_cache_spec(mesh, batch=B, seq_shard=seq_shard)
        if seq_shard:
            attn_fn = _flash_attn_factory(mesh, batch_axes=(), seq_axes=(*da, "model"))
        else:
            attn_fn = _flash_attn_factory(mesh, batch_axes=da, seq_axes=("model",))

        def decode(params, cache, tokens, lengths):
            return tf_m.decode_step(params, cfg, cache, tokens, lengths, attn_fn=attn_fn)

        cache_structs = _sds(
            jax.eval_shape(lambda: tf_m.init_kv_cache(cfg, B, S))
        )
        structs = (
            _sds(abs_params),
            cache_structs,
            jax.ShapeDtypeStruct((B, 1), jnp.int32),
            jax.ShapeDtypeStruct((B,), jnp.int32),
        )
        tok_spec = P(da, None) if not seq_shard else P(None, None)
        len_spec = P(da) if not seq_shard else P(None)
        in_shard = (
            shd.to_shardings(mesh, pspecs),
            shd.to_shardings(mesh, cache_spec),
            shd.to_shardings(mesh, tok_spec),
            shd.to_shardings(mesh, len_spec),
        )
        out_shard = (
            shd.to_shardings(mesh, P(da, "model") if not seq_shard else P(None, "model")),
            shd.to_shardings(mesh, cache_spec),
        )
        return Cell(
            self.name, shape, "decode", decode, structs, in_shard, out_shard,
            self.model_flops(shape),
            {"tokens_per_step": B, "params": count_abstract_params(abs_params),
             "kv_bytes": sum(int(np.prod(l.shape)) * l.dtype.itemsize
                             for l in jax.tree.leaves(cache_structs))},
        )

    # -- smoke -------------------------------------------------------------
    def smoke(self, seed: int = 0) -> dict:
        cfg = self.smoke_cfg
        assert cfg is not None, f"{self.name}: no smoke config"
        key = jax.random.PRNGKey(seed)
        params = tf_m.init_params(key, cfg)
        toks = jax.random.randint(key, (2, 16), 0, cfg.vocab)
        opt = OptimizerConfig(name=self.optimizer.name, lr=1e-3, warmup_steps=2, total_steps=10)
        oinit, oupd = make_optimizer(opt)
        step = jax.jit(make_train_step(
            lambda p, b: tf_m.lm_loss(p, cfg, b["tokens"], b["labels"]), oupd))
        state = {"params": params, "opt": oinit(params)}
        batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        state, m1 = step(state, batch)
        state, m2 = step(state, batch)
        logits, _ = tf_m.forward(params, cfg, toks)
        assert logits.shape == (2, 16, cfg.vocab)
        assert np.isfinite(float(m2["loss"]))
        # decode smoke
        cache = tf_m.init_kv_cache(cfg, 2, 8)
        lg, cache = tf_m.decode_step(params, cfg, cache, toks[:, :1], jnp.zeros(2, jnp.int32))
        assert lg.shape == (2, cfg.vocab) and bool(jnp.isfinite(lg).all())
        return {"loss0": float(m1["loss"]), "loss1": float(m2["loss"])}


def _flash_attn_factory(mesh, batch_axes, seq_axes):
    from repro.dist.collectives import flash_decode_shardmap

    return flash_decode_shardmap(mesh, batch_axes=batch_axes, seq_axes=seq_axes)


# ---------------------------------------------------------------------------
# GNN family (GAT)
# ---------------------------------------------------------------------------

# Static budgets are padded to multiples of 512 so node/edge arrays shard
# over the 512-chip multi-pod mesh (sentinel padding is mathematically
# neutral — see models/gnn.py). True dataset sizes are kept in `true_*`.
GNN_SHAPES = {
    "full_graph_sm": dict(
        kind="train", n_nodes=3071, n_edges=10752, d_feat=1433, n_classes=7,
        true_nodes=2708, true_edges=10556,  # Cora
    ),
    "minibatch_lg": dict(
        kind="train", n_nodes=170_495, n_edges=168_960, d_feat=602, n_classes=41,
        sampled=True, batch_nodes=1024, fanout=(15, 10),
        true_nodes=232_965, true_edges=114_615_892,  # Reddit (sampled)
    ),
    "ogb_products": dict(
        kind="train", n_nodes=2_449_407, n_edges=61_865_984, d_feat=100, n_classes=47,
        true_nodes=2_449_029, true_edges=61_859_140,  # ogbn-products
    ),
    "molecule": dict(
        kind="train", n_nodes=4095, n_edges=8192, d_feat=16, n_classes=2,
        graphs=128, true_nodes=30 * 128, true_edges=64 * 128,
    ),
}


@dataclasses.dataclass
class GNNArch(BaseArch):
    name: str
    n_layers: int = 2
    d_hidden: int = 8
    n_heads: int = 8
    family: str = "gnn"
    optimizer: OptimizerConfig = dataclasses.field(
        default_factory=lambda: OptimizerConfig(lr=5e-3, weight_decay=5e-4)
    )
    shape_names: tuple[str, ...] = tuple(GNN_SHAPES)
    # §Perf: 0 = jit auto-sharding baseline; 1 = dst-aligned edge-sharded
    # shard_map layer (one all-gather per layer, local scatter/softmax)
    opt: int = 0

    def _cfg(self, shape: str) -> gnn_m.GATConfig:
        sh = GNN_SHAPES[shape]
        return gnn_m.GATConfig(
            name=self.name, n_layers=self.n_layers, d_in=sh["d_feat"],
            d_hidden=self.d_hidden, n_heads=self.n_heads, n_classes=sh["n_classes"],
        )

    def model_flops(self, shape: str) -> float:
        sh = GNN_SHAPES[shape]
        cfg = self._cfg(shape)
        H, d = cfg.n_heads, cfg.d_hidden
        l1 = sh["n_nodes"] * cfg.d_in * H * d * 2 + sh["n_edges"] * H * (4 * d)
        l2 = sh["n_nodes"] * (H * d) * H * cfg.n_classes * 2 + sh["n_edges"] * H * 4 * cfg.n_classes
        return 3.0 * (l1 + l2)  # fwd+bwd

    def build_cell(self, shape: str, mesh: Mesh) -> Cell:
        sh = GNN_SHAPES[shape]
        cfg = self._cfg(shape)
        N, E = sh["n_nodes"], sh["n_edges"]
        abs_params = jax.eval_shape(lambda k: gnn_m.gat_init(k, cfg), jax.random.PRNGKey(0))
        pspecs = shd.replicate(abs_params)
        oinit, oupd = make_optimizer(self.optimizer)

        graphs = sh.get("graphs")
        flat_axes = (*shd.data_axes(mesh), "model")
        use_sharded = self.opt >= 1 and not graphs and not sh.get("sampled")

        def loss_fn(params, batch):
            if use_sharded:
                return gnn_m.gat_loss_edge_sharded(
                    params, cfg, batch, mesh, flat_axes,
                    min_side_gather=self.opt >= 2,
                )
            if graphs:
                gid = batch.pop("graph_ids")
                glab = batch.pop("graph_labels")
                g = gnn_m.Graph(**batch)
                return gnn_m.gat_graph_loss(params, cfg, g, gid, glab, graphs)
            g = gnn_m.Graph(**batch)
            return gnn_m.gat_loss(params, cfg, g)

        step = make_train_step(loss_fn, oupd)
        abs_state = jax.eval_shape(lambda p: {"params": p, "opt": oinit(p)}, abs_params)
        sspecs = _state_specs(pspecs, abs_params, self.optimizer)
        bspec = shd.gnn_batch_spec(mesh)
        structs = (
            _sds(abs_state),
            {
                "x": jax.ShapeDtypeStruct((N + 1, sh["d_feat"]), jnp.float32),
                "edge_src": jax.ShapeDtypeStruct((E,), jnp.int32),
                "edge_dst": jax.ShapeDtypeStruct((E,), jnp.int32),
                "labels": jax.ShapeDtypeStruct((N + 1,), jnp.int32),
                "train_mask": jax.ShapeDtypeStruct((N + 1,), jnp.bool_),
            },
        )
        if graphs:
            structs[1]["graph_ids"] = jax.ShapeDtypeStruct((N + 1,), jnp.int32)
            structs[1]["graph_labels"] = jax.ShapeDtypeStruct((graphs,), jnp.int32)
            bspec = dict(bspec)
            bspec["graph_ids"] = P(shd.data_axes(mesh))
            bspec["graph_labels"] = P(None)
        if use_sharded:
            # dst-aligned contract: float mask, flat node/edge sharding
            structs[1]["train_mask"] = jax.ShapeDtypeStruct((N + 1,), jnp.float32)
            bspec = {
                "x": P(flat_axes, None),
                "edge_src": P(flat_axes),
                "edge_dst": P(flat_axes),
                "labels": P(flat_axes),
                "train_mask": P(flat_axes),
            }
        return Cell(
            self.name, shape, "train", step, structs,
            (shd.to_shardings(mesh, sspecs), shd.to_shardings(mesh, bspec)),
            (shd.to_shardings(mesh, sspecs), None),
            self.model_flops(shape),
            {"params": count_abstract_params(abs_params), "edges": E},
        )

    def smoke(self, seed: int = 0) -> dict:
        rng = np.random.default_rng(seed)
        N, E, F, C = 64, 256, 12, 5
        cfg = gnn_m.GATConfig(name="smoke", d_in=F, n_classes=C,
                              d_hidden=self.d_hidden, n_heads=self.n_heads)
        g = gnn_m.pad_graph(
            rng.normal(size=(N, F)).astype(np.float32),
            rng.integers(0, N, size=(2, E)),
            rng.integers(0, C, size=N),
            rng.random(N) < 0.5,
        )
        params = gnn_m.gat_init(jax.random.PRNGKey(seed), cfg)
        oinit, oupd = make_optimizer(self.optimizer)
        step = jax.jit(make_train_step(
            lambda p, b: gnn_m.gat_loss(p, cfg, gnn_m.Graph(**b)), oupd))
        state = {"params": params, "opt": oinit(params)}
        batch = dict(x=g.x, edge_src=g.edge_src, edge_dst=g.edge_dst,
                     labels=g.labels, train_mask=g.train_mask)
        losses = []
        for _ in range(3):
            state, m = step(state, batch)
            losses.append(float(m["loss"]))
        assert np.isfinite(losses).all()
        # sampler smoke
        smp = gnn_m.NeighborSampler(np.asarray(rng.integers(0, N, size=(2, E))), N)
        nid, es, ed = smp.sample_padded(np.arange(4), (3, 2), 64, 128)
        assert len(nid) == 64 and len(es) == 128
        return {"losses": losses}


# ---------------------------------------------------------------------------
# RecSys family
# ---------------------------------------------------------------------------

REC_SHAPES = {
    "train_batch": dict(kind="train", batch=65_536),
    "serve_p99": dict(kind="serve", batch=512),
    "serve_bulk": dict(kind="serve", batch=262_144),
    # candidates padded 1,000,000 → 1,000,448 (divisible by the 512-chip flat mesh)
    "retrieval_cand": dict(kind="serve", batch=1, n_candidates=1_000_448),
}


@dataclasses.dataclass
class RecsysArch(BaseArch):
    name: str
    cfg: Any = None
    family: str = "recsys"
    optimizer: OptimizerConfig = dataclasses.field(
        default_factory=lambda: OptimizerConfig(lr=1e-3)
    )
    shape_names: tuple[str, ...] = tuple(REC_SHAPES)
    smoke_cfg: Any = None

    # dispatch tables -------------------------------------------------------
    def _fns(self, cfg):
        m = cfg.name
        if m == "deepfm":
            return rec_m.deepfm_init, rec_m.deepfm_loss, rec_m.deepfm_forward
        if m == "dcn-v2":
            return rec_m.dcnv2_init, rec_m.dcnv2_loss, rec_m.dcnv2_forward
        if m == "sasrec":
            return rec_m.sasrec_init, rec_m.sasrec_loss, None
        if m == "din":
            return rec_m.din_init, rec_m.din_loss, None
        raise KeyError(m)

    def _batch_structs(self, cfg, B: int) -> dict:
        m = cfg.name
        sds = jax.ShapeDtypeStruct
        if m == "deepfm":
            return {"sparse": sds((B, cfg.n_fields), jnp.int32), "label": sds((B,), jnp.float32)}
        if m == "dcn-v2":
            return {
                "dense": sds((B, cfg.n_dense), jnp.float32),
                "sparse": sds((B, cfg.n_fields), jnp.int32),
                "label": sds((B,), jnp.float32),
            }
        if m == "sasrec":
            return {
                "seq": sds((B, cfg.seq_len), jnp.int32),
                "pos_label": sds((B, cfg.seq_len), jnp.int32),
                "neg_label": sds((B, cfg.seq_len, cfg.n_negatives), jnp.int32),
            }
        if m == "din":
            return {
                "hist": sds((B, cfg.seq_len), jnp.int32),
                "target": sds((B,), jnp.int32),
                "label": sds((B,), jnp.float32),
            }
        raise KeyError(m)

    def _smoke_batch(self, cfg, B: int, key) -> dict:
        structs = self._batch_structs(cfg, B)

        def rnd(s):
            if s.dtype == jnp.int32:
                return jax.random.randint(key, s.shape, 0, 32)
            return jax.random.uniform(key, s.shape)

        return jax.tree.map(rnd, structs)

    def model_flops(self, shape: str) -> float:
        cfg = self.cfg
        sh = REC_SHAPES[shape]
        B = sh.get("n_candidates", sh["batch"]) if shape == "retrieval_cand" else sh["batch"]
        m = cfg.name
        if m == "deepfm":
            per = cfg.n_fields * cfg.embed_dim * (2 + 2 * cfg.mlp[0]) + sum(
                2 * a * b for a, b in zip(cfg.mlp[:-1], cfg.mlp[1:])
            )
        elif m == "dcn-v2":
            d = cfg.d_interact
            per = cfg.n_cross_layers * 2 * d * d + 2 * d * cfg.mlp[0] + sum(
                2 * a * b for a, b in zip(cfg.mlp[:-1], cfg.mlp[1:])
            )
        elif m == "sasrec":
            D, S = cfg.embed_dim, cfg.seq_len
            per = cfg.n_blocks * (8 * S * D * D + 4 * S * S * D) + S * D * 2 * (
                1 + cfg.n_negatives
            )
        else:  # din
            D, S = cfg.embed_dim, cfg.seq_len
            per = S * (2 * 4 * D * cfg.attn_mlp[0] + 2 * cfg.attn_mlp[0] * cfg.attn_mlp[1]) + \
                2 * 3 * D * cfg.mlp[0] + 2 * cfg.mlp[0] * cfg.mlp[1]
        mult = 3.0 if sh["kind"] == "train" else 1.0
        return float(per) * B * mult

    def build_cell(self, shape: str, mesh: Mesh) -> Cell:
        sh = REC_SHAPES[shape]
        cfg = self.cfg
        init_fn, loss_fn_raw, fwd_fn = self._fns(cfg)
        abs_params = jax.eval_shape(lambda k: init_fn(k, cfg), jax.random.PRNGKey(0))
        pspecs = shd.recsys_param_specs(cfg.name, abs_params, mesh)
        da = shd.data_axes(mesh)
        B = sh["batch"]

        if sh["kind"] == "train":
            oinit, oupd = make_optimizer(self.optimizer)
            step = make_train_step(lambda p, b: loss_fn_raw(p, cfg, b), oupd)
            abs_state = jax.eval_shape(lambda p: {"params": p, "opt": oinit(p)}, abs_params)
            sspecs = _state_specs(pspecs, abs_params, self.optimizer)
            bspec = shd.recsys_batch_spec(cfg.name, mesh)
            return Cell(
                self.name, shape, "train", step,
                (_sds(abs_state), self._batch_structs(cfg, B)),
                (shd.to_shardings(mesh, sspecs), shd.to_shardings(mesh, bspec)),
                (shd.to_shardings(mesh, sspecs), None),
                self.model_flops(shape),
                {"params": count_abstract_params(abs_params)},
            )

        if shape == "retrieval_cand":
            N = sh["n_candidates"]
            flat = (*da, "model")
            if cfg.name == "deepfm":
                fn = lambda p, u, c: rec_m.deepfm_score_candidates(p, cfg, u, c, 3)
                structs = (
                    _sds(abs_params),
                    jax.ShapeDtypeStruct((1, cfg.n_fields), jnp.int32),
                    jax.ShapeDtypeStruct((N,), jnp.int32),
                )
                in_sh = (shd.to_shardings(mesh, pspecs),
                         shd.to_shardings(mesh, P(None, None)),
                         shd.to_shardings(mesh, P(flat)))
                out_sh = shd.to_shardings(mesh, P(flat))
            elif cfg.name == "dcn-v2":
                fn = lambda p, ud, us, c: rec_m.dcnv2_score_candidates(p, cfg, ud, us, c, 3)
                structs = (
                    _sds(abs_params),
                    jax.ShapeDtypeStruct((1, cfg.n_dense), jnp.float32),
                    jax.ShapeDtypeStruct((1, cfg.n_fields), jnp.int32),
                    jax.ShapeDtypeStruct((N,), jnp.int32),
                )
                in_sh = (shd.to_shardings(mesh, pspecs),
                         shd.to_shardings(mesh, P(None, None)),
                         shd.to_shardings(mesh, P(None, None)),
                         shd.to_shardings(mesh, P(flat)))
                out_sh = shd.to_shardings(mesh, P(flat))
            elif cfg.name == "sasrec":
                fn = lambda p, s, c: rec_m.sasrec_score_candidates(p, cfg, s, c)
                structs = (
                    _sds(abs_params),
                    jax.ShapeDtypeStruct((1, cfg.seq_len), jnp.int32),
                    jax.ShapeDtypeStruct((N,), jnp.int32),
                )
                in_sh = (shd.to_shardings(mesh, pspecs),
                         shd.to_shardings(mesh, P(None, None)),
                         shd.to_shardings(mesh, P(flat)))
                out_sh = shd.to_shardings(mesh, P(None, flat))
            else:  # din
                fn = lambda p, h, c: rec_m.din_score_candidates(p, cfg, h, c)
                structs = (
                    _sds(abs_params),
                    jax.ShapeDtypeStruct((cfg.seq_len,), jnp.int32),
                    jax.ShapeDtypeStruct((N,), jnp.int32),
                )
                in_sh = (shd.to_shardings(mesh, pspecs),
                         shd.to_shardings(mesh, P(None)),
                         shd.to_shardings(mesh, P(flat)))
                out_sh = shd.to_shardings(mesh, P(flat))
            return Cell(
                self.name, shape, "serve", fn, structs, in_sh, out_sh,
                self.model_flops(shape),
                {"params": count_abstract_params(abs_params), "candidates": N},
            )

        # serve_p99 / serve_bulk — batched forward
        if cfg.name == "deepfm":
            fn = lambda p, b: rec_m.deepfm_forward(p, cfg, b["sparse"])
        elif cfg.name == "dcn-v2":
            fn = lambda p, b: rec_m.dcnv2_forward(p, cfg, b["dense"], b["sparse"])
        elif cfg.name == "sasrec":
            fn = lambda p, b: rec_m.sasrec_encode(p, cfg, b["seq"])[:, -1]
        else:
            fn = lambda p, b: rec_m.din_forward(p, cfg, b["hist"], b["target"])
        structs = self._batch_structs(cfg, B)
        structs.pop("label", None)
        structs.pop("pos_label", None)
        structs.pop("neg_label", None)
        bspec = {k: v for k, v in shd.recsys_batch_spec(cfg.name, mesh).items() if k in structs}
        out_spec = P(da) if cfg.name != "sasrec" else P(da, None)
        return Cell(
            self.name, shape, "serve", fn,
            (_sds(abs_params), structs),
            (shd.to_shardings(mesh, pspecs), shd.to_shardings(mesh, bspec)),
            shd.to_shardings(mesh, out_spec),
            self.model_flops(shape),
            {"params": count_abstract_params(abs_params)},
        )

    def smoke(self, seed: int = 0) -> dict:
        cfg = self.smoke_cfg
        assert cfg is not None
        key = jax.random.PRNGKey(seed)
        init_fn, loss_fn_raw, _ = self._fns(cfg)
        params = init_fn(key, cfg)
        batch = self._smoke_batch(cfg, 8, key)
        oinit, oupd = make_optimizer(self.optimizer)
        step = jax.jit(make_train_step(lambda p, b: loss_fn_raw(p, cfg, b), oupd))
        state = {"params": params, "opt": oinit(params)}
        state, m = step(state, batch)
        assert np.isfinite(float(m["loss"]))
        return {"loss": float(m["loss"])}
