"""deepseek-coder-33b — deep llama-architecture dense code LM.

62L d_model=7168 56H (GQA kv=8) d_ff=19200 vocab=32256.
[arXiv:2401.14196; hf]
"""

import jax.numpy as jnp

from repro.models.transformer import TransformerConfig
from repro.train.optimizer import OptimizerConfig

from .base import LMArch

ARCH = LMArch(
    name="deepseek-coder-33b",
    cfg=TransformerConfig(
        name="deepseek-coder-33b",
        n_layers=62,
        d_model=7168,
        n_heads=56,
        n_kv_heads=8,
        d_head=128,
        d_ff=19200,
        vocab=32256,
        dtype=jnp.bfloat16,
    ),
    optimizer=OptimizerConfig(name="adamw", lr=2e-4, warmup_steps=2000, total_steps=500_000),
    microbatches=16,
    smoke_cfg=TransformerConfig(
        name="dsc-smoke",
        n_layers=2,
        d_model=64,
        n_heads=8,
        n_kv_heads=2,
        d_ff=160,
        vocab=256,
        dtype=jnp.float32,
    ),
)
