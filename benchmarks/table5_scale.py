"""Table 5 — sharded out-of-core serving at scale (EXPERIMENTS.md
§Scale).

The paper's size argument (forward index dominates; compression buys
nothing once the corpus outgrows one host) motivates the sharded
artifact layer (DESIGN.md §9). This table measures what sharding costs
and what it buys, sweeping corpus size N × shard count S:

* ``scale/<engine>-<codec>/N<n>/S<s>/bucket8`` — amortized bucket-8
  per-query latency through one warm plan over the sharded (or S=1
  monolithic) retriever; derived carries ``us_per_q``, ``qps``,
  ``recall`` (vs. exact brute force) and ``disk_ratio`` (summed shard
  payload / monolithic).
* ``scale/residency/N<n>`` — strict out-of-core serving: S=4 with
  ``max_resident=1``; derived carries ``peak_bytes`` (the LRU-bounded
  peak device residency), ``mono_bytes`` (what the monolithic build
  must keep resident) and their ratio.

Two NaN-fail gates ride into ``benchmarks.run --quick`` (the standing
convention: a NaN ``us`` fails the smoke):

* ``scale/latency-gate/N<n>`` — sharded (S=4, fully resident)
  bucket-8 amortized µs/q must stay within ``LATENCY_FACTOR``× of the
  monolithic build at equal N: the fan-out + O(k) merge must not
  swamp the serving path.
* ``scale/residency-gate/N<n>`` — peak resident bytes at S=4 /
  ``max_resident=1`` must drop ≥ ``RESIDENCY_FACTOR``× below the
  monolithic footprint: the whole point of out-of-core serving.

As everywhere in this harness, absolute µs are CPU-XLA wall clock; the
reproducible claim is the *shape*: amortized latency roughly flat in S,
peak residency falling like 1/S.
"""

from __future__ import annotations

import numpy as np

from .common import Row, timeit_us

#: sharded serving may cost per-shard dispatch + merge overhead, but
#: no more than this factor over the monolithic plan at equal N
LATENCY_FACTOR = 1.5
#: out-of-core (S=4, max_resident=1) must cut peak residency ≥ this
RESIDENCY_FACTOR = 2.0

BUCKET = 8
SHARD_COUNTS = (1, 4)


def _resident_bytes(retriever) -> int:
    return sum(int(a.nbytes) for a in retriever.arrays.values())


def run(n_docs_sweep=(2000, 8000), n_queries: int = 32,
        n_requests: int = 64, engine: str = "flat",
        codec: str = "streamvbyte") -> list[Row]:
    from repro.core.seismic import exact_top_k, recall_at_k
    from repro.data.synthetic import generate_collection, splade_config
    from repro.serve.api import Retriever, RetrieverConfig

    rows: list[Row] = []
    for n_docs in n_docs_sweep:
        col = generate_collection(splade_config(n_docs, n_queries, seed=0),
                                  value_format="f16")
        Q = np.stack([col.query_dense(i) for i in range(n_queries)])
        exact = [exact_top_k(col.fwd, Q[i], 10)[0] for i in range(n_queries)]
        cfg = RetrieverConfig(engine=engine, codec=codec, k=10)

        n_disp = max(1, n_requests // BUCKET)
        batches = [
            np.asarray(Q[np.arange(i * BUCKET, (i + 1) * BUCKET) % n_queries])
            for i in range(n_disp)
        ]

        us_per_q: dict[int, float] = {}
        mono_bytes = 0
        mono_disk = 0
        for S in SHARD_COUNTS:
            r = Retriever.build(col.fwd, cfg.replace(n_shards=S))
            if S == 1:
                mono_bytes = _resident_bytes(r)
                mono_disk = sum(int(np.asarray(a).nbytes)
                                for a in r.arrays.values())
                disk_ratio = 1.0
            else:
                disk_ratio = sum(sh.disk_bytes() for sh in r.shards) / mono_disk
            plan = r.plans.get(BUCKET)
            plan(batches[0])  # compile + admit every shard before timing

            def stream():
                for b in batches:
                    plan(b)[0].block_until_ready()

            us = timeit_us(stream) / n_disp
            us_per_q[S] = us / BUCKET
            ids, _ = r.search(Q)
            recall = float(np.mean([
                recall_at_k(exact[i], np.asarray(ids[i]))
                for i in range(n_queries)
            ]))
            rows.append(Row(
                f"scale/{engine}-{codec}/N{n_docs}/S{S}/bucket{BUCKET}",
                us,
                f"bucket={BUCKET};us_per_q={us_per_q[S]:.1f};"
                f"qps={1e6 / us_per_q[S]:.0f};recall={recall:.3f};"
                f"disk_ratio={disk_ratio:.3f}",
                codec=codec,
            ))

        # gate 1: sharded amortized latency within LATENCY_FACTOR×
        ok = us_per_q[4] <= LATENCY_FACTOR * us_per_q[1]
        rows.append(Row(
            f"scale/latency-gate/N{n_docs}",
            us_per_q[4] if ok else float("nan"),
            f"mono_us_per_q={us_per_q[1]:.1f};"
            f"factor={us_per_q[4] / us_per_q[1]:.2f};"
            f"bound={LATENCY_FACTOR}",
        ))

        # gate 2: strict out-of-core residency (S=4, one shard at a
        # time) cuts the peak device footprint
        r = Retriever.build(col.fwd, cfg.replace(n_shards=4))
        r.max_resident = 1
        r.prefetch = False  # this gate prices the bare out-of-core
        # residency bound; the double-buffered (prefetch) footprint is
        # one extra shard by construction and is priced in table7
        r.search(Q)
        peak = r.peak_resident_bytes
        ratio = mono_bytes / max(peak, 1)
        rows.append(Row(
            f"scale/residency/N{n_docs}",
            us_per_q[4],
            f"peak_bytes={peak};mono_bytes={mono_bytes};"
            f"ratio={ratio:.2f};evictions={r.evictions}",
        ))
        ok = ratio >= RESIDENCY_FACTOR
        rows.append(Row(
            f"scale/residency-gate/N{n_docs}",
            float(ratio) if ok else float("nan"),
            f"peak_bytes={peak};mono_bytes={mono_bytes};"
            f"bound={RESIDENCY_FACTOR}x",
        ))
    return rows
