"""Kernel microbenchmarks + HBM-payload accounting.

Wall-times are CPU (jnp path jit-compiled; the Pallas kernel itself runs
interpret=True here, so its number measures the *semantics*, not Mosaic
codegen). The ``derived`` column carries the quantity that transfers to
TPU: bytes the scoring pass streams from HBM per scan — the memory-
roofline numerator the §Perf iterations drive down."""

from __future__ import annotations

import numpy as np

from repro.core.forward_index import pack_forward_index
from repro.core.scoring import score_packed
from repro.data.synthetic import generate_collection, splade_config
from repro.kernels.ops import score_bitpack_bucketed, score_dotvbyte

from .common import Row, timeit_us


def run(n_docs: int = 2000) -> list[Row]:
    col = generate_collection(splade_config(n_docs=n_docs, n_queries=4), value_format="f16")
    q = col.query_dense(0)
    rows: list[Row] = []

    for codec in ("uncompressed", "dotvbyte", "bitpack"):
        packed = pack_forward_index(col.fwd, codec=codec)
        us = timeit_us(lambda p=packed: score_packed(q, p).block_until_ready())
        rows.append(
            Row(f"kernel/jnp_scan/{codec}", us,
                f"hbm_payload_mb={packed.payload_bytes()/2**20:.2f}")
        )

    pd = pack_forward_index(col.fwd, codec="dotvbyte")
    us = timeit_us(lambda: np.asarray(score_dotvbyte(q, pd, interpret=True)), repeats=1)
    rows.append(Row("kernel/pallas_interpret/dotvbyte", us, "semantic-check-only"))

    pb = pack_forward_index(col.fwd, codec="bitpack")
    tight = sum(
        ((pb.block_size * int(w) + 31) // 32) * 4 for w in pb.widths
    )
    padded = pb.words.nbytes
    us = timeit_us(lambda: np.asarray(score_bitpack_bucketed(q, pb, interpret=True)), repeats=1)
    rows.append(
        Row("kernel/pallas_interpret/bitpack_bucketed", us,
            f"tight_words_mb={tight/2**20:.2f};padded_words_mb={padded/2**20:.2f}")
    )
    return rows


if __name__ == "__main__":
    from .common import emit

    emit(run())
