"""Kernel microbenchmarks + HBM-payload accounting.

Wall-times are CPU (jnp path jit-compiled; the Pallas kernels run
interpret=True here, so their numbers measure the *semantics*, not
Mosaic codegen). The ``derived`` column carries the quantity that
transfers to TPU: bytes the scoring pass streams from HBM — the
memory-roofline numerator the §Perf iterations drive down.

Three families:

* ``kernel/jnp_scan`` / ``kernel/pallas_interpret`` — the full block
  scan per codec (now including StreamVByte, EXPERIMENTS.md §Perf);
* ``kernel/rescoring`` — the serve engines' phase-2 candidate path:
  jnp take→decode→dot vs the fused scalar-prefetch rows kernel.
  Derived ``hbm_bytes_per_q`` counts what each path streams per query:
  the fused kernel reads the encoded candidate payload once and writes
  C scores; the jnp chain additionally materialises the gathered
  payload and the decoded i32 components + products in HBM. The fused
  number must be strictly smaller — ``make kernel-parity`` asserts it;
* ``kernel/batch_sweep`` — decode-once/score-many amortisation: the
  query-batched kernels at nq ∈ {1, 8, 64} with per-query amortised µs
  in ``derived``.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import layout
from repro.core.forward_index import pack_forward_index
from repro.core.scoring import score_candidate_rows, score_packed
from repro.data.synthetic import generate_collection, splade_config
from repro.kernels.ops import (
    score_bitpack_bucketed,
    score_dotvbyte,
    score_dotvbyte_batch,
    score_streamvbyte,
    score_streamvbyte_batch,
)
from repro.kernels.registry import get_kernels

from .common import Row, timeit_us

#: candidate-set size for the rescoring family (a Seismic phase-2
#: probe of 64 blocks × 16-doc blocks lands in this regime)
N_CANDIDATES = 256

#: codecs measured end to end (must all be registered layouts)
SCAN_CODECS = ("uncompressed", "dotvbyte", "streamvbyte", "bitpack")
RESCORE_CODECS = ("uncompressed", "dotvbyte", "streamvbyte", "bitpack")


def rows_payload_bytes(arrays, codec: str, n_cand: int) -> int:
    """Encoded bytes the rescoring of ``n_cand`` rows must read from
    HBM: the codec payload + values + nnz of the gathered rows (per-row
    widths as stored, padding included — that is what actually DMAs)."""
    per_row = arrays["vals_rows"].shape[1] * arrays["vals_rows"].dtype.itemsize
    per_row += 4  # nnz i32
    if codec == "uncompressed":
        per_row += arrays["comps_rows"].shape[1] * 4
    elif codec == "bitpack":
        per_row += arrays["words_rows"].shape[1] * 4 + 4
    else:
        per_row += arrays["ctrl_rows"].shape[1] + arrays["data_rows"].shape[1]
    return per_row * n_cand


def rows_hbm_bytes(arrays, codec: str, n_cand: int, *, fused: bool) -> int:
    """HBM bytes one query's candidate rescoring streams.

    fused  — read payload once, write n_cand f32 scores; decoded
             components live and die in VMEM;
    jnp    — the take→decode→dot chain: the gather writes the payload
             back to HBM, the decode writes i32 components (skipped
             for the decode-free uncompressed layout, whose gathered
             comps_rows ARE the components), the dot reads them and
             writes products before the reduction.
    """
    payload = rows_payload_bytes(arrays, codec, n_cand)
    if fused:
        return payload + n_cand * 4
    L = arrays["vals_rows"].shape[1]
    comps = 0 if codec == "uncompressed" else n_cand * L * 4  # decoded i32
    prod = n_cand * L * 4  # qv·vals products before the row reduction
    return payload * 2 + comps + prod + n_cand * 4


def run(n_docs: int = 2000) -> list[Row]:
    col = generate_collection(splade_config(n_docs=n_docs, n_queries=4), value_format="f16")
    q = col.query_dense(0)
    rows: list[Row] = []

    # --- block-scan family ---------------------------------------------
    for codec in SCAN_CODECS:
        packed = pack_forward_index(col.fwd, codec=codec)
        us = timeit_us(lambda p=packed: score_packed(q, p).block_until_ready())
        rows.append(
            Row(f"kernel/jnp_scan/{codec}", us,
                f"hbm_payload_mb={packed.payload_bytes()/2**20:.2f}")
        )

    pd = pack_forward_index(col.fwd, codec="dotvbyte")
    us = timeit_us(lambda: np.asarray(score_dotvbyte(q, pd, interpret=True)), repeats=1)
    rows.append(Row("kernel/pallas_interpret/dotvbyte", us, "semantic-check-only"))

    ps = pack_forward_index(col.fwd, codec="streamvbyte")
    us = timeit_us(lambda: np.asarray(score_streamvbyte(q, ps, interpret=True)), repeats=1)
    rows.append(Row("kernel/pallas_interpret/streamvbyte", us, "semantic-check-only"))

    pb = pack_forward_index(col.fwd, codec="bitpack")
    tight = sum(
        ((pb.block_size * int(w) + 31) // 32) * 4 for w in pb.widths
    )
    padded = pb.words.nbytes
    us = timeit_us(lambda: np.asarray(score_bitpack_bucketed(q, pb, interpret=True)), repeats=1)
    rows.append(
        Row("kernel/pallas_interpret/bitpack_bucketed", us,
            f"tight_words_mb={tight/2**20:.2f};padded_words_mb={padded/2**20:.2f}")
    )

    # --- candidate-rescoring family: jnp chain vs fused rows kernel ----
    rng = np.random.default_rng(0)
    n = col.fwd.n_docs
    cand = np.sort(rng.choice(n, size=min(N_CANDIDATES, n), replace=False)).astype(np.int32)
    scale = float(col.fwd.value_format.scale)
    qj = jnp.asarray(q)
    dj = jnp.asarray(cand)
    for codec in RESCORE_CODECS:
        arrays = {k: jnp.asarray(v) for k, v in layout.pack_rows(col.fwd, codec=codec).arrays().items()}
        us = timeit_us(
            lambda a=arrays, c=codec: score_candidate_rows(
                c, a, dj, qj, scale, backend="jnp"
            ).block_until_ready()
        )
        rows.append(
            Row(f"kernel/rescoring/jnp/{codec}", us,
                f"hbm_bytes_per_q={rows_hbm_bytes(arrays, codec, len(cand), fused=False)}")
        )
        fused = get_kernels(codec).rows_scores
        us = timeit_us(
            lambda a=arrays, f=fused: np.asarray(f(a, dj, qj, scale, True)), repeats=1
        )
        rows.append(
            Row(f"kernel/rescoring/pallas_interpret/{codec}", us,
                f"hbm_bytes_per_q={rows_hbm_bytes(arrays, codec, len(cand), fused=True)}")
        )

    # --- decode-once/score-many query-batch sweep ----------------------
    Q = np.stack([col.query_dense(i % col.n_queries) for i in range(64)])
    sweep_docs = min(n_docs, 800)
    if sweep_docs < n_docs:
        sub = generate_collection(
            splade_config(n_docs=sweep_docs, n_queries=4), value_format="f16"
        )
    else:
        sub = col
    pd_s = pack_forward_index(sub.fwd, codec="dotvbyte")
    ps_s = pack_forward_index(sub.fwd, codec="streamvbyte")
    arrays_s = {
        k: jnp.asarray(v)
        for k, v in layout.pack_rows(sub.fwd, codec="streamvbyte").arrays().items()
    }
    cand_s = jnp.asarray(
        np.sort(rng.choice(sub.fwd.n_docs, size=min(N_CANDIDATES, sub.fwd.n_docs), replace=False)).astype(np.int32)
    )
    scale_s = float(sub.fwd.value_format.scale)
    svb_rows_batch = get_kernels("streamvbyte").rows_scores_batch
    for nq in (1, 8, 64):
        Qn = Q[:nq]
        us = timeit_us(
            lambda: np.asarray(score_dotvbyte_batch(Qn, pd_s, interpret=True)), repeats=1
        )
        rows.append(Row(f"kernel/batch_sweep/dotvbyte_scan/nq{nq}", us,
                        f"us_per_query={us/nq:.1f}"))
        us = timeit_us(
            lambda: np.asarray(score_streamvbyte_batch(Qn, ps_s, interpret=True)), repeats=1
        )
        rows.append(Row(f"kernel/batch_sweep/streamvbyte_scan/nq{nq}", us,
                        f"us_per_query={us/nq:.1f}"))
        us = timeit_us(
            lambda: np.asarray(
                svb_rows_batch(arrays_s, cand_s, jnp.asarray(Qn), scale_s, True)
            ),
            repeats=1,
        )
        rows.append(Row(f"kernel/batch_sweep/streamvbyte_rows/nq{nq}", us,
                        f"us_per_query={us/nq:.1f}"))
    return rows


if __name__ == "__main__":
    from .common import emit

    emit(run())
