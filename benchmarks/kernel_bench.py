"""Kernel microbenchmarks + HBM-traffic accounting.

Every fused kernel family is measured across the three execution modes
(DESIGN.md §3): ``jnp`` (the XLA reference chain), ``pallas_interpret``
(the Pallas emulator — semantics only, wall-clock is meaningless and
measured with repeats=1 purely so the row exists), and
``pallas_compiled`` (Mosaic on TPU hosts, the tiled XLA lowering of the
same tile program on CPU — the number the perf gate tracks). Rows carry
``mode`` and ``codec`` as structured fields on :class:`Row`; nothing
downstream parses the display name.

The ``derived`` column carries the quantity that transfers to TPU:
bytes the pass streams from HBM — the memory-roofline numerator
(``hbm_bytes_per_q``) the §Perf iterations drive down.

Three families:

* ``kernel/scan`` — the full block scan per codec. The jnp chain
  materialises decoded gaps, prefix-summed components and products in
  HBM; the fused tile program streams the encoded payload once and
  writes only slot scores;
* ``kernel/rescoring`` — the serve engines' phase-2 candidate path:
  jnp take→decode→dot vs the fused rows kernel. The fused number must
  be strictly smaller — ``make kernel-parity`` asserts it;
* ``kernel/batch_sweep`` — decode-once/score-many amortisation: the
  query-batched compiled kernels at nq ∈ {1, 8, 64} with per-query
  amortised µs AND per-query amortised HBM bytes (the encoded payload
  is read once for the whole batch, so ``hbm_bytes_per_q`` falls with
  nq — that is the point of the batched grid).
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import layout
from repro.core import values as value_codecs
from repro.core.forward_index import pack_forward_index
from repro.core.scoring import score_candidate_rows, score_packed
from repro.data.synthetic import generate_collection, splade_config
from repro.kernels.ops import (
    score_bitpack_bucketed,
    score_dotvbyte,
    score_dotvbyte_batch,
    score_streamvbyte,
    score_streamvbyte_batch,
)
from repro.kernels.registry import get_kernels

from .common import Row, timeit_us

#: candidate-set size for the rescoring family (a Seismic phase-2
#: probe of 64 blocks × 16-doc blocks lands in this regime)
N_CANDIDATES = 256

#: codecs measured end to end (must all be registered layouts)
SCAN_CODECS = ("uncompressed", "dotvbyte", "streamvbyte", "bitpack")
RESCORE_CODECS = ("uncompressed", "dotvbyte", "streamvbyte", "bitpack")

#: execution modes benchmarked per family
MEASURED_MODES = ("jnp", "pallas_interpret", "pallas_compiled")

#: quantized value codecs swept on the rescoring family (DESIGN.md §12)
VALUE_CODEC_SWEEP = ("u8_sq", "u4_sq", "pq")

#: codec → fused block-scan entry point (mode-dispatching ops wrapper)
_SCAN_FUSED = {
    "dotvbyte": score_dotvbyte,
    "streamvbyte": score_streamvbyte,
    "bitpack": score_bitpack_bucketed,
}


def scan_hbm_bytes(packed, *, fused: bool) -> int:
    """HBM bytes one query's full block scan streams.

    fused — read the encoded streams once, write [B, D] slot scores;
            decoded tiles live and die in VMEM;
    jnp   — the decode→cumsum→dot chain additionally materialises the
            decoded gaps, the prefix-summed components and the products
            (three i32/f32 [B, T] intermediates) in HBM.
    """
    payload = packed.payload_bytes()
    B, T = packed.seg.shape
    D = packed.start_pos.shape[1]
    slot_out = B * D * 4
    if fused:
        return payload + slot_out
    return payload + 3 * B * T * 4 + slot_out


def rows_payload_bytes(arrays, codec: str, n_cand: int) -> int:
    """Encoded bytes the rescoring of ``n_cand`` rows must read from
    HBM: the codec payload + values + nnz of the gathered rows (per-row
    widths as stored, padding included — that is what actually DMAs).

    Value-codec aware (DESIGN.md §12): under a quantized ``vq`` the
    ``vals_rows`` term is already the stored CODE width × u8, the
    scalar-quant clip columns add 8 B/row, and the PQ codebook is read
    once per query (not per row)."""
    per_row = arrays["vals_rows"].shape[1] * arrays["vals_rows"].dtype.itemsize
    per_row += 4  # nnz i32
    for k in ("vq_lo_rows", "vq_scale_rows", "vq_lo4_rows", "vq_scale4_rows"):
        if k in arrays:
            per_row += 4  # per-row f32 clip column, gathered with the row
    if codec == "uncompressed":
        per_row += arrays["comps_rows"].shape[1] * 4
    elif codec == "bitpack":
        per_row += arrays["words_rows"].shape[1] * 4 + 4
    else:
        per_row += arrays["ctrl_rows"].shape[1] + arrays["data_rows"].shape[1]
    once = 0
    if "vq_codebook" in arrays:  # query-resident, read once per query
        once = int(np.prod(arrays["vq_codebook"].shape)) * 4
    return per_row * n_cand + once


def rows_bits_per_posting(arrays, codec: str) -> float:
    """Stored bits per posting of the whole packed row form — ids +
    values + clip ranges + codebooks, padding included (the artifact's
    actual footprint over its live postings)."""
    nnz = int(np.asarray(arrays["nnz_rows"]).sum())
    keys = ["vals_rows", "vq_lo_rows", "vq_scale_rows",
            "vq_lo4_rows", "vq_scale4_rows", "vq_codebook"]
    if codec == "uncompressed":
        keys += ["comps_rows"]
    elif codec == "bitpack":
        keys += ["words_rows", "widths_rows"]
    else:
        keys += ["ctrl_rows", "data_rows"]
    total = sum(int(np.asarray(arrays[k]).nbytes) for k in keys if k in arrays)
    return 8.0 * total / max(nnz, 1)


def rows_hbm_bytes(arrays, codec: str, n_cand: int, *, fused: bool) -> int:
    """HBM bytes one query's candidate rescoring streams.

    fused  — read payload once, write n_cand f32 scores; decoded
             components live and die in VMEM;
    jnp    — the take→decode→dot chain: the gather writes the payload
             back to HBM, the decode writes i32 components (skipped
             for the decode-free uncompressed layout, whose gathered
             comps_rows ARE the components), the dot reads them and
             writes products before the reduction.
    """
    payload = rows_payload_bytes(arrays, codec, n_cand)
    if fused:
        return payload + n_cand * 4
    L = arrays["vals_rows"].shape[1]
    comps = 0 if codec == "uncompressed" else n_cand * L * 4  # decoded i32
    prod = n_cand * L * 4  # qv·vals products before the row reduction
    return payload * 2 + comps + prod + n_cand * 4


def rows_hbm_bytes_batch(
    arrays, codec: str, n_cand: int, nq: int, *, fused: bool
) -> float:
    """Per-query amortised HBM bytes for the nq-query batched rescoring.

    The batched kernels gather+decode the candidate payload ONCE for
    the whole batch, so the payload term amortises over nq while the
    per-query outputs (and, on the jnp path, the per-query product
    intermediates) do not."""
    payload = rows_payload_bytes(arrays, codec, n_cand)
    if fused:
        return payload / nq + n_cand * 4
    L = arrays["vals_rows"].shape[1]
    comps = 0 if codec == "uncompressed" else n_cand * L * 4
    return (payload * 2 + comps) / nq + n_cand * L * 4 + n_cand * 4


def run(
    n_docs: int = 2000,
    modes: tuple[str, ...] = MEASURED_MODES,
    sweep: bool = True,
) -> list[Row]:
    """Measure the requested ``modes`` of every family.

    ``modes`` restricts which execution modes run (the perf gate calls
    with ``("pallas_compiled",)`` to skip the slow interpreter rows);
    ``sweep=False`` drops the batch sweep."""
    col = generate_collection(splade_config(n_docs=n_docs, n_queries=4), value_format="f16")
    q = col.query_dense(0)
    rows: list[Row] = []

    # one FMA per stored component: the roofline numerator (decode
    # shifts/masks are integer ops, not counted — the paper's convention)
    scan_flops = 2 * int(col.fwd.total_nnz)

    # --- block-scan family ---------------------------------------------
    packed_by_codec = {c: pack_forward_index(col.fwd, codec=c) for c in SCAN_CODECS}
    if "jnp" in modes:
        for codec in SCAN_CODECS:
            packed = packed_by_codec[codec]
            us = timeit_us(lambda p=packed: score_packed(q, p).block_until_ready())
            rows.append(
                Row(f"kernel/scan/jnp/{codec}", us,
                    f"hbm_bytes_per_q={scan_hbm_bytes(packed, fused=False)};"
                    f"flops_per_q={scan_flops};"
                    f"hbm_payload_mb={packed.payload_bytes()/2**20:.2f}",
                    mode="jnp", codec=codec)
            )
    for codec, fused_fn in _SCAN_FUSED.items():
        packed = packed_by_codec[codec]
        extra = ""
        if codec == "bitpack":
            tight = sum(
                ((packed.block_size * int(w) + 31) // 32) * 4 for w in packed.widths
            )
            extra = (f";tight_words_mb={tight/2**20:.2f}"
                     f";padded_words_mb={packed.words.nbytes/2**20:.2f}")
        if "pallas_interpret" in modes:
            us = timeit_us(
                lambda p=packed, f=fused_fn: np.asarray(f(q, p, mode="pallas_interpret")),
                repeats=1,
            )
            rows.append(
                Row(f"kernel/scan/pallas_interpret/{codec}", us,
                    "semantic-check-only" + extra,
                    mode="pallas_interpret", codec=codec)
            )
        if "pallas_compiled" in modes:
            us = timeit_us(
                lambda p=packed, f=fused_fn: np.asarray(f(q, p, mode="pallas_compiled"))
            )
            rows.append(
                Row(f"kernel/scan/pallas_compiled/{codec}", us,
                    f"hbm_bytes_per_q={scan_hbm_bytes(packed, fused=True)};"
                    f"flops_per_q={scan_flops}" + extra,
                    mode="pallas_compiled", codec=codec)
            )

    # --- candidate-rescoring family: jnp chain vs fused rows kernel ----
    rng = np.random.default_rng(0)
    n = col.fwd.n_docs
    cand = np.sort(rng.choice(n, size=min(N_CANDIDATES, n), replace=False)).astype(np.int32)
    scale = float(col.fwd.value_format.scale)
    qj = jnp.asarray(q)
    dj = jnp.asarray(cand)
    for codec in RESCORE_CODECS:
        arrays = {k: jnp.asarray(v) for k, v in layout.pack_rows(col.fwd, codec=codec).arrays().items()}
        # one FMA per (candidate, padded slot) — what actually executes
        rows_flops = 2 * len(cand) * int(arrays["vals_rows"].shape[1])
        if "jnp" in modes:
            us = timeit_us(
                lambda a=arrays, c=codec: score_candidate_rows(
                    c, a, dj, qj, scale, backend="jnp"
                ).block_until_ready()
            )
            rows.append(
                Row(f"kernel/rescoring/jnp/{codec}", us,
                    f"hbm_bytes_per_q={rows_hbm_bytes(arrays, codec, len(cand), fused=False)};"
                    f"flops_per_q={rows_flops}",
                    mode="jnp", codec=codec)
            )
        fused = get_kernels(codec).rows_scores
        hbm_fused = rows_hbm_bytes(arrays, codec, len(cand), fused=True)
        if "pallas_interpret" in modes:
            us = timeit_us(
                lambda a=arrays, f=fused: np.asarray(
                    f(a, dj, qj, scale, "pallas_interpret")
                ),
                repeats=1,
            )
            rows.append(
                Row(f"kernel/rescoring/pallas_interpret/{codec}", us,
                    f"hbm_bytes_per_q={hbm_fused}",
                    mode="pallas_interpret", codec=codec)
            )
        if "pallas_compiled" in modes:
            us = timeit_us(
                lambda a=arrays, f=fused: np.asarray(
                    f(a, dj, qj, scale, "pallas_compiled")
                )
            )
            rows.append(
                Row(f"kernel/rescoring/pallas_compiled/{codec}", us,
                    f"hbm_bytes_per_q={hbm_fused};flops_per_q={rows_flops};"
                    f"bits_per_posting={rows_bits_per_posting(arrays, codec):.1f}",
                    mode="pallas_compiled", codec=codec)
            )

    # --- value-codec sweep: quantized fused rescoring (DESIGN.md §12) --
    # the bandwidth-bound path re-measured with in-kernel dequant; rows
    # carry the structured ``vq`` field, so the perf gate's values leg
    # can hold u8_sq against the committed f16 rows by field, not name
    for codec in RESCORE_CODECS:
        for vq in VALUE_CODEC_SWEEP:
            arrays = {
                k: jnp.asarray(v)
                for k, v in layout.pack_rows(
                    col.fwd, codec=codec, vq=vq
                ).arrays().items()
            }
            bpp = rows_bits_per_posting(arrays, codec)
            hbm_fused = rows_hbm_bytes(arrays, codec, len(cand), fused=True)
            # FMAs over the LOGICAL (decoded) row width — the code
            # stream is narrower, but every decoded slot still dots
            logical = int(arrays["vals_rows"].shape[1]) * value_codecs.code_factor(vq)
            vq_flops = 2 * len(cand) * logical
            if "jnp" in modes:
                us = timeit_us(
                    lambda a=arrays, c=codec: score_candidate_rows(
                        c, a, dj, qj, scale, backend="jnp"
                    ).block_until_ready()
                )
                rows.append(
                    Row(f"kernel/rescoring/jnp/{codec}+{vq}", us,
                        f"hbm_bytes_per_q={rows_hbm_bytes(arrays, codec, len(cand), fused=False)};"
                        f"bits_per_posting={bpp:.1f}",
                        mode="jnp", codec=codec, vq=vq)
                )
            if "pallas_compiled" in modes:
                fused = get_kernels(codec).rows_scores
                us = timeit_us(
                    lambda a=arrays, f=fused: np.asarray(
                        f(a, dj, qj, scale, "pallas_compiled")
                    )
                )
                rows.append(
                    Row(f"kernel/rescoring/pallas_compiled/{codec}+{vq}", us,
                        f"hbm_bytes_per_q={hbm_fused};flops_per_q={vq_flops};"
                        f"bits_per_posting={bpp:.1f}",
                        mode="pallas_compiled", codec=codec, vq=vq)
                )

    if not sweep:
        return rows

    # --- decode-once/score-many query-batch sweep ----------------------
    # compiled mode: the amortisation story is about the deployable path
    Q = np.stack([col.query_dense(i % col.n_queries) for i in range(64)])
    sweep_docs = min(n_docs, 800)
    if sweep_docs < n_docs:
        sub = generate_collection(
            splade_config(n_docs=sweep_docs, n_queries=4), value_format="f16"
        )
    else:
        sub = col
    pd_s = pack_forward_index(sub.fwd, codec="dotvbyte")
    ps_s = pack_forward_index(sub.fwd, codec="streamvbyte")
    arrays_s = {
        k: jnp.asarray(v)
        for k, v in layout.pack_rows(sub.fwd, codec="streamvbyte").arrays().items()
    }
    cand_s = jnp.asarray(
        np.sort(rng.choice(sub.fwd.n_docs, size=min(N_CANDIDATES, sub.fwd.n_docs), replace=False)).astype(np.int32)
    )
    n_cand_s = int(cand_s.shape[0])
    scale_s = float(sub.fwd.value_format.scale)
    svb_rows_batch = get_kernels("streamvbyte").rows_scores_batch
    for nq in (1, 8, 64):
        Qn = Q[:nq]
        for codec, packed, fn in (
            ("dotvbyte", pd_s, score_dotvbyte_batch),
            ("streamvbyte", ps_s, score_streamvbyte_batch),
        ):
            us = timeit_us(
                lambda f=fn, p=packed: np.asarray(f(Qn, p, mode="pallas_compiled"))
            )
            # payload read once per batch; slot-score writes stay per query
            hbm_q = packed.payload_bytes() / nq + (
                scan_hbm_bytes(packed, fused=True) - packed.payload_bytes()
            )
            rows.append(
                Row(f"kernel/batch_sweep/{codec}_scan/nq{nq}", us,
                    f"us_per_query={us/nq:.1f};hbm_bytes_per_q={hbm_q:.0f}",
                    mode="pallas_compiled", codec=codec)
            )
        us = timeit_us(
            lambda: np.asarray(
                svb_rows_batch(arrays_s, cand_s, jnp.asarray(Qn), scale_s,
                               "pallas_compiled")
            )
        )
        hbm_q = rows_hbm_bytes_batch(arrays_s, "streamvbyte", n_cand_s, nq, fused=True)
        rows.append(
            Row(f"kernel/batch_sweep/streamvbyte_rows/nq{nq}", us,
                f"us_per_query={us/nq:.1f};hbm_bytes_per_q={hbm_q:.0f}",
                mode="pallas_compiled", codec="streamvbyte")
        )
    return rows


if __name__ == "__main__":
    from .common import emit

    emit(run())
