"""Table 1 — bits/component and full-collection scan time per codec,
with and without RGB component re-ordering.

Paper setup: SPLADE MsMarco, inner product of every document against
100 dev-small queries. Here: synthetic SPLADE-statistics collection
(matched nnz + Zipf gaps + topic structure, labels scrambled), smaller
collection (CPU), 8 queries. Expected *qualitative* reproduction:

* uncompressed = 16 bits, fastest scan;
* Zeta smallest bits, slow scan; VByte/Elias in between;
* StreamVByte fastest of the compressed codecs but largest;
* RGB shrinks every codec (strongest on Elias Gamma — paper: −27 %);
* DotVByte: smaller than StreamVByte AND ~3× faster (fused path);
* DotNibble (paper §4 future work, ours): sub-byte codes beat DotVByte
  by ~1.8 bits/component after RGB;
* bitpack (beyond paper): TPU-native fixed-width — smallest byte-aligned.
"""

from __future__ import annotations

import numpy as np

from repro.core.codecs import get_codec
from repro.core.forward_index import ForwardIndex, pack_forward_index
from repro.core.rgb import recursive_graph_bisection
from repro.core.scoring import score_packed
from repro.data.synthetic import generate_collection, splade_config

from .common import Row, timeit_us

CODEC_ORDER = [
    "uncompressed", "vbyte", "elias_gamma", "elias_delta", "zeta",
    "streamvbyte", "dotvbyte", "dotnibble", "bitpack",
]
PACKED = {"uncompressed", "dotvbyte", "bitpack"}  # fused jnp scan path


def _scan_numpy(fwd: ForwardIndex, codec_name: str, bufs, q) -> np.ndarray:
    """Per-document decode + dot — the paper's scan loop for the
    buffer-decoding codecs (decode cost on the query path)."""
    codec = get_codec(codec_name)
    out = np.zeros(fwd.n_docs, dtype=np.float32)
    vf = fwd.value_format
    for d in range(fwd.n_docs):
        n = fwd.nnz(d)
        comps = codec.decode_doc(bufs[d], n)
        s, e = int(fwd.offsets[d]), int(fwd.offsets[d + 1])
        out[d] = q[comps] @ vf.dequantise(fwd.values[s:e])
    return out


def run(n_docs: int = 4000, n_queries: int = 4, rgb_iters: int = 6) -> list[Row]:
    col = generate_collection(splade_config(n_docs=n_docs, n_queries=max(n_queries, 4)))
    fwd = col.fwd
    queries = [col.query_dense(i) for i in range(n_queries)]

    # RGB permutation (host-side, once per index build — like the paper)
    docs = [fwd.components[int(s):int(e)]
            for s, e in zip(fwd.offsets[:-1], fwd.offsets[1:])]
    pi = recursive_graph_bisection(docs, fwd.dim, max_iters=rgb_iters, leaf_size=32)
    fwd_rgb = fwd.apply_component_permutation(pi)
    from repro.core.rgb import apply_permutation_dense

    queries_rgb = [apply_permutation_dense(q, pi) for q in queries]

    rows: list[Row] = []
    for tag, f, qs in (("no_rgb", fwd, queries), ("rgb", fwd_rgb, queries_rgb)):
        docs_f = [f.components[int(s):int(e)]
                  for s, e in zip(f.offsets[:-1], f.offsets[1:])]
        for name in CODEC_ORDER:
            codec = get_codec(name)
            bpc = codec.bits_per_component(docs_f)
            if name in PACKED:
                packed = pack_forward_index(f, codec=name)

                def scan(packed=packed, qs=qs):
                    for q in qs:
                        score_packed(q, packed).block_until_ready()

                us = timeit_us(scan, repeats=3, warmup=1) / n_queries
            else:
                bufs = [codec.encode_doc(c) for c in docs_f]

                def scan(f=f, name=name, bufs=bufs, qs=qs):
                    for q in qs:
                        _scan_numpy(f, name, bufs, q)

                us = timeit_us(scan, repeats=1, warmup=0) / n_queries
            rows.append(Row(f"table1/{name}/{tag}", us, f"bits_per_component={bpc:.2f}"))
    return rows


if __name__ == "__main__":
    from .common import emit

    emit(run())
