"""Table 7 — overlapped serving: host prefetch + background compaction
(EXPERIMENTS.md §Overlap).

Two overlap mechanisms from DESIGN.md §11, each priced against its
synchronous twin on the SAME paced request stream (open-loop arrivals:
a fixed think-time gap between requests — the idle window a real
serving loop has between batches, which is exactly where overlap can
hide work):

* ``overlap/prefetch-{on,off}/<engine>-<codec>/r1`` — per-request
  latency through the out-of-core sequential sharded path at
  ``max_resident=1`` (every rotation pages a shard in and evicts the
  previous one — the most hostile residency). With the prefetcher on,
  the wrap-around stage (next request's opening shard, mmap + plan
  warm) runs on the worker thread during the think-time gap instead of
  on the first rotation of the next request; derived carries
  ``p95_us``/``mean_us`` per request plus the honest residency
  counters (``prefetch_hits``/``prefetch_misses``, evictions,
  recompiles).
* ``overlap/prefetch-gate/<engine>-<codec>`` — NaN-fail gate (the
  standing convention: a NaN ``us`` fails the smoke): prefetch-on p95
  must not exceed prefetch-off p95. Results are byte-identical either
  way (``tools/overlap_parity.py``); this gate prices the mechanism.

* ``overlap/merge-idle/…`` — serving p95 of a ``MutableRetriever``
  stream with no compaction running (the baseline).
* ``overlap/merge-background/…`` — the same stream while
  ``merge(background=True)`` builds + commits generation N+1 on a
  worker thread; the stream runs THROUGH the commit flip. Derived
  carries the merge build wall-clock and the commit critical-section
  time (``blocked_swap_us`` — the only window a query can block).
* ``overlap/merge-stopworld/…`` — the foreground ``merge()``
  wall-clock on an identical twin index: what every in-flight query
  would have eaten with stop-the-world compaction.
* ``overlap/merge-gate/…`` — NaN-fail gate: serving p95 during the
  background merge must stay within ``MERGE_GATE_FACTOR``× of the
  idle p95 (vs the stop-the-world alternative of a full merge-wall
  stall).

Absolute µs are single-core CPU-XLA wall clock (worker and serving
thread share the core, so overlap wins come from the think-time gap,
not extra silicon); the reproducible claim is the shape: prefetch-on
≤ prefetch-off, background-merge p95 bounded while stop-the-world
pays the full build wall.
"""

from __future__ import annotations

import tempfile
import time

import numpy as np

from .common import Row

#: prefetch-on must not lose to prefetch-off (it moves work off the
#: hot path; byte-parity is checked elsewhere)
PREFETCH_GATE_FACTOR = 1.0
#: serving p95 during a background merge vs idle p95
MERGE_GATE_FACTOR = 2.0

BUCKET = 8
N_SHARDS = 3


def _paced_stream(search, batches, gap_s: float) -> np.ndarray:
    """Per-request wall µs over an open-loop paced stream: one request
    per batch, ``gap_s`` think-time between arrivals (the window where
    background work may proceed)."""
    samples = []
    for b in batches:
        t0 = time.perf_counter()
        np.asarray(search(b)[0])
        samples.append((time.perf_counter() - t0) * 1e6)
        if gap_s:
            time.sleep(gap_s)
    return np.asarray(samples)


def _prefetch_rows(col, Q, n_requests: int, engine: str, codec: str
                   ) -> list[Row]:
    from repro.serve.api import Retriever, RetrieverConfig, open_retriever

    cfg = RetrieverConfig(engine=engine, codec=codec, k=10,
                          n_shards=N_SHARDS)
    batches = [
        np.asarray(Q[np.arange(i * BUCKET, (i + 1) * BUCKET) % Q.shape[0]])
        for i in range(n_requests)
    ]
    rows: list[Row] = []
    p95 = {}
    with tempfile.TemporaryDirectory() as tmp:
        Retriever.build(col.fwd, cfg).save(tmp)

        # probe once (prefetch off) to size the think-time gap: the
        # worker needs roughly one shard's page-in (~1/S of a request)
        # inside the gap for the wrap-around stage to be ready
        probe = open_retriever(tmp)
        probe.max_resident = 1
        probe.prefetch = False
        t0 = time.perf_counter()
        np.asarray(probe.search(batches[0])[0])
        gap_s = 1.5 * (time.perf_counter() - t0) / N_SHARDS

        for label, prefetch in (("off", False), ("on", True)):
            r = open_retriever(tmp)  # fresh residency + counters
            r.max_resident = 1
            r.prefetch = prefetch
            np.asarray(r.search(batches[0])[0])  # settle the rotation
            samples = _paced_stream(r.search, batches, gap_s)
            mean_us = float(samples.mean())
            p95[label] = float(np.percentile(samples, 95))
            rows.append(Row(
                f"overlap/prefetch-{label}/{engine}-{codec}/r1",
                mean_us,
                f"p95_us={p95[label]:.0f};mean_us={mean_us:.0f};"
                f"gap_us={gap_s * 1e6:.0f};n_requests={n_requests};"
                f"prefetch_hits={r.prefetch_hits};"
                f"prefetch_misses={r.prefetch_misses};"
                f"evictions={r.evictions};recompiles={r.plans.compiles}",
                codec=codec,
            ))
    ok = p95["on"] <= PREFETCH_GATE_FACTOR * p95["off"]
    rows.append(Row(
        f"overlap/prefetch-gate/{engine}-{codec}",
        p95["on"] if ok else float("nan"),
        f"off_p95_us={p95['off']:.0f};factor={p95['on'] / p95['off']:.2f};"
        f"bound={PREFETCH_GATE_FACTOR}",
        codec=codec,
    ))
    return rows


def _merge_rows(col, Q, n_requests: int, engine: str, codec: str
                ) -> list[Row]:
    from repro.serve.api import RetrieverConfig
    from repro.serve.segments import MutableRetriever

    cfg = RetrieverConfig(engine=engine, codec=codec, k=10)
    n_docs = col.fwd.n_docs
    seg = max(4, n_docs // 64)
    base = col.fwd.slice(0, n_docs - 2 * seg)

    def build():
        m = MutableRetriever.create(base, cfg)
        for j in range(2):
            lo = base.n_docs + j * seg
            m.insert([col.fwd.doc(i) for i in range(lo, lo + seg)])
        m.delete([1, 3, 5])
        return m

    batches = [
        np.asarray(Q[np.arange(i * BUCKET, (i + 1) * BUCKET) % Q.shape[0]])
        for i in range(n_requests)
    ]
    gap_s = 0.02
    rows: list[Row] = []

    m = build()
    np.asarray(m.search(batches[0])[0])  # compile + admit every part
    idle = _paced_stream(m.search, batches, gap_s)
    idle_p95 = float(np.percentile(idle, 95))
    rows.append(Row(
        f"overlap/merge-idle/{engine}-{codec}/bucket{BUCKET}",
        float(idle.mean()),
        f"p95_us={idle_p95:.0f};n_requests={len(idle)};"
        f"bucket={BUCKET};n_live={m.n_live}",
        codec=codec,
    ))

    # stop-the-world twin: the wall every in-flight query would eat
    twin = build()
    np.asarray(twin.search(batches[0])[0])
    t0 = time.perf_counter()
    twin.merge()
    stw_us = (time.perf_counter() - t0) * 1e6
    rows.append(Row(
        f"overlap/merge-stopworld/{engine}-{codec}",
        stw_us,
        f"n_live={twin.n_live};generation={twin.generation}",
        codec=codec,
    ))

    # background merge with the stream running THROUGH the commit flip
    handle = m.merge(background=True)
    during = []
    i = 0
    while (not handle.done()) and len(during) < 50 * n_requests:
        b = batches[i % len(batches)]
        t0 = time.perf_counter()
        np.asarray(m.search(b)[0])
        during.append((time.perf_counter() - t0) * 1e6)
        i += 1
        time.sleep(gap_s)
    handle.result()
    np.asarray(m.search(batches[0])[0])  # post-flip: plans pre-warmed
    during = np.asarray(during if during else [float("nan")])
    during_p95 = float(np.percentile(during, 95))
    rows.append(Row(
        f"overlap/merge-background/{engine}-{codec}/bucket{BUCKET}",
        float(during.mean()),
        f"p95_us={during_p95:.0f};n_requests={len(during)};"
        f"merge_wall_us={m.merge_wall_us:.0f};"
        f"blocked_swap_us={m.blocked_swap_us:.0f};"
        f"generation={m.generation}",
        codec=codec,
    ))

    ok = during_p95 <= MERGE_GATE_FACTOR * idle_p95
    rows.append(Row(
        f"overlap/merge-gate/{engine}-{codec}",
        during_p95 if ok else float("nan"),
        f"idle_p95_us={idle_p95:.0f};factor={during_p95 / idle_p95:.2f};"
        f"bound={MERGE_GATE_FACTOR};stopworld_wall_us={stw_us:.0f}",
        codec=codec,
    ))
    return rows


def run(n_docs: int = 1500, n_queries: int = 16, n_requests: int = 10,
        engine: str = "flat", codec: str = "streamvbyte") -> list[Row]:
    from repro.data.synthetic import generate_collection, splade_config

    col = generate_collection(splade_config(n_docs, n_queries, seed=0),
                              value_format="f16")
    Q = np.stack([col.query_dense(i) for i in range(n_queries)])
    return (_prefetch_rows(col, Q, n_requests, engine, codec)
            + _merge_rows(col, Q, n_requests, engine, codec))
