"""Shared benchmark scaffolding.

CSV convention (benchmarks/run.py): ``name,us_per_call,derived`` — one
row per measured configuration, ``derived`` carrying the table-specific
secondary metric (bits/component, recall, GB, …).

All wall-clock numbers here are single-thread CPU-XLA / numpy: the paper
measures single-thread Rust+SIMD, so absolute values differ; the
*relative* codec orderings are what reproduce (EXPERIMENTS.md
§Paper-fidelity). TPU projections come from the roofline, not timers.
"""

from __future__ import annotations

import json
import pathlib
import subprocess
import time
from typing import Callable, Iterable

import numpy as np

__all__ = ["timeit_us", "Row", "emit", "git_sha", "write_bench_json"]


def timeit_us(fn: Callable, *, repeats: int = 3, warmup: int = 1) -> float:
    for _ in range(warmup):
        fn()
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


class Row:
    """One measured configuration.

    ``mode`` (kernel execution mode — jnp | pallas_interpret |
    pallas_compiled), ``codec`` and ``vq`` (value codec, DESIGN.md
    §12) are STRUCTURED fields: consumers (the perf gate, the
    roofline) select rows by them rather than parsing the display
    name, which stays free-form. ``vq=None`` marks a pre-value-codec
    row (implicitly f16 values); rows that sweep the value-codec axis
    set it explicitly."""

    def __init__(
        self,
        name: str,
        us_per_call: float,
        derived: str,
        *,
        mode: str | None = None,
        codec: str | None = None,
        vq: str | None = None,
    ):
        self.name, self.us, self.derived = name, us_per_call, derived
        self.mode, self.codec, self.vq = mode, codec, vq

    def csv(self) -> str:
        return f"{self.name},{self.us:.1f},{self.derived}"


def emit(rows: list[Row]) -> None:
    print("name,us_per_call,derived")
    for r in rows:
        print(r.csv())


def git_sha() -> str:
    """Short sha of HEAD, ``-dirty``-suffixed when the tree has
    uncommitted changes — snapshots are typically generated pre-commit,
    and the suffix keeps `git log -p BENCH_*.json` honest about it."""
    try:
        return subprocess.run(
            ["git", "describe", "--always", "--dirty"],
            cwd=pathlib.Path(__file__).resolve().parent.parent,
            capture_output=True, text=True, timeout=10, check=True,
        ).stdout.strip()
    except Exception:
        return "unknown"


def _parse_derived(derived: str) -> dict:
    """``key=value;key=value`` derived strings → a dict (numbers become
    floats); free-text derived stays under ``"note"``."""
    out: dict = {}
    for part in derived.split(";"):
        if "=" not in part:
            if part:
                out["note"] = part
            continue
        k, v = part.split("=", 1)
        try:
            out[k] = float(v)
        except ValueError:
            out[k] = v
    return out


def write_bench_json(
    path, rows: Iterable[Row], *, sha: str | None = None,
    meta: dict | None = None,
) -> None:
    """Machine-readable benchmark snapshot (``BENCH_*.json``).

    Schema (one file per benchmark family, tracked across PRs so the
    perf trajectory is diffable): ``{"schema", "git_sha", "rows":
    [{"name", "us", "derived": {…}}]}`` — ``us`` is the best-of-repeats
    wall-clock per call, ``derived`` the parsed secondary metrics
    (HBM bytes, recall, per-query amortised µs, …). ``meta`` merges
    extra provenance keys (e.g. the run ``mode``: collection sizes
    differ between quick/fast/full, so trajectories only compare
    like-for-like)."""
    payload = {
        "schema": "repro.bench.v1",
        **(meta or {}),
        "git_sha": sha if sha is not None else git_sha(),
        "rows": [
            {
                # non-finite → null: bare NaN/Infinity tokens are not JSON
                "us": round(r.us, 1) if np.isfinite(r.us) else None,
                "name": r.name,
                # structured row identity (never parsed out of the name)
                **({"mode": r.mode} if r.mode is not None else {}),
                **({"codec": r.codec} if r.codec is not None else {}),
                **({"vq": r.vq} if r.vq is not None else {}),
                "derived": {
                    k: (v if not isinstance(v, float) or np.isfinite(v) else None)
                    for k, v in _parse_derived(r.derived).items()
                },
            }
            for r in rows
        ],
    }
    p = pathlib.Path(path)
    p.write_text(json.dumps(payload, indent=1, sort_keys=True) + "\n", encoding="utf-8")
