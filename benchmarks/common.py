"""Shared benchmark scaffolding.

CSV convention (benchmarks/run.py): ``name,us_per_call,derived`` — one
row per measured configuration, ``derived`` carrying the table-specific
secondary metric (bits/component, recall, GB, …).

All wall-clock numbers here are single-thread CPU-XLA / numpy: the paper
measures single-thread Rust+SIMD, so absolute values differ; the
*relative* codec orderings are what reproduce (EXPERIMENTS.md
§Paper-fidelity). TPU projections come from the roofline, not timers.
"""

from __future__ import annotations

import time
from typing import Callable

import numpy as np

__all__ = ["timeit_us", "Row", "emit"]


def timeit_us(fn: Callable, *, repeats: int = 3, warmup: int = 1) -> float:
    for _ in range(warmup):
        fn()
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


class Row:
    def __init__(self, name: str, us_per_call: float, derived: str):
        self.name, self.us, self.derived = name, us_per_call, derived

    def csv(self) -> str:
        return f"{self.name},{self.us:.1f},{self.derived}"


def emit(rows: list[Row]) -> None:
    print("name,us_per_call,derived")
    for r in rows:
        print(r.csv())
