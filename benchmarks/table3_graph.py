"""Table 3 — graph vs inverted-index sparse MIPS: the same compressed
forward index served through both engines (EXPERIMENTS.md §Graph).

The paper frames forward-index compression as common to *all* ANNS
flavors — "the inverted index-based Seismic and the graph-based HNSW".
This table demonstrates it: one collection, one row-form packed layout
per codec, two engines with very different access patterns —

* **seismic** — two-phase block probe; candidates arrive in bulk
  (≤ n_probe·block_size rows decoded per query);
* **hnsw** — static beam search; ≤ M rows decoded per hop, every hop
  data-dependent on the previous one's scores.

Rows: ``table3/<engine>/splade/<codec>`` with recall@10, per-query
latency, index MiB (forward + engine structure) and bits/component.
Expectation: identical top-k ids per engine across codecs (lossless
components), recall@10 ≥ 0.9 for both engines, HNSW index smaller than
Seismic's (adjacency vs inverted lists + summaries).
"""

from __future__ import annotations

import numpy as np

from repro.core.hnsw import HNSWIndex, HNSWParams
from repro.core.layout import available_layouts
from repro.core.seismic import SeismicIndex, SeismicParams, exact_top_k, recall_at_k
from repro.data.synthetic import generate_collection, splade_config

from .common import Row, timeit_us

#: every codec registered in core/layout.py serves both engines
ENGINE_CODECS = available_layouts()


def run(n_docs: int = 2000, n_queries: int = 8, *, col=None) -> list[Row]:
    import jax.numpy as jnp

    from repro.serve.api import Retriever, RetrieverConfig

    if col is None:
        col = generate_collection(splade_config(n_docs, n_queries, seed=0),
                                  value_format="f16")
    n_queries = col.n_queries
    Q = jnp.asarray(np.stack([col.query_dense(i) for i in range(n_queries)]))
    truth = [exact_top_k(col.fwd, col.query_dense(i), 10)[0] for i in range(n_queries)]

    seismic = SeismicIndex.build(
        col.fwd, SeismicParams(n_postings=1500, block_size=32)
    )
    hnsw = HNSWIndex.build(col.fwd, HNSWParams(m=16, ef_construction=48))

    rows: list[Row] = []
    for codec in ENGINE_CODECS:
        engines = {
            "seismic": (
                Retriever.from_host_index(
                    seismic,
                    RetrieverConfig(engine="seismic", codec=codec, k=10,
                                    params=dict(cut=8, block_budget=512, n_probe=64)),
                ),
                seismic.index_bytes(codec)["total"],
            ),
            "hnsw": (
                Retriever.from_host_index(
                    hnsw,
                    RetrieverConfig(engine="hnsw", codec=codec, k=10,
                                    params=dict(beam=64, iters=64, n_seeds=8)),
                ),
                hnsw.index_bytes(codec)["total"],
            ),
        }
        for name, (eng, index_bytes) in engines.items():
            ids, _ = eng.search(Q)  # compile + correctness sample
            rec = float(np.mean([recall_at_k(truth[i], np.asarray(ids[i]))
                                 for i in range(n_queries)]))
            us = timeit_us(lambda: eng.search(Q)[0].block_until_ready()) / n_queries
            comp_bytes = col.fwd.storage_bytes(codec)["components"]
            rows.append(
                Row(
                    f"table3/{name}/splade/{codec}",
                    us,
                    f"recall={rec:.3f};index_mb={index_bytes/2**20:.1f};"
                    f"comp_bits={8*comp_bytes/col.fwd.total_nnz:.1f}",
                )
            )
    return rows


if __name__ == "__main__":
    from .common import emit

    emit(run())
