"""Table 2 — Seismic query latency (µs) at fixed accuracy levels, per
components codec × values format, plus index size.

Paper setup: MsMarco + SPLADE/LILSR, hyperparameter sweep over
heap_factor ∈ {0.7..1.0} and cut ∈ {2..12}; for each accuracy level the
best (lowest-latency) configuration is reported, along with index GB.
Here: synthetic matched-statistics collections, reduced sweep, numpy
reference engine with codec-timed rescoring (decode happens inside the
measured query path, as in the paper).

Qualitative expectations (paper): Zeta = slowest / smallest;
StreamVByte trades space for ~3× uncompressed latency; DotVByte ≈
uncompressed latency with ~12-22 % space saving; fixedU8 halves the
values array with minimal degradation.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core.layout import available_layouts
from repro.core.seismic import SeismicIndex, SeismicParams, exact_top_k, recall_at_k
from repro.data.synthetic import generate_collection, lilsr_config, splade_config

from .common import Row, timeit_us

CODECS = ["uncompressed", "zeta", "streamvbyte", "dotvbyte"]
#: TPU serving path — every codec registered in core/layout.py serves
ENGINE_CODECS = available_layouts()
ACCURACY_LEVELS = (0.90, 0.95)
SWEEP = [(0.8, 4), (0.9, 8), (1.0, 12)]  # (heap_factor, cut)


def _eval(index, col, codec, k=10):
    """→ list of (recall, us_per_query) across the hyperparameter sweep."""
    truth = [exact_top_k(col.fwd, col.query_dense(i), k)[0] for i in range(col.n_queries)]
    out = []
    for hf, cut in SWEEP:
        t0 = time.perf_counter()
        recs = []
        for i in range(col.n_queries):
            ids, _ = index.search(col.query_dense(i), k=k, heap_factor=hf, cut=cut,
                                  codec=codec)
            recs.append(recall_at_k(truth[i], ids))
        us = (time.perf_counter() - t0) * 1e6 / col.n_queries
        out.append((float(np.mean(recs)), us))
    return out


def run_engine(
    n_docs: int = 3000, n_queries: int = 10, *, col=None, index=None, truth=None
) -> list[Row]:
    """Batched static-shape engine latency per codec (decode inside the
    measured jit'd search, codecs swapped through core/layout.py and
    served through the unified ``repro.serve.api`` Retriever).

    ``run()`` passes its already-built splade/f16 collection+index+truth
    so the engine section costs no second index build.

    Expectation: identical top-k across codecs (lossless components),
    latency ordering uncompressed ≤ dotvbyte ≤ streamvbyte on CPU-XLA."""
    import jax.numpy as jnp

    from repro.serve.api import Retriever, RetrieverConfig

    rows: list[Row] = []
    if col is None:
        col = generate_collection(splade_config(n_docs, n_queries, seed=0), value_format="f16")
    n_queries = col.n_queries
    if index is None:
        index = SeismicIndex.build(col.fwd, SeismicParams(n_postings=1500, block_size=32))
    Q = jnp.asarray(np.stack([col.query_dense(i) for i in range(n_queries)]))
    if truth is None:
        truth = [exact_top_k(col.fwd, col.query_dense(i), 10)[0] for i in range(n_queries)]
    for codec in ENGINE_CODECS:
        eng = Retriever.from_host_index(
            index,
            RetrieverConfig(engine="seismic", codec=codec, k=10,
                            params=dict(cut=8, block_budget=512, n_probe=64)),
        )
        ids, _ = eng.search(Q)  # compile + correctness sample
        rec = float(np.mean([recall_at_k(truth[i], np.asarray(ids[i]))
                             for i in range(n_queries)]))
        us = timeit_us(lambda: eng.search(Q)[0].block_until_ready()) / n_queries
        comp_bytes = col.fwd.storage_bytes(codec)["components"]
        rows.append(
            Row(
                f"table2/engine/splade/{codec}",
                us,
                f"recall={rec:.3f};comp_bits={8*comp_bytes/col.fwd.total_nnz:.1f}",
            )
        )
    return rows


def run(n_docs: int = 3000, n_queries: int = 10) -> list[Row]:
    rows: list[Row] = []
    engine_col = engine_index = None  # splade/f16 build reused by run_engine
    for enc_name, cfg_fn in (("splade", splade_config), ("lilsr", lilsr_config)):
        for vf in ("f16", "fixedu8"):
            col = generate_collection(cfg_fn(n_docs, n_queries, seed=0), value_format=vf)
            index = SeismicIndex.build(
                col.fwd, SeismicParams(n_postings=1500, block_size=32)
            )
            if enc_name == "splade" and vf == "f16":
                engine_col, engine_index = col, index
            for codec in CODECS:
                if codec != "uncompressed":
                    index.prepare_codec(codec)
                sweep = _eval(index, col, codec)
                comp_bytes = col.fwd.storage_bytes(codec)["components"]
                total = index.index_bytes(codec)["total"]
                for level in ACCURACY_LEVELS:
                    ok = [us for rec, us in sweep if rec >= level]
                    us = min(ok) if ok else float("nan")
                    rows.append(
                        Row(
                            f"table2/{enc_name}/{vf}/{codec}/acc{int(level*100)}",
                            us,
                            f"index_mb={total/2**20:.1f};comp_bits="
                            f"{8*comp_bytes/col.fwd.total_nnz:.1f}",
                        )
                    )
    rows.extend(run_engine(n_docs, n_queries, col=engine_col, index=engine_index))
    return rows


if __name__ == "__main__":
    from .common import emit

    emit(run())
