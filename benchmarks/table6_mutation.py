"""Table 6 — serving under live index mutation (EXPERIMENTS.md
§Mutation).

The mutable retriever (DESIGN.md §10) serves delta segments next to the
base index instead of rebuilding, at the cost of fanning every query
over base + segments and merging an O(k·parts) candidate strip. This
table prices that trade, sweeping live segment count against the merged
(compacted) baseline:

* ``mutation/<engine>-<codec>/segs<n>/bucket8`` — per-query latency
  through ``MutableRetriever.search`` with ``n`` live delta segments
  (plus a handful of tombstones once segments exist); derived carries
  ``p95_us_per_q``, ``us_per_q`` (mean), ``qps``, ``n_live``.
* ``mutation/<engine>-<codec>/merged/bucket8`` — the same stream after
  ``merge()`` folds everything into a fresh single-part generation.
* ``mutation/merge/<engine>-<codec>`` — merge/compaction wall-clock
  (rebuild + atomic generation flip); derived carries ``n_live`` and
  the number of segments folded.
* ``mutation/latency-gate/<engine>-<codec>`` — NaN-fail gate (the
  standing convention: a NaN ``us`` fails the smoke): 1-live-segment
  serving p95 must stay within ``GATE_FACTOR``× of the merged p95.
  One delta segment is the steady state under trickle updates; if it
  already costs more than this, compaction would have to run after
  every insert and the mutation path buys nothing.

As everywhere in this harness, absolute µs are CPU-XLA wall clock; the
reproducible claim is the *shape*: latency degrading gently in live
segment count, merge amortising the degradation away.
"""

from __future__ import annotations

import time

import numpy as np

from .common import Row

#: 1-segment p95 may pay fan-out + merge overhead, but no more than
#: this factor over the compacted generation
GATE_FACTOR = 1.5

BUCKET = 8
SEGMENT_COUNTS = (0, 1, 4)


def _per_query_us(m, batches) -> tuple[float, float]:
    """(mean, p95) per-query µs over one warm pass of ``batches``."""
    np.asarray(m.search(batches[0])[0])  # compile + admit every part
    samples = []
    for b in batches:
        t0 = time.perf_counter()
        np.asarray(m.search(b)[0])
        samples.append((time.perf_counter() - t0) * 1e6 / b.shape[0])
    arr = np.asarray(samples)
    return float(arr.mean()), float(np.percentile(arr, 95))


def run(n_docs: int = 2000, n_queries: int = 32, n_requests: int = 48,
        engine: str = "flat", codec: str = "streamvbyte") -> list[Row]:
    from repro.data.synthetic import generate_collection, splade_config
    from repro.serve.api import RetrieverConfig
    from repro.serve.segments import MutableRetriever

    col = generate_collection(splade_config(n_docs, n_queries, seed=0),
                              value_format="f16")
    Q = np.stack([col.query_dense(i) for i in range(n_queries)])
    n_disp = max(1, n_requests // BUCKET)
    batches = [
        np.asarray(Q[np.arange(i * BUCKET, (i + 1) * BUCKET) % n_queries])
        for i in range(n_disp)
    ]

    # reserve a pool of docs to feed the delta segments; the base is
    # everything else, so corpus size stays ~n_docs at every point
    seg_batch = max(4, n_docs // 128)
    pool = max(SEGMENT_COUNTS) * seg_batch
    base = col.fwd.slice(0, n_docs - pool)
    cfg = RetrieverConfig(engine=engine, codec=codec, k=10)
    m = MutableRetriever.create(base, cfg)

    rows: list[Row] = []
    p95_by_segs: dict[int, float] = {}
    next_doc = base.n_docs
    for segs in SEGMENT_COUNTS:
        while len(m.segments) < segs:
            m.insert([col.fwd.doc(i)
                      for i in range(next_doc, next_doc + seg_batch)])
            next_doc += seg_batch
        if segs and int(m.base_dead.sum()) < 3:
            # a few dead rows in the base: the realistic steady state
            m.delete([1, 3, 5])
        mean_us, p95 = _per_query_us(m, batches)
        p95_by_segs[segs] = p95
        rows.append(Row(
            f"mutation/{engine}-{codec}/segs{segs}/bucket{BUCKET}",
            mean_us * BUCKET,
            f"bucket={BUCKET};us_per_q={mean_us:.1f};"
            f"p95_us_per_q={p95:.1f};qps={1e6 / mean_us:.0f};"
            f"n_live={m.n_live}",
            codec=codec,
        ))

    t0 = time.perf_counter()
    folded = len(m.segments)
    m.merge()
    merge_us = (time.perf_counter() - t0) * 1e6
    rows.append(Row(
        f"mutation/merge/{engine}-{codec}",
        merge_us,
        f"segments_folded={folded};n_live={m.n_live};"
        f"generation={m.generation}",
        codec=codec,
    ))

    mean_us, p95_merged = _per_query_us(m, batches)
    rows.append(Row(
        f"mutation/{engine}-{codec}/merged/bucket{BUCKET}",
        mean_us * BUCKET,
        f"bucket={BUCKET};us_per_q={mean_us:.1f};"
        f"p95_us_per_q={p95_merged:.1f};qps={1e6 / mean_us:.0f};"
        f"n_live={m.n_live}",
        codec=codec,
    ))

    ok = p95_by_segs[1] <= GATE_FACTOR * p95_merged
    rows.append(Row(
        f"mutation/latency-gate/{engine}-{codec}",
        p95_by_segs[1] if ok else float("nan"),
        f"merged_p95_us_per_q={p95_merged:.1f};"
        f"factor={p95_by_segs[1] / p95_merged:.2f};bound={GATE_FACTOR}",
    ))
    return rows
