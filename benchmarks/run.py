"""Benchmark entry point — one section per paper table + kernel/roofline
extras. Prints ``name,us_per_call,derived`` CSV (benchmarks/common.py)
and snapshots the kernel + serving + pipeline + scale + mutation +
overlap families to machine-readable ``BENCH_kernels.json`` /
``BENCH_serve.json`` / ``BENCH_pipeline.json`` /
``BENCH_roofline.json`` / ``BENCH_scale.json`` /
``BENCH_mutation.json`` / ``BENCH_overlap.json`` at the repo root
(schema: name, µs, structured mode/codec, parsed derived metrics, git
sha — see ``common.write_bench_json``) so the perf trajectory is
diffable across PRs.

    PYTHONPATH=src python -m benchmarks.run            # everything
    PYTHONPATH=src python -m benchmarks.run --fast     # reduced sizes
    PYTHONPATH=src python -m benchmarks.run --only table1
    PYTHONPATH=src python -m benchmarks.run --quick    # CI smoke: tier-1
                                                       # pytest + tiny
                                                       # Table-1/2/3 +
                                                       # kernel pass
"""

from __future__ import annotations

import argparse
import os
import subprocess
import sys
import time

from .common import emit, write_bench_json

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _snapshot(kernel_rows, serve_rows, mode: str, pipeline_rows=None,
              n_docs: int | None = None, scale_rows=None,
              mutation_rows=None, overlap_rows=None) -> None:
    """Write the committed snapshots. ``mode`` (quick/fast/full) is
    recorded in the payload so the perf trajectory is only compared
    like-for-like (``n_docs`` likewise, for the kernel family — the
    perf gate re-measures at the committed size); a family is only
    (over)written when its sections ran completely — a partial
    ``--only`` run never drops rows from a committed file."""
    if kernel_rows:
        kmeta = {"mode": mode}
        if n_docs is not None:
            kmeta["n_docs"] = n_docs
        write_bench_json(os.path.join(_ROOT, "BENCH_kernels.json"), kernel_rows,
                         meta=kmeta)
        # the roofline placement derives entirely from the kernel rows
        # (+ any dry-run records on disk), so it snapshots with them
        from . import roofline

        write_bench_json(
            os.path.join(_ROOT, "BENCH_roofline.json"),
            roofline.run() + roofline.kernel_roofline(kernel_rows),
            meta={"mode": mode},
        )
    if serve_rows:
        write_bench_json(os.path.join(_ROOT, "BENCH_serve.json"), serve_rows,
                         meta={"mode": mode})
    if pipeline_rows:
        write_bench_json(os.path.join(_ROOT, "BENCH_pipeline.json"),
                         pipeline_rows, meta={"mode": mode})
    if scale_rows:
        write_bench_json(os.path.join(_ROOT, "BENCH_scale.json"),
                         scale_rows, meta={"mode": mode})
    if mutation_rows:
        write_bench_json(os.path.join(_ROOT, "BENCH_mutation.json"),
                         mutation_rows, meta={"mode": mode})
    if overlap_rows:
        write_bench_json(os.path.join(_ROOT, "BENCH_overlap.json"),
                         overlap_rows, meta={"mode": mode})


def _quick_smoke() -> int:
    """One-command regression gate (``make check``): the tier-1 test
    suite plus a miniature Table-1/2/3 benchmark pass, so codec, layout
    or engine regressions surface even when they only bend a curve."""
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(root, "src")]
        + [p for p in env.get("PYTHONPATH", "").split(os.pathsep) if p]
    )
    print("# tier-1 pytest…", file=sys.stderr, flush=True)
    proc = subprocess.run(
        [sys.executable, "-m", "pytest", "-x", "-q"], cwd=root, env=env
    )
    if proc.returncode:
        return proc.returncode

    from . import (kernel_bench, table1_codecs, table2_seismic, table3_graph,
                   table4_pipeline, table5_scale, table6_mutation,
                   table7_overlap)

    print("# tiny table1/table2/table3/table4/table5/table6/table7 + kernels…",
          file=sys.stderr, flush=True)
    rows = table1_codecs.run(n_docs=400, n_queries=2, rgb_iters=2)
    serve_rows = table2_seismic.run(n_docs=400, n_queries=4)
    serve_rows += table3_graph.run(n_docs=400, n_queries=4)
    kernel_rows = kernel_bench.run(n_docs=300)
    pipeline_rows = table4_pipeline.run(n_docs=400, n_queries=8, n_requests=64)
    scale_rows = table5_scale.run(n_docs_sweep=(2000,), n_queries=16,
                                  n_requests=32)
    mutation_rows = table6_mutation.run(n_docs=1000, n_queries=16,
                                        n_requests=32)
    overlap_rows = table7_overlap.run(n_docs=1000, n_queries=16,
                                      n_requests=8)
    rows += serve_rows + kernel_rows + pipeline_rows + scale_rows
    rows += mutation_rows + overlap_rows
    emit(rows)
    # a NaN latency means no sweep point reached the accuracy level —
    # or, for the pipeline/amortized-gate rows, that bucketed serving
    # failed to beat per-query dispatch — the regression classes this
    # gate exists to catch (a healthy build produces zero NaN rows)
    bad = [r.name for r in rows if r.us != r.us]
    if bad:
        print(f"# quick smoke FAILED: unmet accuracy rows: {bad}", file=sys.stderr)
        return 1
    # snapshot only after the gate passes — a failing run must not
    # overwrite the committed trajectory with regression numbers
    _snapshot(kernel_rows, serve_rows, mode="quick", pipeline_rows=pipeline_rows,
              n_docs=300, scale_rows=scale_rows, mutation_rows=mutation_rows,
              overlap_rows=overlap_rows)
    print(f"# quick smoke OK ({len(rows)} rows)", file=sys.stderr)
    return 0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="reduced collection sizes")
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: tier-1 pytest + tiny table1/table2/table3")
    ap.add_argument("--only", default=None,
                    choices=["table1", "table2", "table3", "table4", "table5",
                             "table6", "table7", "kernel", "roofline"])
    args = ap.parse_args()

    if args.quick:
        sys.exit(_quick_smoke())

    rows = []
    by_section: dict[str, list] = {}
    t0 = time.time()

    def section(name, fn):
        if args.only and args.only != name:
            return
        print(f"# running {name}…", file=sys.stderr, flush=True)
        got = fn()
        by_section[name] = got
        rows.extend(got)

    from . import (kernel_bench, roofline, table1_codecs, table2_seismic,
                   table3_graph, table4_pipeline, table5_scale,
                   table6_mutation, table7_overlap)

    if args.fast:
        section("table1", lambda: table1_codecs.run(n_docs=1500, n_queries=2, rgb_iters=3))
        section("table2", lambda: table2_seismic.run(n_docs=1200, n_queries=6))
        section("table3", lambda: table3_graph.run(n_docs=800, n_queries=6))
        section("table4", lambda: table4_pipeline.run(n_docs=800, n_queries=16,
                                                      n_requests=128))
        section("table5", lambda: table5_scale.run(n_docs_sweep=(2000,),
                                                   n_queries=16, n_requests=64))
        section("table6", lambda: table6_mutation.run(n_docs=1500,
                                                      n_queries=16,
                                                      n_requests=64))
        section("table7", lambda: table7_overlap.run(n_docs=1200,
                                                     n_queries=16,
                                                     n_requests=8))
        section("kernel", lambda: kernel_bench.run(n_docs=800))
    else:
        section("table1", lambda: table1_codecs.run())
        section("table2", lambda: table2_seismic.run())
        section("table3", lambda: table3_graph.run())
        section("table4", lambda: table4_pipeline.run())
        section("table5", lambda: table5_scale.run())
        section("table6", lambda: table6_mutation.run())
        section("table7", lambda: table7_overlap.run())
        section("kernel", lambda: kernel_bench.run())
    section("roofline", roofline.run)

    serve_complete = "table2" in by_section and "table3" in by_section
    _snapshot(
        by_section.get("kernel", []),
        by_section.get("table2", []) + by_section.get("table3", [])
        if serve_complete else [],
        mode="fast" if args.fast else "full",
        pipeline_rows=by_section.get("table4", []),
        n_docs=800 if args.fast else 2000,
        scale_rows=by_section.get("table5", []),
        mutation_rows=by_section.get("table6", []),
        overlap_rows=by_section.get("table7", []),
    )
    emit(rows)
    print(f"# total {time.time()-t0:.0f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
