"""Benchmark entry point — one section per paper table + kernel/roofline
extras. Prints ``name,us_per_call,derived`` CSV (benchmarks/common.py).

    PYTHONPATH=src python -m benchmarks.run            # everything
    PYTHONPATH=src python -m benchmarks.run --fast     # reduced sizes
    PYTHONPATH=src python -m benchmarks.run --only table1
"""

from __future__ import annotations

import argparse
import sys
import time

from .common import emit


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="reduced collection sizes")
    ap.add_argument("--only", default=None,
                    choices=["table1", "table2", "kernel", "roofline"])
    args = ap.parse_args()

    rows = []
    t0 = time.time()

    def section(name, fn):
        if args.only and args.only != name:
            return
        print(f"# running {name}…", file=sys.stderr, flush=True)
        rows.extend(fn())

    from . import kernel_bench, roofline, table1_codecs, table2_seismic

    if args.fast:
        section("table1", lambda: table1_codecs.run(n_docs=1500, n_queries=2, rgb_iters=3))
        section("table2", lambda: table2_seismic.run(n_docs=1200, n_queries=6))
        section("kernel", lambda: kernel_bench.run(n_docs=800))
    else:
        section("table1", lambda: table1_codecs.run())
        section("table2", lambda: table2_seismic.run())
        section("kernel", lambda: kernel_bench.run())
    section("roofline", roofline.run)

    emit(rows)
    print(f"# total {time.time()-t0:.0f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
