"""Table 4 — online serving pipeline throughput (EXPERIMENTS.md
§Throughput).

Two measurement families over the same synthetic collection:

* ``pipeline/<engine>-<codec>/bucketB`` — amortized batching curve:
  the full request stream dispatched through ONE warm compiled plan at
  bucket B (exact-fit batches, no scheduler). ``us`` is the wall time
  per dispatch; ``derived`` carries ``bucket``, ``us_per_q`` (the
  amortized per-query cost — the number that must FALL as B grows)
  and ``qps``. Bucket 1 is the per-query-dispatch baseline the paper's
  single-query latency story corresponds to.

* ``pipeline/sched/<engine>-<codec>`` — the closed-loop scheduler:
  a repeat-heavy trace driven through the full Pipeline (deadline
  coalescing + result cache); derived carries qps, hit_rate and the
  latency percentiles.

The ``pipeline/amortized-gate/*`` rows encode the acceptance
criterion: ``us`` is the bucket-8 amortized per-query cost when it is
strictly below the bucket-1 baseline, NaN otherwise — a NaN row fails
``benchmarks.run --quick`` (the standing accuracy-gate convention).

All numbers are CPU-XLA wall clock (see EXPERIMENTS.md §Methodology);
the *shape* of the curve — amortization with bucket size — is the
reproducible claim, not the absolute µs.
"""

from __future__ import annotations

import numpy as np

from .common import Row, timeit_us

#: engine×codec cells measured; seismic exercises the vmap'd two-phase
#: dispatch, flat the decode-once/score-many shared-candidate batch
CELLS = (
    ("flat", "uncompressed"),
    ("flat", "streamvbyte"),
    ("seismic", "streamvbyte"),
)
BUCKETS = (1, 8, 32)


def _engine_params(n_docs: int) -> dict:
    return {
        "flat": {},
        "seismic": dict(cut=8, block_budget=256, n_probe=48,
                        n_postings=max(200, n_docs // 2), block_size=32),
    }


def run(n_docs: int = 4000, n_queries: int = 64, n_requests: int = 256):
    from repro.data.synthetic import generate_collection, splade_config
    from repro.serve.api import Retriever, RetrieverConfig

    col = generate_collection(splade_config(n_docs, n_queries, seed=0),
                              value_format="f16")
    Q = np.stack([col.query_dense(i) for i in range(col.n_queries)])
    params = _engine_params(n_docs)

    rows: list[Row] = []
    for engine, codec in CELLS:
        r = Retriever.build(
            col.fwd,
            RetrieverConfig(engine=engine, codec=codec, k=10,
                            params=params[engine]),
        )
        us_per_q: dict[int, float] = {}
        for bucket in BUCKETS:
            plan = r.plans.get(bucket)
            n_disp = max(1, n_requests // bucket)
            batches = [
                np.asarray(Q[np.arange(i * bucket, (i + 1) * bucket) % n_queries])
                for i in range(n_disp)
            ]

            def stream():
                for b in batches:
                    plan(b)[0].block_until_ready()

            us = timeit_us(stream) / n_disp
            us_per_q[bucket] = us / bucket
            rows.append(Row(
                f"pipeline/{engine}-{codec}/bucket{bucket}",
                us,
                f"bucket={bucket};us_per_q={us_per_q[bucket]:.1f};"
                f"qps={1e6 / us_per_q[bucket]:.0f}",
            ))
        # acceptance gate: amortized per-query cost at bucket ≥ 8 must
        # be strictly below the bucket-1 (per-query dispatch) baseline
        ok = us_per_q[8] < us_per_q[1]
        rows.append(Row(
            f"pipeline/amortized-gate/{engine}-{codec}",
            us_per_q[8] if ok else float("nan"),
            f"bucket1_us_per_q={us_per_q[1]:.1f};speedup="
            f"{us_per_q[1] / us_per_q[8]:.2f}",
        ))

    # closed-loop scheduler over a repeat-heavy trace (result cache on)
    from repro.serve.pipeline import synthetic_trace

    engine, codec = "flat", "streamvbyte"
    r = Retriever.build(
        col.fwd,
        RetrieverConfig(engine=engine, codec=codec, k=10,
                        params=params[engine]),
    )
    rng = np.random.default_rng(1)
    trace = synthetic_trace(rng, n_requests, n_queries)

    def drive(pipe):
        for qi in trace:
            pipe.poll()
            pipe.submit(Q[qi])
        pipe.flush()

    # warm-up pass: compile every plan the trace's dispatch pattern can
    # reach (shared r.plans), so the committed sched row measures the
    # steady state, not XLA compiles — matching the bucketB family's
    # timeit_us warmup
    drive(r.pipeline(deadline_us=500.0, cache_size=0))
    pipe = r.pipeline(deadline_us=500.0)
    drive(pipe)
    snap = pipe.snapshot()
    rows.append(Row(
        f"pipeline/sched/{engine}-{codec}",
        1e6 / snap["qps"] if snap["qps"] > 0 else float("nan"),
        f"qps={snap['qps']:.0f};hit_rate={snap['cache_hit_rate']:.2f};"
        f"p50_us={snap['p50_us']:.0f};p95_us={snap['p95_us']:.0f};"
        f"p99_us={snap['p99_us']:.0f};recompiles={snap['recompiles']}",
    ))
    return rows
