"""§Roofline builders.

Two sections feed ``BENCH_roofline.json`` (EXPERIMENTS.md §Roofline):

* the **training dry-run** section — reads the dry-run JSON records
  (experiments/dryrun/<mesh>/) and renders per-(arch × shape) roofline
  terms. Run the dry-run first: ``PYTHONPATH=src python -m
  repro.launch.dryrun``;
* the **kernel** section — places every measured codec × mode scoring
  kernel on the bytes/FLOP roofline of a nominal TPU. Rows are selected
  by the structured ``mode``/``codec``/``derived`` fields of the kernel
  bench (never by parsing names): arithmetic intensity =
  ``flops_per_q / hbm_bytes_per_q``, and the projected bound is
  ``max(flops/peak, bytes/bw)``. LSR scoring sits far left of the ridge
  point, so HBM bytes — i.e. the compression ratio — IS the kernel's
  speed on accelerator hardware; that is the paper's thesis restated as
  a roofline position.

CLI: ``PYTHONPATH=src python -m benchmarks.roofline`` prints the
markdown tables for both sections.
"""

from __future__ import annotations

import json
import os

from .common import Row, _parse_derived

__all__ = [
    "load_records", "markdown_table", "kernel_roofline",
    "kernel_markdown_table", "run",
]

#: nominal accelerator for the projection — TPU v5e, matching the
#: dry-run conventions (EXPERIMENTS.md §Roofline): 819 GB/s HBM, and a
#: nominal 3 TFLOP/s f32 VPU path (sparse scoring never touches the
#: MXU, so the bf16 peak is irrelevant); ridge ≈ 3.7 FLOP/B
HBM_BYTES_PER_S = 8.19e11
PEAK_VPU_FLOPS = 3.0e12


def load_records(base: str = "experiments/dryrun", mesh: str = "pod256") -> list[dict]:
    d = os.path.join(base, mesh)
    recs = []
    if not os.path.isdir(d):
        return recs
    for name in sorted(os.listdir(d)):
        if name.endswith(".json"):
            with open(os.path.join(d, name)) as f:
                recs.append(json.load(f))
    return recs


def markdown_table(recs: list[dict]) -> str:
    head = (
        "| arch | shape | kind | compute_s | memory_s | collective_s | dominant "
        "| mem/dev GiB | MODEL_FLOPs | useful ratio | MFU bound |\n"
        "|---|---|---|---|---|---|---|---|---|---|---|\n"
    )
    lines = []
    for r in recs:
        ro = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['kind']} "
            f"| {ro['compute_s']:.2e} | {ro['memory_s']:.2e} | {ro['collective_s']:.2e} "
            f"| **{ro['dominant']}** "
            f"| {r['memory']['peak_device_bytes']/2**30:.2f} "
            f"| {ro['model_flops']:.2e} | {ro['useful_flops_ratio']:.3f} "
            f"| {ro['mfu_upper_bound']:.3f} |"
        )
    return head + "\n".join(lines)


def kernel_roofline(kernel_rows: list[Row]) -> list[Row]:
    """Project every kernel-bench row that carries both roofline terms
    onto the nominal TPU roofline.

    Selection is purely structural: a row participates iff ``row.mode``
    and ``row.codec`` are set and its derived metrics include
    ``hbm_bytes_per_q`` and ``flops_per_q``. Emitted µs is the
    roofline-bound time per query on the nominal accelerator; derived
    records the intensity, which side of the ridge the kernel sits on,
    and the measured CPU µs it was projected from."""
    out: list[Row] = []
    for r in kernel_rows:
        if r.mode is None or r.codec is None:
            continue
        d = _parse_derived(r.derived)
        bytes_q, flops_q = d.get("hbm_bytes_per_q"), d.get("flops_per_q")
        if not bytes_q or not flops_q:
            continue
        family = r.name.split("/")[1] if "/" in r.name else r.name
        mem_s = bytes_q / HBM_BYTES_PER_S
        cmp_s = flops_q / PEAK_VPU_FLOPS
        bound_us = max(mem_s, cmp_s) * 1e6
        intensity = flops_q / bytes_q
        # the value codec joins the identity AFTER the codec component,
        # so ``name.split("/")`` positions stay stable for f16 rows
        vq_suffix = f"+{r.vq}" if r.vq else ""
        out.append(
            Row(
                f"roofline/kernel/{family}/{r.mode}/{r.codec}{vq_suffix}",
                bound_us,
                f"intensity_flop_per_byte={intensity:.2f};"
                f"dominant={'memory' if mem_s >= cmp_s else 'compute'};"
                f"hbm_bytes_per_q={bytes_q:.0f};flops_per_q={flops_q:.0f};"
                f"measured_cpu_us={r.us:.1f}",
                mode=r.mode, codec=r.codec, vq=r.vq,
            )
        )
    return out


def kernel_markdown_table(roof_rows: list[Row]) -> str:
    head = (
        "| kernel | mode | codec | vq | FLOP/B | dominant | HBM B/q "
        "| bound µs/q (nominal TPU) |\n|---|---|---|---|---|---|---|---|\n"
    )
    lines = []
    for r in roof_rows:
        d = _parse_derived(r.derived)
        family = r.name.split("/")[2]
        lines.append(
            f"| {family} | {r.mode} | {r.codec} | {r.vq or 'f16'} "
            f"| {d['intensity_flop_per_byte']:.2f} | {d['dominant']} "
            f"| {d['hbm_bytes_per_q']:.0f} | {r.us:.1f} |"
        )
    return head + "\n".join(lines)


def run() -> list[Row]:
    """Dry-run section only (kernel section needs the measured kernel
    rows — ``benchmarks.run`` composes the two into the snapshot)."""
    rows: list[Row] = []
    for mesh in ("pod256", "pod512x2"):
        for r in load_records(mesh=mesh):
            ro = r["roofline"]
            rows.append(
                Row(
                    f"roofline/{mesh}/{r['arch']}/{r['shape']}",
                    ro["step_lower_bound_s"] * 1e6,
                    f"dominant={ro['dominant']};mfu_bound={ro['mfu_upper_bound']:.3f}",
                )
            )
    return rows


if __name__ == "__main__":
    for mesh in ("pod256", "pod512x2"):
        recs = load_records(mesh=mesh)
        if recs:
            print(f"\n## {mesh}\n")
            print(markdown_table(recs))
    from . import kernel_bench

    roof = kernel_roofline(kernel_bench.run(n_docs=300, modes=("jnp", "pallas_compiled"),
                                            sweep=False))
    print("\n## kernel roofline (codec × mode)\n")
    print(kernel_markdown_table(roof))
