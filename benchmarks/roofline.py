"""§Roofline table builder: reads the dry-run JSON records
(experiments/dryrun/<mesh>/) and renders the per-(arch × shape) roofline
terms as markdown for EXPERIMENTS.md.

Run the dry-run first:  PYTHONPATH=src python -m repro.launch.dryrun
Then:                    PYTHONPATH=src python -m benchmarks.roofline
"""

from __future__ import annotations

import json
import os

from .common import Row

__all__ = ["load_records", "markdown_table", "run"]


def load_records(base: str = "experiments/dryrun", mesh: str = "pod256") -> list[dict]:
    d = os.path.join(base, mesh)
    recs = []
    if not os.path.isdir(d):
        return recs
    for name in sorted(os.listdir(d)):
        if name.endswith(".json"):
            with open(os.path.join(d, name)) as f:
                recs.append(json.load(f))
    return recs


def markdown_table(recs: list[dict]) -> str:
    head = (
        "| arch | shape | kind | compute_s | memory_s | collective_s | dominant "
        "| mem/dev GiB | MODEL_FLOPs | useful ratio | MFU bound |\n"
        "|---|---|---|---|---|---|---|---|---|---|---|\n"
    )
    lines = []
    for r in recs:
        ro = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['kind']} "
            f"| {ro['compute_s']:.2e} | {ro['memory_s']:.2e} | {ro['collective_s']:.2e} "
            f"| **{ro['dominant']}** "
            f"| {r['memory']['peak_device_bytes']/2**30:.2f} "
            f"| {ro['model_flops']:.2e} | {ro['useful_flops_ratio']:.3f} "
            f"| {ro['mfu_upper_bound']:.3f} |"
        )
    return head + "\n".join(lines)


def run() -> list[Row]:
    rows: list[Row] = []
    for mesh in ("pod256", "pod512x2"):
        for r in load_records(mesh=mesh):
            ro = r["roofline"]
            rows.append(
                Row(
                    f"roofline/{mesh}/{r['arch']}/{r['shape']}",
                    ro["step_lower_bound_s"] * 1e6,
                    f"dominant={ro['dominant']};mfu_bound={ro['mfu_upper_bound']:.3f}",
                )
            )
    return rows


if __name__ == "__main__":
    for mesh in ("pod256", "pod512x2"):
        recs = load_records(mesh=mesh)
        if recs:
            print(f"\n## {mesh}\n")
            print(markdown_table(recs))
