"""End-to-end serving driver (deliverable b): serve a small collection
with batched requests through the static TPU engine.

Builds SPLADE + LILSR collections, constructs Seismic indexes, runs
batched search with uncompressed vs DotVByte forward indexes, and
reports recall / per-query latency / index bytes — the serving analogue
of the paper's Table 2.

Run:  PYTHONPATH=src python examples/retrieval_serving.py [--n-docs 8000]
"""

import argparse
import time

import jax.numpy as jnp
import numpy as np

from repro.core.seismic import SeismicIndex, SeismicParams, exact_top_k, recall_at_k
from repro.data.synthetic import generate_collection, lilsr_config, splade_config
from repro.serve.engine import BatchedSeismic, EngineConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-docs", type=int, default=6000)
    ap.add_argument("--n-queries", type=int, default=48)
    ap.add_argument("--k", type=int, default=10)
    args = ap.parse_args()

    for enc, cfg_fn in (("splade", splade_config), ("lilsr", lilsr_config)):
        print(f"\n=== {enc}: {args.n_docs} docs ===")
        col = generate_collection(cfg_fn(args.n_docs, args.n_queries, seed=0),
                                  value_format="f16")
        index = SeismicIndex.build(col.fwd, SeismicParams(n_postings=1500, block_size=64))
        Q = jnp.asarray(np.stack([col.query_dense(i) for i in range(args.n_queries)]))
        truth = [exact_top_k(col.fwd, np.asarray(Q[i]), args.k)[0]
                 for i in range(args.n_queries)]

        for codec in ("uncompressed", "dotvbyte"):
            engine = BatchedSeismic(
                index, EngineConfig(cut=8, block_budget=512, n_probe=96, k=args.k,
                                    codec=codec))
            ids, _ = engine.search_batch(Q)  # warm-up / compile
            t0 = time.perf_counter()
            ids, _ = engine.search_batch(Q)
            np.asarray(ids)
            dt = (time.perf_counter() - t0) * 1e6 / args.n_queries
            rec = np.mean([recall_at_k(truth[i], np.asarray(ids[i]))
                           for i in range(args.n_queries)])
            comp = col.fwd.storage_bytes(codec)["components"]
            print(f"  {codec:13s} recall@{args.k}={rec:.3f} "
                  f"{dt:8.0f} µs/query (CPU)  components={comp/2**20:6.2f} MiB")


if __name__ == "__main__":
    main()
